#!/usr/bin/env bash
# Tier-1 verify gate — the exact command from ROADMAP.md, reproducible.
#   ./scripts/tier1.sh            # full suite
#   ./scripts/tier1.sh -m 'not slow'   # quick pass (extra args forwarded)
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
# The serving path (model bank + cell-routed engine) and the streaming
# pipeline (bitwise cell-plan parity, wave training) are part of the default
# gate: when extra args filter the main run, still verify them explicitly.
if [ "$#" -gt 0 ]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    tests/test_serve_svm.py tests/test_pipeline.py
fi
