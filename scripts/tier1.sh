#!/usr/bin/env bash
# Tier-1 verify gate — the exact command from ROADMAP.md, reproducible.
#   ./scripts/tier1.sh            # full suite
#   ./scripts/tier1.sh -m 'not slow'   # quick pass (extra args forwarded)
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q "$@"
