#!/usr/bin/env bash
# Tier-1 verify gate — the exact command from ROADMAP.md, reproducible.
#   ./scripts/tier1.sh            # full suite + CLI smoke
#   ./scripts/tier1.sh -m 'not slow'   # quick pass (extra args forwarded)
set -euo pipefail
cd "$(dirname "$0")/.."
# Injected deadlocks in the fault suite must FAIL the gate, not hang it:
# with pytest-timeout installed every test gets a hard cap; without it
# the SIGALRM fallback in tests/conftest.py honours the same `timeout`
# markers (the fault tests all carry one).
TIMEOUT_ARGS=""
if python -c 'import pytest_timeout' 2>/dev/null; then
  TIMEOUT_ARGS="--timeout=120 --timeout-method=thread"
fi
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
  $TIMEOUT_ARGS "$@"
# The serving path (model bank + cell-routed engine), the async/overlap
# serving conformance suite (swap conservation included), the fault
# injection suite (crash-safe checkpoints, wave preemption, hot swap,
# overload shedding), the ChunkSource contract, the streaming pipeline
# (bitwise cell-plan parity, wave training) and the staged
# train->select->test API are part of the default gate: when extra args
# filter the main run, still verify them explicitly (quick hypothesis
# profiles only — the large profiles carry the slow marker).
if [ "$#" -gt 0 ]; then
  PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -x -q \
    $TIMEOUT_ARGS -m 'not slow' \
    tests/test_serve_svm.py tests/test_serve_async.py tests/test_faults.py \
    tests/test_sources_contract.py tests/test_pipeline.py \
    tests/test_staged_api.py
fi

# CLI smoke: the staged cycle as three separate processes on tiny synthetic
# data — train writes the surface, select re-picks under NPL, test streams.
SMOKE=$(mktemp -d)
trap 'rm -rf "$SMOKE"' EXIT
PYTHONPATH=src python - "$SMOKE" <<'PY'
import sys
import numpy as np
from repro.data.synthetic import covtype_like, train_test_split
x, y = covtype_like(n=300, d=4, seed=0, label_noise=0.05, n_modes=3)
xtr, ytr, xte, yte = train_test_split(x, np.where(y == 0, -1, 1), 0.25, 0)
d = sys.argv[1]
np.save(f"{d}/xtr.npy", xtr); np.save(f"{d}/ytr.npy", ytr)
np.save(f"{d}/xte.npy", xte); np.save(f"{d}/yte.npy", yte)
PY
PYTHONPATH=src python -m repro.cli train --data "$SMOKE/xtr.npy" \
  --labels "$SMOKE/ytr.npy" --model-dir "$SMOKE/model" --scenario npl \
  -S FOLDS=2 -S MAX_ITERATIONS=150 -S ADAPTIVITY_CONTROL=1 \
  -S WEIGHTS='0.5 1.0 2.0' > /dev/null
PYTHONPATH=src python -m repro.cli select --model-dir "$SMOKE/model" \
  -S NPL_CONSTRAINT=0.05 > /dev/null
PYTHONPATH=src python -m repro.cli test --data "$SMOKE/xte.npy" \
  --labels "$SMOKE/yte.npy" --model-dir "$SMOKE/model"
# serve: cold-start the async engine from bank/ alone, latency-bounded,
# with the hot-swap watcher, a bounded admission queue, the health
# monitor (SLO + drift keys), and the observability keys (tracing +
# metrics/trace export) enabled
PYTHONPATH=src python -m repro.cli serve --data "$SMOKE/xte.npy" \
  --model-dir "$SMOKE/model" --wave 16 -S DEADLINE_MS=5 \
  -S SWAP_POLL_MS=50 -S MAX_QUEUE=4096 --swap-watch \
  -S SLO_P99_MS=500 -S DRIFT_WINDOW=5 -S DRIFT_REFRESH_THRESHOLD=3 \
  -S TRACE=1 -S METRICS_OUT="$SMOKE/metrics.jsonl" \
  -S TRACE_OUT="$SMOKE/trace.jsonl" \
  --out "$SMOKE/pred.npy" > "$SMOKE/serve_out.json"
PYTHONPATH=src python - "$SMOKE" <<'PY'
import sys
import numpy as np
pred = np.load(f"{sys.argv[1]}/pred.npy")
yte = np.load(f"{sys.argv[1]}/yte.npy")
assert pred.shape == yte.shape, (pred.shape, yte.shape)
assert (pred == np.sign(yte)).mean() > 0.5, "serve predictions degenerate"
PY

# metrics-schema smoke: the serve run above exported its registry via
# METRICS_OUT — the JSONL must validate against repro.obs.metrics.v1
# (operator dashboards pin this schema; drift fails the gate here), and
# the serve payload must carry the per-request stage breakdown + trace
PYTHONPATH=src python - "$SMOKE" <<'PY'
import json
import sys
from repro.obs.metrics import MetricsRegistry, validate_jsonl
d = sys.argv[1]
errs = validate_jsonl(f"{d}/metrics.jsonl")
assert errs == [], f"metrics JSONL schema drift: {errs}"
reg, header = MetricsRegistry.read_jsonl(f"{d}/metrics.jsonl")
assert header["stage"] == "serve", header
served = reg.counter("serve.served").value
assert served > 0 and reg.histogram("serve.request_ms").count == served
payload = json.load(open(f"{d}/serve_out.json"))
assert set(payload["per_stage"]) == {"queue", "pack", "dispatch",
                                     "device", "collect"}, payload
assert "serve.pack" in payload["trace"], sorted(payload["trace"])
# health monitor keys attached a HealthMonitor: the payload carries the
# structured verdict (drift baseline recorded at to_bank time, SLO state)
h = payload["health"]
assert h["status"] in ("ok", "degraded", "breaching"), h
assert h["drift"]["baseline"] is True, h
assert "burn_rate" in h["slo"], h
assert "deadline_miss_ratio" in h, h
PY

# trace-schema smoke: TRACE_OUT dumped the retained span window — the
# JSONL must validate against repro.obs.trace.v1 (same contract as the
# metrics schema above: operator tooling pins it, drift fails the gate)
PYTHONPATH=src python - "$SMOKE" <<'PY'
import json
import sys
from repro.obs.trace import validate_trace_jsonl
d = sys.argv[1]
errs = validate_trace_jsonl(f"{d}/trace.jsonl")
assert errs == [], f"trace JSONL schema drift: {errs}"
payload = json.load(open(f"{d}/serve_out.json"))
assert payload["trace_out"] == f"{d}/trace.jsonl", payload.get("trace_out")
PY

# CLI failure modes: missing/incomplete artifacts must exit non-zero with
# an actionable message (which stage to run), never a raw traceback
if PYTHONPATH=src python -m repro.cli select \
    --model-dir "$SMOKE/nomodel" 2> "$SMOKE/err.txt"; then
  echo "tier1: select on a missing model dir must fail"; exit 1
fi
grep -q "missing 'train/'" "$SMOKE/err.txt"
grep -q "repro.cli train" "$SMOKE/err.txt"
if PYTHONPATH=src python -m repro.cli test --data "$SMOKE/xte.npy" \
    --labels "$SMOKE/yte.npy" \
    --model-dir "$SMOKE/nomodel" 2> "$SMOKE/err.txt"; then
  echo "tier1: test on a missing model dir must fail"; exit 1
fi
grep -q "missing 'select/'" "$SMOKE/err.txt"
mkdir -p "$SMOKE/torn/train"           # dir exists but artifact is torn
if PYTHONPATH=src python -m repro.cli select \
    --model-dir "$SMOKE/torn" 2> "$SMOKE/err.txt"; then
  echo "tier1: select on a torn train artifact must fail"; exit 1
fi

echo "tier1: CLI smoke OK"

# embed-cycle smoke: the LM-embedding vertical as separate processes —
# embed materializes the frozen-backbone cache under <model-dir>/embed,
# train/select run over the replayed shards, serve takes raw TOKENS and
# reports the co-located embed->route->blend breakdown (embed stage
# present in per_stage)
PYTHONPATH=src python - "$SMOKE" <<'PY'
import sys
import numpy as np
rng = np.random.default_rng(0)
n = 120
tok = np.concatenate([rng.integers(0, 250, size=(n // 2, 12)),
                      rng.integers(250, 500, size=(n // 2, 12))]
                     ).astype(np.int32)
y = np.repeat([-1.0, 1.0], n // 2)
perm = rng.permutation(n)
d = sys.argv[1]
np.save(f"{d}/tok.npy", tok[perm]); np.save(f"{d}/ytok.npy", y[perm])
PY
PYTHONPATH=src python -m repro.cli embed --tokens "$SMOKE/tok.npy" \
  --model-dir "$SMOKE/emodel" -S EMBED_ARCH=stablelm-1.6b:smoke \
  -S EMBED_BATCH=32 > /dev/null
PYTHONPATH=src python -m repro.cli train --data "$SMOKE/emodel/embed" \
  --labels "$SMOKE/ytok.npy" --model-dir "$SMOKE/emodel" \
  -S FOLDS=2 -S MAX_ITERATIONS=150 > /dev/null
PYTHONPATH=src python -m repro.cli select --model-dir "$SMOKE/emodel" \
  > /dev/null
PYTHONPATH=src python -m repro.cli serve --tokens "$SMOKE/tok.npy" \
  --model-dir "$SMOKE/emodel" --wave 32 > "$SMOKE/embed_serve_out.json"
PYTHONPATH=src python - "$SMOKE" <<'PY'
import json
import sys
payload = json.load(open(f"{sys.argv[1]}/embed_serve_out.json"))
assert set(payload["per_stage"]) == {"queue", "pack", "dispatch", "device",
                                     "collect", "embed"}, payload
assert payload["per_stage"]["embed"]["total_ms"] > 0, payload
PY

echo "tier1: embed cycle OK"

# fused-solver parity smoke: (1) training with the wave-fused CD polish
# (SOLVER_POLISH) must leave every argmin (gamma, lambda) decision of the
# FISTA-only path unchanged and keep coefs inside the tol band; (2) the
# fused wave CD launch must agree with per-slot launches within solver
# tolerance (the cd_solver wave-fusion contract, end to end)
PYTHONPATH=src python - <<'PY'
import numpy as np
import jax.numpy as jnp
from repro.api import SVM
from repro.data.synthetic import covtype_like, train_test_split
from repro.kernels.cd_solver import ops as cd_ops
from repro.train.svm_trainer import SVMTrainerConfig

x, y = covtype_like(n=240, d=4, seed=5, label_noise=0.05, n_modes=3)
xtr, ytr, _, _ = train_test_split(x, np.where(y == 0, -1, 1), 0.25, 5)
sels = {}
for pol in (0, 2):
    cfg = SVMTrainerConfig(n_folds=2, max_iters=150, adaptivity_control=1,
                           cd_polish=pol)
    sess = SVM(xtr, ytr, config=cfg)
    sess.train()
    sels[pol] = sess.select("argmin")
plain, polished = sels[0], sels[2]
assert np.array_equal(plain.gamma, polished.gamma), \
    "cd_polish moved an argmin gamma decision"
assert np.array_equal(plain.lam, polished.lam), \
    "cd_polish moved an argmin lambda decision"
diff = float(np.max(np.abs(plain.coefs - polished.coefs)))
assert diff <= 50 * plain.cv_cfg.tol, \
    f"polished coefs drifted {diff} beyond the tol band"

rng = np.random.default_rng(0)
s, n, p = 3, 96, 4
a = rng.normal(size=(s, n, n)).astype(np.float32)
k = jnp.asarray(np.einsum("sij,skj->sik", a, a) / n
                + np.eye(n, dtype=np.float32))
yv = jnp.asarray(rng.normal(size=(s, n, p)), jnp.float32)
hi = jnp.asarray(np.abs(rng.normal(size=(s, n, p))) + 0.1, jnp.float32)
lo, c0 = -hi, jnp.zeros((s, n, p), jnp.float32)
fused = cd_ops.cd_epochs_wave(k, yv, lo, hi, c0, epochs=3)
for i in range(s):
    slot = cd_ops.cd_epochs(k[i], yv[i], lo[i], hi[i], c0[i], epochs=3)
    gap = float(jnp.max(jnp.abs(fused[i] - slot)))
    assert gap <= 1e-3, f"wave slot {i} disagrees with per-slot launch: {gap}"
print("tier1: fused-solver parity OK")
PY

# perf-regression gate: compare a fresh quick-mode drain against the
# committed BENCH_serve.json baselines (wide tolerances — catches
# collapses, not machine noise; REPRO_SKIP_REGRESSION=1 for the
# baseline-only validation)
PYTHONPATH=src python -m benchmarks.check_regression

echo "tier1: OK"
