"""Serving subsystem: model bank compaction, cell-routed engine, wave plan.

Contract under test, end to end:

  * the engine's one-launch-per-step batched path is BITWISE equal (f32) to
    looping per-cell ``TrainedSVM.decision_function`` at the same padded
    launch shapes (batching must not change numerics);
  * compaction (zero-row drop + dedup) and the checkpoint round-trip
    preserve decisions — compact -> serialize -> load -> identical;
  * the fused batched Pallas kernel matches the distance-cache oracle;
  * a 3-class OvA model trained with cells serves correct class values
    through the bank (accuracy + agreement with the estimator);
  * ``plan_wave`` chunking/padding/LPT invariants.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from repro.data.synthetic import banana_mc, train_test_split
from repro.distributed.planner import plan_wave
from repro.kernels.svm_predict.ops import svm_predict_cells
from repro.kernels.svm_predict.ref import svm_predict_cells_ref
from repro.core.svm import TrainedSVM, train_select
from repro.core.svm import test_error as svm_test_error
from repro.serve.model_bank import ModelBank, _dedup_rows
from repro.serve.svm_engine import SVMEngine
from repro.tasks.builder import make_tasks
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig


def _random_bank(seed=0, n_cells=4, k=40, d=6, t_count=2, s_count=3,
                 zero_frac=0.0, **kwargs):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_cells, d)).astype(np.float32) * 4
    sv = (centers[:, None, :] + rng.normal(size=(n_cells, k, d))).astype(np.float32)
    coefs = rng.normal(size=(n_cells, k, t_count, s_count)).astype(np.float32)
    if zero_frac:
        coefs[rng.random((n_cells, k)) < zero_frac] = 0.0
    gamma = rng.uniform(0.5, 3.0, size=(n_cells, t_count, s_count)).astype(np.float32)
    mask = np.ones((n_cells, k), np.float32)
    bank = ModelBank.from_cells(sv, mask, coefs, gamma, centers, **kwargs)
    queries = (centers[rng.integers(0, n_cells, 30)]
               + rng.normal(size=(30, d)) * 0.5).astype(np.float32)
    return bank, queries


class TestWavePlan:
    def test_hot_cell_is_chunked_not_padded(self):
        counts = np.array([3, 100, 0, 5])
        plan = plan_wave(counts, m_pad=8)
        assert plan.n_requests == 108
        hot = plan.slot_cell == 1
        assert hot.sum() == 13            # ceil(100 / 8)
        # each cell's chunks cover its queue exactly, in order
        offs = np.sort(plan.slot_off[hot])
        assert offs[0] == 0 and plan.slot_take[hot].sum() == 100

    def test_lpt_order_and_slot_padding(self):
        plan = plan_wave(np.array([1, 9, 2]), m_pad=4, slot_bucket=4)
        takes = plan.slot_take
        assert (takes[:-1] >= takes[1:]).all()       # largest first
        assert plan.n_slots % 4 == 0
        assert (plan.slot_cell[takes == 0] == -1).all()

    def test_auto_m_pad_ignores_outlier(self):
        counts = np.zeros(50, np.int64)
        counts[:49] = 6
        counts[49] = 500                              # one viral cell
        plan = plan_wave(counts, row_bucket=8)
        assert plan.m_pad <= 16                       # p75 of loads, not max
        assert plan.n_requests == int(counts.sum())
        assert plan.pad_fraction < 0.5

    def test_empty(self):
        plan = plan_wave(np.zeros(4, np.int64))
        assert plan.n_slots == 0 and plan.n_requests == 0


class TestCompaction:
    def test_zero_rows_dropped_decisions_kept(self):
        bank, q = _random_bank(seed=1, zero_frac=0.6, drop_tol=0.0)
        assert int(bank.sv_count.sum()) < bank.raw_sv_total
        full_bank, _ = _random_bank(seed=1, zero_frac=0.6, drop_tol=None,
                                    dedup=False)
        x = jnp.asarray(q[:8])
        for c in range(bank.n_cells):
            got = np.asarray(bank.cell_model(c).decision_function(x))
            ref = np.asarray(full_bank.cell_model(c).decision_function(x))
            np.testing.assert_allclose(got, ref, atol=2e-6)

    def test_dedup_merges_duplicate_rows(self):
        rng = np.random.default_rng(3)
        sv = rng.normal(size=(6, 4)).astype(np.float32)
        sv[4] = sv[1]                                  # exact duplicate
        coefs = rng.normal(size=(6, 2)).astype(np.float32)
        out_sv, out_co = _dedup_rows(sv, coefs)
        assert out_sv.shape[0] == 5
        np.testing.assert_array_equal(out_sv[1], sv[1])
        np.testing.assert_allclose(out_co[1], coefs[1] + coefs[4], atol=1e-7)
        # decision values preserved: k(x, u) identical for identical u
        x = rng.normal(size=(3, 4)).astype(np.float32)
        k_full = np.exp(-((x[:, None] - sv[None]) ** 2).sum(-1))
        k_comp = np.exp(-((x[:, None] - out_sv[None]) ** 2).sum(-1))
        np.testing.assert_allclose(k_full @ coefs, k_comp @ out_co, atol=1e-5)

    def test_dedup_noop_is_identity(self):
        rng = np.random.default_rng(4)
        sv = rng.normal(size=(5, 3)).astype(np.float32)
        coefs = rng.normal(size=(5, 2)).astype(np.float32)
        out_sv, out_co = _dedup_rows(sv, coefs)
        assert (out_sv == sv).all() and (out_co == coefs).all()

    def test_checkpoint_roundtrip_identical_decisions(self, tmp_path):
        bank, q = _random_bank(seed=2, zero_frac=0.5, drop_tol=0.0)
        x = jnp.asarray(q[:6])
        before = np.asarray(bank.cell_model(0).decision_function(x))
        bank.save(str(tmp_path))
        loaded = ModelBank.load(str(tmp_path))
        for f in ("sv", "coefs", "gammas", "sv_count", "centers",
                  "feat_mean", "feat_std", "classes", "pairs"):
            np.testing.assert_array_equal(getattr(bank, f), getattr(loaded, f))
        assert (loaded.kernel, loaded.n_tasks, loaded.n_sub) == \
            (bank.kernel, bank.n_tasks, bank.n_sub)
        after = np.asarray(loaded.cell_model(0).decision_function(x))
        np.testing.assert_array_equal(before, after)   # bitwise

    def test_bf16_storage_halves_bytes_keeps_decisions(self, tmp_path):
        bank32, q = _random_bank(seed=5, drop_tol=None, dedup=False)
        bank16, _ = _random_bank(seed=5, drop_tol=None, dedup=False,
                                 dtype="bf16")
        assert bank16.sv.nbytes * 2 == bank32.sv.nbytes
        x = jnp.asarray(q[:8])
        d32 = np.asarray(bank32.cell_model(0).decision_function(x))
        d16 = np.asarray(bank16.cell_model(0).decision_function(x))
        # storage-only downcast: decisions track f32 to bf16 rounding scale
        np.testing.assert_allclose(d16, d32, atol=0.05 * np.abs(d32).max())
        # and the bf16 payload survives the raw-byte checkpoint format
        bank16.save(str(tmp_path))
        loaded = ModelBank.load(str(tmp_path))
        assert str(loaded.sv.dtype) == "bfloat16"
        np.testing.assert_array_equal(
            d16, np.asarray(loaded.cell_model(0).decision_function(x)))


class TestEngineParity:
    def test_batched_step_bitwise_equals_per_cell_decision_function(self):
        bank, q = _random_bank(seed=1, drop_tol=None, dedup=False)
        eng = SVMEngine(bank, fused=False, row_bucket=8)
        dec = eng.predict(q)
        assert eng.counters["steps"] == 1              # one launch drained it
        # reference: per-cell decision_function at the same padded shapes
        xs = (q - bank.feat_mean) / bank.feat_std
        cells = eng.route(xs)
        m_pad = 8
        ref = np.zeros_like(dec)
        for c in np.unique(cells):
            model = bank.cell_model(int(c))
            idx = np.where(cells == c)[0]
            for lo in range(0, len(idx), m_pad):
                chunk = idx[lo:lo + m_pad]
                xp = np.zeros((m_pad, xs.shape[1]), np.float32)
                xp[:len(chunk)] = xs[chunk]
                out = np.asarray(model.decision_function(jnp.asarray(xp)))
                ref[chunk] = out[:len(chunk)]
        np.testing.assert_array_equal(dec, ref)        # bitwise, f32 path

    def test_unpadded_reference_within_f32_tolerance(self):
        """Against per-cell decision_function on the RAW routed subsets the
        match is allclose, not bitwise: XLA retiles reductions per batch
        shape (two direct decision_function calls with different m differ
        the same way)."""
        bank, q = _random_bank(seed=6, drop_tol=None, dedup=False)
        eng = SVMEngine(bank, fused=False)
        dec = eng.predict(q)
        xs = (q - bank.feat_mean) / bank.feat_std
        cells = eng.route(xs)
        for c in np.unique(cells):
            idx = np.where(cells == c)[0]
            ref = np.asarray(bank.cell_model(int(c))
                             .decision_function(jnp.asarray(xs[idx])))
            np.testing.assert_allclose(dec[idx], ref, atol=1e-5)

    def test_fused_pallas_kernel_matches_oracle(self):
        rng = np.random.default_rng(7)
        n_cells, m, k, d, p = 3, 37, 50, 7, 5
        xt = jnp.asarray(rng.normal(size=(n_cells, m, d)), jnp.float32)
        sv = jnp.asarray(rng.normal(size=(n_cells, k, d)), jnp.float32)
        co = jnp.asarray(rng.normal(size=(n_cells, k, p)), jnp.float32)
        g = jnp.asarray(rng.uniform(0.5, 3.0, size=(n_cells, p)), jnp.float32)
        for kind in ("gauss_rbf", "laplacian"):
            got = svm_predict_cells(xt, sv, co, g, kind=kind, force_pallas=True)
            ref = svm_predict_cells_ref(xt, sv, co, g, kind=kind)
            np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                       atol=1e-4)

    def test_fused_engine_path_close_to_cached(self):
        bank, q = _random_bank(seed=8, drop_tol=0.0, zero_frac=0.4)
        dec_cached = SVMEngine(bank, fused=False).predict(q)
        dec_fused = SVMEngine(bank, fused=True).predict(q)
        np.testing.assert_allclose(dec_fused, dec_cached, atol=1e-4)


class TestPersistentGram:
    def test_repeat_wave_hits_d2_cache(self):
        bank, q = _random_bank(seed=9)
        eng = SVMEngine(bank, fused=False)
        first = eng.predict(q)
        second = eng.predict(q)                        # same routed batch
        assert eng.counters["d2_misses"] == 1
        assert eng.counters["d2_hits"] == 1
        np.testing.assert_array_equal(first, second)

    def test_sweep_gammas_epilogue_only_replay(self):
        import dataclasses
        bank, q = _random_bank(seed=10)
        eng = SVMEngine(bank, fused=False)
        eng.predict(q)
        misses_before = eng.counters["d2_misses"]
        gammas = np.asarray([0.5, 1.0, 2.0], np.float32)
        sweep = np.asarray(eng.sweep_gammas(gammas))
        assert eng.counters["d2_misses"] == misses_before   # no new cross term
        assert sweep.shape[0] == 3
        # each sweep plane == a full engine pass with that gamma everywhere:
        # every reference decision row must appear in the sweep plane
        uniform = dataclasses.replace(bank,
                                      gammas=np.full_like(bank.gammas, 2.0))
        ref = SVMEngine(uniform, fused=False).predict(q)
        flat = sweep[2].reshape(-1, bank.n_tasks * bank.n_sub)
        for row in ref.reshape(ref.shape[0], -1):
            assert np.any(np.all(np.isclose(flat, row, atol=1e-5), axis=1))

    def test_bf16_cache_dtype_bounds_error_and_halves_bytes(self):
        bank, q = _random_bank(seed=11)
        e32 = SVMEngine(bank, fused=False, cache_dtype="f32")
        e16 = SVMEngine(bank, fused=False, cache_dtype="bf16")
        d32 = e32.predict(q)
        d16 = e16.predict(q)
        assert e16.stats()["cached_d2_bytes"] * 2 == e32.stats()["cached_d2_bytes"]
        # one bf16 rounding of d2 before the exp; coefs amplify by sum|c|
        amp = np.abs(bank.coefs).sum(1).max()
        assert np.abs(d16 - d32).max() <= np.exp(-1.0) * 2.0 ** -8 * amp * 1.05


class TestEndToEnd:
    def test_ova_three_class_bank_serving(self):
        x, y = banana_mc(n=900, n_classes=3, seed=21)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 21)
        est = LiquidSVM(SVMTrainerConfig(scenario="ova", n_folds=3,
                                         max_iters=300, cell_method="voronoi",
                                         cell_size=300)).fit(xtr, ytr)
        bank = est.to_bank()
        assert bank.n_tasks == 3 and len(bank.classes) == 3
        assert int(bank.sv_count.sum()) <= bank.raw_sv_total
        eng = SVMEngine(bank, fused=False)
        pred = eng.predict_label(xte)
        acc = float((pred == yte).mean())
        assert acc > 0.8, acc
        agree = float((pred == est.predict(xte)).mean())
        assert agree > 0.97, agree            # bank serving ≈ estimator path

    def test_bank_cold_start_from_checkpoint(self, tmp_path):
        x, y = banana_mc(n=500, n_classes=3, seed=22)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.3, 22)
        est = LiquidSVM(SVMTrainerConfig(scenario="ova", n_folds=3,
                                         max_iters=200)).fit(xtr, ytr)
        est.to_bank().save(str(tmp_path))
        eng = SVMEngine(ModelBank.load(str(tmp_path)), fused=False)
        pred_cold = eng.predict_label(xte)
        pred_warm = SVMEngine(est.to_bank(), fused=False).predict_label(xte)
        np.testing.assert_array_equal(pred_cold, pred_warm)

    def test_trained_svm_multitask_predict_label(self):
        x, y = banana_mc(n=400, n_classes=3, seed=23)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.3, 23)
        tasks = make_tasks(ytr, "ova")
        model = train_select(jnp.asarray(xtr), jnp.asarray(tasks.labels[0]),
                             y_tasks=jnp.asarray(tasks.labels),
                             task_mask=jnp.asarray(tasks.task_mask))
        pred = model.predict_label(jnp.asarray(xte), scenario="ova",
                                   classes=tasks.classes)
        acc = float((pred == yte).mean())
        assert acc > 0.8, acc
        err = float(svm_test_error(model, xte, yte, task="ova",
                                   classes=tasks.classes))
        assert abs((1.0 - acc) - err) < 1e-6

    def test_trained_svm_ava_predict_label(self):
        x, y = banana_mc(n=400, n_classes=3, seed=24)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.3, 24)
        tasks = make_tasks(ytr, "ava")
        model = train_select(jnp.asarray(xtr), jnp.asarray(tasks.labels[0]),
                             y_tasks=jnp.asarray(tasks.labels),
                             task_mask=jnp.asarray(tasks.task_mask))
        pred = model.predict_label(jnp.asarray(xte), scenario="ava",
                                   classes=tasks.classes, pairs=tasks.pairs)
        assert float((pred == yte).mean()) > 0.8
