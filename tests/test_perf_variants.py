"""§Perf variant correctness: every beyond-paper optimization must keep
the math (exactly, or within quantization tolerance)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.serve.kv_cache import pad_cache


class TestInt8KVCache:
    def _setup(self):
        spec = get_arch("stablelm-1.6b")
        cfg = dataclasses.replace(spec.smoke, dtype=jnp.float32)
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))
        x = jax.random.randint(jax.random.PRNGKey(1), (2, 13), 0, cfg.vocab)
        return cfg, params, x

    def test_int8_decode_close_to_bf16(self):
        cfg, params, x = self._setup()
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        _, cache = model_mod.prefill(cfg, params, x[:, :12])
        c16 = pad_cache(cfg, cache, 16)
        l16, _ = model_mod.decode_step(cfg, params, x[:, 12:13], c16,
                                       jnp.int32(12))
        c8 = pad_cache(cfg8, cache, 16)
        l8, nc8 = model_mod.decode_step(cfg8, params, x[:, 12:13], c8,
                                        jnp.int32(12))
        rel = float(jnp.max(jnp.abs(l8 - l16)) / jnp.max(jnp.abs(l16)))
        assert rel < 0.02, rel
        # cache stays int8 across steps
        assert nc8["stack"]["pos0"]["k"].dtype == jnp.int8
        assert "k_scale" in nc8["stack"]["pos0"]

    def test_int8_argmax_agreement(self):
        """Greedy decisions agree between int8 and bf16 caches."""
        cfg, params, x = self._setup()
        cfg8 = dataclasses.replace(cfg, kv_cache_dtype="int8")
        _, cache = model_mod.prefill(cfg, params, x[:, :12])
        l16, _ = model_mod.decode_step(cfg, params, x[:, 12:13],
                                       pad_cache(cfg, cache, 16), jnp.int32(12))
        l8, _ = model_mod.decode_step(cfg8, params, x[:, 12:13],
                                      pad_cache(cfg8, cache, 16), jnp.int32(12))
        np.testing.assert_array_equal(np.argmax(np.asarray(l16), -1),
                                      np.argmax(np.asarray(l8), -1))


class TestGatherMoE:
    def test_gather_equals_einsum_forward_and_grad(self):
        spec = get_arch("qwen3-moe-235b-a22b")
        cfgE = dataclasses.replace(spec.smoke, dtype=jnp.float32)
        cfgG = dataclasses.replace(cfgE, moe_impl="gather")
        params = init_params(model_mod.build_template(cfgE), jax.random.PRNGKey(2))
        toks = jax.random.randint(jax.random.PRNGKey(3), (2, 32), 0, cfgE.vocab)
        batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
        lE = model_mod.loss_fn(cfgE, params, batch)
        lG = model_mod.loss_fn(cfgG, params, batch)
        assert float(jnp.abs(lE - lG)) < 1e-6
        gE = jax.grad(lambda p: model_mod.loss_fn(cfgE, p, batch))(params)
        gG = jax.grad(lambda p: model_mod.loss_fn(cfgG, p, batch))(params)
        worst = max(float(jnp.max(jnp.abs(a - b)))
                    for a, b in zip(jax.tree.leaves(gE), jax.tree.leaves(gG)))
        assert worst < 1e-5, worst

    def test_gather_capacity_drops_match_einsum(self):
        """With tight capacity both impls drop the SAME tokens."""
        spec = get_arch("jamba-v0.1-52b")
        cfgE = dataclasses.replace(spec.smoke, dtype=jnp.float32,
                                   moe_capacity_factor=0.5)
        cfgG = dataclasses.replace(cfgE, moe_impl="gather")
        params = init_params(model_mod.build_template(cfgE), jax.random.PRNGKey(4))
        toks = jax.random.randint(jax.random.PRNGKey(5), (2, 32), 0, cfgE.vocab)
        batch = {"inputs": toks, "labels": jnp.roll(toks, -1, 1)}
        lE = model_mod.loss_fn(cfgE, params, batch)
        lG = model_mod.loss_fn(cfgG, params, batch)
        assert float(jnp.abs(lE - lG)) < 1e-6


class TestBf16Gram:
    def test_bf16_gram_error_parity(self):
        from repro.core import cv as cv_mod
        from repro.core.svm import test_error as svm_err, train_select
        from repro.data.synthetic import covtype_like, train_test_split
        x, yc = covtype_like(n=600, d=6, seed=0, label_noise=0.05, n_modes=3)
        y = np.where(yc == 0, -1.0, 1.0).astype(np.float32)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
        errs = {}
        for gd in ("f32", "bf16"):
            cfg = cv_mod.CVConfig(n_folds=3, max_iters=300, gram_dtype=gd)
            m = train_select(xtr, ytr, cfg=cfg)
            errs[gd] = float(svm_err(m, xte, yte))
        assert abs(errs["f32"] - errs["bf16"]) < 0.02, errs

    def test_shared_lipschitz_same_fixed_point(self):
        """box_qp with the full-Gram L reaches the same optimum as with the
        (smaller) masked-Gram L — step size changes the path, not the
        fixed point (lambda_max(MKM) <= lambda_max(K))."""
        from repro.core import kernel_fns
        from repro.core.solvers import base
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(80, 4)), jnp.float32)
        k = kernel_fns.gaussian(x, x, jnp.float32(1.2))
        mask = jnp.asarray([1.0] * 60 + [0.0] * 20)
        km = k * mask[:, None] * mask[None, :]
        y = jnp.asarray(np.sign(rng.normal(size=(80, 3))), jnp.float32) \
            * mask[:, None]
        lo, hi = jnp.minimum(0.0, y), jnp.maximum(0.0, y)
        l_full = base.power_iteration_l(k)
        l_masked = base.power_iteration_l(km)
        assert float(l_full) >= float(l_masked)  # the bound that makes it safe
        c_full = base.box_qp(k, y, lo, hi, tol=1e-7, max_iters=30000,
                             l_est=l_full).c
        c_masked = base.box_qp(k, y, lo, hi, tol=1e-7, max_iters=30000,
                               l_est=l_masked).c
        np.testing.assert_allclose(np.asarray(c_full), np.asarray(c_masked),
                                   atol=1e-4)
