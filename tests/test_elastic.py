"""Elastic scaling: a checkpoint written under one mesh restores and keeps
training under a DIFFERENT device count (the re-shard path)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    from repro.models import model as model_mod
    from repro.models.layers import init_params, sharding_tree
    from repro.train import checkpoint as ckpt
    from repro.train.lm_trainer import make_train_step
    from repro.train.optimizer import OptConfig, init_opt_state

    ckpt_dir = sys.argv[1]
    cfg = dataclasses.replace(get_arch("stablelm-1.6b").smoke,
                              dtype=jnp.float32, batch_axes=("data",))
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=16,
                                             global_batch=8, seed=0))

    def run_on_mesh(shape, start_step, n_steps, restore):
        mesh = jax.make_mesh(shape, ("data", "model"))
        shards = sharding_tree(model_mod.build_template(cfg), mesh)
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))
        opt = init_opt_state(params, ocfg)
        if restore:
            (params, opt), start, _ = ckpt.restore_checkpoint(
                ckpt_dir, (params, opt))
            start_step = start
        params = jax.tree.map(jax.device_put, params, shards)
        with mesh:
            step = jax.jit(make_train_step(cfg, ocfg))
            bshard = NamedSharding(mesh, P("data", None))
            for i in range(start_step, start_step + n_steps):
                batch = {k: jax.device_put(v, bshard)
                         for k, v in pipe.batch(i).items()}
                params, opt, m = step(params, opt, batch)
        return params, opt, float(m["loss"])

    # phase 1: 4x2 mesh, 3 steps, checkpoint
    p, o, _ = run_on_mesh((4, 2), 0, 3, restore=False)
    ckpt.save_checkpoint(ckpt_dir, 3, (p, o))

    # phase 2a: resume on a DIFFERENT mesh (2x4 — elastic re-shard), 2 steps
    p2, o2, loss_elastic = run_on_mesh((2, 4), 3, 2, restore=True)
    # phase 2b: control — same continuation on the original mesh
    p3, o3, loss_same = run_on_mesh((4, 2), 3, 2, restore=True)

    assert abs(loss_elastic - loss_same) < 1e-4, (loss_elastic, loss_same)
    worst = max(float(jnp.max(jnp.abs(jax.device_get(a) - jax.device_get(b))))
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(p3)))
    assert worst < 1e-4, worst
    print("OK elastic", loss_elastic, "same", loss_same, "worst", worst)
""")


@pytest.mark.slow
def test_elastic_remesh_resume(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT, str(tmp_path)], env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
