"""Mesh-sharded cell training == unsharded results (run in a subprocess with
8 forced host devices so shard_map actually distributes)."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    assert len(jax.devices()) == 8
    from repro.data.synthetic import covtype_like, train_test_split
    from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig

    x, y = covtype_like(n=1600, d=5, seed=0, label_noise=0.02, n_modes=3)
    y = np.where(y == 0, -1, 1)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    cfg = SVMTrainerConfig(n_folds=3, max_iters=300, cell_method="voronoi",
                           cell_size=200, seed=0)

    m_local = LiquidSVM(cfg).fit(xtr, ytr)
    err_local = m_local.error(xte, yte)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    m_mesh = LiquidSVM(cfg, mesh=mesh, mesh_axes=("data", "model")).fit(xtr, ytr)
    err_mesh = m_mesh.error(xte, yte)

    print("ERR", err_local, err_mesh)
    assert err_mesh < 0.2, err_mesh
    assert abs(err_local - err_mesh) < 0.05, (err_local, err_mesh)

    # per-CELL comparison (bin packing differs with device count); vmap vs
    # shard_map can reassociate float reductions -> near-tie argmins may
    # flip a cell's gamma to the neighboring grid point: require bulk
    # agreement + val-loss parity.  Observed agreement on jax 0.4.37 CPU is
    # 0.667 with the D2 cache both ON and OFF (controlled experiment), i.e.
    # layout-induced tie-breaking, not a kernel-pipeline regression; the
    # val-loss parity check below is the meaningful invariant
    n_cells = m_local.plan.n_cells
    sl, sm = m_local.packed.slot_of_cell, m_mesh.packed.slot_of_cell
    g_same = np.mean([np.isclose(m_local.gamma[sl[c]], m_mesh.gamma[sm[c]],
                                 rtol=1e-5).all() for c in range(n_cells)])
    assert g_same >= 0.65, g_same  # observed 0.667 (8/12 cells) on jax 0.4 CPU
    v_close = np.mean([abs(m_local.val_loss[sl[c]] - m_mesh.val_loss[sm[c]])
                       < 0.02 for c in range(n_cells)])
    assert v_close == 1.0, v_close
    print("OK")
""")


@pytest.mark.slow
def test_sharded_cells_match_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env, cwd=os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))), capture_output=True, text=True,
        timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
