"""Observability layer: spans, metrics, ring buffers, schemas.

What is pinned here and why:
  * span timing/nesting against an INJECTED clock — the tracer's numbers
    must be exactly the clock deltas, not approximately;
  * the disabled path — `span()` on a disabled tracer must return the
    same singleton object and allocate nothing (measured with
    tracemalloc), because these sites sit on the serve hot path;
  * histogram bucket-edge semantics (a value ON an edge lands in that
    edge's bucket; past the last edge lands in overflow);
  * metrics JSONL round-trip + `validate_jsonl` (the tier-1 CLI smoke
    validates real CLI output against this same checker);
  * schema stability: the `stats()` keys and wave-record keys other tests
    and the benchmark exporters rely on.
"""
import json
import tracemalloc

import numpy as np
import pytest

from repro import obs
from repro.obs.metrics import (Counter, Gauge, Histogram, LATENCY_MS_BUCKETS,
                               METRICS_SCHEMA, MetricsRegistry,
                               validate_jsonl)
from repro.obs.trace import NULL_SPAN, RingBuffer, Tracer


# ------------------------------------------------------------- ring buffer
class TestRingBuffer:
    def test_below_capacity_is_a_plain_list(self):
        rb = RingBuffer(4)
        for i in range(3):
            rb.append(i)
        assert list(rb) == [0, 1, 2]
        assert len(rb) == 3 and rb.total == 3 and rb.dropped == 0
        assert rb[0] == 0 and rb[-1] == 2

    def test_wraps_keeping_newest(self):
        rb = RingBuffer(4)
        for i in range(10):
            rb.append(i)
        assert list(rb) == [6, 7, 8, 9]
        assert len(rb) == 4
        assert rb.total == 10 and rb.dropped == 6
        assert rb[-1] == 9 and rb[0] == 6

    def test_clear_and_bad_capacity(self):
        rb = RingBuffer(2)
        rb.append("x")
        rb.clear()
        assert len(rb) == 0 and rb.total == 0
        with pytest.raises(ValueError):
            RingBuffer(0)


# ------------------------------------------------------------------ tracer
class TestTracer:
    def test_span_timing_with_injected_clock(self):
        clk = [0.0]
        tr = Tracer(enabled=True, clock=lambda: clk[0])
        with tr.span("outer"):
            clk[0] = 1.0
            with tr.span("inner"):
                clk[0] = 1.5
            clk[0] = 3.0
        spans = list(tr.spans)
        assert [s.name for s in spans] == ["inner", "outer"]  # exit order
        inner, outer = spans
        assert inner.dur_s == 0.5 and inner.depth == 1
        assert outer.dur_s == 3.0 and outer.depth == 0

    def test_record_uses_caller_timestamps(self):
        tr = Tracer(enabled=True, clock=lambda: 99.0)
        tr.record("site", 2.0, 5.0)
        (s,) = tr.spans
        assert (s.t0, s.t1, s.dur_s) == (2.0, 5.0, 3.0)

    def test_summary_is_exact_past_the_ring(self):
        tr = Tracer(enabled=True, clock=lambda: 0.0, capacity=4)
        for i in range(10):
            tr.record("a", 0.0, float(i))
        assert len(tr.spans) == 4 and tr.spans.total == 10
        agg = tr.summary()["a"]
        assert agg["count"] == 10
        assert agg["total_s"] == sum(range(10))
        assert agg["max_s"] == 9.0

    def test_disabled_returns_the_null_singleton(self):
        tr = Tracer(enabled=False)
        assert tr.span("x") is NULL_SPAN
        assert tr.span("y") is NULL_SPAN
        with tr.span("z") as sp:
            sp.set(attr=1)            # no-op, no error
        tr.record("w", 0.0, 1.0)
        assert len(tr.spans) == 0 and not tr.summary()

    def test_disabled_hot_path_allocates_nothing(self):
        tr = Tracer(enabled=False)
        name = "serve.pack"
        # warm up interned/cached state
        for _ in range(10):
            with tr.span(name):
                pass
            tr.record(name, 0.0, 1.0)
        tracemalloc.start()
        before = tracemalloc.take_snapshot()
        for _ in range(1000):
            with tr.span(name):
                pass
            tr.record(name, 0.0, 1.0)
        after = tracemalloc.take_snapshot()
        tracemalloc.stop()
        grown = sum(d.size_diff for d in after.compare_to(before, "lineno")
                    if d.size_diff > 0)
        # tracemalloc's own bookkeeping shows up as a few small blocks;
        # 1000 iterations of real allocation would be tens of KB
        assert grown < 2048

    def test_attrs_attach_to_live_spans(self):
        tr = Tracer(enabled=True, clock=lambda: 0.0)
        with tr.span("s") as sp:
            sp.set(rows=7)
        (s,) = tr.spans
        assert s.attrs == {"rows": 7}

    def test_trace_jsonl_dump(self, tmp_path):
        tr = Tracer(enabled=True, clock=lambda: 0.0)
        tr.record("a", 0.0, 1.0)
        tr.record("b", 1.0, 3.0)
        p = str(tmp_path / "trace.jsonl")
        assert tr.write_jsonl(p) == 2
        lines = [json.loads(l) for l in open(p)]
        assert lines[0]["schema"] == "repro.obs.trace.v1"
        assert lines[0]["spans_total"] == 2
        assert {l["name"] for l in lines[1:]} == {"a", "b"}


# ----------------------------------------------------------------- metrics
class TestMetrics:
    def test_counter_monotonic(self):
        c = Counter("c")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(ValueError):
            c.inc(-1)

    def test_gauge_last_write_wins(self):
        g = Gauge("g")
        g.set(3.5)
        g.set(1.25)
        assert g.value == 1.25

    def test_histogram_bucket_edges(self):
        h = Histogram("h", (1.0, 2.0, 5.0))
        # a value exactly ON an edge lands in that edge's bucket
        # (bisect_left: bucket i covers (edge[i-1], edge[i]])
        for v, want in [(0.5, 0), (1.0, 0), (1.5, 1), (2.0, 1),
                        (4.9, 2), (5.0, 2), (5.1, 3), (100.0, 3)]:
            before = list(h.counts)
            h.observe(v)
            assert h.counts[want] == before[want] + 1, (v, want)
        assert h.count == 8 and sum(h.counts) == 8
        assert h.mean() == pytest.approx(sum(
            [0.5, 1.0, 1.5, 2.0, 4.9, 5.0, 5.1, 100.0]) / 8)

    def test_histogram_rejects_bad_edges(self):
        with pytest.raises(ValueError):
            Histogram("h", ())
        with pytest.raises(ValueError):
            Histogram("h", (1.0, 1.0))
        with pytest.raises(ValueError):
            Histogram("h", (2.0, 1.0))

    def test_registry_get_or_create_same_object(self):
        reg = MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        assert reg.histogram("h") is reg.histogram("h")
        with pytest.raises(TypeError):
            reg.gauge("a")
        with pytest.raises(ValueError):
            reg.histogram("h", (1.0, 2.0))   # bucket mismatch

    def test_jsonl_round_trip(self, tmp_path):
        reg = MetricsRegistry()
        reg.counter("serve.served").inc(42)
        reg.gauge("checkpoint.save_mbps").set(123.5)
        h = reg.histogram("serve.request_ms")
        for v in (0.3, 1.5, 7.0, 2000.0):
            h.observe(v)
        p = str(tmp_path / "metrics.jsonl")
        assert reg.write_jsonl(p, extra={"stage": "serve"}) == 3
        assert validate_jsonl(p) == []
        back, header = MetricsRegistry.read_jsonl(p)
        assert header["schema"] == METRICS_SCHEMA
        assert header["stage"] == "serve"
        assert back.counter("serve.served").value == 42
        assert back.gauge("checkpoint.save_mbps").value == 123.5
        hb = back.histogram("serve.request_ms")
        assert hb.counts == h.counts and hb.count == 4
        assert hb.buckets == tuple(LATENCY_MS_BUCKETS)

    def test_validate_jsonl_catches_drift(self, tmp_path):
        p = str(tmp_path / "bad.jsonl")
        with open(p, "w") as f:
            f.write(json.dumps({"schema": "other.v9", "unix_time": 0}) + "\n")
            f.write(json.dumps({"name": "c", "type": "counter",
                                "value": "NaN-ish"}) + "\n")
            f.write(json.dumps({"name": "h", "type": "histogram",
                                "buckets": [1.0, 2.0],
                                "counts": [1, 2], "sum": 3.0,
                                "count": 3}) + "\n")   # counts too short
            f.write(json.dumps({"name": "c", "type": "gauge",
                                "value": 1}) + "\n")   # duplicate name
        errs = validate_jsonl(p)
        assert any("schema" in e for e in errs)
        assert any("non-numeric" in e for e in errs)
        assert any("len(buckets)+1" in e for e in errs)
        assert any("duplicate" in e for e in errs)
        assert validate_jsonl(str(tmp_path / "missing.jsonl")) != []

    def test_empty_and_garbage_files(self, tmp_path):
        p = str(tmp_path / "empty.jsonl")
        open(p, "w").close()
        assert validate_jsonl(p) == ["empty file (expected a schema "
                                     "header line)"]
        with open(p, "w") as f:
            f.write("not json\n")
        assert any("not JSON" in e for e in validate_jsonl(p))


# ------------------------------------------------------- module-level obs
class TestGlobalConfigure:
    def test_configure_and_reset(self, tmp_path):
        try:
            obs.configure(trace=True, metrics_out=str(tmp_path / "m.jsonl"),
                          profile_dir=str(tmp_path / "prof"))
            assert obs.tracer.enabled
            assert obs.metrics_out() == str(tmp_path / "m.jsonl")
            assert obs.profile_dir() == str(tmp_path / "prof")
            obs.configure(trace=False)      # None leaves others unchanged
            assert not obs.tracer.enabled
            assert obs.metrics_out() == str(tmp_path / "m.jsonl")
        finally:
            obs.reset()
        assert not obs.tracer.enabled
        assert obs.metrics_out() is None and obs.profile_dir() is None

    def test_flush_metrics_writes_configured_path(self, tmp_path):
        try:
            p = str(tmp_path / "m.jsonl")
            obs.configure(metrics_out=p)
            obs.metrics.counter("test.flush").inc(3)
            assert obs.flush_metrics(extra={"stage": "t"}) == p
            assert validate_jsonl(p) == []
        finally:
            obs.reset()
        assert obs.flush_metrics() is None

    def test_jaxprof_noop_when_unconfigured(self):
        from repro.obs import jaxprof
        assert jaxprof.profile_dir() is None
        assert not jaxprof.start()
        assert not jaxprof.stop()
        with jaxprof.step("w", 0):
            pass


# ------------------------------------------------- engine schema stability
class TestEngineSchemas:
    """Pin the stats()/wave-record keys downstream consumers read."""

    @pytest.fixture(scope="class")
    def engine(self):
        from repro.serve.model_bank import ModelBank
        from repro.serve.svm_engine import SVMEngine
        rng = np.random.default_rng(5)
        n_cells, k, d = 2, 16, 4
        centers = rng.normal(size=(n_cells, d)).astype(np.float32) * 4.0
        sv = (centers[:, None, :]
              + rng.normal(size=(n_cells, k, d))).astype(np.float32)
        coefs = rng.normal(size=(n_cells, k, 1, 1)).astype(np.float32)
        gamma = np.ones((n_cells, 1, 1), np.float32)
        mask = np.ones((n_cells, k), np.float32)
        bank = ModelBank.from_cells(sv, mask, coefs, gamma, centers)
        eng = SVMEngine(bank, fused=False,
                        metrics=MetricsRegistry(), tracer=Tracer())
        for _ in range(2):
            eng.submit((centers[rng.integers(0, n_cells, 9)]
                        + rng.normal(size=(9, d))).astype(np.float32))
            eng.step()
        return eng

    def test_stats_pins_existing_keys(self, engine):
        st = engine.stats()
        # the pre-PR-7 surface every existing consumer reads — keep as-is
        for key in ("bank_version", "pending", "pending_requests", "routing",
                    "pad_fraction", "cached_d2_waves", "cached_d2_bytes",
                    "waves", "occupancy_mean", "age_ms_max", "age_hist",
                    "swaps", "swap_requeued", "bank_fallbacks",
                    "routing_degraded", "shed_overflow", "shed_stale",
                    "shed_rows"):
            assert key in st, key
        # the PR-7 additions
        assert set(st["per_stage"]) == {"queue", "pack", "dispatch",
                                        "device", "collect"}
        for v in st["per_stage"].values():
            assert set(v) == {"total_ms", "mean_ms", "count"}
        assert st["wave_stats_dropped"] == 0

    def test_stats_exact_after_ring_wrap(self, monkeypatch):
        """occupancy_mean / age_hist / waves stay exact once the ring
        evicts — they come from running sums, not the retained window."""
        from repro.serve import svm_engine as se
        monkeypatch.setattr(se, "_WAVE_STATS_CAP", 2)
        from repro.serve.model_bank import ModelBank
        rng = np.random.default_rng(11)
        n_cells, k, d = 2, 16, 4
        centers = rng.normal(size=(n_cells, d)).astype(np.float32) * 4.0
        sv = (centers[:, None, :]
              + rng.normal(size=(n_cells, k, d))).astype(np.float32)
        bank = ModelBank.from_cells(
            sv, np.ones((n_cells, k), np.float32),
            rng.normal(size=(n_cells, k, 1, 1)).astype(np.float32),
            np.ones((n_cells, 1, 1), np.float32), centers)
        eng = se.SVMEngine(bank, fused=False,
                           metrics=MetricsRegistry(), tracer=Tracer())
        occ = []
        for _ in range(5):
            eng.submit((centers[rng.integers(0, n_cells, 7)]
                        + rng.normal(size=(7, d))).astype(np.float32))
            eng.step()
            occ.append(eng.wave_stats[-1]["occupancy"])
        st = eng.stats()
        assert len(eng.wave_stats) == 2
        assert eng.wave_stats.dropped == 3
        assert st["waves"] == 5 and st["wave_stats_dropped"] == 3
        assert st["occupancy_mean"] == pytest.approx(np.mean(occ))
        assert sum(st["age_hist"]) == eng.counters["served"]

    def test_request_latency_histogram_observes(self, engine):
        h = engine._metrics.histogram("serve.request_ms")
        assert h.count == engine.counters["served"] > 0
        assert engine._metrics.counter("serve.served").value == h.count
        assert engine._metrics.counter("serve.waves").value \
            == engine.stats()["waves"]


# ---------------------------------------------------- trace schema validator
class TestTraceValidator:
    def _dump(self, tmp_path, name="trace.jsonl"):
        tr = Tracer(enabled=True, clock=lambda: 0.0)
        tr.record("serve.pack", 0.0, 1.0)
        tr.record("serve.device", 1.0, 3.5)
        with tr.span("outer") as sp:
            sp.set(rows=3)
        p = str(tmp_path / name)
        tr.write_jsonl(p)
        return p

    def test_real_dump_validates(self, tmp_path):
        from repro.obs.trace import validate_trace_jsonl
        assert validate_trace_jsonl(self._dump(tmp_path)) == []

    def test_corruptions_are_caught(self, tmp_path):
        from repro.obs.trace import validate_trace_jsonl
        p = self._dump(tmp_path)
        lines = open(p).read().splitlines()
        hdr = json.loads(lines[0])

        bad = str(tmp_path / "bad_schema.jsonl")
        with open(bad, "w") as f:
            f.write(json.dumps(dict(hdr, schema="other.v9")) + "\n")
            f.write("\n".join(lines[1:]) + "\n")
        assert any("schema" in e for e in validate_trace_jsonl(bad))

        bad = str(tmp_path / "missing_span.jsonl")     # count mismatch
        with open(bad, "w") as f:
            f.write("\n".join(lines[:-1]) + "\n")
        assert any("spans_total" in e or "span line" in e
                   for e in validate_trace_jsonl(bad))

        span = json.loads(lines[1])
        bad = str(tmp_path / "bad_time.jsonl")         # t1 < t0
        with open(bad, "w") as f:
            f.write(lines[0] + "\n")
            f.write(json.dumps(dict(span, t0=5.0, t1=1.0, dur_s=-4.0)) + "\n")
            f.write("\n".join(lines[2:]) + "\n")
        assert validate_trace_jsonl(bad) != []

        bad = str(tmp_path / "bad_dur.jsonl")          # dur != t1 - t0
        with open(bad, "w") as f:
            f.write(lines[0] + "\n")
            f.write(json.dumps(dict(span, dur_s=99.0)) + "\n")
            f.write("\n".join(lines[2:]) + "\n")
        assert any("dur" in e for e in validate_trace_jsonl(bad))

        assert validate_trace_jsonl(str(tmp_path / "nope.jsonl")) != []


class TestTraceOut:
    def test_trace_out_implies_tracing_and_flushes(self, tmp_path):
        from repro.obs.trace import validate_trace_jsonl
        p = str(tmp_path / "t.jsonl")
        try:
            obs.configure(trace_out=p)
            assert obs.tracer.enabled          # TRACE_OUT implies TRACE
            assert obs.trace_out() == p
            with obs.tracer.span("unit.test"):
                pass
            assert obs.flush_trace() == p
            assert validate_trace_jsonl(p) == []
        finally:
            obs.reset()
        assert obs.trace_out() is None and obs.flush_trace() is None

    def test_explicit_trace_false_wins(self, tmp_path):
        try:
            obs.configure(trace=False, trace_out=str(tmp_path / "t.jsonl"))
            assert not obs.tracer.enabled
        finally:
            obs.reset()
