"""Cell decomposition + task creation invariants (hypothesis property tests
on the system's working-set machinery)."""
from __future__ import annotations

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.cells.builder import build_cells
from repro.tasks.builder import combine_ava, combine_ova, make_tasks


def _coverage(plan, n):
    cover = np.zeros(n, np.int32)
    for c in range(plan.n_cells):
        ids = plan.indices[c][plan.mask[c] > 0]
        cover[ids] += 1
    return cover


class TestCells:
    @settings(max_examples=12, deadline=None)
    @given(n=st.integers(10, 600), d=st.integers(1, 6),
           method=st.sampled_from(["random", "voronoi", "recursive"]),
           k=st.integers(8, 200))
    def test_partition_property(self, n, d, method, k):
        """Non-overlapping methods cover every sample exactly once."""
        rng = np.random.default_rng(0)
        x = rng.normal(size=(n, d)).astype(np.float32)
        plan = build_cells(x, cell_size=k, method=method, seed=1)
        assert (_coverage(plan, n) == 1).all()
        # owner consistent with membership
        for i in range(n):
            c = plan.owner[i]
            assert i in plan.indices[c][plan.mask[c] > 0]

    def test_overlap_covers_at_least_once(self):
        rng = np.random.default_rng(1)
        x = rng.normal(size=(500, 3)).astype(np.float32)
        plan = build_cells(x, cell_size=100, method="overlap", seed=2)
        cover = _coverage(plan, 500)
        assert (cover >= 1).all() and (cover <= 2).all()

    def test_recursive_respects_cell_size(self):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(1000, 4)).astype(np.float32)
        plan = build_cells(x, cell_size=120, method="recursive", seed=3)
        sizes = plan.mask.sum(1)
        assert (sizes <= 120).all()

    def test_coarse_fine_two_level(self):
        rng = np.random.default_rng(3)
        x = rng.normal(size=(2000, 3)).astype(np.float32)
        plan = build_cells(x, cell_size=100, method="coarse_fine", seed=4,
                           coarse_size=500)
        assert (_coverage(plan, 2000) == 1).all()
        assert plan.coarse_of.max() >= 1           # >1 coarse group
        assert (plan.mask.sum(1) <= 100).all()

    def test_route_returns_owner_for_training_points(self):
        rng = np.random.default_rng(4)
        x = rng.normal(size=(300, 2)).astype(np.float32)
        plan = build_cells(x, cell_size=60, method="voronoi", seed=5)
        routed = plan.route(x)
        agree = (routed == plan.owner).mean()
        assert agree > 0.95  # ties at boundaries may flip a few

    def test_single_cell_when_small(self):
        x = np.random.default_rng(5).normal(size=(50, 2)).astype(np.float32)
        plan = build_cells(x, cell_size=2000, method="voronoi")
        assert plan.n_cells == 1


class TestTasks:
    def test_ova_shapes_and_labels(self):
        y = np.array([0, 1, 2, 1, 0, 2])
        ts = make_tasks(y, "ova")
        assert ts.n_tasks == 3
        np.testing.assert_array_equal(ts.labels[0], [1, -1, -1, -1, 1, -1])
        assert (ts.task_mask == 1).all()

    def test_ava_masks_out_other_classes(self):
        y = np.array([0, 1, 2, 1])
        ts = make_tasks(y, "ava")
        assert ts.n_tasks == 3  # (0,1), (0,2), (1,2)
        np.testing.assert_array_equal(ts.task_mask[0], [1, 1, 0, 1])
        np.testing.assert_array_equal(ts.labels[0], [1, -1, 0, -1])

    def test_combine_ova_argmax(self):
        dec = np.array([[0.9, -0.2], [0.1, 0.7], [-0.5, 0.1]])
        classes = np.array([10, 20, 30])
        np.testing.assert_array_equal(combine_ova(dec, classes), [10, 20])

    def test_combine_ava_voting(self):
        classes = np.array([0, 1, 2])
        pairs = np.array([[0, 1], [0, 2], [1, 2]])
        # sample where 0 beats 1, 0 beats 2, (1 vs 2 irrelevant) -> class 0
        dec = np.array([[1.0], [1.0], [-1.0]])
        np.testing.assert_array_equal(combine_ava(dec, pairs, classes), [0])

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(6, 100), n_classes=st.integers(2, 5))
    def test_ava_property(self, n, n_classes):
        rng = np.random.default_rng(6)
        y = rng.integers(0, n_classes, n)
        if len(np.unique(y)) < 2:
            return
        ts = make_tasks(y, "ava")
        c = len(np.unique(y))
        assert ts.n_tasks == c * (c - 1) // 2
        # every sample participates in exactly (c - 1) tasks
        np.testing.assert_array_equal(ts.task_mask.sum(0), c - 1)

    def test_binary_requires_pm1(self):
        with pytest.raises(AssertionError):
            make_tasks(np.array([0, 1, 1]), "binary")
