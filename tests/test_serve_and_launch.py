"""Serve utilities + launch-layer unit tests (no 512-device requirement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_cells, get_arch
from repro.launch.dryrun import collective_bytes
from repro.models import model as model_mod
from repro.serve.kv_cache import cache_bytes, pad_cache


class TestCollectiveParser:
    def test_parses_hlo_ops(self):
        hlo = """\
ENTRY %main.1 (p0: f32[4]) -> f32[4] {
  %all-reduce.17 = f32[8,1,32,1]{3,2,1,0} all-reduce(%x), channel_id=4
  %all-gather.21 = f32[2048,352]{1,0} all-gather(%y), dimensions={0}
  %ag2 = bf16[16,128]{1,0} all-gather(%z), dimensions={0}
  %fusion = f32[4]{0} fusion(%all-reduce.17), kind=kLoop
  %rs = (f32[4]{0}, f32[4]{0}) reduce-scatter-start(%a, %b)
}
"""
        got = collective_bytes(hlo)
        assert got["all-reduce"] == 8 * 32 * 4
        assert got["all-gather"] == 2048 * 352 * 4 + 16 * 128 * 2
        assert got["reduce-scatter"] == 2 * 4 * 4
        assert got["counts"]["all-reduce"] == 1  # fusion operand NOT counted
        assert got["counts"]["all-gather"] == 2

    def test_while_trip_multiplication(self):
        """Collectives inside a scan body multiply by the recovered trips."""
        hlo = """\
%wide.body (arg: (s32[], f32[16])) -> (s32[], f32[16]) {
  %ar = f32[16]{0} all-reduce(%x), channel_id=1
}

%wide.cond (arg: (s32[], f32[16])) -> pred[] {
  %c = s32[] constant(24)
  %cmp = pred[] compare(%iv, %c), direction=LT
}

ENTRY %main.2 (p0: f32[16]) -> f32[16] {
  %w = (s32[], f32[16]{0}) while(%t), condition=%wide.cond, body=%wide.body
  %ar2 = f32[8]{0} all-reduce(%y), channel_id=2
}
"""
        got = collective_bytes(hlo)
        assert got["all-reduce"] == 24 * 16 * 4 + 8 * 4

    def test_ignores_non_collectives(self):
        hlo = "ENTRY %m (p: f32[4]) -> f32[4] {\n  %dot = f32[4,4]{1,0} dot(%a, %b)\n}\n"
        got = collective_bytes(hlo)
        assert sum(v for k, v in got.items() if k != "counts") == 0


class TestCellMatrix:
    def test_cell_count_matches_design(self):
        """40 nominal cells - 6 long_500k skips - 2 hubert decode skips = 32."""
        cells = all_cells()
        assert len(cells) == 32
        long_runners = [a for a, s in cells if s == "long_500k"]
        assert sorted(long_runners) == ["gemma3-4b", "jamba-v0.1-52b",
                                        "rwkv6-1.6b"]
        hubert = [s for a, s in cells if a == "hubert-xlarge"]
        assert sorted(hubert) == ["prefill_32k", "train_4k"]

    def test_shape_kinds(self):
        spec = get_arch("hubert-xlarge")
        assert spec.shape("prefill_32k").kind == "encode"
        spec = get_arch("gemma3-4b")
        assert spec.shape("long_500k").kind == "decode"
        assert spec.shape("train_4k").kind == "train"

    def test_unknown_shape_raises(self):
        with pytest.raises(KeyError):
            get_arch("stablelm-12b").shape("long_500k")


class TestKVCacheUtils:
    def test_cache_bytes_scales_linearly_for_attn(self):
        cfg = get_arch("stablelm-1.6b").smoke
        b1 = cache_bytes(cfg, batch=2, seq=100)
        b2 = cache_bytes(cfg, batch=2, seq=200)
        assert b2 > 1.9 * b1  # kv dominates, linear in seq

    def test_cache_bytes_constant_for_rwkv(self):
        cfg = get_arch("rwkv6-1.6b").smoke
        b1 = cache_bytes(cfg, batch=2, seq=100)
        b2 = cache_bytes(cfg, batch=2, seq=200000)
        assert b1 == b2  # O(1) state — the long_500k story

    def test_pad_cache_pads_only_kv(self):
        cfg = get_arch("jamba-v0.1-52b").smoke
        cache = model_mod.init_cache(cfg, batch=2, seq=8)
        padded = pad_cache(cfg, cache, 16)
        flat_before = jax.tree_util.tree_flatten_with_path(cache)[0]
        flat_after = jax.tree_util.tree_flatten_with_path(padded)[0]
        for (path_b, leaf_b), (path_a, leaf_a) in zip(flat_before, flat_after):
            name = str(path_b[-1])
            if "'k'" in name or "'v'" in name:
                assert leaf_a.shape[-3] == 16
            else:
                assert leaf_a.shape == leaf_b.shape


class TestMeshHelpers:
    def test_batch_axes(self):
        from repro.launch.mesh import batch_axes
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        assert batch_axes(mesh) == ("data",)

    def test_adapt_config_decode_long(self):
        from types import SimpleNamespace
        from repro.launch.shapes import adapt_config
        # production-shaped mock (adapt_config only reads names/shape)
        mesh = SimpleNamespace(axis_names=("data", "model"),
                               shape={"data": 16, "model": 16})
        arch = get_arch("rwkv6-1.6b")
        cfg = adapt_config(arch, arch.shape("long_500k"), mesh)
        assert cfg.batch_axes == ()             # batch 1 cannot shard 16 ways
        assert cfg.seq_axes == ("data", "model")
        assert not cfg.remat

    def test_adapt_config_decode_batched(self):
        from types import SimpleNamespace
        from repro.launch.shapes import adapt_config
        mesh = SimpleNamespace(axis_names=("data", "model"),
                               shape={"data": 16, "model": 16})
        arch = get_arch("stablelm-12b")
        cfg = adapt_config(arch, arch.shape("decode_32k"), mesh)
        assert cfg.batch_axes == ("data",)      # 128 % 16 == 0
        assert cfg.seq_axes == ("model",)       # flash-decoding over model

    def test_adapt_config_train(self):
        from repro.launch.shapes import adapt_config
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        arch = get_arch("command-r-plus-104b")
        cfg = adapt_config(arch, arch.shape("train_4k"), mesh)
        assert cfg.batch_axes == ("data",)
        assert cfg.shard_activations and cfg.remat


class TestModelFlopsAccounting:
    def test_moe_active_fraction(self):
        from benchmarks.roofline import model_params
        p = model_params("qwen3-moe-235b-a22b")
        # ~22B active of ~235B total
        assert p["active"] / p["total"] < 0.25
        assert p["total"] > 150e9

    def test_dense_active_equals_total(self):
        from benchmarks.roofline import model_params
        p = model_params("stablelm-12b")
        assert p["active"] == p["total"]
        assert 10e9 < p["total"] < 15e9
