"""Quantile sketch: exactness below cap, bounded error above, mergeability.

The sketch is the serving health layer's measurement primitive (per-cell
routing-distance windows, request-latency quantiles), so its contract is
pinned hard:

  * **exact mode** — below ``exact_cap`` the sketch IS ``np.quantile``
    with ``method="lower"`` (smallest value whose cumulative weight
    exceeds q*(count-1)); no approximation sneaks in early;
  * **merge ≡ pool** — merging sketches built from disjoint streams must
    answer exactly like one sketch fed the pooled stream (below cap), and
    within the TRACKED analytic rank-error bound above it.  The bound is
    the point: ``rank_error`` accumulates ``2^i`` per level-i compaction,
    so the property test can assert against the sketch's own error
    arithmetic instead of a hand-tuned epsilon;
  * **weight conservation** — sum over levels of ``len(level) * 2^i``
    equals the observation count at every moment (compaction moves
    weight, never loses it);
  * **JSONL round trip** — the serialized form re-answers identically and
    the metrics validator accepts it / rejects corruptions.
"""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.obs.sketch import QuantileSketch

QS = (0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0)


def _pooled_rank_gap(sk: QuantileSketch, pooled: np.ndarray, q: float) -> int:
    """Rank distance between the sketch's answer and the exact quantile."""
    v = sk.quantile(q)
    exact_rank = q * (pooled.size - 1)
    lo = np.searchsorted(np.sort(pooled), v, side="left")
    hi = np.searchsorted(np.sort(pooled), v, side="right") - 1
    return int(min(abs(lo - exact_rank), abs(hi - exact_rank)))


class TestExactMode:
    def test_matches_numpy_lower_quantile(self):
        rng = np.random.default_rng(0)
        vals = rng.normal(size=500)
        sk = QuantileSketch(exact_cap=2048)
        sk.observe_many(vals)
        assert sk.exact and sk.rank_error == 0
        for q in QS:
            assert sk.quantile(q) == np.quantile(vals, q, method="lower")

    def test_mean_and_count(self):
        sk = QuantileSketch()
        sk.observe_many([1.0, 2.0, 4.0])
        sk.observe(9.0)
        assert sk.count == 4
        assert sk.mean() == pytest.approx(4.0)

    def test_empty_sketch(self):
        sk = QuantileSketch()
        assert sk.count == 0 and np.isnan(sk.quantile(0.5))
        assert sk.summary()["count"] == 0


class TestMerge:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n1=st.integers(1, 400),
           n2=st.integers(1, 400))
    def test_merged_exact_equals_pooled(self, seed, n1, n2):
        rng = np.random.default_rng(seed)
        a, b = rng.normal(size=n1), rng.exponential(size=n2)
        s1 = QuantileSketch(exact_cap=1024)
        s2 = QuantileSketch(exact_cap=1024)
        s1.observe_many(a)
        s2.observe_many(b)
        s1.merge(s2)
        pooled = np.concatenate([a, b])
        assert s1.count == pooled.size
        assert s1.exact        # n1+n2 <= 800 < exact_cap: still exact
        for q in QS:
            assert s1.quantile(q) == np.quantile(pooled, q, method="lower")

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_compacted_merge_within_tracked_bound(self, seed):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=6000)
        b = rng.normal(loc=3.0, size=6000)
        s1 = QuantileSketch(exact_cap=256, level_cap=64)
        s2 = QuantileSketch(exact_cap=256, level_cap=64)
        s1.observe_many(a)
        s2.observe_many(b)
        s1.merge(s2)
        pooled = np.concatenate([a, b])
        assert not s1.exact and s1.rank_error > 0
        for q in (0.1, 0.5, 0.9, 0.99):
            assert _pooled_rank_gap(s1, pooled, q) <= s1.rank_error

    def test_registry_cap_mismatch_rejected(self):
        # merge() follows self's caps by design; the REGISTRY is where two
        # writers with different cap ideas must collide loudly
        from repro.obs.metrics import MetricsRegistry
        reg = MetricsRegistry()
        reg.sketch("s", exact_cap=128)
        with pytest.raises(ValueError):
            reg.sketch("s", exact_cap=64)


class TestConservation:
    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 5000))
    def test_weight_conserved_through_compaction(self, seed, n):
        rng = np.random.default_rng(seed)
        sk = QuantileSketch(exact_cap=64, level_cap=16)
        for chunk in np.array_split(rng.normal(size=n), 7):
            sk.observe_many(chunk)
            assert sum(len(lv) << i
                       for i, lv in enumerate(sk._levels)) == sk.count
        assert sk.count == n

    def test_deterministic(self):
        vals = np.random.default_rng(7).normal(size=20000)
        outs = []
        for _ in range(2):
            sk = QuantileSketch(exact_cap=256, level_cap=64)
            sk.observe_many(vals)
            outs.append((sk.rank_error, sk.quantiles((0.5, 0.9, 0.99))))
        assert outs[0][0] == outs[1][0]
        assert outs[0][1] == outs[1][1]


class TestSerialization:
    def test_json_round_trip(self):
        rng = np.random.default_rng(3)
        sk = QuantileSketch("serve.request_ms.q", exact_cap=128, level_cap=32)
        sk.observe_many(rng.exponential(size=5000))
        d = sk.to_json()
        assert d["type"] == "sketch"
        back = QuantileSketch.from_json(d)
        assert back.count == sk.count and back.rank_error == sk.rank_error
        for q in QS:
            assert back.quantile(q) == sk.quantile(q)

    def test_registry_jsonl_round_trip_and_validation(self, tmp_path):
        from repro.obs.metrics import MetricsRegistry, validate_jsonl
        reg = MetricsRegistry()
        reg.sketch("serve.request_ms.q").observe_many([1.0, 5.0, 9.0, 20.0])
        reg.counter("serve.served").inc(4)
        path = str(tmp_path / "m.jsonl")
        reg.write_jsonl(path)
        assert validate_jsonl(path) == []
        back, _hdr = MetricsRegistry.read_jsonl(path)
        assert back.sketch("serve.request_ms.q").quantile(0.5) == 5.0

    def test_validator_rejects_broken_sketch_lines(self, tmp_path):
        import json
        from repro.obs.metrics import MetricsRegistry, validate_jsonl
        reg = MetricsRegistry()
        reg.sketch("s").observe_many([1.0, 2.0, 3.0])
        path = str(tmp_path / "m.jsonl")
        reg.write_jsonl(path)
        lines = open(path).read().splitlines()
        hdr, sk_line = lines[0], json.loads(lines[1])

        broken = dict(sk_line, count=99)        # weight != count
        p = tmp_path / "bad1.jsonl"
        p.write_text(hdr + "\n" + json.dumps(broken) + "\n")
        assert validate_jsonl(str(p)) != []

        broken = dict(sk_line, rank_error=-1)
        p = tmp_path / "bad2.jsonl"
        p.write_text(hdr + "\n" + json.dumps(broken) + "\n")
        assert validate_jsonl(str(p)) != []

        broken = dict(sk_line, levels="nope")
        p = tmp_path / "bad3.jsonl"
        p.write_text(hdr + "\n" + json.dumps(broken) + "\n")
        assert validate_jsonl(str(p)) != []
