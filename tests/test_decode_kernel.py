"""Fused decode-attention kernel vs oracle, bf16 + int8 KV paths,
shape/dtype sweep per the kernel-validation requirement."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention_fused
from repro.kernels.decode_attention.ref import decode_attention_ref


def _mk(b, hk, g, d, s, quantize, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, hk, g, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
    ks = vs = None
    if quantize:
        ks = jnp.max(jnp.abs(k), -1, keepdims=True) / 127.0
        vs = jnp.max(jnp.abs(v), -1, keepdims=True) / 127.0
        k = jnp.clip(jnp.round(k / ks), -127, 127).astype(jnp.int8)
        v = jnp.clip(jnp.round(v / vs), -127, 127).astype(jnp.int8)
    return q, k, v, ks, vs


class TestDecodeKernel:
    @pytest.mark.parametrize("b,hk,g,d,s", [(2, 2, 4, 64, 256),
                                            (1, 4, 1, 128, 300),
                                            (2, 1, 8, 32, 512)])
    @pytest.mark.parametrize("quantize", [False, True])
    def test_matches_ref(self, b, hk, g, d, s, quantize):
        q, k, v, ks, vs = _mk(b, hk, g, d, s, quantize)
        pos = jnp.int32(s - 1)
        got = decode_attention_fused(q, k, v, pos, scale=d ** -0.5,
                                     k_scale=ks, v_scale=vs,
                                     force_pallas=True)
        want = decode_attention_ref(q, k, v, pos, d ** -0.5, ks, vs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5)

    def test_partial_cache_masking(self):
        """Ring positions beyond cache_pos never attend."""
        q, k, v, _, _ = _mk(1, 2, 2, 32, 256, False)
        pos = jnp.int32(100)
        got = decode_attention_fused(q, k, v, pos, scale=32 ** -0.5,
                                     force_pallas=True)
        # poisoning the invalid region must not change the result
        k2 = k.at[:, 101:].set(99.0)
        v2 = v.at[:, 101:].set(-99.0)
        got2 = decode_attention_fused(q, k2, v2, pos, scale=32 ** -0.5,
                                      force_pallas=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(got2),
                                   atol=1e-6)

    def test_window_masking(self):
        q, k, v, _, _ = _mk(1, 1, 2, 32, 256, False, seed=3)
        pos = jnp.int32(200)
        got = decode_attention_fused(q, k, v, pos, scale=32 ** -0.5,
                                     window=16, force_pallas=True)
        want = decode_attention_ref(q, k, v, pos, 32 ** -0.5, window=16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=3e-5)

    @settings(max_examples=8, deadline=None)
    @given(s=st.integers(16, 700), d=st.sampled_from([32, 64, 128]),
           g=st.integers(1, 8))
    def test_property_sweep(self, s, d, g):
        q, k, v, ks, vs = _mk(1, 2, g, d, s, True, seed=s)
        pos = jnp.int32(min(s - 1, 37))
        got = decode_attention_fused(q, k, v, pos, scale=d ** -0.5,
                                     k_scale=ks, v_scale=vs,
                                     force_pallas=True)
        want = decode_attention_ref(q, k, v, pos, d ** -0.5, ks, vs)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5)

    def test_int8_vs_f32_quality(self):
        """Quantized attention stays close to unquantized attention."""
        qf, kf, vf, _, _ = _mk(2, 2, 4, 64, 256, False, seed=7)
        _, k8, v8, ks, vs = _mk(2, 2, 4, 64, 256, True, seed=7)
        pos = jnp.int32(255)
        full = decode_attention_fused(qf, kf, vf, pos, scale=64 ** -0.5,
                                      force_pallas=True)
        quant = decode_attention_fused(qf, k8, v8, pos, scale=64 ** -0.5,
                                       k_scale=ks, v_scale=vs,
                                       force_pallas=True)
        rel = float(jnp.max(jnp.abs(full - quant)) / jnp.max(jnp.abs(full)))
        assert rel < 0.05, rel


class TestModelWiring:
    """fused_decode (the model-side wrapper) == the jnp decode executor."""

    @pytest.mark.parametrize("quantize", [False, True])
    def test_fused_matches_jnp_decode(self, quantize):
        from repro.models.attention import decode_attention, fused_decode
        rng = np.random.default_rng(11)
        b, h, hk, d, s = 2, 8, 2, 64, 256
        q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
        cache = {"k": k, "v": v}
        if quantize:
            ks = jnp.max(jnp.abs(k), -1, keepdims=True) / 127.0
            vs = jnp.max(jnp.abs(v), -1, keepdims=True) / 127.0
            cache = {"k": jnp.clip(jnp.round(k / ks), -127, 127).astype(jnp.int8),
                     "v": jnp.clip(jnp.round(v / vs), -127, 127).astype(jnp.int8),
                     "k_scale": ks, "v_scale": vs}
        pos = jnp.int32(200)
        got = fused_decode(q, cache, 64 ** -0.5, window=0, cache_pos=pos,
                           force_pallas=True)
        k_eff = cache["k"].astype(jnp.float32)
        v_eff = cache["v"].astype(jnp.float32)
        if quantize:
            k_eff = k_eff * cache["k_scale"]
            v_eff = v_eff * cache["v_scale"]
        want = decode_attention(q, k_eff, v_eff, 64 ** -0.5, cache_pos=pos)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=5e-5)
