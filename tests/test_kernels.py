"""Per-kernel Pallas-vs-oracle validation (interpret=True executes the kernel
body on CPU).  Shapes sweep non-multiples of the 128 tile to exercise the
padding paths; dtypes sweep f32/bf16 inputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels.cd_solver import ref as cd_ref
from repro.kernels.cd_solver.ops import cd_epochs, cd_epochs_wave
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.kernel_matrix import ref as km_ref
from repro.kernels.kernel_matrix.ops import kernel_matrix
from repro.kernels.svm_predict import ref as sp_ref
from repro.kernels.svm_predict.ops import svm_predict


# ------------------------------------------------------------- kernel_matrix

class TestKernelMatrix:
    @pytest.mark.parametrize("n,m,d", [(128, 128, 8), (256, 128, 64),
                                       (100, 37, 5), (130, 257, 200)])
    @pytest.mark.parametrize("kind", ["gauss_rbf", "laplacian"])
    def test_matches_ref(self, n, m, d, kind):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        got = kernel_matrix(x, z, jnp.float32(1.3), kind=kind, force_pallas=True)
        want = km_ref.kernel_matrix_ref(x, z, jnp.float32(1.3), kind)
        np.testing.assert_allclose(got, want, atol=1e-5)

    def test_bf16_inputs_upcast(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(64, 16)), jnp.bfloat16)
        got = kernel_matrix(x, x, jnp.float32(2.0), force_pallas=True)
        want = km_ref.kernel_matrix_ref(x, x, jnp.float32(2.0), "gauss_rbf")
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(got, want, atol=1e-2)

    @settings(max_examples=15, deadline=None)
    @given(n=st.integers(2, 160), d=st.integers(1, 40),
           gamma=st.floats(0.2, 8.0))
    def test_property_gram_valid(self, n, d, gamma):
        """Gram of the Gaussian kernel: symmetric, unit diagonal, in (0, 1]."""
        rng = np.random.default_rng(42)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        k = np.asarray(kernel_matrix(x, x, jnp.float32(gamma), force_pallas=True))
        np.testing.assert_allclose(k, k.T, atol=1e-5)
        # the MXU-friendly ||u||^2+||v||^2-2uv decomposition loses ~1e-4 of
        # d^2 to f32 cancellation; at small gamma that shows up on the diag
        # as exp(-eps/gamma^2) != 1 — inherent to the paper's own GPU trick
        np.testing.assert_allclose(np.diag(k), 1.0, atol=5e-3)
        assert (k >= 0).all() and (k <= 1.0 + 1e-4).all()  # exp may underflow to 0


# ------------------------------------------------------------------ cd_solver

class TestCDSolver:
    @pytest.mark.parametrize("n,p", [(128, 1), (128, 16), (200, 5), (64, 3)])
    def test_epoch_bitwise_matches_ref(self, n, p):
        rng = np.random.default_rng(2)
        a = rng.normal(size=(n, n)).astype(np.float32)
        k = jnp.asarray(a @ a.T / n + np.eye(n, dtype=np.float32))
        y = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
        lo = jnp.full((n, p), -0.7, jnp.float32)
        hi = jnp.full((n, p), 0.7, jnp.float32)
        c0 = jnp.zeros((n, p), jnp.float32)
        got = cd_epochs(k, y, lo, hi, c0, epochs=3, force_pallas=True)
        want, _ = cd_ref.solve_cd_ref(k, y, lo, hi, c0, epochs=3)
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_padding_coordinates_stay_zero(self):
        n, p = 100, 4  # pads to 128
        rng = np.random.default_rng(3)
        a = rng.normal(size=(n, n)).astype(np.float32)
        k = jnp.asarray(a @ a.T / n)
        y = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
        lo, hi = jnp.full((n, p), -1.0), jnp.full((n, p), 1.0)
        c = cd_epochs(k, y, lo.astype(jnp.float32), hi.astype(jnp.float32),
                      jnp.zeros((n, p), jnp.float32), epochs=2, force_pallas=True)
        assert c.shape == (n, p)

    def test_monotone_dual_descent(self):
        from repro.core.solvers.base import dual_objective
        rng = np.random.default_rng(4)
        a = rng.normal(size=(64, 64)).astype(np.float32)
        k = jnp.asarray(a @ a.T / 64 + 0.1 * np.eye(64, dtype=np.float32))
        y = jnp.asarray(np.sign(rng.normal(size=(64, 2))), jnp.float32)
        lo, hi = jnp.minimum(0.0, y), jnp.maximum(0.0, y)
        prev = -np.inf
        c = jnp.zeros((64, 2), jnp.float32)
        for _ in range(4):
            c = cd_epochs(k, y, lo, hi, c, epochs=1, force_pallas=True)
            obj = float(np.sum(np.asarray(dual_objective(k, y, c))))
            assert obj >= prev - 1e-5
            prev = obj


class TestCDWave:
    """Fusion contract of the wave solver (cd_solver.py module docstring):
    the Pallas wave launch reproduces the per-slot kernel bit-for-bit, and
    the off-TPU blocked path matches the exact oracle to f32 rounding."""

    @staticmethod
    def _wave(s, n, p, seed=5):
        rng = np.random.default_rng(seed)
        a = rng.normal(size=(s, n, n)).astype(np.float32)
        k = jnp.asarray(np.einsum("sij,skj->sik", a, a) / n
                        + np.eye(n, dtype=np.float32))
        y = jnp.asarray(rng.normal(size=(s, n, p)), jnp.float32)
        lo = jnp.zeros((s, n, p), jnp.float32)
        hi = jnp.asarray(np.abs(rng.normal(size=(s, n, p))) + 0.1, jnp.float32)
        c0 = jnp.clip(jnp.asarray(rng.normal(size=(s, n, p)) * 0.05,
                                  jnp.float32), lo, hi)
        return k, y, lo, hi, c0

    def test_wave_pallas_bitwise_per_slot(self):
        # at an exact block multiple the fused wave launch must equal S
        # per-slot launches BIT-FOR-BIT (same coordinate sequence)
        s, n, p = 3, 128, 4
        k, y, lo, hi, c0 = self._wave(s, n, p)
        fused = cd_epochs_wave(k, y, lo, hi, c0, epochs=2, force_pallas=True)
        for i in range(s):
            slot = cd_epochs(k[i], y[i], lo[i], hi[i], c0[i], epochs=2,
                             force_pallas=True)
            np.testing.assert_array_equal(np.asarray(fused[i]),
                                          np.asarray(slot))

    def test_wave_pallas_padded_matches_per_slot(self):
        # padded n: the g0 = K c0 matmul pads, shifting reduction order —
        # f32-rounding parity, not bitwise
        s, n, p = 2, 150, 3
        k, y, lo, hi, c0 = self._wave(s, n, p, seed=6)
        fused = cd_epochs_wave(k, y, lo, hi, c0, epochs=2, force_pallas=True)
        assert fused.shape == (s, n, p)
        for i in range(s):
            slot = cd_epochs(k[i], y[i], lo[i], hi[i], c0[i], epochs=2,
                             force_pallas=True)
            np.testing.assert_allclose(np.asarray(fused[i]),
                                       np.asarray(slot), atol=1e-5)

    @pytest.mark.parametrize("n", [128, 96, 150])  # multiple / exact / padded
    def test_wave_blocked_matches_oracle(self, n):
        # the production off-TPU path (delayed trailing updates) reaches the
        # exact sweep's iterates to f32 rounding, padding included
        s, p = 2, 5
        k, y, lo, hi, c0 = self._wave(s, n, p, seed=7)
        got = cd_epochs_wave(k, y, lo, hi, c0, epochs=3)
        want, _ = cd_ref.solve_cd_wave_ref(k, y, lo, hi, c0, 3)
        assert got.shape == (s, n, p)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-5)

    def test_blocked_padding_coordinates_inert(self):
        # a cell whose true size is below the padded width: padded rows
        # carry lo == hi == 0 and must solve to exactly 0
        n_true, n_pad, p = 40, 64, 3
        rng = np.random.default_rng(8)
        a = rng.normal(size=(n_true, n_true)).astype(np.float32)
        k = np.zeros((1, n_pad, n_pad), np.float32)
        k[0, :n_true, :n_true] = a @ a.T / n_true + np.eye(n_true)
        y = np.zeros((1, n_pad, p), np.float32)
        y[0, :n_true] = rng.normal(size=(n_true, p))
        box = np.zeros((1, n_pad, p), np.float32)
        box[0, :n_true] = 0.9
        c = cd_epochs_wave(jnp.asarray(k), jnp.asarray(y),
                           jnp.asarray(-box), jnp.asarray(box),
                           jnp.zeros((1, n_pad, p), jnp.float32), epochs=2)
        assert np.all(np.asarray(c)[0, n_true:] == 0.0)
        want, _ = cd_ref.solve_cd_ref(
            jnp.asarray(k[0, :n_true, :n_true]), jnp.asarray(y[0, :n_true]),
            jnp.asarray(-box[0, :n_true]), jnp.asarray(box[0, :n_true]),
            jnp.zeros((n_true, p), jnp.float32), 2)
        np.testing.assert_allclose(np.asarray(c)[0, :n_true],
                                   np.asarray(want), atol=2e-5)


# ----------------------------------------------------------------- svm_predict

class TestSVMPredict:
    @pytest.mark.parametrize("nt,ns,d,p", [(128, 128, 8, 1), (100, 250, 17, 12),
                                           (257, 64, 4, 3)])
    @pytest.mark.parametrize("kind", ["gauss_rbf", "laplacian"])
    def test_matches_ref(self, nt, ns, d, p, kind):
        rng = np.random.default_rng(5)
        xt = jnp.asarray(rng.normal(size=(nt, d)), jnp.float32)
        sv = jnp.asarray(rng.normal(size=(ns, d)), jnp.float32)
        cf = jnp.asarray(rng.normal(size=(ns, p)), jnp.float32)
        got = svm_predict(xt, sv, cf, jnp.float32(1.1), kind=kind, force_pallas=True)
        want = sp_ref.svm_predict_ref(xt, sv, cf, jnp.float32(1.1), kind)
        np.testing.assert_allclose(got, want, atol=1e-4)

    def test_1d_coef_roundtrip(self):
        rng = np.random.default_rng(6)
        xt = jnp.asarray(rng.normal(size=(50, 3)), jnp.float32)
        sv = jnp.asarray(rng.normal(size=(70, 3)), jnp.float32)
        cf = jnp.asarray(rng.normal(size=70), jnp.float32)
        got = svm_predict(xt, sv, cf, jnp.float32(0.9), force_pallas=True)
        assert got.shape == (50,)

    @settings(max_examples=10, deadline=None)
    @given(nt=st.integers(1, 140), ns=st.integers(1, 140), d=st.integers(1, 24))
    def test_property_matches_dense_path(self, nt, ns, d):
        """Fused predict == materialized Gram @ coefs for any shape."""
        rng = np.random.default_rng(7)
        xt = jnp.asarray(rng.normal(size=(nt, d)), jnp.float32)
        sv = jnp.asarray(rng.normal(size=(ns, d)), jnp.float32)
        cf = jnp.asarray(rng.normal(size=(ns, 2)), jnp.float32)
        got = svm_predict(xt, sv, cf, jnp.float32(1.5), force_pallas=True)
        k = km_ref.kernel_matrix_ref(xt, sv, jnp.float32(1.5), "gauss_rbf")
        np.testing.assert_allclose(got, k @ cf, atol=1e-4)


# ------------------------------------------------------------ flash_attention

class TestFlashAttention:
    @pytest.mark.parametrize("mask_kind,window", [("causal", 0), ("window", 64),
                                                  ("bidir", 0)])
    @pytest.mark.parametrize("t,s,h,hk,d", [
        (128, 128, 4, 4, 64),    # MHA, aligned
        (100, 100, 4, 2, 32),    # GQA, unaligned seq
        (1, 200, 8, 1, 64),      # decode: 1 query vs long kv (MQA)
    ])
    def test_matches_ref(self, mask_kind, window, t, s, h, hk, d):
        if mask_kind in ("causal", "window") and t > s:
            pytest.skip("query longer than kv is not a decode/prefill shape")
        rng = np.random.default_rng(8)
        q = jnp.asarray(rng.normal(size=(2, t, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(2, s, hk, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(2, s, hk, d)), jnp.float32)
        got = flash_attention(q, k, v, mask_kind=mask_kind, window=window,
                              force_pallas=True)
        want = fa_ref.flash_attention_ref(q, k, v, mask_kind, window)
        np.testing.assert_allclose(got, want, atol=2e-4)

    def test_bf16_close_to_f32_ref(self):
        rng = np.random.default_rng(9)
        q = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 64, 2, 32)), jnp.bfloat16)
        got = flash_attention(q, k, v, force_pallas=True)
        want = fa_ref.flash_attention_ref(
            q.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
            "causal", 0)
        np.testing.assert_allclose(np.asarray(got, np.float32), want, atol=3e-2)

    def test_window_equals_causal_when_window_covers_seq(self):
        rng = np.random.default_rng(10)
        q = jnp.asarray(rng.normal(size=(1, 96, 2, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 96, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 96, 2, 32)), jnp.float32)
        a = flash_attention(q, k, v, mask_kind="window", window=96, force_pallas=True)
        b = flash_attention(q, k, v, mask_kind="causal", force_pallas=True)
        np.testing.assert_allclose(a, b, atol=1e-5)

    def test_causal_first_row_attends_self_only(self):
        rng = np.random.default_rng(11)
        q = jnp.asarray(rng.normal(size=(1, 130, 1, 32)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(1, 130, 1, 32)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(1, 130, 1, 32)), jnp.float32)
        out = flash_attention(q, k, v, mask_kind="causal", force_pallas=True)
        np.testing.assert_allclose(out[0, 0, 0], v[0, 0, 0], atol=1e-5)
