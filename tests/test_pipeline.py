"""Streaming data pipeline gates: source round-trips, bit-identical
streaming cell construction, device-side assignment parity, minibatch
k-means determinism, and wave-scheduled training equivalence."""
from __future__ import annotations

import dataclasses
import os

import numpy as np
import pytest

from repro.cells.builder import build_cells
from repro.data.scaling import Scaler
from repro.pipeline import assign
from repro.pipeline.cell_stream import build_cells_stream
from repro.pipeline.dataset import (ArraySource, MemmapSource, ScaledSource,
                                    ShardedNpzSource, as_source,
                                    streaming_mean_std)

PLAN_FIELDS = ("indices", "mask", "owner", "centers", "coarse_of")


def _data(n=733, d=5, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


@pytest.fixture(scope="module")
def x():
    return _data()


@pytest.fixture(scope="module")
def npy_path(tmp_path_factory, x):
    p = tmp_path_factory.mktemp("pipe") / "x.npy"
    np.save(p, x)
    return os.fspath(p)


@pytest.fixture(scope="module")
def npz_paths(tmp_path_factory, x):
    d = tmp_path_factory.mktemp("pipe_npz")
    paths = []
    for i, lo in enumerate(range(0, x.shape[0], 250)):
        p = d / f"shard_{i}.npz"
        np.savez(p, x=x[lo:lo + 250])
        paths.append(os.fspath(p))
    return paths


class TestSources:
    def test_memmap_round_trip(self, x, npy_path):
        src = MemmapSource(npy_path)
        assert src.shape == x.shape
        got = np.concatenate([c for _, c in src.iter_chunks(97)])
        np.testing.assert_array_equal(got, x)
        ids = np.array([700, 3, 3, 12, 0], np.int64)    # unsorted + repeats
        np.testing.assert_array_equal(src.gather(ids), x[ids])

    def test_sharded_npz_round_trip(self, x, npz_paths):
        src = ShardedNpzSource(npz_paths)
        assert src.shape == x.shape
        starts = [lo for lo, _ in src.iter_chunks(61)]
        assert starts[0] == 0 and sorted(starts) == starts
        got = np.concatenate([c for _, c in src.iter_chunks(61)])
        np.testing.assert_array_equal(got, x)
        ids = np.array([0, 501, 249, 250, 732], np.int64)  # cross-shard
        np.testing.assert_array_equal(src.gather(ids), x[ids])

    def test_scaled_source_matches_scaler(self, x):
        sc = Scaler.fit(x)
        src = ScaledSource(ArraySource(x), sc.mean, sc.std)
        np.testing.assert_array_equal(src.materialize(), sc.transform(x))

    def test_streaming_mean_std(self, x, npy_path):
        mean, std = streaming_mean_std(MemmapSource(npy_path), chunk_size=90)
        np.testing.assert_allclose(mean, x.mean(0), atol=1e-5)
        np.testing.assert_allclose(std, x.std(0), atol=1e-5)
        sc = Scaler.fit_stream(npy_path, chunk_size=90)
        np.testing.assert_allclose(sc.mean, Scaler.fit(x).mean, atol=1e-5)

    def test_as_source_coercions(self, x, npy_path, npz_paths):
        assert isinstance(as_source(x), ArraySource)
        assert isinstance(as_source(npy_path), MemmapSource)
        assert isinstance(as_source(npz_paths), ShardedNpzSource)
        src = as_source(x)
        assert as_source(src) is src


class TestStreamingBuilder:
    """The tentpole gate: streaming plan == in-memory plan, bit for bit."""

    @pytest.mark.parametrize("method", ["none", "random", "voronoi",
                                        "overlap", "recursive", "coarse_fine"])
    def test_bitwise_equal_to_in_memory(self, method, x, npy_path, npz_paths):
        ref = build_cells(x, cell_size=120, method=method, seed=3,
                          coarse_size=300)
        for src, cs in ((MemmapSource(npy_path), 97),
                        (ShardedNpzSource(npz_paths), 61)):
            plan = build_cells_stream(src, cell_size=120, method=method,
                                      seed=3, coarse_size=300, chunk_size=cs)
            for f in PLAN_FIELDS:
                a, b = getattr(ref, f), getattr(plan, f)
                assert a.shape == b.shape, (method, f)
                assert (a == b).all(), (method, f)

    def test_chunk_size_invariance(self, x):
        plans = [build_cells_stream(x, cell_size=100, method="voronoi",
                                    seed=1, chunk_size=cs)
                 for cs in (37, 256, 10_000)]
        for p in plans[1:]:
            for f in PLAN_FIELDS:
                assert (getattr(plans[0], f) == getattr(p, f)).all(), f

    def test_pad_to_respected(self, x):
        plan = build_cells_stream(x, cell_size=100, method="voronoi",
                                  seed=1, pad_to=256)
        assert plan.k_max == 256


class TestAssign:
    def test_device_paths_match_host(self, x):
        centers = _data(13, 5, seed=9)
        ref = assign.nearest_center(x, centers, chunk_size=128)
        np.testing.assert_array_equal(
            ref, assign.assign_stream(x, centers, chunk_size=160,
                                      backend="jax"))
        np.testing.assert_array_equal(
            ref, assign.assign_stream(x, centers, chunk_size=200,
                                      backend="pallas"))

    def test_top2_distinct_and_first_is_nearest(self, x):
        centers = _data(11, 5, seed=8)
        nn1, nn2 = assign.nearest_top2(x, centers, chunk_size=100)
        assert (nn1 != nn2).all()
        np.testing.assert_array_equal(nn1, assign.nearest_center(x, centers))

    def test_lloyd_stream_chunk_invariant(self, x):
        init = _data(9, 5, seed=7)
        a = assign.lloyd_stream(x, init, iters=3, chunk_size=77)
        b = assign.lloyd_stream(x, init, iters=3, chunk_size=733)
        np.testing.assert_array_equal(a, b)

    def test_minibatch_kmeans_seeded_determinism(self, x, npy_path):
        a = assign.minibatch_kmeans(x, 8, iters=8, batch_size=128, seed=5)
        b = assign.minibatch_kmeans(MemmapSource(npy_path), 8, iters=8,
                                    batch_size=128, seed=5)
        np.testing.assert_array_equal(a, b)     # source-independent too
        c = assign.minibatch_kmeans(x, 8, iters=8, batch_size=128, seed=6)
        assert not (a == c).all()
        # centers actually cluster: inertia drops vs the initial sample
        init = x[np.random.default_rng(5).choice(len(x), 8, replace=False)]
        def inertia(cen):
            d2 = assign._d2_chunk(x, np.asarray(cen, np.float32))
            return float(d2.min(1).mean())
        assert inertia(a) < inertia(init)


class TestWaveTraining:
    def _fit(self, wave, ckpt_dir=None, **kw):
        from repro.data.synthetic import covtype_like, train_test_split
        from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig
        x, y = covtype_like(n=600, d=4, seed=0, label_noise=0.02, n_modes=3)
        xtr, ytr, xte, yte = train_test_split(x, np.where(y == 0, -1, 1),
                                              0.25, 0)
        cfg = SVMTrainerConfig(n_folds=2, max_iters=150,
                               cell_method="voronoi", cell_size=120,
                               n_slots_per_wave=wave, **kw)
        m = LiquidSVM(cfg).fit(xtr, ytr, ckpt_dir=ckpt_dir)
        return m, xte

    def test_wave_equals_single_wave(self):
        m1, xte = self._fit(None)
        m2, _ = self._fit(2)
        assert m1.packed.n_slots > 2            # waves actually split
        np.testing.assert_array_equal(m1.decision_function(xte),
                                      m2.decision_function(xte))

    def test_wave_checkpoint_resume(self, tmp_path):
        ck = os.fspath(tmp_path / "waves")
        m1, xte = self._fit(2, ckpt_dir=ck)
        assert os.path.exists(os.path.join(ck, "latest"))
        m2, _ = self._fit(2, ckpt_dir=ck)       # restores every wave
        np.testing.assert_array_equal(m1.decision_function(xte),
                                      m2.decision_function(xte))

    def test_stale_checkpoint_rejected(self, tmp_path):
        """A ckpt_dir left by a DIFFERENT run (other seed/config/data) must
        be ignored, not silently restored into the new fit."""
        ck = os.fspath(tmp_path / "waves")
        self._fit(2, ckpt_dir=ck)                  # leaves seed-0 waves
        m_resumed, xte = self._fit(2, ckpt_dir=ck, seed=1)
        m_fresh, _ = self._fit(2, seed=1)          # no checkpoint at all
        np.testing.assert_array_equal(m_resumed.decision_function(xte),
                                      m_fresh.decision_function(xte))

    def test_fit_from_memmap_source(self, tmp_path):
        from repro.data.synthetic import covtype_like, train_test_split
        from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig
        x, y = covtype_like(n=500, d=4, seed=2, label_noise=0.02, n_modes=3)
        xtr, ytr, xte, yte = train_test_split(x, np.where(y == 0, -1, 1),
                                              0.25, 2)
        p = tmp_path / "xtr.npy"
        np.save(p, xtr)
        cfg = SVMTrainerConfig(n_folds=2, max_iters=150,
                               cell_method="voronoi", cell_size=120,
                               n_slots_per_wave=2, chunk_size=128)
        m = LiquidSVM(cfg).fit(os.fspath(p), ytr)
        assert m.error(xte, yte) < 0.2
        # container invariance: the memmap fit IS the ndarray fit, bitwise
        m_arr = LiquidSVM(cfg).fit(xtr, ytr)
        np.testing.assert_array_equal(m.decision_function(xte),
                                      m_arr.decision_function(xte))
        # engine hand-off keeps working from a source-fitted model
        from repro.serve.svm_engine import SVMEngine
        eng = SVMEngine(m.to_bank(), fused=False)
        dec = eng.predict(xte[:16])
        np.testing.assert_allclose(dec.reshape(16, -1),
                                   m.decision_function(xte[:16])
                                   .reshape(16, -1), atol=1e-5)
