"""Distance-cache Gram pipeline: kernel parity, symmetry, CV equivalence.

Covers the contract of the gamma-reuse pipeline end to end:

  * ``gram_from_d2`` epilogue == the ``kernel_fns`` oracles on the same D²
    (1e-5 f32; bf16 carries ~8e-3 — one half-precision rounding of values
    in (0, 1], i.e. 2**-7 ulp at the top of the range);
  * the symmetric (upper-triangle + mirror) train-Gram path is EXACTLY
    symmetric, bitwise;
  * ``cv_cell`` with the cached D² selects identical hyper-parameters and
    matches validation losses to <= 1e-5 vs. the per-gamma-Gram baseline.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cv as cv_mod
from repro.core import grids, kernel_fns
from repro.core.svm import train_select
from repro.kernels.kernel_matrix.ops import gram_from_d2, kernel_matrix, sq_dists


class TestSqDists:
    @pytest.mark.parametrize("n,m,d", [(128, 128, 8), (100, 37, 5), (130, 257, 33)])
    def test_cross_matches_oracle(self, n, m, d):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(m, d)), jnp.float32)
        got = sq_dists(x, z, force_pallas=True)
        np.testing.assert_allclose(got, kernel_fns.sq_dists(x, z), atol=1e-4)

    @pytest.mark.parametrize("n,d", [(64, 4), (130, 17), (256, 40)])
    def test_symmetric_matches_oracle(self, n, d):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
        got = sq_dists(x, x, symmetric=True, force_pallas=True)
        np.testing.assert_allclose(got, kernel_fns.sq_dists(x, x), atol=1e-4)

    @pytest.mark.parametrize("force_pallas", [True, False])
    def test_symmetric_gram_exactly_symmetric(self, force_pallas):
        """Upper-triangle compute + mirror-on-write: K == K.T BITWISE."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.normal(size=(150, 9)), jnp.float32)
        d2 = np.asarray(sq_dists(x, x, symmetric=True, force_pallas=force_pallas))
        assert (d2 == d2.T).all()
        k = np.asarray(gram_from_d2(jnp.asarray(d2), jnp.float32(1.7),
                                    force_pallas=force_pallas))
        assert (k == k.T).all()


class TestGramFromD2:
    @pytest.mark.parametrize("kind,oracle", [("gauss_rbf", kernel_fns.gaussian),
                                             ("laplacian", kernel_fns.laplacian)])
    @pytest.mark.parametrize("gamma", [0.4, 1.3, 6.0])
    def test_f32_parity_with_oracle(self, kind, oracle, gamma):
        """Same D² in, epilogue out must match the jnp kernel oracles 1e-5."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(130, 17)), jnp.float32)
        d2 = kernel_fns.sq_dists(x, x)
        got = gram_from_d2(d2, jnp.float32(gamma), kind=kind, force_pallas=True)
        assert got.dtype == jnp.float32
        np.testing.assert_allclose(got, oracle(x, x, jnp.float32(gamma)), atol=1e-5)

    @pytest.mark.parametrize("kind,oracle", [("gauss_rbf", kernel_fns.gaussian),
                                             ("laplacian", kernel_fns.laplacian)])
    def test_bf16_downcast_tolerance(self, kind, oracle):
        """bf16 fused downcast: kernel values live in (0, 1], so one bf16
        rounding is at most 2**-8 relative ~ 8e-3 absolute (documented)."""
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.normal(size=(96, 12)), jnp.float32)
        d2 = kernel_fns.sq_dists(x, x)
        got = gram_from_d2(d2, jnp.float32(1.1), kind=kind, out_dtype="bf16",
                           force_pallas=True)
        assert got.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   oracle(x, x, jnp.float32(1.1)), atol=8e-3)

    def test_matches_fused_kernel_matrix(self):
        """Split D² + epilogue == the one-shot fused Pallas Gram."""
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(140, 20)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(90, 20)), jnp.float32)
        fused = kernel_matrix(x, z, jnp.float32(2.2), force_pallas=True)
        split = gram_from_d2(sq_dists(x, z, force_pallas=True), jnp.float32(2.2),
                             force_pallas=True)
        np.testing.assert_allclose(split, fused, atol=1e-5)


class TestRegistryFactorization:
    def test_builtins_declare_d2(self):
        assert kernel_fns.factors_through_d2("gauss_rbf")
        assert kernel_fns.factors_through_d2("laplacian")

    def test_custom_kernel_without_epilogue_falls_back(self):
        kernel_fns.register_kernel(
            "_test_poly", lambda x, z, g: (x @ z.T / g) ** 2)
        try:
            assert not kernel_fns.factors_through_d2("_test_poly")
            rng = np.random.default_rng(6)
            x = jnp.asarray(rng.normal(size=(16, 3)), jnp.float32)
            gs = jnp.asarray([1.0, 2.0], jnp.float32)
            ks = kernel_fns.gram_for_gammas(x, x, gs, name="_test_poly")
            np.testing.assert_allclose(
                ks[1], kernel_fns.get_kernel("_test_poly")(x, x, 2.0), atol=1e-5)
        finally:
            kernel_fns.unregister_kernel("_test_poly")

    def test_cached_gram_api(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(40, 6)), jnp.float32)
        cg = kernel_fns.CachedGram.build(x, name="gauss_rbf")
        k1 = cg.gram(jnp.float32(1.5))
        np.testing.assert_allclose(k1, kernel_fns.gaussian(x, x, 1.5), atol=1e-5)
        gs = jnp.asarray([0.5, 1.5, 4.0], jnp.float32)
        ks = cg.grams(gs)
        assert ks.shape == (3, 40, 40)
        np.testing.assert_allclose(ks[1], k1, atol=1e-6)
        many = kernel_fns.gram_for_gammas(x, x, gs, symmetric=True)
        np.testing.assert_allclose(many, ks, atol=1e-6)


class TestBF16D2Storage:
    """bf16 D² *storage* (not just bf16 K): the cache leaf itself is 2-byte.

    Error model (Gaussian): d2' = d2 (1 + δ), |δ| <= 2^-8 (bf16 keeps 7
    fraction bits; round-to-nearest half-ulp), so |K' - K| ~= K * (d2/g²)
    * |δ| <= max_u u e^{-u} * 2^-8 = e^{-1} * 2^-8 ~= 1.4e-3 — UNIFORM in
    gamma.  Small gamma makes the epilogue steep (exp(-d2/g²) swings over
    many orders), but the worst-case absolute error stays at the u e^{-u}
    peak; the test pins the analytic bound exactly there.
    """

    # e^{-1} * 2^-8, plus one f32 epilogue rounding of slack
    _GAUSS_BOUND = float(np.exp(-1.0)) * 2.0 ** -8 * 1.05

    @pytest.mark.parametrize("gamma", [0.05, 0.2, 1.0])
    def test_error_bound_small_gamma(self, gamma):
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.normal(size=(160, 10)), jnp.float32)
        cg16 = kernel_fns.CachedGram.build(x, d2_dtype="bf16")
        cg32 = kernel_fns.CachedGram.build(x, d2_dtype="f32")
        assert cg16.d2.dtype == jnp.bfloat16
        assert cg16.nbytes * 2 == cg32.nbytes
        err = np.abs(np.asarray(cg16.gram(jnp.float32(gamma)))
                     - np.asarray(cg32.gram(jnp.float32(gamma))))
        assert err.max() <= self._GAUSS_BOUND, (gamma, err.max())

    def test_cross_gram_fn_threads_dtype(self):
        rng = np.random.default_rng(12)
        x = jnp.asarray(rng.normal(size=(32, 5)), jnp.float32)
        z = jnp.asarray(rng.normal(size=(24, 5)), jnp.float32)
        gram_of = kernel_fns.cross_gram_fn(x, z, d2_dtype="bf16")
        k = gram_of(jnp.float32(0.3))
        ref = kernel_fns.gaussian(x, z, jnp.float32(0.3))
        np.testing.assert_allclose(np.asarray(k), np.asarray(ref),
                                   atol=self._GAUSS_BOUND)

    def test_bad_dtype_raises(self):
        x = jnp.zeros((8, 2), jnp.float32)
        with pytest.raises(ValueError):
            kernel_fns.CachedGram.build(x, d2_dtype="fp8")


class TestCVEquivalence:
    @pytest.mark.parametrize("solver,kernel", [("hinge", "gauss_rbf"),
                                               ("ls", "gauss_rbf"),
                                               ("hinge", "laplacian")])
    def test_cached_selects_same_hyperparams(self, solver, kernel):
        """cache_d2=True must select the same (gamma, lambda) and match the
        full validation surface to <= 1e-5 vs. the per-gamma-Gram baseline."""
        rng = np.random.default_rng(8)
        n = 120
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 3)) + 1.2 * y[:, None]).astype(np.float32)
        g = grids.GridSpec(gammas=jnp.asarray([4.0, 2.0, 1.0, 0.5], jnp.float32),
                           lambdas=jnp.asarray([1.0, 0.1, 0.01], jnp.float32))
        cfg = cv_mod.CVConfig(solver=solver, kernel=kernel, n_folds=3,
                              max_iters=200)
        m_cached = train_select(x, y, grid=g, cfg=cfg, seed=3)
        m_base = train_select(x, y, grid=g,
                              cfg=dataclasses.replace(cfg, cache_d2=False), seed=3)
        assert float(m_cached.gamma[0, 0]) == float(m_base.gamma[0, 0])
        assert float(m_cached.lam[0, 0]) == float(m_base.lam[0, 0])
        np.testing.assert_allclose(m_cached.val_loss, m_base.val_loss, atol=1e-5)

    def test_full_cv_surface_close(self):
        rng = np.random.default_rng(9)
        n = 100
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 4)) + y[:, None]).astype(np.float32)
        g = grids.GridSpec(gammas=jnp.asarray([3.0, 1.0, 0.3], jnp.float32),
                           lambdas=jnp.asarray([0.5, 0.05], jnp.float32))
        cfg = cv_mod.CVConfig(n_folds=3, max_iters=150)
        lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(g, cfg, 1)
        args = (x, y[None, :], jnp.ones((1, n), jnp.float32),
                jnp.ones((n,), jnp.float32), g.gammas, lam_c, sub_c, task_c,
                jnp.zeros(2, jnp.uint32))
        sel_c = cv_mod.cv_cell(*args, cfg, n_lam=n_lam, n_sub=n_sub)
        sel_b = cv_mod.cv_cell(*args, dataclasses.replace(cfg, cache_d2=False),
                               n_lam=n_lam, n_sub=n_sub)
        np.testing.assert_allclose(sel_c.val_grid, sel_b.val_grid, atol=1e-5)
