"""Tiny deterministic fallback for ``hypothesis`` on bare interpreters.

The tier-1 suite must collect and run without optional dev dependencies.
When hypothesis is installed we re-export the real API unchanged; otherwise
``@given`` degrades to a fixed-seed sweep of a handful of drawn examples —
far weaker than real property testing, but it keeps the property tests
exercising the code instead of being skipped wholesale.

Usage in test modules::

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np

    _FALLBACK_MAX_EXAMPLES = 6  # keep the bare-interpreter sweep cheap

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _Strategies()

    def settings(max_examples=_FALLBACK_MAX_EXAMPLES, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        def deco(fn):
            # no functools.wraps: __wrapped__ would leak the original
            # signature and pytest would treat drawn params as fixtures
            def wrapper(*args):
                n = min(getattr(wrapper, "_max_examples", _FALLBACK_MAX_EXAMPLES),
                        _FALLBACK_MAX_EXAMPLES)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    fn(*args, **{k: s.draw(rng) for k, s in strats.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
