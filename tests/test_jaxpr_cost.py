"""Trip-aware jaxpr cost analyzer: validated against analytic FLOP counts.
This is the meter behind every §Roofline number, so it gets its own tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.jaxpr_cost import Cost, cost_of


class TestDotCost:
    def test_plain_matmul(self):
        a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
        c = cost_of(lambda x, y: x @ y, a, b)
        assert c.flops == 2 * 64 * 128 * 32
        assert c.bytes == (64 * 128 + 128 * 32 + 64 * 32) * 4 \
            + (64 * 128 + 128 * 32) * 4  # invars charged once as sources

    def test_batched_einsum(self):
        a = jax.ShapeDtypeStruct((4, 16, 32), jnp.bfloat16)
        b = jax.ShapeDtypeStruct((4, 32, 8), jnp.bfloat16)
        c = cost_of(lambda x, y: jnp.einsum("bij,bjk->bik", x, y), a, b)
        assert c.flops == 2 * 4 * 16 * 32 * 8

    def test_scan_multiplies_by_length(self):
        """The whole reason this module exists (XLA counts bodies once)."""
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

        def f(x0):
            def body(c, _):
                return c @ c, None
            c, _ = jax.lax.scan(body, x0, None, length=10)
            return c

        c = cost_of(f, x)
        assert c.flops >= 10 * 2 * 128 ** 3
        assert c.flops < 10.5 * 2 * 128 ** 3

    def test_nested_scans_multiply(self):
        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def f(x0):
            def outer(c, _):
                def inner(ci, _):
                    return ci @ ci, None
                ci, _ = jax.lax.scan(inner, c, None, length=3)
                return ci, None
            c, _ = jax.lax.scan(outer, x0, None, length=5)
            return c

        c = cost_of(f, x)
        assert c.flops == pytest.approx(15 * 2 * 64 ** 3, rel=0.05)

    def test_while_uses_caller_trips(self):
        x = jax.ShapeDtypeStruct((32, 32), jnp.float32)

        def f(x0):
            def cond(s):
                return jnp.sum(s) < 1e9
            def body(s):
                return s @ s
            return jax.lax.while_loop(cond, body, x0)

        c = cost_of(f, x, while_trips=100.0)
        assert c.flops >= 100 * 2 * 32 ** 3
        assert c.guessed_whiles >= 1

    def test_grad_counts_backward(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def loss(x):
            return jnp.sum((x @ x) ** 2)

        fwd = cost_of(loss, a).flops
        both = cost_of(jax.grad(loss), a).flops
        assert both > 2.5 * fwd  # fwd + ~2 matmuls in backward

    def test_remat_recompute_counted(self):
        a = jax.ShapeDtypeStruct((64, 64), jnp.float32)

        def loss(x):
            def f(y):
                return jnp.sum(jnp.tanh(y @ y) ** 2)
            return jax.checkpoint(f)(x)

        plain = cost_of(jax.grad(lambda x: jnp.sum(jnp.tanh(x @ x) ** 2)), a)
        remat = cost_of(jax.grad(loss), a)
        assert remat.flops > plain.flops  # recompute visible

    def test_model_train_flops_vs_analytic(self):
        """Smoke config: structural FLOPs within 3x of 6*N*D (attention,
        remat, and norms account for the slack; never BELOW 6ND)."""
        from repro.configs import get_arch
        from repro.models import model as model_mod
        from repro.models.layers import shape_tree, param_count
        spec = get_arch("stablelm-1.6b")
        cfg = spec.smoke
        tmpl = model_mod.build_template(cfg)
        params = shape_tree(tmpl)
        b, t = 4, 64
        batch = {"inputs": jax.ShapeDtypeStruct((b, t), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((b, t), jnp.int32)}
        c = cost_of(jax.grad(lambda p, bt: model_mod.loss_fn(cfg, p, bt)),
                    params, batch)
        analytic = 6 * param_count(tmpl) * b * t
        assert c.flops > 0.8 * analytic
        assert c.flops < 6 * analytic
