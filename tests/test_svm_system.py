"""End-to-end LiquidSVM integration tests: the paper's learning scenarios and
the cell-decomposition error-parity claims (Tables 3/9 mechanism)."""
from __future__ import annotations

import numpy as np
import pytest

from repro.data.synthetic import banana_mc, covtype_like, regression_1d, train_test_split
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig

# shapes are sized for CPU interpret-mode CI: big enough for the error
# thresholds to be stable, no bigger (see pytest.ini slow marker for the
# paper-scale variants)


def _binary_data(n=1200, seed=0):
    x, y = covtype_like(n=n, d=6, seed=seed, label_noise=0.02, n_modes=3)
    return train_test_split(x, np.where(y == 0, -1, 1), 0.25, seed)


class TestScenarios:
    def test_binary(self):
        xtr, ytr, xte, yte = _binary_data()
        m = LiquidSVM(SVMTrainerConfig(n_folds=3, max_iters=300)).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.12

    def test_ova_multiclass(self):
        x, y = banana_mc(n=1000, n_classes=4, seed=1)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 1)
        m = LiquidSVM(SVMTrainerConfig(scenario="ova", n_folds=3,
                                       max_iters=400)).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.2  # 4 overlapping bananas, nonzero Bayes

    def test_ava_multiclass(self):
        x, y = banana_mc(n=1000, n_classes=3, seed=2)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 2)
        m = LiquidSVM(SVMTrainerConfig(scenario="ava", n_folds=3,
                                       max_iters=300)).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.15

    def test_quantile_regression(self):
        x, y = regression_1d(n=600, seed=3)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 3)
        cfg = SVMTrainerConfig(scenario="quantile", taus=(0.1, 0.5, 0.9),
                               n_folds=3, max_iters=1200)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        pred = m.predict(xte)                      # (m, 3)
        cover = (yte[:, None] <= pred).mean(0)
        assert cover[0] < cover[1] < cover[2]
        assert abs(cover[1] - 0.5) < 0.12

    def test_expectile_regression(self):
        x, y = regression_1d(n=350, seed=4)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 4)
        cfg = SVMTrainerConfig(scenario="expectile", taus=(0.25, 0.75),
                               n_folds=3, max_iters=500)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        pred = m.predict(xte)
        assert (pred[:, 0].mean() < pred[:, 1].mean())

    def test_weighted_classification(self):
        xtr, ytr, xte, yte = _binary_data(seed=5)
        cfg = SVMTrainerConfig(scenario="weighted", weights=(0.5, 1.0, 2.0),
                               n_folds=3, max_iters=300)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.15

    @pytest.mark.slow
    def test_neyman_pearson_false_alarm_control(self):
        """npsvm: pick the class weight meeting the false-alarm budget."""
        xtr, ytr, xte, yte = _binary_data(n=1400, seed=10)
        cfg = SVMTrainerConfig(scenario="npsvm", np_alpha=0.05,
                               weights=(0.25, 0.5, 1.0, 2.0),
                               n_folds=3, max_iters=300)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        pred = m.predict(xte)
        fa_test = float((pred[yte < 0] > 0).mean())
        det_test = float((pred[yte > 0] > 0).mean())
        assert m.np_fa[m.np_weight_idx] <= cfg.np_alpha + 1e-9
        assert fa_test <= cfg.np_alpha + 0.05       # generalization slack
        assert det_test > 0.5                        # still detects


class TestCellDecomposition:
    """The paper's Tables 3/9 claim: cells give big speedups with little
    error cost.  We assert the error side; the FLOP side is benchmarked."""

    _full_err_cache = {}

    @pytest.mark.slow
    @pytest.mark.parametrize("method", ["random", "voronoi", "recursive"])
    def test_cells_error_parity(self, method):
        data_key = (1600, 6)                    # keep cache keyed to the data
        xtr, ytr, xte, yte = _binary_data(*data_key)
        if data_key not in self._full_err_cache:  # one baseline, three methods
            base_cfg = SVMTrainerConfig(n_folds=3, max_iters=300)
            self._full_err_cache[data_key] = LiquidSVM(base_cfg).fit(
                xtr, ytr).error(xte, yte)
        err_full = self._full_err_cache[data_key]
        cell_cfg = SVMTrainerConfig(n_folds=3, max_iters=300,
                                    cell_method=method, cell_size=350)
        err_cell = LiquidSVM(cell_cfg).fit(xtr, ytr).error(xte, yte)
        assert err_cell <= err_full + 0.06, (method, err_full, err_cell)

    def test_overlap_cells(self):
        xtr, ytr, xte, yte = _binary_data(n=1200, seed=7)
        cfg = SVMTrainerConfig(n_folds=3, max_iters=300,
                               cell_method="overlap", cell_size=300)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.15

    def test_coarse_fine(self):
        xtr, ytr, xte, yte = _binary_data(n=1400, seed=8)
        cfg = SVMTrainerConfig(n_folds=3, max_iters=300,
                               cell_method="coarse_fine", cell_size=250)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.15


class TestConfigKnobs:
    def test_grid_choice_1(self):
        xtr, ytr, xte, yte = _binary_data(n=800, seed=9)
        cfg = SVMTrainerConfig(n_folds=3, max_iters=200, grid_choice=1)
        m = LiquidSVM(cfg).fit(xtr, ytr)
        assert m.error(xte, yte) < 0.2

    def test_adaptivity_control_shrinks_grid(self):
        from repro.core.grids import adaptive_subgrid, liquid_grid
        g = liquid_grid(n=500, dim=4, grid_choice=0)
        a1 = adaptive_subgrid(g, 1)
        assert len(a1.gammas) * len(a1.lambdas) < len(g.gammas) * len(g.lambdas)
