"""ChunkSource contract conformance: ONE test class, every implementation.

``repro.pipeline.dataset`` promises a single contract ("x lives
anywhere"): ``iter_chunks(chunk_size)`` yields ``(start, chunk)`` pairs in
dataset order, covering every row exactly once, with chunks never longer
than ``chunk_size`` (but possibly shorter at shard boundaries); and
``gather(ids)`` returns the rows of ``ids`` IN THE GIVEN ORDER.  The
streaming builders' bitwise-parity guarantees all lean on these
invariants, but until now each source was exercised ad hoc in
``test_pipeline.py`` — here the same parametrized class runs against
every implementation, so a new source (or a regression in an old one)
is held to the full contract automatically.

The shard layout for ``ShardedNpzSource`` is deliberately uneven (a
1-row shard in the middle) so short-chunk emission at shard boundaries
is exercised, and ``ScaledSource`` wraps the sharded source so the view
composes with the trickiest base.

``EmbeddingSource`` joins the same class twice — cold (computing through
the frozen backbone, ragged 7-row tail block) and warm (replaying a
complete ``EmbedCache``) — because the embedding vertical's bitwise
cell-plan parity rests on exactly these invariants; the cold and warm
paths must additionally agree bit-for-bit with each other AND with the
block-aligned extractor reference.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.embed import EmbeddingExtractor, EmbeddingSource, resolve_arch
from repro.pipeline.dataset import (ArraySource, ChunkSource, DataSourceError,
                                    MemmapSource, ScaledSource,
                                    ShardedNpzSource, as_source)

N, D = 103, 5                      # deliberately not a chunk multiple
SHARD_SIZES = (40, 1, 37, 25)      # uneven; includes a 1-row shard
CHUNK_SIZES = (1, 7, 16, 64, 200)  # below/above shard sizes and n
SEQ = 10                           # token length for the embed sources
EMBED_BATCH = 16                   # N % 16 == 7: ragged tail block


@pytest.fixture(scope="module")
def x() -> np.ndarray:
    return np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)


@pytest.fixture(scope="module")
def scale() -> tuple:
    rng = np.random.default_rng(1)
    return (rng.normal(size=D).astype(np.float32),
            rng.uniform(0.5, 2.0, size=D).astype(np.float32))


@pytest.fixture(scope="module")
def embed_setup(tmp_path_factory):
    """One frozen extractor + token corpus + the block-aligned reference
    matrix + a sealed cache directory, shared by both embed params."""
    cfg = resolve_arch("stablelm-1.6b:smoke")
    tokens = np.random.default_rng(3).integers(
        0, cfg.vocab, size=(N, SEQ)).astype(np.int32)
    ex = EmbeddingExtractor(cfg, batch_size=EMBED_BATCH, seed=0)
    # reference = the extractor over each ABSOLUTE block; any chunking of
    # the source must reproduce these exact bytes
    ref = np.concatenate([ex(tokens[lo:lo + EMBED_BATCH])
                          for lo in range(0, N, EMBED_BATCH)])
    cache = str(tmp_path_factory.mktemp("embed_cache"))
    sealed = EmbeddingSource(tokens, ex, cache=cache)
    sealed.materialize()                    # write-through pass seals it
    assert sealed.cache_complete()
    return tokens, ex, ref, cache


@pytest.fixture(
    scope="module",
    params=["array", "memmap", "sharded_npz", "scaled",
            "embed_cold", "embed_warm"],
)
def source(request, x, scale, embed_setup, tmp_path_factory) -> ChunkSource:
    kind = request.param
    if kind == "embed_cold":
        tokens, ex, _, _ = embed_setup
        return EmbeddingSource(tokens, ex)       # no cache: compute path
    if kind == "embed_warm":
        tokens, ex, _, cache = embed_setup
        src = EmbeddingSource(tokens, ex, cache=cache)
        assert src.cache_complete()              # npz replay path
        return src
    if kind == "array":
        return ArraySource(x)
    if kind == "memmap":
        path = tmp_path_factory.mktemp("mm") / "x.npy"
        np.save(path, x)
        return MemmapSource(path)
    # sharded: uneven shard sizes, 1-row shard included
    d = tmp_path_factory.mktemp("npz")
    paths, lo = [], 0
    for i, s in enumerate(SHARD_SIZES):
        p = d / f"shard{i}.npz"
        np.savez(p, x=x[lo:lo + s])
        paths.append(str(p))
        lo += s
    assert lo == N
    sharded = ShardedNpzSource(paths)
    if kind == "sharded_npz":
        return sharded
    mean, std = scale
    return ScaledSource(sharded, mean, std)


@pytest.fixture(scope="module")
def expected(request, source, x, scale, embed_setup) -> np.ndarray:
    """What the source must present: raw rows, the scaled view, or the
    block-aligned embedding reference."""
    if isinstance(source, EmbeddingSource):
        return embed_setup[2]
    if isinstance(source, ScaledSource):
        mean, std = scale
        return ((x - mean) / std).astype(np.float32)
    return x


class TestChunkSourceContract:
    def test_shape_properties(self, source, expected):
        n, d = expected.shape
        assert source.n_rows == n
        assert source.dim == d
        assert source.shape == (n, d)

    @pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
    def test_iter_chunks_covers_every_row_exactly_once_in_order(
            self, source, expected, chunk_size):
        n, d = expected.shape
        seen = np.zeros(n, np.int64)
        pos = 0
        for lo, chunk in source.iter_chunks(chunk_size):
            assert lo == pos                      # contiguous, dataset order
            assert chunk.ndim == 2 and chunk.shape[1] == d
            assert chunk.dtype == np.float32
            assert 1 <= chunk.shape[0] <= chunk_size
            np.testing.assert_array_equal(chunk, expected[lo:lo + chunk.shape[0]])
            seen[lo:lo + chunk.shape[0]] += 1
            pos = lo + chunk.shape[0]
        assert pos == n
        assert (seen == 1).all()                  # exactly once

    def test_chunk_size_invariance(self, source):
        """Concatenating the chunks gives the same matrix for EVERY chunk
        size — the invariant all streaming bitwise-parity claims rest on."""
        ref = np.concatenate(
            [c for _, c in source.iter_chunks(CHUNK_SIZES[0])])
        for cs in CHUNK_SIZES[1:]:
            got = np.concatenate([c for _, c in source.iter_chunks(cs)])
            np.testing.assert_array_equal(got, ref)

    def test_gather_preserves_given_order(self, source, expected):
        n = expected.shape[0]
        rng = np.random.default_rng(2)
        ids = rng.permutation(n)[: n // 2]        # unsorted, shard-crossing
        got = source.gather(ids)
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, expected[ids])

    def test_gather_repeated_and_single_ids(self, source, expected):
        n = expected.shape[0]
        ids = np.asarray([5, 5, 0, n - 1, 5], np.int64)   # dups, both ends
        np.testing.assert_array_equal(source.gather(ids), expected[ids])
        np.testing.assert_array_equal(source.gather(np.asarray([3])),
                                      expected[[3]])

    def test_gather_matches_iter_chunks(self, source, expected):
        """The two access paths must present identical bytes."""
        n = expected.shape[0]
        via_iter = np.concatenate([c for _, c in source.iter_chunks(16)])
        via_gather = source.gather(np.arange(n, dtype=np.int64))
        np.testing.assert_array_equal(via_gather, via_iter)

    def test_materialize_is_full_in_order_gather(self, source, expected):
        np.testing.assert_array_equal(source.materialize(), expected)


def test_as_source_is_identity_on_sources(x):
    src = ArraySource(x)
    assert as_source(src) is src


def test_embed_cold_equals_warm_bitwise(embed_setup):
    """Cache-hit replay must reproduce the cold compute path bit-for-bit
    (and both must equal the block-aligned extractor reference) — the
    acceptance bar for the embedding cache."""
    tokens, ex, ref, cache = embed_setup
    cold = EmbeddingSource(tokens, ex).materialize()
    warm_src = EmbeddingSource(tokens, ex, cache=cache)
    assert warm_src.cache_complete()
    np.testing.assert_array_equal(cold, warm_src.materialize())
    np.testing.assert_array_equal(cold, ref)


class TestDataSourceErrors:
    """Broken bytes on disk surface as DataSourceError naming the file and
    row range — never a raw numpy/zipfile traceback mid-stream."""

    def test_truncated_npy_raises_naming_file(self, tmp_path, x):
        p = tmp_path / "trunc.npy"
        np.save(p, x)
        raw = p.read_bytes()
        p.write_bytes(raw[: len(raw) // 2])       # body shorter than header
        with pytest.raises(DataSourceError, match="trunc.npy"):
            MemmapSource(p)

    def test_missing_npy_raises(self, tmp_path):
        with pytest.raises(DataSourceError, match="nope.npy"):
            MemmapSource(tmp_path / "nope.npy")

    def test_npz_missing_member_raises_naming_key(self, tmp_path, x):
        p = tmp_path / "wrongkey.npz"
        np.savez(p, y=x[:10])                     # member 'x' absent
        with pytest.raises(DataSourceError, match="no member 'x'"):
            ShardedNpzSource([p])

    def test_truncated_npz_header_raises(self, tmp_path, x):
        p = tmp_path / "torn.npz"
        np.savez(p, x=x[:10])
        raw = p.read_bytes()
        p.write_bytes(raw[: 20])                  # kill the zip directory
        with pytest.raises(DataSourceError, match="torn.npz"):
            ShardedNpzSource([p])

    def test_corrupt_npz_payload_names_shard_and_rows(self, tmp_path):
        """Header parses (construction succeeds) but the payload bytes are
        flipped: the CRC failure on gather must name shard + row range.
        Shards must exceed zipfile's 4 KiB read buffer so the header peek
        doesn't already trip the CRC check."""
        rng = np.random.default_rng(7)
        big = rng.normal(size=(4000, D)).astype(np.float32)
        paths = []
        for i, (lo, hi) in enumerate([(0, 2000), (2000, 4000)]):
            p = tmp_path / f"shard{i}.npz"
            np.savez(p, x=big[lo:hi])
            paths.append(p)
        raw = bytearray(paths[1].read_bytes())
        raw[len(raw) - 200] ^= 0xFF               # deep in member payload
        paths[1].write_bytes(bytes(raw))
        src = ShardedNpzSource(paths)             # headers still fine
        with pytest.raises(DataSourceError,
                           match=r"shard1\.npz.*rows \[2000, 4000\)"):
            src.gather(np.arange(2000, 4000, dtype=np.int64))
