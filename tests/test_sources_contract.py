"""ChunkSource contract conformance: ONE test class, every implementation.

``repro.pipeline.dataset`` promises a single contract ("x lives
anywhere"): ``iter_chunks(chunk_size)`` yields ``(start, chunk)`` pairs in
dataset order, covering every row exactly once, with chunks never longer
than ``chunk_size`` (but possibly shorter at shard boundaries); and
``gather(ids)`` returns the rows of ``ids`` IN THE GIVEN ORDER.  The
streaming builders' bitwise-parity guarantees all lean on these
invariants, but until now each source was exercised ad hoc in
``test_pipeline.py`` — here the same parametrized class runs against
every implementation, so a new source (or a regression in an old one)
is held to the full contract automatically.

The shard layout for ``ShardedNpzSource`` is deliberately uneven (a
1-row shard in the middle) so short-chunk emission at shard boundaries
is exercised, and ``ScaledSource`` wraps the sharded source so the view
composes with the trickiest base.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.pipeline.dataset import (ArraySource, ChunkSource, DataSourceError,
                                    MemmapSource, ScaledSource,
                                    ShardedNpzSource, as_source)

N, D = 103, 5                      # deliberately not a chunk multiple
SHARD_SIZES = (40, 1, 37, 25)      # uneven; includes a 1-row shard
CHUNK_SIZES = (1, 7, 16, 64, 200)  # below/above shard sizes and n


@pytest.fixture(scope="module")
def x() -> np.ndarray:
    return np.random.default_rng(0).normal(size=(N, D)).astype(np.float32)


@pytest.fixture(scope="module")
def scale() -> tuple:
    rng = np.random.default_rng(1)
    return (rng.normal(size=D).astype(np.float32),
            rng.uniform(0.5, 2.0, size=D).astype(np.float32))


@pytest.fixture(
    scope="module",
    params=["array", "memmap", "sharded_npz", "scaled"],
)
def source(request, x, scale, tmp_path_factory) -> ChunkSource:
    kind = request.param
    if kind == "array":
        return ArraySource(x)
    if kind == "memmap":
        path = tmp_path_factory.mktemp("mm") / "x.npy"
        np.save(path, x)
        return MemmapSource(path)
    # sharded: uneven shard sizes, 1-row shard included
    d = tmp_path_factory.mktemp("npz")
    paths, lo = [], 0
    for i, s in enumerate(SHARD_SIZES):
        p = d / f"shard{i}.npz"
        np.savez(p, x=x[lo:lo + s])
        paths.append(str(p))
        lo += s
    assert lo == N
    sharded = ShardedNpzSource(paths)
    if kind == "sharded_npz":
        return sharded
    mean, std = scale
    return ScaledSource(sharded, mean, std)


@pytest.fixture(scope="module")
def expected(request, source, x, scale) -> np.ndarray:
    """What the source must present: raw rows, or the scaled view."""
    if isinstance(source, ScaledSource):
        mean, std = scale
        return ((x - mean) / std).astype(np.float32)
    return x


class TestChunkSourceContract:
    def test_shape_properties(self, source, expected):
        assert source.n_rows == N
        assert source.dim == D
        assert source.shape == (N, D)

    @pytest.mark.parametrize("chunk_size", CHUNK_SIZES)
    def test_iter_chunks_covers_every_row_exactly_once_in_order(
            self, source, expected, chunk_size):
        seen = np.zeros(N, np.int64)
        pos = 0
        for lo, chunk in source.iter_chunks(chunk_size):
            assert lo == pos                      # contiguous, dataset order
            assert chunk.ndim == 2 and chunk.shape[1] == D
            assert chunk.dtype == np.float32
            assert 1 <= chunk.shape[0] <= chunk_size
            np.testing.assert_array_equal(chunk, expected[lo:lo + chunk.shape[0]])
            seen[lo:lo + chunk.shape[0]] += 1
            pos = lo + chunk.shape[0]
        assert pos == N
        assert (seen == 1).all()                  # exactly once

    def test_chunk_size_invariance(self, source):
        """Concatenating the chunks gives the same matrix for EVERY chunk
        size — the invariant all streaming bitwise-parity claims rest on."""
        ref = np.concatenate(
            [c for _, c in source.iter_chunks(CHUNK_SIZES[0])])
        for cs in CHUNK_SIZES[1:]:
            got = np.concatenate([c for _, c in source.iter_chunks(cs)])
            np.testing.assert_array_equal(got, ref)

    def test_gather_preserves_given_order(self, source, expected):
        rng = np.random.default_rng(2)
        ids = rng.permutation(N)[: N // 2]        # unsorted, shard-crossing
        got = source.gather(ids)
        assert got.dtype == np.float32
        np.testing.assert_array_equal(got, expected[ids])

    def test_gather_repeated_and_single_ids(self, source, expected):
        ids = np.asarray([5, 5, 0, N - 1, 5], np.int64)   # dups, both ends
        np.testing.assert_array_equal(source.gather(ids), expected[ids])
        np.testing.assert_array_equal(source.gather(np.asarray([3])),
                                      expected[[3]])

    def test_gather_matches_iter_chunks(self, source):
        """The two access paths must present identical bytes."""
        via_iter = np.concatenate([c for _, c in source.iter_chunks(16)])
        via_gather = source.gather(np.arange(N, dtype=np.int64))
        np.testing.assert_array_equal(via_gather, via_iter)

    def test_materialize_is_full_in_order_gather(self, source, expected):
        np.testing.assert_array_equal(source.materialize(), expected)


def test_as_source_is_identity_on_sources(x):
    src = ArraySource(x)
    assert as_source(src) is src


class TestDataSourceErrors:
    """Broken bytes on disk surface as DataSourceError naming the file and
    row range — never a raw numpy/zipfile traceback mid-stream."""

    def test_truncated_npy_raises_naming_file(self, tmp_path, x):
        p = tmp_path / "trunc.npy"
        np.save(p, x)
        raw = p.read_bytes()
        p.write_bytes(raw[: len(raw) // 2])       # body shorter than header
        with pytest.raises(DataSourceError, match="trunc.npy"):
            MemmapSource(p)

    def test_missing_npy_raises(self, tmp_path):
        with pytest.raises(DataSourceError, match="nope.npy"):
            MemmapSource(tmp_path / "nope.npy")

    def test_npz_missing_member_raises_naming_key(self, tmp_path, x):
        p = tmp_path / "wrongkey.npz"
        np.savez(p, y=x[:10])                     # member 'x' absent
        with pytest.raises(DataSourceError, match="no member 'x'"):
            ShardedNpzSource([p])

    def test_truncated_npz_header_raises(self, tmp_path, x):
        p = tmp_path / "torn.npz"
        np.savez(p, x=x[:10])
        raw = p.read_bytes()
        p.write_bytes(raw[: 20])                  # kill the zip directory
        with pytest.raises(DataSourceError, match="torn.npz"):
            ShardedNpzSource([p])

    def test_corrupt_npz_payload_names_shard_and_rows(self, tmp_path):
        """Header parses (construction succeeds) but the payload bytes are
        flipped: the CRC failure on gather must name shard + row range.
        Shards must exceed zipfile's 4 KiB read buffer so the header peek
        doesn't already trip the CRC check."""
        rng = np.random.default_rng(7)
        big = rng.normal(size=(4000, D)).astype(np.float32)
        paths = []
        for i, (lo, hi) in enumerate([(0, 2000), (2000, 4000)]):
            p = tmp_path / f"shard{i}.npz"
            np.savez(p, x=big[lo:hi])
            paths.append(p)
        raw = bytearray(paths[1].read_bytes())
        raw[len(raw) - 200] ^= 0xFF               # deep in member payload
        paths[1].write_bytes(bytes(raw))
        src = ShardedNpzSource(paths)             # headers still fine
        with pytest.raises(DataSourceError,
                           match=r"shard1\.npz.*rows \[2000, 4000\)"):
            src.gather(np.arange(2000, 4000, dtype=np.int64))
