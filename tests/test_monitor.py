"""Serving health: SLO burn rate, drift windows, and the closed loop.

Everything time-dependent runs against an INJECTED clock (the engine and
the monitor share one by default), so burn rates, window rotations and
drift scores are exact assertions, not sleeps.  The closed-loop test is
the PR's acceptance criterion end to end: a real voronoi fit, injected
covariate shift on a strict subset of cells, drift crossing the
threshold, a refresh that re-solves ONLY the drifted cells (counted
solver columns, orders of magnitude below a full refit), a hot swap
under traffic with zero dropped requests, and the engine's latency
sketch agreeing with the pooled per-request breakdowns.
"""
import dataclasses

import numpy as np
import pytest

from repro.obs import MetricsRegistry, Tracer
from repro.obs.slo import SLOSpec, SLOTracker
from repro.serve.model_bank import ModelBank
from repro.serve.monitor import HealthMonitor
from repro.serve.svm_engine import SVMEngine


def _bank(seed=0, n_cells=3, k=16, d=4):
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_cells, d)).astype(np.float32) * 4.0
    sv = (centers[:, None, :]
          + rng.normal(size=(n_cells, k, d))).astype(np.float32)
    coefs = rng.normal(size=(n_cells, k, 2, 1)).astype(np.float32)
    gamma = rng.uniform(0.5, 3.0, size=(n_cells, 2, 1)).astype(np.float32)
    mask = np.ones((n_cells, k), np.float32)
    bank = ModelBank.from_cells(sv, mask, coefs, gamma, centers)
    pool = (centers[rng.integers(0, n_cells, 64)]
            + rng.normal(size=(64, d)) * 1.0).astype(np.float32)
    return bank, pool


def _fake_engine(bank, clk, **kw):
    return SVMEngine(bank, fused=False, clock=lambda: clk[0],
                     metrics=MetricsRegistry(), tracer=Tracer(), **kw)


# -------------------------------------------------------------- SLO tracker
class TestSLOTracker:
    def test_burn_rate_is_bad_fraction_over_budget(self):
        clk = [100.0]
        t = SLOTracker(SLOSpec(threshold_ms=20.0, percentile=0.99,
                               window_s=60.0), clock=lambda: clk[0])
        for _ in range(98):
            t.record(5.0)
        t.record(25.0)
        t.record(30.0)                        # 2 bad / 100 = 2% vs 1% budget
        assert t.window_counts() == (98, 2)
        assert t.bad_fraction() == pytest.approx(0.02)
        assert t.burn_rate() == pytest.approx(2.0)
        assert not t.ok()

    def test_window_evicts_old_buckets(self):
        clk = [0.0]
        t = SLOTracker(SLOSpec(threshold_ms=10.0, window_s=12.0),
                       clock=lambda: clk[0], n_buckets=12)
        t.record(99.0)                        # bad at t=0
        assert t.window_counts() == (0, 1)
        clk[0] = 6.0
        t.record(1.0)                         # good at t=6; both in window
        assert t.window_counts() == (1, 1)
        clk[0] = 13.0                         # t=0 bucket aged out
        assert t.window_counts() == (1, 0)
        assert t.burn_rate() == 0.0
        assert t.total_bad == 1               # lifetime totals never evict

    def test_breach_and_recover_are_edge_triggered(self):
        clk = [0.0]
        t = SLOTracker(SLOSpec(threshold_ms=10.0, percentile=0.9,
                               window_s=10.0), clock=lambda: clk[0])
        for _ in range(8):
            t.record(1.0)
        t.record(50.0)
        t.record(50.0)                        # 20% bad vs 10% budget
        ev = t.poll()
        assert [e["kind"] for e in ev] == ["slo_breach"]
        assert t.poll() == []                 # still breaching: no re-fire
        clk[0] = 11.0                         # window empties
        ev = t.poll()
        assert [e["kind"] for e in ev] == ["slo_recover"]
        assert t.poll() == []
        kinds = [e["kind"] for e in t.events]
        assert kinds == ["slo_breach", "slo_recover"]

    def test_percentile_zero_degenerates_to_miss_ratio(self):
        clk = [0.0]
        t = SLOTracker(SLOSpec(threshold_ms=2.0, percentile=0.0,
                               window_s=5.0), clock=lambda: clk[0])
        t.record(1.0)
        t.record(3.0)
        t.record(3.0)
        t.record(3.0)
        assert t.bad_fraction() == pytest.approx(0.75)
        assert t.burn_rate() == pytest.approx(0.75)   # budget = 1

    def test_spec_validation(self):
        with pytest.raises(ValueError):
            SLOSpec(threshold_ms=5.0, percentile=1.0)
        with pytest.raises(ValueError):
            SLOSpec(threshold_ms=5.0, window_s=0.0)


# ------------------------------------------------------------ drift windows
class TestHealthMonitor:
    def test_in_distribution_traffic_scores_near_zero(self):
        bank, pool = _bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk)
        mon = HealthMonitor(eng, drift_window_s=1.0, min_window_count=4,
                            metrics=MetricsRegistry())
        for lo in range(0, 64, 8):
            eng.submit(pool[lo:lo + 8])
            eng.step()
            clk[0] += 0.01
        scores = mon.drift_scores()
        assert scores                          # windows populated
        assert max(abs(s) for s in scores.values()) < 3.0
        assert mon.drifted_cells() == []
        h = mon.health()
        assert h["status"] == "ok" and h["drift"]["baseline"]

    def test_shifted_cell_crosses_threshold_alone(self):
        bank, pool = _bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk)
        mon = HealthMonitor(eng, drift_window_s=1.0, drift_threshold=3.0,
                            min_window_count=4, metrics=MetricsRegistry())
        xs = (pool - bank.feat_mean) / bank.feat_std
        owner = eng.route(xs)
        target = int(np.bincount(owner).argmax())
        sel_rows = xs[owner == target]
        # outward covariate shift: scale residuals from the owning center
        shifted_s = bank.centers[target] + (sel_rows
                                            - bank.centers[target]) * 5.0
        still = eng.route(shifted_s.astype(np.float32)) == target
        shifted_s = shifted_s[still]
        assert shifted_s.shape[0] >= 4
        shifted = (shifted_s * bank.feat_std
                   + bank.feat_mean).astype(np.float32)
        for lo in range(0, 64, 8):             # mixed: in-dist + shifted
            eng.submit(pool[lo:lo + 8])
            eng.submit(shifted)
            eng.step()
            clk[0] += 0.01
        drifted = mon.drifted_cells()
        assert drifted == [target]             # ONLY the shifted cell
        assert mon.health()["status"] == "degraded"

    def test_window_rotation_is_clock_deterministic(self):
        def run():
            bank, pool = _bank(1)
            clk = [0.0]
            eng = _fake_engine(bank, clk)
            mon = HealthMonitor(eng, drift_window_s=0.05,
                                min_window_count=2,
                                metrics=MetricsRegistry())
            for lo in range(0, 64, 8):
                eng.submit(pool[lo:lo + 8])
                eng.step()
                clk[0] += 0.02
            return mon.drift_scores(), mon._windows_rotated

        s1, r1 = run()
        s2, r2 = run()
        assert s1 == s2 and r1 == r2 and r1 > 0

    def test_no_baseline_disables_drift(self):
        bank, pool = _bank()
        bare = dataclasses.replace(bank, route_baseline=None)  # pre-PR bank
        clk = [0.0]
        eng = _fake_engine(bare, clk)
        mon = HealthMonitor(eng, metrics=MetricsRegistry())
        eng.submit(pool[:16])
        eng.step()
        assert mon.drift_scores() == {}
        h = mon.health()
        assert h["drift"]["baseline"] is False and h["status"] == "ok"

    def test_reset_cells_clears_windows(self):
        bank, pool = _bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk)
        mon = HealthMonitor(eng, min_window_count=1,
                            metrics=MetricsRegistry())
        eng.submit(pool[:32])
        eng.step()
        cells = list(mon.drift_scores())
        assert cells
        mon.reset_cells(cells)
        assert mon.drift_scores() == {}

    def test_slo_and_deadline_threaded_through_health(self):
        bank, pool = _bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk, deadline_ms=5.0)
        mon = HealthMonitor(eng, slo_p99_ms=1e-6,
                            metrics=MetricsRegistry())
        eng.submit(pool[:16])
        clk[0] += 0.01                         # 10ms in queue: misses both
        eng.step()
        h = mon.health()
        assert h["slo"]["breached"] and h["status"] == "breaching"
        assert h["deadline_miss_ratio"] == pytest.approx(1.0)
        assert mon._metrics.counter("serve.slo_breaches").value >= 1

    def test_constructor_validation(self):
        bank, _ = _bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk)
        with pytest.raises(ValueError):
            HealthMonitor(eng, slo_p99_ms=5.0,
                          slo=SLOSpec(threshold_ms=5.0),
                          metrics=MetricsRegistry())
        with pytest.raises(ValueError):
            HealthMonitor(eng, drift_window_s=0.0,
                          metrics=MetricsRegistry())


# ------------------------------------------------------------- config keys
class TestMonitorKeys:
    def test_apply_keys_rejects_monitor_keys(self):
        from repro.api.config import ConfigError, apply_keys
        from repro.train.svm_trainer import SVMTrainerConfig
        for key in ("SLO_P99_MS", "DRIFT_WINDOW", "DRIFT_REFRESH_THRESHOLD"):
            with pytest.raises(ConfigError, match="health-monitor key"):
                apply_keys(SVMTrainerConfig(), {key: 5.0})

    def test_split_monitor_keys_maps_and_coerces(self):
        from repro.api.config import ConfigError, split_monitor_keys
        rest, mon = split_monitor_keys(
            {"SLO_P99_MS": "20", "DRIFT_WINDOW": "2.5",
             "DRIFT_REFRESH_THRESHOLD": "4", "FOLDS": "3"})
        assert mon == {"slo_p99_ms": 20.0, "drift_window_s": 2.5,
                       "drift_threshold": 4.0}
        assert rest == {"FOLDS": "3"}
        with pytest.raises(ConfigError):
            split_monitor_keys({"SLO_P99_MS": "-1"})


# ------------------------------------------- breakdown eviction (regression)
class TestBreakdownEviction:
    def test_evicted_vs_never_seen_are_distinguishable(self, monkeypatch):
        from repro.serve import svm_engine as se
        monkeypatch.setattr(se, "_SERVED_VERSION_CAP", 4)
        bank, pool = _bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk)
        served = []
        for lo in range(0, 12, 2):
            eng.submit(pool[lo:lo + 2])
            served.extend(eng.step())
            clk[0] += 0.001
        assert len(served) == 12
        # lookups never move the counter; only ring eviction does
        assert eng.breakdown(10 ** 9) is None            # never seen
        assert eng.stats()["breakdown_evicted"] == 8      # 12 served, cap 4
        assert eng.breakdown(min(served)) is None         # evicted (aged out)
        assert eng.breakdown(max(served))["total_ms"] >= 0.0
        # an engine that never wrapped keeps the counter at 0
        eng2 = _fake_engine(bank, clk)
        eng2.submit(pool[:2])
        eng2.step()
        assert eng2.breakdown(10 ** 9) is None
        assert eng2.stats()["breakdown_evicted"] == 0


# ------------------------------------------------------------- closed loop
@pytest.mark.timeout(600)
class TestClosedLoop:
    @pytest.fixture(scope="class")
    def fit(self):
        from repro.api import SVM
        from repro.data.synthetic import covtype_like
        from repro.train.svm_trainer import SVMTrainerConfig
        x, y = covtype_like(n=600, d=4, seed=3, label_noise=0.02, n_modes=3)
        y = np.where(y == 0, -1.0, 1.0)
        cfg = SVMTrainerConfig(n_folds=2, max_iters=150,
                               cell_method="voronoi", cell_size=120)
        sess = SVM(x, y, config=cfg)
        sess.train()
        sel = sess.select("argmin")
        return sess.train_result, sel, x, y

    def _shifted_traffic(self, bank, eng, x, factor=6.0):
        """Covariate shift on ONE cell: scale residuals outward from its
        center so the shifted queries still route there."""
        xs = (np.asarray(x, np.float32) - bank.feat_mean) / bank.feat_std
        owner = eng.route(xs)
        target = int(np.bincount(owner, minlength=bank.n_cells).argmax())
        rows = xs[owner == target]
        shifted_s = bank.centers[target] + (rows
                                            - bank.centers[target]) * factor
        keep = eng.route(shifted_s.astype(np.float32)) == target
        shifted_s = shifted_s[keep]
        shifted = (shifted_s * bank.feat_std
                   + bank.feat_mean).astype(np.float32)
        return target, shifted, rows[keep]

    def test_drift_refresh_swap_end_to_end(self, fit):
        from repro.serve.refresh import refresh_drifted
        tr, sel, x, y = fit
        bank0 = sel.to_bank()
        assert bank0.route_baseline is not None   # recorded at to_bank time
        assert bank0.stats()["drift_baseline"]

        clk = [0.0]
        eng = _fake_engine(bank0, clk)
        mon = HealthMonitor(eng, drift_window_s=1.0, drift_threshold=3.0,
                            min_window_count=4, metrics=MetricsRegistry())

        # phase 1: in-distribution traffic — no cell drifts
        for lo in range(0, 200, 20):
            eng.submit(x[lo:lo + 20].astype(np.float32))
            eng.step()
            clk[0] += 0.01
        assert mon.drifted_cells() == []

        # phase 2: inject covariate shift on one cell
        target, shifted, _rows = self._shifted_traffic(bank0, eng, x)
        assert shifted.shape[0] >= 4
        for _ in range(4):
            eng.submit(shifted)
            eng.step()
            clk[0] += 0.01
        drifted = mon.drifted_cells()
        assert target in drifted
        assert set(drifted) < set(range(bank0.n_cells))   # strict subset

        # phase 3: targeted refresh — ONLY the drifted cells re-solve
        rng = np.random.default_rng(0)
        y_feed = rng.choice([-1.0, 1.0], size=shifted.shape[0])
        bank1, info = refresh_drifted(tr, sel, shifted, y_feed, drifted,
                                      base_version=eng.bank.version)
        assert bank1 is not None and bank1.version == bank0.version + 1
        n_cols = sel.gamma.shape[1] * sel.gamma.shape[2]
        assert info["drifted_slots"] <= len(drifted)
        assert info["columns_resolved"] <= len(drifted) * n_cols
        assert info["feedback_used"] == shifted.shape[0]
        # a full refit would sweep the whole grid on every slot
        full_columns = (tr.packed.n_slots * n_cols
                        * tr.gammas_cells.shape[1] * tr.lambdas.shape[0])
        assert info["columns_resolved"] * 20 < full_columns

        # untouched cells decide identically across the refresh
        xq = x[300:340].astype(np.float32)
        xs = (xq - bank0.feat_mean) / bank0.feat_std
        keep = ~np.isin(eng.route(xs), drifted)
        if keep.any():
            e0 = SVMEngine(bank0, fused=False,
                           metrics=MetricsRegistry(), tracer=Tracer())
            e1 = SVMEngine(bank1, fused=False,
                           metrics=MetricsRegistry(), tracer=Tracer())
            np.testing.assert_allclose(e0.predict(xq[keep]),
                                       e1.predict(xq[keep]),
                                       rtol=1e-5, atol=1e-5)

        # phase 4: hot-swap mid-traffic — conservation, zero drops
        submitted = eng.counters["submitted"]
        served = eng.counters["served"]
        eng.submit(x[400:420].astype(np.float32))
        eng.begin_step()
        eng.swap_bank(bank1)                   # wave in flight on bank0
        eng.submit(x[420:440].astype(np.float32))
        eng.finish_step()
        eng.step()
        clk[0] += 0.01
        assert eng.bank.version == bank1.version
        assert eng.counters["submitted"] - submitted == 40
        assert eng.counters["served"] - served == 40      # nothing dropped
        assert eng.counters["shed_rows"] == 0

        # monitor follows the swap: baseline cache refreshes to bank1
        mon.reset_cells(drifted)
        assert mon._baseline_arrays() is not None
        assert mon._baseline_version == bank1.version

    def test_latency_sketch_matches_pooled_breakdowns(self, fit):
        _tr, sel, x, _y = fit
        bank = sel.to_bank()
        clk = [0.0]
        eng = _fake_engine(bank, clk)
        rng = np.random.default_rng(5)
        rids = []
        for lo in range(0, 400, 16):
            eng.submit(x[lo:lo + 16].astype(np.float32))
            clk[0] += float(rng.uniform(0.0, 0.01))
            rids.extend(eng.step())
            clk[0] += float(rng.uniform(0.0, 0.005))
        pooled = np.asarray([eng.breakdown(r)["total_ms"] for r in rids])
        q = eng.stats()["request_ms_q"]
        assert q["count"] == pooled.size
        sk = eng._m_request_q
        assert sk.exact                         # below cap: exactness
        for name, qq in (("p50", 0.5), ("p90", 0.9), ("p99", 0.99)):
            assert q[name] == np.quantile(pooled, qq, method="lower")
