"""Fault-injection acceptance suite: the robustness gate for PR 6.

Proves, with deterministic kills at named sites (``repro.testing.faults``):

  (a) **preemption survival** — kill ``train_cells_waves`` at ANY wave or
      checkpoint-write boundary, resume from the same ``ckpt_dir``, and the
      final model is BITWISE identical to the uninterrupted run;
  (b) **torn/corrupt detection** — a step dir left by a mid-write kill, a
      truncated manifest, or a bit-flipped payload is detected (checksums)
      and restore falls back to the newest step that verifies, instead of
      loading garbage;
  (c) **hot-swap correctness** — the randomized conservation property lives
      in ``test_serve_async.py::TestSwapConservation``; here the engine's
      fault sites are shown to leave no partial state behind;
  (d) **bounded overload** — a full admission queue sheds with a retry-able
      :class:`OverloadError`, memory stays bounded, the shed is visible in
      ``stats()``, and a post-drain retry succeeds.

Every test carries a ``timeout`` marker so an injected deadlock fails the
gate fast instead of hanging it (pytest-timeout when installed, else the
SIGALRM fallback in ``conftest.py``).
"""
from __future__ import annotations

import os

import numpy as np
import pytest

from repro.serve.model_bank import ModelBank
from repro.serve.svm_engine import OverloadError, SVMEngine
from repro.testing import faults
from repro.train import checkpoint as ckpt

pytestmark = pytest.mark.timeout(120)


@pytest.fixture(autouse=True)
def _clean_faults():
    faults.reset()
    yield
    faults.reset()


# --------------------------------------------------------------- helpers
def _tree(seed: int) -> dict:
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(7, 3)).astype(np.float32),
            "b": rng.normal(size=(3,)).astype(np.float32),
            "step": np.int32(seed)}


def _save(d: str, step: int, **kw) -> str:
    return ckpt.save_checkpoint(d, step, _tree(step),
                                extra={"s": step}, **kw)


def _assert_restores(d: str, step: int, expect_seed: int) -> None:
    tree, extra = ckpt.restore_self_describing(d, step=step)
    want = _tree(expect_seed)
    assert extra == {"s": expect_seed}
    for k in want:
        np.testing.assert_array_equal(tree[k], want[k])


def _corrupt_leaf(step_dir: str, leaf: str = "leaf_0") -> None:
    """Flip one payload byte but keep the npz a VALID zip — exercises the
    manifest checksum, not zipfile's CRC."""
    shard = os.path.join(step_dir, "shard_0.npz")
    with np.load(shard) as z:
        arrays = {k: z[k].copy() for k in z.files}
    arrays[leaf][0] ^= 0xFF
    np.savez(shard, **arrays)


def _bank(seed: int, n_cells: int = 3, version: int = 0):
    """Tiny overlap bank + clustered query pool (mirrors test_serve_async)."""
    k, d = 16, 4
    rng = np.random.default_rng(seed)
    centers = rng.normal(size=(n_cells, d)).astype(np.float32) * 4.0
    sv = (centers[:, None, :]
          + rng.normal(size=(n_cells, k, d))).astype(np.float32)
    coefs = rng.normal(size=(n_cells, k, 2, 1)).astype(np.float32)
    gamma = rng.uniform(0.5, 3.0, size=(n_cells, 2, 1)).astype(np.float32)
    mask = np.ones((n_cells, k), np.float32)
    bank = ModelBank.from_cells(sv, mask, coefs, gamma, centers,
                                routing="overlap", version=version)
    pool = (centers[rng.integers(0, n_cells, 64)]
            + rng.normal(size=(64, d)) * 1.5).astype(np.float32)
    return bank, pool


def _drain(eng: SVMEngine) -> dict:
    out: dict = {}
    while eng.pending or eng.in_flight:
        out.update(eng.step())
    return out


# ---------------------------------------------------------------- harness
class TestFaultHarness:
    def test_fire_is_noop_when_nothing_armed(self):
        faults.fire("nonexistent.site", whatever=1)   # must not raise
        assert faults.hits("nonexistent.site") == 0   # not even counted

    def test_arm_fires_on_nth_hit_then_disarms(self):
        faults.arm("t.site", at_hit=3)
        faults.fire("t.site")
        faults.fire("t.site")
        with pytest.raises(faults.InjectedFault) as ei:
            faults.fire("t.site")
        assert ei.value.site == "t.site" and ei.value.hit == 3
        faults.fire("t.site")                          # disarmed: no raise
        # the 4th fire is the zero-overhead fast path — not even counted
        assert faults.hits("t.site") == 3

    def test_injected_fault_escapes_except_exception(self):
        faults.arm("t.kill")
        with pytest.raises(faults.InjectedFault):
            try:
                faults.fire("t.kill")
            except Exception:                          # the swallow trap
                pytest.fail("InjectedFault must not be caught as Exception")

    def test_action_receives_site_context(self):
        got = []
        faults.arm("t.act", action=lambda **ctx: got.append(ctx))
        faults.fire("t.act", wave=5)                   # action, no raise
        assert got == [{"wave": 5}]

    def test_context_manager_resets_on_exit(self):
        with pytest.raises(faults.InjectedFault):
            with faults.armed("t.cm"):
                assert faults.active()
                faults.fire("t.cm")
        assert not faults.active() and faults.hits("t.cm") == 0


# ---------------------------------------------- crash-safe checkpoints (a,b)
class TestCrashSafeCheckpoint:
    @pytest.mark.parametrize("site", ["checkpoint.save.pre_shard",
                                      "checkpoint.save.post_shard",
                                      "checkpoint.save.pre_rename"])
    def test_kill_before_visibility_keeps_last_good_step(self, tmp_path, site):
        d = os.fspath(tmp_path)
        _save(d, 0)
        with pytest.raises(faults.InjectedFault):
            with faults.armed(site):
                _save(d, 1)
        # the torn write never became visible; step 0 is intact
        assert ckpt.list_steps(d) == [0]
        assert ckpt.latest_step(d) == 0
        _assert_restores(d, 0, 0)
        # debris matches a hard kill (no tidy unwind) …
        assert any(n.startswith(".tmp_step_1") for n in os.listdir(d))
        # … and the next writer sweeps it and completes normally
        _save(d, 1)
        assert not any(n.startswith(".tmp_step_") for n in os.listdir(d))
        assert ckpt.latest_step(d) == 1
        _assert_restores(d, 1, 1)

    def test_kill_after_rename_step_is_durable(self, tmp_path):
        """post_rename kill: the step dir is visible (durable) but the
        ``latest`` pointer is stale — restore still finds the new step."""
        d = os.fspath(tmp_path)
        _save(d, 0)
        with pytest.raises(faults.InjectedFault):
            with faults.armed("checkpoint.save.post_rename"):
                _save(d, 1)
        assert ckpt.list_steps(d) == [0, 1]
        tree, extra = ckpt.restore_self_describing(d)   # newest complete
        assert extra == {"s": 1}

    def test_kill_after_pointer_is_fully_committed(self, tmp_path):
        d = os.fspath(tmp_path)
        _save(d, 0)
        with pytest.raises(faults.InjectedFault):
            with faults.armed("checkpoint.save.post_latest"):
                _save(d, 1)
        assert ckpt.latest_step(d) == 1
        _assert_restores(d, 1, 1)

    def test_torn_manifest_detected(self, tmp_path):
        d = os.fspath(tmp_path)
        _save(d, 0)
        _save(d, 1)
        man = os.path.join(d, "step_00000001", "manifest.json")
        raw = open(man, "rb").read()
        with open(man, "wb") as f:
            f.write(raw[: len(raw) // 2])               # torn JSON
        assert ckpt.list_steps(d) == [0]
        assert ckpt.latest_step(d) == 0                 # pointer overridden
        _assert_restores(d, 0, 0)

    def test_payload_bitflip_falls_back_to_last_good(self, tmp_path):
        d = os.fspath(tmp_path)
        _save(d, 0)
        _save(d, 1)
        _corrupt_leaf(os.path.join(d, "step_00000001"))
        # quick checks still pass — only the deep paths read the payload
        assert ckpt.latest_step(d) == 1
        assert ckpt.verify_step(d, 1) is False
        assert ckpt.verify_step(d, 0) is True
        tree, extra = ckpt.restore_self_describing(d)   # implicit fallback
        assert extra == {"s": 0}
        assert (os.path.abspath(d), 1) in [
            (os.path.abspath(p), s) for p, s in ckpt.fallback_log()]
        # an EXPLICIT step must fail fast, never silently substitute
        with pytest.raises(ckpt.CheckpointCorruptError):
            ckpt.restore_self_describing(d, step=1)

    def test_truncated_shard_falls_back(self, tmp_path):
        d = os.fspath(tmp_path)
        _save(d, 0)
        _save(d, 1)
        shard = os.path.join(d, "step_00000001", "shard_0.npz")
        raw = open(shard, "rb").read()
        with open(shard, "wb") as f:
            f.write(raw[: len(raw) // 2])
        tree, extra = ckpt.restore_self_describing(d)
        assert extra == {"s": 0}

    def test_legacy_v1_manifest_without_checksums_restores(self, tmp_path):
        import json
        d = os.fspath(tmp_path)
        _save(d, 0)
        man = os.path.join(d, "step_00000000", "manifest.json")
        with open(man) as f:
            m = json.load(f)
        del m["checksums"]
        m["manifest_version"] = 1
        with open(man, "w") as f:
            json.dump(m, f)
        _assert_restores(d, 0, 0)                       # size check only
        assert ckpt.verify_step(d, 0) is True

    def test_torn_latest_pointer_falls_back_to_listing(self, tmp_path):
        d = os.fspath(tmp_path)
        _save(d, 0)
        _save(d, 1)
        with open(os.path.join(d, "latest"), "w") as f:
            f.write("step_garb")                        # torn/garbled pointer
        assert ckpt.latest_step(d) == 1

    def test_structure_mismatch_raises_not_falls_back(self, tmp_path):
        d = os.fspath(tmp_path)
        _save(d, 0)
        bad_target = {"completely": np.zeros((), np.float32),
                      "different": np.zeros((), np.float32),
                      "keys": np.zeros((), np.float32)}
        with pytest.raises(ValueError, match="structure mismatch"):
            ckpt.restore_checkpoint(d, bad_target)


# -------------------------------------------------------- GC guards (sat. 2)
class TestGCGuards:
    def test_gc_never_deletes_the_only_complete_step(self, tmp_path):
        """keep_last newer — but torn — dirs must not evict the one good
        step (regression guard for the `_gc` sparing rule)."""
        d = os.fspath(tmp_path)
        _save(d, 0)
        for s in (1, 2):                                # torn: manifest only
            os.makedirs(os.path.join(d, f"step_{s:08d}"))
        ckpt._gc(d, keep_last=2)                        # victims include 0
        assert ckpt.list_steps(d) == [0]
        assert ckpt.verify_step(d, 0) is True
        _assert_restores(d, 0, 0)

    def test_gc_skips_step_being_restored(self, tmp_path):
        """A save with aggressive keep_last landing in the MIDDLE of a
        restore (via the restore.mid fault action) must not delete the
        step dir under the reader's feet."""
        d = os.fspath(tmp_path)
        for s in range(4):
            _save(d, s, keep_last=0)                    # keep all
        faults.arm("checkpoint.restore.mid",
                   action=lambda **ctx: _save(d, 4, keep_last=1))
        tree, extra = ckpt.restore_self_describing(d, step=0)
        assert extra == {"s": 0}                        # restore unharmed
        # the concurrent GC ran: newest survives, restoring step spared
        assert os.path.isdir(os.path.join(d, "step_00000000"))
        assert ckpt.list_steps(d) == [0, 4]

    def test_keep_last_prunes_old_complete_steps(self, tmp_path):
        d = os.fspath(tmp_path)
        for s in range(5):
            _save(d, s, keep_last=2)
        assert ckpt.list_steps(d) == [3, 4]


# ------------------------------------------------- wave preemption (crit. a)
class TestWaveResume:
    def _fit(self, wave, ckpt_dir=None, seed=0):
        from repro.data.synthetic import covtype_like, train_test_split
        from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig
        x, y = covtype_like(n=600, d=4, seed=seed, label_noise=0.02,
                            n_modes=3)
        xtr, ytr, xte, yte = train_test_split(x, np.where(y == 0, -1, 1),
                                              0.25, seed)
        cfg = SVMTrainerConfig(n_folds=2, max_iters=150,
                               cell_method="voronoi", cell_size=120,
                               n_slots_per_wave=wave)
        m = LiquidSVM(cfg).fit(xtr, ytr, ckpt_dir=ckpt_dir)
        return m, xte

    @pytest.mark.parametrize("site,at_hit", [
        ("trainer.wave.start", 1),          # killed before ANY progress
        ("trainer.wave.start", 2),          # wave 0 done+saved, wave 1 not
        ("trainer.wave.solved", 1),         # solved but NOT yet checkpointed
        ("checkpoint.save.post_shard", 1),  # mid checkpoint write
        ("checkpoint.save.pre_rename", 2),  # 2nd wave's save mid-write
    ])
    def test_kill_anywhere_resume_is_bitwise_identical(self, tmp_path,
                                                       site, at_hit):
        ref, xte = self._fit(2)                         # uninterrupted run
        ck = os.fspath(tmp_path / "waves")
        with pytest.raises(faults.InjectedFault):
            with faults.armed(site, at_hit=at_hit):
                self._fit(2, ckpt_dir=ck)
        resumed, _ = self._fit(2, ckpt_dir=ck)          # survive the kill
        np.testing.assert_array_equal(resumed.decision_function(xte),
                                      ref.decision_function(xte))

    def test_corrupt_wave_checkpoint_is_resolved(self, tmp_path):
        """Bit rot in one wave's saved shard: that wave re-solves, the rest
        restore, and the model is still bitwise identical."""
        ref, xte = self._fit(2)
        ck = os.fspath(tmp_path / "waves")
        self._fit(2, ckpt_dir=ck)                       # leaves all waves
        steps = ckpt.list_steps(ck)
        assert len(steps) >= 2                          # waves actually split
        _corrupt_leaf(os.path.join(ck, f"step_{steps[0]:08d}"))
        resumed, _ = self._fit(2, ckpt_dir=ck)
        np.testing.assert_array_equal(resumed.decision_function(xte),
                                      ref.decision_function(xte))


# ------------------------------------------- engine fault sites + swap (c)
class TestEngineFaults:
    def test_submit_fault_leaves_no_partial_state(self):
        bank, pool = _bank(11)
        eng = SVMEngine(bank, fused=False)
        eng.submit(pool[:4])
        before = (eng.counters["submitted"], eng.pending, eng._next_id)
        with pytest.raises(faults.InjectedFault):
            with faults.armed("engine.submit"):
                eng.submit(pool[4:10])
        # the killed admission burned nothing: no ids, no rows, no counters
        assert (eng.counters["submitted"], eng.pending,
                eng._next_id) == before
        assert len(_drain(eng)) == 4                    # old traffic intact

    def test_swap_fault_leaves_engine_on_old_bank(self):
        bank0, pool = _bank(12)
        bank1, _ = _bank(13, version=1)
        eng = SVMEngine(bank0, fused=False)
        eng.submit(pool[:6])
        with pytest.raises(faults.InjectedFault):
            with faults.armed("engine.swap"):
                eng.swap_bank(bank1)
        assert eng.bank.version == 0                    # swap never happened
        assert eng.counters["swaps"] == 0
        served = _drain(eng)
        assert len(served) == 6
        assert all(eng.served_version[r] == 0 for r in served)

    def test_begin_step_fault_keeps_queues_intact(self):
        bank, pool = _bank(14)
        eng = SVMEngine(bank, fused=False)
        eng.submit(pool[:5])
        with pytest.raises(faults.InjectedFault):
            with faults.armed("engine.begin_step"):
                eng.begin_step()
        assert not eng.in_flight and eng.pending > 0    # nothing dispatched
        assert len(_drain(eng)) == 5


# ------------------------------------------------- overload shedding (d)
class TestOverloadShedding:
    def test_overflow_sheds_retryable_and_bounded(self):
        bank, pool = _bank(21)
        eng = SVMEngine(bank, fused=False)
        eng.submit(pool[:4])
        eng.max_queue = eng.pending                     # queue exactly full
        with pytest.raises(OverloadError) as ei:
            eng.submit(pool[4:6])
        assert ei.value.retryable is True
        assert OverloadError.code in str(ei.value)
        assert eng.pending <= eng.max_queue             # memory bounded
        s = eng.stats()
        assert s["shed_overflow"] == 1
        assert s["shed_rows"] == 2
        # all-or-nothing: the shed batch got NO ids, queue holds batch 1 only
        assert eng.counters["submitted"] == 4
        served = _drain(eng)
        assert len(served) == 4
        eng.submit(pool[4:6])                           # retry now succeeds
        assert len(_drain(eng)) == 2

    def test_stale_backlog_sheds_until_drained(self):
        clk = [0.0]
        bank, pool = _bank(22)
        eng = SVMEngine(bank, fused=False, shed_ms=5.0, clock=lambda: clk[0])
        eng.submit(pool[:3])
        clk[0] = 0.010                                  # backlog now 10 ms old
        with pytest.raises(OverloadError, match="stale"):
            eng.submit(pool[3:5])
        assert eng.stats()["shed_stale"] == 1
        assert len(_drain(eng)) == 3                    # backlog drains
        eng.submit(pool[3:5])                           # fresh queue: admitted
        assert len(_drain(eng)) == 2

    def test_run_sheds_overload_and_serves_the_rest(self):
        bank, pool = _bank(23)
        rng = np.random.default_rng(0)
        eng = SVMEngine(bank, fused=False)
        traffic = [pool[rng.integers(0, 64, 6)] for _ in range(12)]
        results = eng.run(iter(traffic), max_queue=24)
        s = eng.stats()
        assert s["shed_overflow"] > 0       # overload was real
        assert s["served"] == s["submitted"]
        assert len(results) == s["served"]  # admitted all served
        assert s["served"] + s["shed_rows"] == 72


# ---------------------------------------------------- incremental refresh
class TestRefresh:
    @pytest.fixture(scope="class")
    def fit(self):
        from repro.api import SVM
        from repro.data.synthetic import covtype_like
        from repro.train.svm_trainer import SVMTrainerConfig
        x, y = covtype_like(n=600, d=4, seed=3, label_noise=0.02, n_modes=3)
        y = np.where(y == 0, -1.0, 1.0)
        cfg = SVMTrainerConfig(n_folds=2, max_iters=150,
                               cell_method="voronoi", cell_size=120)
        sess = SVM(x, y, config=cfg)
        tr = sess.train()
        sel = sess.select("argmin")
        return tr, sel, x

    def test_refresh_touches_only_drifted_cells_and_bumps_version(self, fit):
        from repro.serve.refresh import refresh_bank
        tr, sel, x = fit
        bank0 = sel.to_bank()
        assert bank0.version == 0

        x_new = np.repeat(x[:1], 3, axis=0)             # one cell, 3 points
        y_new = np.asarray([1.0, -1.0, 1.0])
        bank1, info = refresh_bank(tr, sel, x_new, y_new)

        assert bank1.version == 1
        assert info["drifted_slots"] == 1
        assert info["rows_added"] == 3
        assert info["resolve_calls"] >= 1
        np.testing.assert_array_equal(bank1.centers, bank0.centers)

        # queries routed to NON-drifted cells decide identically
        drifted = int(np.asarray(tr.packed.slot_of_cell)[
            tr.plan.route(tr.scaler.transform(x_new))[0]])
        eng0 = SVMEngine(bank0, fused=False)
        eng1 = SVMEngine(bank1, fused=False)
        xq = x[50:90]
        xs = (xq - bank0.feat_mean) / bank0.feat_std
        keep = eng0.route(xs) != drifted
        assert keep.any()
        np.testing.assert_allclose(eng0.predict(xq[keep]),
                                   eng1.predict(xq[keep]), atol=1e-5)

    def test_refreshed_bank_hot_swaps(self, fit):
        from repro.serve.refresh import refresh_bank
        tr, sel, x = fit
        bank0 = sel.to_bank()
        bank1, _ = refresh_bank(tr, sel, x[:2], np.asarray([1.0, -1.0]))
        eng = SVMEngine(bank0, fused=False)
        eng.submit(x[10:16])
        out = eng.swap_bank(bank1)
        assert out["version"] == 1 and out["requeued"] == 6
        served = _drain(eng)
        assert len(served) == 6
        assert all(eng.served_version[r] == 1 for r in served)
        assert eng.stats()["bank_version"] == 1
