"""repro.embed: extractor one-compile guarantee, cache identity +
crash-safety, pad-tail containment, streaming labels, and the co-located
EmbedServe accounting contract.

(The full ChunkSource contract conformance for ``EmbeddingSource`` — cold
and warm — lives in ``test_sources_contract.py``; this file covers what
the contract suite can't: jit recompile counting, fingerprint semantics,
cell-plan parity, and the serving wrapper.)
"""
from __future__ import annotations

import os

import jax
import numpy as np
import pytest

from repro.embed import (EmbeddingExtractor, EmbeddingSource, LabeledSource,
                         embed_source, params_digest, resolve_arch)
from repro.embed.source import EmbedCache, EmbedCacheError
from repro.models.layers import init_params
from repro.models.model import build_template
from repro.pipeline.cell_stream import build_cells_stream
from repro.pipeline.dataset import ArraySource, DataSourceError

ARCH = "stablelm-1.6b:smoke"
SEQ = 10
B = 16


@pytest.fixture(scope="module")
def cfg():
    return resolve_arch(ARCH)


@pytest.fixture(scope="module")
def extractor(cfg):
    return EmbeddingExtractor(cfg, batch_size=B, seed=0)


@pytest.fixture(scope="module")
def tokens(cfg):
    return np.random.default_rng(0).integers(
        0, cfg.vocab, size=(103, SEQ)).astype(np.int32)


# ------------------------------------------------------------- extractor
class TestExtractor:
    def test_one_compile_across_ragged_calls(self, cfg):
        """The fixed batch shape is the whole point: full blocks, ragged
        tails and sub-batch calls must all reuse ONE compiled program per
        entry point (forward, pool)."""
        ex = EmbeddingExtractor(cfg, batch_size=8, seed=0)
        rng = np.random.default_rng(1)
        for m in (8, 3, 17, 1, 24):              # full, short, ragged, 1-row
            out = ex(rng.integers(0, cfg.vocab, size=(m, SEQ)))
            assert out.shape == (m, cfg.d_model)
            assert out.dtype == np.float32
        assert ex.compile_count == 1
        assert ex._pool_compiles == 1

    def test_padded_rows_do_not_change_real_rows(self, extractor, tokens):
        """A ragged tail is zero-padded to the batch shape; the pad rows
        are sliced off and the REAL rows' bytes match the same rows
        embedded inside a full block."""
        full = extractor(tokens[:B])             # one full block
        short = extractor(tokens[:5])            # same rows + 11 pad rows
        np.testing.assert_array_equal(short, full[:5])

    def test_pooling_matches_unjitted_reference(self, cfg, tokens):
        from repro.models import model as model_mod
        import jax.numpy as jnp
        for pooling in ("mean", "last"):
            ex = EmbeddingExtractor(cfg, pooling=pooling, batch_size=B,
                                    seed=0)
            got = ex(tokens[:B])
            x = tokens[:B].astype(np.int32)
            pos = jnp.broadcast_to(
                jnp.arange(SEQ, dtype=jnp.int32)[None], (B, SEQ))
            h, _, _ = model_mod.backbone(ex.cfg, ex.params,
                                         jnp.asarray(x), pos)
            h32 = np.asarray(h.astype(jnp.float32))
            want = h32.mean(axis=1) if pooling == "mean" else h32[:, -1]
            np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)

    def test_empty_input(self, extractor):
        out = extractor(np.zeros((0, SEQ), np.int32))
        assert out.shape == (0, extractor.dim)

    def test_fingerprint_sensitivity(self, cfg, extractor):
        fp = extractor.fingerprint(SEQ)
        assert extractor.fingerprint(SEQ) == fp          # deterministic
        assert extractor.fingerprint(SEQ + 1) != fp      # seq_len
        other_pool = EmbeddingExtractor(cfg, pooling="last", batch_size=B,
                                        seed=0)
        assert other_pool.fingerprint(SEQ) != fp         # pooling
        other_seed = EmbeddingExtractor(cfg, batch_size=B, seed=1)
        assert other_seed.fingerprint(SEQ) != fp         # params
        # batch size is NOT identity: blocks align to corpus offsets
        other_batch = EmbeddingExtractor(cfg, batch_size=B * 2, seed=0)
        assert other_batch.fingerprint(SEQ) == fp

    def test_params_digest_order_independent(self, cfg):
        params = init_params(build_template(cfg), jax.random.PRNGKey(0))
        flipped = dict(reversed(list(params.items())))
        assert params_digest(params) == params_digest(flipped)


# ----------------------------------------------------------------- cache
class TestEmbedCache:
    def test_write_through_seals_and_replays(self, extractor, tokens,
                                             tmp_path):
        src = EmbeddingSource(tokens, extractor, cache=str(tmp_path))
        assert not src.cache_complete()
        cold = src.materialize()                 # write-through pass
        assert src.cache_complete()
        warm = EmbeddingSource(tokens, extractor, cache=str(tmp_path))
        assert warm.cache_complete()
        np.testing.assert_array_equal(warm.materialize(), cold)

    def test_no_tmp_stragglers_after_write(self, extractor, tokens,
                                           tmp_path):
        """Crash-safe writes: after a clean pass, only complete shards +
        meta.json exist — no ``*.tmp.*`` files a reader could trip on."""
        src = EmbeddingSource(tokens, extractor, cache=str(tmp_path))
        src.materialize()
        cache_dir = src.cache.path
        names = os.listdir(cache_dir)
        assert not [n for n in names if ".tmp." in n], names
        assert "meta.json" in names

    def test_partial_cache_resumes_not_recomputes(self, extractor, tokens,
                                                  tmp_path):
        """A crash mid-pass leaves some shards; the next run reuses them
        byte-for-byte and fills only the holes."""
        s1 = EmbeddingSource(tokens, extractor, cache=str(tmp_path))
        next(iter(s1.iter_chunks(B)))            # compute + persist block 0
        cache_dir = s1.cache.path
        shard0 = os.path.join(cache_dir, "shard_00000.npz")
        before = open(shard0, "rb").read()
        s2 = EmbeddingSource(tokens, extractor, cache=str(tmp_path))
        full = s2.materialize()
        assert s2.cache_complete()
        assert open(shard0, "rb").read() == before
        np.testing.assert_array_equal(
            full, EmbeddingSource(tokens, extractor).materialize())

    def test_fingerprint_mismatch_raises(self, cfg, extractor, tokens,
                                         tmp_path):
        EmbeddingSource(tokens, extractor, cache=str(tmp_path)).materialize()
        other = EmbeddingExtractor(cfg, batch_size=B, seed=7)
        fp_dir = os.path.join(str(tmp_path),
                              extractor.fingerprint(SEQ)[:12])
        with pytest.raises(EmbedCacheError, match="identity"):
            EmbedCache(fp_dir, other.fingerprint(SEQ), n_rows=103,
                       dim=extractor.dim, block=B, seq_len=SEQ)
        # the multi-identity root keeps them apart instead
        s2 = EmbeddingSource(tokens, other, cache=str(tmp_path))
        assert not s2.cache_complete()

    def test_geometry_mismatch_raises(self, extractor, tokens, tmp_path):
        cache = EmbedCache(str(tmp_path / "c"), extractor.fingerprint(SEQ),
                           n_rows=50, dim=extractor.dim, block=B,
                           seq_len=SEQ)
        with pytest.raises(EmbedCacheError, match="geometry"):
            EmbeddingSource(tokens, extractor, cache=cache)

    def test_corrupt_shard_names_file_and_rows(self, extractor, tokens,
                                               tmp_path):
        src = EmbeddingSource(tokens, extractor, cache=str(tmp_path))
        src.materialize()
        shard1 = os.path.join(src.cache.path, "shard_00001.npz")
        with open(shard1, "wb") as f:
            f.write(b"not a zip")
        fresh = EmbedCache(src.cache.path, extractor.fingerprint(SEQ),
                           n_rows=103, dim=extractor.dim, block=B,
                           seq_len=SEQ)
        with pytest.raises(DataSourceError, match=r"shard_00001\.npz"):
            fresh.get(1)


# ------------------------------------------------------- pad-tail / cells
class TestCellPlanParity:
    def test_cell_plan_bitwise_invariant_to_chunk_size(self, extractor,
                                                       tokens):
        """The acceptance bar: cell plans built over an EmbeddingSource
        are bit-identical for ANY chunk size, and identical to the plan
        over the materialized reference — padded rows never leak into
        cell statistics or assignments."""
        ref = EmbeddingSource(tokens, extractor).materialize()
        base = build_cells_stream(ArraySource(ref), cell_size=40,
                                  method="voronoi", seed=0)
        for cs in (7, 16, 50, 1000):
            plan = build_cells_stream(EmbeddingSource(tokens, extractor),
                                      cell_size=40, method="voronoi",
                                      seed=0, chunk_size=cs)
            np.testing.assert_array_equal(plan.indices, base.indices)
            np.testing.assert_array_equal(plan.mask, base.mask)
            np.testing.assert_array_equal(plan.owner, base.owner)
            np.testing.assert_array_equal(plan.centers, base.centers)

    def test_pad_rows_never_surface(self, extractor, tokens):
        """103 rows / block 16 -> a 7-row tail padded with 9 zero
        sequences; no chunking may ever emit more than n_rows rows or a
        row equal to the zero-sequence embedding in the tail position."""
        src = EmbeddingSource(tokens, extractor)
        pad_emb = extractor(np.zeros((1, SEQ), np.int32))[0]
        total = 0
        for _, chunk in src.iter_chunks(9):      # straddles the tail block
            total += chunk.shape[0]
        assert total == 103
        tail = src.gather(np.arange(96, 103))
        assert not np.array_equal(tail[-1], pad_emb)


# ---------------------------------------------------------------- labels
class TestStreamingLabels:
    def test_labeled_source_pairs_and_streams(self, tmp_path):
        x = np.random.default_rng(2).normal(size=(57, 4)).astype(np.float32)
        y = np.where(np.random.default_rng(3).random(57) > .5, 1., -1.)
        # label shards on disk, mirroring x npz shards
        paths = []
        for i, (lo, hi) in enumerate([(0, 20), (20, 21), (21, 57)]):
            p = tmp_path / f"y{i}.npz"
            np.savez(p, y=y[lo:hi])
            paths.append(str(p))
        ls = LabeledSource(x, paths)
        np.testing.assert_array_equal(ls.labels_vector(),
                                      y.astype(np.float32))
        ids = np.asarray([56, 0, 20, 20, 33])
        np.testing.assert_array_equal(ls.gather_labels(ids),
                                      y[ids].astype(np.float32))
        for lo, xc, yc in ls.iter_labeled_chunks(10):
            np.testing.assert_array_equal(xc, x[lo:lo + xc.shape[0]])
            np.testing.assert_array_equal(
                yc, y[lo:lo + xc.shape[0]].astype(np.float32))

    def test_row_mismatch_raises(self):
        x = np.zeros((10, 3), np.float32)
        with pytest.raises(DataSourceError, match="mismatch"):
            LabeledSource(x, np.zeros(9))

    def test_svm_session_streams_labels_from_source(self, extractor,
                                                    tokens):
        """SVM(y=None) over a label-carrying EmbeddingSource: the whole
        train->select->test cycle runs without a caller-held y array."""
        from repro.api.session import SVM
        rng = np.random.default_rng(4)
        y = np.where(rng.random(103) > .5, 1., -1.).astype(np.float32)
        src = EmbeddingSource(tokens, extractor, labels=y)
        sel = SVM(src, FOLDS=2, MAX_ITERATIONS=60, CELL_SIZE=60) \
            .train().select()
        res = sel.test(EmbeddingSource(tokens, extractor), y)
        assert 0.0 <= res.error <= 1.0

    def test_plain_source_with_y_none_raises(self):
        from repro.api.session import SVM
        x = np.zeros((20, 3), np.float32)
        with pytest.raises(ValueError, match="label-carrying"):
            SVM(x).train()

    def test_unlabeled_embedding_source_raises(self, extractor, tokens):
        with pytest.raises(DataSourceError, match="no labels"):
            EmbeddingSource(tokens, extractor).labels_vector()


# ------------------------------------------------------------ embed keys
class TestEmbedKeys:
    def test_split_embed_keys(self):
        from repro.api.config import ConfigError, split_embed_keys
        rest, emb = split_embed_keys(
            {"EMBED_ARCH": ARCH, "EMBED_POOL": "last", "EMBED_BATCH": "8",
             "FOLDS": 3})
        assert rest == {"FOLDS": 3}
        assert emb == {"arch": ARCH, "pooling": "last", "batch_size": 8}
        with pytest.raises(ConfigError, match="EMBED_ARCH"):
            split_embed_keys({"EMBED_POOL": "mean"})

    def test_embed_keys_rejected_by_trainer(self):
        from repro.api.config import ConfigError, apply_keys
        from repro.train.svm_trainer import SVMTrainerConfig
        with pytest.raises(ConfigError, match="embed-stage key"):
            apply_keys(SVMTrainerConfig(), {"EMBED_ARCH": ARCH})

    def test_session_wraps_tokens_via_keys(self, tokens):
        from repro.api.session import SVM
        y = np.where(np.random.default_rng(5).random(103) > .5, 1., -1.)
        sess = SVM(tokens, y, EMBED_ARCH=ARCH, EMBED_BATCH=16,
                   FOLDS=2, MAX_ITERATIONS=40)
        assert isinstance(sess._x, EmbeddingSource)

    def test_scenario_front_end_wraps_tokens(self, tokens):
        from repro.api.scenarios import mcSVM
        y = np.random.default_rng(6).integers(0, 3, size=103)
        sess = mcSVM(tokens, y, EMBED_ARCH=ARCH, EMBED_BATCH=16, FOLDS=2)
        assert isinstance(sess._x, EmbeddingSource)


# ------------------------------------------------------------ EmbedServe
class TestEmbedServe:
    @pytest.fixture(scope="class")
    def served(self, extractor, tokens):
        from repro.api.session import SVM
        from repro.serve import EmbedServe, SVMEngine
        rng = np.random.default_rng(7)
        y = np.where(rng.random(103) > .5, 1., -1.).astype(np.float32)
        src = EmbeddingSource(tokens, extractor, labels=y)
        bank = SVM(src, FOLDS=2, MAX_ITERATIONS=60, CELL_SIZE=60) \
            .train().select().to_bank()
        return EmbedServe(SVMEngine(bank), extractor)

    def test_breakdown_sums_exactly_including_embed(self, served, tokens):
        ids = served.submit_tokens(tokens[:9])
        while served.pending:
            served.step()
        for rid in ids:
            b = served.breakdown(int(rid))
            assert b is not None
            assert b["embed_ms"] > 0.0
            parts = (b["embed_ms"] + b["queue_ms"] + b["pack_ms"]
                     + b["dispatch_ms"] + b["device_ms"] + b["collect_ms"])
            assert parts == pytest.approx(b["total_ms"], abs=1e-6)

    def test_stats_merge_embed_stage(self, served):
        st = served.stats()
        assert "embed" in st["per_stage"]
        emb = st["per_stage"]["embed"]
        assert emb["count"] >= 1 and emb["total_ms"] > 0.0
        # the engine's own stages are still there, untouched
        for s in ("queue", "pack", "dispatch", "device", "collect"):
            assert s in st["per_stage"]

    def test_feature_space_passthrough_has_zero_embed(self, served,
                                                      extractor, tokens):
        emb = extractor(tokens[9:12])
        ids = served.submit(emb)
        while served.pending:
            served.step()
        b = served.breakdown(int(ids[0]))
        assert b["embed_ms"] == 0.0

    def test_run_tokens_serves_all_and_monitor_sees_routing(
            self, served, tokens):
        from repro.serve import HealthMonitor
        mon = HealthMonitor(served.engine, drift_window_s=60.0)
        served.attach_monitor(mon)
        results = served.run_tokens(
            tokens[i:i + 8] for i in range(12, 60, 8))
        assert len(results) == 48
        # the monitor observed embedding-space routing: a drift verdict
        # exists (scores keyed by cell)
        assert mon.health()["drift"] is not None

    def test_predict_tokens_matches_engine_on_embeddings(self, served,
                                                         extractor, tokens):
        want = served.engine.predict(extractor(tokens[:5]))
        got = served.predict_tokens(tokens[:5])
        np.testing.assert_array_equal(got, want)

    def test_dim_mismatch_raises(self, served):
        from repro.serve import EmbedServe

        class FakeExtractor:
            dim = 3
            batch_size = 4
        with pytest.raises(ValueError, match="d=3"):
            EmbedServe(served.engine, FakeExtractor())


def test_embed_source_front_door(tokens, tmp_path):
    src = embed_source(tokens, arch=ARCH, batch_size=16,
                       cache_dir=str(tmp_path))
    assert isinstance(src, EmbeddingSource)
    assert src.dim == resolve_arch(ARCH).d_model
    src.materialize()
    warm = embed_source(tokens, arch=ARCH, batch_size=16,
                        cache_dir=str(tmp_path))
    assert warm.cache_complete()
