"""CV driver: fold construction, grid columns, selection, warm-start path."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cv as cv_mod
from repro.core import grids
from repro.core.svm import TrainedSVM, train_select
from repro.core.svm import test_error as svm_test_error


class TestFoldMasks:
    def test_partition_of_valid_samples(self):
        key = jax.random.PRNGKey(0)
        mask = jnp.asarray([1.0] * 50 + [0.0] * 14)
        folds = cv_mod.make_fold_masks(key, mask, 5)
        f = np.asarray(folds)
        assert f.shape == (5, 64)
        np.testing.assert_array_equal(f.sum(0), np.asarray(mask))  # each valid in 1 fold
        sizes = f.sum(1)
        assert sizes.max() - sizes.min() <= 1  # balanced

    def test_blocks_scheme_contiguous(self):
        key = jax.random.PRNGKey(0)
        mask = jnp.ones(30)
        folds = np.asarray(cv_mod.make_fold_masks(key, mask, 3, scheme="blocks"))
        # first 10 valid samples in fold 0
        assert folds[0, :10].all() and not folds[0, 10:].any()

    def test_stratified_balances_classes(self):
        key = jax.random.PRNGKey(1)
        n = 100
        y = jnp.asarray([1.0] * 20 + [-1.0] * 80)
        folds = np.asarray(cv_mod.make_fold_masks(key, jnp.ones(n), 5,
                                                  scheme="stratified", y=y))
        pos_per_fold = (folds * (np.asarray(y) > 0)).sum(1)
        assert pos_per_fold.max() - pos_per_fold.min() <= 1


class TestGrids:
    def test_libsvm_grid_shape_and_order(self):
        g = grids.libsvm_grid(n=4000)
        assert g.shape == (10, 11)
        lam = np.asarray(g.lambdas)
        assert (np.diff(lam) < 0).all()  # descending: largest lambda first

    def test_liquid_grid_choices(self):
        for choice, exp in [(0, (10, 10)), (1, (15, 15)), (2, (20, 20))]:
            g = grids.liquid_grid(n=1000, dim=5, grid_choice=choice)
            assert g.shape == exp
            assert float(g.gammas[0]) > float(g.gammas[-1]) > 0

    def test_adaptive_subgrid(self):
        g = grids.liquid_grid(n=1000, dim=5)
        sub = grids.adaptive_subgrid(g, 1)
        assert len(sub.gammas) == 5 and len(sub.lambdas) == 5

    def test_grid_columns_task_major(self):
        g = grids.GridSpec(gammas=jnp.asarray([1.0]),
                           lambdas=jnp.asarray([0.1, 0.01]))
        cfg = cv_mod.CVConfig(solver="quantile", taus=(0.2, 0.8))
        lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(g, cfg, n_tasks=2)
        assert lam_c.shape == (8,)
        np.testing.assert_allclose(np.asarray(task_c), [0, 0, 0, 0, 1, 1, 1, 1])
        np.testing.assert_allclose(np.asarray(lam_c)[:4], [0.1, 0.1, 0.01, 0.01])
        np.testing.assert_allclose(np.asarray(sub_c)[:4], [0.2, 0.8, 0.2, 0.8])


class TestTrainSelect:
    def test_binary_separable(self):
        rng = np.random.default_rng(0)
        n = 200
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 2)) + 2.5 * y[:, None]).astype(np.float32)
        model = train_select(x, y, cfg=cv_mod.CVConfig(n_folds=3, max_iters=400))
        err = float(svm_test_error(model, x, y))
        assert err <= 0.02
        assert float(model.val_loss[0, 0]) <= 0.05

    def test_selected_hyperparams_inside_grid(self):
        rng = np.random.default_rng(1)
        n = 150
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 3)) + y[:, None]).astype(np.float32)
        g = grids.liquid_grid(n=n, dim=3)
        model = train_select(x, y, grid=g,
                             cfg=cv_mod.CVConfig(n_folds=3, max_iters=300))
        assert float(model.gamma[0, 0]) in [float(v) for v in np.asarray(g.gammas)]
        assert float(model.lam[0, 0]) in [float(v) for v in np.asarray(g.lambdas)]

    def test_quantile_multi_tau_selection(self):
        rng = np.random.default_rng(2)
        n = 250
        x = rng.uniform(-1, 1, (n, 1)).astype(np.float32)
        y = (np.sin(2 * x[:, 0]) + 0.3 * rng.normal(size=n)).astype(np.float32)
        cfg = cv_mod.CVConfig(solver="quantile", taus=(0.1, 0.5, 0.9),
                              n_folds=3, max_iters=2000)
        model = train_select(x, y, cfg=cfg)
        f = np.asarray(model.decision_function(x))[:, 0, :]  # (n, 3)
        cover = (y[:, None] <= f).mean(0)
        assert cover[0] < cover[1] < cover[2]
        # per-tau selection may pick different gamma/lambda
        assert model.gamma.shape == (1, 3)

    def test_multitask_ova_path(self):
        from repro.tasks.builder import make_tasks
        rng = np.random.default_rng(3)
        n, c = 180, 3
        y = rng.integers(0, c, n)
        centers = np.array([[0, 3], [3, -2], [-3, -2]], np.float32)
        x = (centers[y] + 0.7 * rng.normal(size=(n, 2))).astype(np.float32)
        ts = make_tasks(y, "ova")
        model = train_select(x, None, y_tasks=ts.labels, task_mask=ts.task_mask,
                             cfg=cv_mod.CVConfig(n_folds=3, max_iters=400))
        dec = np.asarray(model.decision_function(x))[:, :, 0]  # (n, 3)
        pred = dec.argmax(1)
        assert (pred == y).mean() > 0.95

    def test_warm_start_quality_invariance(self):
        """Scanning gammas in either order lands at comparable val loss
        (warm start is an accelerator, not a result-changer)."""
        rng = np.random.default_rng(4)
        n = 120
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 2)) + 1.4 * y[:, None]).astype(np.float32)
        g = grids.liquid_grid(n=n, dim=2)
        cfg = cv_mod.CVConfig(n_folds=3, max_iters=1500, tol=1e-4)
        m1 = train_select(x, y, grid=g, cfg=cfg)
        g_rev = grids.GridSpec(gammas=g.gammas[::-1], lambdas=g.lambdas)
        m2 = train_select(x, y, grid=g_rev, cfg=cfg)
        assert abs(float(m1.val_loss[0, 0]) - float(m2.val_loss[0, 0])) < 0.05
