"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 host
devices (in its own process).

Per-test timeouts: the fault-injection suite (tests/test_faults.py) marks
tests with ``@pytest.mark.timeout(N)`` so an injected deadlock fails fast
instead of hanging the gate.  When the pytest-timeout plugin is installed
(requirements-dev.txt) it owns the marker; otherwise a SIGALRM-based
fallback here honours the same marker on POSIX, and the marker degrades to
a no-op where neither applies (non-main-thread runners, Windows).
"""
from __future__ import annotations

import signal
import threading

import numpy as np
import pytest

try:
    import pytest_timeout as _pytest_timeout  # noqa: F401
    _HAVE_TIMEOUT_PLUGIN = True
except ImportError:
    _HAVE_TIMEOUT_PLUGIN = False


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    if not _HAVE_TIMEOUT_PLUGIN:
        config.addinivalue_line(
            "markers",
            "timeout(seconds): per-test limit (SIGALRM fallback when the "
            "pytest-timeout plugin is not installed)")


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    marker = (None if _HAVE_TIMEOUT_PLUGIN
              else item.get_closest_marker("timeout"))
    use_alarm = (marker is not None and marker.args
                 and hasattr(signal, "SIGALRM")
                 and threading.current_thread() is threading.main_thread())
    if not use_alarm:
        yield
        return

    seconds = int(marker.args[0])

    def _expired(signum, frame):
        raise TimeoutError(
            f"{item.nodeid}: exceeded {seconds}s per-test timeout")

    prev = signal.signal(signal.SIGALRM, _expired)
    signal.alarm(seconds)
    try:
        yield
    finally:
        signal.alarm(0)
        signal.signal(signal.SIGALRM, prev)
