"""Sharded LM training == single-device training (8 forced host devices in
a subprocess).  This is the correctness proof for the TP/FSDP/activation
sharding rules the dry-run uses."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch
    from repro.data.tokens import TokenPipeline, TokenPipelineConfig
    from repro.models import model as model_mod
    from repro.models.layers import init_params, sharding_tree
    from repro.train.optimizer import OptConfig, adamw_step, init_opt_state
    from repro.train.lm_trainer import make_train_step

    spec = get_arch("qwen3-moe-235b-a22b")   # MoE: hardest sharding case
    cfg = dataclasses.replace(spec.smoke, dtype=jnp.float32)
    ocfg = OptConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=32,
                                             global_batch=8, seed=0))
    batch = pipe.batch(0)
    params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))

    # ---- single device
    step = jax.jit(make_train_step(cfg, ocfg))
    p1, o1, m1 = step(params, init_opt_state(params, ocfg), batch)

    # ---- 4x2 mesh with sharded params + batch + activation constraints
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    cfg2 = dataclasses.replace(cfg, batch_axes=("data",),
                               shard_activations=True)
    shards = sharding_tree(model_mod.build_template(cfg2), mesh)
    params2 = jax.tree.map(jax.device_put, params, shards)
    bshard = NamedSharding(mesh, P("data", None))
    batch2 = {k: jax.device_put(v, bshard) for k, v in batch.items()}
    with mesh:
        step2 = jax.jit(make_train_step(cfg2, ocfg))
        p2, o2, m2 = step2(params2, init_opt_state(params2, ocfg), batch2)

    assert abs(float(m1["loss"]) - float(m2["loss"])) < 2e-4, \
        (float(m1["loss"]), float(m2["loss"]))
    flat1 = jax.tree.leaves(p1)
    flat2 = jax.tree.leaves(p2)
    worst = max(float(jnp.max(jnp.abs(a - jax.device_get(b))))
                for a, b in zip(flat1, flat2))
    assert worst < 5e-3, worst
    print("OK loss", float(m1["loss"]), "worst param delta", worst)
""")


@pytest.mark.slow
def test_sharded_lm_train_matches_local():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))),
                       capture_output=True, text=True, timeout=900)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
