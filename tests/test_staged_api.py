"""Staged train->select->test API (liquidSVM's three-binary cycle).

The contract under test (ISSUE 4 acceptance):

  * staged-vs-fused parity: ``SVM.train() -> select("argmin") -> test()``
    is BITWISE-identical to the fused ``LiquidSVM.fit`` path per scenario;
  * re-selection on one cached ``TrainResult`` (npl -> roc -> argmin)
    changes winners without re-solving the grid: the solver touches only
    the moved columns (count << full sweep), and coming back to argmin
    reuses the cached models bitwise;
  * NPL selection reads VALIDATION false-alarm/detection rates from the
    retained surface (counts aggregate exactly over cells/folds);
  * stage artifacts round-trip through checkpoints, and the CLI's
    train/select/test artifacts cold-start an ``SVMEngine``.
"""
from __future__ import annotations

import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import SVM, ConfigError, mcSVM, qtSVM, rocSVM
from repro.api.config import apply_keys, parse_keys, weight_grid
from repro.api.session import SelectResult, TrainResult
from repro.data.synthetic import banana_mc, covtype_like, regression_1d, train_test_split
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig


def _binary_data(n=400, seed=0):
    x, y = covtype_like(n=n, d=4, seed=seed, label_noise=0.05, n_modes=3)
    return train_test_split(x, np.where(y == 0, -1, 1), 0.25, seed)


@pytest.fixture(scope="module")
def weighted_session():
    """One weighted-scenario train shared by every re-selection test."""
    xtr, ytr, xte, yte = _binary_data(n=500, seed=0)
    cfg = SVMTrainerConfig(scenario="weighted", weights=(0.5, 1.0, 2.0),
                           n_folds=2, max_iters=150, adaptivity_control=1)
    sess = SVM(xtr, ytr, config=cfg)
    sess.train()
    return sess, (xtr, ytr, xte, yte)


class TestStagedFusedParity:
    """train -> select(argmin) -> test == the fused fit, bitwise."""

    def _check(self, cfg, xtr, ytr, xte, yte):
        fused = LiquidSVM(cfg).fit(xtr, ytr)
        sess = SVM(xtr, ytr, config=cfg)
        sess.train()
        sel = sess.select("argmin")
        np.testing.assert_array_equal(sel.coefs, fused.coefs)
        np.testing.assert_array_equal(sel.gamma, fused.gamma)
        np.testing.assert_array_equal(sel.decision_function(xte),
                                      fused.decision_function(xte))
        assert sess.test(xte, yte).error == fused.error(xte, yte)
        assert sel.stats["columns_resolved"] == 0

    def test_binary(self):
        xtr, ytr, xte, yte = _binary_data(seed=1)
        self._check(SVMTrainerConfig(n_folds=2, max_iters=150,
                                     adaptivity_control=1),
                    xtr, ytr, xte, yte)

    def test_ova_cells(self):
        x, y = banana_mc(n=500, n_classes=3, seed=2)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 2)
        self._check(SVMTrainerConfig(scenario="ova", n_folds=2,
                                     max_iters=200, adaptivity_control=1,
                                     cell_method="voronoi", cell_size=150),
                    xtr, ytr, xte, yte)

    def test_quantile(self):
        x, y = regression_1d(n=250, seed=3)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 3)
        self._check(SVMTrainerConfig(scenario="quantile", taus=(0.1, 0.9),
                                     n_folds=2, max_iters=400,
                                     adaptivity_control=1),
                    xtr, ytr, xte, yte)


class TestReselection:
    """New rules on a cached TrainResult: one targeted wave, not a refit."""

    def test_npl_moves_winners_with_few_solves(self, weighted_session):
        sess, _ = weighted_session
        sel_arg = sess.select("argmin")
        sel_npl = sess.select("npl", alpha=0.02)
        st = sel_npl.stats
        assert st["winners_moved"] > 0          # the rule actually differs
        assert st["columns_resolved"] == st["winners_moved"]
        # solver invocations << the full fold x grid sweep
        assert st["columns_resolved"] <= 0.1 * st["grid_columns"]
        # untouched columns keep the cached models bitwise
        moved = (sel_npl.gamma != sel_arg.gamma) | (sel_npl.lam != sel_arg.lam)
        same = ~moved
        np.testing.assert_array_equal(
            np.moveaxis(sel_npl.coefs, 1, -1)[same],
            np.moveaxis(sel_arg.coefs, 1, -1)[same])
        assert moved.sum() == st["winners_moved"]

    def test_npl_rates_come_from_validation_surface(self, weighted_session):
        sess, _ = weighted_session
        tr = sess.train_result
        sel = sess.select("npl", alpha=0.02)
        fa, det = np.asarray(sel.extras["np_fa"]), np.asarray(sel.extras["np_det"])
        assert fa.shape == det.shape == tr.gamma.shape[1:]    # (T, S)
        assert ((0 <= fa) & (fa <= 1)).all() and ((0 <= det) & (det <= 1)).all()
        # counts on the surface are bounded by the per-cell class totals
        neg, pos = tr.class_counts()
        assert (tr.surf_fa <= neg[:, None, :, None, None] + 1e-6).all()
        assert (tr.surf_det <= pos[:, None, :, None, None] + 1e-6).all()
        # the weight pick honors the constraint when any weight meets it
        widx = int(sel.extras["np_weight_idx"][0])
        if (fa[0] <= 0.02).any():
            assert fa[0, widx] <= 0.02
        else:
            assert widx == int(fa[0].argmin())

    def test_roc_front_without_solves(self, weighted_session):
        sess, _ = weighted_session
        sel = sess.select("roc")
        assert sel.stats["columns_resolved"] == 0     # argmin winners cached
        front = np.asarray(sel.extras["roc_front"])   # (T, S, 2)
        t, s = sel.gamma.shape[1:]
        assert front.shape == (t, s, 2)
        assert (np.diff(front[0, :, 0]) >= 0).all()   # sorted along FA
        assert ((0 <= front) & (front <= 1)).all()

    def test_argmin_returns_to_cache_bitwise(self, weighted_session):
        sess, _ = weighted_session
        sess.select("npl", alpha=0.02)                # perturb
        sel = sess.select("argmin")
        assert sel.stats["columns_resolved"] == 0
        np.testing.assert_array_equal(sel.coefs, sess.train_result.coefs)
        # and the argmin val_loss is the surface at the argmin winners
        np.testing.assert_allclose(
            sel.val_loss, sess.train_result.val_loss, rtol=0, atol=0)

    def test_batched_resolve_one_launch_per_gamma_group(self, weighted_session):
        """Moved cells sharing a gamma-grid index re-solve in ONE vmapped
        launch: resolve_calls equals the number of distinct winning
        gamma indices, not the number of (cell, gamma) pairs."""
        sess, _ = weighted_session
        tr = sess.train_result
        sel_arg = sess.select("argmin")
        sel_npl = sess.select("npl", alpha=0.02)
        st = sel_npl.stats
        moved = (sel_npl.gamma != sel_arg.gamma) | (sel_npl.lam != sel_arg.lam)
        assert moved.sum() > 0
        groups = set()
        for c, t, s in np.argwhere(moved):
            g_idx = np.flatnonzero(
                tr.gammas_cells[c] == sel_npl.gamma[c, t, s])
            assert g_idx.size >= 1      # winner gamma comes from the grid
            groups.add(int(g_idx[0]))
        assert st["resolve_calls"] == len(groups)
        assert st["solver_iters"] > 0   # the re-solve really ran the QP


class TestWarmStartResolve:
    """Per-fold warm starts collapse a re-materializing re-solve: starting
    each fold from its own cached solution of the SAME columns, the box-QP
    passes its first KKT check instead of re-running the solve."""

    def test_iteration_counts_drop(self, weighted_session):
        from repro.core import cv

        sess, _ = weighted_session
        tr = sess.train_result
        c = int(np.flatnonzero(tr.mask_cells.sum(-1) > 0)[0])
        gv = tr.gamma[c, 0, 0]
        ts = np.argwhere(tr.gamma[c] == gv)            # (m, 2) same-gamma winners
        sub_grid = np.asarray(tr.config.weights, np.float32)
        args = (jnp.asarray(tr.x_cells[c]), jnp.asarray(tr.y_cells[c]),
                jnp.asarray(tr.tmask_cells[c]), jnp.asarray(tr.mask_cells[c]),
                jnp.asarray(np.float32(gv)),
                jnp.asarray(tr.lam[c, ts[:, 0], ts[:, 1]], jnp.float32),
                jnp.asarray(sub_grid[ts[:, 1]], jnp.float32),
                jnp.asarray(ts[:, 0], jnp.int32),
                jnp.asarray(tr.fold_keys[c]))

        cold_mean, it_cold, fold_coefs = cv.solve_columns_at(*args, tr.cv_cfg)
        warm_mean, it_warm, _ = cv.solve_columns_at(*args, tr.cv_cfg,
                                                    c0=fold_coefs)
        it_cold, it_warm = int(it_cold), int(it_warm)
        assert it_cold > 0
        assert it_warm < it_cold          # the satellite's headline claim
        assert it_warm <= it_cold // 2    # and the drop is substantial
        # the fixture caps max_iters, so the cold run may stop short of the
        # KKT point and the warm run polishes past it — parity is at the
        # decisions level (cfg.tol), not exact
        np.testing.assert_allclose(np.asarray(warm_mean),
                                   np.asarray(cold_mean), atol=1e-2)


class TestSurface:
    def test_val_loss_is_surface_min(self, weighted_session):
        sess, _ = weighted_session
        tr = sess.train_result
        # streaming selection == min over the retained surface
        np.testing.assert_allclose(
            tr.val_loss, tr.surf_loss.min(axis=(1, 3)), atol=0)


class TestPersistenceAndStreaming:
    def test_train_result_roundtrip_reselect(self, weighted_session, tmp_path):
        sess, _ = weighted_session
        tr = sess.train_result
        tr.save(str(tmp_path / "train"))
        tr2 = TrainResult.load(str(tmp_path / "train"))
        a = tr.select("npl", alpha=0.02)
        b = tr2.select("npl", alpha=0.02)
        np.testing.assert_array_equal(a.coefs, b.coefs)
        np.testing.assert_array_equal(a.gamma, b.gamma)
        assert a.stats == b.stats

    def test_select_result_roundtrip_and_bank(self, weighted_session, tmp_path):
        from repro.serve.svm_engine import SVMEngine
        sess, (_, _, xte, yte) = weighted_session
        sel = sess.select("npl", alpha=0.02)
        sel.save(str(tmp_path / "select"))
        sel2 = SelectResult.load(str(tmp_path / "select"))
        np.testing.assert_array_equal(sel2.decision_function(xte),
                                      sel.decision_function(xte))
        assert sel2.default_sub == sel.default_sub
        eng = SVMEngine(sel2.to_bank())
        np.testing.assert_array_equal(eng.predict_label(xte), sel.predict(xte))

    def test_streamed_test_matches_in_memory(self, weighted_session, tmp_path):
        sess, (_, _, xte, yte) = weighted_session
        sel = sess.select("argmin")
        ref = sel.test(xte, yte)
        np.save(tmp_path / "xte.npy", xte)
        via_mmap = sel.test(str(tmp_path / "xte.npy"), yte)
        chunked = sel.test(xte, yte, chunk_size=32)
        assert via_mmap.error == ref.error        # classification: exact
        assert chunked.error == ref.error
        assert via_mmap.n == ref.n == len(xte)


class TestCLI:
    def test_cycle_cold_starts_engine(self, tmp_path, capsys):
        from repro import cli
        from repro.serve.model_bank import ModelBank
        from repro.serve.svm_engine import SVMEngine

        xtr, ytr, xte, yte = _binary_data(n=300, seed=4)
        for name, arr in [("xtr", xtr), ("ytr", ytr), ("xte", xte),
                          ("yte", yte)]:
            np.save(tmp_path / f"{name}.npy", arr)
        md = str(tmp_path / "model")
        common = ["-S", "FOLDS=2", "-S", "MAX_ITERATIONS=150",
                  "-S", "ADAPTIVITY_CONTROL=1"]
        assert cli.main(["train", "--data", str(tmp_path / "xtr.npy"),
                         "--labels", str(tmp_path / "ytr.npy"),
                         "--model-dir", md, "--scenario", "npl",
                         "-S", "WEIGHTS=0.5 1.0 2.0"] + common) == 0
        out_train = json.loads(capsys.readouterr().out)
        assert out_train["stage"] == "train" and out_train["slots"] >= 1

        assert cli.main(["select", "--model-dir", md,
                         "-S", "NPL_CONSTRAINT=0.05"]) == 0
        out_sel = json.loads(capsys.readouterr().out)
        assert out_sel["rule"] == "npl"
        assert out_sel["stats"]["columns_resolved"] \
            <= out_sel["stats"]["grid_columns"]
        # select/ references the cells in train/ instead of re-writing the
        # O(n*d) staged rows on every re-selection
        with open(f"{md}/select/step_00000000/manifest.json") as f:
            sel_paths = " ".join(json.load(f)["paths"])
        assert "x_cells" not in sel_paths

        assert cli.main(["test", "--data", str(tmp_path / "xte.npy"),
                         "--labels", str(tmp_path / "yte.npy"),
                         "--model-dir", md]) == 0
        out_test = json.loads(capsys.readouterr().out)
        assert out_test["n"] == len(xte) and out_test["error"] < 0.25

        # re-select under a different rule: no retrain, new bank
        assert cli.main(["select", "--model-dir", md, "--rule", "roc"]) == 0
        out_roc = json.loads(capsys.readouterr().out)
        assert out_roc["stats"]["columns_resolved"] == 0
        assert "roc_front" in out_roc

        # a predict server cold-starts from the select output alone
        sel = SelectResult.load(f"{md}/select")
        eng = SVMEngine(ModelBank.load(f"{md}/bank"))
        np.testing.assert_array_equal(eng.predict_label(xte),
                                      sel.predict(xte))

    def test_weight_sweep_scenarios_get_default_grids(self, tmp_path, capsys):
        """`--scenario roc` without WEIGHTS must not degenerate to S=1."""
        from repro import cli
        xtr, ytr, _, _ = _binary_data(n=200, seed=9)
        np.save(tmp_path / "x.npy", xtr)
        np.save(tmp_path / "y.npy", ytr)
        assert cli.main(["train", "--data", str(tmp_path / "x.npy"),
                         "--labels", str(tmp_path / "y.npy"),
                         "--model-dir", str(tmp_path / "m"),
                         "--scenario", "roc", "-S", "FOLDS=2",
                         "-S", "MAX_ITERATIONS=100",
                         "-S", "ADAPTIVITY_CONTROL=2"]) == 0
        out = json.loads(capsys.readouterr().out)
        assert out["grid"]["sub"] == 9           # the rocSVM default grid


class TestConfigKeys:
    def test_coercion_and_mapping(self):
        cfg, sel = apply_keys(SVMTrainerConfig(), {
            "folds": "3", "Kernel": "gauss_rbf", "VORONOI": "6",
            "cell_size": "250", "NPL_CONSTRAINT": "0.01", "npl_class": "1",
            "max_iterations": 200, "THREADS": 8})
        assert cfg.n_folds == 3 and cfg.cell_method == "recursive"
        assert cfg.cell_size == 250 and cfg.max_iters == 200
        assert sel == {"alpha": 0.01, "npl_class": 1}

    def test_weight_grid_keys(self):
        cfg, _ = apply_keys(SVMTrainerConfig(), {
            "MIN_WEIGHT": 0.5, "MAX_WEIGHT": 2.0, "WEIGHT_STEPS": 3})
        np.testing.assert_allclose(cfg.weights, (0.5, 1.0, 2.0))
        assert weight_grid(1.0, 1.0, 1) == (1.0,)

    def test_validation_errors(self):
        with pytest.raises(ConfigError, match="unknown config key"):
            parse_keys({"FOLDZ": 3})
        with pytest.raises(ConfigError, match="below minimum"):
            parse_keys({"FOLDS": 1})
        with pytest.raises(ConfigError, match="not in"):
            parse_keys({"FOLD_SCHEME": "sorted"})
        with pytest.raises(ConfigError, match="cannot parse"):
            parse_keys({"FOLDS": "three"})
        with pytest.raises(ConfigError, match="KERNEL"):
            apply_keys(SVMTrainerConfig(), {"KERNEL": "cubic"})

    def test_session_accepts_string_keys(self):
        sess = SVM(np.zeros((4, 2), np.float32), np.ones(4), FOLDS=3,
                   NPL_CONSTRAINT=0.1)
        assert sess.config.n_folds == 3
        assert sess.select_kwargs == {"alpha": 0.1}


class TestScenarioFrontEnds:
    def test_mcSVM_cycle(self):
        x, y = banana_mc(n=400, n_classes=3, seed=5)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 5)
        sess = mcSVM(xtr, ytr, FOLDS=2, MAX_ITERATIONS=200,
                     ADAPTIVITY_CONTROL=1)
        sess.train()
        assert sess.test(xte, yte).error < 0.25

    def test_qtSVM_cycle(self):
        x, y = regression_1d(n=250, seed=6)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 6)
        sess = qtSVM(xtr, ytr, taus=(0.1, 0.9), FOLDS=2,
                     MAX_ITERATIONS=600, ADAPTIVITY_CONTROL=1)
        sess.train()
        sel = sess.select()                      # defaults to the "quantile" rule
        assert sel.rule == "quantile"
        pred = sel.predict(xte)                  # (m, 2)
        cover = (yte[:, None] <= pred).mean(0)
        assert cover[0] < cover[1]

    def test_lsSVM_cycle(self):
        from repro.api import lsSVM
        x, y = regression_1d(n=250, seed=8)
        xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 8)
        sess = lsSVM(xtr, ytr, FOLDS=2, ADAPTIVITY_CONTROL=1)
        sess.train()
        res = sess.test(xte, yte)                # mse
        assert res.error < 2.0 * float(np.var(yte))
        assert sess.select_result.predict(xte).shape == (len(xte),)

    def test_rocSVM_front(self):
        xtr, ytr, _, _ = _binary_data(n=300, seed=7)
        sess = rocSVM(xtr, ytr, weight_steps=3, FOLDS=2,
                      MAX_ITERATIONS=150, ADAPTIVITY_CONTROL=1)
        sess.train()
        sel = sess.select()
        assert sel.rule == "roc"
        front = np.asarray(sel.extras["roc_front"])
        assert front.shape == (1, 3, 2)
        assert (np.diff(front[0, :, 0]) >= 0).all()
