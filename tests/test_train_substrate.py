"""Optimizer, checkpointing, fault tolerance, data determinism, compression."""
from __future__ import annotations

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.train import checkpoint as ckpt
from repro.train.lm_trainer import Trainer, TrainLoopConfig, make_train_step
from repro.train.optimizer import (OptConfig, adamw_step, init_opt_state,
                                   schedule_lr)


def _quad_params():
    return {"w": jnp.asarray([3.0, -2.0], jnp.float32),
            "b": jnp.asarray([[1.0, 1.0], [1.0, 1.0]], jnp.float32)}


class TestOptimizer:
    def test_adamw_reduces_quadratic(self):
        params = _quad_params()
        cfg = OptConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                        total_steps=100, schedule="constant")
        opt = init_opt_state(params, cfg)

        def loss(p):
            return jnp.sum(p["w"] ** 2) + jnp.sum((p["b"] - 0.5) ** 2)

        l0 = float(loss(params))
        for _ in range(50):
            g = jax.grad(loss)(params)
            params, opt, _ = adamw_step(g, opt, cfg)
        assert float(loss(params)) < 0.1 * l0

    @pytest.mark.parametrize("policy", ["fp32", "bf16_mom", "pure_bf16"])
    def test_policies_dtypes(self, policy):
        params = {"w": jnp.zeros((4, 4), jnp.bfloat16)}
        cfg = OptConfig(policy=policy)
        opt = init_opt_state(params, cfg)
        want_master = jnp.float32 if policy != "pure_bf16" else jnp.bfloat16
        want_mom = jnp.float32 if policy == "fp32" else jnp.bfloat16
        assert opt.master["w"].dtype == want_master
        assert opt.m["w"].dtype == want_mom
        g = {"w": jnp.ones((4, 4), jnp.bfloat16)}
        p2, opt2, _ = adamw_step(g, opt, cfg)
        assert p2["w"].dtype == jnp.bfloat16  # compute dtype preserved

    def test_grad_clip(self):
        params = {"w": jnp.zeros((2,), jnp.float32)}
        cfg = OptConfig(grad_clip=1.0, lr=1.0, warmup_steps=0,
                        schedule="constant", weight_decay=0.0)
        opt = init_opt_state(params, cfg)
        g = {"w": jnp.asarray([300.0, 400.0])}  # norm 500
        _, _, metrics = adamw_step(g, opt, cfg)
        np.testing.assert_allclose(float(metrics["grad_norm"]), 500.0, rtol=1e-5)
        np.testing.assert_allclose(float(metrics["clip_scale"]), 1 / 500.0,
                                   rtol=1e-5)

    def test_warmup_cosine_schedule(self):
        cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                        min_lr_frac=0.1)
        assert float(schedule_lr(cfg, jnp.int32(5))) == pytest.approx(0.5)
        assert float(schedule_lr(cfg, jnp.int32(10))) == pytest.approx(1.0)
        assert float(schedule_lr(cfg, jnp.int32(100))) == pytest.approx(0.1)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                "b": {"c": jnp.ones((4,), jnp.bfloat16)}}
        ckpt.save_checkpoint(str(tmp_path), 7, tree, extra={"note": "x"})
        target = jax.tree.map(jnp.zeros_like, tree)
        restored, step, extra = ckpt.restore_checkpoint(str(tmp_path), target)
        assert step == 7 and extra["note"] == "x"
        jax.tree.map(lambda x, y: np.testing.assert_array_equal(x, y),
                     restored, tree)

    def test_keep_last_gc(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        for s in (1, 2, 3, 4):
            ckpt.save_checkpoint(str(tmp_path), s, tree, keep_last=2)
        dirs = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert dirs == ["step_00000003", "step_00000004"]

    def test_structure_mismatch_rejected(self, tmp_path):
        ckpt.save_checkpoint(str(tmp_path), 1, {"a": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="structure mismatch"):
            ckpt.restore_checkpoint(str(tmp_path), {"zzz": jnp.zeros((2,))})

    def test_latest_pointer_fallback(self, tmp_path):
        tree = {"a": jnp.zeros((2,))}
        ckpt.save_checkpoint(str(tmp_path), 3, tree)
        with open(tmp_path / "latest", "w") as f:
            f.write("step_99999999")  # torn pointer
        assert ckpt.latest_step(str(tmp_path)) == 3


class TestTokenPipeline:
    def test_deterministic_replay(self):
        cfg = TokenPipelineConfig(vocab=211, seq_len=16, global_batch=4, seed=3)
        p1, p2 = TokenPipeline(cfg), TokenPipeline(cfg)
        b1, b2 = p1.batch(17), p2.batch(17)
        np.testing.assert_array_equal(b1["inputs"], b2["inputs"])
        b3 = p1.batch(18)
        assert not np.array_equal(b1["inputs"], b3["inputs"])

    def test_labels_are_shifted_inputs(self):
        cfg = TokenPipelineConfig(vocab=97, seq_len=12, global_batch=2)
        b = TokenPipeline(cfg).batch(0)
        np.testing.assert_array_equal(np.asarray(b["labels"])[:, :-1],
                                      np.asarray(b["inputs"])[:, 1:])
        assert float(b["mask"][0, -1]) == 0.0

    def test_embed_kind(self):
        cfg = TokenPipelineConfig(vocab=97, seq_len=8, global_batch=2,
                                  input_kind="embed", d_frontend=32)
        b = TokenPipeline(cfg).batch(0)
        assert b["inputs"].shape == (2, 8, 32)


class TestTrainerFaultTolerance:
    def _mk(self, tmp_path, total=12, every=4):
        spec = get_arch("stablelm-1.6b")
        cfg = spec.smoke
        pipe = TokenPipeline(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=16, global_batch=4, seed=0))
        opt_cfg = OptConfig(lr=1e-3, warmup_steps=2, total_steps=total)
        loop = TrainLoopConfig(total_steps=total, ckpt_every=every,
                               ckpt_dir=str(tmp_path), log_every=1)
        return Trainer(cfg, opt_cfg, loop, pipe)

    def test_loss_decreases(self, tmp_path):
        t = self._mk(tmp_path, total=30, every=100)
        out = t.run()
        assert out["history"][-1]["loss"] < out["history"][0]["loss"]

    def test_crash_resume_matches_uninterrupted(self, tmp_path):
        """Kill at step 6, restart; final params == one uninterrupted run."""
        t_ref = self._mk(tmp_path / "ref", total=8, every=8)
        ref = t_ref.run()

        t_crash = self._mk(tmp_path / "crash", total=8, every=4)
        with pytest.raises(RuntimeError, match="injected failure"):
            t_crash.run(fail_at=6)
        # restart picks up from the step-4 checkpoint
        out = self._mk(tmp_path / "crash", total=8, every=4).run()
        jax.tree.map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b, np.float32),
                atol=1e-5),
            out["params"], ref["params"])

    def test_grad_accum_equivalence(self):
        """accum=2 over batch 8 == accum=1 with the same 8 rows."""
        spec = get_arch("stablelm-1.6b")
        cfg = dataclasses.replace(spec.smoke, dtype=jnp.float32)
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))
        pipe = TokenPipeline(TokenPipelineConfig(vocab=cfg.vocab, seq_len=16,
                                                 global_batch=8, seed=1))
        batch = pipe.batch(0)
        ocfg = OptConfig(lr=1e-3, warmup_steps=0, schedule="constant")
        s1 = make_train_step(cfg, ocfg, grad_accum=1)
        s2 = make_train_step(cfg, ocfg, grad_accum=2)
        p1, _, m1 = s1(params, init_opt_state(params, ocfg), batch)
        p2, _, m2 = s2(params, init_opt_state(params, ocfg), batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-5
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), atol=2e-5), p1, p2)


class TestCompression:
    def test_quantize_roundtrip_error_bounded(self):
        from repro.distributed.compression import dequantize_int8, quantize_int8
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(0, 0.1, (256,)), jnp.float32)
        q, s = quantize_int8(g)
        err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(g))
        assert err.max() <= float(s) / 2 + 1e-9

    def test_error_feedback_reduces_bias(self):
        """Mean EF-compressed gradient over many steps converges to the true
        mean gradient (the EF contract)."""
        from repro.distributed.compression import ef_compress, dequantize_int8
        rng = np.random.default_rng(1)
        g_true = jnp.asarray(rng.normal(0, 1, (64,)), jnp.float32)
        err = jnp.zeros_like(g_true)
        acc = np.zeros(64)
        n = 200
        for _ in range(n):
            q, s, err = ef_compress(g_true, err)
            acc += np.asarray(dequantize_int8(q, s))
        np.testing.assert_allclose(acc / n, np.asarray(g_true), atol=1e-3)

    def test_ef_psum_under_shard_map(self):
        """int8 EF all-reduce across 8 forced host devices == f32 mean."""
        import subprocess, sys, textwrap, os as _os
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
            import jax, jax.numpy as jnp, numpy as np
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import ef_psum
            mesh = jax.make_mesh((8,), ("pod",))
            rng = np.random.default_rng(0)
            g = jnp.asarray(rng.normal(0, 1, (8, 128)), jnp.float32)
            def body(gl, el):
                out, new_err = ef_psum(gl[0], el[0], "pod")
                return out[None], new_err[None]
            # one shard_map version resolver for the whole repo
            from repro.distributed.cell_trainer import _shard_map as sm
            f = jax.jit(sm(body, mesh=mesh,
                in_specs=(P("pod"), P("pod")), out_specs=(P("pod"), P("pod"))))
            out, err = f(g, jnp.zeros_like(g))
            want = np.mean(np.asarray(g), axis=0)
            got = np.asarray(out)[0]
            assert np.allclose(got, want, atol=2e-2), np.abs(got-want).max()
            # every device returns the same mean
            assert np.allclose(np.asarray(out), np.asarray(out)[0:1], atol=1e-6)
            print("OK")
        """)
        env = dict(_os.environ); env["PYTHONPATH"] = "src"
        env.pop("XLA_FLAGS", None)
        r = subprocess.run([sys.executable, "-c", script], env=env,
                           cwd=_os.path.dirname(_os.path.dirname(
                               _os.path.abspath(__file__))),
                           capture_output=True, text=True, timeout=300)
        assert r.returncode == 0, r.stdout + r.stderr
        assert "OK" in r.stdout
