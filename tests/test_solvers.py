"""Solver unit tests: every liquidSVM dual reaches its KKT point and the
statistical contract of each loss holds (margin / coverage / expectile).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import kernel_fns
from repro.core.solvers import (
    base, expectile as exp_solver, hinge, least_squares as ls, quantile as qs,
)

jax.config.update("jax_enable_x64", False)


def _gram(x, gamma=1.0):
    return kernel_fns.gaussian(jnp.asarray(x, jnp.float32), jnp.asarray(x, jnp.float32),
                               jnp.float32(gamma))


# ---------------------------------------------------------------- box QP core

class TestBoxQP:
    def test_identity_kernel_analytic(self):
        """With K = I the solution is clip(y, lo, hi) exactly."""
        n, p = 40, 7
        rng = np.random.default_rng(1)
        y = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
        lo = jnp.full((n, p), -0.5, jnp.float32)
        hi = jnp.full((n, p), 0.8, jnp.float32)
        res = base.box_qp(jnp.eye(n), y, lo, hi, tol=1e-6, max_iters=5000)
        np.testing.assert_allclose(res.c, np.clip(y, -0.5, 0.8), atol=2e-5)

    def test_kkt_residual_below_tol(self):
        rng = np.random.default_rng(2)
        x = rng.normal(size=(96, 5)).astype(np.float32)
        k = _gram(x)
        y = jnp.asarray(np.sign(rng.normal(size=(96, 4))), jnp.float32)
        lo, hi = jnp.minimum(0.0, y) * 2.0, jnp.maximum(0.0, y) * 2.0
        res = base.box_qp(k, y, lo, hi, tol=1e-4, max_iters=8000)
        assert np.max(np.asarray(res.kkt)) <= 1e-4

    def test_matches_cd_reference_fixed_point(self):
        """FISTA and Gauss-Seidel CD land on the same box-QP optimum."""
        from repro.kernels.cd_solver import ref as cd_ref
        rng = np.random.default_rng(3)
        x = rng.normal(size=(64, 4)).astype(np.float32)
        k = _gram(x) + 1e-3 * jnp.eye(64)
        y = jnp.asarray(rng.normal(size=(64, 3)), jnp.float32)
        lo = jnp.full((64, 3), -1.0, jnp.float32)
        hi = jnp.full((64, 3), 1.0, jnp.float32)
        c_fista = base.box_qp(k, y, lo, hi, tol=1e-7, max_iters=20000).c
        c_cd, _ = cd_ref.solve_cd_ref(k, y, lo, hi, jnp.zeros((64, 3)), epochs=600)
        np.testing.assert_allclose(c_fista, c_cd, atol=5e-4)

    def test_dual_objective_monotone_in_iterations(self):
        rng = np.random.default_rng(4)
        x = rng.normal(size=(48, 3)).astype(np.float32)
        k = _gram(x)
        y = jnp.asarray(np.sign(rng.normal(size=(48, 1))), jnp.float32)
        lo, hi = jnp.minimum(0.0, y), jnp.maximum(0.0, y)
        objs = []
        for iters in (5, 20, 80, 400):
            c = base.box_qp(k, y, lo, hi, tol=0.0, max_iters=iters).c
            objs.append(float(base.dual_objective(k, y, c)[0]))
        assert objs == sorted(objs) or max(
            objs[i] - objs[i + 1] for i in range(len(objs) - 1)) < 1e-5

    def test_power_iteration_upper_bounds_spectrum(self):
        rng = np.random.default_rng(5)
        a = rng.normal(size=(60, 60)).astype(np.float32)
        k = a @ a.T / 60.0
        l_est = float(base.power_iteration_l(jnp.asarray(k)))
        l_true = float(np.linalg.eigvalsh(k).max())
        assert l_est >= 0.99 * l_true  # 1.05 safety factor in estimator


# ------------------------------------------------------- warm-start property

class TestWarmStartProperty:
    """A warm start from far OUTSIDE the (lambda, weight) box must land on
    the same optimum as the cold ``c0 = 0`` solve: ``clip_warm_start``
    projects it into the feasible box and every solver's descent from a
    feasible start is monotone.  Exercised through the full
    ``solve_columns_at`` path so each solver's c0 threading is covered."""

    @staticmethod
    def _cell(seed, regression):
        rng = np.random.default_rng(seed)
        n, d = 48, 3
        x = rng.normal(size=(n, d)).astype(np.float32)
        if regression:
            y = (np.sin(x[:, 0]) + 0.1 * rng.normal(size=n)).astype(np.float32)
        else:
            y = np.sign(rng.normal(size=n)).astype(np.float32)
        return x, y

    @pytest.mark.parametrize("solver", ["hinge", "quantile", "expectile", "ls"])
    def test_outside_box_start_matches_cold(self, solver):
        from repro.core import cv
        x, y = self._cell(11, regression=solver != "hinge")
        n = x.shape[0]
        sub = (0.3, 0.7) if solver in ("quantile", "expectile") else (1.0, 2.0)
        cfg = cv.CVConfig(
            solver=solver, n_folds=2, tol=1e-5, max_iters=20000,
            taus=sub if solver in ("quantile", "expectile") else (0.5,),
            weights=sub if solver == "hinge" else (1.0,))
        lams = (0.05, 0.5)
        if solver == "ls":
            sub = (1.0,)
        lam_cols = jnp.asarray(np.repeat(lams, len(sub)), jnp.float32)
        sub_cols = jnp.asarray(np.tile(sub, len(lams)), jnp.float32)
        p = lam_cols.shape[0]
        task_cols = jnp.zeros((p,), jnp.int32)
        args = (jnp.asarray(x), jnp.asarray(y[None, :]),
                jnp.ones((1, n), jnp.float32), jnp.ones((n,), jnp.float32),
                jnp.float32(1.0), lam_cols, sub_cols, task_cols,
                jax.random.PRNGKey(0))

        cold_mean, _, cold_folds = cv.solve_columns_at(*args, cfg)
        # a start orders of magnitude outside any feasible box
        c0_wild = jnp.asarray(50.0 * np.random.default_rng(12).normal(
            size=(n, p)), jnp.float32)
        warm_mean, _, warm_folds = cv.solve_columns_at(*args, cfg, c0=c0_wild)

        scale = max(float(jnp.max(jnp.abs(cold_folds))), 1e-6)
        np.testing.assert_allclose(np.asarray(warm_folds) / scale,
                                   np.asarray(cold_folds) / scale, atol=5e-3)
        np.testing.assert_allclose(np.asarray(warm_mean) / scale,
                                   np.asarray(cold_mean) / scale, atol=5e-3)


# ------------------------------------------------------------------- hinge

class TestHinge:
    def test_separable_margin(self):
        rng = np.random.default_rng(6)
        n = 120
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 2)) + 3.0 * y[:, None]).astype(np.float32)
        k = _gram(x, gamma=3.0)
        lam = jnp.asarray([1e-4], jnp.float32)
        res = hinge.solve_hinge(k, jnp.asarray(y), lam, jnp.float32(n),
                                tol=1e-5, max_iters=10000)
        f = np.asarray(k @ res.c)[:, 0]
        assert np.mean(np.sign(f) == y) == 1.0

    def test_duality_gap_closes(self):
        rng = np.random.default_rng(7)
        n = 100
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 4)) + 1.2 * y[:, None]).astype(np.float32)
        k = _gram(x, gamma=2.0)
        lam = jnp.asarray([1e-3, 1e-2], jnp.float32)
        res = hinge.solve_hinge(k, jnp.asarray(y), lam, jnp.float32(n),
                                tol=1e-6, max_iters=30000)
        gap = np.asarray(hinge.primal_dual_gap(k, jnp.asarray(y), res.c, lam,
                                               jnp.float32(n)))
        assert np.all(gap < 1e-3)

    def test_box_respects_class_weight(self):
        y = jnp.asarray([1.0, -1.0], jnp.float32)
        lam = jnp.asarray([0.1], jnp.float32)
        w = jnp.asarray([2.0, 1.0], jnp.float32)  # +1 class weighted 2x
        lo, hi = hinge.hinge_boxes(y, lam, jnp.float32(2.0), sample_weight=w)
        c = 1.0 / (2.0 * 0.1 * 2.0)
        np.testing.assert_allclose(hi[0, 0], 2.0 * c, rtol=1e-6)
        np.testing.assert_allclose(lo[1, 0], -c, rtol=1e-6)

    def test_masked_samples_are_inert(self):
        """Zero-width box == removing the sample from the dual exactly."""
        rng = np.random.default_rng(8)
        n = 60
        y = np.sign(rng.normal(size=n)).astype(np.float32)
        x = (rng.normal(size=(n, 3)) + 1.5 * y[:, None]).astype(np.float32)
        k_full = _gram(x)
        mask = np.ones(n, np.float32)
        mask[40:] = 0.0
        lam = jnp.asarray([1e-3], jnp.float32)
        res_m = hinge.solve_hinge(k_full, jnp.asarray(y), lam, jnp.float32(40),
                                  train_mask=jnp.asarray(mask), tol=1e-6,
                                  max_iters=20000)
        k_sub = _gram(x[:40])
        res_s = hinge.solve_hinge(k_sub, jnp.asarray(y[:40]), lam,
                                  jnp.float32(40), tol=1e-6, max_iters=20000)
        np.testing.assert_allclose(res_m.c[:40], res_s.c, atol=5e-4)
        np.testing.assert_allclose(res_m.c[40:], 0.0, atol=1e-7)


# ------------------------------------------------------------------ quantile

class TestQuantile:
    @pytest.mark.parametrize("tau", [0.1, 0.5, 0.9])
    def test_pinball_coverage(self, tau):
        rng = np.random.default_rng(9)
        n = 400
        x = rng.uniform(-1, 1, (n, 1)).astype(np.float32)
        y = (np.sin(2 * x[:, 0]) + 0.3 * rng.normal(size=n)).astype(np.float32)
        k = _gram(x, gamma=0.4)
        res = qs.solve_quantile(k, jnp.asarray(y), jnp.asarray([tau], jnp.float32),
                                jnp.asarray([2e-5], jnp.float32), jnp.float32(n),
                                tol=1e-5, max_iters=30000)
        f = np.asarray(k @ res.c)[:, 0]
        cover = float(np.mean(y <= f))
        assert abs(cover - tau) < 0.08, (tau, cover)

    def test_box_is_label_independent(self):
        lo, hi = qs.quantile_boxes(jnp.asarray([0.3]), jnp.asarray([0.1]),
                                   jnp.float32(10.0), n=4)
        c = 1.0 / (2.0 * 0.1 * 10.0)
        np.testing.assert_allclose(lo, np.full((4, 1), (0.3 - 1.0) * c), rtol=1e-6)
        np.testing.assert_allclose(hi, np.full((4, 1), 0.3 * c), rtol=1e-6)


# ----------------------------------------------------------------- LS / KRR

class TestLeastSquares:
    def test_eigh_path_matches_cholesky(self):
        rng = np.random.default_rng(10)
        x = rng.normal(size=(80, 4)).astype(np.float32)
        y = jnp.asarray(rng.normal(size=80), jnp.float32)
        k = _gram(x)
        lams = jnp.asarray([1e-3, 1e-2, 1e-1], jnp.float32)
        c_path = ls.solve_krr_eigh(k, y, lams, jnp.float32(80))
        for j, lam in enumerate(np.asarray(lams)):
            c_chol = ls.solve_krr_chol(k, y, jnp.float32(lam), jnp.float32(80))
            np.testing.assert_allclose(c_path[:, j], c_chol, atol=2e-3)

    def test_interpolates_at_tiny_lambda(self):
        rng = np.random.default_rng(11)
        x = rng.normal(size=(50, 2)).astype(np.float32)
        y = rng.normal(size=50).astype(np.float32)
        k = _gram(x, gamma=1.5) + 1e-4 * jnp.eye(50)
        c = ls.solve_krr_eigh(k, jnp.asarray(y), jnp.asarray([1e-9], jnp.float32),
                              jnp.float32(50))
        f = np.asarray(k @ c)[:, 0]
        assert np.max(np.abs(f - y)) < 0.15  # f32 eigh conditioning floor

    def test_masked_fold_equals_subproblem(self):
        rng = np.random.default_rng(12)
        x = rng.normal(size=(60, 3)).astype(np.float32)
        y = rng.normal(size=60).astype(np.float32)
        mask = np.ones(60, np.float32); mask[45:] = 0.0
        k = _gram(x)
        c_m = ls.solve_krr_eigh(k, jnp.asarray(y), jnp.asarray([1e-2], jnp.float32),
                                jnp.float32(45), train_mask=jnp.asarray(mask))
        c_s = ls.solve_krr_eigh(_gram(x[:45]), jnp.asarray(y[:45]),
                                jnp.asarray([1e-2], jnp.float32), jnp.float32(45))
        np.testing.assert_allclose(c_m[:45], c_s, atol=1e-3)
        np.testing.assert_allclose(c_m[45:], 0.0, atol=1e-5)


# ---------------------------------------------------------------- expectile

class TestExpectile:
    def test_tau_half_is_krr(self):
        """tau = 0.5 halves the LS loss => lambda is effectively doubled."""
        rng = np.random.default_rng(13)
        x = rng.normal(size=(70, 3)).astype(np.float32)
        y = rng.normal(size=70).astype(np.float32)
        k = _gram(x)
        c_exp = exp_solver.solve_expectile(
            k, jnp.asarray(y), jnp.asarray([0.5], jnp.float32),
            jnp.asarray([1e-2], jnp.float32), jnp.float32(70))
        c_krr = ls.solve_krr_eigh(k, jnp.asarray(y),
                                  jnp.asarray([2e-2], jnp.float32), jnp.float32(70))
        np.testing.assert_allclose(c_exp[:, 0], c_krr[:, 0], atol=2e-3)

    def test_expectile_ordering(self):
        """Higher tau => pointwise higher expectile estimate (on average)."""
        rng = np.random.default_rng(14)
        n = 300
        x = rng.uniform(-1, 1, (n, 1)).astype(np.float32)
        y = (0.4 * rng.normal(size=n)).astype(np.float32)
        k = _gram(x, gamma=0.5)
        c = exp_solver.solve_expectile(
            k, jnp.asarray(y), jnp.asarray([0.2, 0.5, 0.8], jnp.float32),
            jnp.asarray([1e-4, 1e-4, 1e-4], jnp.float32), jnp.float32(n))
        f = np.asarray(k @ c)
        assert np.mean(f[:, 0]) < np.mean(f[:, 1]) < np.mean(f[:, 2])

    def test_irls_stationarity(self):
        """At the IRLS fixed point: K c + lam n W^{-1} c - y = 0 on W(c)."""
        rng = np.random.default_rng(15)
        x = rng.normal(size=(40, 2)).astype(np.float32)
        y = rng.normal(size=40).astype(np.float32)
        k = _gram(x)
        tau, lam = 0.7, 1e-2
        c = exp_solver.solve_expectile(
            k, jnp.asarray(y), jnp.asarray([tau], jnp.float32),
            jnp.asarray([lam], jnp.float32), jnp.float32(40), sweeps=40)[:, 0]
        f = np.asarray(k @ c)
        w = np.where(y - f > 0, tau, 1.0 - tau)
        resid = f + lam * 40.0 * np.asarray(c) / w - y
        assert np.max(np.abs(resid)) < 1e-3
