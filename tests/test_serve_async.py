"""Property-based conformance suite for the async, overlap-routed engine.

Blended decisions and async interleavings are where silent wrongness
hides, so the engine's serving contract is pinned by randomized
properties rather than a handful of fixed examples:

  * **async ≡ sync** — draining the same request batches through the
    double-buffered begin/finish pipeline (admission while a wave is in
    flight) is BITWISE identical to the strictly synchronous
    submit+step() drain: interleaving admission with device work must
    change neither wave composition nor numerics;
  * **overlap blending** — the engine's 2-cell blended decision equals
    the explicit two-cell reference (per-cell
    ``ModelBank.cell_model().decision_function`` weighted by
    :func:`blend_weights`) to f32 tolerance, and is EXACT (bitwise, at
    the padded launch shapes) when the two cells are equidistant;
  * **conservation** — across arbitrary legal interleavings of
    submit / begin_step / finish_step / step / run, every submitted
    request id is returned exactly once: none dropped, none double-served;
  * **tie-breaking** — ``_top2_chunk``'s documented rule (lowest center
    index wins; shared by the overlap cell builder and the engine's
    router) at exactly-equidistant rows, duplicated centers included;
  * **deadline stepper** — with an injected clock, ``run`` launches a
    partially-filled wave exactly when the oldest queued request crosses
    ``deadline_ms``, and fills trigger without a deadline.

Strategies draw a seed (plus small structural knobs) and derive the
request interleavings, batch sizes and deadlines from ``np.random``
— this keeps the suite running under ``tests/_hypothesis_compat``'s
fallback on bare interpreters.  Quick profiles run in tier-1; the large
profiles are marked ``slow``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

from _hypothesis_compat import given, settings, st
from repro.pipeline.assign import _top2_chunk, nearest_top2, nearest_top2_dists
from repro.serve.model_bank import ModelBank
from repro.serve.svm_engine import SVMEngine, blend_weights

QUICK_EXAMPLES = 8
SLOW_EXAMPLES = 40

_BANKS: dict = {}


def _bank(seed: int, n_cells: int = 3, t_count: int = 2, s_count: int = 1,
          routing: str = "overlap"):
    """Small bank + clustered query pool (cached: few jit shapes, fast draws)."""
    key = (seed, n_cells, t_count, s_count, routing)
    if key not in _BANKS:
        k, d = 16, 4
        rng = np.random.default_rng(seed)
        centers = rng.normal(size=(n_cells, d)).astype(np.float32) * 4.0
        sv = (centers[:, None, :]
              + rng.normal(size=(n_cells, k, d))).astype(np.float32)
        coefs = rng.normal(size=(n_cells, k, t_count, s_count)).astype(np.float32)
        gamma = rng.uniform(0.5, 3.0,
                            size=(n_cells, t_count, s_count)).astype(np.float32)
        mask = np.ones((n_cells, k), np.float32)
        bank = ModelBank.from_cells(sv, mask, coefs, gamma, centers,
                                    routing=routing)
        pool = (centers[rng.integers(0, n_cells, 64)]
                + rng.normal(size=(64, d)) * 1.5).astype(np.float32)
        _BANKS[key] = (bank, pool)
    return _BANKS[key]


def _batches(rng: np.random.Generator, pool: np.ndarray, n_batches: int):
    """Random request batches drawn (with replacement) from the query pool."""
    out = []
    for _ in range(n_batches):
        m = int(rng.integers(1, 13))
        out.append(pool[rng.integers(0, pool.shape[0], m)])
    return out


def _sync_drain(eng: SVMEngine, batches):
    results = {}
    for b in batches:
        eng.submit(b)
        results.update(eng.step())
    return results


def _async_drain(eng: SVMEngine, batches):
    """Double-buffered pipeline: wave i is in flight while batch i+1 is
    routed and admitted — same per-wave request sets as the sync drain."""
    results = {}
    for i, b in enumerate(batches):
        eng.submit(b)
        if i > 0:
            results.update(eng.finish_step())   # collect wave i-1 ...
        eng.begin_step()                        # ... dispatch wave i
    results.update(eng.finish_step())
    return results


def _assert_same_results(got: dict, want: dict, exact: bool = True):
    assert sorted(got) == sorted(want)
    for rid in want:
        if exact:
            np.testing.assert_array_equal(got[rid], want[rid])
        else:
            np.testing.assert_allclose(got[rid], want[rid], atol=1e-5)


class TestAsyncConformance:
    """(a) async drain is bitwise-identical to the synchronous drain."""

    @settings(max_examples=QUICK_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 20), overlap=st.booleans())
    def test_async_bitwise_equals_sync_drain(self, seed, overlap):
        bank, pool = _bank(seed % 3)
        rng = np.random.default_rng(seed)
        batches = _batches(rng, pool, int(rng.integers(1, 5)))
        sync = _sync_drain(SVMEngine(bank, fused=False, overlap=overlap),
                           batches)
        awf = _async_drain(SVMEngine(bank, fused=False, overlap=overlap),
                           batches)
        _assert_same_results(awf, sync, exact=True)

    @settings(max_examples=QUICK_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 20))
    def test_submit_while_in_flight_is_not_lost_or_reordered(self, seed):
        """Admission DURING an in-flight wave lands in the next wave and
        serves with the same numerics as a fresh engine serving it alone."""
        bank, pool = _bank(seed % 3)
        rng = np.random.default_rng(seed)
        b0, b1 = _batches(rng, pool, 2)
        eng = SVMEngine(bank, fused=False)
        ids0 = eng.submit(b0)
        eng.begin_step()
        ids1 = eng.submit(b1)          # legal mid-flight
        first = eng.finish_step()
        assert set(first) == set(int(i) for i in ids0)
        second = eng.step()
        assert set(second) == set(int(i) for i in ids1)
        # wave composition for b1 matches a fresh sync engine's first wave
        ref_eng = SVMEngine(bank, fused=False)
        ref_ids = ref_eng.submit(b1)
        ref = ref_eng.step()
        for rid, ref_rid in zip(map(int, ids1), map(int, ref_ids)):
            np.testing.assert_array_equal(second[rid], ref[ref_rid])

    @pytest.mark.slow
    @settings(max_examples=SLOW_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 24), overlap=st.booleans())
    def test_async_bitwise_equals_sync_drain_large_profile(self, seed, overlap):
        bank, pool = _bank(seed % 3)
        rng = np.random.default_rng(seed)
        batches = _batches(rng, pool, int(rng.integers(1, 7)))
        sync = _sync_drain(SVMEngine(bank, fused=False, overlap=overlap),
                           batches)
        awf = _async_drain(SVMEngine(bank, fused=False, overlap=overlap),
                           batches)
        _assert_same_results(awf, sync, exact=True)


class TestOverlapBlending:
    """(b) overlap blending equals the explicit two-cell reference."""

    @settings(max_examples=QUICK_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 20))
    def test_blend_matches_two_cell_decision_function(self, seed):
        bank, pool = _bank(seed % 3)
        rng = np.random.default_rng(seed)
        q = pool[rng.integers(0, pool.shape[0], int(rng.integers(2, 16)))]
        eng = SVMEngine(bank, fused=False)
        assert eng.overlap                     # bank records routing=overlap
        dec = eng.predict(q)
        c1, c2, d1, d2 = nearest_top2_dists(q, np.asarray(bank.centers))
        w1, w2 = blend_weights(d1, d2)
        for i in range(q.shape[0]):
            a = np.asarray(bank.cell_model(int(c1[i]))
                           .decision_function(jnp.asarray(q[i:i + 1])))[0]
            b = np.asarray(bank.cell_model(int(c2[i]))
                           .decision_function(jnp.asarray(q[i:i + 1])))[0]
            np.testing.assert_allclose(dec[i], w1[i] * a + w2[i] * b,
                                       atol=1e-5)

    def test_equal_weights_exact(self):
        """Duplicated centers: every query is exactly equidistant, weights
        are exactly (0.5, 0.5), and the blend is BITWISE 0.5*(a + b) of the
        per-cell decisions at the engine's own padded launch shapes."""
        rng = np.random.default_rng(7)
        k, d, p = 16, 4, 2
        center = rng.normal(size=(1, d)).astype(np.float32)
        centers = np.repeat(center, 2, axis=0)          # identical pair
        sv = rng.normal(size=(2, k, d)).astype(np.float32) + center
        coefs = rng.normal(size=(2, k, p, 1)).astype(np.float32)
        gamma = rng.uniform(0.5, 2.0, size=(2, p, 1)).astype(np.float32)
        mask = np.ones((2, k), np.float32)
        bank = ModelBank.from_cells(sv, mask, coefs, gamma, centers,
                                    routing="overlap")
        m = 8                                           # == one padded slot
        q = (center + rng.normal(size=(m, d))).astype(np.float32)

        eng = SVMEngine(bank, fused=False, row_bucket=8)
        dec = eng.predict(q)
        assert eng.counters["steps"] == 1               # both parts, one wave
        c1, c2, d1, d2 = nearest_top2_dists(q, centers)
        assert (d1 == d2).all() and (c1 == 0).all() and (c2 == 1).all()
        w1, w2 = blend_weights(d1, d2)
        assert (w1 == np.float32(0.5)).all() and (w2 == np.float32(0.5)).all()
        # per-cell reference at the SAME padded shape (m == m_pad == 8)
        ref0 = np.asarray(bank.cell_model(0).decision_function(jnp.asarray(q)))
        ref1 = np.asarray(bank.cell_model(1).decision_function(jnp.asarray(q)))
        want = np.float32(0.5) * ref0 + np.float32(0.5) * ref1
        np.testing.assert_array_equal(dec, want)        # bitwise

    def test_nearest_bank_serves_exact_1nn(self):
        """voronoi<5 banks record routing=nearest: the engine must fall
        back to the old single-cell path bitwise, no blending."""
        bank_o, pool = _bank(5, routing="overlap")
        import dataclasses
        bank_n = dataclasses.replace(bank_o, routing="nearest")
        # near-boundary queries: midpoints of center pairs (+ tiny noise),
        # so the second cell's blend weight cannot underflow to zero
        rng = np.random.default_rng(5)
        c = np.asarray(bank_o.centers)
        q = np.concatenate([
            (c[[0]] + c[[1]]) / 2, (c[[1]] + c[[2]]) / 2,
            (c[[0]] + c[[2]]) / 2,
        ]) + rng.normal(size=(3, c.shape[1])).astype(np.float32) * 0.05
        q = q.astype(np.float32)
        eng = SVMEngine(bank_n, fused=False)
        assert not eng.overlap
        dec = eng.predict(q)
        ref = SVMEngine(bank_o, fused=False, overlap=False).predict(q)
        np.testing.assert_array_equal(dec, ref)
        # and it differs from the blended path (the blend is real)
        blended = SVMEngine(bank_o, fused=False).predict(q)
        assert np.abs(blended - dec).max() > 0

    def test_single_cell_bank_falls_back_to_1nn(self):
        bank, pool = _bank(6, n_cells=1, routing="nearest")
        import dataclasses
        eng = SVMEngine(dataclasses.replace(bank, routing="overlap"),
                        fused=False)
        assert not eng.overlap                 # no second center to blend
        dec = eng.predict(pool[:4])
        assert np.isfinite(dec).all()


class TestConservation:
    """(c) no request is ever dropped or double-served."""

    @settings(max_examples=QUICK_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 20), overlap=st.booleans())
    def test_every_request_served_exactly_once(self, seed, overlap):
        bank, pool = _bank(seed % 3)
        rng = np.random.default_rng(seed)
        eng = SVMEngine(bank, fused=False, overlap=overlap)
        submitted: set = set()
        served: list = []
        for _ in range(int(rng.integers(4, 16))):
            op = rng.integers(0, 4)
            if op == 0:                                    # submit a batch
                b = pool[rng.integers(0, pool.shape[0], int(rng.integers(1, 9)))]
                submitted.update(int(i) for i in eng.submit(b))
            elif op == 1 and not eng.in_flight:            # dispatch
                eng.begin_step()
            elif op == 2:                                  # collect
                served.extend(eng.finish_step())
            else:                                          # sync step
                served.extend(eng.step())
        while eng.pending or eng.in_flight:                # drain
            served.extend(eng.step())
        assert len(served) == len(set(served))             # never double-served
        assert set(served) == submitted                    # never dropped
        assert eng.counters["served"] == eng.counters["submitted"]

    @settings(max_examples=QUICK_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 20), deadline_ms=st.floats(1.0, 50.0))
    def test_run_conserves_requests_under_deadlines(self, seed, deadline_ms):
        """The latency-bounded stepper serves everything exactly once for
        any arrival pattern / deadline combination (fake clock)."""
        bank, pool = _bank(seed % 3)
        rng = np.random.default_rng(seed)
        clk = [0.0]
        eng = SVMEngine(bank, fused=False, deadline_ms=deadline_ms,
                        clock=lambda: clk[0])
        n_events = int(rng.integers(3, 12))
        expect = 0

        def traffic():
            nonlocal expect
            for _ in range(n_events):
                clk[0] += float(rng.uniform(0.0, 0.02))    # 0-20 ms per tick
                if rng.random() < 0.7:
                    b = pool[rng.integers(0, pool.shape[0],
                                          int(rng.integers(1, 9)))]
                    expect += b.shape[0]
                    yield b
                else:
                    yield None                             # idle tick

        results = eng.run(traffic())
        assert len(results) == expect
        assert sorted(results) == list(range(expect))      # ids 0..n-1, once
        assert eng.pending == 0 and not eng.in_flight


class TestSwapConservation:
    """Conservation must SPAN a mid-run hot swap: no request dropped or
    double-served, and every response attributable to exactly one bank
    version whose engine would have produced the same decision."""

    @settings(max_examples=QUICK_EXAMPLES, deadline=None)
    @given(seed=st.integers(0, 2 ** 20), overlap=st.booleans())
    def test_swap_mid_run_serves_every_request_exactly_once(self, seed,
                                                           overlap):
        import dataclasses
        bank0, pool = _bank(seed % 3)
        banks = {
            0: bank0,
            1: dataclasses.replace(bank0, coefs=-bank0.coefs, version=1),
            2: dataclasses.replace(bank0, coefs=2.0 * bank0.coefs, version=2),
        }
        rng = np.random.default_rng(seed)
        eng = SVMEngine(bank0, fused=False, overlap=overlap)
        submitted: dict = {}                       # rid -> raw row
        served: dict = {}
        next_v = 1
        for _ in range(int(rng.integers(6, 20))):
            op = rng.integers(0, 5)
            if op == 0:                                    # admit a batch
                b = pool[rng.integers(0, pool.shape[0],
                                      int(rng.integers(1, 9)))]
                for i, rid in enumerate(map(int, eng.submit(b))):
                    submitted[rid] = b[i]
            elif op == 1 and not eng.in_flight:            # dispatch
                eng.begin_step()
            elif op == 2:                                  # collect
                served.update(eng.finish_step())
            elif op == 3 and next_v <= 2:                  # hot swap (legal
                eng.swap_bank(banks[next_v])               # mid-flight too)
                next_v += 1
            else:                                          # sync step
                served.update(eng.step())
        while eng.pending or eng.in_flight:                # drain
            served.update(eng.step())

        assert set(served) == set(submitted)               # exactly once
        assert eng.counters["served"] == eng.counters["submitted"]

        # every response attributed to exactly one bank version, and the
        # per-version counters account for every completion
        by_v: dict = {}
        for rid in served:
            v = eng.served_version[rid]
            assert v in banks
            by_v.setdefault(v, []).append(rid)
        assert sum(eng.counters.get(f"served_v{v}", 0)
                   for v in banks) == len(served)

        # correctness per version: a fresh engine on the attributed bank
        # must reproduce the decision for that request's row
        for v, rids in sorted(by_v.items()):
            ref = SVMEngine(banks[v], fused=False, overlap=overlap)
            want = ref.predict(np.stack([submitted[r] for r in rids]))
            for j, r in enumerate(rids):
                np.testing.assert_allclose(served[r], want[j], atol=1e-5)


class TestTop2TieBreak:
    """Satellite: the documented tie-break at exactly-equidistant rows."""

    def test_duplicated_centers_deterministic_pair(self):
        rng = np.random.default_rng(11)
        c = rng.normal(size=(1, 3)).astype(np.float32)
        centers = np.concatenate([c, c, c + 10.0])         # dup at 0 and 1
        x = (c + rng.normal(size=(9, 3))).astype(np.float32)
        nn1, nn2, d1, d2 = _top2_chunk(x.copy(), centers)
        assert (nn1 == 0).all() and (nn2 == 1).all()       # lowest index wins
        np.testing.assert_array_equal(d1, d2)              # exactly tied
        # chunking cannot change the rule
        s1, s2 = nearest_top2(x, centers, chunk_size=2)
        np.testing.assert_array_equal(s1, nn1)
        np.testing.assert_array_equal(s2, nn2)

    def test_geometrically_equidistant_row(self):
        centers = np.asarray([[-1.0, 0.0], [1.0, 0.0], [0.0, 9.0]],
                             np.float32)
        x = np.asarray([[0.0, 0.5], [0.0, -2.0]], np.float32)  # on the bisector
        nn1, nn2, d1, d2 = nearest_top2_dists(x, centers)
        assert (nn1 == 0).all() and (nn2 == 1).all()
        np.testing.assert_array_equal(d1, d2)
        w1, w2 = blend_weights(d1, d2)
        assert (w1 == np.float32(0.5)).all() and (w2 == np.float32(0.5)).all()

    def test_engine_router_shares_the_assign_code_path(self):
        """route_top2 must agree with pipeline.assign.nearest_top2_dists on
        the bank's own centers — same ids, same distances, same weights."""
        bank, pool = _bank(12)
        eng = SVMEngine(bank, fused=False)
        xs = (pool[:16] - bank.feat_mean) / bank.feat_std
        c1, c2, w1, w2 = eng.route_top2(xs)
        r1, r2, rd1, rd2 = nearest_top2_dists(xs, np.asarray(bank.centers))
        np.testing.assert_array_equal(c1, r1.astype(np.int64))
        np.testing.assert_array_equal(c2, r2.astype(np.int64))
        e1, e2 = blend_weights(rd1, rd2)
        np.testing.assert_array_equal(w1, e1)
        np.testing.assert_array_equal(w2, e2)


class TestDeadlineStepper:
    def test_deadline_forces_partial_launch(self):
        bank, pool = _bank(13)
        clk = [0.0]
        eng = SVMEngine(bank, fused=False, deadline_ms=5.0,
                        clock=lambda: clk[0])

        def traffic():
            yield pool[:3]                     # far below fill_rows
            clk[0] += 0.004
            yield None                         # 4 ms: hold
            assert eng.stats().get("waves", 0) == 0
            clk[0] += 0.002
            yield None                         # 6 ms: deadline launch

        results = eng.run(traffic())
        assert len(results) == 3
        stats = eng.stats()
        assert stats["waves"] == 1
        assert stats["age_ms_max"] >= 5.0
        assert 0.0 < stats["occupancy_mean"] < 1.0
        assert sum(stats["age_hist"]) == eng.counters["served_rows"]

    def test_fill_forces_launch_without_deadline(self):
        bank, pool = _bank(13)
        eng = SVMEngine(bank, fused=False, fill_rows=16)

        def traffic():
            yield pool[:4]
            assert eng.stats().get("waves", 0) == 0    # 4 or 8 rows < 16
            yield pool[4:24]                           # fills

        results = eng.run(traffic())
        assert len(results) == 24
        assert eng.stats()["waves"] >= 1

    def test_wave_stats_schema(self):
        bank, pool = _bank(13)
        eng = SVMEngine(bank, fused=False)
        eng.submit(pool[:10])
        eng.step()
        (w,) = eng.wave_stats
        assert set(w) == {"wave", "n_rows", "n_slots", "m_pad", "occupancy",
                          "oldest_ms", "age_ms_mean", "age_hist",
                          "pack_ms", "dispatch_ms", "device_ms",
                          "collect_ms"}
        assert w["n_rows"] == sum(w["age_hist"])
        assert 0.0 < w["occupancy"] <= 1.0
        assert w["wave"] == 0
        for stage in ("pack_ms", "dispatch_ms", "device_ms", "collect_ms"):
            assert w[stage] >= 0.0

    def test_every_served_response_has_a_breakdown(self):
        bank, pool = _bank(13)
        eng = SVMEngine(bank, fused=False)
        ids = eng.submit(pool[:10])
        results = eng.step()
        assert set(results) == set(int(i) for i in ids)
        for rid in results:
            b = eng.breakdown(rid)
            assert b is not None
            assert set(b) == {"wave", "total_ms", "queue_ms", "pack_ms",
                              "dispatch_ms", "device_ms", "collect_ms"}
            # the decomposition is exact: stages sum to the total
            parts = (b["queue_ms"] + b["pack_ms"] + b["dispatch_ms"]
                     + b["device_ms"] + b["collect_ms"])
            assert parts == pytest.approx(b["total_ms"], abs=1e-6)
        assert eng.breakdown(10 ** 9) is None
