"""Per-architecture smoke tests: reduced same-family config, one forward +
one train-grad step on CPU, asserting output shapes and finiteness.
The FULL configs are exercised only via the dry-run (no allocation here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models import model as model_mod
from repro.models.layers import init_params, param_count
from repro.serve.kv_cache import pad_cache


def _batch(cfg, b=2, t=32, key=jax.random.PRNGKey(0)):
    if cfg.input_kind == "tokens":
        x = jax.random.randint(key, (b, t), 0, cfg.vocab)
    else:
        x = jax.random.normal(key, (b, t, cfg.d_frontend), jnp.float32)
    labels = jax.random.randint(key, (b, t), 0, cfg.vocab)
    return {"inputs": x, "labels": labels}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
class TestArchSmoke:
    def test_forward_loss_finite(self, arch_id):
        spec = get_arch(arch_id)
        cfg = spec.smoke
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(1))
        batch = _batch(cfg)
        loss = model_mod.loss_fn(cfg, params, batch)
        assert loss.shape == ()
        assert np.isfinite(float(loss))
        # at init, loss should be near ln(vocab) (uniform predictions)
        assert abs(float(loss) - np.log(cfg.vocab)) < 2.0

    def test_train_grad_step(self, arch_id):
        spec = get_arch(arch_id)
        cfg = spec.smoke
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(2))
        batch = _batch(cfg)
        loss, grads = jax.value_and_grad(
            lambda p: model_mod.loss_fn(cfg, p, batch))(params)
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(g)).all() for g in leaves)
        # a plain SGD step reduces the loss
        params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                               params, grads)
        loss2 = model_mod.loss_fn(cfg, params2, batch)
        assert float(loss2) < float(loss)

    def test_full_config_dims_match_assignment(self, arch_id):
        """The CONFIG must carry the exact assigned dimensions."""
        expected = {
            "rwkv6-1.6b": (24, 2048, 7168, 65536),
            "stablelm-12b": (40, 5120, 13824, 100352),
            "gemma3-4b": (34, 2560, 10240, 262144),
            "command-r-plus-104b": (64, 12288, 33792, 256000),
            "stablelm-1.6b": (24, 2048, 5632, 100352),
            "internvl2-76b": (80, 8192, 28672, 128256),
            "hubert-xlarge": (48, 1280, 5120, 504),
            "qwen3-moe-235b-a22b": (94, 4096, 1536, 151936),
            "llama4-maverick-400b-a17b": (48, 5120, 8192, 202048),
            "jamba-v0.1-52b": (32, 4096, 14336, 65536),
        }[arch_id]
        cfg = get_arch(arch_id).config
        assert (cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab) == expected
        # pattern consistency: layers = periods * len(pattern) + tail
        assert cfg.n_periods * cfg.period + cfg.tail == cfg.n_layers
        assert cfg.tail < cfg.period or cfg.period == 1


class TestDecodePaths:
    @pytest.mark.parametrize("arch_id", [a for a in ARCH_IDS
                                         if a != "hubert-xlarge"])
    def test_prefill_decode_consistency(self, arch_id):
        """prefill(T) + decode(token T) == prefill(T+1) last logits."""
        spec = get_arch(arch_id)
        import dataclasses
        # drop-free MoE capacity: token dropping legitimately differs between
        # prefill (tokens compete per chunk) and decode (one token) — that is
        # capacity-factor semantics, not a bug; test the exact math instead.
        kw = {}
        if spec.smoke.n_experts:
            kw["moe_capacity_factor"] = float(spec.smoke.n_experts
                                              / max(spec.smoke.top_k, 1))
        cfg = dataclasses.replace(spec.smoke, dtype=jnp.float32, **kw)
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(3))
        b, t = 2, 12
        key = jax.random.PRNGKey(4)
        if cfg.input_kind == "tokens":
            x = jax.random.randint(key, (b, t + 1), 0, cfg.vocab)
        else:
            x = jax.random.normal(key, (b, t + 1, cfg.d_frontend), jnp.float32)
        ref, _ = model_mod.prefill(cfg, params, x)
        _, cache = model_mod.prefill(cfg, params, x[:, :t])
        cache = pad_cache(cfg, cache, t + 4)
        got, new_cache = model_mod.decode_step(cfg, params, x[:, t:t + 1],
                                               cache, jnp.int32(t))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   atol=2e-3, rtol=1e-3)
        # cache pytree keeps its structure
        assert jax.tree.structure(new_cache) == jax.tree.structure(cache)

    def test_hubert_encode(self):
        spec = get_arch("hubert-xlarge")
        cfg = spec.smoke
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(5))
        x = jax.random.normal(jax.random.PRNGKey(6), (2, 16, cfg.d_frontend))
        logits = model_mod.encode(cfg, params, x)
        assert logits.shape == (2, 16, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()


class TestGenerate:
    def test_greedy_generation_runs(self):
        from repro.serve.engine import generate
        spec = get_arch("stablelm-1.6b")
        cfg = spec.smoke
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(7))
        prompt = jax.random.randint(jax.random.PRNGKey(8), (2, 8), 0, cfg.vocab)
        out = generate(cfg, params, prompt, max_new_tokens=6)
        assert out.shape == (2, 14)
        assert (np.asarray(out[:, :8]) == np.asarray(prompt)).all()

    def test_generate_matches_rerun_prefill(self):
        """Greedy decode token-by-token == greedy re-prefill at every step."""
        import dataclasses
        from repro.serve.engine import generate
        spec = get_arch("rwkv6-1.6b")
        cfg = dataclasses.replace(spec.smoke, dtype=jnp.float32)
        params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(9))
        prompt = jax.random.randint(jax.random.PRNGKey(10), (1, 6), 0, cfg.vocab)
        out = np.asarray(generate(cfg, params, prompt, max_new_tokens=4))
        cur = prompt
        for _ in range(4):
            logits, _ = model_mod.prefill(cfg, params, cur)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            cur = jnp.concatenate([cur, nxt], axis=1)
        np.testing.assert_array_equal(out, np.asarray(cur))
