"""Out-of-core training demo: memmap ingestion -> streaming cells ->
wave-scheduled training -> serving bank.

    PYTHONPATH=src python examples/bigdata_train.py [--n 200000]

The training matrix is written to an on-disk ``.npy`` in chunks and never
loaded whole: scaling statistics stream (`Scaler.fit_stream`), Voronoi
cells are built by the two-pass streaming builder (O(chunk · C) peak, not
(n, C)), and the cell solves run in bounded WAVES of packed slots with a
per-wave checkpoint — kill the process mid-fit and a re-run resumes at
the first unfinished wave.  The fitted model hands off to the serving
engine via ``to_bank()`` exactly like an in-memory fit.
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.data.synthetic import covtype_like
from repro.serve.svm_engine import SVMEngine
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig

CHUNK = 16384


def write_memmap_dataset(path, n, d=6, seed=0):
    """Stream a synthetic covtype-like problem to disk in chunks."""
    mm = np.lib.format.open_memmap(path, mode="w+", dtype=np.float32,
                                   shape=(n, d))
    labels = np.empty(n, np.float32)
    for lo in range(0, n, CHUNK):
        hi = min(lo + CHUNK, n)
        # covtype_like rounds n down to its mixture count: over-request + slice
        xc, yc = covtype_like(n=hi - lo + 6, d=d, seed=seed + lo,
                              label_noise=0.02, n_modes=3)
        mm[lo:hi] = xc[: hi - lo]
        labels[lo:hi] = np.where(yc[: hi - lo] == 0, -1, 1)
    mm.flush()
    del mm
    return labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200_000)
    ap.add_argument("--d", type=int, default=6)
    ap.add_argument("--cell-size", type=int, default=2000)
    ap.add_argument("--wave", type=int, default=16,
                    help="packed cell slots staged+solved per wave")
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as tmp:
        path = os.path.join(tmp, "train.npy")
        print(f"== write {args.n}x{args.d} memmap dataset ==")
        y = write_memmap_dataset(path, args.n, args.d)

        cfg = SVMTrainerConfig(
            cell_method="voronoi", cell_size=args.cell_size,
            n_folds=3, max_iters=200,
            n_slots_per_wave=args.wave, chunk_size=CHUNK)
        ckpt = os.path.join(tmp, "waves")

        print(f"== fit from memmap source, waves of {args.wave} slots ==")
        t0 = time.time()
        est = LiquidSVM(cfg).fit(path, y, ckpt_dir=ckpt)   # path IS the source
        n_waves = len([d_ for d_ in os.listdir(ckpt) if d_.startswith("step_")])
        print(f"fit: {time.time() - t0:.1f}s  cells={est.plan.n_cells} "
              f"k_max={est.plan.k_max} waves={n_waves} (checkpointed)")

        print("== hand off to serving bank ==")
        bank = est.to_bank()
        s = bank.stats()
        print(f"bank: {s['n_cells']} cells, SVs {s['sv_raw']} -> {s['sv_live']}"
              f" (compaction {s['compaction']:.2f})")

        eng = SVMEngine(bank)
        # evaluate on a sample of the on-disk rows (each chunk is its own
        # mixture, so only the dataset itself is in-distribution)
        ids = np.random.default_rng(1).choice(args.n, 2000, replace=False)
        q = np.asarray(np.load(path, mmap_mode="r")[np.sort(ids)])
        pred = eng.predict_label(q)
        err = float((pred != y[np.sort(ids)]).mean())
        print(f"served 2000 queries, train-sample error={err:.3f}  "
              f"stats={eng.stats()}")


if __name__ == "__main__":
    main()
