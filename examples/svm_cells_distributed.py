"""Distributed cell training on a (simulated) multi-device mesh.

    PYTHONPATH=src python examples/svm_cells_distributed.py

The paper's Table-4 Spark layer on the TPU stack: coarse Voronoi cells ->
fine cells -> bin-packed slots -> shard_map over the mesh.  This script
forces 8 host devices (it owns its process) so the sharding is real.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time

import jax
import numpy as np

from repro.data.synthetic import covtype_like, train_test_split
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig


def main():
    print(f"devices: {len(jax.devices())}")
    x, yc = covtype_like(n=6000, d=8, seed=0, label_noise=0.08)
    y = np.where(yc == 0, -1, 1)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.2, 0)

    cfg = SVMTrainerConfig(cell_method="coarse_fine", cell_size=300,
                           n_folds=3, max_iters=300)

    t0 = time.time()
    local = LiquidSVM(cfg).fit(xtr, ytr)
    t_local = time.time() - t0
    e_local = local.error(xte, yte)

    mesh = jax.make_mesh((8,), ("data",))
    t0 = time.time()
    dist = LiquidSVM(cfg, mesh=mesh, mesh_axes=("data",)).fit(xtr, ytr)
    t_dist = time.time() - t0
    e_dist = dist.error(xte, yte)

    print(f"cells: {dist.plan.n_cells} fine "
          f"({dist.plan.coarse_of.max() + 1} coarse groups)")
    print(f"single device : {t_local:6.1f}s  err {100 * e_local:.2f}%")
    print(f"8-device mesh : {t_dist:6.1f}s  err {100 * e_dist:.2f}%")
    print("errors match:", abs(e_local - e_dist) < 0.02,
          "(the Spark shuffle, statically scheduled)")


if __name__ == "__main__":
    main()
