"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart, on the real (single-CPU here, mesh at scale) runtime.

    PYTHONPATH=src python examples/train_lm_e2e.py             # ~100M, 300 steps
    PYTHONPATH=src python examples/train_lm_e2e.py --preset small --steps 60

The model is the stablelm family block at reduced width; everything else
is the production path: AdamW policy, cosine schedule, grad accumulation,
atomic checkpoints, deterministic data replay.
"""
import argparse
import json
import os

import jax.numpy as jnp

from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models.model import ModelConfig
from repro.train.lm_trainer import Trainer, TrainLoopConfig
from repro.train.optimizer import OptConfig

PRESETS = {
    # ~101M params: 12L x d512 x ff2048, vocab 32768
    "100m": dict(n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab=32768, batch=8, seq=256),
    # ~8M: for CI-speed runs
    "small": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                  head_dim=32, d_ff=512, vocab=2048, batch=8, seq=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--grad-accum", type=int, default=1)
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"lm-{args.preset}", n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], n_kv_heads=p["n_kv_heads"], head_dim=p["head_dim"],
        d_ff=p["d_ff"], vocab=p["vocab"],
        period_pattern=(("attn", "dense"),), rotary_frac=0.25,
        norm="layernorm", act="silu", dtype=jnp.float32, remat=False,
        ce_chunk=128)
    print(f"model: {cfg.param_count() / 1e6:.1f}M params")

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=p["seq"], global_batch=p["batch"], seed=0))
    trainer = Trainer(
        cfg,
        OptConfig(lr=1e-3, warmup_steps=max(args.steps // 20, 5),
                  total_steps=args.steps),
        TrainLoopConfig(total_steps=args.steps, grad_accum=args.grad_accum,
                        ckpt_every=max(args.steps // 4, 10),
                        ckpt_dir=args.ckpt_dir, log_every=10),
        pipe)
    out = trainer.run()
    for h in out["history"]:
        print(json.dumps(h))
    first, last = out["history"][0]["loss"], out["history"][-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {args.steps} steps "
          f"({out['wall_s']:.0f}s); checkpoints in {args.ckpt_dir}")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
