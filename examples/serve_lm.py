"""Batched serving demo: prefill + autoregressive decode with a KV cache.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --batch 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.serve.engine import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    if not cfg.is_decoder:
        print(f"{args.arch} is encoder-only — no decode path (by design)")
        return
    params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(cfg, params, prompt, max_new_tokens=args.new,
                   temperature=0.8, seed=2)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced config) batch={args.batch}")
    print(f"generated {args.batch}x{args.new} tokens in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s incl. prefill)")
    print("sample row:", np.asarray(out[0, -args.new:]).tolist()[:16], "...")


if __name__ == "__main__":
    main()
