"""Batched LM serving demos: generation, and co-located embed->SVM serving.

    PYTHONPATH=src python examples/serve_lm.py --arch gemma3-4b --batch 4
    PYTHONPATH=src python examples/serve_lm.py --svm-head   # EmbedServe demo

The default path is prefill + autoregressive decode with a KV cache.  With
``--svm-head`` the serving half flips to the embedding vertical: a tiny
SVM bank is trained over frozen-backbone embeddings, then token requests
are served through :class:`repro.serve.EmbedServe` — backbone forward and
cell-routed SVM evaluation co-located in one process, with the per-request
latency breakdown growing an ``embed_ms`` stage.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.serve.engine import generate


def svm_head_demo(arch: str) -> None:
    """Token requests -> embed -> route -> blend, one process."""
    import os
    import sys
    from repro.api.session import SVM
    from repro.embed import EmbeddingExtractor, EmbeddingSource, resolve_arch
    from repro.serve import EmbedServe, SVMEngine
    sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
    from lm_svm_head import token_domains

    cfg = resolve_arch(f"{arch}:smoke")
    tok, y = token_domains(cfg, n_per_class=200, seq=24, n_classes=2)
    y = np.where(y > 0, 1.0, -1.0)
    extractor = EmbeddingExtractor(cfg, pooling="mean", batch_size=64,
                                   seed=0)
    xs = EmbeddingSource(tok, extractor, labels=y)
    bank = SVM(xs, FOLDS=2, MAX_ITERATIONS=200, CELL_SIZE=120) \
        .train().select().to_bank()

    serve = EmbedServe(SVMEngine(bank, deadline_ms=5.0), extractor)
    rng = np.random.default_rng(3)
    queries = tok[rng.integers(0, len(tok), 64)]
    t0 = time.time()
    results = serve.run_tokens(queries[i:i + 16] for i in range(0, 64, 16))
    dt = time.time() - t0
    rid = sorted(results)[0]
    b = serve.breakdown(rid)
    stages = {k: v for k, v in b.items() if k.endswith("_ms")
              and k != "total_ms"}
    assert abs(sum(stages.values()) - b["total_ms"]) < 1e-6
    print(f"arch={arch} (reduced config) embed->route->blend co-located")
    print(f"served {len(results)} token requests in {dt:.2f}s "
          f"({len(results) / dt:.1f} rps)")
    print(f"request {rid} breakdown (ms): " + ", ".join(
        f"{k[:-3]}={v:.3f}" for k, v in b.items() if k.endswith("_ms")))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new", type=int, default=24)
    ap.add_argument("--svm-head", action="store_true",
                    help="serve token requests through the co-located "
                         "embed->SVM engine (EmbedServe) instead of "
                         "autoregressive generation")
    args = ap.parse_args()

    if args.svm_head:
        svm_head_demo(args.arch)
        return

    cfg = get_arch(args.arch).smoke
    if not cfg.is_decoder:
        print(f"{args.arch} is encoder-only — no decode path (by design)")
        return
    params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(cfg, params, prompt, max_new_tokens=args.new,
                   temperature=0.8, seed=2)
    dt = time.time() - t0
    print(f"arch={args.arch} (reduced config) batch={args.batch}")
    print(f"generated {args.batch}x{args.new} tokens in {dt:.2f}s "
          f"({args.batch * args.new / dt:.1f} tok/s incl. prefill)")
    print("sample row:", np.asarray(out[0, -args.new:]).tolist()[:16], "...")


if __name__ == "__main__":
    main()
