"""Quickstart: the staged liquidSVM application cycle in a few lines.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the package's user surface (paper §2-3): scenario front-ends
(`mcSVM`, `qtSVM`, `nplSVM`, `rocSVM`, ...) over one staged
train -> select -> test cycle.  `train()` solves the fold x grid ONCE and
retains the CV surface; `select()` is re-runnable with different criteria
(argmin, Neyman-Pearson constraints, ROC fronts) at the cost of one
targeted wave — never a refit; `test()` streams errors over arrays, memmap
paths or any chunk source.

The same cycle runs as separate processes through the CLI:

    python -m repro.cli train  --data xtr.npy --labels ytr.npy \\
        --model-dir run1 --scenario npl -S FOLDS=3 -S VORONOI=voronoi
    python -m repro.cli select --model-dir run1 -S NPL_CONSTRAINT=0.01
    python -m repro.cli test   --data xte.npy --labels yte.npy --model-dir run1

after which a predict server cold-starts from `run1/bank` alone
(see examples/serve_svm.py).
"""
import numpy as np

from repro.api import SVM, mcSVM, nplSVM, qtSVM, rocSVM
from repro.data.synthetic import banana_mc, regression_1d, train_test_split


def main():
    # ---- multiclass classification (OvA, staged cycle) -------------------
    x, y = banana_mc(n=1600, n_classes=4, seed=0)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    mc = mcSVM(xtr, ytr, FOLDS=3, MAX_ITERATIONS=400)
    mc.train()                                   # fold x grid, surface kept
    res = mc.test(xte, yte)                      # selects (argmin) + streams
    print(f"mcSVM      test error: {100 * res.error:.2f}% "
          f"(4 classes, n={len(xtr)})")

    # ---- quantile regression (pinball solver, 3 quantiles) ---------------
    xq, yq = regression_1d(n=900, seed=1)
    xtr, ytr, xte, yte = train_test_split(xq, yq, 0.25, 1)
    qt = qtSVM(xtr, ytr, taus=(0.1, 0.5, 0.9), FOLDS=3,
               MAX_ITERATIONS=1500)
    qt.train()
    pred = qt.select().predict(xte)              # (m, 3)
    cover = (yte[:, None] <= pred).mean(0)
    print(f"qtSVM      coverage @ tau=0.1/0.5/0.9: "
          f"{cover[0]:.2f}/{cover[1]:.2f}/{cover[2]:.2f}")

    # ---- re-runnable selection: NPL constraints + ROC front --------------
    big_x, big_y = banana_mc(n=3000, n_classes=2, seed=2)
    xtr, ytr, xte, yte = train_test_split(big_x, np.where(big_y == 0, -1, 1),
                                          0.25, 2)
    npl = nplSVM(xtr, ytr, constraint=0.05, FOLDS=3, MAX_ITERATIONS=400,
                 VORONOI="voronoi", CELL_SIZE=500)
    tr = npl.train()                             # ONE training sweep ...
    for alpha in (0.1, 0.05, 0.01):              # ... many selections
        sel = npl.select(alpha=alpha)
        t = sel.test(xte, yte)
        print(f"nplSVM     alpha={alpha:<5} validation FA="
              f"{float(sel.extras['np_fa'][0, sel.default_sub]):.3f} "
              f"test FA={t.details['false_alarm']:.3f} "
              f"detection={t.details['detection']:.3f} "
              f"(re-solved {sel.stats['columns_resolved']} of "
              f"{sel.stats['grid_columns']} columns)")

    # the ROC weight front needs ITS own weight grid -> its own session
    roc = rocSVM(xtr, ytr, weight_steps=5, FOLDS=3, MAX_ITERATIONS=400,
                 VORONOI="voronoi", CELL_SIZE=500)
    roc.train()
    front = np.asarray(roc.select().extras["roc_front"])[0]  # (S, 2)
    pts = " ".join(f"({fa:.3f},{det:.3f})" for fa, det in front)
    print(f"rocSVM     (FA, detection) front: {pts}")

    # ---- low-level staged session + serving hand-off ----------------------
    sess = SVM(xtr, ytr, scenario="binary", FOLDS=3, MAX_ITERATIONS=400,
               VORONOI="voronoi", CELL_SIZE=500)
    sess.train()
    bank = sess.select().to_bank()               # -> serve.SVMEngine(bank)
    print(f"bank       {bank.stats()['sv_live']} SVs over "
          f"{bank.n_cells} cells "
          f"({100 * bank.stats()['compaction']:.0f}% of raw rows kept)")

    # ---- LM-embedding vertical -------------------------------------------
    # Token corpora train the same way through the frozen-backbone
    # embedding pipeline (repro.embed): pass EMBED_ARCH to any front-end
    # (x then holds tokens, not features), or see examples/lm_svm_head.py
    # for the full EmbeddingSource + EmbedCache + EmbedServe composition:
    #   SVM(tokens, y, EMBED_ARCH="stablelm-1.6b:smoke", FOLDS=3).train()
    print("embed      token corpora: see examples/lm_svm_head.py and "
          "examples/serve_lm.py --svm-head")


if __name__ == "__main__":
    main()
