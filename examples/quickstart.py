"""Quickstart: the liquidSVM application cycle in a few lines.

    PYTHONPATH=src python examples/quickstart.py

Mirrors the package's R demo (`mcSVM(Y ~ ., d$train)` on banana-mc):
multiclass classification with fully integrated hyper-parameter selection,
then quantile regression — no hyper-parameters supplied by the user.
"""
import numpy as np

from repro.data.synthetic import banana_mc, regression_1d, train_test_split
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig


def main():
    # ---- multiclass classification (OvA, hinge solver, 5-fold CV) --------
    x, y = banana_mc(n=1600, n_classes=4, seed=0)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)
    model = LiquidSVM(SVMTrainerConfig(scenario="ova", n_folds=3,
                                       max_iters=400))
    model.fit(xtr, ytr)
    print(f"banana-mc  test error: {100 * model.error(xte, yte):.2f}% "
          f"(4 classes, n={len(xtr)})")

    # ---- quantile regression (pinball solver, 3 quantiles) ---------------
    xq, yq = regression_1d(n=900, seed=1)
    xtr, ytr, xte, yte = train_test_split(xq, yq, 0.25, 1)
    qm = LiquidSVM(SVMTrainerConfig(scenario="quantile",
                                    taus=(0.1, 0.5, 0.9), n_folds=3,
                                    max_iters=1500))
    qm.fit(xtr, ytr)
    pred = qm.predict(xte)                       # (m, 3)
    cover = (yte[:, None] <= pred).mean(0)
    print(f"quantile   coverage @ tau=0.1/0.5/0.9: "
          f"{cover[0]:.2f}/{cover[1]:.2f}/{cover[2]:.2f}")

    # ---- cells: same API, two orders less kernel work ---------------------
    big_x, big_y = banana_mc(n=4000, n_classes=2, seed=2)
    xtr, ytr, xte, yte = train_test_split(big_x, np.where(big_y == 0, -1, 1),
                                          0.25, 2)
    cm = LiquidSVM(SVMTrainerConfig(cell_method="voronoi", cell_size=500,
                                    n_folds=3, max_iters=400))
    cm.fit(xtr, ytr)
    print(f"cells      test error: {100 * cm.error(xte, yte):.2f}% "
          f"({cm.plan.n_cells} Voronoi cells of <=500)")


if __name__ == "__main__":
    main()
