"""Cell-routed SVM serving demo: train -> bank -> cold-start -> serve.

    PYTHONPATH=src python examples/serve_svm.py

Trains a 3-class OvA model with Voronoi cells, compacts it into a
ModelBank (zero-coefficient rows dropped, one SV table per cell shared by
all task columns), checkpoints the bank, cold-starts an SVMEngine from
disk, and serves micro-batched traffic — then replays a gamma sweep over
the cached wave D² (epilogue-only, no new cross terms), and finally
drives a bursty arrival stream through the latency-bounded async stepper
(``engine.run(deadline_ms=...)``): waves launch when they fill OR when
the oldest queued request ages past the deadline, admission overlaps the
in-flight device work, and each wave's occupancy / request-age histogram
lands in ``engine.stats()``.

Finishes with a zero-downtime hot swap under live traffic: a v1 bank is
swapped in mid-stream (``engine.swap_bank``) — the in-flight wave
completes on v0, queued requests re-route against v1, and the
per-version ``served_v*`` counters show every request attributed to
exactly one bank version.

The demo runs with the observability layer ON (``repro.obs``): after the
deadline-driven loop it prints where request latency went — the engine's
per-stage breakdown (queue/pack/dispatch/device/collect), one request's
individual attribution (``engine.breakdown(rid)``), the tracer's per-site
summary — and dumps the span trace + metrics registry as JSONL.  In
production the same surfaces come from the CLI keys ``-S TRACE=1
-S METRICS_OUT=<path>`` (and ``-S PROFILE_DIR=<dir>`` for jax.profiler
captures); everything here is off by default and costs ~nothing when off.

The last act closes the loop: a ``HealthMonitor`` watches per-cell
routing-distance sketches against the train-time baseline every bank
records at ``to_bank()`` time, a synthetic covariate shift on ONE cell
drives that cell's drift score past the refresh threshold, and
``refresh_drifted`` re-solves only that cell's columns (warm-started, at
the already-selected hyper-parameters) before hot-swapping the bumped
bank version back under the monitor — the CLI equivalent is ``serve
--swap-watch --feedback-data ... -S SLO_P99_MS=... -S DRIFT_WINDOW=...
-S DRIFT_REFRESH_THRESHOLD=...``.
"""
import argparse
import tempfile
import time

import numpy as np

from repro import obs
from repro.data.synthetic import banana_mc, train_test_split
from repro.serve import ModelBank, SVMEngine
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=1200)
    ap.add_argument("--classes", type=int, default=3)
    ap.add_argument("--wave", type=int, default=128)
    ap.add_argument("--deadline-ms", type=float, default=2.0)
    args = ap.parse_args()

    obs.configure(trace=True)        # the CLI's -S TRACE=1, programmatically

    x, y = banana_mc(n=args.n, n_classes=args.classes, seed=0)
    xtr, ytr, xte, yte = train_test_split(x, y, 0.25, 0)

    print("== train (OvA, Voronoi cells) ==")
    est = LiquidSVM(SVMTrainerConfig(scenario="ova", n_folds=3, max_iters=300,
                                     cell_method="voronoi",
                                     cell_size=300)).fit(xtr, ytr)

    print("== compact into model bank ==")
    bank = est.to_bank()
    s = bank.stats()
    print(f"cells={s['n_cells']}  SVs {s['sv_raw']} -> {s['sv_live']} "
          f"(compaction {s['compaction']:.2f})  bytes={s['bytes']}")

    with tempfile.TemporaryDirectory() as ckpt:
        bank.save(ckpt)
        print(f"== cold-start engine from checkpoint ({ckpt}) ==")
        eng = SVMEngine(ModelBank.load(ckpt))

        t0 = time.time()
        results = {}
        ids_all = []
        for lo in range(0, xte.shape[0], args.wave):
            ids_all.append(eng.submit(xte[lo:lo + args.wave]))
            results.update(eng.step())           # one batched launch per wave
        dt = time.time() - t0
        ids = np.concatenate(ids_all)
        dec = np.stack([results[int(i)] for i in ids])
        from repro.tasks.builder import combine_decisions
        pred = combine_decisions(dec, bank.scenario, classes=bank.classes,
                                 pairs=bank.pairs, sub=bank.default_sub)
        acc = float((pred == yte).mean())
        print(f"served {len(ids)} requests in {dt * 1e3:.1f} ms "
              f"({len(ids) / dt:.0f} req/s)  accuracy={acc:.3f}")
        print("engine stats:", eng.stats())

        print("== gamma sweep over the cached wave D² (epilogue-only) ==")
        t0 = time.time()
        sweep = eng.sweep_gammas(np.logspace(0.5, -0.3, 8).astype(np.float32))
        print(f"8-gamma sweep of the last wave: {(time.time() - t0) * 1e3:.1f} ms "
              f"(shape {tuple(sweep.shape)})")

        print(f"== deadline-driven async loop (deadline={args.deadline_ms} ms) ==")
        # bursty arrivals: small ragged batches with idle gaps — fills are
        # rare, so most launches are forced by the latency bound while the
        # NEXT burst is admitted against the in-flight wave
        eng2 = SVMEngine(ModelBank.load(ckpt),
                         deadline_ms=args.deadline_ms)
        rng = np.random.default_rng(0)

        def bursty():
            lo = 0
            while lo < xte.shape[0]:
                m = int(rng.integers(1, 16))
                yield xte[lo:lo + m]
                lo += m
                if rng.random() < 0.3:
                    time.sleep(args.deadline_ms * 1.5e-3)  # idle gap
                    yield None         # tick: lets the deadline fire
        t0 = time.time()
        results = eng2.run(bursty())
        dt = time.time() - t0
        stats = eng2.stats()
        dec2 = np.stack([results[i] for i in sorted(results)])
        pred2 = combine_decisions(dec2, bank.scenario, classes=bank.classes,
                                  pairs=bank.pairs, sub=bank.default_sub)
        print(f"served {len(results)} requests in {dt * 1e3:.1f} ms over "
              f"{stats['waves']} waves  accuracy={(pred2 == yte).mean():.3f}")
        print(f"occupancy_mean={stats['occupancy_mean']:.2f}  "
              f"oldest_age_ms={stats['age_ms_max']:.2f}  "
              f"age_hist={stats['age_hist']}")

        print("== observability: where did the latency go? ==")
        # per-stage attribution for the whole run: queue (waiting for a
        # wave) / pack (plan + fill) / dispatch (device launch) / device
        # (XLA compute) / collect (blend + deliver)
        for stage, v in stats["per_stage"].items():
            print(f"  {stage:9s} total={v['total_ms']:8.2f} ms  "
                  f"mean={v['mean_ms']:6.3f} ms  n={v['count']}")
        # ... and for ONE request: every served response is attributable
        rid = sorted(results)[0]
        b = eng2.breakdown(rid)
        print(f"request {rid}: total={b['total_ms']:.3f} ms = "
              f"queue {b['queue_ms']:.3f} + pack {b['pack_ms']:.3f} + "
              f"dispatch {b['dispatch_ms']:.3f} + device {b['device_ms']:.3f} "
              f"+ collect {b['collect_ms']:.3f}  (wave {b['wave']})")
        # the tracer aggregated every instrumented site across the demo
        print("trace summary (per site):")
        for site, agg in obs.tracer.summary().items():
            print(f"  {site:24s} n={agg['count']:4d}  "
                  f"mean={agg['mean_s'] * 1e3:7.3f} ms  "
                  f"max={agg['max_s'] * 1e3:7.3f} ms")
        # both surfaces export as JSONL for offline tooling
        obs.tracer.write_jsonl(f"{ckpt}/trace.jsonl")
        obs.metrics.write_jsonl(f"{ckpt}/metrics.jsonl")
        assert obs.validate_jsonl(f"{ckpt}/metrics.jsonl") == []
        print(f"dumped trace.jsonl ({len(obs.tracer.spans)} spans) and "
              f"metrics.jsonl ({len(obs.metrics.names())} metrics)")

        print("== hot swap under traffic (versioned banks) ==")
        # v1: same fit, tighter compaction — a stand-in for any refreshed
        # bank (repro.serve.refresh warm-starts only drifted cells).  The
        # swap is legal mid-flight: the in-flight wave finishes on v0, all
        # still-queued requests are re-routed against v1, and every
        # response is attributed to the version that served it.
        bank_v1 = est.to_bank(drop_tol=1e-2).with_version(1)
        eng3 = SVMEngine(ModelBank.load(ckpt))
        results3 = {}
        batches = [xte[lo:lo + 16] for lo in range(0, xte.shape[0], 16)]
        for i, b in enumerate(batches):
            eng3.submit(b)
            if i == len(batches) // 2:
                info = eng3.swap_bank(bank_v1)       # mid-traffic, no drain
                print(f"swapped to v{info['version']} with "
                      f"{info['requeued']} queued requests re-routed")
            results3.update(eng3.step())
        while eng3.pending or eng3.in_flight:
            results3.update(eng3.step())
        st3 = eng3.stats()
        dec3 = np.stack([results3[i] for i in sorted(results3)])
        pred3 = combine_decisions(dec3, bank.scenario, classes=bank.classes,
                                  pairs=bank.pairs, sub=bank.default_sub)
        print(f"served {len(results3)}/{xte.shape[0]} across the swap: "
              f"{st3.get('served_v0', 0)} on v0, "
              f"{st3.get('served_v1', 0)} on v1 — none dropped, "
              f"accuracy={(pred3 == yte).mean():.3f}")

        print("== closed loop: monitor -> drift -> refresh -> swap ==")
        # The health monitor watches two things the engine already
        # computes: per-request latency (SLO burn rate against
        # SLO_P99_MS) and per-cell routing distance, compared against the
        # train-time baseline every bank records at to_bank() time.  In
        # production the same loop runs as
        #   python -m repro.cli serve --swap-watch \
        #       --feedback-data f.npy --feedback-labels fy.npy \
        #       -S SLO_P99_MS=20 -S DRIFT_REFRESH_THRESHOLD=3
        from repro.serve import HealthMonitor, refresh_drifted
        tr, sel = est.train_result, est.select_result
        bank4 = sel.to_bank()
        eng4 = SVMEngine(bank4)
        # SLO generous enough that first-wave XLA compiles don't drown the
        # drift story (production serves warmed shapes; a demo does not)
        mon = HealthMonitor(eng4, slo_p99_ms=500.0, drift_window_s=2.0,
                            drift_threshold=3.0, min_window_count=4)
        for lo in range(0, xte.shape[0], 32):      # in-distribution traffic
            eng4.submit(xte[lo:lo + 32])
            eng4.step()
        h = mon.health()
        print(f"in-dist verdict: status={h['status']}  "
              f"max_drift={h['drift']['max_score']:.2f}  "
              f"burn_rate={h['slo']['burn_rate']:.2f}")

        # inject covariate shift on ONE cell: push its queries outward from
        # the owning center to a squared distance 5 baseline-spreads past
        # the training median (they still route there, but land where only
        # the training tail did) — by the drift-score formula that pins
        # the score at ~5.0, past the 3.0 refresh threshold
        xs = (xte - bank4.feat_mean) / bank4.feat_std
        owner = eng4.route(xs)
        target = int(np.bincount(owner, minlength=bank4.n_cells).argmax())
        q50, q90, _n = bank4.route_baseline_arrays()
        d2_shift = q50[target] + 5.0 * max(q90[target] - q50[target],
                                           0.05 * q50[target])
        u = xs[owner == target] - bank4.centers[target]
        u /= np.maximum(np.linalg.norm(u, axis=1, keepdims=True), 1e-12)
        far_s = (bank4.centers[target] +
                 u * np.sqrt(d2_shift)).astype(np.float32)
        far_s = far_s[eng4.route(far_s) == target]
        far = (far_s * bank4.feat_std + bank4.feat_mean).astype(np.float32)
        for _ in range(3):
            eng4.submit(far)
            eng4.step()
        drifted = mon.drifted_cells()
        scores = mon.drift_scores()
        print(f"after shift on cell {target}: drifted={drifted}  "
              f"scores={ {c: round(s, 1) for c, s in scores.items()} }")

        # targeted refresh: feedback rows route back through the fit's own
        # plan, ONLY the drifted cells' columns re-solve (warm-started, at
        # the already-selected hyper-parameters), version bumps, hot swap
        y_feed = np.ones(far.shape[0], np.float32)
        bank5, info = refresh_drifted(tr, sel, far, y_feed, drifted,
                                      base_version=eng4.bank.version)
        print(f"refresh: {info['columns_resolved']} columns re-solved on "
              f"{info['drifted_slots']} cell(s) "
              f"({info['feedback_used']}/{info['feedback_rows']} feedback "
              f"rows routed there) -> bank v{bank5.version}")
        eng4.swap_bank(bank5)
        mon.reset_cells(drifted)                   # measure POST-refresh
        for lo in range(0, xte.shape[0], 32):      # traffic returns in-dist
            eng4.submit(xte[lo:lo + 32])
            eng4.step()
        h = mon.health()
        print(f"post-refresh verdict: status={h['status']}  "
              f"bank_version={h['bank_version']}  "
              f"max_drift={h['drift']['max_score']:.2f}")


if __name__ == "__main__":
    main()
