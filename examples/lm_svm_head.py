"""liquidSVM as a first-class downstream head over LM embeddings.

    PYTHONPATH=src python examples/lm_svm_head.py

This is the composition the assignment asks about: the paper's technique
(cells + CV'd local SVMs) applied to the assigned LM architectures, now
through the ``repro.embed`` subsystem.  The backbone (any ``--arch``)
embeds sequences lazily behind the ChunkSource contract — ONE compiled
fixed-batch forward instead of the old whole-corpus un-jit'd call that
recompiled per shape and materialized everything — with a write-through
``EmbedCache`` so the second pass (and every rerun) is I/O-bound.  Voronoi
cells are built in EMBEDDING space; each cell gets a fully CV'd multiclass
SVM.  Local SVMs with a learned metric — Bottou-Vapnik local learning on
top of an LM.
"""
import argparse
import tempfile
import time

import numpy as np

from repro.api.session import SVM
from repro.configs import ARCH_IDS
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.embed import EmbeddingExtractor, EmbeddingSource, resolve_arch


def token_domains(cfg, n_per_class: int, seq: int, n_classes: int = 3):
    """Synthetic "domains": HMM pipelines with different seeds emit
    distinguishable token statistics — the LM embeds them apart."""
    toks, ys = [], []
    for cls in range(n_classes):
        pipe = TokenPipeline(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=seq, global_batch=n_per_class,
            seed=100 + cls, n_states=4,
            input_kind=cfg.input_kind, d_frontend=cfg.d_frontend))
        toks.append(np.asarray(pipe.batch(0)["inputs"]))
        ys.append(np.full(n_per_class, cls))
    tok = np.concatenate(toks)
    y = np.concatenate(ys)
    perm = np.random.default_rng(0).permutation(len(y))
    return tok[perm], y[perm]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list(ARCH_IDS))
    ap.add_argument("--n-per-class", type=int, default=300)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    cfg = resolve_arch(f"{args.arch}:smoke")
    tok, y = token_domains(cfg, args.n_per_class, args.seq)
    n_te = len(y) // 4
    tok_te, y_te, tok_tr, y_tr = (tok[:n_te], y[:n_te],
                                  tok[n_te:], y[n_te:])

    # ONE extractor for train and test: one jit-compiled fixed-batch
    # forward, frozen deterministic params, mean pooling
    extractor = EmbeddingExtractor(cfg, pooling="mean", batch_size=64,
                                   seed=0)
    cache_root = tempfile.mkdtemp(prefix="embed_cache_")
    xtr = EmbeddingSource(tok_tr, extractor, cache=cache_root,
                          labels=y_tr.astype(np.float32))

    # cells in embedding space + per-cell CV'd OvA SVM; labels stream from
    # the source (y=None), features are embedded lazily per chunk
    t0 = time.perf_counter()
    sess = SVM(xtr, scenario="ova", VORONOI="voronoi", CELL_SIZE=200,
               FOLDS=3, MAX_ITERATIONS=400)
    sel = sess.train().select()
    t_train = time.perf_counter() - t0

    err = sel.test(EmbeddingSource(tok_te, extractor), y_te).error
    print(f"arch={args.arch}  embed dim={xtr.dim}  "
          f"cells={sess.train_result.plan.n_cells}  "
          f"test error={100 * err:.2f}%  (train {t_train:.1f}s)")

    # the cache is now complete: a second pass over the same corpus
    # replays npz shards instead of running the backbone
    warm = EmbeddingSource(tok_tr, extractor, cache=cache_root)
    assert warm.cache_complete(), "write-through cache should be sealed"
    t0 = time.perf_counter()
    warm.materialize()
    print(f"warm re-embed of {warm.n_rows} rows: "
          f"{time.perf_counter() - t0:.3f}s (cache replay, backbone idle)")
    assert err < 0.34, "should beat 3-class chance (66%) by a wide margin"


if __name__ == "__main__":
    main()
