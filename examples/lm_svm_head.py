"""liquidSVM as a first-class downstream head over LM embeddings.

    PYTHONPATH=src python examples/lm_svm_head.py

This is the composition the assignment asks about: the paper's technique
(cells + CV'd local SVMs) applied to the assigned LM architectures.  The
backbone (any ``--arch``) embeds sequences; Voronoi cells are built in
EMBEDDING space; each cell gets a fully CV'd multiclass SVM.  Local SVMs
with a learned metric — Bottou-Vapnik local learning on top of an LM.
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig


def embed_sequences(cfg, params, inputs) -> np.ndarray:
    """Mean-pooled final-layer hidden states as sequence embeddings."""
    b, t = inputs.shape[0], inputs.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    h, _, _ = model_mod.backbone(cfg, params, inputs, positions)
    return np.asarray(jnp.mean(h.astype(jnp.float32), axis=1))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b", choices=list(ARCH_IDS))
    ap.add_argument("--n-per-class", type=int, default=300)
    ap.add_argument("--seq", type=int, default=32)
    args = ap.parse_args()

    spec = get_arch(args.arch)
    cfg = spec.smoke
    params = init_params(model_mod.build_template(cfg), jax.random.PRNGKey(0))

    # three synthetic "domains": HMM pipelines with different seeds emit
    # distinguishable token statistics — the LM embeds them apart.
    xs, ys = [], []
    for cls in range(3):
        pipe = TokenPipeline(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=args.seq, global_batch=args.n_per_class,
            seed=100 + cls, n_states=4,
            input_kind=cfg.input_kind, d_frontend=cfg.d_frontend))
        batch = pipe.batch(0)
        emb = embed_sequences(cfg, params, batch["inputs"])
        xs.append(emb)
        ys.append(np.full(args.n_per_class, cls))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys)
    perm = np.random.default_rng(0).permutation(len(x))
    x, y = x[perm], y[perm]
    n_te = len(x) // 4
    xte, yte, xtr, ytr = x[:n_te], y[:n_te], x[n_te:], y[n_te:]

    # cells in embedding space + per-cell CV'd OvA SVM
    svm = LiquidSVM(SVMTrainerConfig(scenario="ova", cell_method="voronoi",
                                     cell_size=200, n_folds=3, max_iters=400))
    svm.fit(xtr, ytr)
    err = svm.error(xte, yte)
    print(f"arch={args.arch}  embed dim={x.shape[1]}  "
          f"cells={svm.plan.n_cells}  test error={100 * err:.2f}%")
    assert err < 0.34, "should beat 3-class chance (66%) by a wide margin"


if __name__ == "__main__":
    main()
