"""Validated string-key configuration (liquidSVM's one config system).

Every liquidSVM binding — R, Python, MATLAB, the command line — shares one
set of string configuration keys (``d$train("FOLDS=3 KERNEL=GAUSS_RBF")``,
``mcSVM(..., folds=3)``).  This module is that layer for the JAX port: a
registry of typed, validated keys that map onto
:class:`repro.train.svm_trainer.SVMTrainerConfig` fields or select-stage
parameters.  Keys are case-insensitive; values arrive as Python values or
as strings (the CLI's ``-S KEY=VALUE``).

Train-stage keys
  SCENARIO             str    binary|ova|ava|weighted|npsvm|quantile|expectile|ls
  SOLVER               str    auto|hinge|ls|quantile|expectile
  KERNEL               str    gauss_rbf|laplacian (the registered kernels)
  SCALE                bool   train-statistics feature scaling (default on)
  FOLDS                int    number of CV folds (>= 2)
  FOLD_SCHEME          str    random|stratified|blocks
  GRID_CHOICE          int    0|1|2 -> 10x10 | 15x15 | 20x20 grid
  ADAPTIVITY_CONTROL   int    0|1|2 coarse-grid subsetting (paper App. C)
  MAX_ITERATIONS       int    solver iteration cap
  SOLVER_POLISH        int    Gauss-Seidel CD epochs appended to each
                       box-QP solve (kernels/cd_solver, wave-fused over
                       the cell batch); 0 = off, bitwise-identical to the
                       FISTA-only path
  TOLERANCE            float  solver duality-gap tolerance
  RANDOM_SEED          int    fold/cell PRNG seed
  VORONOI              int|str cell decomposition: 0=none 1=random
                       2-4=voronoi 5=overlap 6=recursive (or method names,
                       incl. coarse_fine)
  CELL_SIZE            int    max working-set size per cell
  WEIGHTS              floats explicit hinge +1-class weight grid
  MIN_WEIGHT /
  MAX_WEIGHT /
  WEIGHT_STEPS         float/float/int geometric weight grid (wSVM/rocSVM)
  TAUS                 floats quantile/expectile levels
  WAVE_SLOTS           int    packed slots solved per wave (memory bound)
  CHUNK_SIZE           int    streaming-ingestion chunk rows

Select-stage keys (consumed by ``select()``, not the trainer)
  NPL_CONSTRAINT       float  Neyman-Pearson false-alarm budget alpha
  NPL_CLASS            int    +-1: which class the constraint binds on

Serve-stage keys (consumed by the serving engine — ``SVM(...).engine()``
and ``python -m repro.cli serve`` — never the trainer; split off with
:func:`split_serve_keys`)
  SERVE_OVERLAP        bool   route each request to its 2 nearest cells
                       and blend decisions with distance-softmax weights.
                       Defaults to the bank's recorded routing mode
                       (overlap for VORONOI=5 fits, else exact 1-NN).
  DEADLINE_MS          float  latency bound for the async stepper: a wave
                       launches when it fills OR the oldest queued
                       request reaches this age.
  MAX_QUEUE            int    admission-queue bound (launch rows): a
                       submit that would overflow is rejected with a
                       retry-able OverloadError instead of growing
                       memory without bound.
  SWAP_POLL_MS         float  hot-swap watcher poll interval for
                       ``cli serve --swap-watch`` (how often the bank
                       directory is checked for a newer version).

Monitor keys (consumed by ``repro.serve.monitor.HealthMonitor`` —
``SVM(...).monitor()`` and ``cli serve``; split off with
:func:`split_monitor_keys`)
  SLO_P99_MS           float  latency SLO: 99% of requests must complete
                       under this many ms.  Enables rolling-window
                       error-budget burn-rate tracking and breach events.
  DRIFT_WINDOW         float  rolling window (seconds) for the per-cell
                       routing-distance drift sketches and burn rates.
  DRIFT_REFRESH_THRESHOLD float per-cell drift score at which the closed
                       loop triggers a targeted ``refresh_bank`` +
                       hot swap (``cli serve --swap-watch`` with
                       ``--feedback-data``).

Embed-stage keys (consumed by :func:`repro.embed.embed_source` — the
session front door, scenario front-ends and ``cli embed``/``cli serve
--tokens`` when the x input is a TOKEN corpus; split off with
:func:`split_embed_keys`)
  EMBED_ARCH           str    frozen-backbone architecture id from
                       ``repro.configs.ARCH_IDS``; append ``:smoke`` for
                       the smoke-sized variant (tests, synthetic demos).
                       Presence of this key is what flags the x input as
                       tokens rather than features.
  EMBED_POOL           str    mean|last — hidden-state pooling.
  EMBED_CACHE          path   multi-identity embedding-cache root: npz
                       shards land under ``<dir>/<fingerprint>/`` keyed by
                       (arch, params digest, pooling, seq_len); cache hits
                       replay through ShardedNpzSource (I/O-bound).
  EMBED_BATCH          int    fixed jit batch shape for the backbone
                       forward (compute-block size; does NOT affect
                       output bits — blocks align to corpus offsets).
  EMBED_SEED           int    deterministic frozen-backbone init seed
                       (the random-features regime; ignored when real
                       params are supplied programmatically).

Observability keys (consumed by ``repro.obs.configure`` — any stage; split
off with :func:`split_obs_keys`)
  TRACE                bool   enable the span tracer (``repro.obs.tracer``):
                       monotonic-clock spans at every instrumented site,
                       per-site summaries, JSONL trace dumps.  Off by
                       default; disabled sites cost one attribute test.
  TRACE_OUT            path   write the retained span window (schema
                       ``repro.obs.trace.v1``) to this JSONL file when the
                       CLI stage exits; implies TRACE=1 unless TRACE=0 is
                       set explicitly.
  METRICS_OUT          path   write the process metrics registry
                       (counters/gauges/latency histograms/quantile
                       sketches, schema ``repro.obs.metrics.v1``) to this
                       JSONL file when the CLI stage exits.
  PROFILE_DIR          path   capture ``jax.profiler`` device traces around
                       wave launches into this directory (each wave is a
                       ``StepTraceAnnotation`` step; ``cv.d2``/
                       ``cv.epilogue``/``cv.solve`` named scopes label the
                       jitted CV internals).

Accepted for liquidSVM compatibility, no effect here
  DISPLAY, THREADS
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.train.svm_trainer import SVMTrainerConfig

_CELL_CODES = {0: "none", 1: "random", 2: "voronoi", 3: "voronoi",
               4: "voronoi", 5: "overlap", 6: "recursive"}
_CELL_NAMES = ("none", "random", "voronoi", "overlap", "recursive",
               "coarse_fine")


@dataclasses.dataclass(frozen=True)
class ConfigKey:
    name: str
    kind: str                       # int | float | bool | str | path | floats
    doc: str
    field: Optional[str] = None     # SVMTrainerConfig field
    choices: Optional[Tuple] = None
    lo: Optional[float] = None
    hi: Optional[float] = None
    select: bool = False            # select-stage parameter
    serve: bool = False             # serve-stage (engine) parameter
    monitor: bool = False           # health-monitor (HealthMonitor) parameter
    obs: bool = False               # observability (repro.obs.configure)
    embed: bool = False             # embed-stage (repro.embed) parameter
    noop: bool = False              # accepted (compat), ignored


_KEYS: Dict[str, ConfigKey] = {k.name: k for k in [
    ConfigKey("SCENARIO", "str", "learning scenario", field="scenario",
              choices=("binary", "ova", "ava", "weighted", "npsvm",
                       "quantile", "expectile", "ls")),
    ConfigKey("SOLVER", "str", "solver override", field="solver",
              choices=("auto", "hinge", "ls", "quantile", "expectile")),
    ConfigKey("KERNEL", "str", "kernel name", field="kernel"),
    ConfigKey("SCALE", "bool", "train-statistics scaling", field="scale"),
    ConfigKey("FOLDS", "int", "CV folds", field="n_folds", lo=2, hi=64),
    ConfigKey("FOLD_SCHEME", "str", "fold construction", field="fold_scheme",
              choices=("random", "stratified", "blocks")),
    ConfigKey("GRID_CHOICE", "int", "grid size preset", field="grid_choice",
              lo=0, hi=2),
    ConfigKey("ADAPTIVITY_CONTROL", "int", "coarse-grid level",
              field="adaptivity_control", lo=0, hi=2),
    ConfigKey("MAX_ITERATIONS", "int", "solver iteration cap",
              field="max_iters", lo=1),
    ConfigKey("SOLVER_POLISH", "int", "wave-fused CD polish epochs (0 = off)",
              field="cd_polish", lo=0),
    ConfigKey("TOLERANCE", "float", "solver tolerance", field="tol", lo=0.0),
    ConfigKey("RANDOM_SEED", "int", "PRNG seed", field="seed"),
    ConfigKey("VORONOI", "", "cell decomposition code/name"),
    ConfigKey("PARTITION_CHOICE", "", "alias of VORONOI"),
    ConfigKey("CELL_SIZE", "int", "max cell size", field="cell_size", lo=2),
    ConfigKey("WEIGHTS", "floats", "explicit weight grid", field="weights"),
    ConfigKey("MIN_WEIGHT", "float", "weight grid lower end", lo=0.0),
    ConfigKey("MAX_WEIGHT", "float", "weight grid upper end", lo=0.0),
    ConfigKey("WEIGHT_STEPS", "int", "weight grid size", lo=1),
    ConfigKey("TAUS", "floats", "quantile/expectile levels", field="taus"),
    ConfigKey("WAVE_SLOTS", "int", "slots per training wave",
              field="n_slots_per_wave", lo=1),
    ConfigKey("CHUNK_SIZE", "int", "streaming chunk rows",
              field="chunk_size", lo=1),
    ConfigKey("NPL_CONSTRAINT", "float", "NP false-alarm budget",
              select=True, lo=0.0, hi=1.0),
    ConfigKey("NPL_CLASS", "int", "NP constrained class", select=True,
              choices=(-1, 1)),
    ConfigKey("SERVE_OVERLAP", "bool", "blend the 2 nearest cells' decisions",
              serve=True),
    ConfigKey("DEADLINE_MS", "float", "async-stepper latency bound",
              serve=True, lo=0.0),
    ConfigKey("MAX_QUEUE", "int", "admission-queue bound (sheds on overflow)",
              serve=True, lo=1),
    ConfigKey("SWAP_POLL_MS", "float", "hot-swap watcher poll interval",
              serve=True, lo=0.0),
    ConfigKey("SLO_P99_MS", "float", "p99 latency SLO (burn-rate tracking)",
              monitor=True, lo=0.0),
    ConfigKey("DRIFT_WINDOW", "float", "drift/SLO rolling window seconds",
              monitor=True, lo=0.0),
    ConfigKey("DRIFT_REFRESH_THRESHOLD", "float",
              "drift score that triggers a targeted bank refresh",
              monitor=True, lo=0.0),
    ConfigKey("EMBED_ARCH", "str", "frozen-backbone arch id (:smoke variant)",
              embed=True),
    ConfigKey("EMBED_POOL", "str", "hidden-state pooling", embed=True,
              choices=("mean", "last")),
    ConfigKey("EMBED_CACHE", "path", "embedding-cache root directory",
              embed=True),
    ConfigKey("EMBED_BATCH", "int", "fixed jit batch shape for the backbone",
              embed=True, lo=1),
    ConfigKey("EMBED_SEED", "int", "frozen-backbone init seed", embed=True),
    ConfigKey("TRACE", "bool", "enable the span tracer", obs=True),
    ConfigKey("TRACE_OUT", "path", "write trace JSONL here on exit",
              obs=True),
    ConfigKey("METRICS_OUT", "path", "write metrics JSONL here on exit",
              obs=True),
    ConfigKey("PROFILE_DIR", "path", "jax.profiler capture directory",
              obs=True),
    ConfigKey("DISPLAY", "int", "verbosity (compat; ignored)", noop=True),
    ConfigKey("THREADS", "int", "thread count (compat; ignored)", noop=True),
]}

_SELECT_NAMES = {"NPL_CONSTRAINT": "alpha", "NPL_CLASS": "npl_class"}
_SERVE_NAMES = {"SERVE_OVERLAP": "overlap", "DEADLINE_MS": "deadline_ms",
                "MAX_QUEUE": "max_queue", "SWAP_POLL_MS": "swap_poll_ms"}
_MONITOR_NAMES = {"SLO_P99_MS": "slo_p99_ms",
                  "DRIFT_WINDOW": "drift_window_s",
                  "DRIFT_REFRESH_THRESHOLD": "drift_threshold"}
_OBS_NAMES = {"TRACE": "trace", "TRACE_OUT": "trace_out",
              "METRICS_OUT": "metrics_out", "PROFILE_DIR": "profile_dir"}
_EMBED_NAMES = {"EMBED_ARCH": "arch", "EMBED_POOL": "pooling",
                "EMBED_CACHE": "cache_dir", "EMBED_BATCH": "batch_size",
                "EMBED_SEED": "seed"}


class ConfigError(ValueError):
    """A config key or value failed validation."""


def available_keys() -> Tuple[str, ...]:
    return tuple(sorted(_KEYS))


def describe_keys() -> str:
    """Human-readable key table (the CLI's ``--help-keys``)."""
    rows = []
    for name in sorted(_KEYS):
        k = _KEYS[name]
        kind = k.kind or "int|str"
        extra = " (select stage)" if k.select else \
            " (serve stage)" if k.serve else \
            " (health monitor)" if k.monitor else \
            " (observability)" if k.obs else \
            " (embed stage)" if k.embed else \
            " (ignored)" if k.noop else ""
        rows.append(f"  {name:<20} {kind:<7} {k.doc}{extra}")
    return "\n".join(rows)


def _coerce(key: ConfigKey, raw: Any) -> Any:
    kind = key.kind
    try:
        if kind == "int":
            v: Any = int(raw)
        elif kind == "float":
            v = float(raw)
        elif kind == "bool":
            v = (raw.strip().lower() in ("1", "true", "yes", "on")
                 if isinstance(raw, str) else bool(raw))
        elif kind == "floats":
            if isinstance(raw, str):
                v = tuple(float(p) for p in raw.replace(",", " ").split())
            else:
                v = tuple(float(p) for p in np.atleast_1d(raw))
        elif kind == "str":
            v = str(raw).lower()
        elif kind == "path":
            # filesystem paths keep their case, unlike "str" enum values
            v = str(raw)
        else:                       # VORONOI: int code or method name
            s = str(raw).lower()
            if s in _CELL_NAMES:
                return s
            v = _CELL_CODES.get(int(s))
            if v is None:
                raise ValueError(s)
            return v
    except (TypeError, ValueError):
        raise ConfigError(
            f"{key.name}: cannot parse {raw!r} as {kind or 'int|str'}")
    if key.choices is not None and v not in key.choices:
        raise ConfigError(f"{key.name}: {v!r} not in {key.choices}")
    if key.lo is not None and v < key.lo:
        raise ConfigError(f"{key.name}: {v!r} below minimum {key.lo}")
    if key.hi is not None and v > key.hi:
        raise ConfigError(f"{key.name}: {v!r} above maximum {key.hi}")
    return v


def split_serve_keys(pairs: Dict[str, Any]
                     ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Partition raw key pairs into (non-serve pairs, engine kwargs).

    Serve-stage keys (SERVE_OVERLAP, DEADLINE_MS, MAX_QUEUE, SWAP_POLL_MS)
    configure the
    :class:`repro.serve.SVMEngine`, not the trainer: callers that accept
    mixed string keys (the session front door, ``cli serve``) split them
    off here — validated/coerced — before ``apply_keys`` sees the rest.
    """
    rest: Dict[str, Any] = {}
    serve: Dict[str, Any] = {}
    for name, raw in pairs.items():
        canon = str(name).upper()
        k = _KEYS.get(canon)
        if k is not None and k.serve:
            serve[_SERVE_NAMES[canon]] = _coerce(k, raw)
        else:
            rest[name] = raw
    return rest, serve


def split_monitor_keys(pairs: Dict[str, Any]
                       ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Partition raw key pairs into (non-monitor pairs, monitor kwargs).

    Monitor keys (SLO_P99_MS, DRIFT_WINDOW, DRIFT_REFRESH_THRESHOLD)
    configure the :class:`repro.serve.HealthMonitor` attached to an
    engine, not the trainer or the engine itself — callers pass the
    returned kwargs to ``HealthMonitor(engine, **kw)`` (or
    ``SVM(...).monitor()``).
    """
    rest: Dict[str, Any] = {}
    mon: Dict[str, Any] = {}
    for name, raw in pairs.items():
        canon = str(name).upper()
        k = _KEYS.get(canon)
        if k is not None and k.monitor:
            mon[_MONITOR_NAMES[canon]] = _coerce(k, raw)
        else:
            rest[name] = raw
    return rest, mon


def split_obs_keys(pairs: Dict[str, Any]
                   ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Partition raw key pairs into (non-obs pairs, obs kwargs).

    Observability keys (TRACE, METRICS_OUT, PROFILE_DIR) configure the
    process-global ``repro.obs`` instruments, not the trainer or the
    engine — callers pass the returned kwargs to ``repro.obs.configure``.
    """
    rest: Dict[str, Any] = {}
    ob: Dict[str, Any] = {}
    for name, raw in pairs.items():
        canon = str(name).upper()
        k = _KEYS.get(canon)
        if k is not None and k.obs:
            ob[_OBS_NAMES[canon]] = _coerce(k, raw)
        else:
            rest[name] = raw
    return rest, ob


def split_embed_keys(pairs: Dict[str, Any]
                     ) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    """Partition raw key pairs into (non-embed pairs, embed kwargs).

    Embed-stage keys (EMBED_ARCH, EMBED_POOL, EMBED_CACHE, EMBED_BATCH,
    EMBED_SEED) configure :func:`repro.embed.embed_source` — the frozen
    backbone that turns a TOKEN corpus into the feature source the trainer
    and engine consume.  Presence of ``arch`` in the returned kwargs is
    the signal that the x input is tokens: callers wrap it with
    ``embed_source(x, **kw)`` before anything touches the ChunkSource
    contract.
    """
    rest: Dict[str, Any] = {}
    emb: Dict[str, Any] = {}
    for name, raw in pairs.items():
        canon = str(name).upper()
        k = _KEYS.get(canon)
        if k is not None and k.embed:
            emb[_EMBED_NAMES[canon]] = _coerce(k, raw)
        else:
            rest[name] = raw
    if emb and "arch" not in emb:
        raise ConfigError(
            "EMBED_POOL/EMBED_CACHE/EMBED_BATCH/EMBED_SEED require "
            "EMBED_ARCH — without an architecture there is no backbone "
            "to embed with")
    return rest, emb


def parse_keys(pairs: Dict[str, Any]) -> Dict[str, Any]:
    """Normalize/validate a {key: value} mapping to canonical upper keys."""
    out: Dict[str, Any] = {}
    for name, raw in pairs.items():
        canon = name.upper()
        if canon == "PARTITION_CHOICE":
            canon = "VORONOI"
        if canon not in _KEYS:
            raise ConfigError(f"unknown config key {name!r}; known keys:\n"
                              + describe_keys())
        out[canon] = _coerce(_KEYS[canon], raw)
    return out


def apply_keys(base: SVMTrainerConfig, pairs: Dict[str, Any]
               ) -> Tuple[SVMTrainerConfig, Dict[str, Any]]:
    """Apply string keys onto a trainer config.

    Returns ``(config, select_params)`` — the select-stage keys
    (NPL_CONSTRAINT/NPL_CLASS) are routed to ``select()`` rather than the
    trainer.  MIN_WEIGHT/MAX_WEIGHT/WEIGHT_STEPS expand to a geometric
    weight grid (overridden by an explicit WEIGHTS).
    """
    keys = parse_keys(pairs)
    fields: Dict[str, Any] = {}
    select_params: Dict[str, Any] = {}
    w_lo = w_hi = w_steps = None
    for name, v in keys.items():
        k = _KEYS[name]
        if k.noop:
            continue
        if k.serve:
            raise ConfigError(
                f"{name} is a serve-stage key — it configures the engine, "
                f"not the trainer (use SVM(...).engine(), `cli serve`, or "
                f"split_serve_keys)")
        if k.monitor:
            raise ConfigError(
                f"{name} is a health-monitor key — it configures the "
                f"serving HealthMonitor, not the trainer (use "
                f"SVM(...).monitor(), `cli serve`, or split_monitor_keys)")
        if k.obs:
            raise ConfigError(
                f"{name} is an observability key — it configures "
                f"repro.obs, not the trainer (the session front door and "
                f"the CLI split it off; see split_obs_keys)")
        if k.embed:
            raise ConfigError(
                f"{name} is an embed-stage key — it configures the frozen "
                f"embedding backbone, not the trainer (the session front "
                f"door, `cli embed` and `cli serve --tokens` split it "
                f"off; see split_embed_keys)")
        if name == "VORONOI":
            fields["cell_method"] = v
        elif name == "MIN_WEIGHT":
            w_lo = v
        elif name == "MAX_WEIGHT":
            w_hi = v
        elif name == "WEIGHT_STEPS":
            w_steps = v
        elif k.select:
            select_params[_SELECT_NAMES[name]] = v
        else:
            fields[k.field] = v
    if w_steps is not None or w_lo is not None or w_hi is not None:
        w_lo = 1.0 / 9.0 if w_lo is None else w_lo
        w_hi = 9.0 if w_hi is None else w_hi
        w_steps = 5 if w_steps is None else w_steps
        if "weights" not in fields:
            fields["weights"] = weight_grid(w_lo, w_hi, w_steps)
    cfg = dataclasses.replace(base, **fields)
    if cfg.kernel not in _registered_kernels():
        raise ConfigError(f"KERNEL: {cfg.kernel!r} not registered "
                          f"({_registered_kernels()})")
    return cfg, select_params


def weight_grid(lo: float, hi: float, steps: int) -> Tuple[float, ...]:
    """Geometric class-weight grid (the wSVM/rocSVM weight axis)."""
    if steps == 1:
        return (float(lo),)
    return tuple(float(v) for v in np.geomspace(lo, hi, steps))


def _registered_kernels() -> Tuple[str, ...]:
    from repro.core import kernel_fns
    return tuple(sorted(kernel_fns._REGISTRY))
