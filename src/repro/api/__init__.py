"""Staged liquidSVM-style user surface: sessions, scenarios, config keys.

Three layers, mirroring the package's bindings (paper §2-3):

* :mod:`repro.api.session` — the staged cycle.  ``SVM(x, y, ...)`` with
  ``train()`` -> :class:`TrainResult` (models + retained CV surface),
  ``select(rule)`` -> :class:`SelectResult` (re-runnable selection: argmin /
  npl / roc / quantile / expectile — only moved winners are re-solved),
  ``test()`` -> :class:`TestResult` (streamed over any chunk source).  All
  stage artifacts persist via ``save``/``load`` so the stages can run as
  separate processes (``python -m repro.cli {train,select,test}``) and a
  predict server cold-starts from the select output
  (``SelectResult.to_bank()`` -> ``repro.serve.SVMEngine``).

* :mod:`repro.api.scenarios` — front-ends ``mcSVM`` ``lsSVM`` ``qtSVM``
  ``exSVM`` ``nplSVM`` ``rocSVM`` returning pre-configured sessions.

* :mod:`repro.api.config` — the validated string-key config layer shared
  by every entry point (keys are case-insensitive; values may be strings):

  SCENARIO SOLVER KERNEL SCALE FOLDS FOLD_SCHEME GRID_CHOICE
  ADAPTIVITY_CONTROL MAX_ITERATIONS TOLERANCE RANDOM_SEED VORONOI
  (PARTITION_CHOICE) CELL_SIZE WEIGHTS MIN_WEIGHT MAX_WEIGHT WEIGHT_STEPS
  TAUS WAVE_SLOTS CHUNK_SIZE NPL_CONSTRAINT NPL_CLASS SERVE_OVERLAP
  DEADLINE_MS DISPLAY THREADS

  See ``repro.api.config.describe_keys()`` (or ``python -m repro.cli
  train --help-keys``) for types, ranges and semantics.
"""
from repro.api.config import (ConfigError, apply_keys, available_keys,
                              describe_keys, parse_keys, split_serve_keys,
                              weight_grid)
from repro.api.scenarios import exSVM, lsSVM, mcSVM, nplSVM, qtSVM, rocSVM
from repro.api.session import (SVM, SelectResult, TestResult, TrainResult)

__all__ = [
    "SVM", "TrainResult", "SelectResult", "TestResult",
    "mcSVM", "lsSVM", "qtSVM", "exSVM", "nplSVM", "rocSVM",
    "ConfigError", "apply_keys", "parse_keys", "available_keys",
    "describe_keys", "split_serve_keys", "weight_grid",
]
