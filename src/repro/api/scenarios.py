"""Scenario front-ends (liquidSVM §3 "Learning Scenarios").

The package ships pre-configured entry points — ``mcSVM``, ``lsSVM``,
``qtSVM``, ``exSVM``, ``nplSVM``, ``rocSVM`` — that wire the right task
construction, solver, weight/tau grids AND the right selection rule, so
users never touch hyper-parameters.  Each front-end here returns a
configured :class:`repro.api.session.SVM` session; the staged cycle is
then uniform across scenarios:

    sess = mcSVM(x, y, FOLDS=3)
    sess.train(); sess.select(); print(sess.test(xt, yt).error)

All front-ends accept string config keys (see :mod:`repro.api.config`)
as keyword arguments, e.g. ``qtSVM(x, y, FOLDS=3, VORONOI="voronoi",
CELL_SIZE=500)``.
"""
from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.api.config import (apply_keys, split_embed_keys, split_serve_keys,
                              weight_grid)
from repro.api.session import SVM
from repro.train.svm_trainer import SVMTrainerConfig


def _session(scenario: str, x, y, keys: dict,
             select_rule: Optional[str] = None,
             select_kwargs: Optional[dict] = None,
             **cfg_fields) -> SVM:
    base = SVMTrainerConfig(scenario=scenario, **cfg_fields)
    keys, embed_kw = split_embed_keys(keys)
    if embed_kw:
        # EMBED_ARCH flags x as a token corpus: wrap it so the scenario
        # trains over lazily-computed frozen-backbone embeddings
        from repro.embed import embed_source
        x = embed_source(x, **embed_kw)
    keys, serve_kw = split_serve_keys(keys)
    cfg, key_select = apply_keys(base, keys)
    merged = {**key_select, **(select_kwargs or {})}
    return SVM(x, y, config=cfg, select_rule=select_rule,
               select_kwargs=merged, serve_kwargs=serve_kw)


def mcSVM(x, y, mc_type: str = "OvA", **keys) -> SVM:
    """Multiclass classification: one-versus-all (default) or all-versus-
    all hinge tasks over the class values in ``y``."""
    kinds = {"ova": "ova", "ava": "ava",
             "ova_hinge": "ova", "ava_hinge": "ava"}
    k = kinds.get(mc_type.lower())
    if k is None:
        raise ValueError(f"mc_type must be OvA|AvA, got {mc_type!r}")
    return _session(k, x, y, keys)


def lsSVM(x, y, **keys) -> SVM:
    """Least-squares regression (kernel ridge on the cells)."""
    return _session("ls", x, y, keys)


def qtSVM(x, y, taus: Sequence[float] = (0.05, 0.1, 0.5, 0.9, 0.95),
          **keys) -> SVM:
    """Quantile regression: pinball solver, one selected model per tau."""
    return _session("quantile", x, y, keys, select_rule="quantile",
                    taus=tuple(float(t) for t in taus))


def exSVM(x, y, taus: Sequence[float] = (0.05, 0.1, 0.5, 0.9, 0.95),
          **keys) -> SVM:
    """Expectile regression: asymmetric-least-squares solver, per tau."""
    return _session("expectile", x, y, keys, select_rule="expectile",
                    taus=tuple(float(t) for t in taus))


def nplSVM(x, y, npl_class: int = -1, constraint: float = 0.05,
           weights: Optional[Sequence[float]] = None, **keys) -> SVM:
    """Neyman-Pearson classification: false alarm on ``npl_class``
    constrained to ``constraint``, detection maximized.

    Trains the class-weight grid once; ``select()`` defaults to the
    ``"npl"`` rule, whose rates come from the retained VALIDATION surface
    (re-runnable with a different ``alpha``/``npl_class`` without
    retraining: ``sess.select(alpha=0.01)``).
    """
    w = tuple(float(v) for v in (weights if weights is not None
                                 else weight_grid(0.25, 4.0, 5)))
    return _session("npsvm", x, y, keys, select_rule="npl",
                    select_kwargs={"alpha": float(constraint),
                                   "npl_class": int(npl_class)},
                    weights=w, np_alpha=float(constraint))


def rocSVM(x, y, weight_steps: int = 9, min_weight: float = 1.0 / 9.0,
           max_weight: float = 9.0, **keys) -> SVM:
    """ROC curve via weighted binary SVMs: one working point per class
    weight, the whole (false alarm, detection) front emitted.

    ``select()`` defaults to the ``"roc"`` rule: winners are the cached
    per-weight CV argmins (nothing is re-solved) and
    ``SelectResult.extras["roc_front"]`` carries the front aggregated
    from the retained validation counts.
    """
    w = weight_grid(min_weight, max_weight, weight_steps)
    return _session("weighted", x, y, keys, select_rule="roc", weights=w)
