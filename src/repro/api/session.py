"""Staged train -> select -> test sessions (liquidSVM's three-binary cycle).

liquidSVM exposes its application cycle as three separable stages —
``svm-train`` solves the full fold x grid, ``svm-select`` picks
hyper-parameters (re-runnable with different criteria: NPL constraints,
ROC weight fronts) WITHOUT retraining, ``svm-test`` evaluates — and every
binding, from the R front-ends to the command line, composes them.  This
module is that cycle for the JAX port:

    sess = SVM(x, y, config)            # or a repro.api front-end
    tr   = sess.train()                 # TrainResult: models + CV surface
    sel  = sess.select("npl", alpha=.05)   # SelectResult: one targeted wave
    res  = sess.test(x_test, y_test)    # TestResult: streamed errors

Stage artifacts are first-class and persistable (``save``/``load`` through
``repro.train.checkpoint`` step dirs), so the stages can run as separate
processes — exactly what ``python -m repro.cli {train,select,test}`` does —
and a predict server cold-starts from the select output alone
(``SelectResult.to_bank()`` -> ``repro.serve.SVMEngine``).

Why re-selection is cheap: ``train()`` retains the per-cell validation-loss
surface over the whole (gamma, task, lambda, sub) grid plus — for hinge —
validation false-alarm/detection COUNTS (``CVConfig.keep_surface``; the
surface is O(slots x grid), tiny next to the coefficients).  ``select``
applies a registered :mod:`repro.core.select` rule over the surface and
re-solves ONLY the (task, sub) columns whose winning grid coordinates
moved off the train-time argmin (those models are already cached): one
targeted ``solve_columns_at`` wave per (cell, new gamma), not a refit.
Under the "argmin" rule nothing is re-solved at all, so
``train() -> select("argmin") -> test()`` is bitwise-identical to the old
fused ``LiquidSVM.fit``.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.cells.builder import CellPlan
from repro.core import cv as cv_mod
from repro.core import grids, kernel_fns
from repro.core import select as select_mod
from repro.data.scaling import Scaler
from repro.distributed.cell_trainer import predict_cells, train_cells_waves
from repro.distributed.planner import PackedCells, group_rows, pack_cells
from repro.pipeline.cell_stream import build_cells_stream
from repro.pipeline.dataset import ArraySource, ChunkSource, ScaledSource, as_source
from repro.tasks.builder import TaskSet, combine_decisions, make_tasks
from repro.train import checkpoint as ckpt_mod
from repro.train.svm_trainer import SVMTrainerConfig

_TRAIN_FORMAT = "svm_train_result_v1"
_SELECT_FORMAT = "svm_select_result_v1"

# scenario -> the selection rule its select() stage defaults to
_DEFAULT_RULES = {"npsvm": "npl", "quantile": "quantile",
                  "expectile": "expectile"}


# ----------------------------------------------------------- serialization
def _cfg_to_json(cfg) -> dict:
    return dataclasses.asdict(cfg)


def _cfg_from_json(cls, d: dict):
    kw = dict(d)
    for k in ("taus", "weights"):
        if kw.get(k) is not None:
            kw[k] = tuple(kw[k])
    return cls(**kw)


def _ctx_tree(plan: CellPlan, packed: PackedCells, scaler: Scaler,
              tasks: TaskSet) -> Dict[str, np.ndarray]:
    """The shared stage context (routing + scaling + tasks) as a flat tree."""
    # index arrays stored int32 (the restore path runs under 32-bit jax;
    # int64 leaves would be silently truncated) and widened on load
    return {
        "plan_indices": plan.indices, "plan_mask": plan.mask,
        "plan_owner": np.asarray(plan.owner, np.int32),
        "plan_centers": plan.centers,
        "plan_coarse_of": plan.coarse_of,
        "packed_order": np.asarray(packed.order, np.int32),
        "packed_slot_of_cell": np.asarray(packed.slot_of_cell, np.int32),
        "scaler_mean": np.asarray(scaler.mean),
        "scaler_std": np.asarray(scaler.std),
        "tasks_labels": tasks.labels, "tasks_task_mask": tasks.task_mask,
        "tasks_classes": np.asarray(tasks.classes, np.float32),
        "tasks_pairs": np.asarray(tasks.pairs, np.int32),
        "tasks_taus": np.asarray(tasks.taus, np.float32),
        "tasks_weights": np.asarray(tasks.weights, np.float32),
    }


def _ctx_from_tree(t: Dict[str, np.ndarray], extra: dict):
    plan = CellPlan(indices=t["plan_indices"], mask=t["plan_mask"],
                    owner=np.asarray(t["plan_owner"], np.int32),
                    centers=t["plan_centers"],
                    coarse_of=t["plan_coarse_of"])
    packed = PackedCells(order=np.asarray(t["packed_order"], np.int64),
                         slot_of_cell=np.asarray(t["packed_slot_of_cell"],
                                                 np.int64),
                         n_devices=int(extra["packed_n_devices"]),
                         slots_per_device=int(extra["packed_slots_per_device"]))
    scaler = Scaler(mean=t["scaler_mean"], std=t["scaler_std"])
    tasks = TaskSet(kind=extra["tasks_kind"], labels=t["tasks_labels"],
                    task_mask=t["tasks_task_mask"], classes=t["tasks_classes"],
                    pairs=t["tasks_pairs"], taus=t["tasks_taus"],
                    weights=t["tasks_weights"])
    return plan, packed, scaler, tasks


def _ctx_extra(config, cv_cfg, tasks: TaskSet, packed: PackedCells) -> dict:
    return {"config": _cfg_to_json(config), "cv_cfg": _cfg_to_json(cv_cfg),
            "tasks_kind": tasks.kind, "packed_n_devices": packed.n_devices,
            "packed_slots_per_device": packed.slots_per_device}


def _load_tree(ckpt_dir: str, want_format: str):
    if ckpt_mod.peek_manifest(ckpt_dir)["extra"].get("format") != want_format:
        got = ckpt_mod.peek_manifest(ckpt_dir)["extra"].get("format")
        raise ValueError(f"{ckpt_dir} is not a {want_format} checkpoint "
                         f"(format={got!r})")
    return ckpt_mod.restore_self_describing(ckpt_dir)


# ----------------------------------------------------------------- results
@dataclasses.dataclass
class TestResult:
    """Streamed test-stage output."""
    error: float              # scenario error (0-1 loss / pinball / mse ...)
    n: int                    # rows evaluated
    details: Dict[str, float] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class TrainResult:
    """Everything ``svm-train`` produced: cell models at the CV-loss argmin
    PLUS the retained validation surface and the staged cell data needed to
    re-solve a handful of columns when a different rule picks different
    winners.  ``select(rule)`` is re-runnable; ``save``/``load`` make the
    stage a process boundary."""
    config: SVMTrainerConfig
    cv_cfg: cv_mod.CVConfig
    scaler: Scaler
    plan: CellPlan
    packed: PackedCells
    tasks: TaskSet
    lambdas: np.ndarray        # (L,) shared lambda grid values
    gammas_cells: np.ndarray   # (slots, G) per-cell adaptive gamma grids
    fold_keys: np.ndarray      # (slots, 2) per-cell fold PRNG keys
    x_cells: np.ndarray        # (slots, k, d) staged (scaled) cell rows
    mask_cells: np.ndarray     # (slots, k)
    y_cells: np.ndarray        # (slots, T, k) task labels per cell
    tmask_cells: np.ndarray    # (slots, T, k)
    coefs: np.ndarray          # (slots, k, T, S) argmin fold-averaged models
    gamma: np.ndarray          # (slots, T, S) argmin winners
    lam: np.ndarray
    tau: np.ndarray
    val_loss: np.ndarray
    surf_loss: np.ndarray      # (slots, G, T, L, S)
    surf_fa: np.ndarray        # (slots, G, T, L, S) validation FA counts
    surf_det: np.ndarray
    n: int
    d: int

    # ---------------------------------------------------------- surface
    def class_counts(self) -> Tuple[np.ndarray, np.ndarray]:
        """(neg, pos) valid-sample totals per (slot, task) — the exact
        denominators for the retained count grids."""
        on = (self.tmask_cells > 0) & (self.mask_cells[:, None, :] > 0)
        neg = ((self.y_cells < 0) & on).sum(-1).astype(np.float32)
        pos = ((self.y_cells > 0) & on).sum(-1).astype(np.float32)
        return neg, pos

    def surface(self) -> select_mod.Surface:
        neg, pos = self.class_counts()
        return select_mod.Surface(loss=self.surf_loss, fa=self.surf_fa,
                                  det=self.surf_det, neg=neg, pos=pos,
                                  gammas=self.gammas_cells,
                                  lambdas=self.lambdas)

    # ----------------------------------------------------------- select
    def select(self, rule: Optional[str] = None,
               mesh: Optional[Mesh] = None,
               mesh_axes: Optional[Tuple[str, ...]] = None,
               **rule_kwargs) -> "SelectResult":
        """Apply a selection rule over the retained surface.

        Columns whose winning (gamma, lambda) equals the train-time argmin
        reuse the cached models untouched (bitwise); the rest are re-solved
        by :func:`repro.core.cv.solve_columns_batched` — ALL moved cells
        sharing a winning gamma-grid index go into one vmapped launch
        (columns padded to one static width so repeated re-selections share
        one compiled program), each warm-started from its cell's cached
        argmin model instead of ``c0 = 0``.  ``stats`` reports how little
        was solved versus the full sweep (``resolve_calls`` counts
        launches, ``solver_iters`` total box-QP iterations).
        """
        cfg = self.config
        rule = rule or _DEFAULT_RULES.get(cfg.scenario, "argmin")
        if rule in ("npl", "roc") and self.cv_cfg.solver != "hinge":
            raise ValueError(f"rule {rule!r} needs the hinge solver "
                             f"(validation FA/detection counts); "
                             f"got {self.cv_cfg.solver!r}")
        ctx = select_mod.SelectContext(
            scenario=cfg.scenario,
            weights=np.asarray(cfg.weights, np.float32),
            taus=np.asarray(cfg.taus, np.float32),
            alpha=float(rule_kwargs.pop("alpha", cfg.np_alpha)),
            npl_class=int(rule_kwargs.pop("npl_class", -1)))
        if rule_kwargs:
            raise TypeError(f"unknown select() options {sorted(rule_kwargs)}")
        surface = self.surface()
        res = select_mod.get_rule(rule)(surface, ctx)

        base_g, base_l = select_mod.argmin_winners(self.surf_loss)
        nonempty = self.mask_cells.sum(-1) > 0                 # (slots,)
        need = ((res.g_idx != base_g) | (res.l_idx != base_l)) \
            & nonempty[:, None, None]                          # (slots, T, S)

        coefs = self.coefs.copy()
        gamma, lam = self.gamma.copy(), self.lam.copy()
        val = self.val_loss.copy()
        n_tasks, n_sub = gamma.shape[1], gamma.shape[2]
        n_cols = n_tasks * n_sub
        if self.cv_cfg.solver in ("quantile", "expectile"):
            sub_grid = np.asarray(cfg.taus, np.float32)
        else:
            sub_grid = np.asarray(cfg.weights, np.float32)
        stats = {"rule": rule, "grid_columns": surface.grid_columns,
                 "winners_moved": int(need.sum()),
                 "columns_resolved": 0, "resolve_calls": 0,
                 "solver_iters": 0}

        from repro import obs
        m_resolved = obs.metrics.counter("select.columns_resolved")
        # group moved cells by winning gamma-grid INDEX: every cell in a
        # group re-solves in ONE vmapped launch, not one jit call per
        # (cell, gamma)
        groups: Dict[int, list] = {}
        for c in np.flatnonzero(need.any(axis=(1, 2))):
            for g in np.unique(res.g_idx[c][need[c]]):
                groups.setdefault(int(g), []).append(int(c))
        for g, cells in sorted(groups.items()):
            ts_of, pads = {}, {}
            lam_b, sub_b, task_b, c0_b = [], [], [], []
            for c in cells:
                ts = np.argwhere(need[c] & (res.g_idx[c] == g))  # (m, 2)
                ts_of[c] = ts
                # pad to the static (T*S) width: one compiled shape for
                # every re-selection of this fit
                pad = np.concatenate(
                    [ts, np.repeat(ts[:1], n_cols - len(ts), axis=0)])
                pads[c] = pad
                lam_b.append(self.lambdas[res.l_idx[c, pad[:, 0],
                                                    pad[:, 1]]])
                sub_b.append(sub_grid[pad[:, 1]])
                task_b.append(pad[:, 0])
                # warm start: the cached argmin model of the SAME (task,
                # sub) column — the nearest solved grid column; box-clipped
                # to the new (lambda, weight) box inside the solver
                c0_b.append(self.coefs[c][:, pad[:, 0], pad[:, 1]])
            with obs.tracer.span("select.resolve") as sp:
                sp.set(gamma_idx=int(g), cells=len(cells),
                       columns=int(sum(len(ts_of[c]) for c in cells)))
                out, iters, _ = cv_mod.solve_columns_batched(
                    jnp.asarray(self.x_cells[cells]),
                    jnp.asarray(self.y_cells[cells]),
                    jnp.asarray(self.tmask_cells[cells]),
                    jnp.asarray(self.mask_cells[cells]),
                    jnp.asarray(self.gammas_cells[cells, g]),
                    jnp.asarray(np.stack(lam_b), jnp.float32),
                    jnp.asarray(np.stack(sub_b), jnp.float32),
                    jnp.asarray(np.stack(task_b), jnp.int32),
                    jnp.asarray(self.fold_keys[cells]),
                    jnp.asarray(np.stack(c0_b), jnp.float32),
                    self.cv_cfg)                         # (C, k, T*S), (C,)
                out = np.asarray(out)
            for i, c in enumerate(cells):
                for j, (t, s) in enumerate(ts_of[c]):
                    coefs[c, :, t, s] = out[i, :, j]
                    gamma[c, t, s] = self.gammas_cells[c, g]
                    lam[c, t, s] = self.lambdas[res.l_idx[c, t, s]]
                    val[c, t, s] = self.surf_loss[c, g, t,
                                                  res.l_idx[c, t, s], s]
                stats["columns_resolved"] += len(ts_of[c])
                m_resolved.inc(len(ts_of[c]))
            stats["resolve_calls"] += 1
            stats["solver_iters"] += int(np.asarray(iters).sum())

        return SelectResult(
            rule=rule, config=cfg, cv_cfg=self.cv_cfg, scaler=self.scaler,
            plan=self.plan, packed=self.packed, tasks=self.tasks,
            x_cells=self.x_cells, mask_cells=self.mask_cells,
            coefs=coefs, gamma=gamma, lam=lam, tau=self.tau.copy(),
            val_loss=val, extras=dict(res.extras), stats=stats,
            mesh=mesh, mesh_axes=mesh_axes)

    # ------------------------------------------------------ persistence
    _ARRAYS = ("lambdas", "gammas_cells", "fold_keys", "x_cells",
               "mask_cells", "y_cells", "tmask_cells", "coefs", "gamma",
               "lam", "tau", "val_loss", "surf_loss", "surf_fa", "surf_det")

    def save(self, ckpt_dir: str) -> str:
        tree = {k: getattr(self, k) for k in self._ARRAYS}
        tree.update(_ctx_tree(self.plan, self.packed, self.scaler, self.tasks))
        extra = _ctx_extra(self.config, self.cv_cfg, self.tasks, self.packed)
        extra.update(format=_TRAIN_FORMAT, n=self.n, d=self.d)
        return ckpt_mod.save_checkpoint(ckpt_dir, 0, tree, extra=extra,
                                        keep_last=0)

    @classmethod
    def load(cls, ckpt_dir: str) -> "TrainResult":
        tree, extra = _load_tree(ckpt_dir, _TRAIN_FORMAT)
        plan, packed, scaler, tasks = _ctx_from_tree(tree, extra)
        return cls(config=_cfg_from_json(SVMTrainerConfig, extra["config"]),
                   cv_cfg=_cfg_from_json(cv_mod.CVConfig, extra["cv_cfg"]),
                   scaler=scaler, plan=plan, packed=packed, tasks=tasks,
                   n=int(extra["n"]), d=int(extra["d"]),
                   **{k: tree[k] for k in cls._ARRAYS})


@dataclasses.dataclass
class SelectResult:
    """One selection outcome: final per-cell models + rule extras.

    Owns the test phase (``decision_function`` / ``predict`` /
    streaming ``test``) and the serving hand-off (``to_bank``).
    """
    rule: str
    config: SVMTrainerConfig
    cv_cfg: cv_mod.CVConfig
    scaler: Scaler
    plan: CellPlan
    packed: PackedCells
    tasks: TaskSet
    x_cells: np.ndarray
    mask_cells: np.ndarray
    coefs: np.ndarray          # (slots, k, T, S)
    gamma: np.ndarray          # (slots, T, S)
    lam: np.ndarray
    tau: np.ndarray
    val_loss: np.ndarray
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)
    stats: Dict[str, Any] = dataclasses.field(default_factory=dict)
    mesh: Optional[Mesh] = None
    mesh_axes: Optional[Tuple[str, ...]] = None

    # -------------------------------------------------------- test phase
    @property
    def default_sub(self) -> int:
        """The sub column predictions read: the NP weight pick when the
        rule produced one, else column 0."""
        if "np_weight_idx" in self.extras:
            return int(np.asarray(self.extras["np_weight_idx"]).reshape(-1)[0])
        return 0

    def decision_function(self, x_test: np.ndarray) -> np.ndarray:
        """(m, d) raw features -> (m, T, S) via Voronoi cell routing."""
        xt = self.scaler.transform(np.asarray(x_test, np.float32))
        cell_of = self.plan.route(xt)
        slot_of = self.packed.slot_of_cell[cell_of]
        n_slots = self.packed.n_slots
        g = group_rows(slot_of, n_slots)
        # bucket the padded row count so repeated chunked calls (streamed
        # evaluation) hit one compiled shape; extra all-zero rows are
        # computed-then-dropped (row-independent)
        m_pad = -(-g.m_max // 8) * 8
        xt_cells = np.zeros((n_slots, m_pad, xt.shape[1]), np.float32)
        xt_cells[g.slot, g.pos] = xt[g.rows]

        dec = np.asarray(predict_cells(
            jnp.asarray(xt_cells), jnp.asarray(self.x_cells),
            jnp.asarray(self.coefs), jnp.asarray(self.gamma),
            kernel=self.config.kernel,
            mesh=self.mesh, axis_names=self.mesh_axes))

        out = np.zeros((xt.shape[0],) + dec.shape[2:], np.float32)
        out[g.rows] = dec[g.slot, g.pos]
        return out

    def predict(self, x_test: np.ndarray) -> np.ndarray:
        return combine_decisions(
            self.decision_function(x_test), self.config.scenario,
            classes=self.tasks.classes, pairs=self.tasks.pairs,
            sub=self.default_sub)

    def test(self, x_test, y_test, chunk_size: Optional[int] = None
             ) -> TestResult:
        """Stream the scenario error over any array / path / ChunkSource."""
        sc = self.config.scenario
        src: ChunkSource = as_source(x_test)
        y = np.asarray(y_test)
        chunk = int(chunk_size or self.config.chunk_size)
        taus = np.asarray(self.config.taus, np.float32)
        err_sum, den = 0.0, 0
        fa = det = neg = pos = 0
        for lo, block in src.iter_chunks(chunk):
            pred = self.predict(block)
            yc = y[lo:lo + block.shape[0]]
            if sc in ("binary", "weighted", "npsvm"):
                err_sum += float((pred != np.sign(yc)).sum())
                den += yc.shape[0]
                fa += int(((pred > 0) & (yc < 0)).sum())
                det += int(((pred > 0) & (yc > 0)).sum())
                neg += int((yc < 0).sum())
                pos += int((yc > 0).sum())
            elif sc in ("ova", "ava"):
                err_sum += float((pred != yc).sum())
                den += yc.shape[0]
            elif sc == "quantile":
                r = yc[:, None] - pred
                err_sum += float(np.where(r >= 0, taus * r,
                                          (taus - 1) * r).sum())
                den += r.size
            elif sc == "expectile":
                r = yc[:, None] - pred
                err_sum += float(np.where(r >= 0, taus * r * r,
                                          (1 - taus) * r * r).sum())
                den += r.size
            elif sc == "ls":
                err_sum += float(((pred - yc) ** 2).sum())
                den += yc.shape[0]
            else:
                raise ValueError(sc)
        details: Dict[str, float] = {}
        if neg + pos:
            details = {"false_alarm": fa / max(neg, 1),
                       "detection": det / max(pos, 1)}
        return TestResult(error=err_sum / max(den, 1), n=src.n_rows,
                          details=details)

    # ----------------------------------------------------------- serving
    def to_bank(self, drop_tol: float | None = 0.0, dtype: str = "f32",
                dedup: bool = True, version: int = 0):
        """Compact into a serving ModelBank (cold-starts ``SVMEngine``).

        A ``VORONOI=5`` (overlap) fit records ``routing="overlap"`` in the
        bank, so the engine blends the 2 nearest cells' decisions by
        default — the 2-cell ownership the models were trained on.
        ``version`` tags the bank for hot swapping
        (``SVMEngine.swap_bank`` accepts strictly newer versions only).
        """
        from repro.serve.model_bank import _FAR, ModelBank
        n_slots = self.packed.n_slots
        d = self.x_cells.shape[2]
        centers = np.full((n_slots, d), _FAR, np.float32)
        for s, cid in enumerate(self.packed.order):
            if cid >= 0:
                centers[s] = self.plan.centers[cid]
        routing = "overlap" if self.config.cell_method == "overlap" \
            else "nearest"
        return ModelBank.from_cells(
            self.x_cells, self.mask_cells, self.coefs, self.gamma, centers,
            kernel=self.config.kernel, drop_tol=drop_tol, dtype=dtype,
            dedup=dedup,
            feat_mean=np.asarray(self.scaler.mean, np.float32),
            feat_std=np.asarray(self.scaler.std, np.float32),
            classes=self.tasks.classes, pairs=self.tasks.pairs,
            scenario=self.config.scenario, default_sub=self.default_sub,
            routing=routing, version=version)

    # ------------------------------------------------------ persistence
    _ARRAYS = ("x_cells", "mask_cells", "coefs", "gamma", "lam", "tau",
               "val_loss")
    _CELL_ARRAYS = ("x_cells", "mask_cells")   # the O(n·d) staged rows

    def save(self, ckpt_dir: str, train_ref: Optional[str] = None) -> str:
        """Persist the selection outcome.

        ``train_ref`` (a path relative to ``ckpt_dir``, e.g. ``"../train"``)
        skips re-writing the staged cell rows — the dominant O(n·d) arrays,
        identical for every re-selection of one fit — and records a
        reference to the TrainResult checkpoint that already holds them;
        the CLI uses this since ``train/`` always sits beside ``select/``.
        """
        skip = self._CELL_ARRAYS if train_ref is not None else ()
        tree = {k: getattr(self, k) for k in self._ARRAYS if k not in skip}
        tree.update(_ctx_tree(self.plan, self.packed, self.scaler, self.tasks))
        tree.update({f"extra_{k}": np.asarray(v)
                     for k, v in self.extras.items()})
        extra = _ctx_extra(self.config, self.cv_cfg, self.tasks, self.packed)
        extra.update(format=_SELECT_FORMAT, rule=self.rule, stats=self.stats,
                     train_ref=train_ref)
        return ckpt_mod.save_checkpoint(ckpt_dir, 0, tree, extra=extra,
                                        keep_last=0)

    @classmethod
    def load(cls, ckpt_dir: str) -> "SelectResult":
        tree, extra = _load_tree(ckpt_dir, _SELECT_FORMAT)
        plan, packed, scaler, tasks = _ctx_from_tree(tree, extra)
        extras = {k[len("extra_"):]: v for k, v in tree.items()
                  if k.startswith("extra_")}
        if extra.get("train_ref"):                 # cells live in train/
            ref = os.path.normpath(os.path.join(ckpt_dir, extra["train_ref"]))
            ref_tree, _ = _load_tree(ref, _TRAIN_FORMAT)
            for k in cls._CELL_ARRAYS:
                tree[k] = ref_tree[k]
        return cls(rule=extra["rule"],
                   config=_cfg_from_json(SVMTrainerConfig, extra["config"]),
                   cv_cfg=_cfg_from_json(cv_mod.CVConfig, extra["cv_cfg"]),
                   scaler=scaler, plan=plan, packed=packed, tasks=tasks,
                   extras=extras, stats=dict(extra.get("stats", {})),
                   **{k: tree[k] for k in cls._ARRAYS})


# ----------------------------------------------------------------- session
class SVM:
    """A staged session over one training set.

    ``x`` may be an (n, d) array or anything ``repro.pipeline`` can stream
    (memmap ``.npy`` path, npz shard list, custom ``ChunkSource``).  String
    config keys (the liquidSVM-style layer, see ``repro.api.config``) can
    be passed directly: ``SVM(x, y, scenario="binary", FOLDS=3)``.

    Token corpora: passing ``EMBED_ARCH`` (plus the other ``EMBED_*`` keys)
    flags ``x`` as a TOKEN source — it is wrapped with
    ``repro.embed.embed_source`` so training streams lazily-computed
    frozen-backbone embeddings.  ``y=None`` is accepted whenever ``x``
    carries its own labels (``repro.embed.LabeledSource`` or an
    ``EmbeddingSource`` built with ``labels=``): the label vector is then
    streamed from the source per wave instead of being required up front.
    """

    def __init__(self, x, y: Optional[np.ndarray] = None,
                 config: Optional[SVMTrainerConfig] = None,
                 mesh: Optional[Mesh] = None,
                 mesh_axes: Optional[Tuple[str, ...]] = None,
                 select_rule: Optional[str] = None,
                 select_kwargs: Optional[dict] = None,
                 serve_kwargs: Optional[dict] = None,
                 monitor_kwargs: Optional[dict] = None,
                 **config_keys):
        cfg = config or SVMTrainerConfig()
        sel_kw = dict(select_kwargs or {})
        srv_kw = dict(serve_kwargs or {})
        mon_kw = dict(monitor_kwargs or {})
        if config_keys:
            from repro.api.config import (apply_keys, split_embed_keys,
                                          split_monitor_keys, split_obs_keys,
                                          split_serve_keys)
            config_keys, key_obs = split_obs_keys(config_keys)
            if key_obs:
                from repro import obs
                obs.configure(**key_obs)
            config_keys, key_emb = split_embed_keys(config_keys)
            if key_emb:
                from repro.embed import embed_source
                x = embed_source(x, **key_emb)
            config_keys, key_mon = split_monitor_keys(config_keys)
            mon_kw = {**key_mon, **mon_kw}
            config_keys, key_srv = split_serve_keys(config_keys)
            srv_kw = {**key_srv, **srv_kw}
            cfg, key_sel = apply_keys(cfg, config_keys)
            sel_kw.update(key_sel)
        self.config = cfg
        self.mesh, self.mesh_axes = mesh, mesh_axes
        self.select_rule = select_rule
        self.select_kwargs = sel_kw
        self.serve_kwargs = srv_kw
        self.monitor_kwargs = mon_kw
        self._x, self._y = x, y
        self.train_result: Optional[TrainResult] = None
        self.select_result: Optional[SelectResult] = None

    # ------------------------------------------------------------- train
    def train(self, ckpt_dir: Optional[str] = None) -> TrainResult:
        """Solve the full fold x grid over all cells (wave-scheduled) and
        retain the validation surface.  ``ckpt_dir``: per-wave resume."""
        cfg = self.config
        x, y = self._x, self._y
        if y is None:
            if not hasattr(x, "labels_vector"):
                raise ValueError(
                    "SVM(y=None) needs a label-carrying x source "
                    "(repro.embed.LabeledSource, or an EmbeddingSource "
                    "built with labels=...) — plain feature sources "
                    "require an explicit y")
            # labels stream from the source: O(n) scalars assembled
            # chunk-by-chunk, never a caller-held per-shard copy
            y = x.labels_vector(cfg.chunk_size)

        raw_src: ChunkSource = as_source(x)
        if cfg.scale:
            scaler = Scaler.fit_stream(raw_src, cfg.chunk_size)
        else:
            scaler = Scaler(mean=np.zeros(raw_src.dim, np.float32),
                            std=np.ones(raw_src.dim, np.float32))
        if isinstance(raw_src, ArraySource):     # in-memory: scale once
            xs_src: ChunkSource = ArraySource(
                scaler.transform(raw_src.materialize()))
        else:                                    # out-of-core: scale lazily
            xs_src = ScaledSource(raw_src, scaler.mean, scaler.std)
        n, d = xs_src.shape

        scenario = "weighted" if cfg.scenario in ("weighted", "npsvm") \
            else cfg.scenario
        tasks: TaskSet = make_tasks(y, scenario, taus=cfg.taus,
                                    weights=cfg.weights)

        n_dev = 1
        if self.mesh is not None and self.mesh_axes is not None:
            n_dev = int(np.prod([self.mesh.shape[a] for a in self.mesh_axes]))
        plan: CellPlan = build_cells_stream(
            xs_src, cell_size=cfg.cell_size, method=cfg.cell_method,
            seed=cfg.seed, chunk_size=cfg.chunk_size)
        packed: PackedCells = pack_cells(plan, n_dev)

        k = plan.k_max
        n_slots = packed.n_slots
        t_count = tasks.n_tasks
        cv_cfg = cv_mod.CVConfig(
            solver=cfg.resolve_solver(), kernel=cfg.kernel,
            n_folds=cfg.n_folds, fold_scheme=cfg.fold_scheme, tol=cfg.tol,
            max_iters=cfg.max_iters, taus=cfg.taus, weights=cfg.weights,
            keep_surface=True, cd_polish=cfg.cd_polish)

        base_grid = grids.liquid_grid(n=k, dim=d, median_dist=1.0,
                                      grid_choice=cfg.grid_choice,
                                      cell_size=cfg.cell_size)
        if cfg.adaptivity_control > 0:
            base_grid = grids.adaptive_subgrid(base_grid,
                                               cfg.adaptivity_control)
        n_gamma = len(base_grid.gammas)
        keys_all = np.asarray(
            jax.random.split(jax.random.PRNGKey(cfg.seed), n_slots))

        # the model + re-solve context: stage() fills these as a side effect
        # so the source is read ONCE; slots of checkpoint-restored waves are
        # back-filled afterwards (same deterministic computation).
        x_cells = np.zeros((n_slots, k, d), np.float32)
        mask_cells = np.zeros((n_slots, k), np.float32)
        y_cells = np.zeros((n_slots, t_count, k), np.float32)
        tmask_cells = np.zeros((n_slots, t_count, k), np.float32)
        gam_cells = np.ones((n_slots, n_gamma), np.float32)
        staged = np.zeros(n_slots, bool)

        def cell_gammas(x_c: np.ndarray, m: np.ndarray) -> np.ndarray:
            # per-cell adaptive gamma endpoints (paper: grid scaled per cell)
            med = float(kernel_fns.median_heuristic(jnp.asarray(x_c),
                                                    jnp.asarray(m)))
            g = grids.liquid_grid(n=int(m.sum()), dim=d, median_dist=med,
                                  grid_choice=cfg.grid_choice,
                                  cell_size=cfg.cell_size)
            if cfg.adaptivity_control > 0:
                g = grids.adaptive_subgrid(g, cfg.adaptivity_control)
            return np.asarray(g.gammas, np.float32)

        def stage(lo: int, hi: int):
            """Host arrays for slots [lo, hi) ONLY — O(wave) staging.

            Slots past n_slots (wave padding) stay empty: zero masks, unit
            gammas, zero keys — the same shape the planner's -1 slots get.
            """
            w = hi - lo
            x_w = np.zeros((w, k, d), np.float32)
            mask_w = np.zeros((w, k), np.float32)
            y_w = np.zeros((w, t_count, k), np.float32)
            tmask_w = np.zeros((w, t_count, k), np.float32)
            gam_w = np.ones((w, n_gamma), np.float32)
            keys_w = np.zeros((w,) + keys_all.shape[1:], keys_all.dtype)
            keys_w[: max(min(hi, n_slots) - lo, 0)] = keys_all[lo:hi]
            for j, s in enumerate(range(lo, min(hi, n_slots))):
                staged[s] = True
                cid = packed.order[s]
                if cid < 0:
                    continue
                ids = plan.indices[cid]
                m = plan.mask[cid]
                x_w[j] = xs_src.gather(ids)
                mask_w[j] = m
                y_w[j] = tasks.labels[:, ids] * m[None, :]
                tmask_w[j] = tasks.task_mask[:, ids] * m[None, :]
                gam_w[j] = cell_gammas(x_w[j], m)
                x_cells[s], mask_cells[s] = x_w[j], m
                y_cells[s], tmask_cells[s] = y_w[j], tmask_w[j]
                gam_cells[s] = gam_w[j]
            return x_w, y_w, tmask_w, mask_w, gam_w, keys_w

        lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(
            base_grid, cv_cfg, t_count)

        fingerprint = self._fingerprint(cv_cfg, plan, tasks, n, d)
        (coefs, gamma, lam, tau, val,
         surf_loss, surf_fa, surf_det) = train_cells_waves(
            stage, n_slots, cfg.n_slots_per_wave,
            lam_c, sub_c, task_c, cv_cfg, n_lam, n_sub,
            mesh=self.mesh, axis_names=self.mesh_axes, ckpt_dir=ckpt_dir,
            fingerprint=fingerprint)

        for s in np.flatnonzero(~staged):   # waves restored from checkpoint
            cid = packed.order[s]
            if cid >= 0:
                ids = plan.indices[cid]
                m = plan.mask[cid]
                x_cells[s] = xs_src.gather(ids)
                mask_cells[s] = m
                y_cells[s] = tasks.labels[:, ids] * m[None, :]
                tmask_cells[s] = tasks.task_mask[:, ids] * m[None, :]
                gam_cells[s] = cell_gammas(x_cells[s], m)

        self.train_result = TrainResult(
            config=cfg, cv_cfg=cv_cfg, scaler=scaler, plan=plan,
            packed=packed, tasks=tasks,
            lambdas=np.asarray(base_grid.lambdas, np.float32),
            gammas_cells=gam_cells, fold_keys=keys_all,
            x_cells=x_cells, mask_cells=mask_cells,
            y_cells=y_cells, tmask_cells=tmask_cells,
            coefs=np.asarray(coefs), gamma=np.asarray(gamma),
            lam=np.asarray(lam), tau=np.asarray(tau),
            val_loss=np.asarray(val), surf_loss=np.asarray(surf_loss),
            surf_fa=np.asarray(surf_fa), surf_det=np.asarray(surf_det),
            n=n, d=d)
        self.select_result = None
        return self.train_result

    def _fingerprint(self, cv_cfg, plan: CellPlan, tasks: TaskSet,
                     n: int, d: int) -> str:
        """Identity of this fit for wave-checkpoint resume: config, data
        layout (cell plan) and labels — a stale ckpt_dir from a different
        run must be rejected, not silently restored."""
        import hashlib
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(self.config).encode())
        h.update(repr(cv_cfg).encode())
        h.update(np.int64([n, d]).tobytes())
        h.update(plan.indices.tobytes())
        h.update(plan.mask.tobytes())
        h.update(plan.centers.tobytes())
        h.update(np.ascontiguousarray(tasks.labels).tobytes())
        return h.hexdigest()

    # ------------------------------------------------------------ select
    def select(self, rule: Optional[str] = None, **rule_kwargs
               ) -> SelectResult:
        """Pick hyper-parameters over the retained surface (re-runnable)."""
        if self.train_result is None:
            raise RuntimeError("call train() before select()")
        merged = {**self.select_kwargs, **rule_kwargs}
        self.select_result = self.train_result.select(
            rule or self.select_rule, mesh=self.mesh,
            mesh_axes=self.mesh_axes, **merged)
        return self.select_result

    # -------------------------------------------------------------- test
    def test(self, x_test, y_test,
             chunk_size: Optional[int] = None) -> TestResult:
        """Streamed scenario error; selects with the session default rule
        first if select() has not been called."""
        if self.select_result is None:
            self.select()
        return self.select_result.test(x_test, y_test, chunk_size=chunk_size)

    # ------------------------------------------------------------- serve
    def engine(self, **engine_kwargs):
        """Compact the selection into a bank and build an ``SVMEngine``.

        Serve-stage string keys given at session construction
        (``SERVE_OVERLAP``, ``DEADLINE_MS``) carry through here; explicit
        ``engine_kwargs`` win.  Selects with the session default rule first
        if ``select()`` has not been called.
        """
        if self.select_result is None:
            self.select()
        from repro.serve.svm_engine import SVMEngine
        return SVMEngine(self.select_result.to_bank(),
                         **{**self.serve_kwargs, **engine_kwargs})

    def monitor(self, engine, **monitor_kwargs):
        """Attach a :class:`repro.serve.HealthMonitor` to an engine.

        Monitor-stage string keys given at session construction
        (``SLO_P99_MS``, ``DRIFT_WINDOW``, ``DRIFT_REFRESH_THRESHOLD``)
        carry through here; explicit ``monitor_kwargs`` win.
        """
        from repro.serve.monitor import HealthMonitor
        return HealthMonitor(engine,
                             **{**self.monitor_kwargs, **monitor_kwargs})
