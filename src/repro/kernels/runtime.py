"""Backend-aware Pallas execution mode.

Every kernel wrapper takes an ``interpret`` knob.  Historically it defaulted
to ``True`` (safe everywhere, slow); the correct default depends on where we
run: on a real TPU the Mosaic-compiled kernel must execute natively, anywhere
else (CPU CI, GPU hosts) only the interpreter can run the kernel body.

All ``ops.py`` entry points now accept ``interpret=None`` meaning "resolve
against the actual backend at trace time" via :func:`resolve_interpret`.
Passing an explicit bool still wins (tests force ``interpret=True`` to
validate kernel bodies off-TPU).
"""
from __future__ import annotations

import jax


def on_tpu() -> bool:
    """True when jax will dispatch to a real TPU backend."""
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    """Resolve the tri-state interpret knob.

    ``None``  -> auto: native on TPU, interpreter elsewhere.
    ``True``  -> interpreter, except on TPU where native is always correct
                 (and the interpreter is not supported on device).
    ``False`` -> native Mosaic compilation (only valid on TPU).
    """
    if interpret is None:
        return not on_tpu()
    return bool(interpret) and not on_tpu()
