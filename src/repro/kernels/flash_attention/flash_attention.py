"""Pallas TPU kernel: flash attention (causal / sliding-window / bidir).

Online-softmax tiling: grid (B*H, T/bq, S/bk) with the kv axis innermost
and sequential; running max m, normalizer l, and the output accumulator
live in VMEM scratch across the kv sweep.  Fully-masked tiles (kv block
entirely in the causal future, or entirely outside the sliding window) are
skipped with pl.when so the causal/window cost is the true masked FLOPs.

The kernel handles one q-head per grid row; GQA mapping (repeat kv heads)
is done by ops.py.  D is padded to the 128 lane width by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30
BLOCK_Q = 128
BLOCK_K = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  mask_kind: str, window: int, scale: float,
                  t_total: int, s_total: int, block_q: int, block_k: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(kj == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # global row/col coordinates in REAL (unpadded) terms; q rows are offset
    # so the final real q row attends to the final real kv row (decode
    # alignment).  t_total/s_total are the real lengths; the grid may cover
    # right-padded blocks whose rows are sliced off by ops.py.
    row0 = qi * block_q + (s_total - t_total)
    col0 = kj * block_k

    def compute():
        q = q_ref[0].astype(jnp.float32)        # (bq, d)
        k = k_ref[0].astype(jnp.float32)        # (bk, d)
        v = v_ref[0].astype(jnp.float32)        # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        cols = col0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = cols < s_total                    # padded kv columns never visible
        if mask_kind in ("causal", "window"):
            mask &= rows >= cols
            if mask_kind == "window":
                mask &= (rows - cols) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                      # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                   # (bq, bk)
        alpha = jnp.exp(m_prev - m_new)          # (bq, 1)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if mask_kind in ("causal", "window"):
        # skip tiles entirely above the diagonal (and, for windows,
        # entirely left of the band)
        visible = (col0 <= row0 + block_q - 1) & (col0 < s_total)
        if mask_kind == "window":
            visible &= (col0 + block_k - 1) >= (row0 - window + 1)
        pl.when(visible)(compute)
    else:
        pl.when(col0 < s_total)(compute)

    @pl.when(kj == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "mask_kind", "window", "scale", "t_real", "s_real", "interpret"))
def flash_attention_pallas(q: Array, k: Array, v: Array, mask_kind: str = "causal",
                           window: int = 0, scale: float | None = None,
                           t_real: int | None = None, s_real: int | None = None,
                           interpret: bool = True) -> Array:
    """q (BH, T, D), k/v (BH, S, D); T % BLOCK_Q == S % BLOCK_K == 0.

    t_real/s_real are the unpadded lengths used for mask coordinates.
    """
    bh, t, d = q.shape
    s_len = k.shape[1]
    t_real = t if t_real is None else t_real
    s_real = s_len if s_real is None else s_real
    scale = float(d ** -0.5) if scale is None else scale
    grid = (bh, t // BLOCK_Q, s_len // BLOCK_K)
    return pl.pallas_call(
        functools.partial(
            _flash_kernel, mask_kind=mask_kind, window=window, scale=scale,
            t_total=t_real, s_total=s_real, block_q=BLOCK_Q, block_k=BLOCK_K),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, BLOCK_Q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, BLOCK_K, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, BLOCK_K, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_Q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, t, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, 1), jnp.float32),
            pltpu.VMEM((BLOCK_Q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
