"""jit'd wrapper: GQA head mapping, padding, backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import (
    BLOCK_K, BLOCK_Q, flash_attention_pallas)

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("mask_kind", "window", "force_pallas", "interpret"))
def flash_attention(q: Array, k: Array, v: Array, mask_kind: str = "causal",
                    window: int = 0, force_pallas: bool = False,
                    interpret: bool | None = None) -> Array:
    """q (B, T, H, D); k, v (B, S, Hk, D); returns (B, T, H, D)."""
    if not (force_pallas or runtime.on_tpu()):
        return ref.flash_attention_ref(q, k, v, mask_kind, window)

    b, t, h, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    k = jnp.repeat(k, g, axis=2)
    v = jnp.repeat(v, g, axis=2)
    scale = float(d ** -0.5)

    pad_t, pad_s, pad_d = (-t) % BLOCK_Q, (-s) % BLOCK_K, (-d) % 128
    qp = jnp.pad(q, ((0, 0), (0, pad_t), (0, 0), (0, pad_d)))
    kp = jnp.pad(k, ((0, 0), (0, pad_s), (0, 0), (0, pad_d)))
    vp = jnp.pad(v, ((0, 0), (0, pad_s), (0, 0), (0, pad_d)))
    # right-padding everywhere; the kernel masks in REAL coordinates
    # (t_real/s_real) so padded kv columns are never attended and padded q
    # rows are sliced off below.
    qp = qp.transpose(0, 2, 1, 3).reshape(b * h, t + pad_t, d + pad_d)
    kp = kp.transpose(0, 2, 1, 3).reshape(b * h, s + pad_s, d + pad_d)
    vp = vp.transpose(0, 2, 1, 3).reshape(b * h, s + pad_s, d + pad_d)
    use_interpret = runtime.resolve_interpret(interpret)
    out = flash_attention_pallas(qp, kp, vp, mask_kind=mask_kind, window=window,
                                 scale=scale, t_real=t, s_real=s,
                                 interpret=use_interpret)
    out = out.reshape(b, h, t + pad_t, d + pad_d)[:, :, :t, :d]
    return out.transpose(0, 2, 1, 3)
