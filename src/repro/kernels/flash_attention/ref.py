"""Pure-jnp oracle: masked softmax attention with GQA.

q (B, T, H, D); k, v (B, S, Hk, D) with H % Hk == 0.
mask kinds: "causal" (row >= col, offset so the last q row attends to the
last kv row), "window" (causal AND row - col < window), "bidir".
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def attention_mask(t: int, s: int, kind: str, window: int = 0) -> Array:
    rows = jnp.arange(t)[:, None] + (s - t)  # align last q row to last kv row
    cols = jnp.arange(s)[None, :]
    if kind == "bidir":
        return jnp.ones((t, s), bool)
    causal = rows >= cols
    if kind == "causal":
        return causal
    if kind == "window":
        return causal & (rows - cols < window)
    raise ValueError(kind)


def flash_attention_ref(q: Array, k: Array, v: Array, mask_kind: str = "causal",
                        window: int = 0, scale: float | None = None) -> Array:
    b, t, h, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    scale = (d ** -0.5) if scale is None else scale
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    logits = jnp.einsum("bthgd,bshd->bhgts", qf, kf) * scale
    m = attention_mask(t, s, mask_kind, window)
    logits = jnp.where(m[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgts,bshd->bthgd", p, vf)
    return out.reshape(b, t, h, d).astype(q.dtype)
