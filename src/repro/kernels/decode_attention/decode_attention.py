"""Pallas TPU kernel: fused decode attention over an (optionally int8) KV
cache — the serving hot loop behind the §Perf kv-int8 hillclimb.

One new token attends to a full ring-buffer cache.  TPU adaptation:
the cache streams HBM->VMEM one (C, D) tile at a time **in its stored
dtype** (int8 tiles move 2x fewer bytes than bf16 — this kernel is what
makes the roofline's fused-dequant byte accounting real); dequantization
(x * scale) happens in VMEM registers right before the MXU matmuls.
Online softmax (running max/sum scratch) across the sequential S grid
axis, exactly like flash decoding; the cross-device merge for a
sequence-sharded cache is XLA's all-reduce outside this kernel.

Grid: (B * Hk, S / BLOCK_S); per program: q tile (G, D) resident in VMEM,
kv tiles (BLOCK_S, D) streamed, accumulator (G, D) f32 in scratch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

NEG_INF = -1e30
BLOCK_S = 256


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, s_total: int,
                   block_s: int, window: int, quantized: bool):
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    pos = pos_ref[0]

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                    # (G, D)
    k = k_ref[0].astype(jnp.float32)                    # (C, D)
    v = v_ref[0].astype(jnp.float32)
    if quantized:
        k = k * ks_ref[0].astype(jnp.float32)           # (C, 1) scales
        v = v * vs_ref[0].astype(jnp.float32)

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
    idx = j * block_s + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
    valid = (idx < s_total) & ((idx <= pos) | (pos >= s_total))
    if window > 0:
        age = jnp.remainder(pos - idx, s_total)
        valid &= age < window
    logits = jnp.where(valid, logits, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(logits, axis=1, keepdims=True))
    p = jnp.exp(logits - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nj - 1)
    def _done():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "window", "s_real",
                                              "interpret"))
def decode_attention_pallas(q: Array, k_cache: Array, v_cache: Array,
                            k_scale: Array, v_scale: Array, cache_pos: Array,
                            scale: float, window: int = 0,
                            s_real: Optional[int] = None,
                            interpret: bool = True) -> Array:
    """q (BH, G, D); caches (BH, S, D) (+ scales (BH, S, 1)); S % 256 == 0.

    BH = B * Hk (one kv head per grid row); G = query heads per kv head.
    """
    bh, g, d = q.shape
    s = k_cache.shape[1]
    s_real = s if s_real is None else s_real
    quantized = k_cache.dtype == jnp.int8
    pos = jnp.asarray(cache_pos, jnp.int32).reshape(1)
    grid = (bh, s // BLOCK_S)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, s_total=s_real,
                          block_s=BLOCK_S, window=window, quantized=quantized),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                 # pos
            pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),       # q
            pl.BlockSpec((1, BLOCK_S, d), lambda b, j: (b, j, 0)),  # k tile
            pl.BlockSpec((1, BLOCK_S, d), lambda b, j: (b, j, 0)),  # v tile
            pl.BlockSpec((1, BLOCK_S, 1), lambda b, j: (b, j, 0)),  # k scale
            pl.BlockSpec((1, BLOCK_S, 1), lambda b, j: (b, j, 0)),  # v scale
        ],
        out_specs=pl.BlockSpec((1, g, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, g, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos, q, k_cache, v_cache, k_scale, v_scale)
