"""jit'd wrapper: layout/padding + backend dispatch for fused decode."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.decode_attention import ref
from repro.kernels.decode_attention.decode_attention import (
    BLOCK_S, decode_attention_pallas)

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("scale", "window",
                                              "force_pallas", "interpret"))
def decode_attention_fused(q: Array, k_cache: Array, v_cache: Array,
                           cache_pos: Array, scale: float,
                           k_scale: Optional[Array] = None,
                           v_scale: Optional[Array] = None,
                           window: int = 0, force_pallas: bool = False,
                           interpret: bool | None = None) -> Array:
    """q (B, Hk, G, D); caches (B, S, Hk, D) [+ scales (B, S, Hk, 1)].

    Streams the cache in its stored dtype (int8 halves HBM traffic),
    dequantizes in VMEM.  Returns (B, Hk, G, D).
    """
    if not (force_pallas or runtime.on_tpu()):
        return ref.decode_attention_ref(q, k_cache, v_cache, cache_pos,
                                        scale, k_scale, v_scale, window)
    b, hk, g, d = q.shape
    s = k_cache.shape[1]
    pad_s = (-s) % BLOCK_S
    quantized = k_cache.dtype == jnp.int8
    if k_scale is None:
        k_scale = jnp.ones((b, s, hk, 1), jnp.float32)
        v_scale = jnp.ones((b, s, hk, 1), jnp.float32)

    def to_bh(x):  # (B, S, Hk, X) -> (B*Hk, S+pad, X)
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(b * hk, s + pad_s, x.shape[-1])

    qf = q.reshape(b * hk, g, d)
    out = decode_attention_pallas(
        qf, to_bh(k_cache), to_bh(v_cache), to_bh(k_scale), to_bh(v_scale),
        cache_pos, scale=scale, window=window, s_real=s,
        interpret=runtime.resolve_interpret(interpret))
    return out.reshape(b, hk, g, d)
