"""Pure-jnp oracle for fused decode attention (optionally int8 KV)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

Array = jax.Array

NEG_INF = -1e30


def decode_attention_ref(q: Array, k_cache: Array, v_cache: Array,
                         cache_pos: Array, scale: float,
                         k_scale: Optional[Array] = None,
                         v_scale: Optional[Array] = None,
                         window: int = 0) -> Array:
    """q (B, Hk, G, D); caches (B, S, Hk, D) [+ (B, S, Hk, 1) scales].
    Returns (B, Hk, G, D).  Ring-buffer validity from cache_pos."""
    b, hk, g, d = q.shape
    s = k_cache.shape[1]
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    if k_scale is not None:
        kf = kf * k_scale
        vf = vf * v_scale
    logits = jnp.einsum("bhgd,bshd->bhgs", q.astype(jnp.float32), kf) * scale
    idx = jnp.arange(s)
    valid = (idx <= cache_pos) | (cache_pos >= s)
    if window > 0:
        age = jnp.mod(cache_pos - idx, s)
        valid &= age < window
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bshd->bhgd", p, vf).astype(q.dtype)
