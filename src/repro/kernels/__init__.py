# TPU Pallas kernels for liquidSVM's compute hot spots (the parts the paper
# implements with SIMD/CUDA):
#   kernel_matrix    — tiled Gram-matrix computation (MXU cross term)
#   cd_solver        — in-VMEM (block) Gauss-Seidel coordinate descent sweep
#   svm_predict      — fused K(test, SV) @ coefs evaluation, no Gram in HBM
#   flash_attention  — causal/windowed/bidirectional flash for the LM stack
# Each package ships <name>.py (pallas_call + BlockSpec), ops.py (jit'd
# dispatching wrapper), ref.py (pure-jnp oracle used by tests).
