"""jit'd wrapper: pads to the coordinate block, runs E epochs, dispatches
Pallas on TPU / interpret validation elsewhere, with the jnp oracle as the
default CPU production path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.cd_solver import ref
from repro.kernels.cd_solver.cd_solver import BLOCK_COORDS, cd_epoch_pallas

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("epochs", "force_pallas", "interpret"))
def cd_epochs(k_mat: Array, y: Array, lo: Array, hi: Array, c0: Array,
              epochs: int = 1, force_pallas: bool = False,
              interpret: bool | None = None) -> Array:
    """Run `epochs` Gauss-Seidel sweeps on min 0.5 c'Kc - c'y, lo<=c<=hi.

    k_mat (n, n); y (n,) or (n, P); lo/hi/c0 (n, P).  Returns c (n, P).
    Padding coordinates must have lo == hi == 0 (they then never move and
    contribute nothing to g).
    """
    n = k_mat.shape[0]
    if y.ndim == 1:
        y = y[:, None]
    p = c0.shape[1]
    y = jnp.broadcast_to(y.astype(jnp.float32), (n, p))

    use_pallas = force_pallas or runtime.on_tpu()
    if not use_pallas:
        c, _ = ref.solve_cd_ref(k_mat, y, lo, hi, c0, epochs)
        return c

    pad = (-n) % BLOCK_COORDS
    if pad:
        k_mat = jnp.pad(k_mat, ((0, pad), (0, pad)))
        # padded diag 0 -> guarded by max(d, eps); box [0,0] pins c at 0
        y = jnp.pad(y, ((0, pad), (0, 0)))
        lo = jnp.pad(lo, ((0, pad), (0, 0)))
        hi = jnp.pad(hi, ((0, pad), (0, 0)))
        c0 = jnp.pad(c0, ((0, pad), (0, 0)))
    g0 = k_mat @ c0 - y
    use_interpret = runtime.resolve_interpret(interpret)

    def body(_, state):
        return cd_epoch_pallas(k_mat, state[0], state[1], lo, hi,
                               interpret=use_interpret)

    c, _ = jax.lax.fori_loop(0, epochs, body, (c0, g0))
    return c[:n]
