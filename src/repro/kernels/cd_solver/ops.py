"""jit'd wrappers: pad to the coordinate block, run E epochs, dispatch
Pallas on TPU / interpret validation elsewhere, with the jnp oracle as the
default CPU production path.

Three entry points:

* :func:`cd_epochs`        — one cell, the original per-slot launch;
* :func:`cd_epochs_wave`   — a whole wave of slots in ONE launch (the
  fused path ``train_cells_waves`` amortizes dispatch over — see the
  wave-fusion contract in ``cd_solver.py``);
* :func:`cd_polish`        — unjitted epoch loop callable from INSIDE an
  outer jit (``repro.core.cv`` runs it after FISTA, per gamma); under
  ``train_cells``'s vmap over slots the per-cell polish batches into the
  same wave-fused execution.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.cd_solver import ref
from repro.kernels.cd_solver.cd_solver import (
    BLOCK_COORDS, cd_epoch_pallas, cd_wave_epoch_pallas)

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("epochs", "force_pallas", "interpret"))
def cd_epochs(k_mat: Array, y: Array, lo: Array, hi: Array, c0: Array,
              epochs: int = 1, force_pallas: bool = False,
              interpret: bool | None = None) -> Array:
    """Run `epochs` Gauss-Seidel sweeps on min 0.5 c'Kc - c'y, lo<=c<=hi.

    k_mat (n, n); y (n,) or (n, P); lo/hi/c0 (n, P).  Returns c (n, P).
    Padding coordinates must have lo == hi == 0 (they then never move and
    contribute nothing to g).
    """
    n = k_mat.shape[0]
    if y.ndim == 1:
        y = y[:, None]
    p = c0.shape[1]
    y = jnp.broadcast_to(y.astype(jnp.float32), (n, p))

    use_pallas = force_pallas or runtime.on_tpu()
    if not use_pallas:
        c, _ = ref.solve_cd_ref(k_mat, y, lo, hi, c0, epochs)
        return c

    pad = (-n) % BLOCK_COORDS
    if pad:
        k_mat = jnp.pad(k_mat, ((0, pad), (0, pad)))
        # padded diag 0 -> guarded by max(d, eps); box [0,0] pins c at 0
        y = jnp.pad(y, ((0, pad), (0, 0)))
        lo = jnp.pad(lo, ((0, pad), (0, 0)))
        hi = jnp.pad(hi, ((0, pad), (0, 0)))
        c0 = jnp.pad(c0, ((0, pad), (0, 0)))
    g0 = k_mat @ c0 - y
    use_interpret = runtime.resolve_interpret(interpret)

    def body(_, state):
        return cd_epoch_pallas(k_mat, state[0], state[1], lo, hi,
                               interpret=use_interpret)

    c, _ = jax.lax.fori_loop(0, epochs, body, (c0, g0))
    return c[:n]


@functools.partial(jax.jit, static_argnames=("epochs", "force_pallas", "interpret"))
def cd_epochs_wave(k_mats: Array, y: Array, lo: Array, hi: Array, c0: Array,
                   epochs: int = 1, force_pallas: bool = False,
                   interpret: bool | None = None) -> Array:
    """Wave-fused :func:`cd_epochs`: S slots in one launch per epoch.

    k_mats (S, n, n); y (S, n) or (S, n, P); lo/hi/c0 (S, n, P).  Returns
    c (S, n, P).  Same coordinate order and fixed point as calling
    :func:`cd_epochs` slot by slot; on TPU the Pallas wave kernel
    reproduces the per-slot sweep bit-for-bit in ONE launch, while the
    off-TPU path additionally uses delayed trailing updates
    (``ref.cd_epoch_wave_blocked_ref``) so the bulk work runs as batched
    GEMMs — per-slot parity is then f32-rounding-level, within solver
    tolerance.
    """
    s, n = k_mats.shape[:2]
    if y.ndim == 2:
        y = y[:, :, None]
    p = c0.shape[2]
    y = jnp.broadcast_to(y.astype(jnp.float32), (s, n, p))

    use_pallas = force_pallas or runtime.on_tpu()
    if not use_pallas:
        pad = (-n) % ref.WAVE_BLOCK
        if pad:
            k_mats = jnp.pad(k_mats, ((0, 0), (0, pad), (0, pad)))
            y = jnp.pad(y, ((0, 0), (0, pad), (0, 0)))
            lo = jnp.pad(lo, ((0, 0), (0, pad), (0, 0)))
            hi = jnp.pad(hi, ((0, 0), (0, pad), (0, 0)))
            c0 = jnp.pad(c0, ((0, 0), (0, pad), (0, 0)))
        g0 = jnp.einsum("sij,sjp->sip", k_mats, c0) - y

        def body(_, state):
            return ref.cd_epoch_wave_blocked_ref(k_mats, state[0], state[1],
                                                 lo, hi)

        c, _ = jax.lax.fori_loop(0, epochs, body, (c0, g0))
        return c[:, :n]

    pad = (-n) % BLOCK_COORDS
    if pad:
        k_mats = jnp.pad(k_mats, ((0, 0), (0, pad), (0, pad)))
        y = jnp.pad(y, ((0, 0), (0, pad), (0, 0)))
        lo = jnp.pad(lo, ((0, 0), (0, pad), (0, 0)))
        hi = jnp.pad(hi, ((0, 0), (0, pad), (0, 0)))
        c0 = jnp.pad(c0, ((0, 0), (0, pad), (0, 0)))
    g0 = jnp.einsum("sij,sjp->sip", k_mats, c0) - y
    use_interpret = runtime.resolve_interpret(interpret)

    def body(_, state):
        return cd_wave_epoch_pallas(k_mats, state[0], state[1], lo, hi,
                                    interpret=use_interpret)

    c, _ = jax.lax.fori_loop(0, epochs, body, (c0, g0))
    return c[:, :n]


def cd_polish(k_mat: Array, y: Array, lo: Array, hi: Array, c0: Array,
              epochs: int) -> Array:
    """Polish a box-QP iterate with `epochs` Gauss-Seidel sweeps — callable
    from inside an outer jit (no nested-jit dispatch).

    k_mat (n, n) any float dtype (accumulation is f32); y/lo/hi/c0 (n, P).
    Warm starts are clipped into the box here (see
    ``repro.core.solvers.base.clip_warm_start`` for why that is safe).
    One epoch costs the same n²P flops as one FISTA iteration; Gauss-
    Seidel descent from a feasible start is monotone, so polishing a
    converged iterate can only tighten it.  Runs the delayed-update
    blocked sweep (``ref.cd_epoch_blocked_ref``); under vmap
    (``train_cells`` batches cells over the slot axis) the epoch loop
    executes wave-fused, matching :func:`cd_epochs_wave`.
    """
    n = k_mat.shape[0]
    k_mat = k_mat.astype(jnp.float32)
    y = y.astype(jnp.float32)
    lo = lo.astype(jnp.float32)
    hi = hi.astype(jnp.float32)
    c0 = jnp.clip(c0.astype(jnp.float32), lo, hi)
    pad = (-n) % ref.WAVE_BLOCK
    if pad:
        k_mat = jnp.pad(k_mat, ((0, pad), (0, pad)))
        y = jnp.pad(y, ((0, pad), (0, 0)))
        lo = jnp.pad(lo, ((0, pad), (0, 0)))
        hi = jnp.pad(hi, ((0, pad), (0, 0)))
        c0 = jnp.pad(c0, ((0, pad), (0, 0)))
    g0 = k_mat @ c0 - y

    def body(_, state):
        return ref.cd_epoch_blocked_ref(k_mat, state[0], state[1], lo, hi)

    c, _ = jax.lax.fori_loop(0, epochs, body, (c0, g0))
    return c[:n]
