"""Pure-jnp oracle: exact (batched-column) Gauss-Seidel coordinate descent.

One epoch sweeps coordinates 0..n-1 in order.  For each coordinate i the
update (vectorized over the P grid columns) is

    delta = clip(c_i - g_i / K_ii, lo_i, hi_i) - c_i
    c_i  += delta
    g    += K[:, i] (x) delta            (rank-1 gradient maintenance)

which is the classic liquidSVM/libsvm-style 1-D working-set step; the
Pallas kernel must reproduce this sequence bit-for-bit (same order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def cd_epoch_ref(k_mat: Array, c: Array, g: Array, lo: Array, hi: Array) -> tuple[Array, Array]:
    """k_mat (n, n); c, g, lo, hi (n, P).  Returns updated (c, g)."""
    n = k_mat.shape[0]
    diag = jnp.diag(k_mat)

    def body(i, state):
        c, g = state
        d = jnp.maximum(diag[i], 1e-12)
        ci = c[i]                      # (P,)
        target = jnp.clip(ci - g[i] / d, lo[i], hi[i])
        delta = target - ci
        c = c.at[i].add(delta)
        g = g + k_mat[:, i][:, None] * delta[None, :]
        return c, g

    return jax.lax.fori_loop(0, n, body, (c, g))


def solve_cd_ref(k_mat: Array, y: Array, lo: Array, hi: Array, c0: Array,
                 epochs: int) -> tuple[Array, Array]:
    g0 = k_mat @ c0 - y
    def body(_, state):
        return cd_epoch_ref(k_mat, state[0], state[1], lo, hi)
    return jax.lax.fori_loop(0, epochs, body, (c0, g0))


def solve_cd_wave_ref(k_mats: Array, y: Array, lo: Array, hi: Array,
                      c0: Array, epochs: int) -> tuple[Array, Array]:
    """Wave oracle: per-slot :func:`solve_cd_ref`, batched over the leading
    slot axis.  k_mats (S, n, n); y/lo/hi/c0 (S, n, P).  The fused Pallas
    wave kernel must reproduce each slot's sequence bit-for-bit."""
    return jax.vmap(solve_cd_ref, in_axes=(0, 0, 0, 0, 0, None))(
        k_mats, y, lo, hi, c0, epochs)


WAVE_BLOCK = 32  # delayed-update block width of the fused execution path


def cd_epoch_blocked_ref(k_mat: Array, c: Array, g: Array, lo: Array,
                         hi: Array, block: int = WAVE_BLOCK
                         ) -> tuple[Array, Array]:
    """One epoch with LAPACK-style delayed trailing updates.

    Identical coordinate order and fixed point as :func:`cd_epoch_ref`, but
    the rank-1 gradient maintenance is deferred: within a block of
    ``block`` coordinates only the BLOCK-LOCAL gradient is kept consistent
    (a (1, B) x (B, P) correction per step), and the trailing update for
    all n rows lands afterwards as ONE (n, B) x (B, P) GEMM.  The
    sequential part of the sweep shrinks from n.P to B.P elements per
    step and the bulk 2 n^2 P flops become matmul-shaped — this is the
    wave path's production execution strategy (MXU/BLAS work instead of n
    rank-1 passes).  Summation order differs from the exact sweep, so
    results match :func:`cd_epoch_ref` to f32 rounding, not bitwise.

    Requires ``n % block == 0`` (callers pad with lo == hi == 0, which
    keeps padded coordinates inert).
    """
    n, p = c.shape
    diag = jnp.diag(k_mat)

    def per_block(j, state):
        c, g = state
        base = j * block
        kb = jax.lax.dynamic_slice(k_mat, (0, base), (n, block))    # (n, B)
        kbb = jax.lax.dynamic_slice(kb, (base, 0), (block, block))  # (B, B)
        db = jax.lax.dynamic_slice(diag, (base,), (block,))
        g0 = jax.lax.dynamic_slice(g, (base, 0), (block, p))
        cb = jax.lax.dynamic_slice(c, (base, 0), (block, p))
        lob = jax.lax.dynamic_slice(lo, (base, 0), (block, p))
        hib = jax.lax.dynamic_slice(hi, (base, 0), (block, p))

        def inner(t, st):
            cb, delta = st
            # coord t's gradient = pre-block g + this block's earlier
            # deltas (rows >= t of delta are still zero)
            krow = jax.lax.dynamic_slice(kbb, (t, 0), (1, block))
            corr = (krow @ delta)[0]                                 # (P,)
            gt = jax.lax.dynamic_slice(g0, (t, 0), (1, p))[0] + corr
            d = jnp.maximum(jax.lax.dynamic_slice(db, (t,), (1,))[0], 1e-12)
            ct = jax.lax.dynamic_slice(cb, (t, 0), (1, p))[0]
            lot = jax.lax.dynamic_slice(lob, (t, 0), (1, p))[0]
            hit = jax.lax.dynamic_slice(hib, (t, 0), (1, p))[0]
            target = jnp.clip(ct - gt / d, lot, hit)
            dt = target - ct
            cb = jax.lax.dynamic_update_slice(cb, target[None], (t, 0))
            delta = jax.lax.dynamic_update_slice(delta, dt[None], (t, 0))
            return cb, delta

        cb, delta = jax.lax.fori_loop(
            0, block, inner, (cb, jnp.zeros((block, p), c.dtype)))
        c = jax.lax.dynamic_update_slice(c, cb, (base, 0))
        g = g + kb @ delta        # trailing update: the GEMM-shaped bulk
        return c, g

    return jax.lax.fori_loop(0, n // block, per_block, (c, g))


def cd_epoch_wave_blocked_ref(k_mats: Array, c: Array, g: Array, lo: Array,
                              hi: Array, block: int = WAVE_BLOCK
                              ) -> tuple[Array, Array]:
    """Fused wave epoch: :func:`cd_epoch_blocked_ref` batched over the slot
    axis — the trailing updates of all S slots execute as one batched
    GEMM.  This is what ``ops.cd_epochs_wave`` runs off-TPU."""
    return jax.vmap(cd_epoch_blocked_ref, in_axes=(0, 0, 0, 0, 0, None))(
        k_mats, c, g, lo, hi, block)
