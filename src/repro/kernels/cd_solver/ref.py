"""Pure-jnp oracle: exact (batched-column) Gauss-Seidel coordinate descent.

One epoch sweeps coordinates 0..n-1 in order.  For each coordinate i the
update (vectorized over the P grid columns) is

    delta = clip(c_i - g_i / K_ii, lo_i, hi_i) - c_i
    c_i  += delta
    g    += K[:, i] (x) delta            (rank-1 gradient maintenance)

which is the classic liquidSVM/libsvm-style 1-D working-set step; the
Pallas kernel must reproduce this sequence bit-for-bit (same order).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def cd_epoch_ref(k_mat: Array, c: Array, g: Array, lo: Array, hi: Array) -> tuple[Array, Array]:
    """k_mat (n, n); c, g, lo, hi (n, P).  Returns updated (c, g)."""
    n = k_mat.shape[0]
    diag = jnp.diag(k_mat)

    def body(i, state):
        c, g = state
        d = jnp.maximum(diag[i], 1e-12)
        ci = c[i]                      # (P,)
        target = jnp.clip(ci - g[i] / d, lo[i], hi[i])
        delta = target - ci
        c = c.at[i].add(delta)
        g = g + k_mat[:, i][:, None] * delta[None, :]
        return c, g

    return jax.lax.fori_loop(0, n, body, (c, g))


def solve_cd_ref(k_mat: Array, y: Array, lo: Array, hi: Array, c0: Array,
                 epochs: int) -> tuple[Array, Array]:
    g0 = k_mat @ c0 - y
    def body(_, state):
        return cd_epoch_ref(k_mat, state[0], state[1], lo, hi)
    return jax.lax.fori_loop(0, epochs, body, (c0, g0))
