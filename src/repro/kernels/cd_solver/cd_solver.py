"""Pallas TPU kernels: in-VMEM Gauss-Seidel coordinate-descent epochs —
per-cell and fused across a whole training wave.

The faithful port of liquidSVM's "carefully implemented" sequential solver
(Steinwart–Hush–Scovel 1D working sets).  TPU adaptation:

* the Gram matrix streams through VMEM one (n x B) column-block at a time;
  the sequential TPU grid over blocks IS the Gauss–Seidel order;
* the dual state (c, g, lo, hi) lives in VMEM for the whole epoch via
  input/output aliasing (index_map pins them to one block);
* each 1-D step is batched over the P hyper-parameter-grid columns: the
  rank-1 gradient maintenance g += K[:, i] (x) delta is a (n x P) VPU op, so
  the machine is busy even though coordinates are sequential.

Wave fusion contract (``cd_wave_epoch_pallas``)
-----------------------------------------------
Training solves a WAVE of packed cell slots at a time
(``repro.distributed.cell_trainer.train_cells_waves``); launching the CD
kernel once per slot serializes S kernel dispatches and re-stages state
per launch.  The wave variant is ONE ``pallas_call`` over grid
``(S, n // B)``:

* the slot axis is the outer grid dimension — embarrassingly parallel
  (``dimension_semantics=("parallel", "arbitrary")``), so Mosaic may run
  slots concurrently while the inner block axis stays sequential
  (Gauss–Seidel order within a slot is preserved exactly);
* slot ``s``'s Gram tiles ``K_s[:, jB:(j+1)B]`` stream through VMEM while
  its dual state ``(c_s, g_s, lo_s, hi_s)`` stays RESIDENT across the
  whole ``j`` sweep (index_map pins the state block per slot; c/g are
  input/output-aliased) — the ``kernels/kernel_matrix`` residency idiom
  extended from one cell to the wave;
* slot-major grid order means each slot's state is touched by a single
  contiguous run of grid steps, so the per-slot coordinate sequence is
  bit-identical to the per-slot kernel (asserted in
  ``tests/test_kernels.py::TestCDWave``).

Off TPU, ``ops.cd_epochs_wave`` runs the same wave fusion through
``ref.cd_epoch_wave_blocked_ref`` instead: LAPACK-style delayed trailing
updates (sweep a ``WAVE_BLOCK`` panel keeping only the block-local
gradient consistent, then land the trailing update as one batched GEMM).
Same coordinate order and fixed point, but the summation order differs —
that path matches the exact sweep to f32 rounding (within solver
tolerance), not bitwise; only the TPU Pallas wave keeps per-slot
bit-identity.

Warm-start contract
-------------------
The kernel polishes whatever ``c0`` it is given: the caller passes the
gradient ``g0 = K c0 - y`` consistent with that start.  Across the
hyper-parameter grid the right ``c0`` is the NEIGHBORING grid column's
solution, box-clipped into the new column's feasible box
(``repro.core.solvers.base.clip_warm_start``) — a clipped feasible start
plus Gauss–Seidel's monotone descent means every epoch only improves the
dual, so warm starts can never do worse than the cold ``c0 = 0`` they
replace.  ``repro.core.cv`` owns the grid-neighbor bookkeeping (gamma-scan
carry + select-phase cached columns); this module only requires
``lo <= c0 <= hi``.

Padding: coordinates past a cell's true size carry ``lo == hi == 0`` —
the clip pins them at 0 and their rank-1 update is exactly zero, so padded
slots/rows are inert (the planner's empty slots solve to all-zeros).

Used as a high-accuracy polishing pass after the batched FISTA solver
(``repro.core.solvers.base``) — FISTA owns the MXU-shaped bulk work; one
CD epoch costs the same n²P flops as ONE FISTA iteration but sweeps every
coordinate exactly once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_COORDS = 128  # coordinates per grid step (column-block width)


def _cd_body(k_blk, diag_ref, lo_ref, hi_ref, c_ref, g_ref, base: int,
             block: int):
    """Sweep coordinates [base, base + block) of one cell's state refs.

    k_blk (n, block) is the Gram column block already read into registers;
    diag_ref (1, n); lo/hi/c/g refs (n, P).
    """
    def body(t, _):
        i = base + t
        d = jnp.maximum(diag_ref[0, i], 1e-12)
        ci = pl.load(c_ref, (pl.dslice(i, 1), slice(None)))      # (1, P)
        gi = pl.load(g_ref, (pl.dslice(i, 1), slice(None)))
        li = pl.load(lo_ref, (pl.dslice(i, 1), slice(None)))
        hi = pl.load(hi_ref, (pl.dslice(i, 1), slice(None)))
        target = jnp.clip(ci - gi / d, li, hi)
        delta = target - ci                                       # (1, P)
        pl.store(c_ref, (pl.dslice(i, 1), slice(None)), target)
        k_col = jax.lax.dynamic_slice(k_blk, (0, t), (k_blk.shape[0], 1))  # (n, 1)
        g_ref[...] += k_col * delta
        return 0

    jax.lax.fori_loop(0, block, body, 0)


def _cd_kernel(k_blk_ref, diag_ref, lo_ref, hi_ref, c_in_ref, g_in_ref,
               c_ref, g_ref, *, block: int):
    """Grid step j sweeps coordinates [j*block, (j+1)*block)."""
    del c_in_ref, g_in_ref  # aliased into c_ref / g_ref
    j = pl.program_id(0)
    _cd_body(k_blk_ref[...], diag_ref, lo_ref, hi_ref, c_ref, g_ref,
             j * block, block)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cd_epoch_pallas(k_mat: Array, c: Array, g: Array, lo: Array, hi: Array,
                    interpret: bool = True) -> tuple[Array, Array]:
    """One epoch.  k_mat (n, n) with n % BLOCK_COORDS == 0; c/g/lo/hi (n, P)."""
    n, p = c.shape
    assert n % BLOCK_COORDS == 0, n
    diag = jnp.diag(k_mat).astype(jnp.float32)[None, :]  # (1, n)
    full = lambda i: (0, 0)
    c_out, g_out = pl.pallas_call(
        functools.partial(_cd_kernel, block=BLOCK_COORDS),
        grid=(n // BLOCK_COORDS,),
        in_specs=[
            pl.BlockSpec((n, BLOCK_COORDS), lambda j: (0, j)),   # Gram column block
            pl.BlockSpec((1, n), full),                          # diag
            pl.BlockSpec((n, p), full),                          # lo
            pl.BlockSpec((n, p), full),                          # hi
            pl.BlockSpec((n, p), full),                          # c (aliased out 0)
            pl.BlockSpec((n, p), full),                          # g (aliased out 1)
        ],
        out_specs=[pl.BlockSpec((n, p), full), pl.BlockSpec((n, p), full)],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(k_mat.astype(jnp.float32), diag, lo.astype(jnp.float32),
      hi.astype(jnp.float32), c.astype(jnp.float32), g.astype(jnp.float32))
    return c_out, g_out


def _cd_wave_kernel(k_blk_ref, diag_ref, lo_ref, hi_ref, c_in_ref, g_in_ref,
                    c_ref, g_ref, *, block: int):
    """Grid step (s, j): coordinates [j*block, (j+1)*block) of slot s.

    The leading slot axis is squeezed out of every block (block dim None),
    so the body is the per-cell sweep verbatim — slot s's state blocks are
    pinned across its whole j run by the index_map.
    """
    del c_in_ref, g_in_ref  # aliased into c_ref / g_ref
    j = pl.program_id(1)
    _cd_body(k_blk_ref[...], diag_ref, lo_ref, hi_ref, c_ref, g_ref,
             j * block, block)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cd_wave_epoch_pallas(k_mats: Array, c: Array, g: Array, lo: Array,
                         hi: Array, interpret: bool = True
                         ) -> tuple[Array, Array]:
    """One epoch over a whole wave in ONE launch.

    k_mats (S, n, n) with n % BLOCK_COORDS == 0; c/g/lo/hi (S, n, P).
    Per-slot semantics are identical to :func:`cd_epoch_pallas` (same
    coordinate order, same arithmetic — see the module docstring's wave
    fusion contract).
    """
    s, n, p = c.shape
    assert n % BLOCK_COORDS == 0, n
    diag = jnp.einsum("sii->si", k_mats).astype(jnp.float32)[:, None, :]
    state = lambda si, j: (si, 0, 0)                     # pinned per slot
    kwargs = {}
    if not interpret:  # Mosaic: slots are parallel, the block sweep is not
        from jax.experimental.pallas import tpu as pltpu
        kwargs["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=("parallel", "arbitrary"))
    c_out, g_out = pl.pallas_call(
        functools.partial(_cd_wave_kernel, block=BLOCK_COORDS),
        grid=(s, n // BLOCK_COORDS),
        in_specs=[
            pl.BlockSpec((None, n, BLOCK_COORDS),
                         lambda si, j: (si, 0, j)),      # Gram column block
            pl.BlockSpec((None, 1, n), state),           # diag
            pl.BlockSpec((None, n, p), state),           # lo
            pl.BlockSpec((None, n, p), state),           # hi
            pl.BlockSpec((None, n, p), state),           # c (aliased out 0)
            pl.BlockSpec((None, n, p), state),           # g (aliased out 1)
        ],
        out_specs=[pl.BlockSpec((None, n, p), state),
                   pl.BlockSpec((None, n, p), state)],
        out_shape=[
            jax.ShapeDtypeStruct((s, n, p), jnp.float32),
            jax.ShapeDtypeStruct((s, n, p), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
        **kwargs,
    )(k_mats.astype(jnp.float32), diag, lo.astype(jnp.float32),
      hi.astype(jnp.float32), c.astype(jnp.float32), g.astype(jnp.float32))
    return c_out, g_out
