"""Pallas TPU kernel: in-VMEM Gauss-Seidel coordinate-descent epoch.

The faithful port of liquidSVM's "carefully implemented" sequential solver
(Steinwart–Hush–Scovel 1D working sets).  TPU adaptation:

* the Gram matrix streams through VMEM one (n x B) column-block at a time;
  the sequential TPU grid over blocks IS the Gauss–Seidel order;
* the dual state (c, g, lo, hi) lives in VMEM for the whole epoch via
  input/output aliasing (index_map pins them to one block);
* each 1-D step is batched over the P hyper-parameter-grid columns: the
  rank-1 gradient maintenance g += K[:, i] (x) delta is a (n x P) VPU op, so
  the machine is busy even though coordinates are sequential.

Used as a high-accuracy polishing pass after the batched FISTA solver
(repro.core.solvers.base) — FISTA owns the MXU-shaped bulk work.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_COORDS = 128  # coordinates per grid step (column-block width)


def _cd_kernel(k_blk_ref, diag_ref, lo_ref, hi_ref, c_in_ref, g_in_ref,
               c_ref, g_ref, *, block: int):
    """Grid step j sweeps coordinates [j*block, (j+1)*block)."""
    del c_in_ref, g_in_ref  # aliased into c_ref / g_ref
    j = pl.program_id(0)
    k_blk = k_blk_ref[...]            # (n, block) f32
    base = j * block

    def body(t, _):
        i = base + t
        d = jnp.maximum(diag_ref[0, i], 1e-12)
        ci = pl.load(c_ref, (pl.dslice(i, 1), slice(None)))      # (1, P)
        gi = pl.load(g_ref, (pl.dslice(i, 1), slice(None)))
        li = pl.load(lo_ref, (pl.dslice(i, 1), slice(None)))
        hi = pl.load(hi_ref, (pl.dslice(i, 1), slice(None)))
        target = jnp.clip(ci - gi / d, li, hi)
        delta = target - ci                                       # (1, P)
        pl.store(c_ref, (pl.dslice(i, 1), slice(None)), target)
        k_col = jax.lax.dynamic_slice(k_blk, (0, t), (k_blk.shape[0], 1))  # (n, 1)
        g_ref[...] += k_col * delta
        return 0

    jax.lax.fori_loop(0, block, body, 0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def cd_epoch_pallas(k_mat: Array, c: Array, g: Array, lo: Array, hi: Array,
                    interpret: bool = True) -> tuple[Array, Array]:
    """One epoch.  k_mat (n, n) with n % BLOCK_COORDS == 0; c/g/lo/hi (n, P)."""
    n, p = c.shape
    assert n % BLOCK_COORDS == 0, n
    diag = jnp.diag(k_mat).astype(jnp.float32)[None, :]  # (1, n)
    full = lambda i: (0, 0)
    c_out, g_out = pl.pallas_call(
        functools.partial(_cd_kernel, block=BLOCK_COORDS),
        grid=(n // BLOCK_COORDS,),
        in_specs=[
            pl.BlockSpec((n, BLOCK_COORDS), lambda j: (0, j)),   # Gram column block
            pl.BlockSpec((1, n), full),                          # diag
            pl.BlockSpec((n, p), full),                          # lo
            pl.BlockSpec((n, p), full),                          # hi
            pl.BlockSpec((n, p), full),                          # c (aliased out 0)
            pl.BlockSpec((n, p), full),                          # g (aliased out 1)
        ],
        out_specs=[pl.BlockSpec((n, p), full), pl.BlockSpec((n, p), full)],
        out_shape=[
            jax.ShapeDtypeStruct((n, p), jnp.float32),
            jax.ShapeDtypeStruct((n, p), jnp.float32),
        ],
        input_output_aliases={4: 0, 5: 1},
        interpret=interpret,
    )(k_mat.astype(jnp.float32), diag, lo.astype(jnp.float32),
      hi.astype(jnp.float32), c.astype(jnp.float32), g.astype(jnp.float32))
    return c_out, g_out
