from repro.kernels.cd_solver.ops import cd_epochs

__all__ = ["cd_epochs"]
