"""Pure-jnp oracles for the Gram-matrix kernels (fused and split-D² paths)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def sq_dists_ref(x: Array, z: Array, symmetric: bool = False) -> Array:
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    d2 = jnp.maximum(
        jnp.sum(x * x, -1)[:, None] + jnp.sum(z * z, -1)[None, :] - 2.0 * (x @ z.T), 0.0
    )
    if symmetric:
        # match the Pallas upper-triangle + mirror contract bitwise
        d2 = 0.5 * (d2 + d2.T)
    return d2


def gram_from_d2_ref(d2: Array, gamma: Array, kind: str = "gauss_rbf",
                     out_dtype: str = "f32") -> Array:
    g = jnp.asarray(gamma, jnp.float32)
    d2 = d2.astype(jnp.float32)
    if kind == "gauss_rbf":
        k = jnp.exp(-d2 / jnp.maximum(g * g, 1e-12))
    elif kind == "laplacian":
        k = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(g, 1e-12))
    else:
        raise ValueError(kind)
    return k.astype(jnp.bfloat16) if out_dtype == "bf16" else k


def kernel_matrix_ref(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf") -> Array:
    return gram_from_d2_ref(sq_dists_ref(x, z), gamma, kind)
