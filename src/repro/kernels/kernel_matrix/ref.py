"""Pure-jnp oracle for the Gram-matrix kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def kernel_matrix_ref(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf") -> Array:
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    d2 = jnp.maximum(
        jnp.sum(x * x, -1)[:, None] + jnp.sum(z * z, -1)[None, :] - 2.0 * (x @ z.T), 0.0
    )
    g = jnp.asarray(gamma, jnp.float32)
    if kind == "gauss_rbf":
        return jnp.exp(-d2 / jnp.maximum(g * g, 1e-12))
    if kind == "laplacian":
        return jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(g, 1e-12))
    raise ValueError(kind)
