from repro.kernels.kernel_matrix.ops import kernel_matrix

__all__ = ["kernel_matrix"]
