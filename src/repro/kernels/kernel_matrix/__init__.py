from repro.kernels.kernel_matrix.ops import gram_from_d2, kernel_matrix, sq_dists

__all__ = ["gram_from_d2", "kernel_matrix", "sq_dists"]
