"""jit'd wrappers: pad to tile boundaries, dispatch Pallas-on-TPU vs
jnp-oracle elsewhere (CPU hosts validate the kernels in interpret mode via
``force_pallas=True``; real TPU backends run the Mosaic-compiled kernels).

Three entry points:

  * ``kernel_matrix``  — one-shot Gram/cross-Gram (legacy path);
  * ``sq_dists``       — the gamma-independent D² matrix, computed ONCE per
                         working set (symmetric upper-triangle compute when
                         x is z);
  * ``gram_from_d2``   — the per-gamma VPU epilogue replayed over a cached
                         D², optionally downcast to bf16 on write.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.kernel_matrix import ref
from repro.kernels.kernel_matrix.kernel_matrix import (
    BLOCK_M,
    BLOCK_N,
    gram_from_d2_pallas,
    gram_pallas,
    sq_dists_pallas,
)

Array = jax.Array


def _pad_to(a: Array, mult: int, axis: int) -> Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


@functools.partial(jax.jit, static_argnames=("kind", "force_pallas", "interpret"))
def kernel_matrix(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf",
                  force_pallas: bool = False, interpret: bool | None = None) -> Array:
    """K[i, j] = k_gamma(x_i, z_j); (n, d) x (m, d) -> (n, m) f32."""
    n, m = x.shape[0], z.shape[0]
    if not (force_pallas or runtime.on_tpu()):
        return ref.kernel_matrix_ref(x, z, gamma, kind)
    xp = _pad_to(_pad_to(x, BLOCK_N, 0), 128, 1)
    zp = _pad_to(_pad_to(z, BLOCK_M, 0), 128, 1)
    k = gram_pallas(xp, zp, gamma, kind=kind,
                    interpret=runtime.resolve_interpret(interpret))
    return k[:n, :m]


@functools.partial(jax.jit, static_argnames=("symmetric", "force_pallas", "interpret"))
def sq_dists(x: Array, z: Array, symmetric: bool = False,
             force_pallas: bool = False, interpret: bool | None = None) -> Array:
    """Pairwise squared distances (n, d) x (m, d) -> (n, m) f32.

    ``symmetric=True`` asserts z has x's shape and REQUIRES z to be the
    same points as x (unverifiable at trace time — the caller's contract):
    it computes only the upper-triangle tiles on the MXU and mirrors them —
    ~2x fewer flops for the train Gram, and K == K.T bitwise by
    construction.  Passing different same-shape points would silently mix
    triangles; use ``CachedGram.build(x)`` / ``gram_for_gammas`` which pass
    x on both sides themselves.
    """
    n, m = x.shape[0], z.shape[0]
    if symmetric:
        assert x.shape == z.shape, (x.shape, z.shape)
    if not (force_pallas or runtime.on_tpu()):
        return ref.sq_dists_ref(x, z, symmetric=symmetric)
    xp = _pad_to(_pad_to(x, BLOCK_N, 0), 128, 1)
    zp = _pad_to(_pad_to(z, BLOCK_M, 0), 128, 1)
    d2 = sq_dists_pallas(xp, zp, symmetric=symmetric,
                         interpret=runtime.resolve_interpret(interpret))
    return d2[:n, :m]


@functools.partial(jax.jit, static_argnames=("kind", "out_dtype", "force_pallas", "interpret"))
def gram_from_d2(d2: Array, gamma: Array, kind: str = "gauss_rbf",
                 out_dtype: str = "f32", force_pallas: bool = False,
                 interpret: bool | None = None) -> Array:
    """Apply the per-gamma kernel epilogue to a cached D² matrix.

    One VMEM pass per (bn, bm) tile: exp(-d2/gamma²) (or Laplacian) and the
    optional bf16 downcast happen before the tile is written back, so the
    per-gamma cost is a single elementwise sweep — no MXU work at all.
    """
    n, m = d2.shape
    if not (force_pallas or runtime.on_tpu()):
        return ref.gram_from_d2_ref(d2, gamma, kind, out_dtype)
    d2p = _pad_to(_pad_to(d2, BLOCK_N, 0), BLOCK_M, 1)
    k = gram_from_d2_pallas(d2p, gamma, kind=kind, out_dtype=out_dtype,
                            interpret=runtime.resolve_interpret(interpret))
    return k[:n, :m]
