"""jit'd wrapper: pads to tile boundaries, dispatches Pallas-on-TPU vs
jnp-oracle elsewhere (this container is CPU; the kernel is validated in
interpret mode by tests and enabled on real TPU backends).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.kernel_matrix import ref
from repro.kernels.kernel_matrix.kernel_matrix import BLOCK_M, BLOCK_N, gram_pallas

Array = jax.Array


def _pad_to(a: Array, mult: int, axis: int) -> Array:
    pad = (-a.shape[axis]) % mult
    if pad == 0:
        return a
    widths = [(0, 0)] * a.ndim
    widths[axis] = (0, pad)
    return jnp.pad(a, widths)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("kind", "force_pallas", "interpret"))
def kernel_matrix(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf",
                  force_pallas: bool = False, interpret: bool = True) -> Array:
    """K[i, j] = k_gamma(x_i, z_j); (n, d) x (m, d) -> (n, m) f32."""
    n, m = x.shape[0], z.shape[0]
    if not (force_pallas or _on_tpu()):
        return ref.kernel_matrix_ref(x, z, gamma, kind)
    xp = _pad_to(_pad_to(x, BLOCK_N, 0), 128, 1)
    zp = _pad_to(_pad_to(z, BLOCK_M, 0), 128, 1)
    use_interpret = interpret and not _on_tpu()
    k = gram_pallas(xp, zp, gamma, kind=kind, interpret=use_interpret)
    return k[:n, :m]
