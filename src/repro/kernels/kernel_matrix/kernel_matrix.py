"""Pallas TPU kernel: tiled Gram-matrix computation.

liquidSVM's single hottest loop ("routines for computing the kernel
matrices ... parallelized ... Cuda implementations").  TPU adaptation: the
cross term -2*X@Z^T is an MXU matmul; the squared norms + exp are VPU
epilogue fused in the same VMEM tile, so each (bn x bm) output tile is
written exactly once to HBM.

Tiling: grid (n/bn, m/bm); X tile (bn, d) and Z tile (bm, d) stream through
VMEM with d kept whole (SVM feature dims are small: d <= ~1k).  All dims
padded to the 128 lane width by ops.py; zero-padded features do not change
distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_N = 128
BLOCK_M = 128


def _gram_kernel(x_ref, z_ref, gamma_ref, o_ref, *, kind: str):
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    z = z_ref[...].astype(jnp.float32)          # (bm, d)
    gamma = gamma_ref[0, 0]
    cross = jax.lax.dot_general(                # MXU: (bn, d) x (bm, d)^T
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    d2 = jnp.maximum(xx + zz - 2.0 * cross, 0.0)
    if kind == "gauss_rbf":
        o_ref[...] = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        o_ref[...] = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def gram_pallas(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf",
                interpret: bool = True) -> Array:
    """x (n, d), z (m, d) with n, m multiples of 128; returns K (n, m) f32."""
    n, d = x.shape
    m, _ = z.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind),
        grid=(n // BLOCK_N, m // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, z, gamma_arr)
