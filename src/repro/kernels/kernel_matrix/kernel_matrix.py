"""Pallas TPU kernels: tiled Gram-matrix computation and the split
distance-cache pipeline.

liquidSVM's single hottest loop ("routines for computing the kernel
matrices ... parallelized ... Cuda implementations").  TPU adaptation: the
cross term -2*X@Z^T is an MXU matmul; the squared norms + exp are VPU
epilogue fused in the same VMEM tile, so each (bn x bm) output tile is
written exactly once to HBM.

The CV grid scan needs the Gram for MANY gammas over the SAME points, and
the expensive part — the pairwise squared-distance matrix D² — is
gamma-independent.  So the fused ``gram_pallas`` is complemented by a split
pipeline:

  * ``sq_dists_pallas``     writes D² once.  For the symmetric train Gram it
                            runs the MXU only on upper-triangle tiles
                            (i <= j) and writes the MIRRORED tile from inside
                            the kernel: a two-phase grid (i, j, m) keeps the
                            just-computed tile in VMEM scratch and the m == 1
                            phase stores its transpose at block (j, i).  ~2x
                            fewer MXU flops, a bitwise-symmetric result, and
                            no ``U + U.T`` combine — the old wrapper-side
                            mirror cost one extra full read + write of the
                            n² matrix in HBM;
  * ``gram_from_d2_pallas`` replays the cheap per-gamma VPU epilogue
                            (exp(-d2/gamma²) or Laplacian, optional bf16
                            downcast) over the cached D², one VMEM pass per
                            tile, no MXU work.

Tiling: grid (n/bn, m/bm); X tile (bn, d) and Z tile (bm, d) stream through
VMEM with d kept whole (SVM feature dims are small: d <= ~1k).  All dims
padded to the 128 lane width by ops.py; zero-padded features do not change
distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jax.Array

BLOCK_N = 128
BLOCK_M = 128


def _gram_kernel(x_ref, z_ref, gamma_ref, o_ref, *, kind: str):
    gamma = gamma_ref[0, 0]
    d2 = _d2_tile(x_ref, z_ref)
    if kind == "gauss_rbf":
        o_ref[...] = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        o_ref[...] = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def gram_pallas(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf",
                interpret: bool = True) -> Array:
    """x (n, d), z (m, d) with n, m multiples of 128; returns K (n, m) f32."""
    n, d = x.shape
    m, _ = z.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind),
        grid=(n // BLOCK_N, m // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, z, gamma_arr)


def _d2_tile(x_ref, z_ref) -> Array:
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    z = z_ref[...].astype(jnp.float32)          # (bm, d)
    cross = jax.lax.dot_general(                # MXU: (bn, d) x (bm, d)^T
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    return jnp.maximum(xx + zz - 2.0 * cross, 0.0)


def _sq_dists_kernel(x_ref, z_ref, o_ref):
    o_ref[...] = _d2_tile(x_ref, z_ref)


def _sq_dists_sym_kernel(x_ref, z_ref, o_ref, acc_ref):
    """Two-phase symmetric tile: m == 0 computes the upper tile (i <= j) and
    parks it in VMEM scratch; m == 1 writes the transpose to block (j, i).
    Diagonal tiles are bitwise symmetric (same dot-product order both ways),
    so the m == 1 rewrite of (i, i) stores identical bits.  Strictly-lower
    iterations (i > j) do no compute and their output window is parked on
    the diagonal block (see ``_sym_out_map``), which a later phase of row i
    fully overwrites — every block is written exactly once with real data
    and the MXU runs only on the n_tiles*(n_tiles+1)/2 upper tiles.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    m = pl.program_id(2)

    @pl.when((i <= j) & (m == 0))
    def _compute():
        d2 = _d2_tile(x_ref, z_ref)
        acc_ref[...] = d2
        o_ref[...] = d2

    @pl.when((i <= j) & (m == 1))
    def _mirror():
        o_ref[...] = acc_ref[...].T


def _sym_out_map(i, j, m):
    """Upper tiles: (i, j) then the mirrored (j, i).  Lower iterations park
    on (i, i) so the window index stays constant across the skipped stretch
    (no spurious HBM writebacks between real visits)."""
    up = i <= j
    r = jnp.where(up, jnp.where(m == 0, i, j), i)
    c = jnp.where(up, jnp.where(m == 0, j, i), i)
    return r, c


@functools.partial(jax.jit, static_argnames=("symmetric", "interpret"))
def sq_dists_pallas(x: Array, z: Array, symmetric: bool = False,
                    interpret: bool = True) -> Array:
    """Tiled pairwise D²; n, m multiples of 128; returns (n, m) f32.

    ``symmetric=True`` requires x.shape == z.shape (callers pass x twice):
    the MXU runs only on the n_tiles*(n_tiles+1)/2 upper tiles and each
    tile's transpose is written to the mirrored block from INSIDE the kernel
    (two-phase grid + VMEM scratch) — the result is K == K.T bitwise with no
    post-hoc ``U + U.T`` pass over HBM.
    """
    n, d = x.shape
    m, _ = z.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    if not symmetric:
        return pl.pallas_call(
            _sq_dists_kernel,
            grid=(n // BLOCK_N, m // BLOCK_M),
            in_specs=[
                pl.BlockSpec((BLOCK_N, d), lambda i, j: (i, 0)),
                pl.BlockSpec((BLOCK_M, d), lambda i, j: (j, 0)),
            ],
            out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
            out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
            interpret=interpret,
        )(x, z)

    # the tile predicate i <= j only matches the matrix upper triangle
    # when tiles are square — guard against a BLOCK_M-only perf tweak
    assert n == m and BLOCK_N == BLOCK_M, (n, m, BLOCK_N, BLOCK_M)
    return pl.pallas_call(
        _sq_dists_sym_kernel,
        grid=(n // BLOCK_N, m // BLOCK_M, 2),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i, j, m: (i, 0)),
            pl.BlockSpec((BLOCK_M, d), lambda i, j, m: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), _sym_out_map),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((BLOCK_N, BLOCK_M), jnp.float32)],
        interpret=interpret,
    )(x, z)


def _gram_from_d2_kernel(d2_ref, gamma_ref, o_ref, *, kind: str):
    d2 = d2_ref[...].astype(jnp.float32)
    gamma = gamma_ref[0, 0]
    if kind == "gauss_rbf":
        k = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        k = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)
    o_ref[...] = k.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "out_dtype", "interpret"))
def gram_from_d2_pallas(d2: Array, gamma: Array, kind: str = "gauss_rbf",
                        out_dtype: str = "f32", interpret: bool = True) -> Array:
    """Per-gamma epilogue over a cached D²: exp + optional bf16 downcast in
    one VMEM pass per (bn, bm) tile.  Pure VPU work — the whole point is
    that the CV gamma scan replays THIS instead of the MXU cross-term.
    """
    n, m = d2.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    dtype = jnp.bfloat16 if out_dtype == "bf16" else jnp.float32
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_gram_from_d2_kernel, kind=kind),
        grid=(n // BLOCK_N, m // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), dtype),
        interpret=interpret,
    )(d2, gamma_arr)
