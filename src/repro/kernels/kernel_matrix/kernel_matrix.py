"""Pallas TPU kernels: tiled Gram-matrix computation and the split
distance-cache pipeline.

liquidSVM's single hottest loop ("routines for computing the kernel
matrices ... parallelized ... Cuda implementations").  TPU adaptation: the
cross term -2*X@Z^T is an MXU matmul; the squared norms + exp are VPU
epilogue fused in the same VMEM tile, so each (bn x bm) output tile is
written exactly once to HBM.

The CV grid scan needs the Gram for MANY gammas over the SAME points, and
the expensive part — the pairwise squared-distance matrix D² — is
gamma-independent.  So the fused ``gram_pallas`` is complemented by a split
pipeline:

  * ``sq_dists_pallas``     writes D² once.  For the symmetric train Gram it
                            runs the MXU only on upper-triangle tiles
                            (i <= j), halves the diagonal, and the wrapper
                            mirrors with ``U + U.T`` — ~2x fewer MXU flops
                            and a bitwise-symmetric result;
  * ``gram_from_d2_pallas`` replays the cheap per-gamma VPU epilogue
                            (exp(-d2/gamma²) or Laplacian, optional bf16
                            downcast) over the cached D², one VMEM pass per
                            tile, no MXU work.

Tiling: grid (n/bn, m/bm); X tile (bn, d) and Z tile (bm, d) stream through
VMEM with d kept whole (SVM feature dims are small: d <= ~1k).  All dims
padded to the 128 lane width by ops.py; zero-padded features do not change
distances.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_N = 128
BLOCK_M = 128


def _gram_kernel(x_ref, z_ref, gamma_ref, o_ref, *, kind: str):
    x = x_ref[...].astype(jnp.float32)          # (bn, d)
    z = z_ref[...].astype(jnp.float32)          # (bm, d)
    gamma = gamma_ref[0, 0]
    cross = jax.lax.dot_general(                # MXU: (bn, d) x (bm, d)^T
        x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    d2 = jnp.maximum(xx + zz - 2.0 * cross, 0.0)
    if kind == "gauss_rbf":
        o_ref[...] = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        o_ref[...] = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def gram_pallas(x: Array, z: Array, gamma: Array, kind: str = "gauss_rbf",
                interpret: bool = True) -> Array:
    """x (n, d), z (m, d) with n, m multiples of 128; returns K (n, m) f32."""
    n, d = x.shape
    m, _ = z.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_gram_kernel, kind=kind),
        grid=(n // BLOCK_N, m // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, z, gamma_arr)


def _sq_dists_kernel(x_ref, z_ref, o_ref, *, symmetric: bool):
    i = pl.program_id(0)
    j = pl.program_id(1)

    def compute():
        x = x_ref[...].astype(jnp.float32)      # (bn, d)
        z = z_ref[...].astype(jnp.float32)      # (bm, d)
        cross = jax.lax.dot_general(            # MXU: (bn, d) x (bm, d)^T
            x, z, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        xx = jnp.sum(x * x, axis=-1)[:, None]
        zz = jnp.sum(z * z, axis=-1)[None, :]
        d2 = jnp.maximum(xx + zz - 2.0 * cross, 0.0)
        if symmetric:
            # Diagonal tiles are bitwise symmetric (same dot-product order
            # both ways), so halving them makes U + U.T exact: off-diagonal
            # entries appear once, diagonal-tile entries as 0.5*d2 + 0.5*d2.
            d2 = jnp.where(i == j, 0.5 * d2, d2)
        o_ref[...] = d2

    if symmetric:

        @pl.when(i <= j)
        def _():
            compute()

        @pl.when(i > j)
        def _():
            o_ref[...] = jnp.zeros(o_ref.shape, o_ref.dtype)

    else:
        compute()


@functools.partial(jax.jit, static_argnames=("symmetric", "interpret"))
def sq_dists_pallas(x: Array, z: Array, symmetric: bool = False,
                    interpret: bool = True) -> Array:
    """Tiled pairwise D²; n, m multiples of 128; returns (n, m) f32.

    ``symmetric=True`` requires x.shape == z.shape (callers pass x twice):
    the MXU runs only on the n_tiles*(n_tiles+1)/2 upper tiles and the
    strictly-lower tiles are zero-filled, then mirrored here via U + U.T.
    """
    n, d = x.shape
    m, _ = z.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    if symmetric:
        # the tile predicate i <= j only matches the matrix upper triangle
        # when tiles are square — guard against a BLOCK_M-only perf tweak
        assert n == m and BLOCK_N == BLOCK_M, (n, m, BLOCK_N, BLOCK_M)
    upper = pl.pallas_call(
        functools.partial(_sq_dists_kernel, symmetric=symmetric),
        grid=(n // BLOCK_N, m // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=interpret,
    )(x, z)
    if symmetric:
        return upper + upper.T
    return upper


def _gram_from_d2_kernel(d2_ref, gamma_ref, o_ref, *, kind: str):
    d2 = d2_ref[...].astype(jnp.float32)
    gamma = gamma_ref[0, 0]
    if kind == "gauss_rbf":
        k = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        k = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)
    o_ref[...] = k.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("kind", "out_dtype", "interpret"))
def gram_from_d2_pallas(d2: Array, gamma: Array, kind: str = "gauss_rbf",
                        out_dtype: str = "f32", interpret: bool = True) -> Array:
    """Per-gamma epilogue over a cached D²: exp + optional bf16 downcast in
    one VMEM pass per (bn, bm) tile.  Pure VPU work — the whole point is
    that the CV gamma scan replays THIS instead of the MXU cross-term.
    """
    n, m = d2.shape
    assert n % BLOCK_N == 0 and m % BLOCK_M == 0, (n, m)
    dtype = jnp.bfloat16 if out_dtype == "bf16" else jnp.float32
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_gram_from_d2_kernel, kind=kind),
        grid=(n // BLOCK_N, m // BLOCK_M),
        in_specs=[
            pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_N, BLOCK_M), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), dtype),
        interpret=interpret,
    )(d2, gamma_arr)
