from repro.kernels.svm_predict.ops import svm_predict

__all__ = ["svm_predict"]
