from repro.kernels.svm_predict.ops import svm_predict, svm_predict_cells

__all__ = ["svm_predict", "svm_predict_cells"]
