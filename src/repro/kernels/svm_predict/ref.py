"""Pure-jnp oracle: f = K(x_test, sv) @ coefs (Gram materialized)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kernel_matrix.ref import kernel_matrix_ref

Array = jax.Array


def svm_predict_ref(x_test: Array, sv: Array, coefs: Array, gamma: Array,
                    kind: str = "gauss_rbf") -> Array:
    k = kernel_matrix_ref(x_test, sv, gamma, kind)
    if coefs.ndim == 1:
        coefs = coefs[:, None]
    return k @ coefs.astype(jnp.float32)
