"""Pure-jnp oracles: f = K(x_test, sv) @ coefs (Gram materialized).

``svm_predict_cells_ref`` is the serving-engine contract: a batch of cells,
each with its own SV table and P = n_tasks * n_sub coefficient columns where
every column may carry a DIFFERENT selected gamma.  The D² matrix is
computed once per cell and each column replays only the per-gamma epilogue
— the same distance-cache structure the fused Pallas kernel realizes
tile-locally in VMEM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kernel_matrix.ref import (
    gram_from_d2_ref,
    kernel_matrix_ref,
    sq_dists_ref,
)

Array = jax.Array


def svm_predict_ref(x_test: Array, sv: Array, coefs: Array, gamma: Array,
                    kind: str = "gauss_rbf") -> Array:
    k = kernel_matrix_ref(x_test, sv, gamma, kind)
    if coefs.ndim == 1:
        coefs = coefs[:, None]
    return k @ coefs.astype(jnp.float32)


def svm_predict_cells_ref(xt: Array, sv: Array, coefs: Array, gammas: Array,
                          kind: str = "gauss_rbf") -> Array:
    """xt (C, m, d), sv (C, k, d), coefs (C, k, P), gammas (C, P) -> (C, m, P)."""

    def one_cell(xt_c, sv_c, coef_c, gamma_c):
        d2 = sq_dists_ref(xt_c, sv_c)                        # once per cell

        def per_col(g, c):
            return gram_from_d2_ref(d2, g, kind) @ c         # (m,)

        return jax.vmap(per_col)(gamma_c, coef_c.T).T        # (m, P)

    return jax.vmap(one_cell)(xt, sv, coefs, gammas)
