"""Pallas TPU kernels: fused SVM test-phase evaluation.

liquidSVM parallelizes "evaluating the SVM models on the test data" (CPU
threads + CUDA).  TPU adaptation: never materialize K(test, SV) in HBM —
each (bt x bs) Gram tile is produced in VMEM (MXU cross term + VPU exp)
and immediately contracted against the coefficient block (MXU again),
accumulating f = K @ C tile-by-tile.  Arithmetic intensity rises from
O(1) (Gram write + later GEMV read) to O(bs) per Gram element.

Grid (n_test/bt, n_sv/bs): the sv axis is the sequential inner dimension;
the output tile is revisited and accumulated across it.

``svm_predict_cells_pallas`` is the serving-engine launch: ONE kernel over a
whole batch of routed cells (grid (C, n_test/bt, n_sv/bs)), where each cell
carries P = n_tasks * n_sub coefficient columns and every column its own
selected gamma.  The gamma-independent D² tile is computed once per (bt, bs)
block and each column replays only the cheap exp epilogue against it — the
distance-cache factorization applied inside VMEM, so a multi-task multi-
gamma model bank pays the MXU cross term exactly once per tile per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_T = 128   # test rows per tile
BLOCK_S = 128   # support vectors per tile


def _predict_kernel(x_ref, sv_ref, c_ref, gamma_ref, o_ref, *, kind: str):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)     # (bt, d)
    sv = sv_ref[...].astype(jnp.float32)   # (bs, d)
    gamma = gamma_ref[0, 0]
    cross = jax.lax.dot_general(x, sv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = jnp.maximum(jnp.sum(x * x, -1)[:, None] + jnp.sum(sv * sv, -1)[None, :]
                     - 2.0 * cross, 0.0)
    if kind == "gauss_rbf":
        k_tile = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        k_tile = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)
    partial = jnp.dot(k_tile, c_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)  # (bt, P)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def svm_predict_pallas(x_test: Array, sv: Array, coefs: Array, gamma: Array,
                       kind: str = "gauss_rbf", interpret: bool = True) -> Array:
    """x_test (nt, d), sv (ns, d), coefs (ns, P); nt % 128 == ns % 128 == 0."""
    nt, d = x_test.shape
    ns, p = sv.shape[0], coefs.shape[1]
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_predict_kernel, kind=kind),
        grid=(nt // BLOCK_T, ns // BLOCK_S),
        in_specs=[
            pl.BlockSpec((BLOCK_T, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_S, d), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_S, p), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T, p), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, p), jnp.float32),
        interpret=interpret,
    )(x_test, sv, coefs, gamma_arr)


def _predict_cells_kernel(x_ref, sv_ref, c_ref, g_ref, o_ref, *, kind: str):
    """One routed cell tile: D² once, per-column gamma epilogue + contract.

    Padded SV rows carry zero coefficients (exact zero contribution) and
    padded cells zero coefficient blocks, so no masking is needed; padded
    test rows produce garbage sliced off by the wrapper.
    """
    j = pl.program_id(2)
    x = x_ref[0].astype(jnp.float32)       # (bt, d)
    sv = sv_ref[0].astype(jnp.float32)     # (bs, d)
    c = c_ref[0].astype(jnp.float32)       # (bs, P)
    cross = jax.lax.dot_general(x, sv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = jnp.maximum(jnp.sum(x * x, -1)[:, None] + jnp.sum(sv * sv, -1)[None, :]
                     - 2.0 * cross, 0.0)
    cols = []
    for p in range(c.shape[1]):            # static P, small (n_tasks * n_sub)
        gamma = g_ref[0, 0, p]
        if kind == "gauss_rbf":
            k_tile = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
        elif kind == "laplacian":
            k_tile = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
        else:
            raise ValueError(kind)
        cols.append(jnp.dot(k_tile, c[:, p:p + 1],
                            preferred_element_type=jnp.float32))
    partial = jnp.concatenate(cols, axis=1)  # (bt, P)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial[None]

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial[None]


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def svm_predict_cells_pallas(xt: Array, sv: Array, coefs: Array, gammas: Array,
                             kind: str = "gauss_rbf",
                             interpret: bool = True) -> Array:
    """xt (C, nt, d), sv (C, ns, d), coefs (C, ns, P), gammas (C, P).

    Returns (C, nt, P) f32; nt % 128 == ns % 128 == 0.  One launch covers
    every active cell of a serving step — the cell axis is the outer grid
    dimension, so each cell's SV tiles stream through VMEM exactly once.
    """
    n_cells, nt, d = xt.shape
    ns, p = sv.shape[1], coefs.shape[2]
    g3 = jnp.asarray(gammas, jnp.float32).reshape(n_cells, 1, p)
    return pl.pallas_call(
        functools.partial(_predict_cells_kernel, kind=kind),
        grid=(n_cells, nt // BLOCK_T, ns // BLOCK_S),
        in_specs=[
            pl.BlockSpec((1, BLOCK_T, d), lambda c, i, j: (c, i, 0)),
            pl.BlockSpec((1, BLOCK_S, d), lambda c, i, j: (c, j, 0)),
            pl.BlockSpec((1, BLOCK_S, p), lambda c, i, j: (c, j, 0)),
            pl.BlockSpec((1, 1, p), lambda c, i, j: (c, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_T, p), lambda c, i, j: (c, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_cells, nt, p), jnp.float32),
        interpret=interpret,
    )(xt, sv, coefs, g3)
