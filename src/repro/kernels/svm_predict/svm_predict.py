"""Pallas TPU kernel: fused SVM test-phase evaluation.

liquidSVM parallelizes "evaluating the SVM models on the test data" (CPU
threads + CUDA).  TPU adaptation: never materialize K(test, SV) in HBM —
each (bt x bs) Gram tile is produced in VMEM (MXU cross term + VPU exp)
and immediately contracted against the coefficient block (MXU again),
accumulating f = K @ C tile-by-tile.  Arithmetic intensity rises from
O(1) (Gram write + later GEMV read) to O(bs) per Gram element.

Grid (n_test/bt, n_sv/bs): the sv axis is the sequential inner dimension;
the output tile is revisited and accumulated across it.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

Array = jax.Array

BLOCK_T = 128   # test rows per tile
BLOCK_S = 128   # support vectors per tile


def _predict_kernel(x_ref, sv_ref, c_ref, gamma_ref, o_ref, *, kind: str):
    j = pl.program_id(1)
    x = x_ref[...].astype(jnp.float32)     # (bt, d)
    sv = sv_ref[...].astype(jnp.float32)   # (bs, d)
    gamma = gamma_ref[0, 0]
    cross = jax.lax.dot_general(x, sv, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
    d2 = jnp.maximum(jnp.sum(x * x, -1)[:, None] + jnp.sum(sv * sv, -1)[None, :]
                     - 2.0 * cross, 0.0)
    if kind == "gauss_rbf":
        k_tile = jnp.exp(-d2 / jnp.maximum(gamma * gamma, 1e-12))
    elif kind == "laplacian":
        k_tile = jnp.exp(-jnp.sqrt(d2 + 1e-12) / jnp.maximum(gamma, 1e-12))
    else:
        raise ValueError(kind)
    partial = jnp.dot(k_tile, c_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)  # (bt, P)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = partial

    @pl.when(j > 0)
    def _acc():
        o_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("kind", "interpret"))
def svm_predict_pallas(x_test: Array, sv: Array, coefs: Array, gamma: Array,
                       kind: str = "gauss_rbf", interpret: bool = True) -> Array:
    """x_test (nt, d), sv (ns, d), coefs (ns, P); nt % 128 == ns % 128 == 0."""
    nt, d = x_test.shape
    ns, p = sv.shape[0], coefs.shape[1]
    gamma_arr = jnp.reshape(jnp.asarray(gamma, jnp.float32), (1, 1))
    return pl.pallas_call(
        functools.partial(_predict_kernel, kind=kind),
        grid=(nt // BLOCK_T, ns // BLOCK_S),
        in_specs=[
            pl.BlockSpec((BLOCK_T, d), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_S, d), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_S, p), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T, p), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nt, p), jnp.float32),
        interpret=interpret,
    )(x_test, sv, coefs, gamma_arr)
