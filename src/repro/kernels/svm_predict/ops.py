"""jit'd wrapper with padding + backend dispatch for fused SVM evaluation."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import runtime
from repro.kernels.svm_predict import ref
from repro.kernels.svm_predict.svm_predict import (
    BLOCK_S,
    BLOCK_T,
    svm_predict_cells_pallas,
    svm_predict_pallas,
)

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("kind", "force_pallas", "interpret"))
def svm_predict(x_test: Array, sv: Array, coefs: Array, gamma: Array,
                kind: str = "gauss_rbf", force_pallas: bool = False,
                interpret: bool | None = None) -> Array:
    """f = K(x_test, sv) @ coefs; returns (n_test, P)."""
    squeeze = coefs.ndim == 1
    if squeeze:
        coefs = coefs[:, None]
    if not (force_pallas or runtime.on_tpu()):
        out = ref.svm_predict_ref(x_test, sv, coefs, gamma, kind)
        return out[:, 0] if squeeze else out

    nt, d = x_test.shape
    ns = sv.shape[0]
    pad_t, pad_s, pad_d = (-nt) % BLOCK_T, (-ns) % BLOCK_S, (-d) % 128
    xp = jnp.pad(x_test.astype(jnp.float32), ((0, pad_t), (0, pad_d)))
    svp = jnp.pad(sv.astype(jnp.float32), ((0, pad_s), (0, pad_d)))
    cp = jnp.pad(coefs.astype(jnp.float32), ((0, pad_s), (0, 0)))  # 0-coef padding
    out = svm_predict_pallas(xp, svp, cp, gamma, kind=kind,
                             interpret=runtime.resolve_interpret(interpret))[:nt]
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("kind", "force_pallas", "interpret"))
def svm_predict_cells(xt: Array, sv: Array, coefs: Array, gammas: Array,
                      kind: str = "gauss_rbf", force_pallas: bool = False,
                      interpret: bool | None = None) -> Array:
    """Batched per-cell multi-column prediction — the serving-engine launch.

    xt (C, m, d) routed+padded queries; sv (C, k, d) compacted SV tables;
    coefs (C, k, P) per-(task, sub) columns; gammas (C, P) per-column
    selected gammas.  Returns (C, m, P) f32.  Zero-coefficient padding rows
    (SV axis) and zero-coefficient cells contribute exactly zero, so the
    wrapper only pads — it never masks.
    """
    if not (force_pallas or runtime.on_tpu()):
        return ref.svm_predict_cells_ref(xt, sv, coefs, gammas, kind)
    _, m, d = xt.shape
    k = sv.shape[1]
    pad_m, pad_k, pad_d = (-m) % BLOCK_T, (-k) % BLOCK_S, (-d) % 128
    xp = jnp.pad(xt.astype(jnp.float32), ((0, 0), (0, pad_m), (0, pad_d)))
    svp = jnp.pad(sv.astype(jnp.float32), ((0, 0), (0, pad_k), (0, pad_d)))
    cp = jnp.pad(coefs.astype(jnp.float32), ((0, 0), (0, pad_k), (0, 0)))
    out = svm_predict_cells_pallas(xp, svp, cp, gammas, kind=kind,
                                   interpret=runtime.resolve_interpret(interpret))
    return out[:, :m]
