"""Mesh-sharded cell training — the TPU analogue of the paper's Spark layer.

The paper (Table 4): coarse Voronoi cells are shuffled to Spark workers;
each worker solves its coarse cell via fine cells of <= 2000.  Here:

  * fine cells are padded + bin-packed (repro.distributed.planner) and laid
    out as one (n_slots, k, ...) batch;
  * the slot axis is sharded over EVERY mesh axis (pod x data x model) with
    shard_map — 512 chips solve 512 cell-batches concurrently;
  * inside a shard, vmap over local slots and the fused CV+selection
    (repro.core.cv.cv_cell) does the per-cell work — within which the
    hyper-parameter grid is itself GEMM-batched.  Three nested levels of
    parallelism, zero inter-device communication during the solve phase
    (embarrassingly parallel by construction — the paper's observed
    superlinear Spark speedup is the same effect).

With ``cfg.cd_polish > 0`` each cell's box-QP iterate gets that many
Gauss-Seidel epochs from ``repro.kernels.cd_solver`` appended; under this
module's vmap over slots those per-cell polishes execute as ONE wave-fused
CD pass per gamma (the ``cd_epochs_wave`` launch shape — see the wave
fusion contract in ``kernels/cd_solver/cd_solver.py``), so the polish
rides the wave for free instead of serializing per slot.

Test phase: test points are routed host-side to their owning cell
(nearest center — Voronoi routing), padded per slot, and evaluated with
the same sharding.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import cv as cv_mod
from repro.core import kernel_fns, select

Array = jax.Array

# jax moved shard_map out of experimental and renamed check_rep->check_vma
# on independent schedules; resolve both by inspection, not version guessing.
if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
else:                                              # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

import inspect as _inspect

_SM_PARAMS = _inspect.signature(_shard_map).parameters
_CHECK_KWARGS = ({"check_vma": False} if "check_vma" in _SM_PARAMS
                 else {"check_rep": False} if "check_rep" in _SM_PARAMS
                 else {})


def _cell_train_local(x_c, y_c, tmask_c, mask_c, gammas_c, key_c,
                      lam_c, sub_c, task_c, cfg, n_lam, n_sub):
    """vmap body: one cell."""
    sel = cv_mod.cv_cell(x_c, y_c, tmask_c, mask_c, gammas_c,
                         lam_c, sub_c, task_c, key_c, cfg,
                         n_lam=n_lam, n_sub=n_sub)
    combined = select.combine_fold_models(sel.coefs)      # (n, T, S)
    out = (combined, sel.gamma, sel.lam, sel.tau, sel.val_loss)
    if cfg.keep_surface:
        out = out + (sel.val_grid, sel.fa_grid, sel.det_grid)
    return out


@functools.partial(jax.jit, static_argnames=("cfg", "n_lam", "n_sub", "mesh", "axis_names"))
def train_cells(
    x_cells: Array,        # (n_slots, k, d)
    y_cells: Array,        # (n_slots, n_tasks, k)
    tmask_cells: Array,    # (n_slots, n_tasks, k)
    mask_cells: Array,     # (n_slots, k)
    gammas_cells: Array,   # (n_slots, n_gamma) per-cell adaptive gamma grids
    keys: Array,           # (n_slots, 2) fold PRNG keys
    lam_c: Array, sub_c: Array, task_c: Array,
    cfg: cv_mod.CVConfig,
    n_lam: int, n_sub: int,
    mesh: Mesh | None = None,
    axis_names: Tuple[str, ...] | None = None,
):
    """Returns (coefs (n_slots, k, T, S), gamma/lam/tau/val (n_slots, T, S))."""
    body = functools.partial(_cell_train_local, lam_c=lam_c, sub_c=sub_c,
                             task_c=task_c, cfg=cfg, n_lam=n_lam, n_sub=n_sub)
    vbody = jax.vmap(body)
    if mesh is None:
        return vbody(x_cells, y_cells, tmask_cells, mask_cells, gammas_cells, keys)

    spec = P(axis_names)
    shard = _shard_map(
        vbody, mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec, spec),
        out_specs=(spec,) * len(wave_keys(cfg)),
        **_CHECK_KWARGS,
    )
    return shard(x_cells, y_cells, tmask_cells, mask_cells, gammas_cells, keys)


# ------------------------------------------------------------------ waves
_WAVE_KEYS = ("coefs", "gamma", "lam", "tau", "val")
_SURFACE_KEYS = ("surf_loss", "surf_fa", "surf_det")


def wave_keys(cfg: cv_mod.CVConfig) -> Tuple[str, ...]:
    """Names (in output order) of the arrays one wave produces.

    With ``cfg.keep_surface`` the per-cell validation surface — loss plus
    hinge FA/detection counts over the whole (gamma, task, lambda, sub)
    grid — rides along; it is O(slots · grid), tiny next to the coefs, and
    is what makes the staged ``select()`` phase re-runnable without
    retraining.
    """
    return _WAVE_KEYS + (_SURFACE_KEYS if cfg.keep_surface else ())


def train_cells_waves(
    stage,
    n_slots: int,
    wave_size: int | None,
    lam_c: Array, sub_c: Array, task_c: Array,
    cfg: cv_mod.CVConfig,
    n_lam: int, n_sub: int,
    mesh: Mesh | None = None,
    axis_names: Tuple[str, ...] | None = None,
    ckpt_dir: str | None = None,
    fingerprint: str | None = None,
):
    """Wave-scheduled :func:`train_cells`: bounded staging at any n_slots.

    ``stage(lo, hi)`` materializes ONLY slots [lo, hi) — six host arrays
    ``(x, y, tmask, mask, gammas, keys)`` whose leading axis is
    ``hi - lo`` (slots past ``n_slots`` must be empty padding: zero masks).
    Every wave has the same padded slot count, so the jitted/sharded
    ``train_cells`` compiles once and peak staging memory is
    O(wave · k · d) instead of O(n_slots · k · d).

    ``ckpt_dir`` checkpoints each completed wave through
    ``repro.train.checkpoint`` (step == wave index, all waves kept); a
    re-run with the same directory, wave size, slot count AND
    ``fingerprint`` (the caller's hash of config + data identity —
    ``LiquidSVM`` passes one) restores finished waves instead of
    re-solving them — mid-fit fault tolerance for multi-hour cell sweeps.
    A mismatched fingerprint means a different run left the directory:
    its waves are ignored and re-solved.

    Preemption survival: each wave is matched INDIVIDUALLY against the
    directory (not just the latest step), so a kill at any point — mid
    checkpoint write, mid solve, between waves — leaves only complete,
    checksummed wave dirs behind; the re-run restores those and re-solves
    the rest, and the solve being deterministic per wave makes the final
    models bitwise identical to an uninterrupted run.  A wave dir that
    fails checksum verification (torn write, bit rot) is re-solved, not
    loaded.
    """
    from repro import obs
    from repro.testing import faults
    from repro.train import checkpoint as ckpt_mod

    m_solved = obs.metrics.counter("train.waves_solved")
    m_restored = obs.metrics.counter("train.waves_restored")
    m_corrupt = obs.metrics.counter("train.corrupt_waves")

    keys_out = wave_keys(cfg)
    if wave_size is None or wave_size >= n_slots:
        wave_size = n_slots
    assert wave_size > 0
    if mesh is not None and axis_names is not None:
        n_dev = int(np.prod([mesh.shape[a] for a in axis_names]))
        assert wave_size % n_dev == 0, (
            f"wave_size {wave_size} must divide over {n_dev} devices")
    n_waves = -(-n_slots // wave_size)

    restorable = set()
    if ckpt_dir is not None:
        for s in ckpt_mod.list_steps(ckpt_dir):
            try:
                extra = ckpt_mod.peek_manifest(ckpt_dir, s)["extra"]
            except ckpt_mod.CheckpointCorruptError:
                continue
            if (extra.get("wave_size") == wave_size
                    and extra.get("n_slots") == n_slots
                    and extra.get("fingerprint") == fingerprint):
                restorable.add(s)

    outs = []
    for w in range(n_waves):
        lo = w * wave_size
        faults.fire("trainer.wave.start", wave=w)
        res = None
        if w in restorable:
            with obs.tracer.span("train.wave.restore") as sp:
                try:
                    man = ckpt_mod.peek_manifest(ckpt_dir, w)
                    target = {k: np.zeros(s, np.dtype(dt)) for k, s, dt in zip(
                        sorted(keys_out), man["shapes"], man["dtypes"])}
                    tree, _, _ = ckpt_mod.restore_checkpoint(
                        ckpt_dir, target, step=w)
                    res = tuple(np.asarray(tree[k]) for k in keys_out)
                    m_restored.inc()
                except ckpt_mod.CheckpointCorruptError:
                    res = None             # torn/corrupt wave: re-solve it
                    m_corrupt.inc()
                    sp.set(wave=w, corrupt=True)
        if res is None:
            with obs.tracer.span("train.wave.stage"):
                arrays = stage(lo, lo + wave_size)
            with obs.tracer.span("train.wave.solve") as sp:
                sp.set(wave=w, slots=wave_size, cd_polish=cfg.cd_polish)
                res = train_cells(*[jnp.asarray(a) for a in arrays],
                                  lam_c, sub_c, task_c, cfg, n_lam, n_sub,
                                  mesh=mesh, axis_names=axis_names)
                res = tuple(np.asarray(r) for r in res)
            m_solved.inc()
            faults.fire("trainer.wave.solved", wave=w)
            if ckpt_dir is not None:
                with obs.tracer.span("train.wave.checkpoint"):
                    ckpt_mod.save_checkpoint(
                        ckpt_dir, w, dict(zip(keys_out, res)),
                        extra={"wave": w, "wave_size": wave_size,
                               "n_slots": n_slots, "fingerprint": fingerprint},
                        keep_last=0)
        outs.append(res)
    return tuple(np.concatenate([o[i] for o in outs])[:n_slots]
                 for i in range(len(keys_out)))


def _cell_predict_local(xt_c, sv_c, coef_c, gamma_c, kernel: str):
    """xt_c (m, d); sv_c (k, d); coef_c (k, T, S); gamma_c (T, S).

    Cross-Gram distance cache: each (task, sub) may have selected a
    different gamma but shares the same (test, SV) point pair, so the
    O(m k d) cross term is computed once per cell and the per-gamma
    epilogue is replayed under vmap.
    """
    gram_of = kernel_fns.cross_gram_fn(xt_c, sv_c, kernel)

    def per_ts(gamma, coef):
        return gram_of(gamma) @ coef                     # (m,)

    t, s = gamma_c.shape
    out = jax.vmap(per_ts)(gamma_c.reshape(-1), coef_c.reshape(coef_c.shape[0], -1).T)
    return out.T.reshape(xt_c.shape[0], t, s)            # (m, T, S)


@functools.partial(jax.jit, static_argnames=("kernel", "mesh", "axis_names"))
def predict_cells(
    xt_cells: Array,      # (n_slots, m_max, d) routed+padded test points
    sv_cells: Array,      # (n_slots, k, d)
    coef_cells: Array,    # (n_slots, k, T, S)
    gamma_cells: Array,   # (n_slots, T, S)
    kernel: str = "gauss_rbf",
    mesh: Mesh | None = None,
    axis_names: Tuple[str, ...] | None = None,
) -> Array:
    vbody = jax.vmap(functools.partial(_cell_predict_local, kernel=kernel))
    if mesh is None:
        return vbody(xt_cells, sv_cells, coef_cells, gamma_cells)
    spec = P(axis_names)
    shard = _shard_map(vbody, mesh=mesh,
                       in_specs=(spec, spec, spec, spec), out_specs=spec,
                       **_CHECK_KWARGS)
    return shard(xt_cells, sv_cells, coef_cells, gamma_cells)
