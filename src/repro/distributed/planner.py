"""Trace-time load balancing: replaces the paper's Spark shuffle.

liquidSVM's Spark layer dynamically shuffles coarse cells to workers.  On a
TPU mesh all shapes are static, so balance is decided HERE, before
compilation: cells are padded to a uniform size and greedily bin-packed
(longest-processing-time first) into per-device slots so each device gets
the same number of cells and a near-equal amount of real (unpadded) work.
This is also the straggler story for the SVM phase: there is no dynamic
work to straggle on — every device executes the same static program.

The same static-shape discipline applies to SERVING: each engine step must
lower to one fixed-shape batched launch, but per-cell request counts are
whatever traffic happened to arrive.  :func:`plan_wave` is the per-step
plan: pick a padded row count (bucketed so repeated steps reuse compiled
programs), split hot cells into multiple launch slots instead of padding
every cell to the hottest one, and order slots largest-first (LPT) so a
sharded engine inherits the balance for free.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cells.builder import CellPlan


def _round_up(v: int, mult: int) -> int:
    return -(-max(int(v), 1) // mult) * mult


@dataclasses.dataclass
class RowGroups:
    """Argsort-grouped rows for padded per-slot scatter/gather.

    For m rows routed to slots: ``rows`` is the row ids sorted by slot
    (stable, so ascending within a slot), ``slot`` the matching slot id
    per sorted row and ``pos`` its position within that slot's padded
    block.  One fancy-indexed assignment packs, a second unpacks — the
    vectorized replacement for the per-row Python loops in both the
    trainer's test phase and the serving engine:

        packed[g.slot, g.pos] = x[g.rows]          # pack
        out[g.rows] = dec[g.slot, g.pos]           # unpack
    """
    rows: np.ndarray     # (m,) int64
    slot: np.ndarray     # (m,) int64
    pos: np.ndarray      # (m,) int64
    counts: np.ndarray   # (n_slots,) int64

    @property
    def m_max(self) -> int:
        return max(int(self.counts.max()), 1) if self.counts.size else 1


def group_rows(slot_of: np.ndarray, n_slots: int) -> RowGroups:
    """Group row ids by destination slot (stable — ascending within slot)."""
    slot_of = np.asarray(slot_of, np.int64)
    counts = np.bincount(slot_of, minlength=n_slots).astype(np.int64)
    order = np.argsort(slot_of, kind="stable")
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    slot_sorted = slot_of[order]
    pos = np.arange(slot_of.shape[0], dtype=np.int64) - starts[slot_sorted]
    return RowGroups(rows=order, slot=slot_sorted, pos=pos, counts=counts)


@dataclasses.dataclass
class PackedCells:
    order: np.ndarray          # (n_slots,) cell id per slot, -1 = empty slot
    slot_of_cell: np.ndarray   # (n_cells,)
    n_devices: int
    slots_per_device: int

    @property
    def n_slots(self) -> int:
        return self.order.shape[0]


def pack_cells(plan: CellPlan, n_devices: int) -> PackedCells:
    """LPT bin packing of cells onto devices; returns a slot ordering whose
    leading axis can be sharded over the device mesh."""
    sizes = plan.mask.sum(1)
    n_cells = plan.n_cells
    slots_per_device = int(np.ceil(n_cells / n_devices))
    loads = np.zeros(n_devices)
    counts = np.zeros(n_devices, np.int32)
    assign = np.full((n_devices, slots_per_device), -1, np.int64)
    for cid in np.argsort(-sizes):  # biggest first
        # among devices with a free slot, pick the least loaded
        free = np.where(counts < slots_per_device)[0]
        dev = free[np.argmin(loads[free])]
        assign[dev, counts[dev]] = cid
        loads[dev] += sizes[cid]
        counts[dev] += 1
    order = assign.reshape(-1)
    slot_of = np.full(n_cells, -1, np.int64)
    for s, cid in enumerate(order):
        if cid >= 0:
            slot_of[cid] = s
    return PackedCells(order=order, slot_of_cell=slot_of,
                       n_devices=n_devices, slots_per_device=slots_per_device)


@dataclasses.dataclass
class WavePlan:
    """One serving step's static launch layout.

    slot_cell: (n_slots,) cell id per launch slot, -1 = padding slot
    slot_off:  (n_slots,) offset into that cell's pending queue
    slot_take: (n_slots,) pending rows consumed by this slot (<= m_pad)
    m_pad:     padded rows per slot (every slot is (m_pad, d) in the launch)
    """
    slot_cell: np.ndarray
    slot_off: np.ndarray
    slot_take: np.ndarray
    m_pad: int

    @property
    def n_slots(self) -> int:
        return self.slot_cell.shape[0]

    @property
    def n_requests(self) -> int:
        return int(self.slot_take.sum())

    @property
    def pad_fraction(self) -> float:
        """Fraction of launched rows that are padding (lower = better)."""
        total = self.n_slots * self.m_pad
        return 1.0 - self.n_requests / max(total, 1)


def plan_wave(counts: np.ndarray, m_pad: int | None = None,
              row_bucket: int = 8, slot_bucket: int = 4) -> WavePlan:
    """Padding/bin-packing plan for one engine step.

    ``counts`` (n_cells,) pending requests per cell.  The padded row count
    defaults to the 75th-percentile active-cell load (bucketed to
    ``row_bucket``): cold cells pad a little, hot cells are CHUNKED into
    several launch slots — so one viral cell cannot inflate the whole
    step's padded shape.  Slot count is bucketed to ``slot_bucket`` and
    slots are LPT-ordered; both paddings keep the jitted launch shape set
    small across steps.
    """
    counts = np.asarray(counts, np.int64)
    active = np.where(counts > 0)[0]
    if active.size == 0:
        return WavePlan(slot_cell=np.full(0, -1, np.int64),
                        slot_off=np.zeros(0, np.int64),
                        slot_take=np.zeros(0, np.int64),
                        m_pad=row_bucket)
    if m_pad is None:
        m_pad = _round_up(int(np.percentile(counts[active], 75)), row_bucket)
    cells, offs, takes = [], [], []
    for cid in active:
        left, off = int(counts[cid]), 0
        while left > 0:
            take = min(left, m_pad)
            cells.append(cid)
            offs.append(off)
            takes.append(take)
            off += take
            left -= take
    order = np.argsort(-np.asarray(takes), kind="stable")   # LPT
    n_slots = _round_up(len(cells), slot_bucket)
    slot_cell = np.full(n_slots, -1, np.int64)
    slot_off = np.zeros(n_slots, np.int64)
    slot_take = np.zeros(n_slots, np.int64)
    for s, o in enumerate(order):
        slot_cell[s] = cells[o]
        slot_off[s] = offs[o]
        slot_take[s] = takes[o]
    return WavePlan(slot_cell=slot_cell, slot_off=slot_off,
                    slot_take=slot_take, m_pad=int(m_pad))
