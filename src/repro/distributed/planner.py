"""Trace-time load balancing: replaces the paper's Spark shuffle.

liquidSVM's Spark layer dynamically shuffles coarse cells to workers.  On a
TPU mesh all shapes are static, so balance is decided HERE, before
compilation: cells are padded to a uniform size and greedily bin-packed
(longest-processing-time first) into per-device slots so each device gets
the same number of cells and a near-equal amount of real (unpadded) work.
This is also the straggler story for the SVM phase: there is no dynamic
work to straggle on — every device executes the same static program.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cells.builder import CellPlan


@dataclasses.dataclass
class PackedCells:
    order: np.ndarray          # (n_slots,) cell id per slot, -1 = empty slot
    slot_of_cell: np.ndarray   # (n_cells,)
    n_devices: int
    slots_per_device: int

    @property
    def n_slots(self) -> int:
        return self.order.shape[0]


def pack_cells(plan: CellPlan, n_devices: int) -> PackedCells:
    """LPT bin packing of cells onto devices; returns a slot ordering whose
    leading axis can be sharded over the device mesh."""
    sizes = plan.mask.sum(1)
    n_cells = plan.n_cells
    slots_per_device = int(np.ceil(n_cells / n_devices))
    loads = np.zeros(n_devices)
    counts = np.zeros(n_devices, np.int32)
    assign = np.full((n_devices, slots_per_device), -1, np.int64)
    for cid in np.argsort(-sizes):  # biggest first
        # among devices with a free slot, pick the least loaded
        free = np.where(counts < slots_per_device)[0]
        dev = free[np.argmin(loads[free])]
        assign[dev, counts[dev]] = cid
        loads[dev] += sizes[cid]
        counts[dev] += 1
    order = assign.reshape(-1)
    slot_of = np.full(n_cells, -1, np.int64)
    for s, cid in enumerate(order):
        if cid >= 0:
            slot_of[cid] = s
    return PackedCells(order=order, slot_of_cell=slot_of,
                       n_devices=n_devices, slots_per_device=slots_per_device)
