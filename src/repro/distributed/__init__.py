from repro.distributed.planner import pack_cells
from repro.distributed.cell_trainer import train_cells, predict_cells

__all__ = ["pack_cells", "train_cells", "predict_cells"]
