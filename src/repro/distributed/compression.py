"""Error-feedback int8 gradient compression for the cross-pod DP axis.

The inter-pod links are the scarcest bandwidth at 1000+ nodes; the classic
mitigation is quantized all-reduce with error feedback (1-bit Adam /
EF-SGD family):

    q = round((g + err) / scale) in int8        scale = max|g + err| / 127
    g_hat = psum(q) * scale_shared / n          (4x fewer bytes on the wire)
    err'  = (g + err) - q * scale               (residual carried forward)

``ef_psum`` is shard_map-compatible: it quantizes per-shard, all-reduces
int8 payloads (widened to int32 for the sum — the wire format is int8; the
widening models the accumulator), and shares one scale via a max-reduce.
EF keeps the asymptotic convergence of uncompressed SGD/Adam (Karimireddy
et al. 2019); the test suite checks the residual-norm contraction.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array
PyTree = Any


def quantize_int8(g: Array) -> Tuple[Array, Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def ef_compress(g: Array, err: Array) -> Tuple[Array, Array, Array]:
    """-> (int8 payload, f32 scale, new error residual)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    new_err = corrected - dequantize_int8(q, scale)
    return q, scale, new_err


def ef_psum(g: Array, err: Array, axis_name: str) -> Tuple[Array, Array]:
    """Compressed all-reduce-mean over ``axis_name`` (use under shard_map).

    Returns (g_hat averaged over the axis, new local error residual).
    """
    corrected = g.astype(jnp.float32) + err
    # shared scale so the int8 payloads are summable across devices
    local_max = jnp.max(jnp.abs(corrected))
    global_max = jax.lax.pmax(local_max, axis_name)
    scale = jnp.maximum(global_max / 127.0, 1e-30)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - q.astype(jnp.float32) * scale
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)   # int8 wire format
    n = jax.lax.psum(jnp.ones((), jnp.int32), axis_name)
    return total.astype(jnp.float32) * scale / n.astype(jnp.float32), new_err


def ef_psum_tree(grads: PyTree, errs: PyTree, axis_name: str
                 ) -> Tuple[PyTree, PyTree]:
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(errs)
    outs = [ef_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    g_hat = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return g_hat, new_e


def init_error_state(params: PyTree) -> PyTree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
