"""repro: liquidSVM (Steinwart & Thomann, 2017) as a multi-pod JAX framework.

Layers:
  repro.core         solvers + CV + selection (the paper's contribution)
  repro.cells        working-set decomposition (random/Voronoi/recursive/overlap)
  repro.tasks        OvA/AvA/NP/quantile task creation
  repro.data         synthetic data + scaling + LM token pipeline
  repro.distributed  mesh-aware cell sharding, compression, planner
  repro.kernels      Pallas TPU kernels (kernel_matrix, cd_solver, svm_predict,
                     flash_attention) with jnp oracles
  repro.models       assigned LM architectures (GQA/MoE/RWKV6/Mamba/hybrid)
  repro.train        optimizers, checkpointing, fault tolerance, loops
  repro.serve        KV cache + prefill/decode
  repro.configs      one config per assigned architecture
  repro.launch       mesh, multi-pod dry-run, train/serve drivers
"""
__version__ = "1.0.0"
