"""repro: liquidSVM (Steinwart & Thomann, 2017) as a multi-pod JAX framework.

Layers:
  repro.api          staged train->select->test sessions, scenario
                     front-ends (mcSVM/lsSVM/qtSVM/exSVM/nplSVM/rocSVM),
                     string-key config layer
  repro.cli          `python -m repro.cli {train,select,test}` — the staged
                     cycle as separate processes over persisted artifacts
  repro.core         solvers + CV + selection (the paper's contribution)
  repro.cells        working-set decomposition (random/Voronoi/recursive/overlap)
  repro.tasks        OvA/AvA/NP/quantile task creation
  repro.data         synthetic data + scaling + LM token pipeline
  repro.distributed  mesh-aware cell sharding, compression, planner
  repro.kernels      Pallas TPU kernels (kernel_matrix, cd_solver, svm_predict,
                     flash_attention) with jnp oracles
  repro.models       assigned LM architectures (GQA/MoE/RWKV6/Mamba/hybrid)
  repro.train        optimizers, checkpointing, fault tolerance, loops
  repro.serve        KV cache + prefill/decode
  repro.configs      one config per assigned architecture
  repro.launch       mesh, multi-pod dry-run, train/serve drivers
"""
__version__ = "1.0.0"
