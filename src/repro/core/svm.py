"""High-level single-working-set SVM: the paper's train / select / test cycle
for one (possibly multi-task) working set.  Cell composition lives in
``repro.cells`` / ``repro.train.svm_trainer``; distribution in
``repro.distributed``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cv as cv_mod
from repro.core import grids, kernel_fns, select
from repro.tasks.builder import combine_decisions

Array = jax.Array


class TrainedSVM(NamedTuple):
    """Everything the test phase needs (a pytree — shards/checkpoints cleanly).

    Multi-task: coefs (n, n_tasks, n_sub); per-(task, sub) hyper-params.
    """
    sv_x: Array        # (n, d)
    sv_mask: Array     # (n,)
    coefs: Array       # (n, n_tasks, n_sub)
    gamma: Array       # (n_tasks, n_sub)
    lam: Array
    tau: Array
    val_loss: Array
    kernel: str = "gauss_rbf"

    def decision_function(self, x_test: Array) -> Array:
        """(m, d) -> (m, n_tasks, n_sub).

        Each (task, sub) can select a different gamma; the cross D² matrix
        is gamma-independent, so it is computed once and each (task, sub)
        replays only the cheap per-gamma epilogue (vmap over the small task
        axis).  Kernels without a D² factorization fall back to one full
        cross-Gram per (task, sub).
        """
        x_test = jnp.asarray(x_test, jnp.float32)
        gram_of = kernel_fns.cross_gram_fn(x_test, self.sv_x, self.kernel)

        def per_ts(gamma, coef):
            return gram_of(gamma) @ coef

        t, s = self.gamma.shape
        gflat = self.gamma.reshape(-1)
        cflat = self.coefs.reshape(self.coefs.shape[0], -1).T  # (T*S, n)
        out = jax.vmap(per_ts)(gflat, cflat)                   # (T*S, m)
        return out.T.reshape(x_test.shape[0], t, s)

    def predict_label(self, x_test: Array, scenario: str = "binary",
                      classes: np.ndarray | None = None,
                      pairs: np.ndarray | None = None,
                      sub: int = 0) -> np.ndarray:
        """Scenario-aware labels: binary signs by default; OvA argmax /
        AvA pairwise votes over the task axis when a multi-task model is
        paired with its class values (``tasks.builder`` combiners), so
        multi-class models predict class values end-to-end."""
        return combine_decisions(self.decision_function(x_test), scenario,
                                 classes=classes, pairs=pairs, sub=sub)


def train_select(
    x: Array,
    y: Array,
    mask: Array | None = None,
    cfg: cv_mod.CVConfig = cv_mod.CVConfig(),
    grid: grids.GridSpec | None = None,
    y_tasks: Array | None = None,
    task_mask: Array | None = None,
    seed: int = 0,
) -> TrainedSVM:
    """Train + select on one working set.

    Single-task by default (y used directly); pass y_tasks/task_mask
    (n_tasks, n) for OvA/AvA multi-task working sets.
    """
    x = jnp.asarray(x, jnp.float32)
    n, d = x.shape
    mask = jnp.ones((n,), jnp.float32) if mask is None else jnp.asarray(mask, jnp.float32)
    if y_tasks is None:
        y_tasks = jnp.asarray(y, jnp.float32)[None, :]
        task_mask = jnp.ones_like(y_tasks)
    else:
        y_tasks = jnp.asarray(y_tasks, jnp.float32)
        task_mask = (jnp.ones_like(y_tasks) if task_mask is None
                     else jnp.asarray(task_mask, jnp.float32))

    if grid is None:
        med = kernel_fns.median_heuristic(x, mask)
        grid = grids.liquid_grid(n=int(n), dim=int(d), median_dist=med)

    lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(grid, cfg, y_tasks.shape[0])
    key = jax.random.PRNGKey(seed)
    sel = cv_mod.cv_cell(x, y_tasks, task_mask, mask, grid.gammas,
                         lam_c, sub_c, task_c, key, cfg, n_lam=n_lam, n_sub=n_sub)
    combined = select.combine_fold_models(sel.coefs)   # (n, T, S)
    return TrainedSVM(sv_x=x, sv_mask=mask, coefs=combined,
                      gamma=sel.gamma, lam=sel.lam, tau=sel.tau,
                      val_loss=sel.val_loss, kernel=cfg.kernel)


def test_error(model: TrainedSVM, x_test: Array, y_test: Array,
               task: str = "classify",
               classes: np.ndarray | None = None,
               pairs: np.ndarray | None = None,
               sub: int = 0) -> Array:
    """Test-phase error.  ``task`` "classify"/"mse" evaluate the (0, sub)
    decision column (single-task models); "ova"/"ava" combine the full task
    axis into class values first (misclassification rate vs y_test)."""
    if task in ("ova", "ava"):
        pred = model.predict_label(jnp.asarray(x_test, jnp.float32),
                                   scenario=task, classes=classes,
                                   pairs=pairs, sub=sub)
        return jnp.mean((jnp.asarray(pred) != jnp.asarray(y_test))
                        .astype(jnp.float32))
    f = model.decision_function(jnp.asarray(x_test, jnp.float32))[:, 0, sub]
    y_test = jnp.asarray(y_test, jnp.float32)
    if task == "classify":
        return jnp.mean((f * y_test <= 0).astype(jnp.float32))
    if task == "mse":
        return jnp.mean((f - y_test) ** 2)
    raise ValueError(task)
