# liquidSVM's primary contribution: solvers + integrated hyper-parameter
# selection + working-set management, re-expressed as batched JAX programs.
from repro.core import grids, kernel_fns, select, svm
from repro.core.cv import CVConfig, cv_cell, make_fold_masks
from repro.core.svm import TrainedSVM, test_error, train_select

__all__ = [
    "grids", "kernel_fns", "select", "svm",
    "CVConfig", "cv_cell", "make_fold_masks",
    "TrainedSVM", "test_error", "train_select",
]
