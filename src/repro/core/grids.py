"""Hyper-parameter grids (liquidSVM §2 "Hyper-Parameter Selection", App. B/C).

Two families:

* ``libsvm_grid`` — the fixed 10x11 grid from libsvm's tools/grid.py, used
  by the paper's benchmark tables.  libsvm's gamma is a precision; we
  convert to liquidSVM's length-scale convention.
* ``liquid_grid`` — liquidSVM's default geometric 10x10 grid "where the
  endpoints are scaled to accommodate the number of samples in every fold,
  the cell size, and the dimension".  grid_choice=0/1/2 -> 10x10 / 15x15 /
  20x20 (paper App. C).

Grids are returned as (gammas, lambdas) 1-D arrays; the CV driver takes
their Cartesian product, with gamma as the *outer* loop so each Gram matrix
is re-used across the full lambda path (paper: "the required kernel
matrices may be re-used").
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import kernel_fns

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class GridSpec:
    gammas: Array  # length-scale convention
    lambdas: Array  # regularization in  lambda ||f||^2 + (1/n) sum L

    @property
    def shape(self) -> Tuple[int, int]:
        return (len(self.gammas), len(self.lambdas))


def libsvm_grid(n: int) -> GridSpec:
    """The paper's 10x11 'libsvm grid'.

    gamma_libsvm in 2^{3,1,-1,...,-15}; cost in 2^{-5,-3,...,15}.
    cost C relates to lambda by C = 1/(2 lambda n).
    """
    g = 2.0 ** np.arange(3, -17, -2, dtype=np.float64)  # 10 values
    cost = 2.0 ** np.arange(-5, 17, 2, dtype=np.float64)  # 11 values
    lam = 1.0 / (2.0 * cost * n)
    return GridSpec(
        gammas=kernel_fns.libsvm_gamma_to_scale(jnp.asarray(g, jnp.float32)),
        lambdas=jnp.asarray(np.sort(lam)[::-1].copy(), jnp.float32),  # descending
    )


def liquid_grid(
    n: int,
    dim: int,
    median_dist: float | Array = 1.0,
    grid_choice: int = 0,
    cell_size: int | None = None,
) -> GridSpec:
    """liquidSVM's adaptive geometric grid.

    Endpoint heuristics (documented adaptation; the C++ package's exact
    constants are not published in the paper):

    * gamma_max ~ 5 x median pairwise distance (kernel nearly constant
      beyond that — smoothest candidate).
    * gamma_min ~ median distance x (k / n_fold)^(1/d): the typical
      nearest-neighbor spacing once a fold of the (cell-sized) working set
      is considered — wigglier candidates are statistically useless.
    * lambda_max = 1.0 (essentially the constant model), lambda_min =
      1/(4 n_fold^2): beyond interpolation strength.  Geometric in between.
    """
    sizes = {0: (10, 10), 1: (15, 15), 2: (20, 20)}
    if grid_choice not in sizes:
        raise ValueError(f"grid_choice must be 0/1/2, got {grid_choice}")
    n_gamma, n_lambda = sizes[grid_choice]
    n_fold = max(int(n * 0.8), 2)  # 5-fold default: training part of a fold
    k = cell_size if cell_size is not None else n_fold
    k = min(k, n_fold)

    med = jnp.asarray(median_dist, jnp.float32)
    gamma_max = 5.0 * med
    gamma_min = med * jnp.power(jnp.asarray(max(k, 2), jnp.float32) / n_fold, 1.0 / dim) / jnp.power(
        jnp.asarray(n_fold, jnp.float32), 1.0 / max(dim, 1)
    )
    gamma_min = jnp.minimum(gamma_min, gamma_max / 8.0)
    r = jnp.linspace(0.0, 1.0, n_gamma)
    gammas = gamma_max * jnp.power(gamma_min / gamma_max, r)

    lam_max = 1.0
    lam_min = 1.0 / (4.0 * float(n_fold) ** 2)
    s = np.linspace(0.0, 1.0, n_lambda)
    lambdas = lam_max * np.power(lam_min / lam_max, s)
    return GridSpec(gammas=gammas.astype(jnp.float32), lambdas=jnp.asarray(lambdas, jnp.float32))


def adaptive_subgrid(full: GridSpec, level: int) -> GridSpec:
    """adaptivity_control (paper App. C): coarse pass over a subset.

    level=1 keeps every 2nd gamma/lambda; level=2 every 3rd.  The CV driver
    runs the coarse grid first, then a refinement window around the argmin
    (see repro.core.cv.adaptive_cv).
    """
    if level <= 0:
        return full
    step = level + 1
    return GridSpec(gammas=full.gammas[::step], lambdas=full.lambdas[::step])
