"""Kernel functions (liquidSVM §2 "Solvers") and the distance-cache API.

liquidSVM's RBF convention (paper Table 5) is ``k_gamma(u, v) =
exp(-||u-v||^2 / gamma^2)`` — gamma is a *length scale*, unlike libsvm's
precision convention ``exp(-g ||u-v||^2)``.  ``libsvm_gamma_to_scale``
converts between the two so the "libsvm grid" benchmarks are faithful.

All pairwise ops use the MXU-friendly decomposition
``||u-v||^2 = ||u||^2 + ||v||^2 - 2 u.v`` so the dominant cost is a matmul.
The Pallas kernels in ``repro.kernels.kernel_matrix`` implement the same
contract with explicit VMEM tiling; these jnp versions are the oracles and
the default CPU path.

Distance-cache pipeline (the package's headline kernel-matrix re-use,
§2 "Hyper-Parameter Selection"): both built-in kernels *factor through the
squared-distance matrix* — ``K_gamma = epilogue_gamma(D2)`` with D2
gamma-independent.  The registry records that factorization, so grid scans
(``repro.core.cv``) and multi-gamma prediction (``repro.core.svm``) pay the
O(n²d) MXU cross term ONCE and replay an O(n²) VPU epilogue per gamma.
:class:`CachedGram` / :func:`gram_for_gammas` expose the same shape to
users; kernels registered without an epilogue transparently fall back to
the per-gamma full evaluation.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.kernels.kernel_matrix import ops as km_ops
from repro.kernels.kernel_matrix import ref as km_ref

Array = jax.Array

_EPS = 1e-12

KernelFn = Callable[[Array, Array, Array], Array]
# (d2, gamma, out_dtype) -> K;  out_dtype in {"f32", "bf16"}
D2Epilogue = Callable[[Array, Array, str], Array]


def sq_dists(x: Array, z: Array) -> Array:
    """Pairwise squared distances, (n, d) x (m, d) -> (n, m), f32 accum.

    Single implementation lives in ``kernels.kernel_matrix.ref`` (as with
    the epilogues) so oracle and pipeline can never drift apart.
    """
    return km_ref.sq_dists_ref(x, z)


def gaussian(x: Array, z: Array, gamma: Array) -> Array:
    """liquidSVM Gaussian RBF: exp(-||u-v||^2 / gamma^2).

    Delegates to the single epilogue implementation in
    ``kernels.kernel_matrix.ref`` so oracle and pipeline share one formula.
    """
    return km_ref.gram_from_d2_ref(sq_dists(x, z), gamma, "gauss_rbf")


def laplacian(x: Array, z: Array, gamma: Array) -> Array:
    """Laplacian kernel: exp(-||u-v|| / gamma)."""
    return km_ref.gram_from_d2_ref(sq_dists(x, z), gamma, "laplacian")


def libsvm_gamma_to_scale(g: Array) -> Array:
    """libsvm exp(-g d^2) == liquidSVM exp(-d^2/gamma^2) at gamma = g**-0.5."""
    return jnp.asarray(g, jnp.float32) ** -0.5


def _cast_out(k: Array, out_dtype: str) -> Array:
    """Honor the out_dtype contract on fallback paths too (the D² epilogue
    fuses this downcast; full-kernel fallbacks apply it after the fact)."""
    return k.astype(jnp.bfloat16) if out_dtype == "bf16" else k


def _builtin_epilogue(kind: str) -> D2Epilogue:
    def epilogue(d2: Array, gamma: Array, out_dtype: str = "f32") -> Array:
        return km_ops.gram_from_d2(d2, gamma, kind=kind, out_dtype=out_dtype)

    return epilogue


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Registry entry: the full kernel plus its (optional) D² factorization.

    ``d2_epilogue(d2, gamma, out_dtype)`` must satisfy
    ``fn(x, z, gamma) == d2_epilogue(sq_dists(x, z), gamma, "f32")``; leave
    it None for kernels that do not factor through pairwise distances (the
    grid scan then falls back to one full evaluation per gamma).
    """
    name: str
    fn: KernelFn
    d2_epilogue: Optional[D2Epilogue] = None

    @property
    def factors_through_d2(self) -> bool:
        return self.d2_epilogue is not None


_REGISTRY: Dict[str, KernelSpec] = {
    "gauss_rbf": KernelSpec("gauss_rbf", gaussian, _builtin_epilogue("gauss_rbf")),
    "laplacian": KernelSpec("laplacian", laplacian, _builtin_epilogue("laplacian")),
}


def register_kernel(name: str, fn: KernelFn,
                    d2_epilogue: Optional[D2Epilogue] = None) -> None:
    """Paper: 'it is possible to add own normalized kernels'.

    Pass ``d2_epilogue`` when the kernel is a function of ``||u-v||^2`` so
    grid scans can reuse the cached distance matrix across gammas.
    """
    _REGISTRY[name] = KernelSpec(name, fn, d2_epilogue)


def unregister_kernel(name: str) -> None:
    """Remove a registered kernel (tests / plugin teardown).

    NOTE: jit'd entry points (``gram``, ``gram_for_gammas``) key their
    compilation cache by the static name — re-registering the same name
    with a different fn will NOT recompile already-traced shapes.  Use a
    fresh name per distinct kernel function.
    """
    _REGISTRY.pop(name)


def get_kernel(name: str) -> KernelFn:
    return get_spec(name).fn


def get_spec(name: str) -> KernelSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def factors_through_d2(name: str) -> bool:
    return get_spec(name).factors_through_d2


@functools.partial(jax.jit, static_argnames=("name",))
def gram(x: Array, gamma: Array, name: str = "gauss_rbf") -> Array:
    return get_kernel(name)(x, x, gamma)


@functools.partial(jax.jit, static_argnames=("name",))
def cross_gram(x: Array, z: Array, gamma: Array, name: str = "gauss_rbf") -> Array:
    return get_kernel(name)(x, z, gamma)


@dataclasses.dataclass(frozen=True)
class CachedGram:
    """Gamma-independent state of a Gram matrix: D² plus the epilogue.

    Build once per working set (``symmetric=True`` halves the MXU flops for
    the train Gram), then ``.gram(gamma)`` is a pure VPU pass per gamma.
    A jax pytree (D² is the only leaf), so it threads through jit/vmap.

    ``d2_dtype="bf16"`` stores the cached D² itself in bfloat16 — half the
    resident footprint for long-lived caches (the serving engine keeps one
    D² per routed batch).  The epilogue always accumulates in f32; the error
    is one bf16 rounding of d2 BEFORE the exp, so for the Gaussian kernel
    ``|ΔK| = K * (d2/g²) * δ <= e^{-1} * 2^-8`` — bounded uniformly in
    gamma because u e^{-u} peaks at 1/e (steep small-gamma epilogues hit the
    bound, they do not exceed it; see the error-bound test).
    """
    d2: Array
    name: str = "gauss_rbf"

    @classmethod
    def build(cls, x: Array, z: Array | None = None,
              name: str = "gauss_rbf", d2_dtype: str = "f32") -> "CachedGram":
        spec = get_spec(name)
        if not spec.factors_through_d2:
            raise ValueError(
                f"kernel {name!r} does not factor through D2; "
                "use get_kernel(name) per gamma instead")
        if z is None:
            d2 = km_ops.sq_dists(x, x, symmetric=True)
        else:
            d2 = km_ops.sq_dists(x, z)
        if d2_dtype == "bf16":
            d2 = d2.astype(jnp.bfloat16)
        elif d2_dtype != "f32":
            raise ValueError(f"d2_dtype must be f32|bf16, got {d2_dtype!r}")
        return cls(d2=d2, name=name)

    @property
    def nbytes(self) -> int:
        return self.d2.size * self.d2.dtype.itemsize

    def gram(self, gamma: Array, out_dtype: str = "f32") -> Array:
        return get_spec(self.name).d2_epilogue(self.d2, gamma, out_dtype)

    def grams(self, gammas: Array, out_dtype: str = "f32") -> Array:
        """(n_gamma,) -> (n_gamma, n, m) stacked Grams, one D² read each."""
        return jax.vmap(lambda g: self.gram(g, out_dtype))(gammas)


jax.tree_util.register_pytree_node(
    CachedGram,
    lambda cg: ((cg.d2,), cg.name),
    lambda name, leaves: CachedGram(d2=leaves[0], name=name),
)


@functools.partial(jax.jit, static_argnames=("name", "symmetric", "out_dtype"))
def gram_for_gammas(x: Array, z: Array, gammas: Array, name: str = "gauss_rbf",
                    symmetric: bool = False, out_dtype: str = "f32") -> Array:
    """Stacked (n_gamma, n, m) Grams with at most one D² materialization.

    Kernels that factor through D² pay one O(n m d) cross term total;
    others fall back to the full per-gamma evaluation (jnp oracle).
    ``symmetric=True`` means "the Gram of x with itself": z is ignored and
    the halved upper-triangle path is used.
    """
    spec = get_spec(name)
    if symmetric:
        z = x
    if not spec.factors_through_d2:
        return jax.vmap(lambda g: _cast_out(spec.fn(x, z, g), out_dtype))(gammas)
    d2 = km_ops.sq_dists(x, z, symmetric=symmetric)
    return jax.vmap(lambda g: spec.d2_epilogue(d2, g, out_dtype))(gammas)


def cross_gram_fn(x: Array, z: Array, name: str = "gauss_rbf",
                  d2_dtype: str = "f32"):
    """Per-gamma cross-Gram closure for a FIXED (x, z) pair.

    Returns ``gram_of(gamma) -> (n, m)``; the gamma-independent D² is
    cached up front when the kernel factors through it (the multi-gamma
    prediction paths in ``core.svm`` / ``distributed.cell_trainer`` call
    this once per batch, then sweep selected gammas for free).
    ``d2_dtype="bf16"`` halves the cache footprint (see ``CachedGram``).
    """
    spec = get_spec(name)
    if spec.factors_through_d2:
        return CachedGram.build(x, z, name=name, d2_dtype=d2_dtype).gram
    return lambda gamma, out_dtype="f32": _cast_out(spec.fn(x, z, gamma), out_dtype)


def median_heuristic(x: Array, mask: Array | None = None, max_points: int = 512) -> Array:
    """Median pairwise distance on a subsample — the classic bandwidth scale.

    Deterministic subsample (strided) so it is jit/trace friendly.
    """
    n = x.shape[0]
    stride = max(1, n // max_points)
    xs = x[::stride]
    d2 = sq_dists(xs, xs)
    if mask is not None:
        ms = mask[::stride].astype(bool)
        valid = ms[:, None] & ms[None, :]
        # push masked-out entries to the median-neutral end by replacing with nan
        d2 = jnp.where(valid, d2, jnp.nan)
        off = ~jnp.eye(xs.shape[0], dtype=bool)
        d2 = jnp.where(off, d2, jnp.nan)
        med = jnp.nanmedian(d2)
    else:
        off = ~jnp.eye(xs.shape[0], dtype=bool)
        med = jnp.nanmedian(jnp.where(off, d2, jnp.nan))
    return jnp.sqrt(jnp.maximum(med, _EPS))
