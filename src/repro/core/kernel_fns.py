"""Kernel functions (liquidSVM §2 "Solvers").

liquidSVM's RBF convention (paper Table 5) is ``k_gamma(u, v) =
exp(-||u-v||^2 / gamma^2)`` — gamma is a *length scale*, unlike libsvm's
precision convention ``exp(-g ||u-v||^2)``.  ``libsvm_gamma_to_scale``
converts between the two so the "libsvm grid" benchmarks are faithful.

All pairwise ops use the MXU-friendly decomposition
``||u-v||^2 = ||u||^2 + ||v||^2 - 2 u.v`` so the dominant cost is a matmul.
The Pallas kernel in ``repro.kernels.kernel_matrix`` implements the same
contract with explicit VMEM tiling; these jnp versions are the oracles and
the default CPU path.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp

Array = jax.Array

_EPS = 1e-12


def sq_dists(x: Array, z: Array) -> Array:
    """Pairwise squared distances, (n, d) x (m, d) -> (n, m), f32 accum."""
    x = x.astype(jnp.float32)
    z = z.astype(jnp.float32)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    zz = jnp.sum(z * z, axis=-1)[None, :]
    cross = x @ z.T
    return jnp.maximum(xx + zz - 2.0 * cross, 0.0)


def gaussian(x: Array, z: Array, gamma: Array) -> Array:
    """liquidSVM Gaussian RBF: exp(-||u-v||^2 / gamma^2)."""
    g2 = jnp.asarray(gamma, jnp.float32) ** 2
    return jnp.exp(-sq_dists(x, z) / jnp.maximum(g2, _EPS))


def laplacian(x: Array, z: Array, gamma: Array) -> Array:
    """Laplacian kernel: exp(-||u-v|| / gamma)."""
    d = jnp.sqrt(sq_dists(x, z) + _EPS)
    return jnp.exp(-d / jnp.maximum(jnp.asarray(gamma, jnp.float32), _EPS))


def libsvm_gamma_to_scale(g: Array) -> Array:
    """libsvm exp(-g d^2) == liquidSVM exp(-d^2/gamma^2) at gamma = g**-0.5."""
    return jnp.asarray(g, jnp.float32) ** -0.5


_REGISTRY: Dict[str, Callable[[Array, Array, Array], Array]] = {
    "gauss_rbf": gaussian,
    "laplacian": laplacian,
}


def register_kernel(name: str, fn: Callable[[Array, Array, Array], Array]) -> None:
    """Paper: 'it is possible to add own normalized kernels'."""
    _REGISTRY[name] = fn


def get_kernel(name: str) -> Callable[[Array, Array, Array], Array]:
    if name not in _REGISTRY:
        raise KeyError(f"unknown kernel {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


@functools.partial(jax.jit, static_argnames=("name",))
def gram(x: Array, gamma: Array, name: str = "gauss_rbf") -> Array:
    return get_kernel(name)(x, x, gamma)


@functools.partial(jax.jit, static_argnames=("name",))
def cross_gram(x: Array, z: Array, gamma: Array, name: str = "gauss_rbf") -> Array:
    return get_kernel(name)(x, z, gamma)


def median_heuristic(x: Array, mask: Array | None = None, max_points: int = 512) -> Array:
    """Median pairwise distance on a subsample — the classic bandwidth scale.

    Deterministic subsample (strided) so it is jit/trace friendly.
    """
    n = x.shape[0]
    stride = max(1, n // max_points)
    xs = x[::stride]
    d2 = sq_dists(xs, xs)
    if mask is not None:
        ms = mask[::stride].astype(bool)
        valid = ms[:, None] & ms[None, :]
        # push masked-out entries to the median-neutral end by replacing with nan
        d2 = jnp.where(valid, d2, jnp.nan)
        off = ~jnp.eye(xs.shape[0], dtype=bool)
        d2 = jnp.where(off, d2, jnp.nan)
        med = jnp.nanmedian(d2)
    else:
        off = ~jnp.eye(xs.shape[0], dtype=bool)
        med = jnp.nanmedian(jnp.where(off, d2, jnp.nan))
    return jnp.sqrt(jnp.maximum(med, _EPS))
