"""k-fold cross-validation driver (liquidSVM §2 "Hyper-Parameter Selection").

Execution shape (the whole point of the TPU port):

    D2 = sq_dists(X, X)                    # ONE distance matrix per cell —
                                           #   the only O(n²d) MXU cross term
                                           #   in the whole gamma scan
    for gamma in gammas:                   # lax.scan — Gram re-use
        K = epilogue(D2, gamma)            # O(n²) VPU pass: exp(-D2/gamma²),
                                           #   bf16 downcast fused on write;
                                           #   shared by all folds, all TASKS,
                                           #   and the full lambda/tau/w grid
        for fold in folds:                 # vmap — "multi-threading"
            solve ALL columns (task x lambda x tau/w) as one batched box-QP
            validation predictions = K @ C             (one GEMM)
        streaming selection: keep the per-(task, sub) best model so far

Distance-cache pipeline: both built-in kernels factor through the
gamma-independent D², so the Gram rematerialization cost across an n_gamma
grid drops from n_gamma GEMMs to one GEMM plus n_gamma elementwise passes
(kernels that do not factor — see ``kernel_fns.KernelSpec`` — fall back to
one full evaluation per gamma, as does ``cache_d2=False``, kept as the
benchmark baseline).  On TPU the D² kernel computes only upper-triangle
tiles and mirrors them (``sq_dists_pallas(symmetric=True)``), and the bf16
read path for the hinge/quantile solvers is fused into the per-gamma
epilogue's single VMEM pass (``gram_from_d2_pallas(out_dtype="bf16")``) —
the Gram is never materialized in f32 at all on that path.

Columns are task-major:  col = t * (n_lam * n_sub) + l * n_sub + s, where
"sub" is the quantile/expectile tau or the hinge class-weight index.
Folds are boolean masks (no gathers — static shapes); padding and
task-exclusion are realized as zero-width boxes, which removes a sample
from the dual EXACTLY.

liquidSVM's "warm start across the grid" appears twice:
  * across lambda/tau/w/task: solved simultaneously as GEMM columns
    (strictly stronger than sequential warm starts);
  * across gamma: the previous gamma's solution seeds the next scan step.

Selection is fused into the gamma scan (train phase and select phase in one
pass), so peak memory is O(n x columns), never O(n x whole grid x gammas).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import kernel_fns
from repro.core.grids import GridSpec
from repro.core.solvers import base as qp
from repro.kernels.cd_solver import ops as cd_ops
from repro.core.solvers import expectile as exp_solver
from repro.core.solvers import hinge as hinge_solver
from repro.core.solvers import least_squares as ls_solver
from repro.core.solvers import quantile as q_solver

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class CVConfig:
    solver: str = "hinge"           # hinge | ls | quantile | expectile
    kernel: str = "gauss_rbf"
    n_folds: int = 5
    fold_scheme: str = "random"     # random | stratified | blocks
    tol: float = 1e-3
    max_iters: int = 1000
    val_loss: str = "auto"          # auto: 0-1 for hinge, mse for ls, pinball, ...
    shared_lipschitz: bool = True   # one L per gamma (False: per-fold masked
                                    # Gram + power iteration — the baseline)
    gram_dtype: str = "f32"         # f32 | bf16 (hinge/quantile solve reads
                                    # a 2-byte Gram, accumulates f32 — §Perf)
    cache_d2: bool = True           # hoist the gamma-independent D² out of
                                    # the gamma scan (False: recompute the
                                    # full Gram per gamma — the baseline)
    keep_surface: bool = False      # retain the full validation surface
                                    # (loss + hinge FA/detection counts) per
                                    # grid point — the staged select() phase
                                    # re-runs selection rules over it without
                                    # retraining (repro.api.session)
    taus: Tuple[float, ...] = (0.5,)       # quantile/expectile levels (sub axis)
    weights: Tuple[float, ...] = (1.0,)    # hinge +1-class weight grid (sub axis)
    cd_polish: int = 0              # Gauss-Seidel polish epochs after the
                                    # batched box-QP (hinge/quantile): the
                                    # warm-started CD pass from
                                    # kernels/cd_solver, wave-fused under the
                                    # cell vmap.  0 = off (bitwise-identical
                                    # to the FISTA-only path)

    @property
    def n_sub(self) -> int:
        if self.solver in ("quantile", "expectile"):
            return len(self.taus)
        return len(self.weights)


class CVSelected(NamedTuple):
    """Streaming-selection output, per (task, sub)."""
    coefs: Array        # (n_folds, n, n_tasks, n_sub) fold models at the argmin
    gamma: Array        # (n_tasks, n_sub)
    lam: Array          # (n_tasks, n_sub)
    tau: Array          # (n_tasks, n_sub)
    weight: Array       # (n_tasks, n_sub)
    val_loss: Array     # (n_tasks, n_sub) best mean validation loss
    val_grid: Array     # (n_gamma, n_tasks, n_lam, n_sub) full CV surface
    fa_grid: Array      # (n_gamma, n_tasks, n_lam, n_sub) validation false-
                        # alarm COUNTS (hinge + keep_surface only, else 0)
    det_grid: Array     # (n_gamma, n_tasks, n_lam, n_sub) detection counts


def make_fold_masks(
    key: Array, mask: Array, n_folds: int, scheme: str = "random", y: Array | None = None
) -> Array:
    """(n_folds, n) boolean: True = sample is in the *validation* part."""
    n = mask.shape[0]
    if scheme == "blocks":
        idx = jnp.cumsum(mask.astype(jnp.int32)) - 1
        n_valid = jnp.maximum(jnp.sum(mask.astype(jnp.int32)), 1)
        fold_of = (idx * n_folds) // n_valid
    else:
        u = jax.random.uniform(key, (n,))
        if scheme == "stratified" and y is not None:
            u = u + 10.0 * (y > 0)
        u = jnp.where(mask > 0, u, jnp.inf)
        order = jnp.argsort(u)
        rank = jnp.argsort(order)
        fold_of = rank % n_folds
    fold_of = jnp.where(mask > 0, fold_of, -1)
    return jax.nn.one_hot(fold_of, n_folds, axis=0, dtype=jnp.bool_)


def grid_columns(grid: GridSpec, cfg: CVConfig, n_tasks: int):
    """Task-major flattened columns.  Returns dict of (P,) arrays + ids."""
    lam = grid.lambdas.astype(jnp.float32)
    n_lam = lam.shape[0]
    if cfg.solver in ("quantile", "expectile"):
        sub = jnp.asarray(cfg.taus, jnp.float32)
    else:
        sub = jnp.asarray(cfg.weights, jnp.float32)
    n_sub = sub.shape[0]
    lam_c = jnp.tile(jnp.repeat(lam, n_sub), n_tasks)              # (P,)
    sub_c = jnp.tile(sub, n_lam * n_tasks)                         # (P,)
    task_c = jnp.repeat(jnp.arange(n_tasks, dtype=jnp.int32), n_lam * n_sub)
    return lam_c, sub_c, task_c, n_lam, n_sub


def _val_losses(f_val: Array, y_cols: Array, val_mask_cols: Array, cfg: CVConfig,
                sub_c: Array) -> Array:
    """Masked mean validation loss per column.  All args (n, P)-shaped."""
    denom = jnp.maximum(jnp.sum(val_mask_cols, axis=0), 1.0)
    if cfg.solver == "hinge":
        if cfg.val_loss in ("auto", "zero_one"):
            losses = ((f_val * y_cols) <= 0.0).astype(jnp.float32)
        else:
            losses = jnp.maximum(0.0, 1.0 - y_cols * f_val)
    elif cfg.solver == "ls":
        losses = (y_cols - f_val) ** 2
    elif cfg.solver == "quantile":
        losses = q_solver.pinball_loss(y_cols, f_val, sub_c[None, :])
    elif cfg.solver == "expectile":
        losses = exp_solver.expectile_loss(y_cols, f_val, sub_c[None, :])
    else:
        raise ValueError(cfg.solver)
    return jnp.sum(losses * val_mask_cols, axis=0) / denom


def _solve_columns(k_full, y_cols, train_cols, lam_c, sub_c, n_eff_cols, cfg, c0, l_est):
    """train_cols (n, P): 1 = sample is in this column's training set.

    Returns ``(c, iters)`` — iters is the box-QP iteration count (0 for the
    direct ls/expectile solves), surfaced so callers can assert that warm
    starts actually shorten the solve.  ``cfg.cd_polish > 0`` appends that
    many Gauss-Seidel epochs (``kernels/cd_solver``) after the box-QP —
    warm-started from the FISTA iterate, monotone, and wave-fused when the
    caller is vmapped over cells.
    """
    if cfg.solver in ("hinge", "quantile"):
        cost = 1.0 / (2.0 * lam_c[None, :] * jnp.maximum(n_eff_cols[None, :], 1.0))
        if cfg.solver == "hinge":
            w = jnp.where(y_cols > 0, sub_c[None, :], 1.0)  # class weight on +1
            edge = y_cols * cost * w * train_cols
            lo, hi = jnp.minimum(0.0, edge), jnp.maximum(0.0, edge)
        else:
            lo = cost * (sub_c[None, :] - 1.0) * train_cols
            hi = cost * sub_c[None, :] * train_cols
        y_eff = y_cols * train_cols
        res = qp.box_qp(k_full, y_eff, lo, hi, c0=c0,
                        tol=cfg.tol, max_iters=cfg.max_iters, l_est=l_est)
        c = res.c
        if cfg.cd_polish > 0:
            c = cd_ops.cd_polish(k_full, y_eff, lo, hi, c, cfg.cd_polish)
        return c, res.iters
    if cfg.solver == "ls":
        # all columns must share the fold train mask (task_mask == 1); the
        # eigh is done once and the lambda path is a diagonal rescale.
        tm = train_cols[:, 0]
        km = k_full * tm[:, None] * tm[None, :]
        s, u = jnp.linalg.eigh(km)
        s = jnp.maximum(s, 0.0)
        uty = u.T @ (y_cols * train_cols[:, :1])        # (n, P)
        denom = s[:, None] + lam_c[None, :] * jnp.maximum(n_eff_cols[None, :], 1.0)
        return u @ (uty / denom), jnp.int32(0)
    if cfg.solver == "expectile":
        tm = train_cols[:, 0]
        n_eff = n_eff_cols[0]
        c = exp_solver.solve_expectile(
            k_full, y_cols[:, 0], sub_c, lam_c, n_eff, train_mask=tm, c0=c0)
        return c, jnp.int32(0)
    raise ValueError(cfg.solver)


@functools.partial(jax.jit, static_argnames=("cfg", "n_lam", "n_sub"))
def cv_cell(
    x: Array,              # (n, d) padded cell
    y_tasks: Array,        # (n_tasks, n) labels/targets (0 where excluded)
    task_mask: Array,      # (n_tasks, n) 1 = sample participates in task
    mask: Array,           # (n,) 1 = real sample
    gammas: Array,         # (n_gamma,)
    lam_c: Array, sub_c: Array, task_c: Array,   # (P,) task-major columns
    fold_key: Array,
    cfg: CVConfig,
    n_lam: int,
    n_sub: int,
) -> CVSelected:
    """Fused train+select CV over one working set, all tasks at once."""
    n = x.shape[0]
    n_tasks = y_tasks.shape[0]
    p = lam_c.shape[0]

    y_strat = y_tasks[0] if cfg.solver == "hinge" else None
    val_folds = make_fold_masks(fold_key, mask, cfg.n_folds, cfg.fold_scheme, y_strat)
    train_folds = (~val_folds) & (mask > 0)[None, :]          # (k, n)

    y_cols = y_tasks[task_c].T                                 # (n, P)
    colmask = task_mask[task_c].T * mask[:, None]              # (n, P)

    spec = kernel_fns.get_spec(cfg.kernel)
    use_d2 = cfg.cache_d2 and spec.factors_through_d2
    want_bf16 = cfg.gram_dtype == "bf16" and cfg.solver in ("hinge", "quantile")
    gram_dtype = "bf16" if want_bf16 else "f32"
    track_rates = cfg.keep_surface and cfg.solver == "hinge"
    # ONE D² for the whole gamma scan: the O(n²d) MXU cross term is hoisted
    # out of the lax.scan; each scan step replays only the O(n²) epilogue.
    # named_scope markers label the D²-vs-epilogue-vs-solve split in a
    # PROFILE_DIR device trace (host timing cannot see inside this jit).
    if use_d2:
        with jax.named_scope("cv.d2"):
            cg = kernel_fns.CachedGram.build(x, name=cfg.kernel)
    else:
        cg = None

    def per_gamma(carry, gamma):
        best_val, best_cfs, best_g, best_l, c0_all = carry
        with jax.named_scope("cv.epilogue"):
            if use_d2:
                k_full = cg.gram(gamma, gram_dtype)            # VPU-only pass
            else:
                k_full = spec.fn(x, x, gamma)                  # ONE Gram/gamma
                if want_bf16:
                    k_full = k_full.astype(jnp.bfloat16)  # 2-byte solver reads

        # ONE Lipschitz estimate per gamma, shared by every fold: for a PSD
        # Gram, lambda_max(M K M) <= lambda_max(K) for any 0/1 mask M, so
        # the shared step 1/L is valid for all masked subproblems.  This
        # removes n_folds (n, n) masked-Gram materializations + per-fold
        # power iterations (§Perf hillclimb: SVM cell trainer).
        needs_l = cfg.solver in ("hinge", "quantile")
        l_shared = (qp.power_iteration_l(k_full)
                    if (needs_l and cfg.shared_lipschitz) else None)

        def per_fold(tr_mask, va_mask, c0_f):
            tr_cols = tr_mask.astype(jnp.float32)[:, None] * colmask   # (n, P)
            va_cols = va_mask.astype(jnp.float32)[:, None] * colmask
            n_eff_cols = jnp.sum(tr_cols, axis=0)                      # (P,)
            if needs_l and not cfg.shared_lipschitz:  # baseline path
                mt = tr_mask.astype(jnp.float32)
                l_est = qp.power_iteration_l(k_full * mt[:, None] * mt[None, :])
            else:
                l_est = l_shared
            coefs, _ = _solve_columns(k_full, y_cols, tr_cols, lam_c, sub_c,
                                      n_eff_cols, cfg, c0_f, l_est)
            f_val = k_full @ coefs
            vl = _val_losses(f_val, y_cols, va_cols, cfg, sub_c)
            if track_rates:
                # validation-fold confusion counts per column: every valid
                # sample sits in exactly ONE validation fold, so summing the
                # per-fold counts gives exact whole-set validation rates —
                # the NP/ROC selection rules read these, never the train set
                pred_pos = (f_val > 0) & (va_cols > 0)
                fa = jnp.sum((pred_pos & (y_cols < 0)).astype(jnp.float32), 0)
                det = jnp.sum((pred_pos & (y_cols > 0)).astype(jnp.float32), 0)
            else:
                fa = det = jnp.zeros_like(vl)
            return vl, fa, det, coefs

        with jax.named_scope("cv.solve"):
            vl, fa, det, coefs = jax.vmap(per_fold)(train_folds, val_folds,
                                                    c0_all)
        vl_mean = jnp.mean(vl, axis=0)                                  # (P,)
        fa_tls = jnp.sum(fa, axis=0).reshape(n_tasks, n_lam, n_sub)
        det_tls = jnp.sum(det, axis=0).reshape(n_tasks, n_lam, n_sub)

        # streaming selection: best lambda for this gamma, per (task, sub)
        vl_tls = vl_mean.reshape(n_tasks, n_lam, n_sub)
        lam_star = jnp.argmin(vl_tls, axis=1)                           # (T, S)
        val_star = jnp.min(vl_tls, axis=1)                              # (T, S)
        t_idx = jnp.arange(n_tasks)[:, None]
        s_idx = jnp.arange(n_sub)[None, :]
        flat_cols = (t_idx * n_lam + lam_star) * n_sub + s_idx          # (T, S)
        cand_cfs = coefs[:, :, flat_cols]                               # (k, n, T, S)
        improved = val_star < best_val                                   # (T, S)
        best_val = jnp.where(improved, val_star, best_val)
        best_cfs = jnp.where(improved[None, None], cand_cfs, best_cfs)
        best_g = jnp.where(improved, gamma, best_g)
        best_l = jnp.where(improved, lam_c[flat_cols.reshape(-1)].reshape(n_tasks, n_sub), best_l)
        carry = (best_val, best_cfs, best_g, best_l, coefs)             # warm start
        return carry, (vl_tls, fa_tls, det_tls)

    init = (
        jnp.full((n_tasks, n_sub), jnp.inf, jnp.float32),
        jnp.zeros((cfg.n_folds, n, n_tasks, n_sub), jnp.float32),
        jnp.zeros((n_tasks, n_sub), jnp.float32),
        jnp.zeros((n_tasks, n_sub), jnp.float32),
        jnp.zeros((cfg.n_folds, n, p), jnp.float32),
    )
    (best_val, best_cfs, best_g, best_l, _), (vl_all, fa_all, det_all) = \
        jax.lax.scan(per_gamma, init, gammas)

    sub_grid = sub_c[:n_sub]
    if cfg.solver in ("quantile", "expectile"):
        tau = jnp.broadcast_to(sub_grid[None, :], (n_tasks, n_sub))
        weight = jnp.ones((n_tasks, n_sub), jnp.float32)
    else:
        tau = jnp.full((n_tasks, n_sub), 0.5, jnp.float32)
        weight = jnp.broadcast_to(sub_grid[None, :], (n_tasks, n_sub))

    return CVSelected(coefs=best_cfs, gamma=best_g, lam=best_l, tau=tau,
                      weight=weight, val_loss=best_val, val_grid=vl_all,
                      fa_grid=fa_all, det_grid=det_all)


def _solve_columns_at_core(x, y_tasks, task_mask, mask, gamma, lam_cols,
                           sub_cols, task_cols, fold_key, c0, cfg):
    """Unjitted body shared by :func:`solve_columns_at` (one cell) and
    :func:`solve_columns_batched` (a vmapped group of cells)."""
    y_strat = y_tasks[0] if cfg.solver == "hinge" else None
    val_folds = make_fold_masks(fold_key, mask, cfg.n_folds, cfg.fold_scheme,
                                y_strat)
    train_folds = (~val_folds) & (mask > 0)[None, :]          # (k, n)
    y_cols = y_tasks[task_cols].T                              # (n, P')
    colmask = task_mask[task_cols].T * mask[:, None]           # (n, P')

    spec = kernel_fns.get_spec(cfg.kernel)
    k_full = spec.fn(x, x, gamma)
    if cfg.gram_dtype == "bf16" and cfg.solver in ("hinge", "quantile"):
        k_full = k_full.astype(jnp.bfloat16)
    needs_l = cfg.solver in ("hinge", "quantile")
    l_shared = (qp.power_iteration_l(k_full)
                if (needs_l and cfg.shared_lipschitz) else None)
    n, p_cols = x.shape[0], lam_cols.shape[0]
    if c0 is None:
        c0 = jnp.zeros((cfg.n_folds, n, p_cols), jnp.float32)
    elif c0.ndim == 2:
        # one shared start (nearest cached grid column, solved at a possibly
        # different (gamma, lambda)) broadcast to every fold — _solve_columns
        # clips it into each column's box (qp.clip_warm_start) first.
        c0 = jnp.broadcast_to(c0.astype(jnp.float32)[None],
                              (cfg.n_folds, n, p_cols))
    else:
        # per-fold starts: each fold resumes from ITS OWN cached solution
        # (the fold coefs this function returns) — the re-materialization
        # path, where the start is already at the optimum.
        c0 = c0.astype(jnp.float32)

    def per_fold(tr_mask, c0_f):
        tr_cols = tr_mask.astype(jnp.float32)[:, None] * colmask
        n_eff_cols = jnp.sum(tr_cols, axis=0)
        if needs_l and not cfg.shared_lipschitz:
            mt = tr_mask.astype(jnp.float32)
            l_est = qp.power_iteration_l(k_full * mt[:, None] * mt[None, :])
        else:
            l_est = l_shared
        return _solve_columns(k_full, y_cols, tr_cols, lam_cols, sub_cols,
                              n_eff_cols, cfg, c0_f, l_est)

    coefs, iters = jax.vmap(per_fold)(train_folds, c0)         # (folds, n, P')
    return jnp.mean(coefs, axis=0), jnp.sum(iters), coefs


@functools.partial(jax.jit, static_argnames=("cfg",))
def solve_columns_at(
    x: Array,              # (n, d) padded cell
    y_tasks: Array,        # (n_tasks, n)
    task_mask: Array,      # (n_tasks, n)
    mask: Array,           # (n,)
    gamma: Array,          # scalar — ONE gamma for every requested column
    lam_cols: Array,       # (P',) per-column lambda VALUES
    sub_cols: Array,       # (P',) per-column tau / class weight
    task_cols: Array,      # (P',) per-column task index
    fold_key: Array,
    cfg: CVConfig,
    c0: Array | None = None,   # (n, P') shared or (folds, n, P') per-fold
) -> tuple[Array, Array, Array]:
    """Targeted re-solve: the given columns at one gamma, all folds, fold-
    averaged — the select() phase's "one targeted wave".

    Changing the selection rule over a retained surface only moves a handful
    of (task, sub) winners to new (gamma, lambda) coordinates; this solves
    exactly those columns (one Gram, one batched box-QP per distinct gamma)
    instead of re-running the full fold x grid sweep.  ``fold_key`` must be
    the cell's training key so the CV folds — and hence the model the
    surface scored — are reproduced exactly.

    ``c0`` warm-starts the solve, box-clipped per column (warm or cold
    ``c0=None`` converges to the same box-QP optimum within ``cfg.tol``):

    * ``(n, P')`` — one start shared by every fold, e.g. the nearest
      cached grid column from ``TrainResult``.  Measured effect on the
      batched FISTA iteration count: roughly neutral — FISTA's count is
      gated by the worst-conditioned column, and a neighbor-grid start is
      far from that column's optimum (the gamma-scan warm starts that DO
      pay are the CD path's; see ``benchmarks/roofline.py``).  Kept
      because clipping makes it free and never worse than cold.
    * ``(folds, n, P')`` — per-fold starts.  When these are the fold
      coefs of a previous solve of the SAME columns (the third return
      value), each fold starts at its own optimum and the re-solve
      collapses to a KKT check — orders of magnitude fewer iterations
      (asserted in ``tests/test_staged_api.py``).  This is the
      re-materialization path: rebuilding a model the surface already
      scored without paying the solve again.

    Returns ``(fold-mean coefs (n, P'), total box-QP iters,
    per-fold coefs (folds, n, P'))``.
    """
    return _solve_columns_at_core(x, y_tasks, task_mask, mask, gamma,
                                  lam_cols, sub_cols, task_cols, fold_key,
                                  c0, cfg)


@functools.partial(jax.jit, static_argnames=("cfg",))
def solve_columns_batched(
    x: Array,              # (C, n, d) stacked cells
    y_tasks: Array,        # (C, n_tasks, n)
    task_mask: Array,      # (C, n_tasks, n)
    mask: Array,           # (C, n)
    gamma: Array,          # (C,) one gamma per cell (same grid index)
    lam_cols: Array,       # (C, P') per-column lambda values
    sub_cols: Array,       # (C, P')
    task_cols: Array,      # (C, P')
    fold_key: Array,       # (C, 2)
    c0: Array,             # (C, n, P') shared or (C, folds, n, P') per-fold
    cfg: CVConfig,
) -> tuple[Array, Array, Array]:
    """Vmapped :func:`solve_columns_at`: ONE launch for every moved cell
    that shares a gamma-grid index, instead of one jit call per (cell,
    gamma).  Returns ``(coefs (C, n, P'), iters (C,),
    fold_coefs (C, folds, n, P'))``.
    """
    core = functools.partial(_solve_columns_at_core, cfg=cfg)
    return jax.vmap(core)(x, y_tasks, task_mask, mask, gamma, lam_cols,
                          sub_cols, task_cols, fold_key, c0)
