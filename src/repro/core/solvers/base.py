"""Batched box-constrained QP engine — the heart of every liquidSVM solver.

Every non-smooth liquidSVM dual (hinge, weighted hinge, pinball) is

    min_c   0.5 c^T K c  -  c^T y      s.t.   lo <= c <= hi      (coordinatewise)

in *coefficient space* ``c`` (f = sum_i c_i k(x_i, .)).  Crucially the
objective does not depend on lambda at all: lambda (and the weight w) only
move the box.  So the whole hyper-parameter grid is solved as **columns of
one matrix iteration**: C is (n, P) for P = |lambda-grid| x |w-grid| and the
per-iteration cost is one GEMM ``K @ C`` — this is how liquidSVM's
"kernel-matrix re-use + warm starts" becomes MXU-native.

The iteration is FISTA (accelerated projected gradient) with gradient-based
adaptive restart; the step is 1/L with L from power iteration (shared across
all columns, K is shared).  liquidSVM's sequential 2D-working-set CD is
latency-bound on a systolic machine; block/batched first-order iterations
reach the same KKT point (asserted in tests) with matmul-shaped work.  A
faithful in-VMEM Gauss-Seidel CD sweep lives in
``repro.kernels.cd_solver`` and can be used as a polishing pass.

Stopping: projected-gradient (KKT) residual, uniform across solvers:
``r = || c - clip(c - g, lo, hi) ||_inf`` with ``g = K c - y``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class BoxQPResult(NamedTuple):
    c: Array          # (n, P) solution
    kkt: Array        # (P,) final KKT residual per column
    iters: Array      # () iterations used
    l_est: Array      # () estimated Lipschitz constant


def _kdot(k_mat: Array, c: Array) -> Array:
    """K @ C in K's storage dtype with f32 accumulation (bf16 Gram path:
    the MXU reads 2-byte tiles, accumulates f32 — §Perf SVM hillclimb)."""
    return jax.lax.dot_general(
        k_mat, c.astype(k_mat.dtype), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def power_iteration_l(k_mat: Array, iters: int = 32, seed: int = 0) -> Array:
    """Largest eigenvalue of PSD K (safety-factored), shared across columns."""
    n = k_mat.shape[-1]
    v = jax.random.normal(jax.random.PRNGKey(seed), (n,), jnp.float32)

    def body(_, v):
        w = _kdot(k_mat, v[:, None])[:, 0]
        return w / jnp.maximum(jnp.linalg.norm(w), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v)
    lam = v @ _kdot(k_mat, v[:, None])[:, 0]
    return jnp.maximum(lam, 1e-12) * 1.05


def kkt_residual(c: Array, g: Array, lo: Array, hi: Array) -> Array:
    """Projected-gradient residual per column, scaled by the box width."""
    r = c - jnp.clip(c - g, lo, hi)
    width = jnp.maximum(jnp.max(hi - lo, axis=0), 1e-30)
    return jnp.max(jnp.abs(r), axis=0) / width


def clip_warm_start(c0: Array, lo: Array, hi: Array) -> Array:
    """Project a warm start into a column's feasible box.

    The grid-neighbor solution being reused generally lives in a DIFFERENT
    box (lambda and the class weight scale it; a moved select-phase winner
    may change both), so the projection is mandatory before any solver
    touches it: both the FISTA iteration below and the Gauss-Seidel polish
    (``repro.kernels.cd_solver``) require ``lo <= c0 <= hi`` — from a
    feasible start their descent is monotone, so a clipped warm start can
    never end worse than the cold ``c0 = 0`` it replaces.
    """
    return jnp.clip(c0, lo, hi)


def box_qp(
    k_mat: Array,
    y: Array,
    lo: Array,
    hi: Array,
    c0: Array | None = None,
    tol: float = 1e-3,
    max_iters: int = 2000,
    l_est: Array | None = None,
    check_every: int = 10,
) -> BoxQPResult:
    """Solve min 0.5 c^T K c - c^T y, lo <= c <= hi for all columns at once.

    Shapes: k_mat (n, n); y (n,) or (n, P); lo/hi broadcastable to (n, P);
    c0 warm start (n, P).  Returns f32 everywhere.  k_mat may be bf16
    (read-optimized Gram); all accumulation stays f32.
    """
    if k_mat.dtype not in (jnp.bfloat16, jnp.float16):
        k_mat = k_mat.astype(jnp.float32)
    if y.ndim == 1:
        y = y[:, None]
    p = max(y.shape[1], lo.shape[1] if lo.ndim == 2 else 1, hi.shape[1] if hi.ndim == 2 else 1)
    n = k_mat.shape[0]
    y = jnp.broadcast_to(y.astype(jnp.float32), (n, p))
    lo = jnp.broadcast_to(lo.astype(jnp.float32), (n, p))
    hi = jnp.broadcast_to(hi.astype(jnp.float32), (n, p))
    c0 = jnp.zeros((n, p), jnp.float32) if c0 is None else jnp.broadcast_to(c0.astype(jnp.float32), (n, p))
    c0 = clip_warm_start(c0, lo, hi)  # warm starts from a larger box are clipped in

    if l_est is None:
        l_est = power_iteration_l(k_mat)
    step = 1.0 / l_est

    def grad(c):
        return _kdot(k_mat, c) - y

    def cond(state):
        c, z, t, it, res = state
        return jnp.logical_and(it < max_iters, jnp.max(res) > tol)

    def body(state):
        c, z, t, it, _ = state
        g = grad(z)
        c_new = jnp.clip(z - step * g, lo, hi)
        # gradient-based adaptive restart (O'Donoghue & Candes)
        restart = jnp.sum(g * (c_new - c)) > 0.0
        t_new = jnp.where(restart, 1.0, 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t)))
        beta = jnp.where(restart, 0.0, (t - 1.0) / t_new)
        z_new = c_new + beta * (c_new - c)
        res = jax.lax.cond(
            (it + 1) % check_every == 0,
            lambda: kkt_residual(c_new, grad(c_new), lo, hi),
            lambda: jnp.full((p,), jnp.inf, jnp.float32),
        )
        return c_new, z_new, t_new, it + 1, res

    init = (c0, c0, jnp.float32(1.0), jnp.int32(0), jnp.full((p,), jnp.inf, jnp.float32))
    c, _, _, it, _ = jax.lax.while_loop(cond, body, init)
    final_res = kkt_residual(c, grad(c), lo, hi)
    return BoxQPResult(c=c, kkt=final_res, iters=it, l_est=l_est)


def dual_objective(k_mat: Array, y: Array, c: Array) -> Array:
    """-(0.5 c^T K c - c^T y) per column — monotone diagnostics / tests."""
    if y.ndim == 1:
        y = y[:, None]
    kc = k_mat @ c
    return jnp.sum(c * y, axis=0) - 0.5 * jnp.sum(c * kc, axis=0)
