"""Pinball-loss solver for quantile regression (liquidSVM §2).

Primal:  min_f lambda ||f||^2 + (1/n) sum L_tau(y_i - f(x_i)),
L_tau(r) = tau r_+ + (1-tau) r_-.   Dual in coefficient space:

    min_c 0.5 c^T K c - c^T y,    c_i in [C (tau - 1), C tau]

— the same box QP as hinge with an asymmetric, label-independent box.
Multiple quantiles tau and the lambda grid are both just columns.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.solvers import base

Array = jax.Array


def quantile_boxes(
    taus: Array,        # (P,) quantile level per column
    lambdas: Array,     # (P,) regularization per column
    n_eff: Array,
    train_mask: Array | None = None,
    n: int | None = None,
) -> tuple[Array, Array]:
    cost = 1.0 / (2.0 * lambdas.astype(jnp.float32) * jnp.maximum(n_eff, 1.0))  # (P,)
    lo_row = cost * (taus.astype(jnp.float32) - 1.0)  # (P,)
    hi_row = cost * taus.astype(jnp.float32)
    if train_mask is not None:
        m = train_mask.astype(jnp.float32)[:, None]
    else:
        assert n is not None
        m = jnp.ones((n, 1), jnp.float32)
    return m * lo_row[None, :], m * hi_row[None, :]


def solve_quantile(
    k_mat: Array,
    y: Array,
    taus: Array,
    lambdas: Array,
    n_eff: Array,
    train_mask: Array | None = None,
    c0: Array | None = None,
    tol: float = 1e-3,
    max_iters: int = 3000,
    l_est: Array | None = None,
) -> base.BoxQPResult:
    lo, hi = quantile_boxes(taus, lambdas, n_eff, train_mask, n=k_mat.shape[0])
    y_col = y.astype(jnp.float32)
    if train_mask is not None:
        y_col = y_col * train_mask.astype(jnp.float32)
    return base.box_qp(k_mat, y_col, lo, hi, c0=c0, tol=tol, max_iters=max_iters, l_est=l_est)


def pinball_loss(y: Array, f: Array, tau: Array) -> Array:
    r = y - f
    return jnp.where(r >= 0, tau * r, (tau - 1.0) * r)
