"""Asymmetric least-squares (expectile) solver — Farooq & Steinwart (2017).

Primal: min_f lambda ||f||^2 + (1/n) sum L_tau(y_i - f(x_i)),
L_tau(r) = tau r_+^2 + (1 - tau) r_-^2.

The loss is smooth and piecewise quadratic; we solve by IRLS ("more care
was necessary" — the weights depend on the residual sign):

    W_i = tau if y_i > f_i else (1 - tau)
    (K + lambda n W^{-1}) c = y        (weighted KRR step)

IRLS is a contraction here (strongly convex objective, monotone weights);
a fixed, small number of sweeps suffices and keeps the loop jit-static.
Columns (tau, lambda) are vmapped — each needs its own Cholesky.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def expectile_loss(y: Array, f: Array, tau: Array) -> Array:
    r = y - f
    return jnp.where(r >= 0, tau * r * r, (1.0 - tau) * r * r)


def _irls_single(k_masked: Array, y: Array, tau: Array, lam_n: Array,
                 mask: Array, sweeps: int, c0: Array) -> Array:
    def body(_, c):
        f = k_masked @ c
        w = jnp.where(y - f > 0, tau, 1.0 - tau)
        w = jnp.where(mask > 0, w, 1.0)  # padded coords: any positive weight
        # (K + lam_n W^{-1}) c = y  — W^{-1} only scales the diagonal
        a = k_masked + jnp.diag(lam_n / w)
        cf = jax.scipy.linalg.cho_factor(a)
        return jax.scipy.linalg.cho_solve(cf, y)

    return jax.lax.fori_loop(0, sweeps, body, c0)


def solve_expectile(
    k_mat: Array,
    y: Array,
    taus: Array,       # (P,)
    lambdas: Array,    # (P,)
    n_eff: Array,
    train_mask: Array | None = None,
    sweeps: int = 12,
    c0: Array | None = None,
) -> Array:
    """Returns c (n, P).

    ``c0`` (n, P) warm-starts the IRLS from a grid-neighbor solution: only
    the FIRST sweep's residual-sign weights depend on it (each sweep's
    linear solve replaces c outright), so a good neighbor start means the
    weights are right from sweep one — the IRLS fixed point itself is
    unchanged, warm or cold.
    """
    k_mat = k_mat.astype(jnp.float32)
    if train_mask is None:
        mask = jnp.ones((k_mat.shape[0],), jnp.float32)
    else:
        mask = train_mask.astype(jnp.float32)
    km = k_mat * mask[:, None] * mask[None, :]
    y = y.astype(jnp.float32) * mask
    lam_n = lambdas.astype(jnp.float32) * jnp.maximum(n_eff, 1.0)  # (P,)
    if c0 is None:
        c0 = jnp.zeros((k_mat.shape[0], taus.shape[0]), jnp.float32)

    def one(tau, ln, c0_col):
        return _irls_single(km, y, tau, ln, mask, sweeps, c0_col)

    return jax.vmap(one, in_axes=(0, 0, 1), out_axes=1)(
        taus.astype(jnp.float32), lam_n, c0.astype(jnp.float32))
