from repro.core.solvers.base import BoxQPResult, box_qp, kkt_residual, power_iteration_l
from repro.core.solvers.hinge import hinge_boxes, solve_hinge
from repro.core.solvers.least_squares import solve_krr_eigh, solve_krr_chol
from repro.core.solvers.quantile import quantile_boxes, solve_quantile
from repro.core.solvers.expectile import solve_expectile

__all__ = [
    "BoxQPResult",
    "box_qp",
    "kkt_residual",
    "power_iteration_l",
    "hinge_boxes",
    "solve_hinge",
    "solve_krr_eigh",
    "solve_krr_chol",
    "quantile_boxes",
    "solve_quantile",
    "solve_expectile",
]
