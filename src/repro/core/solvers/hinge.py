"""(Weighted) hinge-loss SVM solver — offset-free dual (Steinwart et al. 2011).

Primal:  min_f  lambda ||f||_H^2 + (1/n) sum w(y_i) max(0, 1 - y_i f(x_i))
Dual in coefficient space c (f = sum c_i k(x_i, .)):

    min_c 0.5 c^T K c - c^T y,    c_i y_i in [0, C w_i],  C = 1/(2 lambda n)

i.e. a box QP with  lo_i = min(0, y_i C w_i),  hi_i = max(0, y_i C w_i).
Padding / non-fold samples get lo = hi = 0, which removes them exactly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.solvers import base

Array = jax.Array


def hinge_boxes(
    y: Array,            # (n,) labels in {-1, +1} (float)
    lambdas: Array,      # (P,) regularization per column
    n_eff: Array,        # () effective #train samples (mask-aware)
    sample_weight: Array | None = None,  # (n,) or (n, P): w(y_i) per sample
    train_mask: Array | None = None,     # (n,) bool
) -> tuple[Array, Array]:
    """Per-column boxes (lo, hi), each (n, P)."""
    y = y.astype(jnp.float32)
    cost = 1.0 / (2.0 * lambdas.astype(jnp.float32) * jnp.maximum(n_eff, 1.0))  # (P,)
    w = jnp.ones_like(y) if sample_weight is None else sample_weight.astype(jnp.float32)
    if w.ndim == 1:
        w = w[:, None]
    edge = y[:, None] * cost[None, :] * w  # (n, P): signed far corner of the box
    lo = jnp.minimum(0.0, edge)
    hi = jnp.maximum(0.0, edge)
    if train_mask is not None:
        m = train_mask.astype(jnp.float32)[:, None]
        lo, hi = lo * m, hi * m
    return lo, hi


def solve_hinge(
    k_mat: Array,
    y: Array,
    lambdas: Array,
    n_eff: Array,
    sample_weight: Array | None = None,
    train_mask: Array | None = None,
    c0: Array | None = None,
    tol: float = 1e-3,
    max_iters: int = 2000,
    l_est: Array | None = None,
) -> base.BoxQPResult:
    lo, hi = hinge_boxes(y, lambdas, n_eff, sample_weight, train_mask)
    y_col = y.astype(jnp.float32)
    if train_mask is not None:
        y_col = y_col * train_mask.astype(jnp.float32)
    return base.box_qp(k_mat, y_col, lo, hi, c0=c0, tol=tol, max_iters=max_iters, l_est=l_est)


def primal_dual_gap(k_mat: Array, y: Array, c: Array, lambdas: Array, n_eff: Array,
                    train_mask: Array | None = None) -> Array:
    """Relative duality gap per column (tests / diagnostics).

    Uses C-SVM scaling: P(c) = 0.5 c^T K c + C sum_i hinge(y_i f_i),
    D(c) = c^T y - 0.5 c^T K c; both with C = 1/(2 lambda n).
    """
    if c.ndim == 1:
        c = c[:, None]
    m = jnp.ones_like(y, jnp.float32) if train_mask is None else train_mask.astype(jnp.float32)
    cost = 1.0 / (2.0 * lambdas.astype(jnp.float32) * jnp.maximum(n_eff, 1.0))
    f = k_mat @ c                                     # (n, P)
    quad = 0.5 * jnp.sum(c * f, axis=0)               # (P,)
    hinge = jnp.sum(m[:, None] * jnp.maximum(0.0, 1.0 - y[:, None] * f), axis=0)
    primal = quad + cost * hinge
    dual = jnp.sum(c * (y * m)[:, None], axis=0) - quad
    return (primal - dual) / jnp.maximum(jnp.abs(primal) + jnp.abs(dual), 1e-12)
