"""Least-squares solver (kernel ridge regression) — liquidSVM's LS path.

Primal: min_f lambda ||f||^2 + (1/n) sum (y_i - f(x_i))^2.  Stationarity
gives (K + lambda n I) c = y on the training coordinates.

Beyond-paper optimization (recorded in EXPERIMENTS.md): instead of one
Cholesky per lambda we eigendecompose the (masked) Gram matrix ONCE per
(fold, gamma) and sweep the whole lambda path as a diagonal rescale:

    K = U diag(s) U^T   =>   c(lambda) = U diag(1/(s + lambda n)) U^T y

O(n^3) once + O(n^2) per lambda — the logical endpoint of the paper's
"kernel matrices may be re-used" for the smooth-loss solver.

Masking: with M = diag(train_mask), eigh(M K M) solves the fold subproblem
exactly — padded coordinates see (0 + lambda n) c = 0 => c = 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def _masked(k_mat: Array, train_mask: Array | None) -> Array:
    if train_mask is None:
        return k_mat
    m = train_mask.astype(k_mat.dtype)
    return k_mat * m[:, None] * m[None, :]


def solve_krr_eigh(
    k_mat: Array,
    y: Array,
    lambdas: Array,       # (P,)
    n_eff: Array,
    train_mask: Array | None = None,
) -> Array:
    """All-lambda KRR path via one eigh.  Returns c (n, P)."""
    km = _masked(k_mat.astype(jnp.float32), train_mask)
    y = y.astype(jnp.float32)
    if train_mask is not None:
        y = y * train_mask.astype(jnp.float32)
    s, u = jnp.linalg.eigh(km)
    s = jnp.maximum(s, 0.0)  # PSD clip against f32 round-off
    uty = u.T @ y  # (n,)
    denom = s[:, None] + lambdas[None, :].astype(jnp.float32) * jnp.maximum(n_eff, 1.0)  # (n, P)
    return u @ (uty[:, None] / denom)


def solve_krr_chol(
    k_mat: Array,
    y: Array,
    lam: Array,
    n_eff: Array,
    train_mask: Array | None = None,
) -> Array:
    """Single-lambda Cholesky path (used by IRLS and small problems)."""
    km = _masked(k_mat.astype(jnp.float32), train_mask)
    y = y.astype(jnp.float32)
    if train_mask is not None:
        y = y * train_mask.astype(jnp.float32)
    n = km.shape[0]
    a = km + (lam * jnp.maximum(n_eff, 1.0)) * jnp.eye(n, dtype=jnp.float32)
    cf = jax.scipy.linalg.cho_factor(a)
    return jax.scipy.linalg.cho_solve(cf, y)
