"""Selection-phase helpers (liquidSVM §2).

The heavy lifting (streaming argmin over the grid) is fused into
``repro.core.cv.cv_cell``; here live the model-combination policies and
NP-mode (Neyman-Pearson) selection, which picks per-task weights under a
false-alarm constraint instead of plain argmin.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array


def combine_fold_models(fold_coefs: Array, how: str = "average") -> Array:
    """(n_folds, n, ...) -> (n, ...): the paper's 'how the k models are
    combined during the test phase'.  Coefficients are linear in the
    decision function, so averaging coefs == averaging functions."""
    if how == "average":
        return jnp.mean(fold_coefs, axis=0)
    raise ValueError(how)


def np_select_weight(false_alarm: Array, detection: Array, alpha: float) -> Array:
    """Neyman-Pearson selection over the weight axis.

    false_alarm/detection: (n_weights,) validation rates per weight-column.
    Picks the weight with the best detection among those with
    false_alarm <= alpha; falls back to the smallest false alarm.
    """
    ok = false_alarm <= alpha
    det_masked = jnp.where(ok, detection, -jnp.inf)
    best_ok = jnp.argmax(det_masked)
    fallback = jnp.argmin(false_alarm)
    return jnp.where(jnp.any(ok), best_ok, fallback)
