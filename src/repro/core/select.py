"""Selection phase (liquidSVM §2): pluggable rules over the retained surface.

liquidSVM separates ``svm-train`` (solve the full fold x grid) from
``svm-select`` (pick hyper-parameters) so that selection can be re-run with
a different criterion — plain CV-loss argmin, a Neyman-Pearson false-alarm
constraint, an ROC weight front — WITHOUT retraining.  The staged API
(``repro.api.session``) reproduces that split: training retains, per cell,

  loss (G, T, L, S)  mean validation loss at every grid point
  fa   (G, T, L, S)  validation false-alarm COUNTS   (hinge only)
  det  (G, T, L, S)  validation detection COUNTS     (hinge only)

(G = per-cell gamma grid, T = tasks, L = lambdas, S = sub axis: class
weights or quantile/expectile taus).  A :class:`SelectionRule` maps that
surface to per-(task, sub) winning grid coordinates; the session layer then
re-solves ONLY the winners that moved off the train-time argmin (whose
models are already cached) — one targeted wave, not a refit.

Counts, not rates, are retained so multi-cell aggregation is exact: every
valid sample lands in exactly one validation fold of its one owning cell,
so summing counts over cells reproduces whole-training-set validation
rates (the fix for the old train-set-rate NPL selection, which was
optimistic versus paper §2).

Registered rules (``get_rule`` / ``available_rules``):

  argmin                — CV-loss argmin per (task, sub); matches the fused
                          fit bitwise (zero columns re-solved)
  quantile / expectile  — aliases of argmin (selection is already per tau)
  npl                   — per (task, weight): best detection among grid
                          points whose validation false-alarm rate is
                          <= alpha (fallback: smallest false alarm), plus
                          the NP weight pick over the sub axis
  roc                   — argmin winners per weight + the aggregated
                          (false alarm, detection) front over the weight
                          grid, sorted along the false-alarm axis
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


def combine_fold_models(fold_coefs: Array, how: str = "average") -> Array:
    """(n_folds, n, ...) -> (n, ...): the paper's 'how the k models are
    combined during the test phase'.  Coefficients are linear in the
    decision function, so averaging coefs == averaging functions."""
    if how == "average":
        return jnp.mean(fold_coefs, axis=0)
    raise ValueError(how)


def np_select_weight(false_alarm: Array, detection: Array, alpha: float) -> Array:
    """Neyman-Pearson selection over the weight axis.

    false_alarm/detection: (n_weights,) validation rates per weight-column.
    Picks the weight with the best detection among those with
    false_alarm <= alpha; falls back to the smallest false alarm.
    """
    ok = false_alarm <= alpha
    det_masked = jnp.where(ok, detection, -jnp.inf)
    best_ok = jnp.argmax(det_masked)
    fallback = jnp.argmin(false_alarm)
    return jnp.where(jnp.any(ok), best_ok, fallback)


# --------------------------------------------------------------- surface
@dataclasses.dataclass(frozen=True)
class Surface:
    """The per-cell validation surface a trained session retains.

    Leading axis C is the packed SLOT axis (padding slots are all-zero and
    select harmlessly); ``neg``/``pos`` are per-(slot, task) class totals
    over valid samples, the denominators for the count grids.
    """
    loss: np.ndarray      # (C, G, T, L, S) mean validation loss
    fa: np.ndarray        # (C, G, T, L, S) validation false-alarm counts
    det: np.ndarray       # (C, G, T, L, S) validation detection counts
    neg: np.ndarray       # (C, T) negative-class valid-sample totals
    pos: np.ndarray       # (C, T) positive-class valid-sample totals
    gammas: np.ndarray    # (C, G) per-cell gamma grids (values)
    lambdas: np.ndarray   # (L,) shared lambda grid (values)

    @property
    def grid_columns(self) -> int:
        """Total solvable columns in the full sweep: C*G*T*L*S."""
        return int(np.prod(self.loss.shape))


@dataclasses.dataclass(frozen=True)
class SelectContext:
    """Scenario knobs a rule may consult (the select-stage config keys)."""
    scenario: str = "binary"
    weights: np.ndarray = dataclasses.field(
        default_factory=lambda: np.ones(1, np.float32))
    taus: np.ndarray = dataclasses.field(
        default_factory=lambda: np.full(1, 0.5, np.float32))
    alpha: float = 0.05       # NPL false-alarm budget
    npl_class: int = -1       # class the false-alarm constraint binds on


@dataclasses.dataclass
class RuleResult:
    """Winning grid coordinates per (slot, task, sub) + rule extras."""
    g_idx: np.ndarray     # (C, T, S) gamma index into the per-cell grid
    l_idx: np.ndarray     # (C, T, S) lambda index
    extras: Dict[str, np.ndarray] = dataclasses.field(default_factory=dict)


SelectionRule = Callable[[Surface, SelectContext], RuleResult]

_RULES: Dict[str, SelectionRule] = {}


def register_rule(name: str):
    """Decorator: register a selection rule under a string key."""
    def deco(fn: SelectionRule) -> SelectionRule:
        _RULES[name] = fn
        return fn
    return deco


def get_rule(name: str) -> SelectionRule:
    if name not in _RULES:
        raise KeyError(f"unknown selection rule {name!r}; "
                       f"known: {available_rules()}")
    return _RULES[name]


def available_rules() -> Tuple[str, ...]:
    return tuple(sorted(_RULES))


# ---------------------------------------------------------------- helpers
def _flat_gl(grid: np.ndarray) -> np.ndarray:
    """(C, G, T, L, S) -> (C, T, S, G*L), gamma-major like the train scan."""
    c, g, t, l, s = grid.shape
    return grid.transpose(0, 2, 4, 1, 3).reshape(c, t, s, g * l)


def _unflat_gl(idx: np.ndarray, n_lam: int):
    return idx // n_lam, idx % n_lam


def argmin_winners(loss: np.ndarray):
    """First-occurrence flat argmin over (gamma, lambda), per (slot, t, s).

    Matches the train-time streaming selection exactly: the scan keeps the
    FIRST strict improvement with gamma as the outer loop and lambda inner,
    which is precisely C-order first-occurrence argmin over (G, L).
    """
    n_lam = loss.shape[3]
    idx = _flat_gl(loss).argmin(axis=-1)            # (C, T, S)
    return _unflat_gl(idx, n_lam)


def _constrained_rates(surface: Surface, ctx: SelectContext):
    """Count grids + totals oriented so 'fa' is the constrained class's
    error and 'det' the other class's hit rate (npl_class=-1: the stored
    orientation; npl_class=+1: alarms are +1 samples predicted -1)."""
    neg = surface.neg[:, None, :, None, None]       # (C, 1, T, 1, 1)
    pos = surface.pos[:, None, :, None, None]
    if ctx.npl_class == -1:
        return surface.fa, surface.det, neg, pos
    if ctx.npl_class == 1:
        return pos - surface.det, neg - surface.fa, pos, neg
    raise ValueError(f"npl_class must be +-1, got {ctx.npl_class}")


def _global_rates_at(cnt: np.ndarray, tot: np.ndarray,
                     g_idx: np.ndarray, l_idx: np.ndarray):
    """Aggregate count grids at the winners into whole-set rates (T, S)."""
    c_ax = np.arange(cnt.shape[0])[:, None, None]
    t_ax = np.arange(cnt.shape[2])[None, :, None]
    s_ax = np.arange(cnt.shape[4])[None, None, :]
    picked = cnt[c_ax, g_idx, t_ax, l_idx, s_ax]    # (C, T, S)
    denom = np.maximum(tot[:, 0, :, 0, 0].sum(0), 1.0)       # (T,)
    return picked.sum(0) / denom[:, None]           # (T, S)


# ------------------------------------------------------------------ rules
@register_rule("argmin")
def rule_argmin(surface: Surface, ctx: SelectContext) -> RuleResult:
    g_idx, l_idx = argmin_winners(surface.loss)
    return RuleResult(g_idx=g_idx, l_idx=l_idx)


# per-tau selection is already the argmin semantics; registered under the
# scenario names so front-ends can say select("quantile") explicitly
_RULES["quantile"] = rule_argmin
_RULES["expectile"] = rule_argmin


@register_rule("npl")
def rule_npl(surface: Surface, ctx: SelectContext) -> RuleResult:
    """Neyman-Pearson: constrained (gamma, lambda) pick per (task, weight).

    Per cell and (task, weight) column: among grid points whose validation
    false-alarm rate (on the constrained class) meets ``ctx.alpha``, take
    the best detection; if no point qualifies, fall back to the smallest
    false alarm.  Extras carry the EXACT whole-set validation rates at the
    winners (count aggregation over cells) and the NP weight pick per task.
    """
    fa_cnt, det_cnt, fa_tot, det_tot = _constrained_rates(surface, ctx)
    fa_rate = fa_cnt / np.maximum(fa_tot, 1.0)
    det_rate = det_cnt / np.maximum(det_tot, 1.0)

    n_lam = surface.loss.shape[3]
    fa_f = _flat_gl(fa_rate)
    det_f = _flat_gl(det_rate)
    ok = fa_f <= ctx.alpha
    score = np.where(ok, det_f, -np.inf)
    best_ok = score.argmax(axis=-1)                  # first max in scan order
    fallback = fa_f.argmin(axis=-1)
    idx = np.where(ok.any(axis=-1), best_ok, fallback)
    g_idx, l_idx = _unflat_gl(idx, n_lam)

    np_fa = _global_rates_at(fa_cnt, fa_tot, g_idx, l_idx)      # (T, S)
    np_det = _global_rates_at(det_cnt, det_tot, g_idx, l_idx)
    w_idx = np.asarray([int(np_select_weight(jnp.asarray(np_fa[t]),
                                             jnp.asarray(np_det[t]),
                                             ctx.alpha))
                        for t in range(np_fa.shape[0])], np.int32)
    return RuleResult(g_idx=g_idx, l_idx=l_idx,
                      extras={"np_fa": np_fa, "np_det": np_det,
                              "np_weight_idx": w_idx,
                              "alpha": np.float32(ctx.alpha),
                              "npl_class": np.int32(ctx.npl_class)})


@register_rule("roc")
def rule_roc(surface: Surface, ctx: SelectContext) -> RuleResult:
    """ROC mode: one working point per class weight, whole front emitted.

    Winners are the per-(task, weight) CV-loss argmins — identical to the
    models the train phase cached, so this rule re-solves NOTHING — and the
    extras carry the full (false alarm, detection) front over the weight
    grid, aggregated from the retained validation counts and sorted along
    the false-alarm axis (``roc_front[t, i] = (fa, det)`` of the i-th
    working point).
    """
    g_idx, l_idx = argmin_winners(surface.loss)
    fa_cnt, det_cnt, fa_tot, det_tot = _constrained_rates(surface, ctx)
    roc_fa = _global_rates_at(fa_cnt, fa_tot, g_idx, l_idx)     # (T, S)
    roc_det = _global_rates_at(det_cnt, det_tot, g_idx, l_idx)
    order = np.argsort(roc_fa, axis=1, kind="stable")           # (T, S)
    front = np.stack([np.take_along_axis(roc_fa, order, 1),
                      np.take_along_axis(roc_det, order, 1)], axis=-1)
    return RuleResult(g_idx=g_idx, l_idx=l_idx,
                      extras={"roc_fa": roc_fa, "roc_det": roc_det,
                              "roc_order": order.astype(np.int32),
                              "roc_front": front})
