from repro.data.synthetic import banana_mc, covtype_like, gaussian_blobs, regression_1d
from repro.data.scaling import Scaler

__all__ = ["banana_mc", "covtype_like", "gaussian_blobs", "regression_1d", "Scaler"]
