"""Train-statistics scaling (the paper: "Based on the training a scaling was
determined and both training and test set were normalized by that")."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Scaler:
    mean: np.ndarray
    std: np.ndarray

    @staticmethod
    def fit(x: np.ndarray) -> "Scaler":
        return Scaler(mean=x.mean(0), std=np.maximum(x.std(0), 1e-8))

    @staticmethod
    def fit_stream(source, chunk_size: int = 65536) -> "Scaler":
        """Fit from a :class:`repro.pipeline.dataset.ChunkSource` in one
        pass (f64 accumulators) — x is never resident."""
        from repro.pipeline.dataset import as_source, streaming_mean_std
        mean, std = streaming_mean_std(as_source(source), chunk_size)
        return Scaler(mean=mean, std=np.maximum(std, 1e-8))

    def transform(self, x: np.ndarray) -> np.ndarray:
        return ((x - self.mean) / self.std).astype(np.float32)