"""Train-statistics scaling (the paper: "Based on the training a scaling was
determined and both training and test set were normalized by that")."""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class Scaler:
    mean: np.ndarray
    std: np.ndarray

    @staticmethod
    def fit(x: np.ndarray) -> "Scaler":
        return Scaler(mean=x.mean(0), std=np.maximum(x.std(0), 1e-8))

    def transform(self, x: np.ndarray) -> np.ndarray:
        return ((x - self.mean) / self.std).astype(np.float32)
