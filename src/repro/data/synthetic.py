"""Synthetic datasets in the spirit of the paper's benchmark suite.

The paper's data (BANK-MARKETING, COD-RNA, COVTYPE, ...) is not shippable;
these generators produce problems with the same qualitative structure:

  banana_mc     — the package's demo set: crescent-shaped classes (2D,
                  multi-class), non-linearly separable
  covtype_like  — overlapping anisotropic Gaussian mixture in d dims with
                  label noise (hard, like COVTYPE at ~20% Bayes error)
  gaussian_blobs— easy separable control
  regression_1d — heteroscedastic sine for quantile/expectile demos
"""
from __future__ import annotations

import numpy as np


def _banana(rng: np.random.Generator, n: int, flip: float, shift: np.ndarray,
            rot: float) -> np.ndarray:
    t = rng.uniform(0.2 * np.pi, 1.2 * np.pi, n)
    r = 2.0 + rng.normal(0, 0.35, n)
    pts = np.stack([r * np.cos(t), r * np.sin(t)], 1)
    c, s = np.cos(rot), np.sin(rot)
    pts = pts @ np.array([[c, -s], [s, c]]).T
    return pts * np.array([1.0, flip]) + shift


def banana_mc(n: int = 4000, n_classes: int = 4, seed: int = 0):
    """Multi-class banana set (the package's 'banana-mc' demo)."""
    rng = np.random.default_rng(seed)
    per = n // n_classes
    xs, ys = [], []
    for c in range(n_classes):
        shift = np.array([2.2 * (c % 2) - 0.8, 2.6 * (c // 2) - 0.8])
        xs.append(_banana(rng, per, 1.0 if c % 2 == 0 else -1.0, shift, 0.25 * c))
        ys.append(np.full(per, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    p = rng.permutation(len(x))
    return x[p], y[p]


def covtype_like(n: int = 10000, d: int = 10, n_classes: int = 2, seed: int = 0,
                 label_noise: float = 0.08, n_modes: int = 6):
    """Hard overlapping mixture: each class is a mixture of anisotropic
    Gaussians; modes of different classes interleave."""
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    per = n // (n_classes * n_modes)
    for c in range(n_classes):
        for m in range(n_modes):
            mean = rng.normal(0, 1.6, d)
            a = rng.normal(0, 1, (d, d)) / np.sqrt(d)
            cov_half = 0.55 * a + 0.45 * np.eye(d)
            pts = rng.normal(size=(per, d)) @ cov_half.T + mean
            xs.append(pts)
            ys.append(np.full(per, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    flip = rng.uniform(size=len(y)) < label_noise
    y = np.where(flip, rng.integers(0, n_classes, len(y)), y).astype(np.int32)
    p = rng.permutation(len(x))
    return x[p], y[p]


def gaussian_blobs(n: int = 2000, d: int = 5, n_classes: int = 2, seed: int = 0,
                   sep: float = 3.0):
    rng = np.random.default_rng(seed)
    per = n // n_classes
    xs, ys = [], []
    for c in range(n_classes):
        mean = rng.normal(0, 1, d)
        mean = sep * mean / np.linalg.norm(mean)
        xs.append(rng.normal(size=(per, d)) + mean)
        ys.append(np.full(per, c))
    x = np.concatenate(xs).astype(np.float32)
    y = np.concatenate(ys).astype(np.int32)
    p = rng.permutation(len(x))
    return x[p], y[p]


def regression_1d(n: int = 1000, seed: int = 0, hetero: bool = True):
    """y = sin(3x)/ (heteroscedastic noise) — quantile/expectile demo."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(-1, 1, (n, 1)).astype(np.float32)
    noise_scale = 0.08 + (0.25 * (x[:, 0] + 1.0) if hetero else 0.0)
    y = np.sin(3.0 * x[:, 0]) + noise_scale * rng.normal(size=n)
    return x, y.astype(np.float32)


def train_test_split(x: np.ndarray, y: np.ndarray, test_frac: float = 0.25, seed: int = 0):
    rng = np.random.default_rng(seed)
    p = rng.permutation(len(x))
    n_test = int(len(x) * test_frac)
    te, tr = p[:n_test], p[n_test:]
    return x[tr], y[tr], x[te], y[te]
