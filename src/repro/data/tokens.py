"""Synthetic LM token pipeline with deterministic, step-indexed batches.

Fault-tolerance contract: batch(step) is a pure function of (seed, step) —
after a crash/restore the pipeline replays the exact token order with no
persistent iterator state (the checkpoint only stores the step counter).

The generator is a hidden-Markov "language": a random transition matrix
over a small state space emits token ids with state-dependent unigram
mixtures.  A ~100M model reaches < ln(vocab) loss quickly, which gives the
end-to-end example something real to learn.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_states: int = 12
    input_kind: str = "tokens"   # tokens | embed
    d_frontend: int = 0


class TokenPipeline:
    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # sticky HMM over n_states; each state emits from its own zipf-ish
        # slice of the vocabulary
        n = cfg.n_states
        trans = rng.dirichlet(0.3 * np.ones(n), size=n) + 4.0 * np.eye(n)
        self._trans = jnp.asarray(trans / trans.sum(1, keepdims=True),
                                  jnp.float32)
        emits = rng.dirichlet(0.05 * np.ones(cfg.vocab), size=n)
        self._emits = jnp.asarray(np.log(emits + 1e-9), jnp.float32)
        self._proj = None
        if cfg.input_kind == "embed":
            self._proj = jnp.asarray(
                rng.normal(0, 1, (cfg.vocab, cfg.d_frontend)) / np.sqrt(cfg.d_frontend),
                jnp.float32)

    def batch(self, step: int) -> Dict[str, Array]:
        """Deterministic batch for ``step`` (replayable after restart)."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
        k_state, k_emit = jax.random.split(key)
        b, t = cfg.global_batch, cfg.seq_len

        def walk(carry, ks):
            state = carry
            nxt = jax.random.categorical(ks, jnp.log(self._trans[state] + 1e-9))
            return nxt, nxt

        s0 = jax.random.randint(k_state, (b,), 0, cfg.n_states)
        _, states = jax.lax.scan(walk, s0, jax.random.split(k_state, t))
        states = states.T                                   # (b, t)
        tokens = jax.random.categorical(k_emit, self._emits[states],
                                        axis=-1).astype(jnp.int32)  # (b, t)

        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.ones((b, t), jnp.float32).at[:, -1].set(0.0)
        if cfg.input_kind == "embed":
            inputs = jnp.take(self._proj, tokens, axis=0)
        else:
            inputs = tokens
        return {"inputs": inputs, "labels": labels, "mask": mask}
