"""Mamba(1) selective-state-space mixer, chunked for TPU memory.

The selective scan h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t is a diagonal
per-(channel, state) linear recurrence.  A full-sequence associative scan
would materialize (B, T, D_inner, N) state history — 30+ GB at train_4k —
so we scan over chunks of ``chunk`` steps: the carry is one (B, D_inner, N)
state, and only the within-chunk history (B, chunk, D_inner, N) is ever
live.  Decode is the exact same recurrence with T == 1: an O(1)-state step
(the SSM's whole point for `long_500k`).

Sharding: d_inner is the TP axis ('model'); the state dim N is tiny (16)
and replicated.  The depthwise conv is causal with a (d_conv - 1) carry so
chunking does not change results.
"""
from __future__ import annotations

import math
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import ParamSpec, Template

Array = jax.Array


class SSMState(NamedTuple):
    conv: Array   # (B, d_conv - 1, d_inner) rolling conv inputs
    ssm: Array    # (B, d_inner, N) f32 recurrent state


def mamba_template(d: int, d_inner: int, d_state: int, d_conv: int,
                   dt_rank: int, dtype, fsdp: bool) -> Template:
    dax = "data" if fsdp else None
    return {
        "in_proj": ParamSpec((d, 2 * d_inner), dtype, P(dax, "model"), "fan_in"),
        "conv_w": ParamSpec((d_conv, d_inner), jnp.float32, P(None, "model"), "normal", 0.2),
        "conv_b": ParamSpec((d_inner,), jnp.float32, P("model"), "zeros"),
        "x_proj": ParamSpec((d_inner, dt_rank + 2 * d_state), dtype,
                            P("model", None), "fan_in"),
        "dt_proj_w": ParamSpec((dt_rank, d_inner), jnp.float32, P(None, "model"),
                               "fan_in"),
        "dt_proj_b": ParamSpec((d_inner,), jnp.float32, P("model"), "ones", 0.01),
        "a_log": ParamSpec((d_inner, d_state), jnp.float32, P("model", None),
                           "normal", 0.5),
        "d_skip": ParamSpec((d_inner,), jnp.float32, P("model"), "ones"),
        "out_proj": ParamSpec((d_inner, d), dtype, P("model", dax), "fan_in"),
    }


def _causal_conv(x: Array, w: Array, b: Array, carry: Array) -> Tuple[Array, Array]:
    """Depthwise causal conv1d.  x (B, T, D); w (K, D); carry (B, K-1, D)."""
    k = w.shape[0]
    xin = jnp.concatenate([carry.astype(x.dtype), x], axis=1)   # (B, K-1+T, D)
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xin[:, i: i + x.shape[1]].astype(jnp.float32) * w[i]
    new_carry = xin[:, -(k - 1):] if k > 1 else xin[:, :0]
    return (out + b).astype(x.dtype), new_carry.astype(jnp.float32)


def _ssm_chunk(xz: Array, dt: Array, b_t: Array, c_t: Array, a: Array,
               h0: Array) -> Tuple[Array, Array]:
    """One chunk of the selective scan via associative_scan.

    xz (B, Q, D) conv'd input; dt (B, Q, D); b_t/c_t (B, Q, N); a (D, N);
    h0 (B, D, N).  Returns (y (B, Q, D), h_end).
    """
    da = jnp.exp(dt[..., None] * a)                       # (B, Q, D, N) decay
    dbx = (dt * xz)[..., None] * b_t[:, :, None, :]       # (B, Q, D, N) input

    # prepend h0 as step 0 with decay 1, then scan the composition
    # (a2, b2) o (a1, b1) = (a1 a2, a2 b1 + b2)
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, ar * bl + br

    ones = jnp.ones_like(h0)[:, None]                     # (B, 1, D, N)
    a_all = jnp.concatenate([ones, da], axis=1)
    b_all = jnp.concatenate([h0[:, None], dbx], axis=1)
    _, h_hist = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    h_hist = h_hist[:, 1:]                                # (B, Q, D, N)
    y = jnp.einsum("bqdn,bqn->bqd", h_hist, c_t,
                   preferred_element_type=jnp.float32)
    return y, h_hist[:, -1]


def mamba_mixer(
    p: Dict[str, Array],
    x: Array,                       # (B, T, d)
    *,
    d_inner: int,
    d_state: int,
    d_conv: int,
    dt_rank: int,
    dtype=jnp.bfloat16,
    chunk: int = 256,
    state: Optional[SSMState] = None,
) -> Tuple[Array, SSMState]:
    """Returns (out (B, T, d), end state).  Pass ``state`` for decode."""
    b, t, _ = x.shape
    xz = layers.linear(x, p["in_proj"], dtype)            # (B, T, 2*D)
    xs, z = jnp.split(xz, 2, axis=-1)

    if state is None:
        conv_carry = jnp.zeros((b, d_conv - 1, d_inner), jnp.float32)
        h0 = jnp.zeros((b, d_inner, d_state), jnp.float32)
    else:
        conv_carry, h0 = state.conv, state.ssm

    xs, conv_carry = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_carry)
    xs = jax.nn.silu(xs.astype(jnp.float32)).astype(dtype)

    proj = layers.linear(xs, p["x_proj"], dtype).astype(jnp.float32)
    dt_in, b_t, c_t = jnp.split(proj, [dt_rank, dt_rank + d_state], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_proj_w"] + p["dt_proj_b"])   # (B, T, D)
    a = -jnp.exp(p["a_log"])                                         # (D, N)
    xf = xs.astype(jnp.float32)

    if t == 1:
        # decode: closed-form single step
        da = jnp.exp(dt[:, 0, :, None] * a)                          # (B, D, N)
        h = da * h0 + (dt[:, 0] * xf[:, 0])[..., None] * b_t[:, 0, None, :]
        y = jnp.einsum("bdn,bn->bd", h, c_t[:, 0],
                       preferred_element_type=jnp.float32)[:, None]
        h_end = h
    else:
        q = min(chunk, t)
        n_chunks = -(-t // q)
        pad = n_chunks * q - t
        if pad:
            xf = jnp.pad(xf, ((0, 0), (0, pad), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
            b_t = jnp.pad(b_t, ((0, 0), (0, pad), (0, 0)))
            c_t = jnp.pad(c_t, ((0, 0), (0, pad), (0, 0)))

        # chunk-level remat: without it, backward keeps every chunk's
        # (B, Q, D, N) state history alive at once (~17 GB/layer at
        # train_4k) — recomputing h_hist per chunk caps the live set at
        # one chunk.
        @jax.checkpoint
        def body(h, xs_):
            xq, dtq, bq, cq = xs_
            y, h_end = _ssm_chunk(xq, dtq, bq, cq, a, h)
            return h_end, y

        xs_c = (xf.reshape(b, n_chunks, q, d_inner).transpose(1, 0, 2, 3),
                dt.reshape(b, n_chunks, q, d_inner).transpose(1, 0, 2, 3),
                b_t.reshape(b, n_chunks, q, d_state).transpose(1, 0, 2, 3),
                c_t.reshape(b, n_chunks, q, d_state).transpose(1, 0, 2, 3))
        h_end, ys = jax.lax.scan(body, h0, xs_c)
        y = ys.transpose(1, 0, 2, 3).reshape(b, n_chunks * q, d_inner)[:, :t]

    y = y + xf[:, :t if t > 1 else 1] * p["d_skip"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = layers.linear(y.astype(dtype), p["out_proj"], dtype)
    return out, SSMState(conv=conv_carry, ssm=h_end)
