"""LM assembly: one composable stack covering all 10 assigned architectures.

An architecture is a ``ModelConfig`` whose ``period_pattern`` lists the
(mixer, mlp) kind of each layer inside one repeating period:

    mixer: attn | attn_local | attn_bidir | mamba | rwkv
    mlp:   dense | moe | rwkv_cm

``n_layers = n_periods * len(period) + tail`` — full periods run under one
``lax.scan`` (params stacked over the period axis, jax.checkpoint'd body),
the tail (< one period) is unrolled with its own params.  This keeps HLO
size O(period), not O(n_layers), for 94-layer stacks.

Losses never materialize (tokens, vocab): cross-entropy is lax.scan'd over
token chunks (mandatory at vocab 262k).

The same forward drives three entry points:
    loss_fn     (B, T) tokens -> scalar loss           [train_4k]
    prefill     (B, T) tokens -> last logits + cache    [prefill_32k]
    decode_step (B, 1) token + cache -> logits + cache  [decode_32k/long_500k]
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import attention, layers, moe as moe_mod, rwkv6, ssm
from repro.models.layers import ParamSpec, Template

Array = jax.Array


# --------------------------------------------------------------------------
# config
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    period_pattern: Tuple[Tuple[str, str], ...] = (("attn", "dense"),)
    # attention
    window: int = 0
    rope_theta: float = 10000.0
    rotary_frac: float = 1.0
    qk_norm: bool = False
    attn_impl: str = "blocked"
    attn_chunk: int = 1024
    kv_cache_dtype: str = "bf16"    # bf16 (baseline) | int8 (§Perf decode)
    # moe
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    moe_chunk: int = 1024
    moe_capacity_factor: float = 1.25
    moe_impl: str = "einsum"        # einsum (baseline) | gather (§Perf)
    moe_pregather: bool = False     # hoist FSDP weight all-gather out of
                                    # the chunk scan (§Perf)
    aux_loss_weight: float = 0.01
    # ssm
    ssm_d_state: int = 16
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # rwkv
    rwkv_head_dim: int = 64
    rwkv_chunk: int = 128
    # frontend
    input_kind: str = "tokens"      # tokens | embed (audio/vision stub)
    d_frontend: int = 0
    # numerics / structure
    norm: str = "rmsnorm"
    act: str = "silu"
    tie_embeddings: bool = False
    dtype: Any = jnp.bfloat16
    remat: bool = True
    ce_chunk: int = 2048
    fsdp_params: bool = False
    batch_axes: Tuple[str, ...] = ()   # mesh axes the batch is sharded over
    seq_axes: Tuple[str, ...] = ()     # mesh axes decode caches shard seq over
    shard_activations: bool = False    # layer-boundary h sharded over 'model'
                                       # on d (ZeRO-activations; big-arch train)

    @property
    def period(self) -> int:
        return len(self.period_pattern)

    @property
    def n_periods(self) -> int:
        return self.n_layers // self.period

    @property
    def tail(self) -> int:
        return self.n_layers - self.n_periods * self.period

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def dt_rank(self) -> int:
        return max(self.d_model // 16, 1)

    @property
    def rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    @property
    def is_decoder(self) -> bool:
        return all(m != "attn_bidir" for m, _ in self.period_pattern)

    def param_count(self) -> int:
        return layers.param_count(build_template(self))


def _constrain(cfg: ModelConfig, x: Array) -> Array:
    if not cfg.batch_axes:
        return x
    if cfg.shard_activations and x.ndim == 3:
        # layer-boundary storage sharded over 'model' on d_model; XLA
        # all-gathers at use sites (sequence-parallel-style storage saving)
        spec = P(cfg.batch_axes, None, "model")
    else:
        spec = P(cfg.batch_axes, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------
# templates
# --------------------------------------------------------------------------

def _mixer_template(cfg: ModelConfig, kind: str) -> Template:
    if kind in ("attn", "attn_local", "attn_bidir"):
        return attention.attention_template(
            cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim,
            cfg.dtype, cfg.fsdp_params, qk_norm=cfg.qk_norm)
    if kind == "mamba":
        return ssm.mamba_template(cfg.d_model, cfg.d_inner, cfg.ssm_d_state,
                                  cfg.ssm_d_conv, cfg.dt_rank, cfg.dtype,
                                  cfg.fsdp_params)
    if kind == "rwkv":
        return rwkv6.rwkv6_template(cfg.d_model, cfg.rwkv_heads,
                                    cfg.rwkv_head_dim, cfg.dtype,
                                    cfg.fsdp_params)
    raise ValueError(kind)


def _mlp_template(cfg: ModelConfig, kind: str) -> Template:
    if kind == "dense":
        t = layers.glu_mlp_template(cfg.d_model, cfg.d_ff, cfg.dtype)
        if cfg.fsdp_params:
            return t
        return t
    if kind == "moe":
        shared_ff = cfg.d_ff if cfg.n_shared_experts else 0
        return moe_mod.moe_template(cfg.d_model, cfg.moe_d_ff, cfg.n_experts,
                                    cfg.dtype, cfg.fsdp_params,
                                    n_shared=cfg.n_shared_experts,
                                    shared_ff=shared_ff)
    if kind == "rwkv_cm":
        return rwkv6.channel_mix_template(cfg.d_model, cfg.d_ff, cfg.dtype,
                                          cfg.fsdp_params)
    raise ValueError(kind)


def _layer_template(cfg: ModelConfig, mixer: str, mlp: str) -> Template:
    return {
        "norm1": layers.norm_template(cfg.norm, cfg.d_model),
        "mixer": _mixer_template(cfg, mixer),
        "norm2": layers.norm_template(cfg.norm, cfg.d_model),
        "mlp": _mlp_template(cfg, mlp),
    }


def _stack_template(t: Template, n: int) -> Template:
    """Prepend a period axis to every leaf; remember the true fan-in."""
    def one(ps: ParamSpec):
        fan = int(np.prod(ps.shape[:-1])) if len(ps.shape) >= 2 else ps.shape[0]
        return ParamSpec((n,) + ps.shape, ps.dtype, P(None, *tuple(ps.spec)),
                         ps.init, ps.scale, fan=fan)
    return jax.tree.map(one, t, is_leaf=lambda x: isinstance(x, ParamSpec))


def build_template(cfg: ModelConfig) -> Template:
    dax = "data" if cfg.fsdp_params else None
    t: Template = {}
    if cfg.input_kind == "tokens":
        espec = P("model", dax) if cfg.vocab % 64 == 0 else P(None, "model")
        t["embed"] = {"tok": ParamSpec((cfg.vocab, cfg.d_model), cfg.dtype,
                                       espec, "normal", 0.02)}
    else:
        t["frontend"] = {"proj": ParamSpec((cfg.d_frontend, cfg.d_model),
                                           cfg.dtype, P(None, "model"), "fan_in")}
    if cfg.n_periods > 0:
        t["stack"] = {
            f"pos{i}": _stack_template(_layer_template(cfg, m, f), cfg.n_periods)
            for i, (m, f) in enumerate(cfg.period_pattern)
        }
    for j in range(cfg.tail):
        m, f = cfg.period_pattern[j]
        t[f"tail{j}"] = _layer_template(cfg, m, f)
    t["final_norm"] = layers.norm_template(cfg.norm, cfg.d_model)
    if not cfg.tie_embeddings:
        # tiny class heads (e.g. hubert's 504 codebook classes) cannot
        # shard a 16-way model axis — replicate them
        vspec = P(dax, "model") if cfg.vocab % 64 == 0 else P(dax, None)
        t["lm_head"] = {"w": ParamSpec((cfg.d_model, cfg.vocab), cfg.dtype,
                                       vspec, "fan_in")}
    return t


# --------------------------------------------------------------------------
# caches (decode state)
# --------------------------------------------------------------------------

def _layer_cache(cfg: ModelConfig, mixer: str, batch: int, seq: int,
                 seq_spec) -> Any:
    if mixer in ("attn", "attn_local", "attn_bidir"):
        kv_shape = (batch, seq, cfg.n_kv_heads, cfg.head_dim)
        if cfg.kv_cache_dtype == "int8":
            sc_shape = (batch, seq, cfg.n_kv_heads, 1)
            return {"k": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
                    "v": jax.ShapeDtypeStruct(kv_shape, jnp.int8),
                    "k_scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32),
                    "v_scale": jax.ShapeDtypeStruct(sc_shape, jnp.float32)}
        return {"k": jax.ShapeDtypeStruct(kv_shape, cfg.dtype, sharding=None),
                "v": jax.ShapeDtypeStruct(kv_shape, cfg.dtype, sharding=None)}
    if mixer == "mamba":
        return {"conv": jax.ShapeDtypeStruct(
                    (batch, cfg.ssm_d_conv - 1, cfg.d_inner), jnp.float32),
                "ssm": jax.ShapeDtypeStruct(
                    (batch, cfg.d_inner, cfg.ssm_d_state), jnp.float32)}
    if mixer == "rwkv":
        return {"shift": jax.ShapeDtypeStruct((batch, 1, cfg.d_model), jnp.float32),
                "wkv": jax.ShapeDtypeStruct(
                    (batch, cfg.rwkv_heads, cfg.rwkv_head_dim, cfg.rwkv_head_dim),
                    jnp.float32),
                "shift_ffn": jax.ShapeDtypeStruct((batch, 1, cfg.d_model),
                                                  jnp.float32)}
    raise ValueError(mixer)


def cache_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    """ShapeDtypeStruct tree describing the decode cache."""
    def stackit(tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree)

    out: Dict[str, Any] = {}
    if cfg.n_periods > 0:
        out["stack"] = {
            f"pos{i}": stackit(_layer_cache(cfg, m, batch, seq, None),
                               cfg.n_periods)
            for i, (m, _) in enumerate(cfg.period_pattern)
        }
    for j in range(cfg.tail):
        m, _ = cfg.period_pattern[j]
        out[f"tail{j}"] = _layer_cache(cfg, m, batch, seq, None)
    return out


def init_cache(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, seq))


def cache_pspec(cfg: ModelConfig, batch: int) -> Dict[str, Any]:
    """PartitionSpec tree for the cache: batch over batch_axes when it can
    shard, sequence over seq_axes (flash-decoding), states over model."""
    def one(s: jax.ShapeDtypeStruct):
        nd = len(s.shape)
        if nd >= 4 and s.shape[-3] > 1 and s.dtype != jnp.float32:
            # stacked kv cache (n_periods, B, S, Hk, D) or (B, S, Hk, D)
            lead = (None,) * (nd - 4)
            return P(*lead, cfg.batch_axes or None,
                     cfg.seq_axes or None, None, None)
        return P(*([None] * nd))
    return jax.tree.map(one, cache_struct(cfg, batch, 8))


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _apply_mixer(cfg: ModelConfig, kind: str, p, h, positions, cache, pos):
    """Returns (out, new_cache)."""
    if kind in ("attn", "attn_local", "attn_bidir"):
        mask_kind = {"attn": "causal", "attn_local": "window",
                     "attn_bidir": "bidir"}[kind]
        out, new = attention.attention_block(
            p, h, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.head_dim, mask_kind=mask_kind, window=cfg.window,
            rope_theta=cfg.rope_theta, rotary_frac=cfg.rotary_frac,
            dtype=cfg.dtype, impl=cfg.attn_impl, chunk=cfg.attn_chunk,
            cache=cache, cache_pos=pos)
        return out, new
    if kind == "mamba":
        state = None if cache is None else ssm.SSMState(cache["conv"], cache["ssm"])
        out, new = ssm.mamba_mixer(p, h, d_inner=cfg.d_inner,
                                   d_state=cfg.ssm_d_state, d_conv=cfg.ssm_d_conv,
                                   dt_rank=cfg.dt_rank, dtype=cfg.dtype,
                                   chunk=cfg.ssm_chunk, state=state)
        return out, {"conv": new.conv, "ssm": new.ssm}
    if kind == "rwkv":
        state = None if cache is None else cache["wkv"]
        carry = None if cache is None else cache["shift"]
        out, s_end, new_carry = rwkv6.rwkv6_mixer(
            p, h, n_heads=cfg.rwkv_heads, head_dim=cfg.rwkv_head_dim,
            dtype=cfg.dtype, chunk=cfg.rwkv_chunk, state=state,
            shift_carry=carry)
        return out, {"wkv": s_end, "shift": new_carry}
    raise ValueError(kind)


def _apply_mlp(cfg: ModelConfig, kind: str, p, h, cache):
    """Returns (out, aux_loss, new_cache_piece)."""
    if kind == "dense":
        return layers.glu_mlp(p, h, cfg.act, cfg.dtype), 0.0, None
    if kind == "moe":
        out, aux = moe_mod.moe_mlp(p, h, top_k=cfg.top_k, n_experts=cfg.n_experts,
                                   act=cfg.act, dtype=cfg.dtype,
                                   capacity_factor=cfg.moe_capacity_factor,
                                   chunk=cfg.moe_chunk, impl=cfg.moe_impl,
                                   pregather=cfg.moe_pregather)
        return out, aux, None
    if kind == "rwkv_cm":
        b = h.shape[0]
        carry = (jnp.zeros((b, 1, cfg.d_model), jnp.float32) if cache is None
                 else cache["shift_ffn"])
        out, new_carry = rwkv6.channel_mix(p, h, carry, cfg.dtype)
        return out, 0.0, new_carry
    raise ValueError(kind)


def _layer(cfg: ModelConfig, mixer: str, mlp: str, p, h, positions,
           cache, pos):
    """Pre-norm residual layer.  Returns (h, aux, new_cache)."""
    mixed, new_cache = _apply_mixer(cfg, mixer, p["mixer"],
                                    layers.apply_norm(cfg.norm, h, p["norm1"]),
                                    positions, cache, pos)
    h = _constrain(cfg, h + mixed)
    out, aux, cm_carry = _apply_mlp(cfg, mlp, p["mlp"],
                                    layers.apply_norm(cfg.norm, h, p["norm2"]),
                                    cache)
    if cm_carry is not None and new_cache is not None:
        new_cache["shift_ffn"] = cm_carry
    return _constrain(cfg, h + out), aux, new_cache


def _embed_in(cfg: ModelConfig, params, x: Array) -> Array:
    if cfg.input_kind == "tokens":
        h = jnp.take(params["embed"]["tok"], x, axis=0).astype(cfg.dtype)
        if cfg.tie_embeddings:
            h = h * jnp.asarray(cfg.d_model ** 0.5, cfg.dtype)  # gemma-style
        return h
    return layers.linear(x.astype(cfg.dtype), params["frontend"]["proj"],
                         cfg.dtype)


def backbone(cfg: ModelConfig, params, x: Array, positions: Array,
             cache: Optional[Dict] = None, pos: Optional[Array] = None,
             collect_cache: bool = False
             ) -> Tuple[Array, Array, Optional[Dict]]:
    """-> (hidden (B, T, d), aux_loss, new_cache).

    cache=None + collect_cache=True is the prefill path: per-layer states
    (full-sequence kv / end states) are captured and stacked by the scan.
    """
    h = _constrain(cfg, _embed_in(cfg, params, x))
    aux_total = jnp.float32(0.0)
    decoding = cache is not None
    collect = decoding or collect_cache
    new_cache: Optional[Dict] = {} if collect else None

    if cfg.n_periods > 0:
        def period_body(carry, xs):
            h, aux = carry
            if decoding:
                pp, cc = xs
            else:
                pp, cc = xs, {f"pos{i}": None for i in range(cfg.period)}
            new_cc = {}
            for i, (m, f) in enumerate(cfg.period_pattern):
                h, a, nc = _layer(cfg, m, f, pp[f"pos{i}"], h, positions,
                                  cc[f"pos{i}"], pos)
                new_cc[f"pos{i}"] = nc
                aux = aux + a
            return (h, aux), (new_cc if collect else None)

        body = period_body
        if cfg.remat and not collect:
            body = jax.checkpoint(period_body)
        xs = (params["stack"], cache["stack"]) if decoding else params["stack"]
        (h, aux_total), stack_cache = jax.lax.scan(body, (h, aux_total), xs)
        if collect:
            new_cache["stack"] = stack_cache

    for j in range(cfg.tail):
        m, f = cfg.period_pattern[j]
        cc = cache[f"tail{j}"] if decoding else None
        h, a, nc = _layer(cfg, m, f, params[f"tail{j}"], h, positions, cc, pos)
        aux_total = aux_total + a
        if collect:
            new_cache[f"tail{j}"] = nc

    h = layers.apply_norm(cfg.norm, h, params["final_norm"])
    return h, aux_total, new_cache


def _head_matrix(cfg: ModelConfig, params) -> Array:
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["lm_head"]["w"]


def logits_fn(cfg: ModelConfig, params, h: Array) -> Array:
    """Unchunked logits — only for tiny smoke shapes / last-position decode."""
    return layers.linear(h, _head_matrix(cfg, params), cfg.dtype).astype(jnp.float32)


def chunked_ce(cfg: ModelConfig, params, h: Array, labels: Array,
               mask: Optional[Array] = None) -> Array:
    """Cross-entropy without materializing (T, vocab).  h (B, T, d)."""
    b, t, d = h.shape
    w = _head_matrix(cfg, params)
    chunk = min(cfg.ce_chunk, t)
    n_chunks = -(-t // chunk)
    pad = n_chunks * chunk - t
    hp = jnp.pad(h, ((0, 0), (0, pad), (0, 0))) if pad else h
    lp = jnp.pad(labels, ((0, 0), (0, pad))) if pad else labels
    mp = (jnp.pad(mask, ((0, 0), (0, pad))) if mask is not None
          else jnp.pad(jnp.ones((b, t), jnp.float32), ((0, 0), (0, pad)))
          if pad else (mask if mask is not None else jnp.ones((b, t), jnp.float32)))
    hc = hp.reshape(b, n_chunks, chunk, d).transpose(1, 0, 2, 3)
    lc = lp.reshape(b, n_chunks, chunk).transpose(1, 0, 2)
    mc = mp.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, xs):
        loss_sum, count = carry
        hi, li, mi = xs
        logit = jax.lax.dot_general(
            hi.astype(cfg.dtype), w.astype(cfg.dtype),
            (((2,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        lse = jax.nn.logsumexp(logit, axis=-1)                    # (B, c)
        gold = jnp.take_along_axis(logit, li[..., None], axis=-1)[..., 0]
        loss_sum = loss_sum + jnp.sum((lse - gold) * mi)
        return (loss_sum + 0.0, count + jnp.sum(mi)), None

    (loss_sum, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc, mc))
    return loss_sum / jnp.maximum(count, 1.0)


# --------------------------------------------------------------------------
# entry points
# --------------------------------------------------------------------------

def loss_fn(cfg: ModelConfig, params, batch: Dict[str, Array]) -> Array:
    """batch: {"inputs": (B,T) int or (B,T,df) float, "labels": (B,T) int,
    optional "mask": (B,T)}."""
    x = batch["inputs"]
    b, t = batch["labels"].shape
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    h, aux, _ = backbone(cfg, params, x, positions)
    ce = chunked_ce(cfg, params, h, batch["labels"], batch.get("mask"))
    return ce + cfg.aux_loss_weight * aux


def prefill(cfg: ModelConfig, params, x: Array
            ) -> Tuple[Array, Dict[str, Any]]:
    """Prefill pass: returns (last-position logits (B, vocab), cache).

    The returned attention caches have length T (the prompt); the serve
    layer pads them to the generation budget before decode_step."""
    b, t = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    h, _, new_cache = backbone(cfg, params, x, positions, collect_cache=True)
    logits = layers.linear(h[:, -1:], _head_matrix(cfg, params),
                           cfg.dtype).astype(jnp.float32)[:, 0]
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, token: Array, cache: Dict[str, Any],
                pos: Array) -> Tuple[Array, Dict[str, Any]]:
    """token (B, 1) (or (B, 1, df) for embed frontends); pos () int32."""
    b = token.shape[0]
    positions = jnp.broadcast_to(pos.astype(jnp.int32).reshape(1, 1), (b, 1))
    h, _, new_cache = backbone(cfg, params, token, positions, cache=cache,
                               pos=pos)
    logits = layers.linear(h[:, -1], _head_matrix(cfg, params),
                           cfg.dtype).astype(jnp.float32)
    return logits, new_cache


def encode(cfg: ModelConfig, params, x: Array) -> Array:
    """Encoder-only (hubert): full-sequence logits via chunk-free head on
    pooled classes (vocab is small: 504)."""
    b, t = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None], (b, t))
    h, _, _ = backbone(cfg, params, x, positions)
    return logits_fn(cfg, params, h)
