"""RWKV-6 "Finch" mixer: linear attention with data-dependent decay.

Per head (dim K): state S (K, V) evolves as

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    o_t = (r_t (S_{t-1} + diag(u) k_t^T v_t))          (bonus u on current)

with w_t = exp(-exp(ww + lora_w(x_t))) in (0, 1) data-dependent decay —
the arch pool's "Finch — data-dependent decay".  Attention-free: O(1)
state per head, so `long_500k` decode runs (the reason this arch keeps
that shape).

Chunked training form: within a chunk of Q steps the contribution of
earlier chunks is  r_t (prod_{chunk} w) ... handled by carrying S between
chunks (lax.scan) and computing within-chunk interactions with cumulative
decay products — O(T/Q) sequential steps, matmul-shaped work inside.

Token-shift ("time-mix") follows RWKV: each block input is a learned lerp
of x_t and x_{t-1}; the shift carry is part of the decode state.

Simplifications vs the reference CUDA kernel (recorded in DESIGN.md):
data-dependent token-shift LoRAs are collapsed to static mix vectors, and
gate/receptance LoRA ranks are folded into dense projections.  The state
recurrence — the part that defines the architecture class — is exact.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import ParamSpec, Template

Array = jax.Array


class RWKVState(NamedTuple):
    shift: Array    # (B, 1, d) previous token (time-mix carry)
    wkv: Array      # (B, H, K, V) f32 linear-attention state
    shift_ffn: Array  # (B, 1, d) channel-mix carry


def rwkv6_template(d: int, n_heads: int, head_dim: int, dtype,
                   fsdp: bool, decay_lora: int = 64) -> Template:
    dax = "data" if fsdp else None
    hd = n_heads * head_dim
    return {
        "mix_r": ParamSpec((d,), jnp.float32, P(None), "ones", 0.5),
        "mix_k": ParamSpec((d,), jnp.float32, P(None), "ones", 0.5),
        "mix_v": ParamSpec((d,), jnp.float32, P(None), "ones", 0.5),
        "mix_w": ParamSpec((d,), jnp.float32, P(None), "ones", 0.5),
        "mix_g": ParamSpec((d,), jnp.float32, P(None), "ones", 0.5),
        "wr": ParamSpec((d, hd), dtype, P(dax, "model"), "fan_in"),
        "wk": ParamSpec((d, hd), dtype, P(dax, "model"), "fan_in"),
        "wv": ParamSpec((d, hd), dtype, P(dax, "model"), "fan_in"),
        "wg": ParamSpec((d, hd), dtype, P(dax, "model"), "fan_in"),
        "wo": ParamSpec((hd, d), dtype, P("model", dax), "fan_in"),
        # data-dependent decay: w_t = exp(-exp(ww + (x W_a) W_b))
        "ww": ParamSpec((hd,), jnp.float32, P("model"), "normal", 0.5),
        "w_lora_a": ParamSpec((d, decay_lora), dtype, P(dax, None), "fan_in"),
        "w_lora_b": ParamSpec((decay_lora, hd), dtype, P(None, "model"), "fan_in", 0.1),
        "u_bonus": ParamSpec((n_heads, head_dim), jnp.float32, P("model", None),
                             "normal", 0.5),
        "ln_x_w": ParamSpec((hd,), jnp.float32, P("model"), "ones"),
    }


def channel_mix_template(d: int, ff: int, dtype, fsdp: bool) -> Template:
    dax = "data" if fsdp else None
    return {
        "mix_k": ParamSpec((d,), jnp.float32, P(None), "ones", 0.5),
        "wk": ParamSpec((d, ff), dtype, P(dax, "model"), "fan_in"),
        "wv": ParamSpec((ff, d), dtype, P("model", dax), "fan_in"),
    }


def _token_shift(x: Array, carry: Array) -> Tuple[Array, Array]:
    """x (B, T, d) -> previous-token tensor, new carry (last token)."""
    prev = jnp.concatenate([carry.astype(x.dtype), x[:, :-1]], axis=1)
    return prev, x[:, -1:].astype(jnp.float32)


def _wkv_chunk(r: Array, k: Array, v: Array, w: Array, u: Array,
               s0: Array) -> Tuple[Array, Array]:
    """One chunk of the RWKV6 recurrence.

    r/k/w (B, H, Q, K); v (B, H, Q, V); u (H, K); s0 (B, H, K, V) f32.
    Returns (o (B, H, Q, V), s_end).

    Derivation: with cumulative decay D_t = prod_{i<=t} w_i,
      contribution of state:     r_t D_t S_0
      intra-chunk (j < t):       r_t (D_t / D_j) k_j^T v_j
      current-token bonus:       (r_t u k_t) v_t
    Products are stabilized in log space (w in (0,1) => log w < 0).
    """
    bh, q = r.shape[:2], r.shape[2]
    logw = jnp.log(jnp.maximum(w, 1e-12))                  # (B, H, Q, K)
    lcum = jnp.cumsum(logw, axis=2)                        # D_t (inclusive)
    d_in = jnp.exp(lcum - logw)                            # D_t / w_t = prod_{i<t}
    r_dec = r * d_in                                       # r_t prod_{i<t} w_i
    o_state = jnp.einsum("bhqk,bhkv->bhqv", r_dec, s0,
                         preferred_element_type=jnp.float32)

    # intra-chunk: A[t, j] = r_t . (k_j * D_{t-1}/D_j) for j < t
    k_dec = k * jnp.exp(-lcum)                             # k_j / D_j
    att = jnp.einsum("bhqk,bhjk->bhqj", r_dec, k_dec,
                     preferred_element_type=jnp.float32)
    tri = jnp.tril(jnp.ones((q, q), jnp.float32), k=-1)    # strictly lower
    att = att * tri
    o_intra = jnp.einsum("bhqj,bhjv->bhqv", att, v,
                         preferred_element_type=jnp.float32)

    # current-token bonus
    o_bonus = jnp.einsum("bhqk,bhqk,bhqv->bhqv", r, u[None, :, None, :] * k,
                         jnp.ones_like(v),
                         preferred_element_type=jnp.float32) if False else (
        jnp.sum(r * u[None, :, None, :] * k, axis=-1, keepdims=True) * v)

    # state update: S_end = D_Q S_0 + sum_j (D_Q / D_j) k_j^T v_j
    d_total = jnp.exp(lcum[:, :, -1])                      # (B, H, K)
    s_end = d_total[..., None] * s0 + jnp.einsum(
        "bhjk,bhjv->bhkv", k_dec * d_total[:, :, None, :], v,
        preferred_element_type=jnp.float32)
    return o_state + o_intra + o_bonus, s_end


def rwkv6_mixer(
    p: Dict[str, Array],
    x: Array,                      # (B, T, d)
    *,
    n_heads: int,
    head_dim: int,
    dtype=jnp.bfloat16,
    chunk: int = 128,
    state: Optional[RWKVState] = None,
    shift_carry: Optional[Array] = None,
) -> Tuple[Array, Array, Array]:
    """Returns (out (B, T, d), wkv_state (B, H, K, V), shift_carry (B, 1, d))."""
    b, t, d = x.shape
    h, kd = n_heads, head_dim

    if state is None:
        carry = jnp.zeros((b, 1, d), jnp.float32)
        s0 = jnp.zeros((b, h, kd, kd), jnp.float32)
    else:
        carry, s0 = shift_carry, state

    prev, new_carry = _token_shift(x, carry)

    def mixed(mv):
        return x * mv.astype(x.dtype) + prev * (1.0 - mv).astype(x.dtype)

    xf = x.astype(jnp.float32)
    r = layers.linear(mixed(p["mix_r"]), p["wr"], dtype)
    k = layers.linear(mixed(p["mix_k"]), p["wk"], dtype)
    v = layers.linear(mixed(p["mix_v"]), p["wv"], dtype)
    g = layers.linear(mixed(p["mix_g"]), p["wg"], dtype)
    w_in = layers.linear(mixed(p["mix_w"]), p["w_lora_a"], dtype)
    w_log = p["ww"] + layers.linear(jnp.tanh(w_in.astype(jnp.float32)).astype(dtype),
                                    p["w_lora_b"], dtype).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(w_log))                            # (B, T, H*K) in (0,1)

    def heads(z):
        return z.astype(jnp.float32).reshape(b, t, h, kd).transpose(0, 2, 1, 3)

    rh, kh, vh, wh = heads(r), heads(k), heads(v), heads(w)
    u = p["u_bonus"]

    if t == 1:
        # decode: o = r (S + u k^T v); S' = diag(w) S + k^T v
        kv = kh[:, :, 0, :, None] * vh[:, :, 0, None, :]     # (B, H, K, V)
        o = jnp.einsum("bhk,bhkv->bhv", rh[:, :, 0],
                       s0 + u[None, :, :, None] * kv,
                       preferred_element_type=jnp.float32)[:, :, None]
        s_end = wh[:, :, 0, :, None] * s0 + kv
        o = o.transpose(0, 2, 1, 3).reshape(b, 1, h * kd)
    else:
        q = min(chunk, t)
        n_chunks = -(-t // q)
        pad = n_chunks * q - t
        if pad:
            padw = ((0, 0), (0, 0), (0, pad), (0, 0))
            rh, kh, vh = (jnp.pad(z, padw) for z in (rh, kh, vh))
            wh = jnp.pad(wh, padw, constant_values=1.0)      # decay 1 = inert

        # chunk-level remat: backward recomputes the intra-chunk (Q, Q)
        # interaction matrices instead of keeping all chunks' alive
        @jax.checkpoint
        def body(s, xs_):
            rq, kq, vq, wq = xs_
            o, s_end = _wkv_chunk(rq, kq, vq, wq, u, s)
            return s_end, o

        def to_chunks(z):
            return z.reshape(b, h, n_chunks, q, kd).transpose(2, 0, 1, 3, 4)

        s_end, os = jax.lax.scan(body, s0, (to_chunks(rh), to_chunks(kh),
                                            to_chunks(vh), to_chunks(wh)))
        o = os.transpose(1, 2, 0, 3, 4).reshape(b, h, n_chunks * q, kd)[:, :, :t]
        o = o.transpose(0, 2, 1, 3).reshape(b, t, h * kd)

    # per-head group norm (ln_x) + silu gate
    of = o.reshape(b, -1, h, kd)
    mu = jnp.mean(of, axis=-1, keepdims=True)
    var = jnp.var(of, axis=-1, keepdims=True)
    of = (of - mu) * jax.lax.rsqrt(var + 1e-5)
    o = (of.reshape(b, -1, h * kd) * p["ln_x_w"]).astype(dtype)
    o = o * jax.nn.silu(g.astype(jnp.float32)).astype(dtype)
    return layers.linear(o, p["wo"], dtype), s_end, new_carry


def channel_mix(p: Dict[str, Array], x: Array, carry: Array, dtype
                ) -> Tuple[Array, Array]:
    """RWKV FFN: squared-relu with token shift.  Returns (out, new carry)."""
    prev, new_carry = _token_shift(x, carry)
    xk = x * p["mix_k"].astype(x.dtype) + prev * (1.0 - p["mix_k"]).astype(x.dtype)
    hidden = layers.act_fn("relu2", layers.linear(xk, p["wk"], dtype))
    return layers.linear(hidden, p["wv"], dtype), new_carry
