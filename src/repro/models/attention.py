"""GQA attention block: train/prefill (flash) + decode (cache) paths.

Three attention executors share one module:

  * ``blocked``  — pure-jnp online-softmax flash (lax.scan over kv chunks).
    Differentiable, O(T x chunk) memory; the default for training and for
    the compiled dry-run, so cost/memory analysis reflects flash-style
    bytes, not a materialized (T, S) score matrix.
  * ``pallas``   — repro.kernels.flash_attention on real TPU backends.
  * ``ref``      — materialized softmax for tiny smoke shapes / oracles.

Decode attends one new token against a full KV cache; with the cache
sequence-sharded over the mesh the softmax max/sum reductions become the
flash-decoding cross-device merge (XLA SPMD inserts the all-reduces).

Sharding (Megatron TP): head-sharded projections over 'model'; the FSDP
axis 'data' optionally shards the d_model dimension of every weight.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import ParamSpec, Template

Array = jax.Array

NEG_INF = -1e30


def attention_template(d: int, n_heads: int, n_kv: int, head_dim: int,
                       dtype, fsdp: bool, qk_norm: bool = False,
                       qkv_bias: bool = False) -> Template:
    dax = "data" if fsdp else None
    t: Template = {
        "wq": ParamSpec((d, n_heads * head_dim), dtype, P(dax, "model"), "fan_in"),
        "wk": ParamSpec((d, n_kv * head_dim), dtype, P(dax, "model"), "fan_in"),
        "wv": ParamSpec((d, n_kv * head_dim), dtype, P(dax, "model"), "fan_in"),
        "wo": ParamSpec((n_heads * head_dim, d), dtype, P("model", dax), "fan_in"),
    }
    if qkv_bias:
        t["bq"] = ParamSpec((n_heads * head_dim,), jnp.float32, P("model"), "zeros")
        t["bk"] = ParamSpec((n_kv * head_dim,), jnp.float32, P("model"), "zeros")
        t["bv"] = ParamSpec((n_kv * head_dim,), jnp.float32, P("model"), "zeros")
    if qk_norm:
        t["q_norm"] = ParamSpec((head_dim,), jnp.float32, P(None), "ones")
        t["k_norm"] = ParamSpec((head_dim,), jnp.float32, P(None), "ones")
    return t


# --------------------------------------------------------------------------
# executors
# --------------------------------------------------------------------------

def _ref_attention(q: Array, k: Array, v: Array, mask_kind: str, window: int,
                   scale: float) -> Array:
    from repro.kernels.flash_attention.ref import flash_attention_ref
    return flash_attention_ref(q, k, v, mask_kind, window, scale)


def _blocked_attention(q: Array, k: Array, v: Array, mask_kind: str,
                       window: int, scale: float, chunk: int) -> Array:
    """Online-softmax flash in jnp: scan over kv chunks.

    q (B, T, H, D); k/v (B, S, Hk, D).  Memory O(B T H chunk).
    """
    b, t, h, d = q.shape
    s, hk = k.shape[1], k.shape[2]
    g = h // hk
    chunk = min(chunk, s)
    n_chunks = -(-s // chunk)
    pad = n_chunks * chunk - s
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, n_chunks, chunk, hk, d).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, hk, d).transpose(1, 0, 2, 3, 4)
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    rows = jnp.arange(t) + (s - t)                     # real row coordinates

    @jax.checkpoint
    def body(carry, xs):
        """kv-chunk step; checkpointed so backward recomputes the (T, chunk)
        probability tile instead of keeping all tiles (flash backward)."""
        m_run, l_run, acc = carry
        kj, vj, j = xs
        cols = j * chunk + jnp.arange(chunk)
        logit = jnp.einsum("bthgd,bshd->bthgs", qf, kj.astype(jnp.float32),
                           preferred_element_type=jnp.float32) * scale
        mask = (cols[None, :] < s)
        if mask_kind in ("causal", "window"):
            mask = mask & (rows[:, None] >= cols[None, :])
            if mask_kind == "window":
                mask = mask & (rows[:, None] - cols[None, :] < window)
        logit = jnp.where(mask[None, :, None, None, :], logit, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(logit, axis=-1))
        p = jnp.exp(logit - m_new[..., None])
        alpha = jnp.exp(m_run - m_new)
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bthgs,bshd->bthgd", p, vj.astype(jnp.float32),
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc), None

    init = (jnp.full((b, t, hk, g), NEG_INF, jnp.float32),
            jnp.zeros((b, t, hk, g), jnp.float32),
            jnp.zeros((b, t, hk, g, d), jnp.float32))
    (m_f, l_f, acc), _ = jax.lax.scan(body, init,
                                      (kc, vc, jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l_f, 1e-30)[..., None]
    return out.reshape(b, t, h, d).astype(q.dtype)


def _pallas_attention(q, k, v, mask_kind, window, scale):
    from repro.kernels.flash_attention.ops import flash_attention
    return flash_attention(q, k, v, mask_kind=mask_kind, window=window)


def run_attention(q: Array, k: Array, v: Array, mask_kind: str, window: int,
                  scale: float, impl: str = "blocked", chunk: int = 1024) -> Array:
    if impl == "ref":
        return _ref_attention(q, k, v, mask_kind, window, scale)
    if impl == "pallas":
        return _pallas_attention(q, k, v, mask_kind, window, scale)
    return _blocked_attention(q, k, v, mask_kind, window, scale, chunk)


# --------------------------------------------------------------------------
# the block
# --------------------------------------------------------------------------

def _split_heads(x: Array, n: int, d: int) -> Array:
    b, t, _ = x.shape
    return x.reshape(b, t, n, d)


def _qk_norm(x: Array, w: Array) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + 1e-6) * w).astype(x.dtype)


def attention_block(
    p: Dict[str, Array],
    x: Array,                      # (B, T, d)
    positions: Array,              # (B, T)
    *,
    n_heads: int,
    n_kv: int,
    head_dim: int,
    mask_kind: str = "causal",     # causal | window | bidir
    window: int = 0,
    rope_theta: float = 10000.0,
    rotary_frac: float = 1.0,
    use_rope: bool = True,
    dtype=jnp.bfloat16,
    impl: str = "blocked",
    chunk: int = 1024,
    cache: Optional[Tuple[Array, Array]] = None,   # (k_cache, v_cache) (B, S, Hk, D)
    cache_pos: Optional[Array] = None,             # () int32 write position
    logit_softcap: float = 0.0,
) -> Tuple[Array, Optional[Tuple[Array, Array]]]:
    """Returns (out (B, T, d), new_cache).

    Decode: pass cache + cache_pos with T == 1; attention runs over the
    full cache (ring-buffer write at cache_pos).  Prefill: cache is None
    and the caller keeps the returned k/v as the new cache.
    """
    b, t, _ = x.shape
    q = _split_heads(layers.linear(x, p["wq"], dtype), n_heads, head_dim)
    k = _split_heads(layers.linear(x, p["wk"], dtype), n_kv, head_dim)
    v = _split_heads(layers.linear(x, p["wv"], dtype), n_kv, head_dim)
    if "bq" in p:
        q = q + p["bq"].reshape(n_heads, head_dim).astype(dtype)
        k = k + p["bk"].reshape(n_kv, head_dim).astype(dtype)
        v = v + p["bv"].reshape(n_kv, head_dim).astype(dtype)
    if "q_norm" in p:
        q = _qk_norm(q, p["q_norm"])
        k = _qk_norm(k, p["k_norm"])
    if use_rope:
        q = layers.apply_rope(q, positions, rope_theta, rotary_frac)
        k = layers.apply_rope(k, positions, rope_theta, rotary_frac)

    scale = float(head_dim ** -0.5)

    if cache is None:
        out = run_attention(q, k, v, mask_kind, window, scale, impl, chunk)
        new_cache = {"k": k, "v": v}
    else:
        s = cache["k"].shape[1]
        pos = jnp.mod(cache_pos, s)   # ring-buffer write position
        quantized = "k_scale" in cache
        new_cache = dict(cache)
        if quantized:
            # per-(token, head) symmetric int8: scale = max|x| / 127
            for name, new in (("k", k), ("v", v)):
                sc = jnp.max(jnp.abs(new.astype(jnp.float32)), axis=-1,
                             keepdims=True) / 127.0
                sc = jnp.maximum(sc, 1e-10)
                q8 = jnp.clip(jnp.round(new.astype(jnp.float32) / sc),
                              -127, 127).astype(jnp.int8)
                new_cache[name] = jax.lax.dynamic_update_slice(
                    cache[name], q8, (0, pos, 0, 0))
                new_cache[name + "_scale"] = jax.lax.dynamic_update_slice(
                    cache[name + "_scale"], sc, (0, pos, 0, 0))
            k_eff = new_cache["k"].astype(jnp.float32) * new_cache["k_scale"]
            v_eff = new_cache["v"].astype(jnp.float32) * new_cache["v_scale"]
        else:
            new_cache["k"] = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            new_cache["v"] = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            k_eff, v_eff = new_cache["k"], new_cache["v"]
        dec_window = window if mask_kind == "window" else 0
        if impl == "pallas" and jax.default_backend() == "tpu":
            # fused serving kernel: streams the cache in its stored dtype
            # (int8 tiles = half the HBM traffic), dequantizes in VMEM.
            # NOTE: requires an unsharded (per-device) cache sequence; the
            # sequence-sharded flash-decoding path keeps the jnp executor
            # (XLA inserts the cross-shard softmax merge).
            out = fused_decode(q, new_cache, scale, window=dec_window,
                               cache_pos=cache_pos)
        else:
            out = decode_attention(q, k_eff, v_eff, scale, window=dec_window,
                                   cache_pos=cache_pos,
                                   logit_softcap=logit_softcap)

    if logit_softcap > 0.0 and cache is None:
        pass  # softcap is folded into the executors only for decode; train
              # paths with softcap use ref impl (gemma-style caps unused here)
    out = out.reshape(b, t, n_heads * head_dim)
    return layers.linear(out, p["wo"], dtype), new_cache


def fused_decode(q: Array, cache: dict, scale: float, window: int,
                 cache_pos: Array, force_pallas: bool = False) -> Array:
    """Route one-token attention through the fused Pallas decode kernel.

    q (B, 1, H, D); cache leaves (B, S, Hk, D) [+ scales].  Returns
    (B, 1, H, D)."""
    from repro.kernels.decode_attention.ops import decode_attention_fused
    b, t, h, d = q.shape
    hk = cache["k"].shape[2]
    g = h // hk
    qh = q.reshape(b, hk, g, d)
    out = decode_attention_fused(
        qh, cache["k"], cache["v"], cache_pos, scale,
        k_scale=cache.get("k_scale"), v_scale=cache.get("v_scale"),
        window=window, force_pallas=force_pallas)
    return out.reshape(b, t, h, d)


def decode_attention(q: Array, k_cache: Array, v_cache: Array, scale: float,
                     window: int = 0, cache_pos: Optional[Array] = None,
                     logit_softcap: float = 0.0) -> Array:
    """One-token attention over the full cache.

    q (B, 1, H, D); caches (B, S, Hk, D).  With the cache sequence-sharded,
    the max/sum reductions lower to cross-device all-reduces — the
    flash-decoding merge.
    """
    b, t, h, d = q.shape
    s, hk = k_cache.shape[1], k_cache.shape[2]
    g = h // hk
    qf = q.astype(jnp.float32).reshape(b, t, hk, g, d)
    logits = jnp.einsum("bthgd,bshd->bthgs", qf, k_cache.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
    if logit_softcap > 0.0:
        logits = jnp.tanh(logits / logit_softcap) * logit_softcap
    if cache_pos is not None:
        idx = jnp.arange(s)
        # never-written ring slots (pos < S, idx > pos) must not attend
        valid = (idx <= cache_pos) | (cache_pos >= s)
        if window > 0:
            age = jnp.mod(cache_pos - idx, s)
            valid &= age < window
        logits = jnp.where(valid[None, None, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bthgs,bshd->bthgd", p / jnp.maximum(l, 1e-30),
                     v_cache.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, t, h, d).astype(q.dtype)
