"""Shared LM building blocks: param templates, norms, RoPE, MLPs.

Parameters are described by a *template* (nested dict of ParamSpec) that
carries shape, dtype, PartitionSpec, and init recipe.  The same template
drives three consumers:

  * real init          (smoke tests / the ~100M example trainer)
  * jax.eval_shape     (multi-pod dry-run: ShapeDtypeStructs, no allocation)
  * NamedSharding tree (jit in_shardings for params/optimizer state)

Sharding vocabulary (logical axes -> mesh axes):
  'model'  tensor-parallel axis: heads / d_ff / experts / vocab
  'data'   FSDP axis: second param shard for >=70B archs; batch axis
  'pod'    outermost data-parallel axis (multi-pod)

All matmuls run in the config's compute dtype (bf16 by default) with f32
accumulation via preferred_element_type; norms/softmax/rope are f32.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jax.Array


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    dtype: Any
    spec: P          # PartitionSpec over ('data', 'model') logical axes
    init: str        # zeros | ones | normal | fan_in
    scale: float = 1.0
    fan: Optional[int] = None  # explicit fan-in (stacked/period templates)


Template = Dict[str, Any]  # nested dict[str, ParamSpec | Template]


def leaf_specs(template: Template):
    return jax.tree.leaves(template, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(template: Template, key: Array) -> Dict[str, Any]:
    """Materialize real parameters (smoke tests / small-model training)."""
    leaves, treedef = jax.tree.flatten(template, is_leaf=lambda x: isinstance(x, ParamSpec))
    keys = jax.random.split(key, len(leaves))
    out = []
    for ps, k in zip(leaves, keys):
        if ps.init == "zeros":
            v = jnp.zeros(ps.shape, ps.dtype)
        elif ps.init == "ones":
            v = jnp.ones(ps.shape, ps.dtype)
        elif ps.init == "normal":
            v = (ps.scale * jax.random.normal(k, ps.shape, jnp.float32)).astype(ps.dtype)
        elif ps.init == "fan_in":
            fan = ps.fan if ps.fan is not None else (
                ps.shape[0] if len(ps.shape) <= 2 else int(np.prod(ps.shape[:-1])))
            std = ps.scale / math.sqrt(max(fan, 1))
            v = (std * jax.random.normal(k, ps.shape, jnp.float32)).astype(ps.dtype)
        else:
            raise ValueError(ps.init)
        out.append(v)
    return jax.tree.unflatten(treedef, out)


def shape_tree(template: Template, mesh: Optional[Mesh] = None) -> Dict[str, Any]:
    """ShapeDtypeStructs (with shardings if mesh given) — dry-run stand-ins."""
    def one(ps: ParamSpec):
        if mesh is None:
            return jax.ShapeDtypeStruct(ps.shape, ps.dtype)
        return jax.ShapeDtypeStruct(ps.shape, ps.dtype,
                                    sharding=NamedSharding(mesh, ps.spec))
    return jax.tree.map(one, template, is_leaf=lambda x: isinstance(x, ParamSpec))


def sharding_tree(template: Template, mesh: Mesh) -> Dict[str, Any]:
    return jax.tree.map(lambda ps: NamedSharding(mesh, ps.spec), template,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def spec_tree(template: Template) -> Dict[str, Any]:
    return jax.tree.map(lambda ps: ps.spec, template,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def param_count(template: Template) -> int:
    return sum(int(np.prod(ps.shape)) for ps in leaf_specs(template))


def param_bytes(template: Template) -> int:
    return sum(int(np.prod(ps.shape)) * jnp.dtype(ps.dtype).itemsize
               for ps in leaf_specs(template))


# --------------------------------------------------------------------------
# numerics
# --------------------------------------------------------------------------

def rms_norm(x: Array, w: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (w.astype(jnp.float32))).astype(x.dtype)


def layer_norm(x: Array, w: Array, b: Optional[Array], eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    if b is not None:
        out = out + b.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(kind: str, x: Array, p: Dict[str, Array]) -> Array:
    if kind == "rmsnorm":
        return rms_norm(x, p["w"])
    return layer_norm(x, p["w"], p.get("b"))


def norm_template(kind: str, d: int, bias: bool = False) -> Template:
    t: Template = {"w": ParamSpec((d,), jnp.float32, P(None), "ones")}
    if kind == "layernorm" and bias:
        t["b"] = ParamSpec((d,), jnp.float32, P(None), "zeros")
    return t


# --------------------------------------------------------------------------
# RoPE (partial-rotary aware)
# --------------------------------------------------------------------------

def rope_frequencies(head_dim: int, rotary_dim: int, theta: float) -> np.ndarray:
    assert rotary_dim % 2 == 0
    return 1.0 / (theta ** (np.arange(0, rotary_dim, 2, dtype=np.float64) / rotary_dim))


def apply_rope(x: Array, positions: Array, theta: float, rotary_frac: float = 1.0) -> Array:
    """x (..., T, H, D); positions (..., T) int32.  Rotates the first
    rotary_frac*D dims (even/odd interleave-free 'half-split' layout)."""
    d = x.shape[-1]
    rd = int(d * rotary_frac)
    rd -= rd % 2
    if rd == 0:
        return x
    freqs = jnp.asarray(rope_frequencies(d, rd, theta), jnp.float32)  # (rd/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs            # (..., T, rd/2)
    cos = jnp.cos(ang)[..., None, :]                                  # (..., T, 1, rd/2)
    sin = jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rd], x[..., rd:]
    x1, x2 = xr[..., : rd // 2], xr[..., rd // 2:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    r1 = xf1 * cos - xf2 * sin
    r2 = xf2 * cos + xf1 * sin
    return jnp.concatenate([r1.astype(x.dtype), r2.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# dense projections & MLPs
# --------------------------------------------------------------------------

def linear(x: Array, w: Array, dtype) -> Array:
    return jax.lax.dot_general(
        x.astype(dtype), w.astype(dtype),
        (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).astype(dtype)


def act_fn(name: str, x: Array) -> Array:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if name == "relu2":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(name)


def glu_mlp_template(d: int, ff: int, dtype) -> Template:
    """Gated MLP (SwiGLU / GeGLU).  ff sharded over model, d over data."""
    return {
        "wi": ParamSpec((d, ff), dtype, P("data", "model"), "fan_in"),
        "wg": ParamSpec((d, ff), dtype, P("data", "model"), "fan_in"),
        "wo": ParamSpec((ff, d), dtype, P("model", "data"), "fan_in"),
    }


def glu_mlp(p: Dict[str, Array], x: Array, act: str, dtype) -> Array:
    h = act_fn(act, linear(x, p["wg"], dtype)) * linear(x, p["wi"], dtype)
    return linear(h, p["wo"], dtype)


# --------------------------------------------------------------------------
# embedding / logits (vocab-sharded, chunked CE lives in model.py)
# --------------------------------------------------------------------------

def embed_template(vocab: int, d: int, dtype) -> Template:
    return {"tok": ParamSpec((vocab, d), dtype, P("model", "data"), "fan_in", 1.0)}


def embed_lookup(emb: Array, tokens: Array, dtype) -> Array:
    return jnp.take(emb, tokens, axis=0).astype(dtype)
