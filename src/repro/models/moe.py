"""Mixture-of-Experts MLP with capacity-chunked token-choice routing.

Expert parallelism: expert weights are sharded over 'model' on the expert
axis; token activations are sharded over 'data'.  The dispatch/combine
einsums against model-sharded experts lower to the all-to-all exchanges of
classic EP under XLA SPMD.

Memory control: the dispatch one-hot (tokens, E, C) is the classic scaling
hazard.  We process tokens in fixed-size chunks with lax.scan, so the
one-hot never exceeds (chunk, E, cap_per_chunk) — the MoE analogue of
chunked cross-entropy.  Capacity per chunk = chunk * top_k / E * cf;
overflow tokens are dropped (standard capacity-factor semantics) and the
residual path keeps them alive.

Router is f32; expert matmuls run in the model compute dtype.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.layers import ParamSpec, Template

Array = jax.Array


def moe_template(d: int, ff: int, n_experts: int, dtype, fsdp: bool,
                 n_shared: int = 0, shared_ff: int = 0) -> Template:
    dax = "data" if fsdp else None
    t: Template = {
        "router": ParamSpec((d, n_experts), jnp.float32, P(dax, None), "fan_in"),
        "wi": ParamSpec((n_experts, d, ff), dtype, P("model", dax, None), "fan_in"),
        "wg": ParamSpec((n_experts, d, ff), dtype, P("model", dax, None), "fan_in"),
        "wo": ParamSpec((n_experts, ff, d), dtype, P("model", None, dax), "fan_in"),
    }
    if n_shared > 0:
        t["shared"] = layers.glu_mlp_template(d, shared_ff, dtype)
    return t


def _route(logits: Array, top_k: int) -> Tuple[Array, Array]:
    """(T, E) f32 -> (weights (T, k), indices (T, k)); softmax over top-k."""
    gate, idx = jax.lax.top_k(logits, top_k)
    gate = jax.nn.softmax(gate, axis=-1)
    return gate, idx


def _chunk_moe(p: Dict[str, Array], xc: Array, *, top_k: int, capacity: int,
               n_experts: int, act: str, dtype,
               impl: str = "einsum") -> Tuple[Array, Array]:
    """One token chunk.  xc (C_t, d) -> (C_t, d), plus aux loss pieces.

    impl="einsum": classic one-hot dispatch/combine matmuls (baseline —
    2*t*E*cap*d FLOPs each, MORE than the expert math at fine-grained
    expert sizes).  impl="gather": scatter-add dispatch + gather combine —
    zero matmul overhead, same capacity semantics (§Perf hillclimb).
    """
    ct, d = xc.shape
    logits = xc.astype(jnp.float32) @ p["router"].astype(jnp.float32)  # (C_t, E)
    gate, idx = _route(logits, top_k)                                  # (C_t, k)

    # position of each (token, k) assignment within its expert's capacity
    onehot = jax.nn.one_hot(idx, n_experts, dtype=jnp.int32)           # (C_t, k, E)
    flat = onehot.reshape(ct * top_k, n_experts)
    pos_in_expert = jnp.cumsum(flat, axis=0) * flat - 1                # (C_t*k, E)
    keep = (pos_in_expert < capacity) & (flat > 0)
    gate_flat = gate.reshape(ct * top_k)
    x_rep = jnp.repeat(xc, top_k, axis=0)                               # (C_t*k, d)

    if impl == "gather":
        # flat slot id: expert * cap + position; dropped -> dump slot E*cap
        slot = jnp.sum(jnp.where(keep, idx.reshape(ct * top_k)[:, None]
                                 * capacity + pos_in_expert, 0), axis=1)
        dropped = ~jnp.any(keep, axis=1)
        slot = jnp.where(dropped, n_experts * capacity, slot)          # (C_t*k,)
        buf = jnp.zeros((n_experts * capacity + 1, d), dtype)
        buf = buf.at[slot].add(x_rep.astype(dtype))                    # scatter
        buf = buf[:-1].reshape(n_experts, capacity, d)
    else:
        disp = jax.nn.one_hot(jnp.where(keep, pos_in_expert, -1), capacity,
                              dtype=dtype)                              # (C_t*k, E, cap)
        disp = disp * keep[..., None].astype(dtype)
        buf = jnp.einsum("tec,td->ecd", disp, x_rep.astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)

    h = jnp.einsum("ecd,edf->ecf", buf, p["wg"].astype(dtype),
                   preferred_element_type=jnp.float32)
    h = layers.act_fn(act, h).astype(dtype) * jnp.einsum(
        "ecd,edf->ecf", buf, p["wi"].astype(dtype),
        preferred_element_type=jnp.float32).astype(dtype)
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(dtype),
                       preferred_element_type=jnp.float32).astype(dtype)  # (E, cap, d)

    if impl == "gather":
        flat_out = jnp.concatenate(
            [out_e.reshape(n_experts * capacity, d),
             jnp.zeros((1, d), dtype)], axis=0)                         # dump row
        y = flat_out[slot] * gate_flat[:, None].astype(dtype)           # gather
        y = jnp.where(dropped[:, None], 0.0, y)
        y = y.reshape(ct, top_k, d).sum(axis=1).astype(dtype)
    else:
        comb = disp * gate_flat[:, None, None].astype(dtype)
        y = jnp.einsum("tec,ecd->td", comb, out_e,
                       preferred_element_type=jnp.float32)              # (C_t*k, d)
        y = y.reshape(ct, top_k, d).sum(axis=1).astype(dtype)

    # load-balance aux (Switch-style): mean gate prob * assignment fraction
    probs = jax.nn.softmax(logits, axis=-1)
    frac_tokens = jnp.mean(onehot.sum(1).astype(jnp.float32), axis=0)   # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = n_experts * jnp.sum(frac_tokens / top_k * frac_probs)
    return y, aux


def moe_mlp(p: Dict[str, Array], x: Array, *, top_k: int, n_experts: int,
            act: str, dtype, capacity_factor: float = 2.0,
            chunk: int = 4096, impl: str = "einsum",
            pregather: bool = False) -> Tuple[Array, Array]:
    """x (B, T, d) -> (B, T, d).  Returns (out, aux_loss).

    pregather=True re-shards FSDP (data-axis) expert weights to
    model-only sharding ONCE per layer, outside the chunk scan — without
    it the remat'd chunk body re-all-gathers the weights on EVERY chunk
    (measured 6.3e12 collective bytes/device at qwen3 train_4k; §Perf).
    """
    b, t, d = x.shape
    if pregather:
        from jax.sharding import PartitionSpec as P
        gathered = {}
        for name in ("wi", "wg", "wo"):
            gathered[name] = jax.lax.with_sharding_constraint(
                p[name], P("model", None, None))
        p = {**p, **gathered}
    xt = x.reshape(b * t, d)
    n_tok = b * t
    chunk = min(chunk, n_tok)
    n_chunks = -(-n_tok // chunk)
    pad = n_chunks * chunk - n_tok
    if pad:
        xt = jnp.pad(xt, ((0, pad), (0, 0)))
    capacity = max(int(chunk * top_k / n_experts * capacity_factor), 4)
    xc = xt.reshape(n_chunks, chunk, d)

    body = functools.partial(_chunk_moe, p, top_k=top_k, capacity=capacity,
                             n_experts=n_experts, act=act, dtype=dtype,
                             impl=impl)

    # chunk-level remat: dispatch one-hots and (E, cap, ff) expert
    # activations are recomputed in backward, never all live at once
    @jax.checkpoint
    def scan_body(_, xci):
        y, aux = body(xci)
        return None, (y, aux)

    if n_chunks == 1:
        y, aux = body(xc[0])
        ys, auxs = y[None], aux[None]
    else:
        _, (ys, auxs) = jax.lax.scan(scan_body, None, xc)
    out = ys.reshape(n_chunks * chunk, d)[:n_tok].reshape(b, t, d)
    if "shared" in p:
        out = out + layers.glu_mlp(p["shared"], x, act, dtype)
    return out, jnp.mean(auxs)
