"""LM training loop: jit'd step + grad accumulation + checkpoint/resume.

Scale posture (the parts that transfer to 1000+ nodes):
  * one compiled train_step under the mesh; all distribution comes from
    param/batch shardings (pjit/SPMD), so the same loop runs 1 or 512 chips;
  * microbatch grad accumulation via lax.scan — the per-microbatch
    backward overlaps with the previous microbatch's gradient all-reduce
    under XLA's async collectives (the compute/comm overlap trick);
  * checkpoint every N steps, atomic, with deterministic data replay
    (batch = f(seed, step)), so preemption costs at most N steps;
  * straggler story: static balanced shapes (no dynamic work), plus
    restart-from-checkpoint on failed hosts — see DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import model as model_mod
from repro.models.model import ModelConfig
from repro.train import checkpoint as ckpt_mod
from repro.train.optimizer import OptConfig, OptState, adamw_step, init_opt_state

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TrainLoopConfig:
    total_steps: int = 100
    grad_accum: int = 1
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10


def make_train_step(model_cfg: ModelConfig, opt_cfg: OptConfig,
                    grad_accum: int = 1) -> Callable:
    """Builds the jit-able (params, opt_state, batch) -> (params, opt, metrics).

    With grad_accum > 1, the batch leading dim is (accum * micro_batch) and
    microbatches are scanned; gradients average across microbatches.
    """

    def loss(params, batch):
        return model_mod.loss_fn(model_cfg, params, batch)

    def step(params, opt_state: OptState, batch: Dict[str, Array]):
        if grad_accum == 1:
            l, grads = jax.value_and_grad(loss)(params, batch)
        else:
            def micro(carry, mb):
                acc, lsum = carry
                l, g = jax.value_and_grad(loss)(params, mb)
                return (jax.tree.map(jnp.add, acc, g), lsum + l), None

            def split(x):
                return x.reshape((grad_accum, x.shape[0] // grad_accum)
                                 + x.shape[1:])

            mbs = jax.tree.map(split, batch)
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params)
            (gsum, lsum), _ = jax.lax.scan(micro, (zeros, jnp.float32(0)), mbs)
            grads = jax.tree.map(lambda g: (g / grad_accum), gsum)
            grads = jax.tree.map(lambda g, p: g.astype(p.dtype), grads, params)
            l = lsum / grad_accum
        new_params, new_opt, metrics = adamw_step(grads, opt_state, opt_cfg)
        metrics["loss"] = l
        return new_params, new_opt, metrics

    return step


class Trainer:
    """Host-side loop with fault tolerance."""

    def __init__(self, model_cfg: ModelConfig, opt_cfg: OptConfig,
                 loop_cfg: TrainLoopConfig, pipeline,
                 param_shardings=None, mesh=None):
        self.model_cfg = model_cfg
        self.opt_cfg = opt_cfg
        self.loop_cfg = loop_cfg
        self.pipeline = pipeline
        self.mesh = mesh
        self.param_shardings = param_shardings
        # no donation: compute params alias opt.master for f32 leaves (norm
        # weights), and XLA rejects donating an aliased buffer twice.  At
        # production scale, donate by keeping master strictly separate.
        self._step_fn = jax.jit(make_train_step(model_cfg, opt_cfg,
                                                loop_cfg.grad_accum))

    def init_state(self, seed: int = 0):
        from repro.models.layers import init_params
        params = init_params(model_mod.build_template(self.model_cfg),
                             jax.random.PRNGKey(seed))
        if self.param_shardings is not None:
            params = jax.tree.map(jax.device_put, params, self.param_shardings)
        opt = init_opt_state(params, self.opt_cfg)
        return params, opt

    def restore_or_init(self, seed: int = 0):
        lc = self.loop_cfg
        params, opt = self.init_state(seed)
        start = 0
        if lc.ckpt_dir and ckpt_mod.latest_step(lc.ckpt_dir) is not None:
            (params, opt), start, _ = ckpt_mod.restore_checkpoint(
                lc.ckpt_dir, (params, opt))
        return params, opt, start

    def run(self, seed: int = 0, fail_at: Optional[int] = None
            ) -> Dict[str, Any]:
        """Train to total_steps; ``fail_at`` raises mid-run to exercise the
        restart path in tests."""
        lc = self.loop_cfg
        params, opt, start = self.restore_or_init(seed)
        history = []
        t0 = time.time()
        for step in range(start, lc.total_steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"injected failure at step {step}")
            batch = self.pipeline.batch(step)
            params, opt, metrics = self._step_fn(params, opt, batch)
            if step % lc.log_every == 0 or step == lc.total_steps - 1:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "lr": float(metrics["lr"])})
            if lc.ckpt_dir and (step + 1) % lc.ckpt_every == 0:
                ckpt_mod.save_checkpoint(lc.ckpt_dir, step + 1, (params, opt),
                                         keep_last=lc.keep_last)
        if lc.ckpt_dir:
            ckpt_mod.save_checkpoint(lc.ckpt_dir, lc.total_steps, (params, opt),
                                     keep_last=lc.keep_last)
        return {"params": params, "opt": opt, "history": history,
                "wall_s": time.time() - t0}
