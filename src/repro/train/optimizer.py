"""AdamW with sharding-aware dtype policies + LR schedules.

Policies (per-arch choice recorded in DESIGN.md §5):
  "fp32"      — fp32 master copy + fp32 moments (default, <70B)
  "bf16_mom"  — fp32 master + bf16 moments
  "pure_bf16" — bf16 master + bf16 moments (>=200B to fit 16 GB/chip);
                update math still runs in f32.

The optimizer state is a pytree congruent with params, so the launcher
shards it with the same NamedShardings (optimizer state lives wherever
its parameter lives — ZeRO-style when fsdp_params shards over 'data').
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array

_POLICIES = {
    "fp32": (jnp.float32, jnp.float32),
    "bf16_mom": (jnp.float32, jnp.bfloat16),
    "pure_bf16": (jnp.bfloat16, jnp.bfloat16),
}


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    policy: str = "fp32"
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"     # cosine | linear | constant
    min_lr_frac: float = 0.1


class OptState(NamedTuple):
    step: Array       # () int32
    master: Any       # params in master dtype
    m: Any
    v: Any


def init_opt_state(params, cfg: OptConfig) -> OptState:
    mdt, sdt = _POLICIES[cfg.policy]
    return OptState(
        step=jnp.int32(0),
        master=jax.tree.map(lambda p: p.astype(mdt), params),
        m=jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
        v=jax.tree.map(lambda p: jnp.zeros(p.shape, sdt), params),
    )


def schedule_lr(cfg: OptConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        decay = 1.0
    else:
        frac = jnp.clip((s - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1.0 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    return cfg.lr * warm * decay


def global_norm(tree) -> Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_step(grads, state: OptState, cfg: OptConfig
               ) -> Tuple[Any, OptState, Dict[str, Array]]:
    """Returns (new compute-dtype params, new state, metrics)."""
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, mast, m, v):
        gf = g.astype(jnp.float32) * scale
        mf = m.astype(jnp.float32) * b1 + (1 - b1) * gf
        vf = v.astype(jnp.float32) * b2 + (1 - b2) * gf * gf
        u = (mf / bc1) / (jnp.sqrt(vf / bc2) + cfg.eps)
        wd = cfg.weight_decay if mast.ndim >= 2 else 0.0  # no decay on norms
        new_master = mast.astype(jnp.float32) - lr * (u + wd * mast.astype(jnp.float32))
        return new_master, mf, vf

    flat_g, treedef = jax.tree.flatten(grads)
    flat_ma = jax.tree.leaves(state.master)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    new_master, new_m, new_v = [], [], []
    for g, ma, m, v in zip(flat_g, flat_ma, flat_m, flat_v):
        nm, mm, vv = upd(g, ma, m, v)
        new_master.append(nm.astype(ma.dtype))
        new_m.append(mm.astype(m.dtype))
        new_v.append(vv.astype(v.dtype))

    master = jax.tree.unflatten(treedef, new_master)
    new_state = OptState(step=step, master=master,
                         m=jax.tree.unflatten(treedef, new_m),
                         v=jax.tree.unflatten(treedef, new_v))
    # compute-dtype params come from the master copy
    compute = jax.tree.map(lambda ma, g: ma.astype(g.dtype), master, grads)
    metrics = {"lr": lr, "grad_norm": gnorm, "clip_scale": scale}
    return compute, new_state, metrics
