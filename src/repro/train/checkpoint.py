"""Checkpointing + fault tolerance.

Design for 1000+ nodes (scaled-down faithfully here):
  * step-sharded directories ``<dir>/step_<n>/`` written atomically
    (tmp dir + rename) so a mid-write failure never corrupts the latest
    complete checkpoint;
  * one ``.npz`` per host with that host's addressable shards plus a JSON
    manifest (step, mesh shape, leaf paths/shapes/dtypes, RNG, config
    fingerprint) — restore works on a DIFFERENT mesh (elastic re-shard:
    arrays are re-placed through device_put with the new sharding);
  * ``keep_last`` garbage collection, ``latest`` pointer file;
  * deterministic resume: the data pipeline keys off (seed, step), so a
    restart reproduces the exact batch order (see repro.data.tokens).

On this single-process container there is exactly one host shard; the
multihost path writes ``shard_<process_index>.npz`` per host — same format.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra: Optional[Dict[str, Any]] = None,
                    keep_last: int = 3) -> str:
    """Atomic save.  Returns the final step directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    paths, leaves, _ = _flatten_with_paths(tree)
    host = jax.process_index()

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        # raw-byte storage: npz cannot roundtrip ml_dtypes (bf16/fp8);
        # shapes and true dtypes live in the manifest
        arrays = {
            f"leaf_{i}": np.frombuffer(np.ascontiguousarray(
                np.asarray(l)).tobytes(), np.uint8)
            for i, l in enumerate(leaves)
        }
        np.savez(os.path.join(tmp, f"shard_{host}.npz"), **arrays)
        manifest = {
            "step": step,
            "n_leaves": len(leaves),
            "paths": paths,
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "n_processes": jax.process_count(),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise

    with open(os.path.join(ckpt_dir, "latest"), "w") as f:
        f.write(f"step_{step:08d}")

    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "latest")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    path = os.path.join(ckpt_dir, name)
    if not os.path.exists(os.path.join(path, "manifest.json")):
        # torn pointer: fall back to newest complete step dir
        steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                       and os.path.exists(os.path.join(ckpt_dir, d,
                                                       "manifest.json")))
        if not steps:
            return None
        name = steps[-1]
    return int(name.split("_")[1])


def peek_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict[str, Any]:
    """Read a checkpoint's manifest without touching the array payload.

    Lets self-describing consumers (e.g. ``repro.serve.model_bank``) build a
    restore target from the stored paths/shapes/dtypes instead of having to
    know them up front — a cold-starting server has nothing but the
    directory.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    with open(os.path.join(ckpt_dir, f"step_{step:08d}", "manifest.json")) as f:
        manifest = json.load(f)
    return manifest


def restore_self_describing(ckpt_dir: str, step: Optional[int] = None
                            ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Restore a FLAT-dict checkpoint with the target built from its own
    manifest — for consumers that have nothing but the directory (model
    banks, stage artifacts).  Returns ``({key: np.ndarray}, extra)``.

    Only valid for checkpoints whose tree was a flat ``{str: array}`` dict
    (every stage artifact in this repo); the manifest path strings are the
    dict keys.
    """
    manifest = peek_manifest(ckpt_dir, step)
    target = {}
    for path, dt in zip(manifest["paths"], manifest["dtypes"]):
        target[path.strip("[]'\"")] = np.zeros((), dtype=np.dtype(dt))
    tree, _, extra = restore_checkpoint(ckpt_dir, target, step=step)
    return {k: np.asarray(v) for k, v in tree.items()}, extra


def restore_checkpoint(ckpt_dir: str, target: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, int, Dict[str, Any]]:
    """Restore into the structure of ``target``.

    ``shardings`` (a NamedSharding tree congruent with target) enables
    elastic re-meshing: the stored host arrays are re-placed under the NEW
    mesh regardless of the mesh they were saved from.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(d, f"shard_{jax.process_index()}.npz"))
    leaves = []
    for i in range(manifest["n_leaves"]):
        raw = data[f"leaf_{i}"]
        dt = np.dtype(manifest["dtypes"][i])
        leaves.append(np.frombuffer(raw.tobytes(), dt).reshape(
            manifest["shapes"][i]))

    t_paths, t_leaves, treedef = _flatten_with_paths(target)
    if t_paths != manifest["paths"]:
        raise ValueError(
            "checkpoint/target structure mismatch:\n"
            f"  missing: {set(manifest['paths']) - set(t_paths)}\n"
            f"  extra:   {set(t_paths) - set(manifest['paths'])}")

    out = []
    for leaf, tgt in zip(leaves, t_leaves):
        arr = jnp.asarray(leaf, dtype=tgt.dtype)
        out.append(arr)
    tree = jax.tree.unflatten(treedef, out)
    if shardings is not None:
        tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, step, manifest["extra"]
