"""Checkpointing + fault tolerance.

Design for 1000+ nodes (scaled-down faithfully here):
  * step-sharded directories ``<dir>/step_<n>/`` written atomically
    (tmp dir + rename) so a mid-write failure never corrupts the latest
    complete checkpoint;
  * one ``.npz`` per host with that host's addressable shards plus a JSON
    manifest (step, mesh shape, leaf paths/shapes/dtypes, RNG, config
    fingerprint) — restore works on a DIFFERENT mesh (elastic re-shard:
    arrays are re-placed through device_put with the new sharding);
  * **crash safety**: every durable write is fsync'd (shard, manifest,
    the containing directory, the ``latest`` pointer — which is itself
    updated via write-to-temp + ``os.replace``), and the manifest carries
    a per-array blake2b checksum.  A torn step dir (kill mid-write) or a
    corrupt one (bit rot, truncation) is DETECTED — ``latest_step`` skips
    dirs whose manifest/shard are incomplete, and the restore paths verify
    checksums and fall back to the newest older step that passes instead
    of loading garbage (:class:`CheckpointCorruptError` when none does);
  * ``keep_last`` garbage collection that never deletes a step currently
    being restored and never deletes the only complete step;
  * deterministic resume: the data pipeline keys off (seed, step), so a
    restart reproduces the exact batch order (see repro.data.tokens).

Fault-injection points (``repro.testing.faults``) bracket every durable
transition of the save path; injected faults deliberately skip the tmp-dir
cleanup so the on-disk debris matches a hard kill, and stale tmp dirs are
swept by the next writer.

On this single-process container there is exactly one host shard; the
multihost path writes ``shard_<process_index>.npz`` per host — same format.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import tempfile
import time
import zipfile
import zlib
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.testing import faults

PyTree = Any

MANIFEST_VERSION = 2        # v2 adds per-leaf checksums; v1 restores fine

# step dirs currently being restored (abspaths): _gc must not delete them
_RESTORING: set = set()

# (ckpt_dir, skipped step) pairs recorded when a restore fell back past a
# torn/corrupt step — observability for serving-side degradation counters
_FALLBACK_LOG: List[Tuple[str, int]] = []


class CheckpointCorruptError(RuntimeError):
    """A step dir failed verification (torn write, checksum mismatch)."""


def fallback_log() -> List[Tuple[str, int]]:
    """Steps skipped as corrupt by restore fallbacks since process start."""
    return list(_FALLBACK_LOG)


def _note_fallback(ckpt_dir: str, skipped: List[int]) -> None:
    """Record steps a restore skipped as corrupt: the module log (exact
    (dir, step) pairs for debugging) AND the metrics registry (the counter
    operators watch — silent fallbacks were invisible before PR 7)."""
    _FALLBACK_LOG.extend((ckpt_dir, int(s)) for s in skipped)
    obs.metrics.counter("checkpoint.fallback_steps").inc(len(skipped))


def _flatten_with_paths(tree: PyTree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def _step_name(step: int) -> str:
    return f"step_{step:08d}"


def _leaf_digest(raw: bytes) -> str:
    return hashlib.blake2b(raw, digest_size=16).hexdigest()


def _fsync_path(path: str) -> None:
    """fsync a file or directory by path (best-effort on exotic fs)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def _sweep_stale_tmp(ckpt_dir: str) -> None:
    """Remove tmp dirs left by a killed writer (single-writer protocol)."""
    for d in os.listdir(ckpt_dir):
        if d.startswith(".tmp_step_"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def save_checkpoint(ckpt_dir: str, step: int, tree: PyTree,
                    extra: Optional[Dict[str, Any]] = None,
                    keep_last: int = 3) -> str:
    """Atomic, fsync'd, checksummed save.  Returns the final step directory.

    Kill this at ANY point and the directory still holds only complete,
    verifiable steps: the shard and manifest land in a tmp dir, are
    fsync'd, and become visible in one ``rename``; the ``latest`` pointer
    is advisory (readers fall back to directory listing when it is stale
    or torn).
    """
    t_save = time.perf_counter()
    os.makedirs(ckpt_dir, exist_ok=True)
    _sweep_stale_tmp(ckpt_dir)
    paths, leaves, _ = _flatten_with_paths(tree)
    host = jax.process_index()

    final = os.path.join(ckpt_dir, _step_name(step))
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=f".tmp_step_{step}_")
    try:
        faults.fire("checkpoint.save.pre_shard", step=step)
        # raw-byte storage: npz cannot roundtrip ml_dtypes (bf16/fp8);
        # shapes and true dtypes live in the manifest
        raw = [np.ascontiguousarray(np.asarray(l)).tobytes() for l in leaves]
        arrays = {f"leaf_{i}": np.frombuffer(b, np.uint8)
                  for i, b in enumerate(raw)}
        shard_path = os.path.join(tmp, f"shard_{host}.npz")
        np.savez(shard_path, **arrays)
        _fsync_path(shard_path)
        faults.fire("checkpoint.save.post_shard", step=step)
        manifest = {
            "manifest_version": MANIFEST_VERSION,
            "step": step,
            "n_leaves": len(leaves),
            "paths": paths,
            "shapes": [list(np.shape(l)) for l in leaves],
            "dtypes": [str(np.asarray(l).dtype) for l in leaves],
            "checksums": [_leaf_digest(b) for b in raw],
            "n_processes": jax.process_count(),
            "extra": extra or {},
        }
        man_path = os.path.join(tmp, "manifest.json")
        with open(man_path, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        _fsync_path(tmp)
        faults.fire("checkpoint.save.pre_rename", step=step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _fsync_path(ckpt_dir)
    except BaseException as e:
        # an InjectedFault emulates SIGKILL: leave the debris on disk so the
        # recovery path is tested against what a real kill leaves behind
        if not isinstance(e, faults.InjectedFault):
            shutil.rmtree(tmp, ignore_errors=True)
        raise
    faults.fire("checkpoint.save.post_rename", step=step)

    # advisory pointer, atomically replaced (a reader never sees a torn
    # pointer file; a STALE one is handled by the listing fallback)
    ptr_tmp = os.path.join(ckpt_dir, ".latest.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(_step_name(step))
        f.flush()
        os.fsync(f.fileno())
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "latest"))
    _fsync_path(ckpt_dir)
    faults.fire("checkpoint.save.post_latest", step=step)

    _gc(ckpt_dir, keep_last)
    t_done = time.perf_counter()
    obs.tracer.record("checkpoint.save", t_save, t_done)
    obs.metrics.counter("checkpoint.saves").inc()
    if t_done > t_save:
        nbytes = sum(len(b) for b in raw)
        obs.metrics.gauge("checkpoint.save_mbps").set(
            nbytes / (t_done - t_save) / 1e6)
    return final


# ----------------------------------------------------------- verification
def _read_manifest(step_dir: str) -> Optional[Dict[str, Any]]:
    """Parse a step dir's manifest; None when missing/torn."""
    try:
        with open(os.path.join(step_dir, "manifest.json")) as f:
            m = json.load(f)
        for k in ("step", "n_leaves", "paths", "shapes", "dtypes"):
            if k not in m:
                return None
        return m
    except (OSError, ValueError):
        return None


def _quick_ok(step_dir: str) -> Optional[Dict[str, Any]]:
    """Cheap completeness check: manifest parses + this host's shard file
    exists.  Payload integrity (checksums) is verified on restore."""
    m = _read_manifest(step_dir)
    if m is None:
        return None
    shard = os.path.join(step_dir, f"shard_{jax.process_index()}.npz")
    return m if os.path.exists(shard) else None


def list_steps(ckpt_dir: str) -> List[int]:
    """Ascending step numbers of COMPLETE (quick-verified) step dirs."""
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in sorted(os.listdir(ckpt_dir)):
        if not d.startswith("step_"):
            continue
        if _quick_ok(os.path.join(ckpt_dir, d)) is not None:
            try:
                out.append(int(d.split("_")[1]))
            except ValueError:
                continue
    return out


def verify_step(ckpt_dir: str, step: int) -> bool:
    """Deep verification: manifest + shard + per-leaf byte sizes and
    checksums (manifest v2; v1 checks sizes only).  Reads the payload."""
    step_dir = os.path.join(ckpt_dir, _step_name(step))
    m = _quick_ok(step_dir)
    if m is None:
        return False
    try:
        _read_leaves(step_dir, m)
    except CheckpointCorruptError:
        return False
    return True


def _read_leaves(step_dir: str, manifest: Dict[str, Any]) -> List[np.ndarray]:
    """Load + verify this host's leaves; raises CheckpointCorruptError."""
    shard = os.path.join(step_dir, f"shard_{jax.process_index()}.npz")
    checksums = manifest.get("checksums")
    leaves = []
    try:
        with np.load(shard) as data:
            names = set(data.files)
            for i in range(manifest["n_leaves"]):
                key = f"leaf_{i}"
                if key not in names:
                    raise CheckpointCorruptError(
                        f"{shard}: missing {key} "
                        f"(has {len(names)}/{manifest['n_leaves']} leaves)")
                raw = data[key].tobytes()
                dt = np.dtype(manifest["dtypes"][i])
                shape = tuple(manifest["shapes"][i])
                want = int(np.prod(shape, dtype=np.int64)) * dt.itemsize
                if len(raw) != want:
                    raise CheckpointCorruptError(
                        f"{shard}: leaf_{i} holds {len(raw)} bytes, manifest "
                        f"says {want} ({shape}, {dt}) — truncated write?")
                if checksums is not None and _leaf_digest(raw) != checksums[i]:
                    raise CheckpointCorruptError(
                        f"{shard}: leaf_{i} checksum mismatch — corrupt "
                        f"payload (path {manifest['paths'][i]!r})")
                leaves.append(np.frombuffer(raw, dt).reshape(shape))
    except (OSError, ValueError, zipfile.BadZipFile, zlib.error,
            KeyError) as e:
        # a torn zip (truncated shard) or a CRC failure during member
        # decompression lands here
        raise CheckpointCorruptError(f"{shard}: unreadable shard ({e})")
    return leaves


def _gc(ckpt_dir: str, keep_last: int) -> None:
    """Delete old step dirs, with two guards that make GC safe to run at
    any moment: a step currently being restored is never deleted, and the
    newest COMPLETE step always survives (even when ``keep_last`` newer —
    but torn — dirs exist above it, the one good step must not be lost)."""
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    if keep_last <= 0:
        return
    victims = list(steps[:-keep_last])
    complete = {d for d in steps
                if _quick_ok(os.path.join(ckpt_dir, d)) is not None}
    surviving_complete = [d for d in steps
                          if d in complete and d not in victims]
    if not surviving_complete:
        for d in reversed(victims):         # spare the newest complete victim
            if d in complete:
                victims.remove(d)
                break
    for d in victims:
        path = os.path.join(ckpt_dir, d)
        if os.path.abspath(path) in _RESTORING:
            continue
        shutil.rmtree(path, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    """Newest COMPLETE step.  The ``latest`` pointer is advisory: when it
    is missing, torn, or names an incomplete dir, fall back to the newest
    step dir that passes the completeness check."""
    if not os.path.isdir(ckpt_dir):
        return None
    ptr = os.path.join(ckpt_dir, "latest")
    if os.path.exists(ptr):
        try:
            with open(ptr) as f:
                name = f.read().strip()
            if name.startswith("step_") and \
                    _quick_ok(os.path.join(ckpt_dir, name)) is not None:
                return int(name.split("_")[1])
        except (OSError, ValueError):
            pass
    steps = list_steps(ckpt_dir)
    return steps[-1] if steps else None


def peek_manifest(ckpt_dir: str, step: Optional[int] = None) -> Dict[str, Any]:
    """Read a checkpoint's manifest without touching the array payload.

    Lets self-describing consumers (e.g. ``repro.serve.model_bank``) build a
    restore target from the stored paths/shapes/dtypes instead of having to
    know them up front — a cold-starting server has nothing but the
    directory.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    m = _read_manifest(os.path.join(ckpt_dir, _step_name(step)))
    if m is None:
        raise CheckpointCorruptError(
            f"{ckpt_dir}/{_step_name(step)}: manifest missing or torn")
    return m


def restore_self_describing(ckpt_dir: str, step: Optional[int] = None
                            ) -> Tuple[Dict[str, np.ndarray], Dict[str, Any]]:
    """Restore a FLAT-dict checkpoint with the target built from its own
    manifest — for consumers that have nothing but the directory (model
    banks, stage artifacts).  Returns ``({key: np.ndarray}, extra)``.

    Only valid for checkpoints whose tree was a flat ``{str: array}`` dict
    (every stage artifact in this repo); the manifest path strings are the
    dict keys.  With ``step=None`` a corrupt newest step is SKIPPED and the
    next older complete step is tried (logged in :func:`fallback_log`); an
    explicit ``step`` raises instead.
    """
    candidates = ([step] if step is not None
                  else list(reversed(list_steps(ckpt_dir))))
    if not candidates:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    last_err: Optional[Exception] = None
    for i, s in enumerate(candidates):
        try:
            manifest = peek_manifest(ckpt_dir, s)
            target = {}
            for path, dt in zip(manifest["paths"], manifest["dtypes"]):
                target[path.strip("[]'\"")] = np.zeros((), dtype=np.dtype(dt))
            tree, _, extra = restore_checkpoint(ckpt_dir, target, step=s)
            if i > 0:
                _note_fallback(ckpt_dir, candidates[:i])
            return {k: np.asarray(v) for k, v in tree.items()}, extra
        except CheckpointCorruptError as e:
            if step is not None:
                raise
            last_err = e
    raise CheckpointCorruptError(
        f"{ckpt_dir}: no step survived verification "
        f"(tried {candidates}; last error: {last_err})")


def restore_checkpoint(ckpt_dir: str, target: PyTree,
                       step: Optional[int] = None,
                       shardings: Optional[PyTree] = None
                       ) -> Tuple[PyTree, int, Dict[str, Any]]:
    """Restore into the structure of ``target``.

    ``shardings`` (a NamedSharding tree congruent with target) enables
    elastic re-meshing: the stored host arrays are re-placed under the NEW
    mesh regardless of the mesh they were saved from.

    Integrity: per-leaf byte sizes and (manifest v2) checksums are verified
    as the payload is read; a torn or corrupt step raises
    :class:`CheckpointCorruptError`.  With ``step=None`` the newest
    complete step is restored and corrupt steps are skipped in favour of
    the next older one (the skip is recorded in :func:`fallback_log`); an
    explicit ``step`` fails fast instead.
    """
    candidates = ([step] if step is not None
                  else list(reversed(list_steps(ckpt_dir))))
    if not candidates:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    last_err: Optional[Exception] = None
    for i, s in enumerate(candidates):
        try:
            out = _restore_one(ckpt_dir, target, int(s), shardings)
            if i > 0:
                _note_fallback(ckpt_dir, candidates[:i])
            return out
        except CheckpointCorruptError as e:
            if step is not None:
                raise
            last_err = e
    raise CheckpointCorruptError(
        f"{ckpt_dir}: no step survived verification "
        f"(tried {candidates}; last error: {last_err})")


def _restore_one(ckpt_dir: str, target: PyTree, step: int,
                 shardings: Optional[PyTree]
                 ) -> Tuple[PyTree, int, Dict[str, Any]]:
    d = os.path.join(ckpt_dir, _step_name(step))
    _RESTORING.add(os.path.abspath(d))
    t_restore = time.perf_counter()
    try:
        manifest = _read_manifest(d)
        if manifest is None:
            raise CheckpointCorruptError(f"{d}: manifest missing or torn")
        leaves = _read_leaves(d, manifest)
        t_read = time.perf_counter()
        obs.tracer.record("checkpoint.restore", t_restore, t_read)
        obs.metrics.counter("checkpoint.restores").inc()
        if t_read > t_restore:
            obs.metrics.gauge("checkpoint.restore_mbps").set(
                sum(l.nbytes for l in leaves) / (t_read - t_restore) / 1e6)
        faults.fire("checkpoint.restore.mid", step=step)

        t_paths, t_leaves, treedef = _flatten_with_paths(target)
        if t_paths != manifest["paths"]:
            raise ValueError(
                "checkpoint/target structure mismatch:\n"
                f"  missing: {set(manifest['paths']) - set(t_paths)}\n"
                f"  extra:   {set(t_paths) - set(manifest['paths'])}")

        out = []
        for leaf, tgt in zip(leaves, t_leaves):
            arr = jnp.asarray(leaf, dtype=tgt.dtype)
            out.append(arr)
        tree = jax.tree.unflatten(treedef, out)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s),
                                tree, shardings)
        return tree, step, manifest["extra"]
    finally:
        _RESTORING.discard(os.path.abspath(d))
