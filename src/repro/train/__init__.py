from repro.train.svm_trainer import LiquidSVM, SVMTrainerConfig

__all__ = ["LiquidSVM", "SVMTrainerConfig"]
