"""The full liquidSVM application cycle: train -> select -> test.

The staged machinery lives in :mod:`repro.api.session` (``SVM`` sessions
producing persistable ``TrainResult`` / ``SelectResult`` / ``TestResult``
stage artifacts, mirroring the package's ``svm-train`` / ``svm-select`` /
``svm-test`` binaries); scenario front-ends (``mcSVM``, ``qtSVM``,
``nplSVM``, ``rocSVM``, ...) and the string-key config layer live in
:mod:`repro.api`; ``python -m repro.cli`` drives the stages as separate
processes.  This module keeps the estimator-style entry point:

:class:`LiquidSVM` is now a thin shim — ``fit()`` is exactly
``SVM.train()`` followed by ``select()`` (the CV-loss argmin rule, or the
validation-surface Neyman-Pearson rule for ``scenario="npsvm"``), and the
test-phase methods delegate to the resulting ``SelectResult``.  Everything
``fit`` used to expose (``coefs``, ``gamma``, ``plan``, ``np_fa``, ...)
is still populated, and under the argmin rule the decisions are
bitwise-identical to the old fused implementation (selection reuses the
exact streaming-argmin models the train stage cached).

Ingestion is streaming end-to-end: ``fit`` takes an in-memory array OR any
``repro.pipeline`` chunk source (memmap ``.npy`` path, npz shard list,
custom ``ChunkSource``); the transient footprint of a fit is
O(wave · cell).  ``n_slots_per_wave`` bounds how many packed cell slots
are staged and solved per launch; ``ckpt_dir`` makes the wave loop
resumable (see ``distributed.cell_trainer.train_cells_waves``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class SVMTrainerConfig:
    scenario: str = "binary"        # binary | ova | ava | weighted | npsvm |
                                    # quantile | expectile | ls
    solver: str = "auto"            # auto: hinge for classification, else ls/quantile/expectile
    kernel: str = "gauss_rbf"
    cell_method: str = "none"       # none | random | voronoi | overlap | recursive | coarse_fine
    cell_size: int = 2000
    n_folds: int = 5
    fold_scheme: str = "random"
    grid_choice: int = 0
    adaptivity_control: int = 0
    taus: Tuple[float, ...] = (0.05, 0.5, 0.95)
    weights: Tuple[float, ...] = (1.0,)
    np_alpha: float = 0.05          # npsvm: false-alarm budget on class -1
    tol: float = 1e-3
    max_iters: int = 1000
    cd_polish: int = 0              # Gauss-Seidel polish epochs after each
                                    # box-QP solve (kernels/cd_solver,
                                    # wave-fused); 0 = off (bitwise-
                                    # identical to the FISTA-only path)
    seed: int = 0
    scale: bool = True              # train-statistics feature scaling
    n_slots_per_wave: Optional[int] = None   # None: all slots in one wave
    chunk_size: int = 65536                  # streaming chunk rows

    def resolve_solver(self) -> str:
        if self.solver != "auto":
            return self.solver
        return {"binary": "hinge", "ova": "hinge", "ava": "hinge",
                "weighted": "hinge", "npsvm": "hinge", "quantile": "quantile",
                "expectile": "expectile", "ls": "ls"}[self.scenario]


class LiquidSVM:
    """Fused-cycle estimator (back-compat shim over the staged API).

    .. deprecated::
        ``LiquidSVM.fit`` now runs ``repro.api.SVM.train()`` +
        ``select()`` internally; prefer the staged session API
        (:mod:`repro.api`) — it keeps the train artifact so selection can
        be re-run under a different rule (NPL constraints, ROC fronts)
        without retraining, and each stage can persist/reload across
        processes (``python -m repro.cli``).
    """

    def __init__(self, config: SVMTrainerConfig = SVMTrainerConfig(),
                 mesh: Optional[Mesh] = None,
                 mesh_axes: Optional[Tuple[str, ...]] = None):
        self.config = config
        self.mesh = mesh
        self.mesh_axes = mesh_axes
        self._fitted = False

    # ------------------------------------------------------------- train
    def fit(self, x, y: np.ndarray,
            ckpt_dir: Optional[str] = None) -> "LiquidSVM":
        """Fit from an (n, d) array or any chunk source (see module doc).

        Equivalent to ``SVM.train()`` + ``select("argmin")`` (scenario
        ``npsvm``: the ``"npl"`` rule, whose false-alarm/detection rates
        come from the retained VALIDATION surface, not the train set).
        ``ckpt_dir``: per-wave checkpointing/resume of the cell solves.
        """
        from repro.api.session import SVM

        cfg = self.config
        sess = SVM(x, y, config=cfg, mesh=self.mesh,
                   mesh_axes=self.mesh_axes)
        tr = sess.train(ckpt_dir=ckpt_dir)
        rule = "npl" if cfg.scenario == "npsvm" else "argmin"
        sel = sess.select(rule)
        self.session, self.train_result, self.select_result = sess, tr, sel

        # legacy attribute surface (everything the fused fit used to set)
        self.scaler, self.tasks = tr.scaler, tr.tasks
        self.plan, self.packed, self.cv_cfg = tr.plan, tr.packed, tr.cv_cfg
        self.x_cells, self.mask_cells = tr.x_cells, tr.mask_cells
        self.coefs, self.gamma = sel.coefs, sel.gamma
        self.lam, self.tau = sel.lam, sel.tau
        self.val_loss = sel.val_loss
        if cfg.scenario == "npsvm":
            self.np_fa = np.asarray(sel.extras["np_fa"])[0]
            self.np_det = np.asarray(sel.extras["np_det"])[0]
            self.np_weight_idx = sel.default_sub
        self._fitted = True
        return self

    # ------------------------------------------------------------- serving
    def to_bank(self, drop_tol: float | None = 0.0, dtype: str = "f32",
                dedup: bool = True):
        """Compact the fitted cell models into a serving ModelBank."""
        assert self._fitted
        return self.select_result.to_bank(drop_tol=drop_tol, dtype=dtype,
                                          dedup=dedup)

    # ------------------------------------------------------------- test
    def decision_function(self, x_test: np.ndarray) -> np.ndarray:
        """(m, d) -> (m, T, S) via Voronoi routing to owning cells."""
        assert self._fitted
        return self.select_result.decision_function(x_test)

    def predict(self, x_test: np.ndarray) -> np.ndarray:
        assert self._fitted
        return self.select_result.predict(x_test)

    def error(self, x_test: np.ndarray, y_test: np.ndarray) -> float:
        assert self._fitted
        return float(self.select_result.test(x_test, y_test).error)
