"""The full liquidSVM application cycle: train -> select -> test, composing
tasks x cells x CV-grid, with optional mesh sharding of the cell axis.

This is the top-level estimator the examples and benchmarks use — the JAX
equivalent of the package's `mcSVM(Y ~ ., d$train, ...)` entry points.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.cells.builder import CellPlan, build_cells
from repro.core import cv as cv_mod
from repro.core import grids, kernel_fns
from repro.data.scaling import Scaler
from repro.distributed.cell_trainer import predict_cells, train_cells
from repro.distributed.planner import PackedCells, pack_cells
from repro.tasks.builder import TaskSet, combine_decisions, make_tasks


@dataclasses.dataclass(frozen=True)
class SVMTrainerConfig:
    scenario: str = "binary"        # binary | ova | ava | weighted | npsvm |
                                    # quantile | expectile
    solver: str = "auto"            # auto: hinge for classification, else ls/quantile/expectile
    kernel: str = "gauss_rbf"
    cell_method: str = "none"       # none | random | voronoi | overlap | recursive | coarse_fine
    cell_size: int = 2000
    n_folds: int = 5
    fold_scheme: str = "random"
    grid_choice: int = 0
    adaptivity_control: int = 0
    taus: Tuple[float, ...] = (0.05, 0.5, 0.95)
    weights: Tuple[float, ...] = (1.0,)
    np_alpha: float = 0.05          # npsvm: false-alarm budget on class -1
    tol: float = 1e-3
    max_iters: int = 1000
    seed: int = 0

    def resolve_solver(self) -> str:
        if self.solver != "auto":
            return self.solver
        return {"binary": "hinge", "ova": "hinge", "ava": "hinge",
                "weighted": "hinge", "npsvm": "hinge", "quantile": "quantile",
                "expectile": "expectile"}[self.scenario]


class LiquidSVM:
    def __init__(self, config: SVMTrainerConfig = SVMTrainerConfig(),
                 mesh: Optional[Mesh] = None,
                 mesh_axes: Optional[Tuple[str, ...]] = None):
        self.config = config
        self.mesh = mesh
        self.mesh_axes = mesh_axes
        self._fitted = False

    # ------------------------------------------------------------- train
    def fit(self, x: np.ndarray, y: np.ndarray) -> "LiquidSVM":
        cfg = self.config
        x = np.asarray(x, np.float32)
        self.scaler = Scaler.fit(x)
        xs = self.scaler.transform(x)
        n, d = xs.shape

        scenario = "weighted" if cfg.scenario in ("weighted", "npsvm") \
            else cfg.scenario
        self.tasks: TaskSet = make_tasks(y, scenario, taus=cfg.taus,
                                         weights=cfg.weights)

        n_dev = 1
        if self.mesh is not None and self.mesh_axes is not None:
            n_dev = int(np.prod([self.mesh.shape[a] for a in self.mesh_axes]))
        self.plan: CellPlan = build_cells(
            xs, cell_size=cfg.cell_size, method=cfg.cell_method, seed=cfg.seed)
        self.packed: PackedCells = pack_cells(self.plan, n_dev)

        # ---- gather padded per-slot arrays (host)
        k = self.plan.k_max
        n_slots = self.packed.n_slots
        t_count = self.tasks.n_tasks
        x_cells = np.zeros((n_slots, k, d), np.float32)
        mask_cells = np.zeros((n_slots, k), np.float32)
        y_cells = np.zeros((n_slots, t_count, k), np.float32)
        tmask_cells = np.zeros((n_slots, t_count, k), np.float32)
        gam_cells = []
        cv_cfg = cv_mod.CVConfig(
            solver=cfg.resolve_solver(), kernel=cfg.kernel, n_folds=cfg.n_folds,
            fold_scheme=cfg.fold_scheme, tol=cfg.tol, max_iters=cfg.max_iters,
            taus=cfg.taus, weights=cfg.weights)

        base_grid = grids.liquid_grid(n=k, dim=d, median_dist=1.0,
                                      grid_choice=cfg.grid_choice,
                                      cell_size=cfg.cell_size)
        if cfg.adaptivity_control > 0:
            base_grid = grids.adaptive_subgrid(base_grid, cfg.adaptivity_control)
        for s, cid in enumerate(self.packed.order):
            if cid < 0:
                gam_cells.append(np.ones(len(base_grid.gammas), np.float32))
                continue
            ids = self.plan.indices[cid]
            m = self.plan.mask[cid]
            x_cells[s], mask_cells[s] = xs[ids], m
            y_cells[s] = self.tasks.labels[:, ids] * m[None, :]
            tmask_cells[s] = self.tasks.task_mask[:, ids] * m[None, :]
            # per-cell adaptive gamma endpoints (paper: grid scaled per cell)
            med = float(kernel_fns.median_heuristic(jnp.asarray(x_cells[s]),
                                                    jnp.asarray(m)))
            g = grids.liquid_grid(n=int(m.sum()), dim=d, median_dist=med,
                                  grid_choice=cfg.grid_choice,
                                  cell_size=cfg.cell_size)
            if cfg.adaptivity_control > 0:
                g = grids.adaptive_subgrid(g, cfg.adaptivity_control)
            gam_cells.append(np.asarray(g.gammas))
        gam_cells = np.stack(gam_cells).astype(np.float32)

        lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(
            base_grid, cv_cfg, t_count)
        keys = jax.random.split(jax.random.PRNGKey(cfg.seed), n_slots)

        coefs, gamma, lam, tau, val = train_cells(
            jnp.asarray(x_cells), jnp.asarray(y_cells), jnp.asarray(tmask_cells),
            jnp.asarray(mask_cells), jnp.asarray(gam_cells), keys,
            lam_c, sub_c, task_c, cv_cfg, n_lam, n_sub,
            mesh=self.mesh, axis_names=self.mesh_axes)

        self.cv_cfg = cv_cfg
        self.x_cells, self.mask_cells = x_cells, mask_cells
        self.coefs = np.asarray(coefs)      # (n_slots, k, T, S)
        self.gamma = np.asarray(gamma)      # (n_slots, T, S)
        self.lam, self.tau = np.asarray(lam), np.asarray(tau)
        self.val_loss = np.asarray(val)
        self._fitted = True

        if cfg.scenario == "npsvm":
            # Neyman-Pearson selection over the weight grid: best detection
            # among weights whose (training-data) false alarm <= alpha
            from repro.core.select import np_select_weight
            dec = self.decision_function(x)          # (n, 1, n_weights)
            yv = np.asarray(y, np.float32)
            neg, pos = yv < 0, yv > 0
            fa = (dec[neg, 0, :] > 0).mean(0)
            det = (dec[pos, 0, :] > 0).mean(0)
            self.np_fa, self.np_det = fa, det
            self.np_weight_idx = int(np_select_weight(
                jnp.asarray(fa), jnp.asarray(det), cfg.np_alpha))
        return self

    # ------------------------------------------------------------- serving
    def to_bank(self, drop_tol: float | None = 0.0, dtype: str = "f32",
                dedup: bool = True):
        """Compact the fitted cell models into a serving ModelBank.

        The bank carries the Voronoi routing centers (empty padding slots
        pushed beyond any real point) and the train-set scaling, so
        ``SVMEngine(model.to_bank())`` serves raw-feature queries with the
        same routing the estimator uses.
        """
        assert self._fitted
        from repro.serve.model_bank import _FAR, ModelBank
        n_slots = self.packed.n_slots
        d = self.x_cells.shape[2]
        centers = np.full((n_slots, d), _FAR, np.float32)
        for s, cid in enumerate(self.packed.order):
            if cid >= 0:
                centers[s] = self.plan.centers[cid]
        return ModelBank.from_cells(
            self.x_cells, self.mask_cells, self.coefs, self.gamma, centers,
            kernel=self.config.kernel, drop_tol=drop_tol, dtype=dtype,
            dedup=dedup,
            feat_mean=self.scaler.mean.astype(np.float32),
            feat_std=self.scaler.std.astype(np.float32),
            classes=self.tasks.classes, pairs=self.tasks.pairs,
            scenario=self.config.scenario)

    # ------------------------------------------------------------- test
    def decision_function(self, x_test: np.ndarray) -> np.ndarray:
        """(m, d) -> (m, T, S) via Voronoi routing to owning cells."""
        assert self._fitted
        xt = self.scaler.transform(np.asarray(x_test, np.float32))
        m_total = xt.shape[0]
        cell_of = self.plan.route(xt)                       # (m,) cell ids
        slot_of = self.packed.slot_of_cell[cell_of]         # (m,) slots
        n_slots = self.packed.n_slots
        counts = np.bincount(slot_of, minlength=n_slots)
        m_max = max(int(counts.max()), 1)
        xt_cells = np.zeros((n_slots, m_max, xt.shape[1]), np.float32)
        back = np.zeros((n_slots, m_max), np.int64)
        fill = np.zeros(n_slots, np.int64)
        for i, s in enumerate(slot_of):
            xt_cells[s, fill[s]] = xt[i]
            back[s, fill[s]] = i
            fill[s] += 1

        dec = np.asarray(predict_cells(
            jnp.asarray(xt_cells), jnp.asarray(self.x_cells),
            jnp.asarray(self.coefs), jnp.asarray(self.gamma),
            kernel=self.config.kernel,
            mesh=self.mesh, axis_names=self.mesh_axes))     # (slots, m_max, T, S)

        out = np.zeros((m_total,) + dec.shape[2:], np.float32)
        for s in range(n_slots):
            for j in range(fill[s]):
                out[back[s, j]] = dec[s, j]
        return out

    def predict(self, x_test: np.ndarray) -> np.ndarray:
        dec = self.decision_function(x_test)
        sc = self.config.scenario
        sub = self.np_weight_idx if sc == "npsvm" else 0
        return combine_decisions(dec, sc, classes=self.tasks.classes,
                                 pairs=self.tasks.pairs, sub=sub)

    def error(self, x_test: np.ndarray, y_test: np.ndarray) -> float:
        pred = self.predict(x_test)
        sc = self.config.scenario
        if sc in ("binary", "weighted", "npsvm"):
            return float((pred != np.sign(y_test)).mean())
        if sc in ("ova", "ava"):
            return float((pred != y_test).mean())
        if sc == "quantile":
            taus = np.asarray(self.config.taus)
            r = y_test[:, None] - pred
            return float(np.where(r >= 0, taus * r, (taus - 1) * r).mean())
        if sc == "expectile":
            taus = np.asarray(self.config.taus)
            r = y_test[:, None] - pred
            return float(np.where(r >= 0, taus * r * r, (1 - taus) * r * r).mean())
        raise ValueError(sc)
