"""The full liquidSVM application cycle: train -> select -> test, composing
tasks x cells x CV-grid, with optional mesh sharding of the cell axis.

This is the top-level estimator the examples and benchmarks use — the JAX
equivalent of the package's `mcSVM(Y ~ ., d$train, ...)` entry points.

Ingestion is streaming end-to-end: ``fit`` takes an in-memory array OR any
``repro.pipeline`` chunk source (memmap ``.npy`` path, npz shard list,
custom ``ChunkSource``).  Scaling statistics, cell construction and
per-wave training staging all run chunk-by-chunk, so the transient footprint
of a fit is O(wave · cell) — only the resulting support-vector tables (the
model itself) scale with n.  ``n_slots_per_wave`` bounds how many packed
cell slots are staged and solved per launch; ``ckpt_dir`` makes the wave
loop resumable (see ``distributed.cell_trainer.train_cells_waves``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.cells.builder import CellPlan
from repro.core import cv as cv_mod
from repro.core import grids, kernel_fns
from repro.data.scaling import Scaler
from repro.distributed.cell_trainer import predict_cells, train_cells_waves
from repro.distributed.planner import (PackedCells, group_rows, pack_cells)
from repro.pipeline.cell_stream import build_cells_stream
from repro.pipeline.dataset import ArraySource, ChunkSource, ScaledSource, as_source
from repro.tasks.builder import TaskSet, combine_decisions, make_tasks


@dataclasses.dataclass(frozen=True)
class SVMTrainerConfig:
    scenario: str = "binary"        # binary | ova | ava | weighted | npsvm |
                                    # quantile | expectile
    solver: str = "auto"            # auto: hinge for classification, else ls/quantile/expectile
    kernel: str = "gauss_rbf"
    cell_method: str = "none"       # none | random | voronoi | overlap | recursive | coarse_fine
    cell_size: int = 2000
    n_folds: int = 5
    fold_scheme: str = "random"
    grid_choice: int = 0
    adaptivity_control: int = 0
    taus: Tuple[float, ...] = (0.05, 0.5, 0.95)
    weights: Tuple[float, ...] = (1.0,)
    np_alpha: float = 0.05          # npsvm: false-alarm budget on class -1
    tol: float = 1e-3
    max_iters: int = 1000
    seed: int = 0
    n_slots_per_wave: Optional[int] = None   # None: all slots in one wave
    chunk_size: int = 65536                  # streaming chunk rows

    def resolve_solver(self) -> str:
        if self.solver != "auto":
            return self.solver
        return {"binary": "hinge", "ova": "hinge", "ava": "hinge",
                "weighted": "hinge", "npsvm": "hinge", "quantile": "quantile",
                "expectile": "expectile"}[self.scenario]


class LiquidSVM:
    def __init__(self, config: SVMTrainerConfig = SVMTrainerConfig(),
                 mesh: Optional[Mesh] = None,
                 mesh_axes: Optional[Tuple[str, ...]] = None):
        self.config = config
        self.mesh = mesh
        self.mesh_axes = mesh_axes
        self._fitted = False

    # ------------------------------------------------------------- train
    def fit(self, x, y: np.ndarray,
            ckpt_dir: Optional[str] = None) -> "LiquidSVM":
        """Fit from an (n, d) array or any chunk source (see module doc).

        ``ckpt_dir``: per-wave checkpointing/resume of the cell solves.
        """
        cfg = self.config

        # one scaling path for every container: the same data fits the same
        # model whether it arrives as an ndarray, a memmap path or shards
        raw_src: ChunkSource = as_source(x)
        self.scaler = Scaler.fit_stream(raw_src, cfg.chunk_size)
        if isinstance(raw_src, ArraySource):     # in-memory: scale once
            xs_src: ChunkSource = ArraySource(
                self.scaler.transform(raw_src.materialize()))
        else:                                    # out-of-core: scale lazily
            xs_src = ScaledSource(raw_src, self.scaler.mean, self.scaler.std)
        n, d = xs_src.shape

        scenario = "weighted" if cfg.scenario in ("weighted", "npsvm") \
            else cfg.scenario
        self.tasks: TaskSet = make_tasks(y, scenario, taus=cfg.taus,
                                         weights=cfg.weights)

        n_dev = 1
        if self.mesh is not None and self.mesh_axes is not None:
            n_dev = int(np.prod([self.mesh.shape[a] for a in self.mesh_axes]))
        self.plan: CellPlan = build_cells_stream(
            xs_src, cell_size=cfg.cell_size, method=cfg.cell_method,
            seed=cfg.seed, chunk_size=cfg.chunk_size)
        self.packed: PackedCells = pack_cells(self.plan, n_dev)

        k = self.plan.k_max
        n_slots = self.packed.n_slots
        t_count = self.tasks.n_tasks
        cv_cfg = cv_mod.CVConfig(
            solver=cfg.resolve_solver(), kernel=cfg.kernel, n_folds=cfg.n_folds,
            fold_scheme=cfg.fold_scheme, tol=cfg.tol, max_iters=cfg.max_iters,
            taus=cfg.taus, weights=cfg.weights)

        base_grid = grids.liquid_grid(n=k, dim=d, median_dist=1.0,
                                      grid_choice=cfg.grid_choice,
                                      cell_size=cfg.cell_size)
        if cfg.adaptivity_control > 0:
            base_grid = grids.adaptive_subgrid(base_grid, cfg.adaptivity_control)
        n_gamma = len(base_grid.gammas)
        keys_all = np.asarray(
            jax.random.split(jax.random.PRNGKey(cfg.seed), n_slots))

        # the model itself: per-slot SV tables (to_bank() compacts further).
        # stage() fills these as a side effect so the source is read ONCE;
        # slots of checkpoint-restored waves are back-filled afterwards.
        x_cells = np.zeros((n_slots, k, d), np.float32)
        mask_cells = np.zeros((n_slots, k), np.float32)
        staged = np.zeros(n_slots, bool)

        def stage(lo: int, hi: int):
            """Host arrays for slots [lo, hi) ONLY — O(wave) staging.

            Slots past n_slots (wave padding) stay empty: zero masks, unit
            gammas, zero keys — the same shape the planner's -1 slots get.
            """
            w = hi - lo
            x_w = np.zeros((w, k, d), np.float32)
            mask_w = np.zeros((w, k), np.float32)
            y_w = np.zeros((w, t_count, k), np.float32)
            tmask_w = np.zeros((w, t_count, k), np.float32)
            gam_w = np.ones((w, n_gamma), np.float32)
            keys_w = np.zeros((w,) + keys_all.shape[1:], keys_all.dtype)
            keys_w[: max(min(hi, n_slots) - lo, 0)] = keys_all[lo:hi]
            for j, s in enumerate(range(lo, min(hi, n_slots))):
                staged[s] = True
                cid = self.packed.order[s]
                if cid < 0:
                    continue
                ids = self.plan.indices[cid]
                m = self.plan.mask[cid]
                x_w[j] = xs_src.gather(ids)
                x_cells[s], mask_cells[s] = x_w[j], m
                mask_w[j] = m
                y_w[j] = self.tasks.labels[:, ids] * m[None, :]
                tmask_w[j] = self.tasks.task_mask[:, ids] * m[None, :]
                # per-cell adaptive gamma endpoints (paper: grid scaled per cell)
                med = float(kernel_fns.median_heuristic(jnp.asarray(x_w[j]),
                                                        jnp.asarray(m)))
                g = grids.liquid_grid(n=int(m.sum()), dim=d, median_dist=med,
                                      grid_choice=cfg.grid_choice,
                                      cell_size=cfg.cell_size)
                if cfg.adaptivity_control > 0:
                    g = grids.adaptive_subgrid(g, cfg.adaptivity_control)
                gam_w[j] = np.asarray(g.gammas, np.float32)
            return x_w, y_w, tmask_w, mask_w, gam_w, keys_w

        lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(
            base_grid, cv_cfg, t_count)

        coefs, gamma, lam, tau, val = train_cells_waves(
            stage, n_slots, cfg.n_slots_per_wave,
            lam_c, sub_c, task_c, cv_cfg, n_lam, n_sub,
            mesh=self.mesh, axis_names=self.mesh_axes, ckpt_dir=ckpt_dir,
            fingerprint=self._fit_fingerprint(cv_cfg, n, d))

        for s in np.flatnonzero(~staged):   # waves restored from checkpoint
            cid = self.packed.order[s]
            if cid >= 0:
                x_cells[s] = xs_src.gather(self.plan.indices[cid])
                mask_cells[s] = self.plan.mask[cid]

        self.cv_cfg = cv_cfg
        self.x_cells, self.mask_cells = x_cells, mask_cells
        self.coefs = np.asarray(coefs)      # (n_slots, k, T, S)
        self.gamma = np.asarray(gamma)      # (n_slots, T, S)
        self.lam, self.tau = np.asarray(lam), np.asarray(tau)
        self.val_loss = np.asarray(val)
        self._fitted = True

        if cfg.scenario == "npsvm":
            # Neyman-Pearson selection over the weight grid: best detection
            # among weights whose (training-data) false alarm <= alpha —
            # decisions streamed chunk-by-chunk over the train source
            from repro.core.select import np_select_weight
            yv = np.asarray(y, np.float32)
            n_w = len(cfg.weights)
            fa_cnt = np.zeros(n_w, np.int64)
            det_cnt = np.zeros(n_w, np.int64)
            neg_tot = pos_tot = 0
            for lo, chunk in raw_src.iter_chunks(cfg.chunk_size):
                dec = self.decision_function(chunk)      # (m, 1, n_weights)
                yc = yv[lo:lo + chunk.shape[0]]
                neg, pos = yc < 0, yc > 0
                fa_cnt += (dec[neg, 0, :] > 0).sum(0)
                det_cnt += (dec[pos, 0, :] > 0).sum(0)
                neg_tot += int(neg.sum())
                pos_tot += int(pos.sum())
            fa = fa_cnt / max(neg_tot, 1)
            det = det_cnt / max(pos_tot, 1)
            self.np_fa, self.np_det = fa, det
            self.np_weight_idx = int(np_select_weight(
                jnp.asarray(fa), jnp.asarray(det), cfg.np_alpha))
        return self

    def _fit_fingerprint(self, cv_cfg, n: int, d: int) -> str:
        """Identity of this fit for wave-checkpoint resume: config, data
        layout (cell plan) and labels — a stale ckpt_dir from a different
        run must be rejected, not silently restored."""
        import hashlib
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(self.config).encode())
        h.update(repr(cv_cfg).encode())
        h.update(np.int64([n, d]).tobytes())
        h.update(self.plan.indices.tobytes())
        h.update(self.plan.mask.tobytes())
        h.update(self.plan.centers.tobytes())
        h.update(np.ascontiguousarray(self.tasks.labels).tobytes())
        return h.hexdigest()

    # ------------------------------------------------------------- serving
    def to_bank(self, drop_tol: float | None = 0.0, dtype: str = "f32",
                dedup: bool = True):
        """Compact the fitted cell models into a serving ModelBank.

        The bank carries the Voronoi routing centers (empty padding slots
        pushed beyond any real point) and the train-set scaling, so
        ``SVMEngine(model.to_bank())`` serves raw-feature queries with the
        same routing the estimator uses.
        """
        assert self._fitted
        from repro.serve.model_bank import _FAR, ModelBank
        n_slots = self.packed.n_slots
        d = self.x_cells.shape[2]
        centers = np.full((n_slots, d), _FAR, np.float32)
        for s, cid in enumerate(self.packed.order):
            if cid >= 0:
                centers[s] = self.plan.centers[cid]
        return ModelBank.from_cells(
            self.x_cells, self.mask_cells, self.coefs, self.gamma, centers,
            kernel=self.config.kernel, drop_tol=drop_tol, dtype=dtype,
            dedup=dedup,
            feat_mean=self.scaler.mean.astype(np.float32),
            feat_std=self.scaler.std.astype(np.float32),
            classes=self.tasks.classes, pairs=self.tasks.pairs,
            scenario=self.config.scenario)

    # ------------------------------------------------------------- test
    def decision_function(self, x_test: np.ndarray) -> np.ndarray:
        """(m, d) -> (m, T, S) via Voronoi routing to owning cells.

        Pack/scatter is argsort-grouped (``planner.group_rows``) — two
        fancy-indexed assignments, no per-row Python loops.
        """
        assert self._fitted
        xt = self.scaler.transform(np.asarray(x_test, np.float32))
        cell_of = self.plan.route(xt)                       # (m,) cell ids
        slot_of = self.packed.slot_of_cell[cell_of]         # (m,) slots
        n_slots = self.packed.n_slots
        g = group_rows(slot_of, n_slots)
        # bucket the padded row count so repeated chunked calls (npsvm
        # selection, streamed evaluation) hit one compiled shape, and the
        # extra all-zero rows are computed-then-dropped (row-independent)
        m_pad = -(-g.m_max // 8) * 8
        xt_cells = np.zeros((n_slots, m_pad, xt.shape[1]), np.float32)
        xt_cells[g.slot, g.pos] = xt[g.rows]

        dec = np.asarray(predict_cells(
            jnp.asarray(xt_cells), jnp.asarray(self.x_cells),
            jnp.asarray(self.coefs), jnp.asarray(self.gamma),
            kernel=self.config.kernel,
            mesh=self.mesh, axis_names=self.mesh_axes))     # (slots, m_max, T, S)

        out = np.zeros((xt.shape[0],) + dec.shape[2:], np.float32)
        out[g.rows] = dec[g.slot, g.pos]
        return out

    def predict(self, x_test: np.ndarray) -> np.ndarray:
        dec = self.decision_function(x_test)
        sc = self.config.scenario
        sub = self.np_weight_idx if sc == "npsvm" else 0
        return combine_decisions(dec, sc, classes=self.tasks.classes,
                                 pairs=self.tasks.pairs, sub=sub)

    def error(self, x_test: np.ndarray, y_test: np.ndarray) -> float:
        pred = self.predict(x_test)
        sc = self.config.scenario
        if sc in ("binary", "weighted", "npsvm"):
            return float((pred != np.sign(y_test)).mean())
        if sc in ("ova", "ava"):
            return float((pred != y_test).mean())
        if sc == "quantile":
            taus = np.asarray(self.config.taus)
            r = y_test[:, None] - pred
            return float(np.where(r >= 0, taus * r, (taus - 1) * r).mean())
        if sc == "expectile":
            taus = np.asarray(self.config.taus)
            r = y_test[:, None] - pred
            return float(np.where(r >= 0, taus * r * r, (1 - taus) * r * r).mean())
        raise ValueError(sc)
