"""Frozen-backbone sequence embedding with ONE compiled program.

The extractor turns token sequences into fixed-dimension feature rows for
the SVM verticals: the existing ``models.model.backbone`` (any ``configs/``
architecture) runs frozen, the final hidden states are pooled (mean over
time, or the last position) in f32, and the result is an ``(m, d_model)``
float32 host array ready for cells, scaling and serving.

Two things make this serve-grade rather than the old example's ad-hoc
whole-corpus call:

  * **fixed batch shape** — the backbone forward is jit-compiled at ONE
    ``(batch_size, seq_len)`` shape; a ragged tail (or any ``m`` not a
    multiple of ``batch_size``) is zero-padded on the ROW axis, computed,
    and sliced off.  Padded rows never leave the extractor, and the ragged
    shapes that used to trigger a recompile per call now reuse one
    compiled program (``compile_count`` stays at 1 per entry point);
  * **determinism by construction** — for one input block the computation
    is a pure function of ``(config, params, tokens)``.  MoE layers have
    cross-row capacity interactions, so callers that need bitwise-stable
    embeddings for a ROW must always present it inside the same batch —
    :class:`repro.embed.source.EmbeddingSource` aligns its compute blocks
    to absolute corpus offsets for exactly this reason.

Instrumented with ``embed.forward`` / ``embed.pool`` tracer sites and an
``embed.sequences`` counter (the process-global ``repro.obs`` instruments,
injectable for tests, following ``SVMEngine``).
"""
from __future__ import annotations

import hashlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.models.model import ModelConfig

POOLINGS = ("mean", "last")


def resolve_arch(arch: str) -> ModelConfig:
    """``"<arch-id>"`` -> full config, ``"<arch-id>:smoke"`` -> smoke config.

    The smoke variant is the right tool for tests, CI and synthetic-corpus
    demos; the full config is the production embedding backbone.
    """
    from repro.configs import get_arch
    name, _, variant = arch.partition(":")
    spec = get_arch(name)
    if variant in ("", "full"):
        return spec.config
    if variant == "smoke":
        return spec.smoke
    raise ValueError(f"unknown arch variant {variant!r} in {arch!r} "
                     f"(use '<id>' or '<id>:smoke')")


def params_digest(params) -> str:
    """Content hash of a parameter tree: blake2b over sorted (path, bytes)
    leaves.  Two trees with identical values share a digest regardless of
    dict insertion order; any weight change moves it."""
    leaves = jax.tree_util.tree_flatten_with_path(params)[0]
    items = sorted((jax.tree_util.keystr(path), leaf)
                   for path, leaf in leaves)
    h = hashlib.blake2b(digest_size=16)
    for path, leaf in items:
        h.update(path.encode())
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


class EmbeddingExtractor:
    """Pooled backbone embeddings at one fixed ``(batch_size, seq_len)``.

    ``__call__(tokens)`` accepts ``(m, seq_len)`` int tokens (or
    ``(m, seq_len, d_frontend)`` float rows for embed-frontend configs) for
    ANY ``m`` and returns ``(m, d_model)`` float32 — internally the rows
    are processed in fixed-shape blocks with a zero-padded tail, so every
    call after the first reuses the same two compiled programs (forward,
    pool).  ``params=None`` initializes a deterministic frozen backbone
    from ``seed`` (the random-features regime the examples use).
    """

    def __init__(self, cfg: ModelConfig, params=None, *,
                 pooling: str = "mean", batch_size: int = 32, seed: int = 0,
                 tracer: Optional["obs.Tracer"] = None,
                 metrics: Optional["obs.MetricsRegistry"] = None):
        if pooling not in POOLINGS:
            raise ValueError(f"pooling must be one of {POOLINGS}, "
                             f"got {pooling!r}")
        if batch_size < 1:
            raise ValueError(f"batch_size must be >= 1, got {batch_size}")
        self.cfg = cfg
        self.pooling = pooling
        self.batch_size = int(batch_size)
        self.seed = int(seed)
        if params is None:
            params = init_params(model_mod.build_template(cfg),
                                 jax.random.PRNGKey(seed))
        self.params = params
        self._digest: Optional[str] = None
        self._tracer = obs.tracer if tracer is None else tracer
        self._metrics = obs.metrics if metrics is None else metrics
        self._m_sequences = self._metrics.counter("embed.sequences")
        # trace-time counters: the bodies run only when jit (re)traces, so
        # a value that stays at 1 across ragged calls IS the one-compile
        # guarantee (asserted by tests/test_embed.py)
        self.compile_count = 0
        self._pool_compiles = 0
        self._fwd = jax.jit(self._forward)
        self._pool = jax.jit(self._pool_fn)

    # ----------------------------------------------------------- identity
    @property
    def dim(self) -> int:
        return self.cfg.d_model

    def digest(self) -> str:
        """Cached content hash of the frozen parameters."""
        if self._digest is None:
            self._digest = params_digest(self.params)
        return self._digest

    def fingerprint(self, seq_len: int) -> str:
        """Cache identity of embeddings this extractor produces over
        ``seq_len``-token sequences: (arch config, params digest, pooling,
        seq_len).  Anything that could change a single output bit moves
        the fingerprint; batch size does NOT participate — block-aligned
        callers pin it separately (see ``EmbedCache``)."""
        h = hashlib.blake2b(digest_size=16)
        h.update(repr(self.cfg).encode())
        h.update(self.digest().encode())
        h.update(self.pooling.encode())
        h.update(np.int64(seq_len).tobytes())
        return h.hexdigest()

    # ------------------------------------------------------------ forward
    def _forward(self, x):
        self.compile_count += 1          # runs at trace time only
        b, t = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32)[None],
                                     (b, t))
        h, _, _ = model_mod.backbone(self.cfg, self.params, x, positions)
        return h

    def _pool_fn(self, h):
        self._pool_compiles += 1         # runs at trace time only
        h32 = h.astype(jnp.float32)
        if self.pooling == "mean":
            return jnp.mean(h32, axis=1)
        return h32[:, -1]

    def _block(self, x: np.ndarray) -> np.ndarray:
        """One fixed-shape block: pad rows to ``batch_size``, run, slice."""
        m = x.shape[0]
        b = self.batch_size
        if m < b:
            pad = np.zeros((b - m,) + x.shape[1:], x.dtype)
            x = np.concatenate([x, pad])
        with self._tracer.span("embed.forward"):
            h = self._fwd(jnp.asarray(x))
        with self._tracer.span("embed.pool"):
            emb = np.asarray(self._pool(h))
        return emb[:m]

    def __call__(self, tokens) -> np.ndarray:
        """(m, seq_len[, d_frontend]) -> (m, d_model) f32, any ``m``."""
        x = np.asarray(tokens)
        if self.cfg.input_kind == "tokens":
            x = x.astype(np.int32, copy=False)
            assert x.ndim == 2, x.shape
        else:
            x = x.astype(np.float32, copy=False)
            assert x.ndim == 3, x.shape
        if x.shape[0] == 0:
            return np.zeros((0, self.dim), np.float32)
        out = np.concatenate(
            [self._block(x[lo:lo + self.batch_size])
             for lo in range(0, x.shape[0], self.batch_size)])
        self._m_sequences.inc(x.shape[0])
        return np.ascontiguousarray(out, np.float32)
