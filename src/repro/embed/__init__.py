"""repro.embed — frozen-backbone embedding pipeline.

The subsystem that connects the LM model stack (``models/``, ``configs/``)
to the SVM verticals: a jit-compiled fixed-batch
:class:`~repro.embed.extractor.EmbeddingExtractor` pools backbone hidden
states into feature rows, :class:`~repro.embed.source.EmbeddingSource`
exposes a token corpus behind the ChunkSource contract (lazy, block-aligned
for bitwise chunk-size invariance, write-through
:class:`~repro.embed.source.EmbedCache` with npz-shard replay), and
:func:`embed_source` is the one-call front door the session/scenario layers
and ``EMBED_*`` config keys use.
"""
from __future__ import annotations

import os
from typing import Optional, Union

from repro.embed.extractor import (POOLINGS, EmbeddingExtractor,
                                   params_digest, resolve_arch)
from repro.embed.source import (EmbedCache, EmbedCacheError, EmbeddingSource,
                                LabeledSource, TokenArraySource)

__all__ = [
    "POOLINGS", "EmbeddingExtractor", "params_digest", "resolve_arch",
    "EmbedCache", "EmbedCacheError", "EmbeddingSource", "LabeledSource",
    "TokenArraySource", "embed_source",
]


def embed_source(tokens, *, arch: str, pooling: str = "mean",
                 cache_dir: Union[str, os.PathLike, None] = None,
                 batch_size: int = 32, params=None, seed: int = 0,
                 labels=None, tracer=None, metrics=None) -> EmbeddingSource:
    """Wrap a token corpus as a lazily-embedded ChunkSource.

    ``arch`` is ``"<arch-id>"`` or ``"<arch-id>:smoke"`` from
    ``repro.configs.ARCH_IDS``; ``params=None`` uses the deterministic
    seed-initialized frozen backbone.  ``cache_dir`` (the ``EMBED_CACHE``
    key) is a multi-identity cache root — shards land under
    ``cache_dir/<fingerprint-prefix>/``.  The result drops into any x slot
    (``SVM(x=...)``, scenario front-ends, ``build_cells_stream``); pass
    ``labels=`` to carry the y pairing through the token->embedding hop.
    """
    cfg = resolve_arch(arch)
    extractor = EmbeddingExtractor(cfg, params, pooling=pooling,
                                   batch_size=batch_size, seed=seed,
                                   tracer=tracer, metrics=metrics)
    return EmbeddingSource(tokens, extractor, cache=cache_dir, labels=labels)
