"""Lazy embedding sources: tokenized corpora behind the ChunkSource contract.

:class:`EmbeddingSource` embeds a token corpus chunk-by-chunk through an
:class:`repro.embed.extractor.EmbeddingExtractor`, honoring the exact
``iter_chunks``/``gather`` contract of :mod:`repro.pipeline.dataset` — so
``Scaler.fit_stream``, ``build_cells_stream`` and wave training run over
tokenized corpora unchanged, and the full corpus embedding matrix never has
to exist in host memory.

**Bitwise invariance.**  The contract demands per-row results independent
of which chunk a row landed in, and the streaming cell builders' parity
claims demand bit-identical rows for every chunk size and gather pattern.
MoE backbones make that non-trivial: expert capacity couples rows within a
batch, so "embed whatever rows the caller asked for" would produce
composition-dependent bits.  The source therefore computes embeddings ONLY
in blocks aligned to absolute corpus offsets (block ``j`` covers rows
``[j*B, (j+1)*B)``, ``B`` = the extractor's fixed batch size); both access
paths read through the same blocks, so row ``i``'s embedding is a pure
function of the corpus — never of the query that requested it.

**Write-through cache.**  ``EmbedCache`` persists computed blocks as npz
shards keyed by the extractor's (arch, params-digest, pooling, seq_len)
fingerprint, with crash-safe tmp+rename writes in the
``train/checkpoint.py`` idiom.  Once every shard exists the source replays
through :class:`repro.pipeline.dataset.ShardedNpzSource` — a second epoch
is I/O-bound, the backbone never runs again, and the replayed bits are
identical to the cold path (npz round-trips floats exactly).

**Label pairing.**  :class:`LabeledSource` pairs any x backend with a
streaming label backend (array / ``.npy`` memmap / npz shards), so labeled
shards stream per wave instead of requiring the caller to assemble one
host ``y`` array; ``EmbeddingSource`` accepts the same ``labels=`` backend
and preserves the pairing across the token->embedding hop.
"""
from __future__ import annotations

import json
import os
import tempfile
from collections import OrderedDict
from typing import Iterator, Optional, Sequence, Tuple, Union

import numpy as np

from repro.embed.extractor import EmbeddingExtractor
from repro.pipeline.dataset import (DEFAULT_CHUNK, ChunkSource,
                                    DataSourceError, ShardedNpzSource,
                                    as_source)

_META = "meta.json"
_CACHE_FORMAT = "repro.embed.cache.v1"

# computed blocks memoized in memory (cold path); small: the contract's
# access patterns (sequential chunks, spatially local gathers) rarely
# touch more than adjacent blocks
_LRU_BLOCKS = 4


class EmbedCacheError(RuntimeError):
    """The cache directory exists but belongs to a different embedding
    identity (fingerprint mismatch) or is structurally invalid."""


def _atomic_write_bytes(path: str, data: bytes) -> None:
    """tmp + fsync + rename in the checkpoint idiom: readers only ever see
    complete files, a crash leaves at most a ``*.tmp.*`` straggler."""
    d = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(path) + ".tmp.")
    try:
        with os.fdopen(fd, "wb") as f:
            f.write(data)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise
    dfd = os.open(d, os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)


class EmbedCache:
    """Persistent block cache for one embedding identity.

    Layout: ``path/meta.json`` plus one ``shard_<j>.npz`` (member ``"x"``)
    per extractor block — shard boundaries ARE block boundaries, so a cache
    written under one fingerprint replays bit-identically regardless of the
    chunk sizes that populated it.  ``meta.json`` records the fingerprint
    and geometry; opening an existing directory under a different
    fingerprint raises :class:`EmbedCacheError` (mixing embeddings from two
    backbones is data corruption, not a cache miss).

    ``EmbedCache.at(root, ...)`` nests the cache under
    ``root/<fingerprint-prefix>/`` — the multi-identity layout the
    ``EMBED_CACHE`` config key points at; the CLI's ``embed`` stage uses a
    flat directory so ``<model-dir>/embed`` is itself the stage artifact.
    """

    def __init__(self, path: Union[str, os.PathLike], fingerprint: str,
                 n_rows: int, dim: int, block: int, seq_len: int,
                 extra: Optional[dict] = None):
        self.path = os.fspath(path)
        self.fingerprint = fingerprint
        self.n_rows = int(n_rows)
        self.dim = int(dim)
        self.block = int(block)
        self.n_blocks = -(-self.n_rows // self.block)
        os.makedirs(self.path, exist_ok=True)
        meta_path = os.path.join(self.path, _META)
        meta = {"format": _CACHE_FORMAT, "fingerprint": fingerprint,
                "n_rows": self.n_rows, "dim": self.dim, "block": self.block,
                "seq_len": int(seq_len), **(extra or {})}
        if os.path.exists(meta_path):
            try:
                with open(meta_path) as f:
                    have = json.load(f)
            except ValueError as e:
                raise EmbedCacheError(
                    f"{meta_path}: unreadable cache metadata ({e})") from e
            for k in ("format", "fingerprint", "n_rows", "dim", "block"):
                if have.get(k) != meta[k]:
                    raise EmbedCacheError(
                        f"{self.path}: cache belongs to a different "
                        f"embedding identity ({k}: {have.get(k)!r} != "
                        f"{meta[k]!r}) — delete the directory or point "
                        f"EMBED_CACHE elsewhere")
            self.meta = have
        else:
            _atomic_write_bytes(meta_path,
                                json.dumps(meta, indent=2).encode())
            self.meta = meta

    @classmethod
    def at(cls, root: Union[str, os.PathLike], fingerprint: str,
           **kw) -> "EmbedCache":
        """The ``root/<fp12>`` layout: one root, many identities."""
        return cls(os.path.join(os.fspath(root), fingerprint[:12]),
                   fingerprint, **kw)

    @classmethod
    def open(cls, path: Union[str, os.PathLike]) -> dict:
        """Read an existing cache's metadata (no validation beyond JSON).
        The CLI uses this to rebuild an extractor from a stage artifact."""
        meta_path = os.path.join(os.fspath(path), _META)
        if not os.path.exists(meta_path):
            raise EmbedCacheError(f"{path}: not an embed cache "
                                  f"(no {_META})")
        with open(meta_path) as f:
            meta = json.load(f)
        if meta.get("format") != _CACHE_FORMAT:
            raise EmbedCacheError(f"{path}: not an embed cache "
                                  f"(format={meta.get('format')!r})")
        return meta

    # ------------------------------------------------------------- blocks
    def _shard_path(self, j: int) -> str:
        return os.path.join(self.path, f"shard_{j:05d}.npz")

    def shard_paths(self) -> Tuple[str, ...]:
        return tuple(self._shard_path(j) for j in range(self.n_blocks))

    def has(self, j: int) -> bool:
        return os.path.exists(self._shard_path(j))

    def complete(self) -> bool:
        return all(self.has(j) for j in range(self.n_blocks))

    def put(self, j: int, emb: np.ndarray) -> None:
        """Write-through one block, crash-safe (tmp+rename): a reader never
        sees a torn shard, a crash mid-put leaves the block absent."""
        lo = j * self.block
        want = min(self.block, self.n_rows - lo)
        assert emb.shape == (want, self.dim), (emb.shape, want, self.dim)
        import io
        buf = io.BytesIO()
        np.savez(buf, x=np.ascontiguousarray(emb, np.float32))
        _atomic_write_bytes(self._shard_path(j), buf.getvalue())

    def get(self, j: int) -> Optional[np.ndarray]:
        p = self._shard_path(j)
        if not os.path.exists(p):
            return None
        lo = j * self.block
        want = min(self.block, self.n_rows - lo)
        try:
            with np.load(p) as z:
                emb = np.asarray(z["x"], np.float32)
        except Exception as e:     # torn/corrupt shard: recompute, don't die
            raise DataSourceError(
                f"{p}: corrupt embed-cache shard covering rows "
                f"[{lo}, {lo + want}) ({e}) — delete it to re-embed") from e
        if emb.shape != (want, self.dim):
            raise DataSourceError(
                f"{p}: embed-cache shard holds {emb.shape} but rows "
                f"[{lo}, {lo + want}) need ({want}, {self.dim})")
        return emb


# --------------------------------------------------------------- token side
class TokenArraySource:
    """Minimal token backend: an (n, seq_len[, d_frontend]) array or an
    on-disk ``.npy`` opened as a memmap.  Rows are sequences, not features —
    this is deliberately NOT a ChunkSource (no float32 coercion, no dim)."""

    def __init__(self, tokens):
        if isinstance(tokens, (str, os.PathLike)):
            try:
                tokens = np.load(os.fspath(tokens), mmap_mode="r")
            except (OSError, ValueError) as e:
                raise DataSourceError(
                    f"{os.fspath(tokens)}: cannot memmap token .npy ({e})"
                ) from e
        self._tok = tokens
        assert self._tok.ndim in (2, 3), self._tok.shape

    @property
    def n_rows(self) -> int:
        return self._tok.shape[0]

    @property
    def seq_len(self) -> int:
        return self._tok.shape[1]

    def rows(self, lo: int, hi: int) -> np.ndarray:
        return np.asarray(self._tok[lo:hi])


def _label_backend(y):
    """Coerce a label spec into a lazily-readable (n,) view.

    Accepts an array, a ``.npy`` path (memmapped) or a sequence of ``.npz``
    shard paths holding member ``"y"`` — mirroring what ``--data`` accepts
    for x, so labeled shard exports stream without a host copy.
    """
    if isinstance(y, (str, os.PathLike)):
        try:
            return np.load(os.fspath(y), mmap_mode="r")
        except (OSError, ValueError) as e:
            raise DataSourceError(
                f"{os.fspath(y)}: cannot memmap label .npy ({e})") from e
    if isinstance(y, (list, tuple)):
        return _ShardedLabels(y)
    return np.asarray(y)


class _ShardedLabels:
    """Ordered npz label shards (member ``"y"``), one resident at a time."""

    def __init__(self, paths: Sequence[Union[str, os.PathLike]]):
        src = ShardedNpzSource([os.fspath(p) for p in paths], key="y") \
            if _is_2d_label_shards(paths) else None
        self._paths = [os.fspath(p) for p in paths]
        self._src = src
        if src is None:
            # 1-D shards: track boundaries ourselves
            sizes = []
            for p in self._paths:
                with np.load(p) as z:
                    if "y" not in z:
                        raise DataSourceError(
                            f"{p}: npz shard has no member 'y'")
                    sizes.append(int(np.asarray(z["y"]).shape[0]))
            self._starts = np.concatenate(
                [[0], np.cumsum(sizes)]).astype(np.int64)
            self._cache: Optional[Tuple[int, np.ndarray]] = None

    @property
    def shape(self):
        if self._src is not None:
            return (self._src.n_rows,)
        return (int(self._starts[-1]),)

    def _load(self, i: int) -> np.ndarray:
        if self._cache is not None and self._cache[0] == i:
            return self._cache[1]
        with np.load(self._paths[i]) as z:
            y = np.asarray(z["y"]).reshape(-1)
        self._cache = (i, y)
        return y

    def __getitem__(self, idx):
        if self._src is not None:
            flat = self._src.gather(np.atleast_1d(
                np.arange(self._src.n_rows)[idx]))
            return flat[:, 0]
        if isinstance(idx, slice):
            ids = np.arange(*idx.indices(self.shape[0]), dtype=np.int64)
        else:
            ids = np.atleast_1d(np.asarray(idx, np.int64))
        out = np.empty(ids.shape[0], self._load(0).dtype
                       if self._paths else np.float32)
        shard_of = np.searchsorted(self._starts, ids, side="right") - 1
        for i in np.unique(shard_of):
            sel = shard_of == i
            out[sel] = self._load(int(i))[ids[sel] - self._starts[i]]
        return out


def _is_2d_label_shards(paths) -> bool:
    try:
        with np.load(os.fspath(paths[0])) as z:
            return "y" in z and np.asarray(z["y"]).ndim == 2
    except Exception:
        return False


class LabeledSource(ChunkSource):
    """An x ChunkSource paired with a streaming label backend.

    Delegates the full ChunkSource contract to ``x`` (anything
    ``as_source`` accepts) and adds the y side: ``gather_labels(ids)``
    mirrors ``gather``, ``iter_labeled_chunks`` yields aligned
    ``(start, x_chunk, y_chunk)`` triples, and ``labels_vector()``
    assembles the (n,) float32 label vector by streaming — O(n) scalars,
    never a caller-held host array per shard.  ``SVM(x, y=None)`` accepts
    any source exposing this API.
    """

    def __init__(self, x, y):
        self._x = as_source(x)
        self._y = _label_backend(y)
        n = self._y.shape[0]
        if n != self._x.n_rows:
            raise DataSourceError(
                f"labeled source row mismatch: {self._x.n_rows} x rows vs "
                f"{n} labels")

    @property
    def n_rows(self) -> int:
        return self._x.n_rows

    @property
    def dim(self) -> int:
        return self._x.dim

    def iter_chunks(self, chunk_size: int = DEFAULT_CHUNK):
        return self._x.iter_chunks(chunk_size)

    def gather(self, ids: np.ndarray) -> np.ndarray:
        return self._x.gather(ids)

    # ------------------------------------------------------------- labels
    def gather_labels(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        return np.asarray(self._y[ids], np.float32).reshape(-1)

    def iter_labeled_chunks(self, chunk_size: int = DEFAULT_CHUNK
                            ) -> Iterator[Tuple[int, np.ndarray, np.ndarray]]:
        for lo, chunk in self.iter_chunks(chunk_size):
            hi = lo + chunk.shape[0]
            yield lo, chunk, np.asarray(self._y[lo:hi],
                                        np.float32).reshape(-1)

    def labels_vector(self, chunk_size: int = DEFAULT_CHUNK) -> np.ndarray:
        """The (n,) label vector, assembled chunk-by-chunk (each label
        shard is resident once) — the one O(n)-scalar array wave training
        needs for task construction."""
        out = np.empty(self.n_rows, np.float32)
        for lo in range(0, self.n_rows, chunk_size):
            hi = min(lo + chunk_size, self.n_rows)
            out[lo:hi] = np.asarray(self._y[lo:hi], np.float32).reshape(-1)
        return out


# ---------------------------------------------------------- embedding source
class EmbeddingSource(ChunkSource):
    """Lazily-embedded token corpus behind the ChunkSource contract.

    ``tokens`` is an (n, seq_len) int array / ``.npy`` path (or
    ``(n, seq_len, d_frontend)`` floats for embed-frontend configs);
    ``extractor`` a fixed-batch :class:`EmbeddingExtractor`.  Embeddings
    are computed per block aligned to absolute corpus offsets (see module
    docstring), memoized in a small LRU, and written through ``cache``
    when given.  When the cache is (or becomes) complete, iteration and
    gathers replay through :class:`ShardedNpzSource` — I/O-bound, bitwise
    identical to the cold path.

    ``cache`` may be an :class:`EmbedCache`, a directory path (the cache is
    created there under the extractor's fingerprint, the ``EMBED_CACHE``
    layout), or ``None``.  ``labels`` adds the :class:`LabeledSource` API
    on top, preserved across the token->embedding hop.
    """

    def __init__(self, tokens, extractor: EmbeddingExtractor,
                 cache: Union[EmbedCache, str, os.PathLike, None] = None,
                 labels=None):
        self._tok = tokens if isinstance(tokens, TokenArraySource) \
            else TokenArraySource(tokens)
        self.extractor = extractor
        b = extractor.batch_size
        if isinstance(cache, (str, os.PathLike)):
            cache = EmbedCache.at(
                cache, extractor.fingerprint(self._tok.seq_len),
                n_rows=self._tok.n_rows, dim=extractor.dim, block=b,
                seq_len=self._tok.seq_len)
        if cache is not None:
            if (cache.n_rows, cache.dim, cache.block) != \
                    (self._tok.n_rows, extractor.dim, b):
                raise EmbedCacheError(
                    f"{cache.path}: cache geometry "
                    f"({cache.n_rows}, {cache.dim}, block {cache.block}) "
                    f"does not match this corpus/extractor "
                    f"({self._tok.n_rows}, {extractor.dim}, block {b})")
            want_fp = extractor.fingerprint(self._tok.seq_len)
            if cache.fingerprint != want_fp:
                raise EmbedCacheError(
                    f"{cache.path}: cache fingerprint "
                    f"{cache.fingerprint[:12]} does not match this "
                    f"extractor ({want_fp[:12]})")
        self.cache = cache
        self._lru: "OrderedDict[int, np.ndarray]" = OrderedDict()
        self._replay: Optional[ShardedNpzSource] = None
        self._maybe_seal()

        self._y = None
        if labels is not None:
            self._y = _label_backend(labels)
            if self._y.shape[0] != self._tok.n_rows:
                raise DataSourceError(
                    f"labeled source row mismatch: {self._tok.n_rows} "
                    f"sequences vs {self._y.shape[0]} labels")

    # ------------------------------------------------------------ geometry
    @property
    def n_rows(self) -> int:
        return self._tok.n_rows

    @property
    def dim(self) -> int:
        return self.extractor.dim

    @property
    def block(self) -> int:
        return self.extractor.batch_size

    @property
    def n_blocks(self) -> int:
        return -(-self.n_rows // self.block)

    def cache_complete(self) -> bool:
        return self._replay is not None

    def _maybe_seal(self) -> None:
        """Flip to npz replay once every block shard exists — mid-run, so
        the second pass of one training job is already I/O-bound."""
        if self._replay is None and self.cache is not None \
                and self.cache.complete():
            self._replay = ShardedNpzSource(self.cache.shard_paths())

    # -------------------------------------------------------------- blocks
    def _block_arr(self, j: int) -> np.ndarray:
        hit = self._lru.get(j)
        if hit is not None:
            self._lru.move_to_end(j)
            return hit
        emb = self.cache.get(j) if self.cache is not None else None
        if emb is None:
            lo = j * self.block
            hi = min(lo + self.block, self.n_rows)
            emb = self.extractor(self._tok.rows(lo, hi))
            if self.cache is not None:
                self.cache.put(j, emb)
                self._maybe_seal()
        self._lru[j] = emb
        while len(self._lru) > _LRU_BLOCKS:
            self._lru.popitem(last=False)
        return emb

    def _rows(self, lo: int, hi: int) -> np.ndarray:
        """Rows [lo, hi) assembled from aligned blocks."""
        b = self.block
        pieces = []
        for j in range(lo // b, (hi - 1) // b + 1):
            blk = self._block_arr(j)
            s = max(lo - j * b, 0)
            e = min(hi - j * b, blk.shape[0])
            pieces.append(blk[s:e])
        return pieces[0] if len(pieces) == 1 else np.concatenate(pieces)

    # ------------------------------------------------------------ contract
    def iter_chunks(self, chunk_size: int = DEFAULT_CHUNK):
        if self._replay is not None:
            yield from self._replay.iter_chunks(chunk_size)
            return
        for lo in range(0, self.n_rows, chunk_size):
            hi = min(lo + chunk_size, self.n_rows)
            yield lo, self._rows(lo, hi)
            if self._replay is not None:     # sealed mid-pass: finish hot
                yield from self._replay_from(hi, chunk_size)
                return

    def _replay_from(self, start: int, chunk_size: int):
        for lo in range(start, self.n_rows, chunk_size):
            ids = np.arange(lo, min(lo + chunk_size, self.n_rows),
                            dtype=np.int64)
            yield lo, self._replay.gather(ids)

    def gather(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, np.int64)
        if self._replay is not None:
            return self._replay.gather(ids)
        out = np.empty((ids.shape[0], self.dim), np.float32)
        block_of = ids // self.block
        for j in np.unique(block_of):
            sel = block_of == j
            out[sel] = self._block_arr(int(j))[ids[sel] - j * self.block]
        return out

    # -------------------------------------------------------------- labels
    def gather_labels(self, ids: np.ndarray) -> np.ndarray:
        self._need_labels()
        ids = np.asarray(ids, np.int64)
        return np.asarray(self._y[ids], np.float32).reshape(-1)

    def iter_labeled_chunks(self, chunk_size: int = DEFAULT_CHUNK):
        self._need_labels()
        for lo, chunk in self.iter_chunks(chunk_size):
            hi = lo + chunk.shape[0]
            yield lo, chunk, np.asarray(self._y[lo:hi],
                                        np.float32).reshape(-1)

    def labels_vector(self, chunk_size: int = DEFAULT_CHUNK) -> np.ndarray:
        self._need_labels()
        out = np.empty(self.n_rows, np.float32)
        for lo in range(0, self.n_rows, chunk_size):
            hi = min(lo + chunk_size, self.n_rows)
            out[lo:hi] = np.asarray(self._y[lo:hi], np.float32).reshape(-1)
        return out

    def _need_labels(self) -> None:
        if self._y is None:
            raise DataSourceError(
                "this EmbeddingSource carries no labels — construct it "
                "with labels=... to use the LabeledSource API")
