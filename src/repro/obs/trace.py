"""Monotonic-clock spans: the timing half of the observability layer.

Production code marks its timed sections the way it marks fault points
(``repro.testing.faults``): a named site, fired through one process-global
object, a no-op unless something turned it on.

    from repro import obs

    with obs.tracer.span("serve.pack"):
        plan = plan_wave(...)

Design constraints (these are serve-hot-path sites):

  * **near-zero overhead disabled** — ``Tracer.span`` on a disabled tracer
    is one attribute test and returns a shared singleton
    (:data:`NULL_SPAN`); no object, no dict, no clock read is allocated.
    Code that already holds wall-clock timestamps (the engine times its
    stages unconditionally for ``wave_stats``) uses :meth:`Tracer.record`
    instead, which is a no-op ``if not enabled`` — the clock is read once,
    by the caller, whichever path runs;
  * **nesting** — live spans carry a depth (0 = root) maintained by the
    tracer, so an exported trace reconstructs the call tree without ids;
  * **bounded** — completed spans land in a :class:`RingBuffer`; a
    long-running serve loop cannot grow memory by being observed
    (``dropped`` counts what the ring evicted).

Known sites (grep ``tracer.span\\|tracer.record`` for the authoritative
list): ``serve.route`` ``serve.pack`` ``serve.dispatch`` ``serve.device``
``serve.collect`` ``train.wave.stage`` ``train.wave.solve``
``train.wave.restore`` ``train.wave.checkpoint`` ``select.resolve``
``checkpoint.save`` ``checkpoint.restore``.
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

TRACE_SCHEMA = "repro.obs.trace.v1"


class RingBuffer:
    """Fixed-capacity append-only view of the most recent items.

    Drop-in for the unbounded lists the engine used to keep
    (``wave_stats``): supports ``append``, ``len``, iteration (oldest ->
    newest), indexing (``[-1]`` = newest) and ``clear``.  ``total`` counts
    every append ever made, ``dropped`` how many the ring evicted — callers
    that need EXACT aggregates over the full history keep running sums and
    use the ring only for the recent-window detail.
    """

    __slots__ = ("_cap", "_buf", "_start", "total")

    def __init__(self, capacity: int):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self._cap = int(capacity)
        self._buf: List[Any] = []
        self._start = 0          # index of the oldest element in _buf
        self.total = 0

    @property
    def capacity(self) -> int:
        return self._cap

    @property
    def dropped(self) -> int:
        return self.total - len(self._buf)

    def append(self, item: Any) -> None:
        self.total += 1
        if len(self._buf) < self._cap:
            self._buf.append(item)
        else:
            self._buf[self._start] = item
            self._start = (self._start + 1) % self._cap

    def clear(self) -> None:
        self._buf.clear()
        self._start = 0
        self.total = 0

    def __len__(self) -> int:
        return len(self._buf)

    def __iter__(self) -> Iterator[Any]:
        n = len(self._buf)
        for i in range(n):
            yield self._buf[(self._start + i) % n]

    def __getitem__(self, idx):
        n = len(self._buf)
        if isinstance(idx, slice):
            return list(self)[idx]
        if not -n <= idx < n:
            raise IndexError(idx)
        return self._buf[(self._start + (idx % n)) % n]

    def __repr__(self) -> str:
        return (f"RingBuffer(cap={self._cap}, len={len(self._buf)}, "
                f"total={self.total})")


class _NullSpan:
    """The disabled-tracer span: one shared instance, does nothing."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **attrs: Any) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class Span:
    """One completed timed section.  ``dur_s`` is monotonic-clock seconds;
    ``depth`` 0 is a root span (nesting recorded at entry time)."""

    __slots__ = ("name", "t0", "t1", "depth", "attrs")

    def __init__(self, name: str, t0: float, t1: float, depth: int = 0,
                 attrs: Optional[Dict[str, Any]] = None):
        self.name = name
        self.t0 = t0
        self.t1 = t1
        self.depth = depth
        self.attrs = attrs

    @property
    def dur_s(self) -> float:
        return self.t1 - self.t0

    def to_json(self) -> Dict[str, Any]:
        d = {"name": self.name, "t0": self.t0, "t1": self.t1,
             "dur_s": self.dur_s, "depth": self.depth}
        if self.attrs:
            d["attrs"] = self.attrs
        return d

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, {self.dur_s * 1e3:.3f}ms, "
                f"depth={self.depth})")


class _LiveSpan:
    """Context manager for an enabled tracer; records itself on exit."""

    __slots__ = ("_tracer", "name", "t0", "attrs")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self.name = name
        self.attrs: Optional[Dict[str, Any]] = None
        self.t0 = 0.0

    def set(self, **attrs: Any) -> "_LiveSpan":
        if self.attrs is None:
            self.attrs = attrs
        else:
            self.attrs.update(attrs)
        return self

    def __enter__(self) -> "_LiveSpan":
        self._tracer._depth += 1
        self.t0 = self._tracer._clock()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = self._tracer._clock()
        tr = self._tracer
        tr._depth -= 1
        tr._emit(Span(self.name, self.t0, t1, tr._depth, self.attrs))
        return False


class Tracer:
    """Span collector with a per-site summary and a bounded span ring.

    ``enabled`` is plain attribute assignment — flip it at runtime (the
    CLI's ``TRACE=1`` key does).  ``clock`` is injectable for deterministic
    tests; it must be monotonic.
    """

    def __init__(self, enabled: bool = False,
                 clock: Callable[[], float] = time.perf_counter,
                 capacity: int = 65536):
        self.enabled = bool(enabled)
        self._clock = clock
        self.spans = RingBuffer(capacity)
        self._depth = 0
        # per-site running aggregates — exact even after the ring wraps
        self._agg: Dict[str, List[float]] = {}   # name -> [count, total, max]

    # ------------------------------------------------------------ recording
    def span(self, name: str):
        """Timed context manager for ``name``; :data:`NULL_SPAN` when
        disabled (no allocation).  Attach attributes inside the body with
        ``sp.set(key=value)`` — a no-op on the null span."""
        if not self.enabled:
            return NULL_SPAN
        return _LiveSpan(self, name)

    def record(self, name: str, t0: float, t1: float) -> None:
        """Record an already-measured interval (caller read the clock).

        The engine's hot path times its stages unconditionally for
        ``wave_stats``; this hands the same two timestamps to the tracer
        without a second clock read — and costs one attribute test when
        the tracer is off.
        """
        if not self.enabled:
            return
        self._emit(Span(name, t0, t1, self._depth, None))

    def _emit(self, span: Span) -> None:
        self.spans.append(span)
        agg = self._agg.get(span.name)
        d = span.dur_s
        if agg is None:
            self._agg[span.name] = [1, d, d]
        else:
            agg[0] += 1
            agg[1] += d
            if d > agg[2]:
                agg[2] = d

    # ------------------------------------------------------------- reading
    def summary(self) -> Dict[str, Dict[str, float]]:
        """Per-site ``{count, total_s, mean_s, max_s}`` over every span
        ever recorded (exact; not limited to the ring window)."""
        return {name: {"count": int(c), "total_s": tot,
                       "mean_s": tot / c, "max_s": mx}
                for name, (c, tot, mx) in sorted(self._agg.items())}

    def clear(self) -> None:
        self.spans.clear()
        self._agg.clear()
        self._depth = 0

    # ------------------------------------------------------------ exporting
    def write_jsonl(self, path: str) -> int:
        """Dump the retained span window as JSONL (header line first);
        returns the number of span lines written.  The format is pinned as
        ``repro.obs.trace.v1`` and checked by :func:`validate_trace_jsonl`
        (the tier-1 smoke runs it against the CLI's ``TRACE_OUT``)."""
        n = 0
        with open(path, "w") as f:
            f.write(json.dumps({
                "schema": TRACE_SCHEMA, "unix_time": time.time(),
                "spans_total": self.spans.total,
                "spans_dropped": self.spans.dropped,
                "summary": self.summary()}) + "\n")
            for s in self.spans:
                f.write(json.dumps(s.to_json()) + "\n")
                n += 1
        return n


_SUMMARY_FIELDS = ("count", "total_s", "mean_s", "max_s")


def validate_trace_jsonl(path: str) -> List[str]:
    """Check a trace JSONL file against the ``repro.obs.trace.v1`` schema.

    The metrics validator's twin (``obs.metrics.validate_jsonl``): returns
    a list of human-readable errors, empty when valid.  Pinned facts:

      line 1:  {"schema": "repro.obs.trace.v1", "unix_time": number,
                "spans_total": int >= "spans_dropped": int >= 0,
                "summary": {site: {count, total_s, mean_s, max_s}}}
      span:    {"name": str, "t0": number, "t1": number >= t0,
                "dur_s": t1 - t0, "depth": int >= 0, "attrs": dict?}

    and the span line count must equal ``spans_total - spans_dropped``
    (the ring retains exactly what was not evicted).
    """
    errors: List[str] = []
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        return [f"unreadable: {e}"]
    if not lines:
        return ["empty file (expected a schema header line)"]
    try:
        header = json.loads(lines[0])
    except ValueError as e:
        return [f"line 1: not JSON ({e})"]
    if header.get("schema") != TRACE_SCHEMA:
        errors.append(f"line 1: schema={header.get('schema')!r}, "
                      f"expected {TRACE_SCHEMA!r}")
    if not isinstance(header.get("unix_time"), (int, float)):
        errors.append("line 1: missing numeric unix_time")
    total, dropped = header.get("spans_total"), header.get("spans_dropped")
    if (not isinstance(total, int) or not isinstance(dropped, int)
            or not 0 <= dropped <= total):
        errors.append("line 1: spans_total/spans_dropped must be ints with "
                      "0 <= dropped <= total")
        total = dropped = None
    summary = header.get("summary")
    if not isinstance(summary, dict):
        errors.append("line 1: missing summary dict")
    else:
        for site, agg in summary.items():
            if (not isinstance(agg, dict)
                    or not all(isinstance(agg.get(k), (int, float))
                               for k in _SUMMARY_FIELDS)):
                errors.append(f"line 1: summary[{site!r}] needs numeric "
                              f"{'/'.join(_SUMMARY_FIELDS)}")
    n_spans = 0
    for i, line in enumerate(lines[1:], start=2):
        if not line.strip():
            continue
        try:
            d = json.loads(line)
        except ValueError as e:
            errors.append(f"line {i}: not JSON ({e})")
            continue
        n_spans += 1
        name = d.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"line {i}: missing span name")
            continue
        t0, t1, dur = d.get("t0"), d.get("t1"), d.get("dur_s")
        if (not isinstance(t0, (int, float)) or not isinstance(t1, (int, float))
                or t1 < t0):
            errors.append(f"line {i}: {name}: t0/t1 must be numeric with "
                          f"t1 >= t0")
        elif (not isinstance(dur, (int, float))
              or abs(dur - (t1 - t0)) > 1e-9 * max(1.0, abs(t1))):
            errors.append(f"line {i}: {name}: dur_s != t1 - t0")
        if not isinstance(d.get("depth"), int) or d["depth"] < 0:
            errors.append(f"line {i}: {name}: depth must be an int >= 0")
        if "attrs" in d and not isinstance(d["attrs"], dict):
            errors.append(f"line {i}: {name}: attrs must be a dict")
    if total is not None and n_spans != total - dropped:
        errors.append(f"{n_spans} span lines but header says "
                      f"{total} total - {dropped} dropped")
    return errors
