"""Mergeable online quantile sketch: exact small, bounded-error large.

Fixed-bucket histograms (``obs.metrics.Histogram``) answer "how many
requests were slower than 20ms" but interpolate percentiles from bucket
edges — a p99 read off 11 latency buckets can be off by the width of a
bucket.  This sketch answers quantile queries with a KNOWN rank error:

  * **exact mode** — below ``exact_cap`` observations the sketch keeps
    every value; quantiles are exact order statistics (and two merged
    exact sketches are exactly the pooled sample);
  * **compactor mode** — past the cap it becomes a deterministic
    KLL-style compactor hierarchy: level ``i`` holds values of weight
    ``2**i``; an over-full level is sorted and every other value is
    promoted to level ``i+1`` (the survivor of each adjacent pair,
    alternating pair parity per level so errors cancel rather than
    accumulate one-sided).  Each compaction of a weight-``w`` level
    shifts any rank by at most ``w`` — the sketch ADDS that to
    :attr:`rank_error`, so the reported bound is analytic, not
    hand-waved, and the property tests assert against it.

Determinism: no RNG anywhere (pair parity alternates deterministically),
so identical observation streams produce identical sketch states —
required for the repo's replay/regression idiom.

``merge`` concatenates levelwise and recompacts; counts, sums and error
bounds add.  Memory is O(level_cap * log2(n / exact_cap)).

Registered as the fourth metric type of ``repro.obs.metrics``
(``MetricsRegistry.sketch``); the JSONL line schema rides the existing
``repro.obs.metrics.v1`` header:

  sketch: {"name": str, "type": "sketch", "count": int, "sum": number,
           "rank_error": int, "exact_cap": int, "level_cap": int,
           "levels": [[level-0 values...], [level-1 ...], ...],
           "q": {"p50": .., "p90": .., "p95": .., "p99": ..}}

(``q`` is a reader convenience; ``levels`` is the authoritative state and
round-trips exactly.)
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Sequence

# quantiles exported in to_json()["q"] / summaries
_SUMMARY_QS = (("p50", 0.50), ("p90", 0.90), ("p95", 0.95), ("p99", 0.99))


class QuantileSketch:
    """Deterministic mergeable quantile sketch (see module docstring).

    ``quantile(q)`` returns the smallest retained value whose cumulative
    weight exceeds ``q * (count - 1)`` — in exact mode this is precisely
    ``np.quantile(values, q, method="lower")``; in compactor mode the
    value's true rank is within :attr:`rank_error` of the target.
    """

    __slots__ = ("name", "exact_cap", "level_cap", "count", "sum",
                 "rank_error", "_levels", "_parity")

    def __init__(self, name: str = "", exact_cap: int = 2048,
                 level_cap: int = 256):
        if exact_cap < 1 or level_cap < 2:
            raise ValueError(f"{name}: need exact_cap >= 1, level_cap >= 2 "
                             f"(got {exact_cap}, {level_cap})")
        self.name = name
        self.exact_cap = int(exact_cap)
        self.level_cap = int(level_cap)
        self.count = 0
        self.sum = 0.0
        self.rank_error = 0          # analytic bound on |est - true| rank
        self._levels: List[List[float]] = [[]]   # level i: weight 2**i
        self._parity: List[int] = [0]            # per-level pair parity

    # ------------------------------------------------------------ observing
    @property
    def exact(self) -> bool:
        """True while every observation is retained individually."""
        return self.rank_error == 0 and len(self._levels) == 1

    def observe(self, v: float) -> None:
        self._levels[0].append(float(v))
        self.count += 1
        self.sum += float(v)
        if self.count > self.exact_cap:
            self._compress()

    def observe_many(self, vs: Iterable[float]) -> None:
        vs = [float(v) for v in vs]
        self._levels[0].extend(vs)
        self.count += len(vs)
        self.sum += sum(vs)
        if self.count > self.exact_cap:
            self._compress()

    # ----------------------------------------------------------- compaction
    def _compress(self) -> None:
        """Restore the per-level bound (level 0 is additionally allowed to
        hold up to ``exact_cap`` values while the sketch is still exact).
        Promotions only move upward, so one bottom-up pass settles."""
        i = 0
        while i < len(self._levels):
            while len(self._levels[i]) > self.level_cap:
                self._compact(i)
            i += 1

    def _compact(self, i: int) -> None:
        buf = sorted(self._levels[i])
        keep: List[float] = []
        if len(buf) % 2:
            # odd element stays at level i (weight conservation is exact)
            keep.append(buf.pop() if self._parity[i] else buf.pop(0))
        take = self._parity[i]       # promote buf[0::2] or buf[1::2]
        self._parity[i] ^= 1
        promoted = buf[take::2]
        self._levels[i] = keep
        if i + 1 == len(self._levels):
            self._levels.append([])
            self._parity.append(0)
        self._levels[i + 1].extend(promoted)
        # collapsing sorted pairs to one survivor each shifts any rank by
        # at most one pair width: the weight of this level
        self.rank_error += 1 << i

    # ------------------------------------------------------------- querying
    def _weighted(self) -> List[tuple]:
        items = []
        for i, lv in enumerate(self._levels):
            w = 1 << i
            items.extend((v, w) for v in lv)
        items.sort()
        return items

    def quantile(self, q: float) -> float:
        return self.quantiles([q])[0]

    def quantiles(self, qs: Sequence[float]) -> List[float]:
        """Batch query over one sort of the retained values."""
        if self.count == 0:
            return [float("nan")] * len(qs)
        items = self._weighted()
        out = []
        for q in qs:
            if not 0.0 <= q <= 1.0:
                raise ValueError(f"quantile {q} outside [0, 1]")
            target = q * (self.count - 1)
            cum = 0
            val = items[-1][0]
            for v, w in items:
                cum += w
                if cum > target:
                    val = v
                    break
            out.append(val)
        return out

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    # -------------------------------------------------------------- merging
    def merge(self, other: "QuantileSketch") -> "QuantileSketch":
        """Fold ``other`` into self (levelwise concat + recompaction).

        Counts/sums/error bounds add; if both inputs were exact and the
        union fits under ``self.exact_cap`` the result is still exact
        (identical to a pooled sample).  Cap parameters follow self.
        """
        while len(self._levels) < len(other._levels):
            self._levels.append([])
            self._parity.append(0)
        for i, lv in enumerate(other._levels):
            self._levels[i].extend(lv)
        self.count += other.count
        self.sum += other.sum
        self.rank_error += other.rank_error
        if self.count > self.exact_cap or not self.exact:
            self._compress()
        return self

    # ---------------------------------------------------------------- JSONL
    def to_json(self) -> Dict[str, Any]:
        d: Dict[str, Any] = {
            "name": self.name, "type": "sketch", "count": self.count,
            "sum": self.sum, "rank_error": self.rank_error,
            "exact_cap": self.exact_cap, "level_cap": self.level_cap,
            "levels": [list(lv) for lv in self._levels],
        }
        if self.count:
            vals = self.quantiles([q for _, q in _SUMMARY_QS])
            d["q"] = {k: v for (k, _), v in zip(_SUMMARY_QS, vals)}
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "QuantileSketch":
        sk = cls(d.get("name", ""), int(d["exact_cap"]),
                 int(d["level_cap"]))
        sk.count = int(d["count"])
        sk.sum = float(d["sum"])
        sk.rank_error = int(d["rank_error"])
        sk._levels = [[float(v) for v in lv] for lv in d["levels"]] or [[]]
        sk._parity = [0] * len(sk._levels)
        return sk

    def summary(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {"count": self.count, "sum": self.sum,
                               "rank_error": self.rank_error}
        if self.count:
            vals = self.quantiles([q for _, q in _SUMMARY_QS])
            out.update({k: v for (k, _), v in zip(_SUMMARY_QS, vals)})
        return out

    def __repr__(self) -> str:
        return (f"QuantileSketch({self.name!r}, count={self.count}, "
                f"rank_error={self.rank_error}, "
                f"levels={[len(lv) for lv in self._levels]})")
