"""Declarative latency SLOs with rolling-window error-budget burn rates.

An SLO like "99% of requests under 20ms" (``SLO_P99_MS=20``) defines an
error budget: 1% of requests may be slower.  The operational signal is
not the raw miss count but the **burn rate** — the fraction of recent
requests over the threshold divided by the budget:

    burn_rate = bad_fraction(window) / (1 - percentile)

burn_rate 1.0 means the budget is being consumed exactly as provisioned;
3.0 means at this pace the period's budget is gone in a third of the
period (the standard SRE multi-window alerting quantity).

:class:`SLOTracker` keeps the window as coarse time buckets of good/bad
counts (``window_s / n_buckets`` resolution) so memory is O(n_buckets)
regardless of traffic, and the clock is injectable so tests drive it
deterministically (the ``tests/test_serve_async.py`` fake-clock idiom).
``poll()`` emits edge-triggered events — one ``slo_breach`` when the burn
rate crosses ``alert_burn_rate`` upward, one ``slo_recover`` when it
falls back — into a bounded ring, so a flapping service cannot grow
memory by being monitored.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.obs.trace import RingBuffer

_EVENTS_CAP = 256


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """Target percentile of ``name`` under ``threshold_ms``.

    ``percentile=0.0`` degenerates to "budget = everything": burn_rate
    equals the plain bad fraction — the form the deadline-miss-ratio
    tracker uses.
    """
    threshold_ms: float
    percentile: float = 0.99
    window_s: float = 60.0
    name: str = "serve.request_ms"

    def __post_init__(self):
        if not 0.0 <= self.percentile < 1.0:
            raise ValueError(f"percentile must be in [0, 1), "
                             f"got {self.percentile}")
        if self.threshold_ms < 0 or self.window_s <= 0:
            raise ValueError(f"need threshold_ms >= 0 and window_s > 0, "
                             f"got {self.threshold_ms}, {self.window_s}")

    @property
    def budget(self) -> float:
        """Allowed bad fraction (1 - percentile)."""
        return 1.0 - self.percentile


class SLOTracker:
    """Rolling-window burn-rate tracker for one :class:`SLOSpec`."""

    def __init__(self, spec: SLOSpec, *,
                 clock: Callable[[], float] = time.monotonic,
                 n_buckets: int = 12,
                 alert_burn_rate: float = 1.0):
        if n_buckets < 1:
            raise ValueError(f"n_buckets must be >= 1, got {n_buckets}")
        self.spec = spec
        self._clock = clock
        self._bucket_s = spec.window_s / n_buckets
        self._n_buckets = int(n_buckets)
        self.alert_burn_rate = float(alert_burn_rate)
        # (bucket_index, good, bad), oldest first; bounded by _evict
        self._buckets: List[List[int]] = []
        self.breached = False
        self.events = RingBuffer(_EVENTS_CAP)
        self.total_good = 0
        self.total_bad = 0

    # ------------------------------------------------------------ recording
    def record(self, latency_ms: float, now: Optional[float] = None) -> None:
        now = float(self._clock()) if now is None else float(now)
        idx = int(now // self._bucket_s)
        bad = latency_ms > self.spec.threshold_ms
        if self._buckets and self._buckets[-1][0] == idx:
            b = self._buckets[-1]
        else:
            self._evict(idx)
            self._buckets.append([idx, 0, 0])
            b = self._buckets[-1]
        b[2 if bad else 1] += 1
        if bad:
            self.total_bad += 1
        else:
            self.total_good += 1

    def _evict(self, idx: int) -> None:
        floor = idx - self._n_buckets + 1
        while self._buckets and self._buckets[0][0] < floor:
            self._buckets.pop(0)

    # ------------------------------------------------------------- querying
    def window_counts(self, now: Optional[float] = None) -> Tuple[int, int]:
        """(good, bad) inside the rolling window ending at ``now``."""
        now = float(self._clock()) if now is None else float(now)
        self._evict(int(now // self._bucket_s))
        good = sum(b[1] for b in self._buckets)
        bad = sum(b[2] for b in self._buckets)
        return good, bad

    def bad_fraction(self, now: Optional[float] = None) -> float:
        good, bad = self.window_counts(now)
        return bad / (good + bad) if good + bad else 0.0

    def burn_rate(self, now: Optional[float] = None) -> float:
        """Bad fraction over budget; 0.0 on an empty window."""
        budget = max(self.spec.budget, 1e-9)
        return self.bad_fraction(now) / budget

    def ok(self, now: Optional[float] = None) -> bool:
        return self.burn_rate(now) <= self.alert_burn_rate

    # --------------------------------------------------------------- events
    def poll(self, now: Optional[float] = None) -> List[Dict[str, Any]]:
        """Edge-triggered breach/recover detection; returns NEW events."""
        now = float(self._clock()) if now is None else float(now)
        rate = self.burn_rate(now)
        good, bad = self.window_counts(now)
        fresh: List[Dict[str, Any]] = []
        crossed_up = rate > self.alert_burn_rate and not self.breached
        crossed_down = rate <= self.alert_burn_rate and self.breached
        if crossed_up or crossed_down:
            self.breached = crossed_up
            ev = {"t": now,
                  "kind": "slo_breach" if crossed_up else "slo_recover",
                  "name": self.spec.name, "burn_rate": rate,
                  "threshold_ms": self.spec.threshold_ms,
                  "percentile": self.spec.percentile,
                  "window_good": good, "window_bad": bad}
            self.events.append(ev)
            fresh.append(ev)
        return fresh

    def state(self, now: Optional[float] = None) -> Dict[str, Any]:
        now = float(self._clock()) if now is None else float(now)
        good, bad = self.window_counts(now)
        return {"threshold_ms": self.spec.threshold_ms,
                "percentile": self.spec.percentile,
                "window_s": self.spec.window_s,
                "window_good": good, "window_bad": bad,
                "bad_fraction": bad / (good + bad) if good + bad else 0.0,
                "burn_rate": self.burn_rate(now),
                "breached": self.breached,
                "events_total": self.events.total,
                "total_good": self.total_good, "total_bad": self.total_bad}
