"""repro.obs — unified tracing + metrics for train/select/serve hot paths.

One process-global :class:`Tracer` and :class:`MetricsRegistry` live here,
mirroring how ``repro.testing.faults`` exposes one global site registry:
production code imports the module and uses ``obs.tracer`` / ``obs.metrics``
directly (or accepts them as injectable constructor arguments, as
``SVMEngine`` does, defaulting to the globals).

Configuration is string keys, threaded through the normal ``-S``
config-key surface (see ``repro.api.config``):

  ``TRACE=1``            enable the span tracer
  ``TRACE_OUT=<path>``   write the retained span window as JSONL on exit
                         (schema ``repro.obs.trace.v1``; implies TRACE=1
                         unless TRACE=0 is given explicitly)
  ``METRICS_OUT=<path>`` write the metrics registry as JSONL on exit
  ``PROFILE_DIR=<path>`` capture ``jax.profiler`` traces around wave
                         launches into this directory

Everything is off by default and each disabled hook costs one attribute
test on the hot path.  The consumer layer on top of these signals —
quantile sketches (``obs.sketch``), SLO burn rates (``obs.slo``) and the
drift-triggered refresh loop (``serve.monitor``) — reads the same global
instruments.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from . import jaxprof
from .metrics import (Counter, Gauge, Histogram, LATENCY_MS_BUCKETS,
                      METRICS_SCHEMA, MetricsRegistry, WELL_KNOWN,
                      validate_jsonl)
from .sketch import QuantileSketch
from .slo import SLOSpec, SLOTracker
from .trace import (NULL_SPAN, RingBuffer, Span, TRACE_SCHEMA, Tracer,
                    validate_trace_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "LATENCY_MS_BUCKETS", "METRICS_SCHEMA",
    "MetricsRegistry", "NULL_SPAN", "QuantileSketch", "RingBuffer",
    "SLOSpec", "SLOTracker", "Span", "TRACE_SCHEMA", "Tracer", "WELL_KNOWN",
    "configure", "flush_metrics", "flush_trace", "jaxprof", "metrics",
    "metrics_out", "profile_dir", "reset", "trace_out", "tracer",
    "validate_jsonl", "validate_trace_jsonl",
]

# process-global instruments — the default sinks for every instrumented site
tracer = Tracer()
metrics = MetricsRegistry()

_METRICS_OUT: Optional[str] = None
_TRACE_OUT: Optional[str] = None


def configure(trace: Optional[bool] = None,
              metrics_out: Optional[str] = None,
              trace_out: Optional[str] = None,
              profile_dir: Optional[str] = None) -> None:
    """Apply the observability config keys.  ``None`` leaves a setting
    unchanged, so callers can forward exactly what the user passed."""
    global _METRICS_OUT, _TRACE_OUT
    if trace is not None:
        tracer.enabled = bool(trace)
    if trace_out is not None:
        _TRACE_OUT = trace_out or None
        # a trace dump with the tracer off would always be empty: TRACE_OUT
        # implies TRACE=1 unless the same call says TRACE=0 explicitly
        if _TRACE_OUT and trace is None:
            tracer.enabled = True
    if metrics_out is not None:
        _METRICS_OUT = metrics_out or None
    if profile_dir is not None:
        jaxprof.configure(profile_dir or None)


def metrics_out() -> Optional[str]:
    return _METRICS_OUT


def trace_out() -> Optional[str]:
    return _TRACE_OUT


def profile_dir() -> Optional[str]:
    return jaxprof.profile_dir()


def flush_metrics(extra: Optional[Dict[str, Any]] = None) -> Optional[str]:
    """Write the global registry to the configured ``METRICS_OUT`` path (if
    any); returns the path written or None.  The CLI calls this on exit."""
    if _METRICS_OUT is None:
        return None
    metrics.write_jsonl(_METRICS_OUT, extra=extra)
    return _METRICS_OUT


def flush_trace() -> Optional[str]:
    """Write the global tracer's span window to ``TRACE_OUT`` (if any);
    returns the path written or None.  The CLI calls this on exit."""
    if _TRACE_OUT is None:
        return None
    tracer.write_jsonl(_TRACE_OUT)
    return _TRACE_OUT


def reset() -> None:
    """Return the process-global instruments to their startup state (tests)."""
    global _METRICS_OUT, _TRACE_OUT
    tracer.enabled = False
    tracer.clear()
    metrics.clear()
    _METRICS_OUT = None
    _TRACE_OUT = None
    jaxprof.configure(None)
