"""Typed metrics registry: counters, gauges, fixed-bucket histograms.

The repo's telemetry used to be an ad-hoc scatter — ``collections.Counter``
in the engine, an unbounded ``wave_stats`` list, a module-global fallback
log in ``train/checkpoint`` — with no shared export path.  This registry is
the one place process-wide operational numbers accumulate; the existing
dict surfaces (``SVMEngine.stats()``, ``refresh_bank`` info) stay intact
as views on top of it.

Metric types
  * :class:`Counter`   — monotonically increasing float/int total
  * :class:`Gauge`     — last-written value (e.g. ``checkpoint.save_mbps``)
  * :class:`Histogram` — fixed bucket upper edges, counts per bucket plus
    one overflow bucket, running sum/count (latency distributions; buckets
    are fixed at creation so merged/exported histograms always line up)
  * :class:`QuantileSketch` (``obs.sketch``) — mergeable online quantile
    sketch: exact order statistics below a sample cap, KLL-style
    bounded-rank-error compaction above it, the bound itself tracked and
    exported.  This is where TRUE p50/p95/p99 come from; the fixed-bucket
    histogram stays for bucket-aligned dashboards.

JSONL schema (``repro.obs.metrics.v1``) — what :meth:`MetricsRegistry.
write_jsonl` emits, :func:`validate_jsonl` checks, and the tier-1 CLI
metrics smoke pins:

  line 1:   {"schema": "repro.obs.metrics.v1", "unix_time": <float>}
  counter:  {"name": str, "type": "counter", "value": number}
  gauge:    {"name": str, "type": "gauge", "value": number}
  histogram:{"name": str, "type": "histogram", "buckets": [edges...],
             "counts": [len(edges)+1 ints], "sum": number, "count": int}
  sketch:   {"name": str, "type": "sketch", "count": int, "sum": number,
             "rank_error": int, "exact_cap": int, "level_cap": int,
             "levels": [[number...]...], "q": {...}?}
            (invariant: sum(len(levels[i]) * 2**i) == count)

Names are dot-separated sites mirroring the tracer/faults idiom
(``serve.request_ms``, ``checkpoint.fallback_steps``).  Well-known names
are listed in :data:`WELL_KNOWN` — emitters register there so operators
can grep one table instead of the codebase.
"""
from __future__ import annotations

import bisect
import json
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from repro.obs.sketch import QuantileSketch

METRICS_SCHEMA = "repro.obs.metrics.v1"

# request-latency histogram upper edges (ms); one overflow bucket follows
LATENCY_MS_BUCKETS = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0, 200.0,
                      500.0, 1000.0)

# name -> one-line meaning; the documented metric surface
WELL_KNOWN: Dict[str, str] = {
    "serve.request_ms": "histogram: submit -> blended-response latency",
    "serve.request_ms.q": "sketch: true p50/p95/p99 of the same latency "
                          "(exact below cap, bounded rank error above)",
    "serve.served": "counter: requests completed by the engine",
    "serve.shed": "counter: admission batches rejected by overload bounds",
    "serve.waves": "counter: waves dispatched",
    "serve.slo_burn_rate": "gauge: SLO error-budget burn rate over the "
                           "rolling window (>1 = burning budget)",
    "serve.slo_breaches": "counter: burn-rate threshold crossings "
                          "(ok -> breached transitions)",
    "serve.drift_score_max": "gauge: worst per-cell routing-distance drift "
                             "score at the last health() poll",
    "serve.drift_alerts": "counter: health() polls with at least one cell "
                          "over DRIFT_REFRESH_THRESHOLD",
    "serve.drift_refreshes": "counter: drift-triggered refresh_bank + "
                             "hot-swap cycles (the closed loop firing)",
    "train.waves_solved": "counter: training waves solved on device",
    "train.waves_restored": "counter: training waves restored from disk",
    "train.corrupt_waves": "counter: wave checkpoints failing verification "
                           "(re-solved, not loaded)",
    "select.columns_resolved": "counter: select-stage targeted re-solves",
    "checkpoint.saves": "counter: checkpoint steps written",
    "checkpoint.restores": "counter: checkpoint steps restored",
    "checkpoint.fallback_steps": "counter: corrupt/torn steps skipped by "
                                 "restore fallbacks (silent before PR 7)",
    "checkpoint.save_mbps": "gauge: last save throughput, MB/s",
    "checkpoint.restore_mbps": "gauge: last restore throughput, MB/s",
}


class Counter:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Union[int, float] = 0

    def inc(self, n: Union[int, float] = 1) -> None:
        if n < 0:
            raise ValueError(f"{self.name}: counters only go up (got {n})")
        self.value += n

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "counter", "value": self.value}


class Gauge:
    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "gauge", "value": self.value}


class Histogram:
    """Fixed-bucket histogram: ``buckets`` are ascending upper edges; an
    observation lands in the first bucket whose edge is >= value, or the
    trailing overflow bucket.  ``observe`` is one bisect + two adds — cheap
    enough for the per-request serve path."""

    __slots__ = ("name", "buckets", "counts", "sum", "count")

    def __init__(self, name: str, buckets: Sequence[float]):
        edges = tuple(float(b) for b in buckets)
        if not edges or any(b <= a for a, b in zip(edges, edges[1:])):
            raise ValueError(f"{name}: bucket edges must be ascending, "
                             f"got {edges}")
        self.name = name
        self.buckets = edges
        self.counts = [0] * (len(edges) + 1)
        self.sum = 0.0
        self.count = 0

    def observe(self, v: float) -> None:
        self.counts[bisect.bisect_left(self.buckets, v)] += 1
        self.sum += v
        self.count += 1

    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def to_json(self) -> Dict[str, Any]:
        return {"name": self.name, "type": "histogram",
                "buckets": list(self.buckets), "counts": list(self.counts),
                "sum": self.sum, "count": self.count}


class MetricsRegistry:
    """Get-or-create home for named metrics.  Re-requesting a name returns
    the SAME object (call sites cache the handle; a histogram re-request
    with different buckets is an error — fixed buckets are the schema)."""

    def __init__(self):
        self._metrics: Dict[str, Union[Counter, Gauge, Histogram,
                                       QuantileSketch]] = {}

    def _get(self, name: str, cls, *args):
        m = self._metrics.get(name)
        if m is None:
            m = cls(name, *args)
            self._metrics[name] = m
            return m
        if not isinstance(m, cls):
            raise TypeError(f"{name} is a {type(m).__name__}, "
                            f"requested {cls.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  buckets: Sequence[float] = LATENCY_MS_BUCKETS) -> Histogram:
        h = self._get(name, Histogram, buckets)
        if h.buckets != tuple(float(b) for b in buckets):
            raise ValueError(f"{name}: histogram exists with buckets "
                             f"{h.buckets}, requested {tuple(buckets)}")
        return h

    def sketch(self, name: str, exact_cap: int = 2048,
               level_cap: int = 256) -> QuantileSketch:
        sk = self._get(name, QuantileSketch, exact_cap, level_cap)
        if (sk.exact_cap, sk.level_cap) != (int(exact_cap), int(level_cap)):
            raise ValueError(f"{name}: sketch exists with caps "
                             f"({sk.exact_cap}, {sk.level_cap}), requested "
                             f"({exact_cap}, {level_cap})")
        return sk

    def clear(self) -> None:
        self._metrics.clear()

    def names(self) -> List[str]:
        return sorted(self._metrics)

    def summary(self) -> Dict[str, Any]:
        """{name: value | histogram-dict} — the quick human view."""
        out: Dict[str, Any] = {}
        for name in self.names():
            m = self._metrics[name]
            if isinstance(m, Histogram):
                out[name] = {"count": m.count, "sum": m.sum,
                             "mean": m.mean(), "counts": list(m.counts)}
            elif isinstance(m, QuantileSketch):
                out[name] = m.summary()
            else:
                out[name] = m.value
        return out

    # ------------------------------------------------------------- JSONL
    def write_jsonl(self, path: str,
                    extra: Optional[Dict[str, Any]] = None) -> int:
        """Write the documented JSONL schema; returns metric line count."""
        n = 0
        with open(path, "w") as f:
            header = {"schema": METRICS_SCHEMA, "unix_time": time.time()}
            if extra:
                header.update(extra)
            f.write(json.dumps(header) + "\n")
            for name in self.names():
                f.write(json.dumps(self._metrics[name].to_json()) + "\n")
                n += 1
        return n

    @classmethod
    def read_jsonl(cls, path: str) -> Tuple["MetricsRegistry",
                                            Dict[str, Any]]:
        """Round-trip reader: rebuilds a registry from :meth:`write_jsonl`
        output.  Raises ``ValueError`` on schema violations (use
        :func:`validate_jsonl` for a non-throwing error list)."""
        errors = validate_jsonl(path)
        if errors:
            raise ValueError(f"{path}: invalid metrics JSONL: {errors[0]}")
        reg = cls()
        with open(path) as f:
            header = json.loads(f.readline())
            for line in f:
                d = json.loads(line)
                if d["type"] == "counter":
                    reg.counter(d["name"]).inc(d["value"])
                elif d["type"] == "gauge":
                    reg.gauge(d["name"]).set(d["value"])
                elif d["type"] == "sketch":
                    reg._metrics[d["name"]] = QuantileSketch.from_json(d)
                else:
                    h = reg.histogram(d["name"], d["buckets"])
                    h.counts = list(d["counts"])
                    h.sum = float(d["sum"])
                    h.count = int(d["count"])
        return reg, header


def validate_jsonl(path: str) -> List[str]:
    """Check a metrics JSONL file against the documented schema.

    Returns a list of human-readable errors (empty = valid).  This is what
    the tier-1 metrics-schema smoke runs against the CLI's ``METRICS_OUT``
    output — the schema is load-bearing for operators' dashboards, so
    drifting it must fail the gate, not a consumer at 3am.
    """
    errors: List[str] = []
    try:
        with open(path) as f:
            lines = f.readlines()
    except OSError as e:
        return [f"unreadable: {e}"]
    if not lines:
        return ["empty file (expected a schema header line)"]
    try:
        header = json.loads(lines[0])
    except ValueError as e:
        return [f"line 1: not JSON ({e})"]
    if header.get("schema") != METRICS_SCHEMA:
        errors.append(f"line 1: schema={header.get('schema')!r}, "
                      f"expected {METRICS_SCHEMA!r}")
    if not isinstance(header.get("unix_time"), (int, float)):
        errors.append("line 1: missing numeric unix_time")
    seen = set()
    for i, line in enumerate(lines[1:], start=2):
        if not line.strip():
            continue
        try:
            d = json.loads(line)
        except ValueError as e:
            errors.append(f"line {i}: not JSON ({e})")
            continue
        name, typ = d.get("name"), d.get("type")
        if not isinstance(name, str) or not name:
            errors.append(f"line {i}: missing name")
            continue
        if name in seen:
            errors.append(f"line {i}: duplicate metric {name!r}")
        seen.add(name)
        if typ in ("counter", "gauge"):
            if not isinstance(d.get("value"), (int, float)):
                errors.append(f"line {i}: {name}: non-numeric value")
        elif typ == "histogram":
            b, c = d.get("buckets"), d.get("counts")
            if (not isinstance(b, list) or not isinstance(c, list)
                    or len(c) != len(b) + 1):
                errors.append(f"line {i}: {name}: counts must have "
                              f"len(buckets)+1 entries")
            elif any(y <= x for x, y in zip(b, b[1:])):
                errors.append(f"line {i}: {name}: bucket edges not "
                              f"ascending")
            elif (not all(isinstance(v, int) and v >= 0 for v in c)
                  or not isinstance(d.get("sum"), (int, float))
                  or not isinstance(d.get("count"), int)
                  or d["count"] != sum(c)):
                errors.append(f"line {i}: {name}: counts/sum/count "
                              f"inconsistent")
        elif typ == "sketch":
            lv = d.get("levels")
            caps_ok = (isinstance(d.get("exact_cap"), int)
                       and isinstance(d.get("level_cap"), int)
                       and d["exact_cap"] >= 1 and d["level_cap"] >= 2)
            if (not isinstance(lv, list) or not caps_ok
                    or not all(isinstance(l, list) and
                               all(isinstance(v, (int, float)) for v in l)
                               for l in lv)):
                errors.append(f"line {i}: {name}: sketch needs integer "
                              f"caps and numeric levels lists")
            elif (not isinstance(d.get("count"), int)
                  or not isinstance(d.get("sum"), (int, float))
                  or not isinstance(d.get("rank_error"), int)
                  or d["rank_error"] < 0
                  or d["count"] != sum(len(l) << j
                                       for j, l in enumerate(lv))):
                # weight conservation: retained weights must cover count
                errors.append(f"line {i}: {name}: sketch count/sum/"
                              f"rank_error inconsistent with levels")
        else:
            errors.append(f"line {i}: {name}: unknown type {typ!r}")
    return errors
