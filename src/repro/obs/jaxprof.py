"""Optional ``jax.profiler`` hooks, gated by the ``PROFILE_DIR`` config key.

The span tracer times HOST stages (queue/pack/device-wait/collect); what it
cannot see is where the device time itself goes.  When a profile directory
is configured (``obs.configure(profile_dir=...)``, or ``-S PROFILE_DIR=...``
through the CLI), wave launches are bracketed with
``jax.profiler.StepTraceAnnotation`` so each serve/train wave shows up as
one step in the captured trace, and :func:`start`/:func:`stop` drive the
device trace capture itself.

Everything here degrades to a no-op when no directory is configured or the
installed jax lacks the profiler — observability must never be the thing
that crashes serving.
"""
from __future__ import annotations

import contextlib
from typing import Optional

# process-global profile directory; None = all hooks are no-ops
_PROFILE_DIR: Optional[str] = None
_ACTIVE = False


def configure(profile_dir: Optional[str]) -> None:
    global _PROFILE_DIR
    _PROFILE_DIR = profile_dir


def profile_dir() -> Optional[str]:
    return _PROFILE_DIR


def active() -> bool:
    """True while a device trace capture is running."""
    return _ACTIVE


def start() -> bool:
    """Begin a device trace capture into the configured directory.
    Returns False (no-op) when unconfigured, already active, or the
    profiler is unavailable on this jax build."""
    global _ACTIVE
    if _PROFILE_DIR is None or _ACTIVE:
        return False
    try:
        import jax
        jax.profiler.start_trace(_PROFILE_DIR)
    except Exception:
        return False
    _ACTIVE = True
    return True


def stop() -> bool:
    global _ACTIVE
    if not _ACTIVE:
        return False
    _ACTIVE = False
    try:
        import jax
        jax.profiler.stop_trace()
    except Exception:
        return False
    return True


def step(name: str, num: int):
    """Context manager bracketing one wave launch as a profiler step.

    ``with jaxprof.step("serve_wave", seq): dec = evaluate(...)`` — shows
    up as step ``num`` of ``name`` in the captured trace.  Returns a
    nullcontext unless a profile directory is configured (the hot path
    pays one global read).
    """
    if _PROFILE_DIR is None:
        return contextlib.nullcontext()
    try:
        import jax
        return jax.profiler.StepTraceAnnotation(name, step_num=num)
    except Exception:
        return contextlib.nullcontext()
