"""Serving health monitor: drift scores, SLO burn rate, health verdicts.

The consumer layer over the engine's raw signals.  PR 7 gave serving
spans, counters and per-stage breakdowns; this module turns them into the
three questions an operator (or the closed loop in ``repro.cli serve``)
actually asks:

  1. **Is latency within SLO?** — an :class:`~repro.obs.slo.SLOTracker`
     over per-request latency (``SLO_P99_MS``), plus a deadline-miss
     tracker against the engine's own ``deadline_ms``;
  2. **Has traffic drifted away from the training data?** — per-cell
     :class:`~repro.obs.sketch.QuantileSketch` windows over the squared
     routing distance (query -> assigned center), compared against the
     train-time baseline the bank recorded at ``to_bank()`` time
     (``ModelBank.route_baseline``).  The score is a scale-free shift:

         score(cell) = (live_p50 - base_p50) / max(base_p90 - base_p50, eps)

     ~0 for in-distribution traffic, ~1 when the median live query sits
     where only the training tail did, and grows without bound as queries
     leave the cell's support — ``DRIFT_REFRESH_THRESHOLD`` (default 3)
     picks the refresh trigger point;
  3. **Is the engine shedding or overloaded?** — shed/served rates read
     from ``SVMEngine.stats()``.

Windows rotate on time (``DRIFT_WINDOW`` seconds, current + previous pane
— scores read the current pane once it has ``min_window_count``
observations, else the previous), and the monitor shares the ENGINE's
injectable clock by default, so the fake-clock test idiom drives both
deterministically.

Hook cost: the engine calls :meth:`observe_routing` once per admitted
batch and :meth:`observe_requests` once per collected wave — both
vectorized over rows — and a detached monitor costs the engine one
``is not None`` test per batch (measured against the 2% disabled-obs bar
in ``benchmarks/serve_microbench``).
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.obs.sketch import QuantileSketch
from repro.obs.slo import SLOSpec, SLOTracker

# per-cell window sketches: small — drift reads p50 of a window, not p99
_CELL_EXACT_CAP = 512
_CELL_LEVEL_CAP = 64

# relative-scale floor for the drift denominator: a cell whose baseline
# spread collapsed (q90 ~= q50) must not turn measurement noise into
# unbounded scores
_SCALE_FLOOR_FRAC = 0.05


class HealthMonitor:
    """Attachable closed-loop health view over one :class:`SVMEngine`.

    Constructing the monitor attaches it (``engine.attach_monitor``); the
    engine then feeds routing distances and request latencies through the
    observe hooks.  ``clock=None`` shares the engine's clock.
    """

    def __init__(self, engine, *,
                 slo_p99_ms: Optional[float] = None,
                 slo: Optional[SLOSpec] = None,
                 drift_window_s: float = 10.0,
                 drift_threshold: float = 3.0,
                 min_window_count: int = 8,
                 clock: Optional[Callable[[], float]] = None,
                 metrics: Optional["obs.MetricsRegistry"] = None):
        if slo is not None and slo_p99_ms is not None:
            raise ValueError("pass slo_p99_ms or a full SLOSpec, not both")
        if drift_window_s <= 0:
            raise ValueError(f"drift_window_s must be > 0, "
                             f"got {drift_window_s}")
        self.engine = engine
        self._clock = engine._clock if clock is None else clock
        self._metrics = obs.metrics if metrics is None else metrics
        self.drift_window_s = float(drift_window_s)
        self.drift_threshold = float(drift_threshold)
        self.min_window_count = int(min_window_count)

        if slo_p99_ms is not None:
            slo = SLOSpec(threshold_ms=float(slo_p99_ms), percentile=0.99)
        self.slo: Optional[SLOTracker] = (
            None if slo is None else SLOTracker(slo, clock=self._clock))
        # deadline-miss ratio: percentile 0 -> burn_rate == bad fraction
        dl = engine.deadline_ms
        self.deadline: Optional[SLOTracker] = None
        if dl is not None:
            self.deadline = SLOTracker(
                SLOSpec(threshold_ms=float(dl), percentile=0.0,
                        window_s=self.drift_window_s * 6,
                        name="serve.deadline"),
                clock=self._clock)

        # routing-distance windows: cell -> sketch, current + previous pane
        self._cur: Dict[int, QuantileSketch] = {}
        self._prev: Dict[int, QuantileSketch] = {}
        self._win_start = float(self._clock())
        self._windows_rotated = 0
        # baseline cache keyed by bank version (swaps refresh it)
        self._baseline_version: Optional[int] = None
        self._baseline = None

        self._m_burn = self._metrics.gauge("serve.slo_burn_rate")
        self._m_breaches = self._metrics.counter("serve.slo_breaches")
        self._m_drift_max = self._metrics.gauge("serve.drift_score_max")
        self._m_alerts = self._metrics.counter("serve.drift_alerts")
        engine.attach_monitor(self)

    # ------------------------------------------------------------ observing
    def _rotate(self, now: float) -> None:
        if now - self._win_start >= self.drift_window_s:
            self._prev = self._cur
            self._cur = {}
            self._win_start = now
            self._windows_rotated += 1

    def observe_routing(self, cells: np.ndarray, d2: np.ndarray,
                        now: Optional[float] = None) -> None:
        """Fold one admitted batch's (cell id, squared routing distance)
        pairs into the current window.  Called by the engine under its
        clock; vectorized per distinct cell."""
        now = float(self._clock()) if now is None else float(now)
        self._rotate(now)
        cells = np.asarray(cells)
        for c in np.unique(cells):
            sk = self._cur.get(int(c))
            if sk is None:
                sk = QuantileSketch(f"cell{int(c)}", _CELL_EXACT_CAP,
                                    _CELL_LEVEL_CAP)
                self._cur[int(c)] = sk
            sk.observe_many(d2[cells == c])

    def observe_requests(self, total_ms: Sequence[float],
                         now: Optional[float] = None) -> None:
        """Fold one collected wave's completed-request latencies into the
        SLO and deadline trackers."""
        if self.slo is None and self.deadline is None:
            return
        now = float(self._clock()) if now is None else float(now)
        for ms in total_ms:
            if self.slo is not None:
                self.slo.record(ms, now=now)
            if self.deadline is not None:
                self.deadline.record(ms, now=now)

    # ---------------------------------------------------------------- drift
    def _baseline_arrays(self):
        bank = self.engine.bank
        v = int(bank.version)
        if self._baseline_version != v:
            self._baseline = bank.route_baseline_arrays()
            self._baseline_version = v
        return self._baseline

    def _window_sketch(self, cell: int) -> Optional[QuantileSketch]:
        sk = self._cur.get(cell)
        if sk is not None and sk.count >= self.min_window_count:
            return sk
        prev = self._prev.get(cell)
        if prev is not None and prev.count >= self.min_window_count:
            return prev
        return None

    def drift_scores(self, now: Optional[float] = None) -> Dict[int, float]:
        """Per-cell drift score for every cell with a usable window AND a
        recorded baseline.  Empty when the bank has no baseline (old
        banks): drift detection disables itself rather than guessing."""
        now = float(self._clock()) if now is None else float(now)
        self._rotate(now)
        base = self._baseline_arrays()
        if base is None:
            return {}
        q50, q90, n = base
        scores: Dict[int, float] = {}
        for cell in set(self._cur) | set(self._prev):
            if not 0 <= cell < q50.shape[0] or n[cell] == 0:
                continue
            sk = self._window_sketch(cell)
            if sk is None:
                continue
            b50, b90 = q50[cell], q90[cell]
            scale = max(b90 - b50, _SCALE_FLOOR_FRAC * max(b50, 1e-9), 1e-12)
            scores[cell] = float((sk.quantile(0.5) - b50) / scale)
        return scores

    def drifted_cells(self, now: Optional[float] = None) -> List[int]:
        """Cells whose drift score crosses the refresh threshold."""
        return sorted(c for c, s in self.drift_scores(now).items()
                      if s >= self.drift_threshold)

    def reset_cells(self, cells: Sequence[int]) -> None:
        """Drop the window state of refreshed cells so the next verdict
        measures post-refresh traffic, not the drift that triggered it."""
        for c in cells:
            self._cur.pop(int(c), None)
            self._prev.pop(int(c), None)

    # --------------------------------------------------------------- verdict
    def health(self, now: Optional[float] = None) -> Dict[str, Any]:
        """One structured verdict: ``status`` is "ok", "degraded" (drift
        over threshold or shedding) or "breaching" (SLO burn rate over its
        alert bar).  Updates the drift/SLO gauges and counters as a side
        effect — polling health IS the metrics heartbeat."""
        now = float(self._clock()) if now is None else float(now)
        stats = self.engine.stats()
        scores = self.drift_scores(now)
        drifted = sorted(c for c, s in scores.items()
                         if s >= self.drift_threshold)
        max_drift = max(scores.values()) if scores else 0.0
        self._m_drift_max.set(max_drift)
        if drifted:
            self._m_alerts.inc()

        submitted = stats.get("submitted", 0)
        shed_rows = stats.get("shed_rows", 0)
        shed_rate = shed_rows / max(submitted + shed_rows, 1)

        out: Dict[str, Any] = {
            "bank_version": stats["bank_version"],
            "drift": {"scores": scores, "drifted_cells": drifted,
                      "threshold": self.drift_threshold,
                      "max_score": max_drift,
                      "baseline": self._baseline_arrays() is not None,
                      "window_s": self.drift_window_s,
                      "windows_rotated": self._windows_rotated},
            "shed_rate": shed_rate,
            "served": stats.get("served", 0),
            "pending": stats.get("pending", 0),
        }
        breaching = False
        if self.slo is not None:
            for _ in self.slo.poll(now):
                self._m_breaches.inc()
            st = self.slo.state(now)
            self._m_burn.set(st["burn_rate"])
            out["slo"] = st
            breaching = st["breached"]
        if self.deadline is not None:
            dst = self.deadline.state(now)
            out["deadline_miss_ratio"] = dst["bad_fraction"]
            out["deadline"] = dst
        out["status"] = ("breaching" if breaching
                         else "degraded" if drifted or shed_rate > 0.01
                         else "ok")
        return out
