"""Cell-routed SVM serving engine: micro-batched prediction over a model bank.

The paper's test phase at serving scale.  Every query is Voronoi-routed
host-side to its owning cell (the same nearest-center rule the training
decomposition uses), requests accumulate per cell, and each ``step()``
drains the queues with ONE batched launch over all active cells:

  * :func:`repro.distributed.planner.plan_wave` turns the ragged per-cell
    queue depths into a static launch layout — hot cells are chunked into
    several slots, cold cells padded a little, shapes bucketed so repeated
    steps reuse compiled programs;
  * on TPU the launch is the fused ``svm_predict_cells`` Pallas kernel (one
    kernel for the whole wave; Gram tiles never touch HBM); elsewhere it is
    the batched distance-cache path;
  * the wave's gamma-independent cross-D² is kept as a persistent
    :class:`CachedGram`-style cache keyed by the routed batch: re-evaluating
    the same wave under new gammas/coefficients (multi-gamma sweeps, task
    A/B coefficient swaps, quantile re-levels) replays only the O(m·k) VPU
    epilogue — the PR-1 distance-cache contract extended across requests.
    ``cache_dtype="bf16"`` halves the resident cache (see ``CachedGram``).

Slots are LPT-ordered by :func:`plan_wave`, so sharding the slot axis over a
mesh (as ``distributed.cell_trainer`` does for training) inherits balanced
waves; this engine runs the single-host slice of that story.
"""
from __future__ import annotations

import collections
import functools
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.planner import WavePlan, plan_wave
from repro.kernels import runtime
from repro.kernels.kernel_matrix import ops as km_ops
from repro.kernels.svm_predict import ops as sp_ops
from repro.serve.model_bank import ModelBank
from repro.tasks.builder import combine_decisions

Array = jax.Array

_ROUTE_CHUNK = 4096


@functools.partial(jax.jit, static_argnames=("kernel",))
def _wave_d2(xt: Array, sv: Array, kernel: str) -> Array:
    """(n_slots, m, d) x (n_slots, k, d) -> (n_slots, m, k) cross-D²."""
    del kernel  # both built-ins factor through the same D²
    return jax.vmap(lambda a, b: km_ops.sq_dists(a, b))(xt, sv)


@functools.partial(jax.jit, static_argnames=("kernel",))
def _decide_cells(d2: Array, gammas: Array, coefs: Array, kernel: str) -> Array:
    """Per-gamma epilogue + contraction over a cached wave D².

    d2 (C, m, k); gammas (C, P); coefs (C, k, P) -> (C, m, P).  Column
    structure mirrors ``TrainedSVM.decision_function`` exactly (vmap of
    ``gram_from_d2(d2, g) @ coef`` over the flattened (task, sub) axis), so
    the f32 path is bit-identical to per-cell decision functions.
    """

    def cell(d2_c, g_c, co_c):
        def col(g, co):
            return km_ops.gram_from_d2(d2_c, g, kind=kernel) @ co

        return jax.vmap(col)(g_c, co_c.T).T

    return jax.vmap(cell)(d2, gammas, coefs)


@functools.partial(jax.jit, static_argnames=("kernel",))
def _sweep_cells(d2: Array, sweep_gammas: Array, coefs: Array,
                 kernel: str) -> Array:
    """Replay the epilogue for a whole gamma grid over one cached wave D².

    (C, m, k) x (G,) x (C, k, P) -> (G, C, m, P): the multi-gamma serving
    scan — no MXU work at all, the D² was paid when the wave first ran.
    """

    def per_g(g):
        gg = jnp.full((d2.shape[0], coefs.shape[2]), g, jnp.float32)
        return _decide_cells(d2, gg, coefs, kernel)

    return jax.vmap(per_g)(sweep_gammas)


class SVMEngine:
    """Serve micro-batched queries against a compacted :class:`ModelBank`."""

    def __init__(
        self,
        bank: ModelBank,
        *,
        fused: Optional[bool] = None,
        cache_dtype: str = "f32",
        row_bucket: int = 8,
        slot_bucket: int = 4,
        max_cached_d2: int = 8,
    ):
        if cache_dtype not in ("f32", "bf16"):
            raise ValueError(f"cache_dtype must be f32|bf16, got {cache_dtype!r}")
        self.bank = bank
        self.fused = runtime.on_tpu() if fused is None else bool(fused)
        self.cache_dtype = cache_dtype
        self.row_bucket = row_bucket
        self.slot_bucket = slot_bucket
        self.max_cached_d2 = max_cached_d2

        self._sv, self._coefs = bank.cell_arrays_f32()
        self._gammas = jnp.asarray(bank.gammas, jnp.float32)
        self._centers = np.asarray(bank.centers, np.float32)

        self._queues: List[List[Tuple[int, np.ndarray]]] = [
            [] for _ in range(bank.n_cells)]
        self._next_id = 0
        self._d2_cache: "collections.OrderedDict[bytes, Array]" = \
            collections.OrderedDict()
        self._last_wave: Optional[dict] = None
        self.counters = collections.Counter()

    # ------------------------------------------------------------- ingestion
    def route(self, x: np.ndarray) -> np.ndarray:
        """Nearest-center Voronoi cell ids for already-scaled queries.

        Same chunked GEMM-form helper the training plan uses
        (``CellPlan.route``), so serve-time routing and the decomposition's
        ownership rule cannot drift apart.
        """
        from repro.pipeline.assign import nearest_center
        return nearest_center(x, self._centers,
                              chunk_size=_ROUTE_CHUNK).astype(np.int64)

    def submit(self, x: np.ndarray) -> np.ndarray:
        """Enqueue queries (raw feature space); returns request ids."""
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        xs = (x - self.bank.feat_mean) / self.bank.feat_std
        cells = self.route(xs)
        ids = np.arange(self._next_id, self._next_id + x.shape[0], dtype=np.int64)
        self._next_id += x.shape[0]
        for i, c in enumerate(cells):
            self._queues[int(c)].append((int(ids[i]), xs[i]))
        self.counters["submitted"] += x.shape[0]
        return ids

    @property
    def pending(self) -> int:
        return sum(len(q) for q in self._queues)

    # -------------------------------------------------------------- the step
    def step(self) -> Dict[int, np.ndarray]:
        """Drain pending queues with one batched launch.

        Returns {request_id: (n_tasks, n_sub) decision block}.
        """
        counts = np.asarray([len(q) for q in self._queues], np.int64)
        plan = plan_wave(counts, row_bucket=self.row_bucket,
                         slot_bucket=self.slot_bucket)
        if plan.n_requests == 0:
            return {}
        d = self._centers.shape[1]
        xt = np.zeros((plan.n_slots, plan.m_pad, d), np.float32)
        slot_ids: List[List[int]] = []
        for s in range(plan.n_slots):
            cid, off, take = (int(plan.slot_cell[s]), int(plan.slot_off[s]),
                              int(plan.slot_take[s]))
            ids_s = []
            if cid >= 0:
                for r, (rid, row) in enumerate(self._queues[cid][off:off + take]):
                    xt[s, r] = row
                    ids_s.append(rid)
            slot_ids.append(ids_s)

        cell_idx = np.maximum(plan.slot_cell, 0)     # padding slots: ignored rows
        dec = np.asarray(self._evaluate(jnp.asarray(xt),
                                        jnp.asarray(cell_idx), plan))

        results: Dict[int, np.ndarray] = {}
        t, s_count = self.bank.n_tasks, self.bank.n_sub
        for s, ids_s in enumerate(slot_ids):
            for r, rid in enumerate(ids_s):
                results[rid] = dec[s, r].reshape(t, s_count)
        for q in self._queues:
            q.clear()                                # plan consumed everything
        self.counters["steps"] += 1
        self.counters["served"] += plan.n_requests
        self.counters["launched_rows"] += plan.n_slots * plan.m_pad
        return results

    def _evaluate(self, xt: Array, cell_idx: Array, plan: WavePlan) -> Array:
        co_w = jnp.take(self._coefs, cell_idx, axis=0)
        ga_w = jnp.take(self._gammas, cell_idx, axis=0)
        if self.fused:
            # one fused Pallas launch; Gram tiles stay in VMEM
            sv_w = jnp.take(self._sv, cell_idx, axis=0)
            dec = sp_ops.svm_predict_cells(
                xt, sv_w, co_w, ga_w, kind=self.bank.kernel,
                force_pallas=not runtime.on_tpu())
            self._last_wave = {"xt": xt, "cell_idx": cell_idx, "d2": None}
            return dec
        d2 = self._d2_for(xt, cell_idx)
        self._last_wave = {"xt": xt, "cell_idx": cell_idx, "d2": d2}
        return _decide_cells(d2, ga_w, co_w, self.bank.kernel)

    # --------------------------------------------------- persistent wave D²
    def _wave_key(self, xt: Array, cell_idx: Array) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(np.asarray(xt).tobytes())
        h.update(np.asarray(cell_idx).tobytes())
        return h.digest()

    def _d2_for(self, xt: Array, cell_idx: Array) -> Array:
        key = self._wave_key(xt, cell_idx)
        hit = self._d2_cache.get(key)
        if hit is not None:
            self._d2_cache.move_to_end(key)
            self.counters["d2_hits"] += 1
            return hit
        self.counters["d2_misses"] += 1
        sv_w = jnp.take(self._sv, cell_idx, axis=0)
        d2 = _wave_d2(xt, sv_w, self.bank.kernel)
        if self.cache_dtype == "bf16":
            d2 = d2.astype(jnp.bfloat16)
        self._d2_cache[key] = d2
        while len(self._d2_cache) > self.max_cached_d2:
            self._d2_cache.popitem(last=False)
        return d2

    def sweep_gammas(self, gammas: np.ndarray) -> Array:
        """Re-evaluate the LAST wave for a whole gamma grid.

        The cached cross-D² is replayed through the per-gamma epilogue only
        — (G,) gammas cost G VPU passes, zero MXU cross terms.  Returns
        (G, n_slots, m_pad, P) raw slot decisions (padding rows included).
        """
        if self._last_wave is None:
            raise RuntimeError("no wave evaluated yet — call step() first")
        w = self._last_wave
        d2 = w["d2"]
        if d2 is None:                    # fused launch kept no D²; build it
            d2 = self._d2_for(w["xt"], w["cell_idx"])
        co_w = jnp.take(self._coefs, w["cell_idx"], axis=0)
        return _sweep_cells(d2, jnp.asarray(gammas, jnp.float32), co_w,
                            self.bank.kernel)

    # ------------------------------------------------------------ high level
    def predict(self, x: np.ndarray) -> np.ndarray:
        """(m, d) -> (m, n_tasks, n_sub): submit + drain, original order."""
        ids = self.submit(x)
        results: Dict[int, np.ndarray] = {}
        while self.pending:
            results.update(self.step())
        return np.stack([results[int(i)] for i in ids])

    def predict_label(self, x: np.ndarray,
                      sub: Optional[int] = None) -> np.ndarray:
        """Scenario labels; ``sub=None`` reads the bank's default column
        (the select stage's NP weight pick for npsvm banks)."""
        if sub is None:
            sub = self.bank.default_sub
        return combine_decisions(self.predict(x), self.bank.scenario,
                                 classes=self.bank.classes,
                                 pairs=self.bank.pairs, sub=sub)

    def stats(self) -> dict:
        out = dict(self.counters)
        out["pad_fraction"] = 1.0 - (out.get("served", 0)
                                     / max(out.get("launched_rows", 0), 1))
        out["cached_d2_waves"] = len(self._d2_cache)
        out["cached_d2_bytes"] = int(sum(a.size * a.dtype.itemsize
                                         for a in self._d2_cache.values()))
        return out
