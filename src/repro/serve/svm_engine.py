"""Cell-routed SVM serving engine: overlap routing, async admission, deadlines.

The paper's test phase at serving scale.  Every query is Voronoi-routed
host-side (the same nearest-center rule the training decomposition uses),
requests accumulate per cell, and each launch drains the queues with ONE
batched launch over all active cells:

  * :func:`repro.distributed.planner.plan_wave` turns the ragged per-cell
    queue depths into a static launch layout — hot cells are chunked into
    several slots, cold cells padded a little, shapes bucketed so repeated
    steps reuse compiled programs;
  * on TPU the launch is the fused ``svm_predict_cells`` Pallas kernel (one
    kernel for the whole wave; Gram tiles never touch HBM); elsewhere it is
    the batched distance-cache path;
  * the wave's gamma-independent cross-D² is kept as a persistent
    :class:`CachedGram`-style cache keyed by the routed batch
    (``cache_dtype="bf16"`` halves it); ``sweep_gammas`` replays only the
    VPU epilogue.

Three serving behaviours layer on top of the batched launch:

  * **overlap routing** — banks built from ``voronoi=5`` (overlap) models
    were TRAINED on 2-cell ownership; serving them 1-NN throws half the
    training signal away.  With ``routing="overlap"`` each request is
    routed to its 2 nearest centers via the SAME
    ``pipeline.assign._top2_chunk`` core the cell builder uses (tie-breaks
    cannot drift) and the two cells' decision blocks are blended with
    distance-softmax weights (:func:`blend_weights`; exactly (0.5, 0.5) for
    equidistant rows, exactly (1, 0) when no second cell is reachable —
    and the engine falls back to exact 1-NN when the bank says
    ``routing="nearest"`` or has fewer than two cells);
  * **async admission** — ``begin_step()`` snapshots the admission queues
    into one wave and DISPATCHES it without blocking; ``submit()`` stays
    legal while the wave is in flight (a double-buffered queue pair, the
    PR-3 wave-prefetch pattern), so host-side routing/packing of wave w+1
    overlaps the device work of wave w; ``finish_step()`` collects.
    ``step()`` is the synchronous begin+finish pair and is bitwise
    identical to the old strictly-synchronous engine;
  * **latency-bounded stepping** — :meth:`run` drives an arrival stream
    and launches when the queued rows would fill a bucketed wave OR the
    oldest queued request's age crosses ``deadline_ms``; every launch
    records occupancy and a request-age histogram (``wave_stats``,
    aggregated by ``stats()`` and exported by
    ``benchmarks/serve_throughput.py`` into ``BENCH_serve.json``).

Slots are LPT-ordered by :func:`plan_wave`, so sharding the slot axis over a
mesh (as ``distributed.cell_trainer`` does for training) inherits balanced
waves; this engine runs the single-host slice of that story.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import hashlib
import time
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.distributed.planner import WavePlan, plan_wave
from repro.kernels import runtime
from repro.kernels.kernel_matrix import ops as km_ops
from repro.kernels.svm_predict import ops as sp_ops
from repro.obs import jaxprof
from repro.obs.trace import RingBuffer
from repro.pipeline.assign import nearest_center, nearest_top2_dists
from repro.serve.model_bank import ModelBank
from repro.tasks.builder import combine_decisions
from repro.testing import faults

Array = jax.Array

_ROUTE_CHUNK = 4096

# request-age histogram bucket upper edges (ms); the last bucket is open
AGE_BUCKETS_MS = (1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0)

# rid -> serving bank version attributions kept for late readers (bounded:
# overload protection must bound EVERY per-request structure)
_SERVED_VERSION_CAP = 65536

# recent-wave detail window; exact aggregates live in running sums so a
# long-running serve loop cannot grow memory by being observed
_WAVE_STATS_CAP = 512

# the per-wave host stages every served response decomposes into
_STAGES = ("queue", "pack", "dispatch", "device", "collect")


class OverloadError(RuntimeError):
    """Admission rejected by the bounded queue (graceful degradation).

    Carries a machine-readable ``code`` and ``retryable=True``: the queue
    drains at the next wave, so the caller should back off and retry
    rather than treat this as a hard failure.  No request id is assigned —
    a shed request was never admitted.
    """

    code = "ENGINE_OVERLOADED"
    retryable = True


def blend_weights(d1: np.ndarray, d2: np.ndarray
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Distance-softmax blend weights for a request's two nearest cells.

    ``softmax(-d²)`` over the pair, computed stably from the non-negative
    gap: ``w1 = 1 / (1 + exp(-(d2 - d1)))``, ``w2 = 1 - w1`` (f32).  An
    exactly equidistant row gets exactly ``(0.5, 0.5)``; a second cell far
    enough that the gap underflows ``exp`` gets exactly ``(1.0, 0.0)`` —
    the engine then enqueues a single part, which is also how padding-slot
    ``_FAR`` centers drop out of blending.
    """
    delta = np.asarray(d2, np.float32) - np.asarray(d1, np.float32)
    w1 = (np.float32(1.0) / (np.float32(1.0) + np.exp(-delta))).astype(
        np.float32)
    return w1, np.float32(1.0) - w1


@dataclasses.dataclass
class _Request:
    """Blend state of one submitted request.

    Parts arrive from (possibly different) waves in any order; the blend
    ``sum_p w_p * vals[p]`` is evaluated in FIXED part order once every
    part landed, so completion numerics are independent of the
    async/sync interleaving that served the parts.
    """
    weights: Tuple[np.float32, ...]
    vals: List[Optional[np.ndarray]]
    ts: float
    left: int
    raw: np.ndarray     # original (unscaled) feature row: a hot swap
                        # re-scales + re-routes still-queued requests
                        # against the new bank's scaling and centers
    version: int        # bank version the request is currently routed with


@functools.partial(jax.jit, static_argnames=("kernel",))
def _wave_d2(xt: Array, sv: Array, kernel: str) -> Array:
    """(n_slots, m, d) x (n_slots, k, d) -> (n_slots, m, k) cross-D²."""
    del kernel  # both built-ins factor through the same D²
    return jax.vmap(lambda a, b: km_ops.sq_dists(a, b))(xt, sv)


@functools.partial(jax.jit, static_argnames=("kernel",))
def _decide_cells(d2: Array, gammas: Array, coefs: Array, kernel: str) -> Array:
    """Per-gamma epilogue + contraction over a cached wave D².

    d2 (C, m, k); gammas (C, P); coefs (C, k, P) -> (C, m, P).  Column
    structure mirrors ``TrainedSVM.decision_function`` exactly (vmap of
    ``gram_from_d2(d2, g) @ coef`` over the flattened (task, sub) axis), so
    the f32 path is bit-identical to per-cell decision functions.
    """

    def cell(d2_c, g_c, co_c):
        def col(g, co):
            return km_ops.gram_from_d2(d2_c, g, kind=kernel) @ co

        return jax.vmap(col)(g_c, co_c.T).T

    return jax.vmap(cell)(d2, gammas, coefs)


@functools.partial(jax.jit, static_argnames=("kernel",))
def _sweep_cells(d2: Array, sweep_gammas: Array, coefs: Array,
                 kernel: str) -> Array:
    """Replay the epilogue for a whole gamma grid over one cached wave D².

    (C, m, k) x (G,) x (C, k, P) -> (G, C, m, P): the multi-gamma serving
    scan — no MXU work at all, the D² was paid when the wave first ran.
    """

    def per_g(g):
        gg = jnp.full((d2.shape[0], coefs.shape[2]), g, jnp.float32)
        return _decide_cells(d2, gg, coefs, kernel)

    return jax.vmap(per_g)(sweep_gammas)


class SVMEngine:
    """Serve micro-batched queries against a compacted :class:`ModelBank`.

    ``overlap=None`` reads the bank's recorded routing mode (set by
    ``SelectResult.to_bank()`` for ``VORONOI=5`` fits); ``deadline_ms``
    is the default latency bound for :meth:`run`; ``clock`` is injectable
    for deterministic deadline/shedding tests.

    Overload protection: ``max_queue`` bounds the admission queue in launch
    rows — a ``submit()`` that would exceed it raises :class:`OverloadError`
    (retry-able, no id assigned) instead of growing memory without bound;
    ``shed_ms`` additionally rejects NEW admissions while the oldest queued
    request is older than the bound (deadline-based shedding: when the
    engine is this far behind, new arrivals would miss their deadline
    anyway, so they are turned away while the backlog drains).

    Hot swap: :meth:`swap_bank` replaces the bank mid-flight — see its
    docstring.  ``swap_poll_ms`` is carried for the serve-loop watcher
    (``repro.cli serve --swap-watch`` polls the bank directory at this
    interval); the engine itself never polls.

    Observability: every wave's pack/dispatch/device/collect host stages
    are timed unconditionally (one ``clock()`` read per boundary) into
    ``wave_stats`` (bounded ring + exact running aggregates, see
    ``stats()["per_stage"]``), every completed request gets a
    queue/pack/dispatch/device/collect breakdown (:meth:`breakdown`), and
    the same timestamps feed the ``tracer``/``metrics`` instruments —
    defaulting to the process-global ``repro.obs`` pair, injectable for
    tests.  A disabled tracer costs one attribute test per site.
    """

    def __init__(
        self,
        bank: ModelBank,
        *,
        fused: Optional[bool] = None,
        cache_dtype: str = "f32",
        row_bucket: int = 8,
        slot_bucket: int = 4,
        max_cached_d2: int = 8,
        overlap: Optional[bool] = None,
        deadline_ms: Optional[float] = None,
        fill_rows: Optional[int] = None,
        max_queue: Optional[int] = None,
        shed_ms: Optional[float] = None,
        swap_poll_ms: Optional[float] = None,
        clock: Callable[[], float] = time.monotonic,
        tracer: Optional["obs.Tracer"] = None,
        metrics: Optional["obs.MetricsRegistry"] = None,
    ):
        if cache_dtype not in ("f32", "bf16"):
            raise ValueError(f"cache_dtype must be f32|bf16, got {cache_dtype!r}")
        self.fused = runtime.on_tpu() if fused is None else bool(fused)
        self.cache_dtype = cache_dtype
        self.row_bucket = row_bucket
        self.slot_bucket = slot_bucket
        self.max_cached_d2 = max_cached_d2
        self._overlap_pref = overlap
        self.deadline_ms = deadline_ms
        # "m_pad fills": one bucketed wave's worth of rows triggers a launch
        self.fill_rows = (row_bucket * slot_bucket if fill_rows is None
                          else int(fill_rows))
        self.max_queue = None if max_queue is None else int(max_queue)
        self.shed_ms = None if shed_ms is None else float(shed_ms)
        self.swap_poll_ms = swap_poll_ms
        self._clock = clock

        self._reqs: Dict[int, _Request] = {}
        self._inflight: Optional[tuple] = None
        self._next_id = 0
        self._d2_cache: "collections.OrderedDict[bytes, Array]" = \
            collections.OrderedDict()
        self._last_wave: Optional[dict] = None
        self.counters = collections.Counter()
        # recent-wave window; stats() aggregates come from the running
        # sums below so they stay EXACT after the ring wraps
        self.wave_stats = RingBuffer(_WAVE_STATS_CAP)
        self._occ_sum = 0.0
        self._age_ms_max = 0.0
        self._age_hist_sum = [0] * (len(AGE_BUCKETS_MS) + 1)
        self._stage_ms = {s: 0.0 for s in _STAGES}
        self._stage_n = {s: 0 for s in _STAGES}
        # rid -> bank version that served it (bounded; see swap_bank)
        self.served_version: "collections.OrderedDict[int, int]" = \
            collections.OrderedDict()
        # rid -> per-stage latency breakdown of the completing wave
        # (bounded like served_version; read via breakdown())
        self.served_breakdown: "collections.OrderedDict[int, dict]" = \
            collections.OrderedDict()
        self._tracer = obs.tracer if tracer is None else tracer
        self._metrics = obs.metrics if metrics is None else metrics
        self._m_request_ms = self._metrics.histogram("serve.request_ms")
        self._m_request_q = self._metrics.sketch("serve.request_ms.q")
        self._m_served = self._metrics.counter("serve.served")
        self._m_shed = self._metrics.counter("serve.shed")
        self._m_waves = self._metrics.counter("serve.waves")
        # health monitor (serve.monitor.HealthMonitor attaches itself);
        # detached cost is one `is not None` test per batch/wave
        self._monitor = None
        self._bind_bank(bank)

    def attach_monitor(self, monitor) -> None:
        """Attach (or detach with ``None``) a health monitor.  The engine
        feeds it per-batch routing distances (``observe_routing``) and
        per-wave completed-request latencies (``observe_requests``)."""
        self._monitor = monitor

    def _bind_bank(self, bank: ModelBank) -> None:
        """Point every bank-derived structure at ``bank``.

        Fresh admission queues are sized to the new cell count; the wave-D²
        cache and the last-wave handle are dropped (they index the OLD
        bank's SV tables).  An in-flight wave is untouched — it carries its
        own snapshot of everything it needs (see ``begin_step``).
        """
        self.bank = bank
        # 1-NN fallback is EXACT: a bank built with voronoi<5 records
        # routing="nearest", and blending needs a second center to exist
        want = ((bank.routing == "overlap") if self._overlap_pref is None
                else bool(self._overlap_pref))
        if want and bank.n_cells < 2:
            self.counters["routing_degraded"] += 1
        self.overlap = want and bank.n_cells >= 2

        self._sv, self._coefs = bank.cell_arrays_f32()
        self._gammas = jnp.asarray(bank.gammas, jnp.float32)
        self._centers = np.asarray(bank.centers, np.float32)

        # admission buffer: per-cell (rid, part, row); begin_step snapshots
        # it into a wave and swaps in a fresh buffer (double buffering)
        self._queues: List[List[Tuple[int, int, np.ndarray]]] = [
            [] for _ in range(bank.n_cells)]
        self._d2_cache.clear()
        self._last_wave = None

    # ------------------------------------------------------------- ingestion
    def route(self, x: np.ndarray) -> np.ndarray:
        """Nearest-center Voronoi cell ids for already-scaled queries.

        Same chunked GEMM-form helper the training plan uses
        (``CellPlan.route``), so serve-time routing and the decomposition's
        ownership rule cannot drift apart.
        """
        return nearest_center(x, self._centers,
                              chunk_size=_ROUTE_CHUNK).astype(np.int64)

    def route_top2(self, x: np.ndarray
                   ) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Two nearest cells + blend weights for already-scaled queries.

        ``pipeline.assign.nearest_top2_dists`` — the overlap cell builder's
        ``_top2_chunk`` core, not a reimplementation — so the serve-time
        pair (tie-breaking included) matches the 2-cell training ownership.
        """
        c1, c2, d1, d2 = nearest_top2_dists(x, self._centers,
                                            chunk_size=_ROUTE_CHUNK)
        w1, w2 = blend_weights(d1, d2)
        return c1.astype(np.int64), c2.astype(np.int64), w1, w2

    def submit(self, x: np.ndarray, now: Optional[float] = None) -> np.ndarray:
        """Enqueue queries (raw feature space); returns request ids.

        Legal at ANY time, including while a wave is in flight — admission
        lands in the fresh queue buffer and is consumed by the next
        ``begin_step()``.  Overlap banks enqueue up to two weighted parts
        per request; parts are merged at completion (``finish_step``).

        With a bounded queue (``max_queue`` / ``shed_ms``) an over-limit
        batch raises :class:`OverloadError` BEFORE any id is assigned —
        admission is all-or-nothing per batch, so a shed batch leaves no
        partial state behind.
        """
        x = np.asarray(x, np.float32)
        if x.ndim == 1:
            x = x[None, :]
        faults.fire("engine.submit", rows=x.shape[0])
        ts = float(self._clock()) if now is None else float(now)
        if x.shape[0]:
            self._admission_check(x.shape[0], ts)
        ids = np.arange(self._next_id, self._next_id + x.shape[0],
                        dtype=np.int64)
        self._next_id += x.shape[0]
        self._enqueue(x, ids, np.full((x.shape[0],), ts, np.float64))
        self.counters["submitted"] += x.shape[0]
        return ids

    def _admission_check(self, m: int, now: float) -> None:
        """Bounded-queue gate; raises :class:`OverloadError` to shed."""
        if self.max_queue is not None:
            parts = m * (2 if self.overlap else 1)
            if self.pending + parts > self.max_queue:
                self.counters["shed_overflow"] += 1
                self.counters["shed_rows"] += m
                self._m_shed.inc()
                raise OverloadError(
                    f"[{OverloadError.code}] admission queue full "
                    f"({self.pending} parts queued, batch needs {parts}, "
                    f"max_queue={self.max_queue}); retry after a step")
        if self.shed_ms is not None and self.pending:
            age = self.oldest_age_ms(now)
            if age >= self.shed_ms:
                self.counters["shed_stale"] += 1
                self.counters["shed_rows"] += m
                self._m_shed.inc()
                raise OverloadError(
                    f"[{OverloadError.code}] backlog too stale (oldest "
                    f"queued request {age:.1f} ms >= shed_ms="
                    f"{self.shed_ms}); retry after the backlog drains")

    def _enqueue(self, x_raw: np.ndarray, ids: np.ndarray,
                 ts: np.ndarray) -> None:
        """Scale, route and queue rows under the CURRENT bank (used by
        both fresh admission and post-swap re-admission, which is why raw
        rows and per-row timestamps come in explicitly)."""
        xs = (x_raw - self.bank.feat_mean) / self.bank.feat_std
        version = int(self.bank.version)
        if self.overlap:
            with self._tracer.span("serve.route"):
                c1, c2, w1, w2 = self.route_top2(xs)
            if self._monitor is not None:
                self._observe_routing(xs, c1)
            for i, rid in enumerate(map(int, ids)):
                parts = [(int(c1[i]), np.float32(w1[i]))]
                if w2[i] > 0.0:          # unreachable 2nd cell: single part
                    parts.append((int(c2[i]), np.float32(w2[i])))
                self._reqs[rid] = _Request(
                    weights=tuple(w for _, w in parts),
                    vals=[None] * len(parts), ts=float(ts[i]),
                    left=len(parts), raw=x_raw[i], version=version)
                for p, (c, _) in enumerate(parts):
                    self._queues[c].append((rid, p, xs[i]))
        else:
            with self._tracer.span("serve.route"):
                cells = self.route(xs)
            if self._monitor is not None:
                self._observe_routing(xs, cells)
            for i, rid in enumerate(map(int, ids)):
                self._reqs[rid] = _Request(
                    weights=(np.float32(1.0),), vals=[None],
                    ts=float(ts[i]), left=1, raw=x_raw[i], version=version)
                self._queues[int(cells[i])].append((rid, 0, xs[i]))

    def _observe_routing(self, xs: np.ndarray, primary: np.ndarray) -> None:
        """Feed the attached monitor each row's squared distance to its
        PRIMARY routing center — O(m*d), uniform across the nearest and
        overlap paths, and the same quantity the bank's train-time
        ``route_baseline`` recorded."""
        diff = xs - self._centers[primary]
        d2 = np.einsum("ij,ij->i", diff, diff)
        self._monitor.observe_routing(primary, d2,
                                      now=float(self._clock()))

    # ------------------------------------------------------------- hot swap
    def swap_bank(self, new_bank: ModelBank, *, force: bool = False) -> dict:
        """Swap the serving bank, mid-flight, with zero downtime.

        The in-flight wave (if any) FINISHES on the old bank — it was
        dispatched with a full snapshot (decisions, entry map, shape,
        version), so nothing it needs is rebound.  Still-QUEUED requests
        are re-admitted against the new bank: re-scaled with its feature
        scaling, re-routed against its centers, original request ids and
        admission timestamps preserved.  This is whole-request by
        construction — ``begin_step`` drains every queue into the wave, so
        a request is either fully in flight or fully queued, never split
        across banks.

        Versions are monotonic: ``new_bank.version`` must be strictly
        greater than the serving version unless ``force=True`` (an
        emergency rollback; counted as ``bank_fallbacks``).  The new bank
        must be decision-compatible (same feature dim and (n_tasks, n_sub)
        block shape); cell count, SV tables, routing mode and scaling may
        all change freely.

        Returns ``{"version", "requeued"}``; counters: ``swaps``,
        ``swap_requeued``, ``bank_fallbacks``, ``routing_degraded``.
        """
        faults.fire("engine.swap")
        d_old = self._centers.shape[1]
        d_new = np.asarray(new_bank.centers).shape[1]
        if d_new != d_old:
            raise ValueError(
                f"swap_bank: feature dim changed ({d_old} -> {d_new})")
        if (new_bank.n_tasks, new_bank.n_sub) != (self.bank.n_tasks,
                                                  self.bank.n_sub):
            raise ValueError(
                "swap_bank: decision block shape changed "
                f"(({self.bank.n_tasks}, {self.bank.n_sub}) -> "
                f"({new_bank.n_tasks}, {new_bank.n_sub}))")
        if int(new_bank.version) <= int(self.bank.version):
            if not force:
                raise ValueError(
                    f"swap_bank: version must be strictly newer (serving "
                    f"v{self.bank.version}, offered v{new_bank.version}); "
                    f"pass force=True to roll back")
            self.counters["bank_fallbacks"] += 1

        queued_rids: List[int] = []
        seen = set()
        for q in self._queues:
            for rid, _part, _row in q:
                if rid not in seen:
                    seen.add(rid)
                    queued_rids.append(rid)
        requeue = [(rid, self._reqs.pop(rid)) for rid in queued_rids]

        self._bind_bank(new_bank)

        if requeue:
            raws = np.stack([r.raw for _, r in requeue]).astype(np.float32)
            ids = np.asarray([rid for rid, _ in requeue], np.int64)
            ts = np.asarray([r.ts for _, r in requeue], np.float64)
            self._enqueue(raws, ids, ts)
            self.counters["swap_requeued"] += len(requeue)
        self.counters["swaps"] += 1
        return {"version": int(new_bank.version), "requeued": len(requeue)}

    @property
    def pending(self) -> int:
        """Queued launch rows (overlap requests count once per part)."""
        return sum(len(q) for q in self._queues)

    @property
    def in_flight(self) -> bool:
        return self._inflight is not None

    def oldest_age_ms(self, now: Optional[float] = None) -> float:
        """Age of the oldest QUEUED (not yet launched) request, ms."""
        now = float(self._clock()) if now is None else float(now)
        ts = [self._reqs[rid].ts for q in self._queues for (rid, _, _) in q]
        return 0.0 if not ts else (now - min(ts)) * 1e3

    # -------------------------------------------------------------- the step
    def begin_step(self) -> bool:
        """Snapshot the admission queues into one wave and DISPATCH it.

        Non-blocking: the batched launch is left in flight on the device
        and a fresh admission buffer is swapped in, so routing/packing of
        the next wave (and any amount of ``submit()`` traffic) overlaps
        the device work.  Returns False when nothing was queued.
        """
        if self._inflight is not None:
            raise RuntimeError(
                "a wave is already in flight - call finish_step() first")
        faults.fire("engine.begin_step")
        t_begin = float(self._clock())
        counts = np.asarray([len(q) for q in self._queues], np.int64)
        plan = plan_wave(counts, row_bucket=self.row_bucket,
                         slot_bucket=self.slot_bucket)
        if plan.n_requests == 0:
            return False
        queues, self._queues = self._queues, [
            [] for _ in range(self.bank.n_cells)]
        d = self._centers.shape[1]
        xt = np.zeros((plan.n_slots, plan.m_pad, d), np.float32)
        slot_entries: List[List[Tuple[int, int]]] = []
        now = float(self._clock())
        ages: List[float] = []
        for s in range(plan.n_slots):
            cid, off, take = (int(plan.slot_cell[s]), int(plan.slot_off[s]),
                              int(plan.slot_take[s]))
            entries: List[Tuple[int, int]] = []
            if cid >= 0:
                for r, (rid, part, row) in enumerate(queues[cid][off:off + take]):
                    xt[s, r] = row
                    entries.append((rid, part))
                    ages.append((now - self._reqs[rid].ts) * 1e3)
            slot_entries.append(entries)
        t_pack = float(self._clock())

        cell_idx = np.maximum(plan.slot_cell, 0)     # padding slots: ignored rows
        with jaxprof.step("serve_wave", self.wave_stats.total):
            dec = self._evaluate(jnp.asarray(xt), jnp.asarray(cell_idx), plan)
        t_disp = float(self._clock())
        rec = self._record_wave(plan, ages,
                                pack_ms=(t_pack - t_begin) * 1e3,
                                dispatch_ms=(t_disp - t_pack) * 1e3)
        # full snapshot: a swap_bank between begin and finish must not
        # change what this wave returns or which version it is tagged with
        # (rec rides along so finish_step can attach device/collect times)
        self._inflight = (plan, slot_entries, dec,
                          self.bank.n_tasks, self.bank.n_sub,
                          int(self.bank.version), rec)
        self._tracer.record("serve.pack", t_begin, t_pack)
        self._tracer.record("serve.dispatch", t_pack, t_disp)
        self._m_waves.inc()
        self.counters["steps"] += 1
        return True

    def finish_step(self) -> Dict[int, np.ndarray]:
        """Collect the in-flight wave (blocking).

        Returns ``{request_id: (n_tasks, n_sub) decision block}`` for every
        request COMPLETED by this wave — an overlap request whose second
        part is still queued stays pending and is returned by the wave that
        serves its last part.  Blending (``sum_p w_p * part_p``) happens
        here, in fixed part order, in f32.

        Every completion is attributed to the bank version the wave was
        DISPATCHED with (``served_version[rid]``, plus a per-version
        ``served_v<N>`` counter) — under a mid-flight swap, old-wave
        responses carry the old version and post-swap admissions the new
        one, so every response is attributable to exactly one bank.
        """
        if self._inflight is None:
            return {}
        plan, slot_entries, dec, t, s_count, version, rec = self._inflight
        self._inflight = None
        t_wait = float(self._clock())
        dec = np.asarray(dec)
        t_dev = float(self._clock())
        results: Dict[int, np.ndarray] = {}
        done_ts: List[Tuple[int, float]] = []
        for s, entries in enumerate(slot_entries):
            for r, (rid, part) in enumerate(entries):
                req = self._reqs[rid]
                req.vals[part] = dec[s, r].reshape(t, s_count)
                req.left -= 1
                if req.left == 0:
                    out = req.weights[0] * req.vals[0]
                    for p in range(1, len(req.vals)):
                        out = out + req.weights[p] * req.vals[p]
                    results[rid] = out
                    del self._reqs[rid]
                    done_ts.append((rid, req.ts))
                    self.served_version[rid] = version
                    while len(self.served_version) > _SERVED_VERSION_CAP:
                        self.served_version.popitem(last=False)
        t_col = float(self._clock())
        device_ms = (t_dev - t_wait) * 1e3
        collect_ms = (t_col - t_dev) * 1e3
        rec["device_ms"] = device_ms
        rec["collect_ms"] = collect_ms
        self._stage_ms["device"] += device_ms
        self._stage_ms["collect"] += collect_ms
        self._stage_n["device"] += 1
        self._stage_n["collect"] += 1
        self._tracer.record("serve.device", t_wait, t_dev)
        self._tracer.record("serve.collect", t_dev, t_col)
        # per-response latency attribution: total is exact; queue is the
        # residual (time not spent in this wave's pack/dispatch/device/
        # collect — i.e. waiting in the admission queue or an earlier wave)
        wave_ms = rec["pack_ms"] + rec["dispatch_ms"] + device_ms + collect_ms
        totals: List[float] = []
        for rid, ts in done_ts:
            total_ms = (t_col - ts) * 1e3
            totals.append(total_ms)
            queue_ms = max(total_ms - wave_ms, 0.0)
            self._stage_ms["queue"] += queue_ms
            self._stage_n["queue"] += 1
            self._m_request_ms.observe(total_ms)
            self._m_request_q.observe(total_ms)
            self.served_breakdown[rid] = {
                "wave": rec["wave"], "total_ms": total_ms,
                "queue_ms": queue_ms, "pack_ms": rec["pack_ms"],
                "dispatch_ms": rec["dispatch_ms"],
                "device_ms": device_ms, "collect_ms": collect_ms}
            while len(self.served_breakdown) > _SERVED_VERSION_CAP:
                self.served_breakdown.popitem(last=False)
                self.counters["breakdown_evicted"] += 1
        if self._monitor is not None and totals:
            self._monitor.observe_requests(totals, now=t_col)
        self._m_served.inc(len(results))
        self.counters["served"] += len(results)
        self.counters[f"served_v{version}"] += len(results)
        self.counters["served_rows"] += plan.n_requests
        # counted here, with served_rows, so stats() ratios stay consistent
        # while a wave is in flight
        self.counters["launched_rows"] += plan.n_slots * plan.m_pad
        return results

    def step(self) -> Dict[int, np.ndarray]:
        """Synchronous drain: dispatch (unless a wave is already in flight)
        and collect.  Bitwise-identical to the pre-async engine."""
        if self._inflight is None:
            self.begin_step()
        return self.finish_step()

    def _record_wave(self, plan: WavePlan, ages: List[float], *,
                     pack_ms: float, dispatch_ms: float) -> dict:
        """Append one wave record to the ring AND fold it into the running
        aggregates (``stats()`` reads the sums, so it stays exact after the
        ring wraps).  ``device_ms``/``collect_ms`` are filled in by
        ``finish_step`` mutating the returned dict."""
        a = np.asarray(ages, np.float64)
        hist = np.bincount(np.searchsorted(AGE_BUCKETS_MS, a, side="right"),
                           minlength=len(AGE_BUCKETS_MS) + 1)
        rec = {
            "wave": self.wave_stats.total,      # 0-based wave sequence no.
            "n_rows": plan.n_requests,
            "n_slots": plan.n_slots,
            "m_pad": plan.m_pad,
            "occupancy": plan.n_requests / max(plan.n_slots * plan.m_pad, 1),
            "oldest_ms": float(a.max()) if a.size else 0.0,
            "age_ms_mean": float(a.mean()) if a.size else 0.0,
            "age_hist": hist.tolist(),
            "pack_ms": pack_ms,
            "dispatch_ms": dispatch_ms,
            "device_ms": 0.0,
            "collect_ms": 0.0,
        }
        self.wave_stats.append(rec)
        self._occ_sum += rec["occupancy"]
        if rec["oldest_ms"] > self._age_ms_max:
            self._age_ms_max = rec["oldest_ms"]
        for i, n in enumerate(rec["age_hist"]):
            self._age_hist_sum[i] += n
        self._stage_ms["pack"] += pack_ms
        self._stage_ms["dispatch"] += dispatch_ms
        self._stage_n["pack"] += 1
        self._stage_n["dispatch"] += 1
        return rec

    def breakdown(self, rid: int) -> Optional[dict]:
        """Per-stage latency breakdown of a completed request:
        ``{wave, total_ms, queue_ms, pack_ms, dispatch_ms, device_ms,
        collect_ms}`` with ``total = queue + pack + dispatch + device +
        collect`` exactly (queue is the residual: admission-queue wait plus
        any earlier wave that served only part of an overlap request).

        ``None`` has two distinct causes a caller can tell apart:

          * the rid never completed here (unknown id, still pending, or
            shed) — ``stats()["breakdown_evicted"]`` is unchanged by such
            lookups and stays 0 on an engine that never wrapped;
          * the entry was EVICTED from the bounded ring (oldest-first, cap
            ``_SERVED_VERSION_CAP``) — every eviction increments
            ``breakdown_evicted``, so a nonzero counter says old rids are
            being dropped and a late reader holding one should treat its
            ``None`` as "aged out", not "never served".
        """
        return self.served_breakdown.get(int(rid))

    # -------------------------------------------------- latency-bounded run
    def should_launch(self, deadline_ms: Optional[float] = None,
                      now: Optional[float] = None) -> bool:
        """The launch policy: queued rows fill a bucketed wave, OR the
        oldest queued request's age crosses the deadline."""
        rows = self.pending
        if rows == 0:
            return False
        if rows >= self.fill_rows:
            return True
        deadline_ms = self.deadline_ms if deadline_ms is None else deadline_ms
        return (deadline_ms is not None
                and self.oldest_age_ms(now) >= deadline_ms)

    def run(self, traffic: Iterable[Optional[np.ndarray]],
            deadline_ms: Optional[float] = None,
            max_queue: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Latency-bounded async serving over an arrival stream.

        ``traffic`` yields request batches ((m, d) raw-feature arrays);
        yield ``None`` or an empty batch as an idle tick so the deadline
        can force a partially-filled launch.  Launches follow
        :meth:`should_launch`; each one is dispatched right after the
        PREVIOUS wave is collected, so admission and host routing/packing
        overlap device work.  Exhausting ``traffic`` drains everything.
        Returns ``{request_id: blended (n_tasks, n_sub) decision block}``
        for every ADMITTED request.

        ``max_queue`` (or the engine-level default) bounds the admission
        queue for the duration of the run: an arrival batch that would
        overflow is SHED — rejected with :class:`OverloadError` at
        admission, counted in ``shed_*``, never assigned an id — and the
        run continues.  Graceful degradation instead of unbounded memory.
        """
        results: Dict[int, np.ndarray] = {}
        prev_mq = self.max_queue
        if max_queue is not None:
            self.max_queue = int(max_queue)
        try:
            for batch in traffic:
                if batch is not None and np.size(batch):
                    try:
                        self.submit(batch)
                    except OverloadError:
                        pass             # shed; visible in shed_* counters
                if self.should_launch(deadline_ms):
                    if self._inflight is not None:
                        results.update(self.finish_step())
                    self.begin_step()
            if self._inflight is not None:
                results.update(self.finish_step())
            while self.pending:
                results.update(self.step())
        finally:
            self.max_queue = prev_mq
        return results

    def _evaluate(self, xt: Array, cell_idx: Array, plan: WavePlan) -> Array:
        co_w = jnp.take(self._coefs, cell_idx, axis=0)
        ga_w = jnp.take(self._gammas, cell_idx, axis=0)
        if self.fused:
            # one fused Pallas launch; Gram tiles stay in VMEM
            sv_w = jnp.take(self._sv, cell_idx, axis=0)
            dec = sp_ops.svm_predict_cells(
                xt, sv_w, co_w, ga_w, kind=self.bank.kernel,
                force_pallas=not runtime.on_tpu())
            self._last_wave = {"xt": xt, "cell_idx": cell_idx, "d2": None}
            return dec
        d2 = self._d2_for(xt, cell_idx)
        self._last_wave = {"xt": xt, "cell_idx": cell_idx, "d2": d2}
        return _decide_cells(d2, ga_w, co_w, self.bank.kernel)

    # --------------------------------------------------- persistent wave D²
    def _wave_key(self, xt: Array, cell_idx: Array) -> bytes:
        h = hashlib.blake2b(digest_size=16)
        h.update(np.asarray(xt).tobytes())
        h.update(np.asarray(cell_idx).tobytes())
        return h.digest()

    def _d2_for(self, xt: Array, cell_idx: Array) -> Array:
        key = self._wave_key(xt, cell_idx)
        hit = self._d2_cache.get(key)
        if hit is not None:
            self._d2_cache.move_to_end(key)
            self.counters["d2_hits"] += 1
            return hit
        self.counters["d2_misses"] += 1
        sv_w = jnp.take(self._sv, cell_idx, axis=0)
        d2 = _wave_d2(xt, sv_w, self.bank.kernel)
        if self.cache_dtype == "bf16":
            d2 = d2.astype(jnp.bfloat16)
        self._d2_cache[key] = d2
        while len(self._d2_cache) > self.max_cached_d2:
            self._d2_cache.popitem(last=False)
        return d2

    def sweep_gammas(self, gammas: np.ndarray) -> Array:
        """Re-evaluate the LAST wave for a whole gamma grid.

        The cached cross-D² is replayed through the per-gamma epilogue only
        — (G,) gammas cost G VPU passes, zero MXU cross terms.  Returns
        (G, n_slots, m_pad, P) raw slot decisions (padding rows included).
        """
        if self._last_wave is None:
            raise RuntimeError("no wave evaluated yet — call step() first")
        w = self._last_wave
        d2 = w["d2"]
        if d2 is None:                    # fused launch kept no D²; build it
            d2 = self._d2_for(w["xt"], w["cell_idx"])
        co_w = jnp.take(self._coefs, w["cell_idx"], axis=0)
        return _sweep_cells(d2, jnp.asarray(gammas, jnp.float32), co_w,
                            self.bank.kernel)

    # ------------------------------------------------------------ high level
    def predict(self, x: np.ndarray) -> np.ndarray:
        """(m, d) -> (m, n_tasks, n_sub): submit + drain, original order."""
        ids = self.submit(x)
        results: Dict[int, np.ndarray] = {}
        while self.pending or self._inflight is not None:
            results.update(self.step())
        if ids.size == 0:
            return np.zeros((0, self.bank.n_tasks, self.bank.n_sub),
                            np.float32)
        return np.stack([results[int(i)] for i in ids])

    def predict_label(self, x: np.ndarray,
                      sub: Optional[int] = None) -> np.ndarray:
        """Scenario labels; ``sub=None`` reads the bank's default column
        (the select stage's NP weight pick for npsvm banks)."""
        if sub is None:
            sub = self.bank.default_sub
        return combine_decisions(self.predict(x), self.bank.scenario,
                                 classes=self.bank.classes,
                                 pairs=self.bank.pairs, sub=sub)

    def stats(self) -> dict:
        out = dict(self.counters)
        # robustness counters are always visible, even at zero
        for k in ("swaps", "swap_requeued", "bank_fallbacks",
                  "routing_degraded", "shed_overflow", "shed_stale",
                  "shed_rows", "breakdown_evicted"):
            out.setdefault(k, 0)
        out["bank_version"] = int(self.bank.version)
        out["pending"] = self.pending
        out["pending_requests"] = len(self._reqs)
        out["routing"] = "overlap" if self.overlap else "nearest"
        launched = out.get("launched_rows", 0)
        out["pad_fraction"] = (1.0 - out.get("served_rows", 0) / launched
                               if launched else 0.0)
        out["cached_d2_waves"] = len(self._d2_cache)
        out["cached_d2_bytes"] = int(sum(a.size * a.dtype.itemsize
                                         for a in self._d2_cache.values()))
        # wave aggregates come from running sums, NOT the ring window, so
        # they cover every wave ever launched (exact after the ring wraps)
        out["waves"] = self.wave_stats.total
        out["wave_stats_dropped"] = self.wave_stats.dropped
        if self.wave_stats.total:
            out["occupancy_mean"] = self._occ_sum / self.wave_stats.total
            out["age_ms_max"] = self._age_ms_max
            out["age_hist"] = list(self._age_hist_sum)
        out["per_stage"] = {
            s: {"total_ms": self._stage_ms[s],
                "mean_ms": (self._stage_ms[s] / self._stage_n[s]
                            if self._stage_n[s] else 0.0),
                "count": self._stage_n[s]}
            for s in _STAGES}
        # true request-latency quantiles from the sketch (exact below its
        # cap, analytic rank-error bound above; see obs.sketch)
        if self._m_request_q.count:
            out["request_ms_q"] = self._m_request_q.summary()
        return out
