"""Incremental bank refresh: warm-start ONLY the cells new data touched.

The serving-side half of the ROADMAP's "online model bank" item.  A batch
of fresh labelled points arrives; retraining the whole fit to fold them in
would cost a full grid sweep, but the cell decomposition localizes the
change: a new point only alters the decision function of the cell it
routes to.  So the refresh

  1. routes the new points with the FIT's own plan (``CellPlan.route`` —
     the same rule serving uses, so drift lands exactly where queries will
     be routed);
  2. folds each point into its cell's staged rows (padding rows first,
     then a FIFO overwrite of the oldest rows when the cell is full — the
     cell size k is a static shape and stays put);
  3. re-solves every (task, sub) column of the DRIFTED cells only, at the
     already-selected (gamma, lambda) — one targeted
     ``repro.core.cv.solve_columns_at`` wave per (cell, selected gamma),
     the same warm path ``TrainResult.select`` uses, not a grid sweep
     (the Glasmachers recipe: warm-started re-solves make incremental
     updates cheap enough to run under traffic);
  4. compacts a new :class:`~repro.serve.model_bank.ModelBank` with the
     version bumped, ready for ``SVMEngine.swap_bank``.

Untouched cells keep their coefficient columns bitwise intact, and the
routing centers never move (they define cell ownership; moving them would
silently re-route traffic), so a refreshed bank is a drop-in swap: an
engine mid-traffic re-routes only its queued requests.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core import cv as cv_mod
from repro.serve.model_bank import ModelBank

if TYPE_CHECKING:                      # session imports are heavy; type-only
    from repro.api.session import SelectResult, TrainResult


def _labels_for(tasks, y_new: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-task (labels, mask) for new rows, under the FIT's task set.

    Mirrors ``repro.tasks.builder.make_tasks`` per scenario, but against
    the ORIGINAL class/pair tables — a refresh batch that happens to miss
    a class must not renumber the tasks.
    """
    y = np.asarray(y_new)
    kind = tasks.kind
    if kind in ("binary", "weighted"):
        lab = np.asarray(y, np.float32)[None, :]
        return lab, np.ones_like(lab)
    if kind == "ova":
        lab = np.stack([np.where(y == c, 1.0, -1.0)
                        for c in tasks.classes]).astype(np.float32)
        return lab, np.ones_like(lab)
    if kind == "ava":
        labs = []
        for a, b in np.asarray(tasks.pairs):
            labs.append(np.where(y == tasks.classes[a], 1.0,
                                 np.where(y == tasks.classes[b], -1.0, 0.0)))
        lab = np.asarray(labs, np.float32)
        return lab, (lab != 0.0).astype(np.float32)
    # regression scenarios: one task, raw targets
    lab = np.asarray(y, np.float32)[None, :]
    return np.repeat(lab, tasks.n_tasks, axis=0), \
        np.ones((tasks.n_tasks, y.shape[0]), np.float32)


def refresh_bank(
    tr: "TrainResult",
    sel: "SelectResult",
    x_new: np.ndarray,
    y_new: np.ndarray,
    *,
    base_version: Optional[int] = None,
    drop_tol: float | None = 0.0,
    dtype: str = "f32",
    dedup: bool = True,
) -> Tuple[ModelBank, dict]:
    """Fold new labelled points into the fit and build a swappable bank.

    Returns ``(bank, info)``: a bank whose version is ``base_version + 1``
    (default: one past the select output's base of 0) and an info dict
    (``drifted_slots``, ``rows_added``, ``rows_evicted``,
    ``resolve_calls``, ``columns_resolved``).  Cells no new point routed
    to are bitwise untouched.
    """
    x_new = np.asarray(x_new, np.float32)
    if x_new.ndim == 1:
        x_new = x_new[None, :]
    xs = tr.scaler.transform(x_new)
    lab_new, msk_new = _labels_for(tr.tasks, y_new)

    cell_of = tr.plan.route(xs)
    slot_of = np.asarray(tr.packed.slot_of_cell)[cell_of]

    x_cells = sel.x_cells.copy()
    mask_cells = sel.mask_cells.copy()
    y_cells = tr.y_cells.copy()
    tmask_cells = tr.tmask_cells.copy()
    coefs = sel.coefs.copy()

    k = x_cells.shape[1]
    info = {"drifted_slots": 0, "rows_added": 0, "rows_evicted": 0,
            "resolve_calls": 0, "columns_resolved": 0}

    n_tasks, n_sub = sel.gamma.shape[1], sel.gamma.shape[2]
    n_cols = n_tasks * n_sub
    if tr.cv_cfg.solver in ("quantile", "expectile"):
        sub_grid = np.asarray(tr.config.taus, np.float32)
    else:
        sub_grid = np.asarray(tr.config.weights, np.float32)

    for c in np.unique(slot_of):
        c = int(c)
        rows = np.flatnonzero(slot_of == c)
        if rows.size > k:                    # cell-sized batch: newest win
            rows = rows[-k:]
        # placement: padding rows first, then FIFO-overwrite the oldest
        free = np.flatnonzero(mask_cells[c] == 0)
        live = np.flatnonzero(mask_cells[c] > 0)
        pos = np.concatenate([free, live])[: rows.size]
        info["rows_evicted"] += int(max(rows.size - free.size, 0))
        info["rows_added"] += int(rows.size)
        x_cells[c, pos] = xs[rows]
        mask_cells[c, pos] = 1.0
        y_cells[c][:, pos] = lab_new[:, rows]
        tmask_cells[c][:, pos] = msk_new[:, rows]
        info["drifted_slots"] += 1

        # re-solve EVERY column of the drifted cell at its already-selected
        # (gamma, lambda) — grouped per selected gamma, padded to the same
        # static (T*S) width select() compiles (shared program); the
        # serving model being replaced is the warm start (the drift moved
        # some rows, not the whole solution)
        for gv in np.unique(sel.gamma[c]):
            ts = np.argwhere(sel.gamma[c] == gv)          # (m, 2)
            pad = np.concatenate(
                [ts, np.repeat(ts[:1], n_cols - len(ts), axis=0)])
            out, _, _ = cv_mod.solve_columns_at(
                jnp.asarray(x_cells[c]),
                jnp.asarray(y_cells[c]),
                jnp.asarray(tmask_cells[c]),
                jnp.asarray(mask_cells[c]),
                jnp.asarray(np.float32(gv)),
                jnp.asarray(sel.lam[c, pad[:, 0], pad[:, 1]], jnp.float32),
                jnp.asarray(sub_grid[pad[:, 1]], jnp.float32),
                jnp.asarray(pad[:, 0], jnp.int32),
                jnp.asarray(tr.fold_keys[c]),
                tr.cv_cfg,
                c0=jnp.asarray(sel.coefs[c][:, pad[:, 0], pad[:, 1]],
                               jnp.float32))              # (k, T*S)
            out = np.asarray(out)
            for j, (t, s) in enumerate(ts):
                coefs[c, :, t, s] = out[:, j]
            info["columns_resolved"] += len(ts)
            info["resolve_calls"] += 1

    if base_version is None:
        base_version = 0
    refreshed = dataclasses.replace(sel, x_cells=x_cells,
                                    mask_cells=mask_cells, coefs=coefs)
    bank = refreshed.to_bank(drop_tol=drop_tol, dtype=dtype, dedup=dedup,
                             version=int(base_version) + 1)
    return bank, info


def refresh_drifted(
    tr: "TrainResult",
    sel: "SelectResult",
    x_feed: np.ndarray,
    y_feed: np.ndarray,
    drifted_slots,
    **kwargs,
) -> Tuple[Optional[ModelBank], dict]:
    """Refresh EXACTLY the drifted cells from a labelled feedback pool.

    The closed loop's refresh half (``serve.monitor`` names the slots, this
    routes the feedback): feedback rows are routed with the fit's own plan
    and only those landing in ``drifted_slots`` are folded in, so
    :func:`refresh_bank` re-solves the drifted cells' columns and nothing
    else — cells the monitor did not flag stay bitwise intact even when the
    feedback pool contains rows for them.

    Returns ``(bank, info)`` like :func:`refresh_bank`, with
    ``feedback_rows`` / ``feedback_used`` added; ``bank`` is ``None`` (no
    refresh, no version bump) when no feedback row routes into a drifted
    slot — the caller keeps serving the current bank.
    """
    x_feed = np.asarray(x_feed, np.float32)
    if x_feed.ndim == 1:
        x_feed = x_feed[None, :]
    y_feed = np.asarray(y_feed)
    drifted = np.unique(np.asarray(list(drifted_slots), np.int64))
    xs = tr.scaler.transform(x_feed)
    slot_of = np.asarray(tr.packed.slot_of_cell)[tr.plan.route(xs)]
    keep = np.isin(slot_of, drifted)
    feed_info = {"feedback_rows": int(x_feed.shape[0]),
                 "feedback_used": int(keep.sum())}
    if not keep.any():
        return None, {"drifted_slots": 0, "rows_added": 0, "rows_evicted": 0,
                      "resolve_calls": 0, "columns_resolved": 0, **feed_info}
    bank, info = refresh_bank(tr, sel, x_feed[keep], y_feed[keep], **kwargs)
    info.update(feed_info)
    return bank, info
