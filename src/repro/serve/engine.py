"""Serving engine: batched prefill + autoregressive decode.

``serve_step`` is the unit the decode/long-context dry-run shapes lower:
one new token against a full cache.  ``generate`` is the host-side loop
used by the examples (greedy / temperature sampling), with continuous
batching via a per-row "done" mask.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import model as model_mod
from repro.models.model import ModelConfig
from repro.serve.kv_cache import pad_cache

Array = jax.Array


@functools.partial(jax.jit, static_argnames=("cfg",))
def serve_step(cfg: ModelConfig, params, token: Array, cache: Dict[str, Any],
               pos: Array) -> Tuple[Array, Dict[str, Any]]:
    """One decode step: token (B, 1) -> (logits (B, vocab), new cache)."""
    return model_mod.decode_step(cfg, params, token, cache, pos)


@functools.partial(jax.jit, static_argnames=("cfg",))
def prefill_step(cfg: ModelConfig, params, tokens: Array
                 ) -> Tuple[Array, Dict[str, Any]]:
    return model_mod.prefill(cfg, params, tokens)


def _sample(logits: Array, key: Array, temperature: float) -> Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


def generate(
    cfg: ModelConfig,
    params,
    prompt: Array,                 # (B, T_prompt) int32
    max_new_tokens: int,
    temperature: float = 0.0,
    eos_id: Optional[int] = None,
    seed: int = 0,
) -> Array:
    """Greedy/sampled generation.  Returns (B, T_prompt + max_new_tokens)."""
    b, t0 = prompt.shape
    budget = t0 + max_new_tokens
    logits, cache = prefill_step(cfg, params, prompt)
    cache = pad_cache(cfg, cache, budget)

    key = jax.random.PRNGKey(seed)
    tokens = [prompt]
    done = jnp.zeros((b,), bool)
    cur = _sample(logits, key, temperature).astype(jnp.int32)

    for step in range(max_new_tokens):
        if eos_id is not None:
            done = done | (cur == eos_id)
            cur = jnp.where(done, eos_id if eos_id is not None else 0, cur)
        tokens.append(cur[:, None])
        if step == max_new_tokens - 1:
            break
        key, sk = jax.random.split(key)
        logits, cache = serve_step(cfg, params, cur[:, None], cache,
                                   jnp.int32(t0 + step))
        cur = _sample(logits, sk, temperature).astype(jnp.int32)
        if eos_id is not None and bool(done.all()):
            tokens.append(jnp.full((b, max_new_tokens - step - 1), eos_id,
                                   jnp.int32))
            break
    return jnp.concatenate(tokens, axis=1)[:, :budget]
