"""Model bank: compacted cell-SVM storage for the serving engine.

liquidSVM's test phase ships every trained cell model to the predict
workers; at serving scale (the Rgtsvm observation: batched prediction is
where large-SVM deployments spend their time) the resident model set is a
first-class artifact.  The bank ingests trained cell models — a single
:class:`repro.core.svm.TrainedSVM` or the distributed ``(n_slots, k, ...)``
cell batch — and compacts them:

  * **zero-row dropping** — the hinge duals are sparse (box-projected
    coordinate descent leaves exact zeros), so SV rows whose coefficients
    vanish across ALL (task, sub) columns are dropped;
  * **SV dedup** — one SV table per cell, shared by every task, fold and
    gamma: the per-(task, sub) models are coefficient COLUMNS over that
    table (fold models were already averaged into one column by
    ``select.combine_fold_models``), and exact-duplicate SV rows are merged
    by summing their coefficient rows (k(x, u) is identical for identical
    u, so the decision function is unchanged);
  * **bf16 storage** — optional 2-byte SV/coefficient tables (decisions are
    always computed in f32; storage-only downcast).

Serialization goes through ``repro.train.checkpoint`` (atomic step dirs,
raw-byte bf16-safe storage), so a predict server cold-starts from disk
without retraining: ``bank.save(dir)`` / ``ModelBank.load(dir)``.

Layout (C = number of cells, P = n_tasks * n_sub, column p = t * n_sub + s
— the same task-major flattening as ``TrainedSVM.decision_function``):

  sv        (C, k, d)   compacted, padded SV tables
  coefs     (C, k, P)   per-(task, sub) coefficient columns
  gammas    (C, P)      per-column selected gamma
  sv_count  (C,)        live rows per cell (rows beyond carry zero coefs)
  centers   (C, d)      Voronoi routing centers (empty slots pushed to inf)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.svm import TrainedSVM
from repro.distributed.planner import _round_up
from repro.train import checkpoint as ckpt_mod

# empty-slot routing center: beyond any real (scaled) point, but small
# enough that its squared distance stays finite in f32
_FAR = np.float32(1.0e18)


def _route_baseline(sv_cells: np.ndarray, mask_cells: np.ndarray,
                    centers: np.ndarray) -> dict:
    """Per-cell squared-distance quantiles of the training rows that BUILT
    each cell, measured to the cell's own routing center — the reference
    distribution ``serve.monitor`` scores live traffic against.  Computed
    from the pre-compaction staged rows (``from_cells`` inputs), so it
    reflects the training data, not the surviving SVs.  Cells with no live
    rows (or non-finite padding centers) record n=0 and are skipped by the
    drift scorer."""
    c_count = sv_cells.shape[0]
    q50 = np.zeros((c_count,), np.float64)
    q90 = np.zeros((c_count,), np.float64)
    n = np.zeros((c_count,), np.int64)
    for c in range(c_count):
        live = mask_cells[c] > 0
        center = centers[c]
        if not live.any() or not np.all(np.isfinite(center)):
            continue
        d2 = ((sv_cells[c][live] - center[None, :]) ** 2).sum(axis=1)
        lo, hi = np.quantile(d2, (0.5, 0.9))
        if not (np.isfinite(lo) and np.isfinite(hi)):
            continue
        q50[c], q90[c], n[c] = float(lo), float(hi), int(live.sum())
    return {"q50": q50.tolist(), "q90": q90.tolist(), "n": n.tolist()}


def _dedup_rows(sv: np.ndarray, coefs: np.ndarray):
    """Merge exact-duplicate SV rows, first-occurrence order preserved.

    sv (k, d), coefs (k, P) -> smaller (k', d), (k', P) with coefficient
    rows of duplicates summed into the first occurrence.
    """
    _, first, inverse = np.unique(sv, axis=0, return_index=True,
                                  return_inverse=True)
    if first.shape[0] == sv.shape[0]:
        return sv, coefs                      # no duplicates: exact identity
    # remap unique-group ids to first-occurrence order
    order = np.argsort(first, kind="stable")
    rank = np.empty_like(order)
    rank[order] = np.arange(order.shape[0])
    g = rank[inverse]                         # (k,) group id, order-preserving
    out_sv = sv[np.sort(first)]
    out_coefs = np.zeros((first.shape[0], coefs.shape[1]), coefs.dtype)
    np.add.at(out_coefs, g, coefs)
    return out_sv, out_coefs


@dataclasses.dataclass(frozen=True)
class ModelBank:
    sv: np.ndarray            # (C, k, d) f32 or bf16
    coefs: np.ndarray         # (C, k, P) f32 or bf16
    gammas: np.ndarray        # (C, P) f32
    sv_count: np.ndarray      # (C,) int32
    centers: np.ndarray       # (C, d) f32
    feat_mean: np.ndarray     # (d,) f32 — input scaling baked into the bank
    feat_std: np.ndarray      # (d,) f32
    classes: np.ndarray       # (n_classes,) f32 (empty for regression)
    pairs: np.ndarray         # (n_tasks, 2) int32 AvA pairs (or -1)
    kernel: str = "gauss_rbf"
    n_tasks: int = 1
    n_sub: int = 1
    scenario: str = "binary"
    raw_sv_total: int = 0     # pre-compaction SV rows (for stats)
    default_sub: int = 0      # sub column label combination reads by default
                              # (the select stage's NP weight pick rides
                              # along into serving)
    routing: str = "nearest"  # "nearest" (1-NN) | "overlap" (voronoi=5
                              # banks: route to the 2 nearest centers and
                              # blend decisions; the engine reads this)
    version: int = 0          # monotonic bank version: the serving engine
                              # only accepts hot swaps to a strictly newer
                              # version, and tags every response with the
                              # version that served it
    route_baseline: Optional[dict] = None
                              # train-time routing-distance baseline:
                              # {"q50": [C], "q90": [C], "n": [C]} — per-cell
                              # quantiles of the squared distance from the
                              # cell's own (scaled) training rows to its
                              # center.  serve.monitor compares live query
                              # distances against this to score covariate
                              # drift; None for banks that predate it
                              # (drift detection disables itself).

    # ------------------------------------------------------------ properties
    @property
    def n_cells(self) -> int:
        return self.sv.shape[0]

    @property
    def k_max(self) -> int:
        return self.sv.shape[1]

    @property
    def n_columns(self) -> int:
        return self.coefs.shape[2]

    @property
    def nbytes(self) -> int:
        return self.sv.nbytes + self.coefs.nbytes + self.gammas.nbytes

    def stats(self) -> dict:
        live = int(self.sv_count.sum())
        return {
            "n_cells": self.n_cells,
            "k_max": self.k_max,
            "sv_live": live,
            "sv_raw": int(self.raw_sv_total),
            "compaction": live / max(int(self.raw_sv_total), 1),
            "bytes": self.nbytes,
            "dtype": str(self.sv.dtype),
            "routing": self.routing,
            "version": int(self.version),
            "drift_baseline": bool(self.route_baseline),
        }

    def with_version(self, version: int) -> "ModelBank":
        """Same bank, new version tag (arrays shared, not copied)."""
        return dataclasses.replace(self, version=int(version))

    def route_baseline_arrays(self):
        """(q50, q90, n) f64/int arrays from the recorded baseline, or
        ``None`` when the bank predates drift baselines."""
        rb = self.route_baseline
        if not rb:
            return None
        return (np.asarray(rb["q50"], np.float64),
                np.asarray(rb["q90"], np.float64),
                np.asarray(rb["n"], np.int64))

    # ---------------------------------------------------------- construction
    @classmethod
    def from_cells(
        cls,
        sv_cells: np.ndarray,       # (C, k, d)
        mask_cells: np.ndarray,     # (C, k)
        coef_cells: np.ndarray,     # (C, k, T, S)
        gamma_cells: np.ndarray,    # (C, T, S)
        centers: np.ndarray,        # (C, d)
        *,
        kernel: str = "gauss_rbf",
        drop_tol: Optional[float] = 0.0,
        dedup: bool = True,
        dtype: str = "f32",
        feat_mean: Optional[np.ndarray] = None,
        feat_std: Optional[np.ndarray] = None,
        classes: Optional[np.ndarray] = None,
        pairs: Optional[np.ndarray] = None,
        scenario: str = "binary",
        default_sub: int = 0,
        routing: str = "nearest",
        version: int = 0,
        pad_multiple: int = 8,
        route_baseline: Optional[dict] = None,
    ) -> "ModelBank":
        """Compact a trained cell batch into a bank.

        ``drop_tol``: SV rows with ``max_p |coef| <= drop_tol`` are dropped
        (0.0 drops the exact zeros of the sparse hinge duals; ``None``
        disables dropping).  Row order is preserved, so with no droppable
        rows and no duplicates the compacted tables are bitwise identical
        to the inputs.

        ``route_baseline``: pass a precomputed drift baseline to carry it
        through; ``None`` (the default) computes it here from the
        pre-compaction rows — every bank built this way supports drift
        monitoring for free.
        """
        sv_cells = np.asarray(sv_cells, np.float32)
        mask_cells = np.asarray(mask_cells, np.float32)
        coef_cells = np.asarray(coef_cells, np.float32)
        c_count, _, t_count, s_count = coef_cells.shape
        p = t_count * s_count
        coef_flat = coef_cells.reshape(c_count, -1, p)

        kept_sv, kept_coefs = [], []
        for c in range(c_count):
            live = mask_cells[c] > 0
            if drop_tol is not None:
                live &= np.abs(coef_flat[c]).max(axis=1) > drop_tol
            sv_c, coef_c = sv_cells[c][live], coef_flat[c][live]
            if dedup and sv_c.shape[0] > 1:
                sv_c, coef_c = _dedup_rows(sv_c, coef_c)
            kept_sv.append(sv_c)
            kept_coefs.append(coef_c)

        k_max = _round_up(max((s.shape[0] for s in kept_sv), default=1),
                          pad_multiple)
        d = sv_cells.shape[2]
        sv = np.zeros((c_count, k_max, d), np.float32)
        coefs = np.zeros((c_count, k_max, p), np.float32)
        counts = np.zeros((c_count,), np.int32)
        for c, (s, co) in enumerate(zip(kept_sv, kept_coefs)):
            sv[c, : s.shape[0]] = s
            coefs[c, : s.shape[0]] = co
            counts[c] = s.shape[0]

        if dtype == "bf16":
            sv = np.asarray(jnp.asarray(sv).astype(jnp.bfloat16))
            coefs = np.asarray(jnp.asarray(coefs).astype(jnp.bfloat16))
        elif dtype != "f32":
            raise ValueError(f"dtype must be f32|bf16, got {dtype!r}")
        if routing not in ("nearest", "overlap"):
            raise ValueError(f"routing must be nearest|overlap, got {routing!r}")
        centers = np.asarray(centers, np.float32)
        if route_baseline is None:
            route_baseline = _route_baseline(sv_cells, mask_cells, centers)

        if feat_mean is None:
            feat_mean = np.zeros((d,), np.float32)
        if feat_std is None:
            feat_std = np.ones((d,), np.float32)
        return cls(
            sv=sv, coefs=coefs,
            gammas=np.asarray(gamma_cells, np.float32).reshape(c_count, p),
            sv_count=counts,
            centers=centers,
            feat_mean=np.asarray(feat_mean, np.float32),
            feat_std=np.asarray(feat_std, np.float32),
            classes=(np.zeros((0,), np.float32) if classes is None
                     else np.asarray(classes, np.float32)),
            pairs=(-np.ones((t_count, 2), np.int32) if pairs is None
                   else np.asarray(pairs, np.int32)),
            kernel=kernel, n_tasks=t_count, n_sub=s_count, scenario=scenario,
            raw_sv_total=int((mask_cells > 0).sum()),
            default_sub=int(default_sub), routing=routing,
            version=int(version), route_baseline=route_baseline,
        )

    @classmethod
    def from_trained(cls, model: TrainedSVM, **kwargs) -> "ModelBank":
        """Single-cell bank from one working-set model."""
        sv = np.asarray(model.sv_x, np.float32)
        mask = np.asarray(model.sv_mask, np.float32)
        coefs = np.asarray(model.coefs, np.float32)
        gamma = np.asarray(model.gamma, np.float32)
        denom = max(float(mask.sum()), 1.0)
        center = (sv * mask[:, None]).sum(0, keepdims=True) / denom
        kwargs.setdefault("kernel", model.kernel)
        return cls.from_cells(sv[None], mask[None], coefs[None],
                              gamma[None], center, **kwargs)

    # -------------------------------------------------------------- adapters
    def cell_arrays_f32(self):
        """(sv, coefs) upcast to f32 jnp arrays — the compute dtype."""
        return (jnp.asarray(self.sv).astype(jnp.float32),
                jnp.asarray(self.coefs).astype(jnp.float32))

    def cell_model(self, c: int) -> TrainedSVM:
        """Reconstruct one cell as a TrainedSVM (the per-cell oracle view)."""
        k = int(self.sv_count[c])
        sv, coefs = self.cell_arrays_f32()
        z = jnp.zeros((self.n_tasks, self.n_sub), jnp.float32)
        return TrainedSVM(
            sv_x=sv[c, :k],
            sv_mask=jnp.ones((k,), jnp.float32),
            coefs=coefs[c, :k].reshape(k, self.n_tasks, self.n_sub),
            gamma=jnp.asarray(self.gammas[c].reshape(self.n_tasks, self.n_sub)),
            lam=z, tau=z, val_loss=z, kernel=self.kernel)

    # --------------------------------------------------------- serialization
    _META_KEYS = ("kernel", "n_tasks", "n_sub", "scenario", "raw_sv_total",
                  "default_sub", "routing", "version", "route_baseline")

    def save(self, ckpt_dir: str, step: int = 0) -> str:
        """Atomic checkpoint write; a server cold-starts from this alone."""
        tree = {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)
                if f.name not in self._META_KEYS}
        extra = {k: getattr(self, k) for k in self._META_KEYS}
        extra["format"] = "svm_model_bank_v1"
        return ckpt_mod.save_checkpoint(ckpt_dir, step, tree, extra=extra)

    @classmethod
    def load(cls, ckpt_dir: str, step: Optional[int] = None) -> "ModelBank":
        extra = ckpt_mod.peek_manifest(ckpt_dir, step)["extra"]
        if extra.get("format") != "svm_model_bank_v1":
            raise ValueError(f"{ckpt_dir} is not a model-bank checkpoint "
                             f"(format={extra.get('format')!r})")
        arrays, extra = ckpt_mod.restore_self_describing(ckpt_dir, step)
        # field defaults cover banks written before a meta key existed
        defaults = {f.name: f.default for f in dataclasses.fields(cls)}
        meta = {k: extra.get(k, defaults[k]) for k in cls._META_KEYS}
        return cls(**arrays, **meta)
