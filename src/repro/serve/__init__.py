from repro.serve.kv_cache import pad_cache, cache_bytes
from repro.serve.engine import generate, serve_step

__all__ = ["pad_cache", "cache_bytes", "generate", "serve_step"]
