"""Serving layer: the LM token engine (``engine``/``kv_cache``) and the
cell-routed SVM serving subsystem (``model_bank`` + ``svm_engine``)."""
from repro.serve.kv_cache import pad_cache, cache_bytes
from repro.serve.engine import generate, serve_step
from repro.serve.model_bank import ModelBank
from repro.serve.monitor import HealthMonitor
from repro.serve.refresh import refresh_bank, refresh_drifted
from repro.serve.svm_engine import OverloadError, SVMEngine
from repro.serve.embed_engine import EmbedServe

__all__ = ["pad_cache", "cache_bytes", "generate", "serve_step",
           "EmbedServe", "HealthMonitor", "ModelBank", "OverloadError",
           "SVMEngine", "refresh_bank", "refresh_drifted"]
