"""KV-cache utilities: prefill-cache padding, ring-buffer semantics, sizing.

Cache layout (see repro.models.model.cache_struct):
  {"stack": {"pos<i>": {leafs stacked over n_periods}}, "tail<j>": {...}}
  attention leafs "k"/"v": (..., B, S, Hk, D); ssm/rwkv leafs are O(1)
  recurrent states that never grow with S.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model import ModelConfig

Array = jax.Array


def _quantize_kv(leaf: Array):
    """bf16 kv -> (int8, per-(token, head) f32 scale)."""
    sc = jnp.max(jnp.abs(leaf.astype(jnp.float32)), axis=-1,
                 keepdims=True) / 127.0
    sc = jnp.maximum(sc, 1e-10)
    q8 = jnp.clip(jnp.round(leaf.astype(jnp.float32) / sc),
                  -127, 127).astype(jnp.int8)
    return q8, sc


def _pad_layer_cache(piece: Dict[str, Any], target_len: int,
                     quantize: bool) -> Dict[str, Any]:
    out = {}
    for name, leaf in piece.items():
        if name in ("k", "v"):
            if quantize and leaf.dtype != jnp.int8:
                leaf, sc = _quantize_kv(leaf)
                out[name + "_scale"] = sc
            seq_axis = leaf.ndim - 3
            cur = leaf.shape[seq_axis]
            if cur < target_len:
                widths = [(0, 0)] * leaf.ndim
                widths[seq_axis] = (0, target_len - cur)
                leaf = jnp.pad(leaf, widths)
        out[name] = leaf
    # pad the scales to match
    for name in ("k_scale", "v_scale"):
        if name in out:
            leaf = out[name]
            seq_axis = leaf.ndim - 3
            cur = leaf.shape[seq_axis]
            if cur < target_len:
                widths = [(0, 0)] * leaf.ndim
                widths[seq_axis] = (0, target_len - cur)
                out[name] = jnp.pad(leaf, widths, constant_values=1e-10)
    return out


def pad_cache(cfg: ModelConfig, cache: Dict[str, Any], target_len: int
              ) -> Dict[str, Any]:
    """Right-pad every attention kv cache to ``target_len`` slots (and
    quantize prefill kv when the config serves an int8 cache).

    Padded slots are masked in decode (never-written ring positions), so
    prefill(T) + pad(S) + decode at pos=T is exact.
    """
    quant = cfg.kv_cache_dtype == "int8"
    out: Dict[str, Any] = {}
    for key, piece in cache.items():
        if key == "stack":
            out["stack"] = {p: _pad_layer_cache(lc, target_len, quant)
                            for p, lc in piece.items()}
        else:
            out[key] = _pad_layer_cache(piece, target_len, quant)
    return out


def cache_bytes(cfg: ModelConfig, batch: int, seq: int) -> int:
    """Total decode-state bytes (capacity planning / roofline memory term)."""
    from repro.models.model import cache_struct
    tree = cache_struct(cfg, batch, seq)
    return sum(int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
               for s in jax.tree.leaves(tree))
