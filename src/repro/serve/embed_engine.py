"""Co-located embed->route->blend serving.

:class:`EmbedServe` wraps an :class:`~repro.serve.svm_engine.SVMEngine`
with a frozen-backbone :class:`~repro.embed.extractor.EmbeddingExtractor`
in the SAME process: ``submit_tokens()`` runs the backbone forward and
feeds the pooled embeddings straight into the engine's admission queue —
no serialization hop, no second service, and the engine's cell routing now
operates in embedding space, which means an attached
:class:`~repro.serve.monitor.HealthMonitor` scores drift over
embedding-space routing distances for free.

Accounting: the per-request breakdown grows an ``embed_ms`` stage.  The
embed stage ends at the exact timestamp passed to ``engine.submit(now=)``
as the admission time, so the engine's own invariant
(``queue + pack + dispatch + device + collect == engine total``) extends
to ``embed + queue + ... + collect == total_ms`` with no gap and no
double-counting between the stages.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterable, Optional

import numpy as np

from repro import obs
from repro.embed.extractor import EmbeddingExtractor
from repro.serve.svm_engine import _SERVED_VERSION_CAP, SVMEngine

_EMBED_STAGE = "embed"


class EmbedServe:
    """An ``SVMEngine`` fronted by an in-process embedding stage.

    Token-space requests enter via :meth:`submit_tokens`; feature-space
    requests may still use :meth:`submit` (their ``embed_ms`` is 0.0).
    Everything else — stepping, hot swap, overload shedding, monitor
    attachment — delegates to the wrapped engine, so existing serving
    tooling (swap watchers, ``HealthMonitor``, traffic drivers) works
    unchanged.
    """

    def __init__(self, engine: SVMEngine, extractor: EmbeddingExtractor,
                 *, tracer: Optional["obs.Tracer"] = None):
        bank_d = int(engine.bank.centers.shape[1])
        if extractor.dim != bank_d:
            raise ValueError(
                f"extractor produces d={extractor.dim} embeddings but the "
                f"bank was trained at d={bank_d}")
        self.engine = engine
        self.extractor = extractor
        self._tracer = obs.tracer if tracer is None else tracer
        # rid -> embed-stage latency, bounded exactly like the engine's
        # served_breakdown ring so the two age out together
        self._embed_ms: "collections.OrderedDict[int, float]" = \
            collections.OrderedDict()
        self._embed_ms_sum = 0.0
        self._embed_n = 0

    # ------------------------------------------------------------ admission
    def submit_tokens(self, tokens, now: Optional[float] = None
                      ) -> np.ndarray:
        """Embed a batch of token sequences and enqueue the embeddings.

        The backbone forward + pooling run here, in-process; the resulting
        rows land in the engine's admission queue with the embed-end
        timestamp as their admission time, so the engine's queue-residual
        accounting starts exactly where the embed stage stops.  Returns
        the engine-assigned request ids.  Overload shedding happens at the
        ENGINE's admission gate — a shed batch still paid for its
        embedding (the forward ran), which is the honest cost model for a
        co-located stage.
        """
        t0 = float(self.engine._clock()) if now is None else float(now)
        with self._tracer.span("serve.embed"):
            emb = self.extractor(tokens)
        t1 = float(self.engine._clock())
        ids = self.engine.submit(emb, now=t1)
        embed_ms = (t1 - t0) * 1e3
        per_req = embed_ms / max(len(ids), 1)
        for rid in ids:
            self._embed_ms[int(rid)] = per_req
        while len(self._embed_ms) > _SERVED_VERSION_CAP:
            self._embed_ms.popitem(last=False)
        self._embed_ms_sum += embed_ms
        self._embed_n += 1
        return ids

    def submit(self, x: np.ndarray, now: Optional[float] = None
               ) -> np.ndarray:
        """Feature-space admission passthrough (``embed_ms`` = 0)."""
        return self.engine.submit(x, now=now)

    # ----------------------------------------------------------- accounting
    def breakdown(self, rid: int) -> Optional[dict]:
        """Engine breakdown plus the ``embed_ms`` stage; ``total_ms`` is
        the end-to-end figure (embed + queue + pack + dispatch + device +
        collect — the stages sum to it exactly, inheriting the engine's
        own exactness guarantee)."""
        b = self.engine.breakdown(rid)
        if b is None:
            return None
        embed_ms = self._embed_ms.get(int(rid), 0.0)
        out = dict(b)
        out["embed_ms"] = embed_ms
        out["total_ms"] = b["total_ms"] + embed_ms
        return out

    def stats(self) -> dict:
        """Engine stats with the embed stage merged into ``per_stage``."""
        out = self.engine.stats()
        per_stage = dict(out["per_stage"])
        per_stage[_EMBED_STAGE] = {
            "total_ms": self._embed_ms_sum,
            "mean_ms": (self._embed_ms_sum / self._embed_n
                        if self._embed_n else 0.0),
            "count": self._embed_n,
        }
        out["per_stage"] = per_stage
        out["embedded_batches"] = self._embed_n
        return out

    # ------------------------------------------------------------ lifecycle
    def run_tokens(self, traffic: Iterable[Optional[np.ndarray]],
                   deadline_ms: Optional[float] = None,
                   max_queue: Optional[int] = None
                   ) -> Dict[int, np.ndarray]:
        """Latency-bounded serving over a token-batch arrival stream —
        the token-space mirror of :meth:`SVMEngine.run` (same launch
        policy, same overlap of admission with device work, same shedding
        semantics; ``None``/empty batches are idle ticks)."""
        from repro.serve.svm_engine import OverloadError
        eng = self.engine
        results: Dict[int, np.ndarray] = {}
        prev_mq = eng.max_queue
        if max_queue is not None:
            eng.max_queue = int(max_queue)
        try:
            for batch in traffic:
                if batch is not None and np.size(batch):
                    try:
                        self.submit_tokens(batch)
                    except OverloadError:
                        pass         # shed; visible in engine shed_* stats
                if eng.should_launch(deadline_ms):
                    if eng._inflight is not None:
                        results.update(eng.finish_step())
                    eng.begin_step()
            if eng._inflight is not None:
                results.update(eng.finish_step())
            while eng.pending:
                results.update(eng.step())
        finally:
            eng.max_queue = prev_mq
        return results

    def predict_tokens(self, tokens) -> np.ndarray:
        """Synchronous convenience: embed + engine.predict."""
        return self.engine.predict(self.extractor(tokens))

    def predict_label_tokens(self, tokens, **kw) -> np.ndarray:
        return self.engine.predict_label(self.extractor(tokens), **kw)

    # ------------------------------------------------------------ delegates
    def attach_monitor(self, monitor) -> None:
        """Drift scores now watch embedding-space routing distances —
        the engine routes what the extractor produced."""
        self.engine.attach_monitor(monitor)

    def swap_bank(self, new_bank, **kw) -> dict:
        return self.engine.swap_bank(new_bank, **kw)

    def step(self):
        return self.engine.step()

    def begin_step(self):
        return self.engine.begin_step()

    def finish_step(self):
        return self.engine.finish_step()

    def should_launch(self, deadline_ms: Optional[float] = None,
                      now: Optional[float] = None) -> bool:
        return self.engine.should_launch(deadline_ms, now)

    @property
    def pending(self) -> int:
        return self.engine.pending

    @property
    def bank(self):
        return self.engine.bank

    @property
    def counters(self):
        return self.engine.counters
