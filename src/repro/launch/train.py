"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs REAL training of the reduced (smoke) config by default — that is what
fits this container — or, with --full, builds the full config's sharded
train step on whatever mesh the host exposes (use the dry-run for the
production meshes).  The same launcher is the multihost entry point: on a
real cluster each host runs it under `jax.distributed.initialize()`.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.data.tokens import TokenPipeline, TokenPipelineConfig
from repro.train.lm_trainer import Trainer, TrainLoopConfig
from repro.train.optimizer import OptConfig


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (needs a big mesh)")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.config if args.full else arch.smoke

    pipe = TokenPipeline(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch,
        seed=args.seed, input_kind=cfg.input_kind,
        d_frontend=cfg.d_frontend))

    opt_cfg = OptConfig(lr=args.lr, warmup_steps=min(50, args.steps // 10 + 1),
                        total_steps=args.steps)
    loop_cfg = TrainLoopConfig(total_steps=args.steps,
                               grad_accum=args.grad_accum,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir)
    trainer = Trainer(cfg, opt_cfg, loop_cfg, pipe)
    out = trainer.run(seed=args.seed)
    first, last = out["history"][0], out["history"][-1]
    print(json.dumps({"arch": args.arch,
                      "loss_first": first["loss"], "loss_last": last["loss"],
                      "steps": args.steps, "wall_s": round(out["wall_s"], 1)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
