"""Serving launcher: batched generation with the reduced config.

``python -m repro.launch.serve --arch stablelm-1.6b --batch 4 --new 16``
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_arch
from repro.models import model as model_mod
from repro.models.layers import init_params
from repro.serve.engine import generate


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_IDS))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    arch = get_arch(args.arch)
    cfg = arch.smoke
    if not cfg.is_decoder:
        print(f"{args.arch} is encoder-only; no autoregressive serve path")
        return 0
    params = init_params(model_mod.build_template(cfg),
                         jax.random.PRNGKey(args.seed))
    prompt = jax.random.randint(jax.random.PRNGKey(args.seed + 1),
                                (args.batch, args.prompt_len), 0, cfg.vocab)
    t0 = time.time()
    out = generate(cfg, params, prompt, max_new_tokens=args.new,
                   temperature=args.temperature, seed=args.seed)
    dt = time.time() - t0
    print(json.dumps({
        "arch": args.arch, "out_shape": list(out.shape),
        "tokens_per_s": round(args.batch * args.new / dt, 1),
        "wall_s": round(dt, 2)}))
    return 0


if __name__ == "__main__":
    sys.exit(main())
