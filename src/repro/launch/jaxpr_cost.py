"""Trip-count-aware cost analysis from the jaxpr.

XLA's ``compiled.cost_analysis()`` visits a while/scan body ONCE — for a
94-layer model under a period scan that under-counts FLOPs by ~2 orders
(verified in tests).  This analyzer walks the jaxpr instead and multiplies
every nested scan body by its trip count, giving exact structural FLOPs.

Byte model (HBM traffic of a well-fused program):
  * dot_general: read both operands + write the output (matmul tiles
    stream from HBM; fusion cannot remove these);
  * gather/scatter & dynamic slices: output (+ indices) bytes;
  * everything elementwise/reshape/reduce: assumed fused into a producer
    (0 extra bytes) but its FLOPs are counted;
  * jaxpr invars (params + batch) are charged once per enclosing-scan
    iteration in which they are consumed — weights re-stream from HBM on
    every layer of a scanned stack, exactly like a real TPU step.

while_loop trip counts are unknowable statically; callers pass
``while_trips`` (e.g. the SVM box-QP solver's max_iters) — the analyzer
flags any while it had to guess.

All numbers are GLOBAL (pre-SPMD): divide by the device count for
per-device roofline terms (perfect-balance assumption; collective bytes
come from the partitioned HLO instead, see launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import core as jcore

ELEMENTWISE_FLOP = {
    "add", "sub", "mul", "div", "max", "min", "neg", "abs", "exp", "log",
    "tanh", "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "erf",
    "select_n", "clamp", "floor", "round", "sign", "cos", "sin", "and",
    "or", "not", "xor", "ge", "gt", "le", "lt", "eq", "ne", "rem",
    "nextafter", "cbrt", "atan2", "square", "cumsum", "cumprod",
    "cummax", "cumlogsumexp", "erf_inv", "expm1", "log1p", "is_finite",
    "shift_right_logical", "shift_left", "population_count", "clz",
}

ZERO_COST = {
    "reshape", "transpose", "broadcast_in_dim", "squeeze", "convert_element_type",
    "slice", "concatenate", "pad", "rev", "iota", "copy", "stop_gradient",
    "bitcast_convert_type", "expand_dims", "device_put", "sharding_constraint",
    "split", "real", "imag", "empty", "eye", "tie_in", "opt_barrier",
    "optimization_barrier", "pvary",
}

REDUCE_OPS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
              "reduce_and", "reduce_or", "argmax", "argmin",
              "reduce_precision"}


def _nbytes(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    try:
        itemsize = jnp.dtype(aval.dtype).itemsize
    except TypeError:  # extended dtypes (PRNG keys): count the raw payload
        itemsize = 8
    return float(np.prod(aval.shape, dtype=np.float64) * itemsize) \
        if aval.shape else float(itemsize)


def _nelems(aval) -> float:
    if not hasattr(aval, "shape"):
        return 0.0
    return float(np.prod(aval.shape, dtype=np.float64)) if aval.shape else 1.0


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    guessed_whiles: int = 0

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.guessed_whiles += o.guessed_whiles
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.guessed_whiles)


def _source_bytes(var, producers, depth: int = 6) -> float:
    """HBM bytes behind a dot operand: follow fusible elementwise chains
    (convert, scale-multiply, broadcast, transpose/reshape) to the stored
    tensor — an int8 KV cache dequantized on the fly is read as int8."""
    best = _nbytes(var.aval)
    v = var
    for _ in range(depth):
        eqn = producers.get(id(v))
        if eqn is None:
            break
        name = eqn.primitive.name
        if name not in ("convert_element_type", "mul", "transpose",
                        "reshape", "broadcast_in_dim"):
            break
        # step to the operand with the same element count (the data path)
        nel = _nelems(v.aval)
        nxt = None
        for iv in eqn.invars:
            if hasattr(iv, "aval") and _nelems(iv.aval) == nel:
                nxt = iv
                break
        if nxt is None:
            break
        v = nxt
        best = min(best, _nbytes(v.aval))
    return best


def _dot_cost(eqn, producers) -> Cost:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    batch = np.prod([lhs.shape[i] for i in lb], dtype=np.float64) if lb else 1.0
    contract = np.prod([lhs.shape[i] for i in lc], dtype=np.float64) if lc else 1.0
    lfree = np.prod([s for i, s in enumerate(lhs.shape)
                     if i not in lc and i not in lb], dtype=np.float64)
    rfree = np.prod([s for i, s in enumerate(rhs.shape)
                     if i not in rc and i not in rb], dtype=np.float64)
    flops = 2.0 * batch * contract * lfree * rfree
    bytes_ = (_source_bytes(eqn.invars[0], producers)
              + _source_bytes(eqn.invars[1], producers)
              + sum(_nbytes(o.aval) for o in eqn.outvars))
    return Cost(flops, bytes_)


def _inner_jaxprs(eqn):
    """(jaxpr, multiplier) pairs for call-like primitives."""
    name = eqn.primitive.name
    p = eqn.params
    if name == "scan":
        return [(p["jaxpr"].jaxpr, float(p["length"]))]
    if name == "while":
        return [(p["body_jaxpr"].jaxpr, None),       # trips resolved later
                (p["cond_jaxpr"].jaxpr, None)]
    if name == "cond":
        return [(b.jaxpr, 1.0) for b in p["branches"]]
    for key in ("jaxpr", "call_jaxpr", "fun_jaxpr"):
        if key in p:
            j = p[key]
            return [(j.jaxpr if hasattr(j, "jaxpr") else j, 1.0)]
    return []


def jaxpr_cost(jaxpr, while_trips: float = 1.0) -> Cost:
    total = Cost()
    # charge source tensors (params/batch) once per enclosing iteration
    for v in jaxpr.invars:
        total.bytes += _nbytes(v.aval)

    producers = {id(o): e for e in jaxpr.eqns for o in e.outvars}

    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        if name == "dot_general":
            total += _dot_cost(eqn, producers)
        elif name in ("gather", "take", "dynamic_slice"):
            total.bytes += sum(_nbytes(o.aval) for o in eqn.outvars)
        elif name in ("dynamic_update_slice", "scatter", "scatter-add",
                      "scatter_add", "scatter_mul"):
            # in-place update: HBM traffic is the UPDATE payload (+ indices),
            # not the whole destination buffer
            total.bytes += sum(_nbytes(v.aval) for v in eqn.invars[1:]
                               if hasattr(v, "aval"))
        elif name in ELEMENTWISE_FLOP:
            total.flops += sum(_nelems(o.aval) for o in eqn.outvars)
        elif name in REDUCE_OPS or name.startswith("reduce_"):
            total.flops += max((_nelems(v.aval) for v in eqn.invars
                                if hasattr(v, "aval")), default=0.0)
        elif name in ("sort", "top_k"):
            n = max((_nelems(v.aval) for v in eqn.invars
                     if hasattr(v, "aval")), default=0.0)
            total.flops += n * max(math.log2(max(n, 2.0)), 1.0)
        elif name in ("eigh", "cholesky", "triangular_solve", "lu", "qr"):
            a = eqn.invars[0].aval
            n = float(a.shape[-1])
            batch = _nelems(a) / max(n * n, 1.0)
            factor = {"eigh": 9.0, "cholesky": 1.0 / 3.0, "lu": 2.0 / 3.0,
                      "qr": 4.0 / 3.0, "triangular_solve": 1.0}[name]
            total.flops += batch * factor * n ** 3
            total.bytes += sum(_nbytes(v.aval) for v in eqn.invars
                               if hasattr(v, "aval")) + \
                sum(_nbytes(o.aval) for o in eqn.outvars)
        elif name in ZERO_COST:
            pass
        inner = _inner_jaxprs(eqn)
        for sub, mult in inner:
            sub_cost = jaxpr_cost(sub, while_trips)
            if mult is None:             # while: caller-provided guess
                sub_cost.guessed_whiles += 1
                mult = while_trips
            total += sub_cost.scaled(mult)
    return total


def cost_of(fn, *args, while_trips: float = 1.0, **kw) -> Cost:
    """Trip-aware cost of fn(*args) (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn, **kw)(*args)
    return jaxpr_cost(closed.jaxpr, while_trips)
