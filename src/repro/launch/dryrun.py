import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: prove every (arch x shape x mesh) cell lowers,
# partitions, and compiles on the production mesh — and extract the
# roofline terms (FLOPs / bytes / collective bytes) from the compiled
# artifact.
#
# MUST run as its own process: the XLA_FLAGS lines above execute before
# ANY jax import (jax locks the device count on first init).  Do NOT set
# this flag globally — smoke tests and benches must see 1 device.
#
# Usage:
#   PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-12b --shape train_4k
#   PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out results.json

import argparse
import functools
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, all_cells, get_arch
from repro.launch import shapes as shapes_mod
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_mod
from repro.train.lm_trainer import make_train_step


# --------------------------------------------------------------------------
# collective-bytes extraction from the partitioned HLO
# --------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"=\s*([^=]*?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}


def _line_coll_bytes(line: str):
    m = _COLL_RE.search(line)
    if not m:
        return None
    shapes_str, op = m.group(1), m.group(2)
    total = 0
    for sm in _SHAPE_RE.finditer(shapes_str):
        dt, dims = sm.group(1), sm.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return op, float(total)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(r"\bwhile\(")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_S32_CONST = re.compile(r"s32\[\]\s+constant\((\d+)\)")
# non-while computation edges (executed once per call site)
_CALL_EDGES = re.compile(
    r"(?:to_apply|calls|true_computation|false_computation)=%?([\w\.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")

_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
        "collective-permute")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Trip-count-aware collective bytes from the partitioned HLO.

    XLA text places a scan's body in a separate while-body computation —
    summing naively counts it ONCE.  We parse computations, recover each
    while's trip count from the s32 bound in its condition computation,
    and multiply nested collective bytes accordingly.
    """
    # ---- split into computations (header: unindented "name (...) -> ... {")
    comps: Dict[str, list] = {}
    cur: Optional[str] = None
    entry: Optional[str] = None
    for line in hlo_text.splitlines():
        s = line.strip()
        if not line.startswith(" ") and s.endswith("{") and "->" in s:
            toks = s.split()
            name = toks[1] if toks[0] == "ENTRY" else toks[0]
            cur = name.lstrip("%")
            comps[cur] = []
            if toks[0] == "ENTRY":
                entry = cur
            continue
        if cur is not None:
            if s == "}":
                cur = None
            else:
                comps[cur].append(line)

    def trip_count(cond_name: str) -> float:
        consts = [int(v) for l in comps.get(cond_name, ())
                  for v in _S32_CONST.findall(l)]
        return float(max(consts)) if consts else 1.0

    # ---- per-computation direct bytes + nested whiles
    direct: Dict[str, Dict[str, float]] = {}
    children: Dict[str, list] = {}
    for name, lines in comps.items():
        d = {op: 0.0 for op in _OPS}
        counts = {op: 0 for op in _OPS}
        kids = []
        for line in lines:
            got = _line_coll_bytes(line)
            if got:
                d[got[0]] += got[1]
                counts[got[0]] += 1
            if _WHILE_RE.search(line):
                mc, mb = _COND_RE.search(line), _BODY_RE.search(line)
                if mb:
                    kids.append((mb.group(1),
                                 trip_count(mc.group(1)) if mc else 1.0))
            else:
                for callee in _CALL_EDGES.findall(line):
                    kids.append((callee, 1.0))
                mb = _BRANCHES.search(line)
                if mb:
                    for callee in mb.group(1).split(","):
                        kids.append((callee.strip().lstrip("%"), 1.0))
        direct[name] = d
        direct[name + "/counts"] = counts  # type: ignore
        children[name] = kids

    def total(name: str, seen=()) -> Dict[str, float]:
        if name in seen or name not in direct:
            return {op: 0.0 for op in _OPS}
        out = dict(direct[name])
        for kid, trips in children.get(name, ()):  # nested scans multiply
            sub = total(kid, seen + (name,))
            for op in _OPS:
                out[op] += sub[op] * trips
        return out

    if entry is None:
        entry = next(iter(comps), None)
    result = total(entry) if entry else {op: 0.0 for op in _OPS}
    result["counts"] = (direct.get(entry + "/counts")
                        if entry else None) or {op: 0 for op in _OPS}
    return result


# --------------------------------------------------------------------------
# per-cell lowering
# --------------------------------------------------------------------------

def build_step_fn(spec: Dict[str, Any]):
    cfg = spec["cfg"]
    kind = spec["kind"]
    if kind == "train":
        step = make_train_step(cfg, spec["opt_cfg"], spec["grad_accum"])
        return jax.jit(step)
    if kind == "prefill":
        return jax.jit(functools.partial(model_mod.prefill, cfg))
    if kind == "encode":
        return jax.jit(functools.partial(model_mod.encode, cfg))
    if kind == "decode":
        return jax.jit(functools.partial(model_mod.decode_step, cfg))
    raise ValueError(kind)


VARIANTS: Dict[str, Dict[str, Any]] = {
    "baseline": {},
    "kv8": {"kv_cache_dtype": "int8"},
    "moe_gather": {"moe_impl": "gather"},
    "moe_gather_cap1": {"moe_impl": "gather", "moe_capacity_factor": 1.0},
    "moe_pregather": {"moe_impl": "gather", "moe_capacity_factor": 1.0,
                      "moe_pregather": True},
    "moe_bigchunk": {"moe_impl": "gather", "moe_capacity_factor": 1.0,
                     "moe_chunk": 8192},
    "noactshard": {"shard_activations": False},
    "noactshard_accum4": {"shard_activations": False, "grad_accum": 4},
}


def dryrun_cell(arch_id: str, shape_name: str, mesh, mesh_name: str,
                verbose: bool = True, variant: str = "baseline"
                ) -> Dict[str, Any]:
    from repro.launch.jaxpr_cost import cost_of

    t0 = time.time()
    spec = shapes_mod.input_specs(arch_id, shape_name, mesh,
                                  overrides=VARIANTS[variant])
    fn = build_step_fn(spec)
    with mesh:
        lowered = fn.lower(*spec["args"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        # trip-aware structural FLOPs/bytes from the jaxpr (global -> /dev)
        structural = cost_of(fn, *spec["args"])

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()   # per-device, but scan bodies once
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())   # trip-aware, per device

    n_dev = int(np.prod(list(mesh.shape.values())))

    result = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": spec["kind"],
        "variant": variant,
        "n_devices": n_dev,
        "flops": structural.flops / n_dev,
        "bytes_accessed": structural.bytes / n_dev,
        "xla_flops_body_once": float(cost.get("flops", -1.0)),
        "xla_bytes_body_once": float(cost.get("bytes accessed", -1.0)),
        "collective_bytes": {k: v for k, v in coll.items() if k != "counts"},
        "collective_counts": coll["counts"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "generated_code_bytes": getattr(mem, "generated_code_size_in_bytes", 0),
        },
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
    }
    if verbose:
        print(f"[dryrun] {arch_id} x {shape_name} x {mesh_name}: "
              f"flops/dev={result['flops']:.3e} bytes/dev={result['bytes_accessed']:.3e} "
              f"coll/dev={sum(result['collective_bytes'].values()):.3e} "
              f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
        print(f"  memory_analysis: {result['memory']}", flush=True)
    return result


def dryrun_svm(mesh, mesh_name: str, slots_per_dev: int = 2, k: int = 2000,
               d: int = 128, verbose: bool = True,
               shared_lipschitz: bool = True,
               gram_dtype: str = "f32") -> Dict[str, Any]:
    """Roofline the paper's own technique: the sharded cell-CV trainer.

    One slot = one padded cell of k samples; the full 10x10 grid x 5 folds
    CV runs per slot, slots sharded over every mesh axis.
    shared_lipschitz=False is the paper-faithful baseline (per-fold masked
    Gram); True + gram_dtype="bf16" are the §Perf-optimized variants."""
    from repro.core import cv as cv_mod
    from repro.core.grids import liquid_grid
    from repro.distributed.cell_trainer import train_cells

    t0 = time.time()
    n_dev = int(np.prod(list(mesh.shape.values())))
    n_slots = n_dev * slots_per_dev
    cfg = cv_mod.CVConfig(n_folds=5, max_iters=500,
                          shared_lipschitz=shared_lipschitz,
                          gram_dtype=gram_dtype)
    grid = liquid_grid(n=k, dim=d)
    lam_c, sub_c, task_c, n_lam, n_sub = cv_mod.grid_columns(grid, cfg, 1)
    axes = tuple(mesh.axis_names)

    from jax.sharding import NamedSharding, PartitionSpec as P
    def sds(shape, dtype):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, P(axes)))

    keys = jax.random.split(jax.random.PRNGKey(0), n_slots)  # concrete, tiny
    args = (sds((n_slots, k, d), jnp.float32),        # x_cells
            sds((n_slots, 1, k), jnp.float32),        # y_cells
            sds((n_slots, 1, k), jnp.float32),        # tmask
            sds((n_slots, k), jnp.float32),           # mask
            sds((n_slots, len(grid.gammas)), jnp.float32),
            keys)                                      # fold keys
    with mesh:
        lowered = train_cells.lower(*args, lam_c, sub_c, task_c, cfg,
                                    n_lam, n_sub, mesh=mesh, axis_names=axes)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    mem = compiled.memory_analysis()

    from repro.launch.jaxpr_cost import cost_of
    structural = cost_of(
        lambda *a: train_cells(*a, lam_c, sub_c, task_c, cfg, n_lam, n_sub,
                               mesh=mesh, axis_names=axes),
        *args, while_trips=float(cfg.max_iters))

    variant = ("sharedL" if shared_lipschitz else "baseline") + \
        ("_bf16gram" if gram_dtype == "bf16" else "")
    result = {
        "arch": "svm-cell-trainer", "shape": f"cells_k{k}_d{d}_{variant}",
        "mesh": mesh_name, "kind": "svm_train", "n_devices": n_dev,
        "flops": structural.flops / n_dev,
        "bytes_accessed": structural.bytes / n_dev,
        "while_trips_assumed": cfg.max_iters,
        "xla_flops_body_once": float(cost.get("flops", -1.0)),
        "collective_bytes": {kk: v for kk, v in coll.items() if kk != "counts"},
        "collective_counts": coll["counts"],
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        },
        "compile_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(f"[dryrun] svm-cell-trainer x {mesh_name}: "
              f"flops={result['flops']:.3e} bytes={result['bytes_accessed']:.3e} "
              f"coll={sum(result['collective_bytes'].values()):.3e}", flush=True)
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS))
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--svm", action="store_true",
                    help="also dry-run the SVM cell trainer workload")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS),
                    help="ModelConfig perf-variant overrides")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default=None, help="append JSON-lines results here")
    args = ap.parse_args(argv)

    assert len(jax.devices()) == 512, \
        f"dryrun needs 512 forced host devices, got {len(jax.devices())}"

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16", make_production_mesh(multi_pod=True)))

    cells = all_cells() if args.all else (
        [(args.arch, args.shape)] if args.arch else [])
    failures = []
    results = []
    if args.svm:
        for mesh_name, mesh in meshes:
            for shared, gdt in ((False, "f32"), (True, "f32"),
                                (True, "bf16")):  # baseline -> optimized
                try:
                    r = dryrun_svm(mesh, mesh_name, shared_lipschitz=shared,
                                   gram_dtype=gdt)
                    results.append(r)
                    if args.out:
                        with open(args.out, "a") as f:
                            f.write(json.dumps(r) + "\n")
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append(("svm-cell-trainer", "cells", mesh_name,
                                     repr(e)))
    for arch_id, shape_name in cells:
        for mesh_name, mesh in meshes:
            try:
                r = dryrun_cell(arch_id, shape_name, mesh, mesh_name,
                                variant=args.variant)
                results.append(r)
                if args.out:
                    with open(args.out, "a") as f:
                        f.write(json.dumps(r) + "\n")
            except Exception as e:  # noqa: BLE001 — report all cell failures
                traceback.print_exc()
                failures.append((arch_id, shape_name, mesh_name, repr(e)))

    print(f"\n[dryrun] {len(results)} cells OK, {len(failures)} failed")
    for f in failures:
        print("  FAIL:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
