"""Production mesh definitions.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
dryrun.py forces 512 host devices in its own process).

Mesh shapes (TPU v5e pods of 256):
  single pod:  (data=16, model=16)
  multi-pod:   (pod=2, data=16, model=16)  — 512 chips

Axis roles:
  'pod'    outermost data parallelism; gradient all-reduce crosses DCI —
           the axis the int8-EF compression targets
  'data'   in-pod data parallel + FSDP/ZeRO param sharding (>=70B archs)
  'model'  tensor/expert parallel: heads, d_ff, experts, vocab
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    need = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) != need:
        # dry-run process forces 512 host devices; the single-pod mesh uses
        # the first 256 of them
        return jax.make_mesh(shape, axes, devices=devs[:need])
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    return jax.make_mesh(shape, axes)


def batch_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the global batch shards over."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_batch_shards(mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n
