"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, zero allocation — exactly what
``jax.jit(...).lower(**input_specs(...))`` needs.  This module also owns
the per-(arch, shape, mesh) config adaptation: batch/sequence sharding
axes, activation sharding, grad-accum factor, optimizer dtype policy.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, get_arch
from repro.configs.common import ShapeSpec
from repro.launch import mesh as mesh_mod
from repro.models import layers, model as model_mod
from repro.models.model import ModelConfig
from repro.train.optimizer import OptConfig

# per-arch optimizer dtype policy (DESIGN.md §5)
OPT_POLICY: Dict[str, str] = {
    "command-r-plus-104b": "bf16_mom",
    "internvl2-76b": "bf16_mom",
    "jamba-v0.1-52b": "bf16_mom",
    "qwen3-moe-235b-a22b": "pure_bf16",
    "llama4-maverick-400b-a17b": "pure_bf16",
}

# microbatch accumulation for train_4k (activation-memory control)
GRAD_ACCUM: Dict[str, int] = {
    "command-r-plus-104b": 4,
    "internvl2-76b": 4,
    "qwen3-moe-235b-a22b": 4,
    "llama4-maverick-400b-a17b": 4,
    "jamba-v0.1-52b": 2,
}


def adapt_config(arch: ArchSpec, shape: ShapeSpec, mesh: Mesh) -> ModelConfig:
    """Mesh/shape-aware copy of the full config."""
    cfg = arch.config
    baxes = mesh_mod.batch_axes(mesh)
    n_b = mesh_mod.n_batch_shards(mesh)
    kw: Dict[str, Any] = {}
    if shape.kind == "train":
        kw["batch_axes"] = baxes
        kw["shard_activations"] = True
        kw["remat"] = True
    elif shape.kind in ("prefill", "encode"):
        kw["batch_axes"] = baxes if shape.global_batch % n_b == 0 else ()
        kw["shard_activations"] = shape.global_batch % n_b == 0
        kw["remat"] = False
    else:  # decode
        kw["remat"] = False
        kw["shard_activations"] = False
        if shape.global_batch % n_b == 0:
            kw["batch_axes"] = baxes
            kw["seq_axes"] = ("model",)
        else:  # long_500k batch 1: flash-decoding over the whole mesh
            kw["batch_axes"] = ()
            kw["seq_axes"] = tuple(mesh.axis_names)
    return dataclasses.replace(cfg, **kw)


def opt_config(arch_id: str, total_steps: int = 10000) -> OptConfig:
    return OptConfig(policy=OPT_POLICY.get(arch_id, "fp32"),
                     total_steps=total_steps)


def grad_accum(arch_id: str, shape: ShapeSpec) -> int:
    if shape.kind != "train":
        return 1
    return GRAD_ACCUM.get(arch_id, 1)


# --------------------------------------------------------------------------
# ShapeDtypeStruct builders
# --------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def param_structs(cfg: ModelConfig, mesh: Mesh):
    return layers.shape_tree(model_mod.build_template(cfg), mesh)


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    return layers.sharding_tree(model_mod.build_template(cfg), mesh)


def opt_structs(cfg: ModelConfig, ocfg: OptConfig, mesh: Mesh):
    """OptState ShapeDtypeStructs congruent with the params tree."""
    from repro.train.optimizer import _POLICIES, OptState
    mdt, sdt = _POLICIES[ocfg.policy]
    tmpl = model_mod.build_template(cfg)

    def of(dt):
        return jax.tree.map(
            lambda ps: _sds(ps.shape, dt, mesh, ps.spec), tmpl,
            is_leaf=lambda x: isinstance(x, layers.ParamSpec))

    return OptState(step=_sds((), jnp.int32, mesh, P()),
                    master=of(mdt), m=of(sdt), v=of(sdt))


def batch_structs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """Training batch {"inputs", "labels", "mask"}."""
    b, t = shape.global_batch, shape.seq_len
    bspec = cfg.batch_axes or None
    if cfg.input_kind == "tokens":
        inputs = _sds((b, t), jnp.int32, mesh, P(bspec, None))
    else:
        inputs = _sds((b, t, cfg.d_frontend), jnp.bfloat16,
                      mesh, P(bspec, None, None))
    return {
        "inputs": inputs,
        "labels": _sds((b, t), jnp.int32, mesh, P(bspec, None)),
        "mask": _sds((b, t), jnp.float32, mesh, P(bspec, None)),
    }


def prefill_structs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    b, t = shape.global_batch, shape.seq_len
    bspec = cfg.batch_axes or None
    if cfg.input_kind == "tokens":
        return _sds((b, t), jnp.int32, mesh, P(bspec, None))
    return _sds((b, t, cfg.d_frontend), jnp.bfloat16, mesh,
                P(bspec, None, None))


def cache_structs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    """Decode cache ShapeDtypeStructs with flash-decoding shardings."""
    b, s = shape.global_batch, shape.seq_len
    tree = model_mod.cache_struct(cfg, b, s)
    bspec = cfg.batch_axes or None
    sspec = cfg.seq_axes or None

    def one(sd: jax.ShapeDtypeStruct):
        nd = len(sd.shape)
        # kv caches: (..., B, S, Hk, D)
        if nd >= 4 and sd.shape[-1] == cfg.head_dim \
                and sd.shape[-2] == cfg.n_kv_heads and sd.shape[-3] == s:
            lead = (None,) * (nd - 4)
            return _sds(sd.shape, sd.dtype, mesh,
                        P(*lead, bspec, sspec, None, None))
        # O(1) recurrent states: shard batch if possible, else replicate
        spec = [None] * nd
        # batch dim position: stacked states carry it at axis 1, tail at 0
        if bspec is not None and b > 1:
            for cand in (0, 1):
                if cand < nd and sd.shape[cand] == b:
                    spec[cand] = bspec
                    break
        return _sds(sd.shape, sd.dtype, mesh, P(*spec))

    return jax.tree.map(one, tree)


def decode_token_structs(cfg: ModelConfig, shape: ShapeSpec, mesh: Mesh):
    b = shape.global_batch
    bspec = cfg.batch_axes or None
    if cfg.input_kind == "tokens":
        return _sds((b, 1), jnp.int32, mesh, P(bspec, None))
    return _sds((b, 1, cfg.d_frontend), jnp.bfloat16, mesh,
                P(bspec, None, None))


def input_specs(arch_id: str, shape_name: str, mesh: Mesh,
                overrides: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Everything needed to lower the cell's step function.

    ``overrides``: ModelConfig field overrides (perf-variant lowering,
    e.g. {"kv_cache_dtype": "int8"}).
    Returns {"kind", "cfg", "args": tuple of ShapeDtypeStructs, ...}.
    """
    arch = get_arch(arch_id)
    shape = arch.shape(shape_name)
    cfg = adapt_config(arch, shape, mesh)
    accum_override = None
    if overrides:
        overrides = dict(overrides)
        accum_override = overrides.pop("grad_accum", None)
        cfg = dataclasses.replace(cfg, **overrides)
    out: Dict[str, Any] = {"kind": shape.kind, "cfg": cfg, "shape": shape}
    params = param_structs(cfg, mesh)
    if shape.kind == "train":
        ocfg = opt_config(arch_id)
        out["opt_cfg"] = ocfg
        out["grad_accum"] = accum_override or grad_accum(arch_id, shape)
        out["args"] = (params, opt_structs(cfg, ocfg, mesh),
                       batch_structs(cfg, shape, mesh))
    elif shape.kind in ("prefill", "encode"):
        out["args"] = (params, prefill_structs(cfg, shape, mesh))
    else:
        out["args"] = (params, decode_token_structs(cfg, shape, mesh),
                       cache_structs(cfg, shape, mesh),
                       jax.ShapeDtypeStruct((), jnp.int32))
    return out
