"""liquidSVM-style command line: the staged cycle as separate processes.

The package ships ``svm-train`` / ``svm-select`` / ``svm-test`` binaries
that communicate through files, so selection can be re-run (new NPL
constraint, ROC front, plain argmin) without repeating the expensive
training sweep.  This is the same cycle over the staged session API:

    python -m repro.cli train  --data xtr.npy --labels ytr.npy \\
        --model-dir run1 --scenario binary -S FOLDS=3 -S VORONOI=voronoi
    python -m repro.cli select --model-dir run1 --rule npl -S NPL_CONSTRAINT=0.01
    python -m repro.cli select --model-dir run1 --rule roc      # no retrain
    python -m repro.cli test   --data xte.npy --labels yte.npy --model-dir run1
    python -m repro.cli serve  --data xq.npy --model-dir run1 \\
        -S DEADLINE_MS=5 --out pred.npy     # async engine from bank/ alone

Token corpora get one extra stage in front — the frozen-backbone
embedding pipeline (``repro.embed``):

    python -m repro.cli embed  --tokens tok.npy --model-dir run1 \\
        -S EMBED_ARCH=stablelm-1.6b:smoke -S EMBED_POOL=mean
    python -m repro.cli train  --data run1/embed --labels y.npy ...
    python -m repro.cli serve  --tokens tokq.npy --model-dir run1 ...

Artifacts under ``--model-dir`` (all ``repro.train.checkpoint`` step dirs
except ``embed/``, which is an ``EmbedCache`` shard directory):

    embed/   EmbedCache    — fingerprinted npz embedding shards + meta.json
             (``--data <model-dir>/embed`` streams them; ``serve --tokens``
             rebuilds the recorded extractor for in-process embedding)
    train/   TrainResult  — cell models + retained CV surface
    select/  SelectResult — final models, rule extras, stats
    bank/    ModelBank    — compacted serving bank; a predict server
             cold-starts from it alone:
             ``SVMEngine(ModelBank.load(f"{model_dir}/bank"))``

``--data`` accepts an ``.npy`` file (opened as a memmap — training and
testing stream, the array is never resident), a comma-separated list of
``.npz`` shards, or a completed ``embed/`` artifact directory; ``--labels``
is an ``.npy`` vector.  ``-S KEY=VALUE`` sets any string config key
(``--help-keys`` lists them).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
from typing import List, Optional

import numpy as np

# scenario aliases: front-end names -> trainer scenarios (+ default rule)
_SCENARIOS = {
    "binary": "binary", "ova": "ova", "ava": "ava", "mc": "ova",
    "weighted": "weighted", "roc": "weighted", "npl": "npsvm",
    "npsvm": "npsvm", "quantile": "quantile", "qt": "quantile",
    "expectile": "expectile", "ex": "expectile", "ls": "ls",
}
_SCENARIO_RULES = {"roc": "roc", "npl": "npl", "npsvm": "npl"}


def _load_data(spec: str):
    """'.npy' path (memmap-streamed), comma-separated '.npz' shards, or a
    completed ``embed/`` cache directory (replayed shard-by-shard)."""
    from repro.pipeline.dataset import as_source
    if os.path.isdir(spec):
        return _open_embed_artifact(spec)
    if "," in spec:
        return as_source([p for p in spec.split(",") if p])
    return as_source(spec)


def _open_embed_artifact(path: str):
    """A directory as ``--data``: it must be a COMPLETE embed cache."""
    from repro.embed.source import EmbedCache, EmbedCacheError
    from repro.pipeline.dataset import ShardedNpzSource
    try:
        meta = EmbedCache.open(path)
    except EmbedCacheError as e:
        _fail(f"{e} — run `python -m repro.cli embed` to produce one")
    cache = EmbedCache(path, meta["fingerprint"], n_rows=meta["n_rows"],
                       dim=meta["dim"], block=meta["block"],
                       seq_len=meta["seq_len"])
    if not cache.complete():
        _fail(f"{path}: incomplete 'embed/' artifact (missing shards) — "
              f"re-run `python -m repro.cli embed`")
    return ShardedNpzSource(cache.shard_paths())


def _parse_sets(pairs: Optional[List[str]]) -> dict:
    out = {}
    for p in pairs or []:
        if "=" not in p:
            raise SystemExit(f"-S expects KEY=VALUE, got {p!r}")
        k, v = p.split("=", 1)
        out[k] = v
    return out


def _emit(payload: dict) -> None:
    json.dump(payload, sys.stdout, indent=2, default=float)
    sys.stdout.write("\n")


def _setup_obs(pairs: dict) -> dict:
    """Split TRACE/METRICS_OUT/PROFILE_DIR off a ``-S`` key dict and apply
    them to the process-global ``repro.obs`` instruments; returns the
    remaining pairs for the stage's own key handling."""
    from repro.api.config import split_obs_keys
    rest, obs_kw = split_obs_keys(pairs)
    if obs_kw:
        from repro import obs
        obs.configure(**obs_kw)
    return rest


def _finish_obs(payload: dict) -> dict:
    """Fold observability output into a stage's JSON payload.

    Always surfaces restore fallbacks and corrupt-wave re-solves (silent
    degradation an operator must see, satellite of PR 7); writes the
    metrics JSONL when ``METRICS_OUT`` was configured and the per-site
    span summary when ``TRACE`` was on.
    """
    from repro import obs
    from repro.train.checkpoint import fallback_log
    fl = fallback_log()
    payload["checkpoint_fallbacks"] = len(fl)
    if fl:
        payload["checkpoint_fallback_steps"] = [list(x) for x in fl]
    summary = obs.metrics.summary()
    corrupt = summary.get("train.corrupt_waves", 0)
    if corrupt:
        payload["corrupt_waves_resolved"] = int(corrupt)
    out = obs.flush_metrics(extra={"stage": payload.get("stage")})
    if out:
        payload["metrics_out"] = out
    if obs.tracer.enabled:
        payload["trace"] = obs.tracer.summary()
    tout = obs.flush_trace()
    if tout:
        payload["trace_out"] = tout
    return payload


def _fail(msg: str) -> "SystemExit":
    """Actionable operator error -> stderr + exit code 2 (not a traceback)."""
    print(f"error: {msg}", file=sys.stderr)
    raise SystemExit(2)


def _load_artifact(model_dir: str, name: str, loader, produced_by: str):
    """Load a staged artifact dir with actionable failure messages.

    Missing, incomplete (no checkpoint step survived) and corrupt
    (checksum/manifest verification failed) dirs all exit with code 2 and
    say which stage to (re-)run, instead of surfacing a raw traceback.
    """
    from repro.train.checkpoint import CheckpointCorruptError

    path = os.path.join(model_dir, name)
    hint = f"run `python -m repro.cli {produced_by}` first"
    if not os.path.isdir(path):
        _fail(f"{path}: missing '{name}/' artifact — {hint}")
    try:
        return loader(path)
    except FileNotFoundError as e:
        _fail(f"{path}: incomplete '{name}/' artifact ({e}) — {hint}")
    except CheckpointCorruptError as e:
        _fail(f"{path}: corrupt '{name}/' artifact ({e}) — re-{hint}")
    except ValueError as e:
        _fail(f"{path}: not a valid '{name}/' artifact ({e}) — {hint}")


# ------------------------------------------------------------------ embed
def cmd_embed(args) -> int:
    """Run the frozen-backbone embedding stage over a token corpus and
    persist the cache directory as the ``embed/`` stage artifact.

    ``--tokens`` is an ``(n, seq_len)`` int ``.npy`` (memmap-streamed; or
    ``(n, seq_len, d_frontend)`` floats for embed-frontend configs);
    ``-S EMBED_ARCH=<id>[:smoke]`` picks the backbone, ``EMBED_POOL`` the
    pooling, ``EMBED_BATCH`` the fixed jit batch shape, ``EMBED_SEED`` the
    deterministic frozen-init seed.  The output is write-through and
    crash-safe: re-running after an interruption computes only the missing
    shards, re-running after a config change rebuilds the artifact under
    the new fingerprint.  Downstream: ``train --data <model-dir>/embed``
    streams the shards, ``serve --tokens`` rebuilds the recorded extractor.
    """
    import shutil

    from repro.api.config import split_embed_keys
    from repro.embed import EmbeddingExtractor, EmbeddingSource, resolve_arch
    from repro.embed.source import EmbedCache, EmbedCacheError, \
        TokenArraySource

    leftover, emb_kw = split_embed_keys(_setup_obs(_parse_sets(args.set)))
    if leftover:
        raise SystemExit(f"embed only takes the EMBED_* keys and the "
                         f"observability keys, got {sorted(leftover)}")
    if "arch" not in emb_kw:
        _fail("embed requires -S EMBED_ARCH=<arch-id>[:smoke] "
              "(see repro.configs.ARCH_IDS)")
    emb_kw.pop("cache_dir", None)   # the artifact location is --model-dir
    arch = emb_kw.pop("arch")
    tok = TokenArraySource(args.tokens)
    ex = EmbeddingExtractor(resolve_arch(arch), **emb_kw)
    out_dir = os.path.join(args.model_dir, "embed")
    fp = ex.fingerprint(tok.seq_len)
    ident = dict(n_rows=tok.n_rows, dim=ex.dim, block=ex.batch_size,
                 seq_len=tok.seq_len,
                 extra={"arch": arch, "pooling": ex.pooling,
                        "seed": ex.seed})
    rebuilt = False
    try:
        cache = EmbedCache(out_dir, fp, **ident)
    except EmbedCacheError:
        # different corpus/arch/pooling than the previous run: the stage
        # artifact is being re-produced, like re-running train over it
        shutil.rmtree(out_dir)
        cache = EmbedCache(out_dir, fp, **ident)
        rebuilt = True
    src = EmbeddingSource(tok, ex, cache=cache)
    already = src.cache_complete()
    for _ in src.iter_chunks(args.chunk_size or 4096):
        pass                        # drive the write-through pass
    assert src.cache_complete()
    _emit(_finish_obs(
        {"stage": "embed", "n": src.n_rows, "d": src.dim,
         "seq_len": tok.seq_len, "arch": arch, "pooling": ex.pooling,
         "fingerprint": fp, "shards": cache.n_blocks,
         "cache_hit": bool(already), "rebuilt": rebuilt,
         "cache_dir": out_dir, "model_dir": args.model_dir}))
    return 0


# ------------------------------------------------------------------ train
def cmd_train(args) -> int:
    from repro.api.config import apply_keys
    from repro.api.session import SVM
    from repro.train.svm_trainer import SVMTrainerConfig

    from repro.api.config import weight_grid

    scenario = _SCENARIOS[args.scenario]
    cfg, select_params = apply_keys(
        SVMTrainerConfig(scenario=scenario), _setup_obs(_parse_sets(args.set)))
    if cfg.weights == (1.0,):
        # npl/roc are weight-sweep scenarios: without an explicit
        # WEIGHTS/MIN_WEIGHT/... key, give them the front-ends' default
        # grids rather than a degenerate single-weight axis
        if args.scenario == "npl" or scenario == "npsvm":
            cfg = dataclasses.replace(cfg, weights=weight_grid(0.25, 4.0, 5))
        elif args.scenario == "roc":
            cfg = dataclasses.replace(cfg,
                                      weights=weight_grid(1.0 / 9.0, 9.0, 9))
    x = _load_data(args.data)
    y = np.load(args.labels)

    sess = SVM(x, y, config=cfg,
               select_rule=_SCENARIO_RULES.get(args.scenario),
               select_kwargs=select_params)
    ckpt = os.path.join(args.model_dir, "waves") if args.resumable else None
    tr = sess.train(ckpt_dir=ckpt)
    tr.save(os.path.join(args.model_dir, "train"))
    # stage hand-off for select: the scenario's default rule + key params
    with open(os.path.join(args.model_dir, "session.json"), "w") as f:
        json.dump({"select_rule": sess.select_rule,
                   "select_kwargs": sess.select_kwargs}, f)
    _emit(_finish_obs(
        {"stage": "train", "n": tr.n, "d": tr.d,
         "cells": tr.plan.n_cells, "slots": tr.packed.n_slots,
         "grid": {"gammas": int(tr.gammas_cells.shape[1]),
                  "lambdas": int(tr.lambdas.shape[0]),
                  "tasks": int(tr.tasks.n_tasks),
                  "sub": int(tr.gamma.shape[2])},
         "model_dir": args.model_dir}))
    return 0


# ----------------------------------------------------------------- select
def cmd_select(args) -> int:
    from repro.api.config import parse_keys
    from repro.api.session import TrainResult

    tr = _load_artifact(args.model_dir, "train", TrainResult.load,
                        f"train --data ... --labels ... "
                        f"--model-dir {args.model_dir}")
    rule, kwargs = None, {}
    sess_path = os.path.join(args.model_dir, "session.json")
    if os.path.exists(sess_path):
        with open(sess_path) as f:
            saved = json.load(f)
        rule, kwargs = saved.get("select_rule"), saved.get("select_kwargs", {})
    if args.rule:
        rule = args.rule
    keys = parse_keys(_parse_sets(args.set))
    if "NPL_CONSTRAINT" in keys:
        kwargs["alpha"] = keys.pop("NPL_CONSTRAINT")
    if "NPL_CLASS" in keys:
        kwargs["npl_class"] = keys.pop("NPL_CLASS")
    if keys:
        raise SystemExit(f"select only takes NPL_CONSTRAINT/NPL_CLASS keys, "
                         f"got {sorted(keys)}")

    sel = tr.select(rule, **kwargs)
    # the staged cell rows already live in train/ next door — reference,
    # don't re-write, the O(n·d) arrays on every re-selection
    sel.save(os.path.join(args.model_dir, "select"),
             train_ref=os.path.join("..", "train"))
    bank = sel.to_bank()
    bank.save(os.path.join(args.model_dir, "bank"))
    payload = {"stage": "select", "rule": sel.rule, "stats": sel.stats,
               "bank": bank.stats(), "model_dir": args.model_dir}
    for k in ("np_fa", "np_det", "np_weight_idx", "roc_front"):
        if k in sel.extras:
            payload[k] = np.asarray(sel.extras[k]).tolist()
    _emit(payload)
    return 0


# ------------------------------------------------------------------- test
def cmd_test(args) -> int:
    from repro.api.session import SelectResult

    sel = _load_artifact(args.model_dir, "select", SelectResult.load,
                         f"select --model-dir {args.model_dir}")
    x = _load_data(args.data)
    y = np.load(args.labels)
    res = sel.test(x, y, chunk_size=args.chunk_size)
    _emit({"stage": "test", "rule": sel.rule, "error": res.error,
           "n": res.n, **res.details})
    return 0


# ------------------------------------------------------------------ serve
def cmd_serve(args) -> int:
    """Cold-start the engine from ``bank/`` and serve ``--data`` through
    the latency-bounded async stepper.

    The bank's recorded routing mode (overlap for VORONOI=5 fits) applies
    unless overridden with ``-S SERVE_OVERLAP=...``; ``-S DEADLINE_MS=...``
    bounds queueing latency; ``-S MAX_QUEUE=...`` bounds admission (overflow
    batches are shed, not queued).  ``--out`` writes predicted labels.

    ``--swap-watch`` polls ``bank/`` every ``SWAP_POLL_MS`` (default 500)
    between arrival bursts; when a STRICTLY newer bank version appears
    (``select`` re-run, or an incremental ``repro.serve.refresh`` write),
    it is hot-swapped mid-traffic — in-flight waves finish on the old
    version, later admissions serve the new one.  A bank dir caught
    mid-write is skipped and retried at the next poll.

    Monitor keys (``-S SLO_P99_MS=... / DRIFT_WINDOW=... /
    DRIFT_REFRESH_THRESHOLD=...``) attach a
    :class:`repro.serve.HealthMonitor`; the final payload then carries a
    ``health`` verdict.  With ``--swap-watch`` AND a labelled feedback pool
    (``--feedback-data``/``--feedback-labels``) the loop CLOSES: a cell
    whose drift score crosses ``DRIFT_REFRESH_THRESHOLD`` triggers a
    targeted ``refresh_drifted`` (only the drifted cells re-solve), the
    bumped bank is written to ``bank/`` and hot-swapped mid-traffic, and
    each trigger is traced (``serve.drift_refresh``) and counted
    (``serve.drift_refreshes``).  Closing the loop needs the ``train/``
    and ``select/`` artifacts next to ``bank/``.
    """
    from repro.api.config import split_monitor_keys, split_serve_keys
    from repro.serve.model_bank import ModelBank
    from repro.serve.svm_engine import SVMEngine
    from repro.train import checkpoint as ckpt_mod
    from repro.tasks.builder import combine_decisions
    from repro import obs
    import time as _time

    leftover, mon_kw = split_monitor_keys(_setup_obs(_parse_sets(args.set)))
    leftover, serve_kw = split_serve_keys(leftover)
    if leftover:
        raise SystemExit(f"serve only takes SERVE_OVERLAP/DEADLINE_MS/"
                         f"MAX_QUEUE/SWAP_POLL_MS, the monitor keys "
                         f"(SLO_P99_MS/DRIFT_WINDOW/DRIFT_REFRESH_THRESHOLD) "
                         f"and the observability keys (TRACE/TRACE_OUT/"
                         f"METRICS_OUT/PROFILE_DIR), got {sorted(leftover)}")
    if (args.feedback_data is None) != (args.feedback_labels is None):
        _fail("--feedback-data and --feedback-labels go together")
    if (args.data is None) == (args.tokens is None):
        _fail("serve takes exactly one of --data (feature space) or "
              "--tokens (token space, in-process embedding)")
    bank_dir = os.path.join(args.model_dir, "bank")
    bank = _load_artifact(args.model_dir, "bank", ModelBank.load,
                          f"select --model-dir {args.model_dir}")
    eng = SVMEngine(bank, **serve_kw)

    # token-space serving: rebuild the extractor the embed stage recorded
    # and co-locate it with the engine (EmbedServe); the per-request
    # breakdown then carries the embed_ms stage and the monitor's drift
    # scores watch embedding-space routing distances
    serve_obj, tok, src = eng, None, None
    if args.tokens is not None:
        from repro.embed import EmbeddingExtractor, resolve_arch
        from repro.embed.source import EmbedCache, EmbedCacheError, \
            TokenArraySource
        from repro.serve.embed_engine import EmbedServe
        embed_dir = os.path.join(args.model_dir, "embed")
        try:
            emeta = EmbedCache.open(embed_dir)
        except EmbedCacheError as e:
            _fail(f"{e} — `serve --tokens` needs the embed/ artifact; run "
                  f"`python -m repro.cli embed --model-dir "
                  f"{args.model_dir}` first")
        ex = EmbeddingExtractor(resolve_arch(emeta["arch"]),
                                pooling=emeta["pooling"],
                                batch_size=emeta["block"],
                                seed=emeta["seed"])
        tok = TokenArraySource(args.tokens)
        serve_obj = EmbedServe(eng, ex)
    else:
        src = _load_data(args.data)

    mon = None
    if mon_kw or args.feedback_data is not None:
        from repro.serve.monitor import HealthMonitor
        mon = HealthMonitor(eng, **mon_kw)

    # the refresh half of the closed loop: needs the fit context (train/,
    # select/) and a labelled feedback pool to re-solve drifted cells from
    tr = sel = x_feed = y_feed = None
    if args.feedback_data is not None:
        if not args.swap_watch:
            _fail("--feedback-data closes the drift->refresh loop; it "
                  "requires --swap-watch")
        from repro.api.session import SelectResult, TrainResult
        tr = _load_artifact(args.model_dir, "train", TrainResult.load,
                            f"train --model-dir {args.model_dir}")
        sel = _load_artifact(args.model_dir, "select", SelectResult.load,
                             f"select --model-dir {args.model_dir}")
        x_feed = _load_data(args.feedback_data).materialize()
        y_feed = np.load(args.feedback_labels)
        if x_feed.shape[0] != y_feed.shape[0]:
            _fail(f"feedback rows mismatch: {x_feed.shape[0]} data vs "
                  f"{y_feed.shape[0]} labels")

    poll_ms = serve_kw.get("swap_poll_ms") or 500.0
    swaps_seen = {"polls": 0}
    triggers: List[dict] = []
    refreshed_slots: set = set()

    def _maybe_swap(last_poll: list) -> None:
        now = _time.monotonic()
        if (now - last_poll[0]) * 1e3 < poll_ms:
            return
        last_poll[0] = now
        swaps_seen["polls"] += 1
        try:
            extra = ckpt_mod.peek_manifest(bank_dir)["extra"]
            if int(extra.get("version", 0)) > int(eng.bank.version):
                eng.swap_bank(ModelBank.load(bank_dir))
        except (ckpt_mod.CheckpointCorruptError, FileNotFoundError,
                OSError, ValueError):
            pass                   # mid-write / torn bank: retry next poll

    def _maybe_refresh() -> None:
        """Drift crossed the threshold -> refresh ONLY those cells, write
        the bumped bank and hot-swap it under the live traffic."""
        from repro.serve.refresh import refresh_drifted
        drifted = [c for c in mon.drifted_cells() if c not in refreshed_slots]
        if not drifted:
            return
        refreshed_slots.update(drifted)   # one shot per slot per run
        with obs.tracer.span("serve.drift_refresh") as sp:
            sp.set(cells=len(drifted))
            bank1, info = refresh_drifted(tr, sel, x_feed, y_feed, drifted,
                                          base_version=eng.bank.version)
        rec = {"cells": drifted, "scores": mon.drift_scores(), **info}
        if bank1 is not None:
            bank1.save(bank_dir, step=bank1.version)
            eng.swap_bank(bank1)
            obs.metrics.counter("serve.drift_refreshes").inc()
            mon.reset_cells(drifted)
            rec["version"] = bank1.version
        triggers.append(rec)

    def arrivals():
        if src is not None:
            for _, chunk in src.iter_chunks(args.wave):
                yield chunk
        else:
            for lo in range(0, tok.n_rows, args.wave):
                yield tok.rows(lo, min(lo + args.wave, tok.n_rows))

    def traffic():
        last_poll = [float("-inf")]
        for chunk in arrivals():
            if args.swap_watch:
                _maybe_swap(last_poll)
            if tr is not None:
                _maybe_refresh()
            yield chunk

    n_in = int(src.n_rows if src is not None else tok.n_rows)
    t0 = _time.time()
    results = (serve_obj.run_tokens(traffic()) if tok is not None
               else eng.run(traffic()))
    dt = _time.time() - t0
    dec = (np.stack([results[i] for i in sorted(results)]) if results
           else np.zeros((0, bank.n_tasks, bank.n_sub), np.float32))
    pred = combine_decisions(dec, bank.scenario, classes=bank.classes,
                             pairs=bank.pairs, sub=bank.default_sub)
    if args.out:
        np.save(args.out, pred)
    stats = serve_obj.stats()
    payload = {"stage": "serve", "n": n_in,
               "rps": n_in / max(dt, 1e-9),
               "routing": stats["routing"],
               "deadline_ms": serve_kw.get("deadline_ms"),
               "waves": stats.get("waves", 0),
               "occupancy_mean": stats.get("occupancy_mean"),
               "age_ms_max": stats.get("age_ms_max"),
               "per_stage": stats["per_stage"],
               "bank_version": stats["bank_version"],
               "swaps": stats["swaps"],
               "swap_requeued": stats["swap_requeued"],
               "shed_rows": stats["shed_rows"],
               "swap_polls": swaps_seen["polls"],
               "out": args.out, "model_dir": args.model_dir}
    if mon is not None:
        payload["health"] = mon.health()
        payload["drift_triggers"] = triggers
    _emit(_finish_obs(payload))
    return 0


# ------------------------------------------------------------------- main
def _build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="python -m repro.cli",
        description="staged liquidSVM cycle: train -> select -> test")
    sub = p.add_subparsers(dest="cmd", required=True)

    bp = sub.add_parser("embed", help="frozen-backbone embedding stage: "
                                      "token corpus -> embed/ cache artifact")
    bp.add_argument("--tokens", required=True,
                    help="(n, seq_len) int .npy token corpus "
                         "(memmap-streamed)")
    bp.add_argument("--model-dir", required=True)
    bp.add_argument("--chunk-size", type=int, default=None,
                    help="rows per driving chunk (default 4096)")
    bp.add_argument("-S", "--set", action="append", metavar="KEY=VALUE",
                    help="EMBED_ARCH (required) / EMBED_POOL / EMBED_BATCH "
                         "/ EMBED_SEED + observability keys")
    bp.set_defaults(fn=cmd_embed)

    tp = sub.add_parser("train", help="solve the fold x grid, keep the "
                                      "CV surface")
    tp.add_argument("--data", required=True,
                    help=".npy path (memmap-streamed) or .npz shard list")
    tp.add_argument("--labels", required=True, help=".npy label vector")
    tp.add_argument("--model-dir", required=True)
    tp.add_argument("--scenario", default="binary",
                    choices=sorted(_SCENARIOS))
    tp.add_argument("-S", "--set", action="append", metavar="KEY=VALUE",
                    help="string config key (repeatable); --help-keys lists")
    tp.add_argument("--resumable", action="store_true",
                    help="per-wave checkpointing under <model-dir>/waves")
    tp.set_defaults(fn=cmd_train)

    sp = sub.add_parser("select", help="(re-)pick hyper-parameters over the "
                                       "retained surface; writes the bank")
    sp.add_argument("--model-dir", required=True)
    sp.add_argument("--rule", default=None,
                    help="argmin|npl|roc|quantile|expectile "
                         "(default: the trained scenario's rule)")
    sp.add_argument("-S", "--set", action="append", metavar="KEY=VALUE",
                    help="NPL_CONSTRAINT / NPL_CLASS")
    sp.set_defaults(fn=cmd_select)

    ep = sub.add_parser("test", help="stream the scenario error")
    ep.add_argument("--data", required=True)
    ep.add_argument("--labels", required=True)
    ep.add_argument("--model-dir", required=True)
    ep.add_argument("--chunk-size", type=int, default=None)
    ep.set_defaults(fn=cmd_test)

    vp = sub.add_parser("serve", help="cold-start the engine from bank/ and "
                                      "serve --data (async, latency-bounded)")
    vp.add_argument("--data", default=None,
                    help="feature-space queries (.npy / .npz shards / "
                         "embed/ dir)")
    vp.add_argument("--tokens", default=None,
                    help="token-space queries (.npy): embed in-process via "
                         "the recorded embed/ extractor (EmbedServe)")
    vp.add_argument("--model-dir", required=True)
    vp.add_argument("--wave", type=int, default=256,
                    help="arrival burst size fed to the stepper")
    vp.add_argument("--out", default=None,
                    help="write predicted labels to this .npy")
    vp.add_argument("--swap-watch", action="store_true",
                    help="poll bank/ for newer versions and hot-swap "
                         "mid-traffic (interval: -S SWAP_POLL_MS)")
    vp.add_argument("--feedback-data", default=None,
                    help="labelled feedback pool: close the drift->refresh "
                         "loop (needs --swap-watch and train/+select/)")
    vp.add_argument("--feedback-labels", default=None,
                    help=".npy labels for --feedback-data")
    vp.add_argument("-S", "--set", action="append", metavar="KEY=VALUE",
                    help="SERVE_OVERLAP / DEADLINE_MS / MAX_QUEUE / "
                         "SWAP_POLL_MS / SLO_P99_MS / DRIFT_WINDOW / "
                         "DRIFT_REFRESH_THRESHOLD / TRACE / TRACE_OUT / "
                         "METRICS_OUT / PROFILE_DIR")
    vp.set_defaults(fn=cmd_serve)
    return p


def main(argv: Optional[List[str]] = None) -> int:
    argv = sys.argv[1:] if argv is None else list(argv)
    if "--help-keys" in argv:
        from repro.api.config import describe_keys
        print(describe_keys())
        return 0
    args = _build_parser().parse_args(argv)
    from repro.api.config import ConfigError
    from repro.embed.source import EmbedCacheError
    from repro.pipeline.dataset import DataSourceError
    from repro.train.checkpoint import CheckpointCorruptError
    try:
        return args.fn(args)
    except (ConfigError, DataSourceError, CheckpointCorruptError,
            EmbedCacheError) as e:
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
