"""hubert-xlarge — audio encoder-only [arXiv:2106.07447].

48L d_model=1280 16H (kv=16 MHA) d_ff=5120 vocab=504 (masked-prediction
codebook classes); head_dim 80.  Bidirectional attention, no RoPE (HuBERT
uses a conv positional frontend — stubbed with the frame embeddings).
Encoder-only => NO autoregressive decode => decode_32k / long_500k SKIPPED.
prefill_32k lowers `encode_step` (full-sequence logits).
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab=504,
    period_pattern=(("attn_bidir", "dense"),),
    rotary_frac=0.0,                      # conv-positional stub, no rope
    input_kind="embed", d_frontend=512,   # CNN feature-extractor output dim
    norm="layernorm", act="gelu",
)

SMOKE = ModelConfig(
    name="hubert-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=61,
    period_pattern=(("attn_bidir", "dense"),),
    rotary_frac=0.0, input_kind="embed", d_frontend=32,
    ce_chunk=16, attn_chunk=16,
    norm="layernorm", act="gelu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k"), encoder_only=True)
