"""stablelm-1.6b — dense, kv=32 => full MHA [hf:stabilityai/stablelm-2-1_6b].

24L d_model=2048 32H (GQA kv=32) d_ff=5632 vocab=100352; head_dim 64,
partial rotary 25%.  Pure full attention => `long_500k` SKIPPED.
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=5632, vocab=100352,
    period_pattern=(("attn", "dense"),),
    rotary_frac=0.25,
    norm="layernorm", act="silu",
)

SMOKE = ModelConfig(
    name="stablelm-1.6b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab=503,
    period_pattern=(("attn", "dense"),),
    rotary_frac=0.25, ce_chunk=16, attn_chunk=16,
    norm="layernorm", act="silu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k", "decode_32k"))
