"""jamba-v0.1-52b — hybrid Mamba + attention 1:7, MoE 16e top-2
[arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536; head_dim 128.
Period-8 Jamba block: 1 attention + 7 Mamba layers; MoE replaces the MLP
on every 2nd layer (odd positions).  Mamba: d_state=16, d_conv=4,
expand=2.  Mostly-SSM => `long_500k` RUNS (only 4/32 layers keep a KV
cache).  FSDP (52B).
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

_PERIOD = tuple(
    ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=65536,
    period_pattern=_PERIOD,
    n_experts=16, top_k=2, moe_d_ff=14336,
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2,
    rotary_frac=0.0,                      # Jamba uses no positional encoding
    norm="rmsnorm", act="silu",
    fsdp_params=True,
)

SMOKE = ModelConfig(
    name="jamba-smoke",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=503,
    period_pattern=tuple(
        ("attn" if i == 0 else "mamba", "moe" if i % 2 == 1 else "dense")
        for i in range(4)),
    n_experts=4, top_k=2, moe_d_ff=64, moe_chunk=64,
    ssm_d_state=4, ssm_d_conv=2, ssm_chunk=8, rotary_frac=0.0,
    ce_chunk=16, attn_chunk=16,
    norm="rmsnorm", act="silu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k", "decode_32k", "long_500k"))
