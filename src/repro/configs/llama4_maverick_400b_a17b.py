"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion
[hf:meta-llama/Llama-4-Maverick-17B-128E].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; head_dim 128.
Interleaved dense/MoE (period 2); MoE layers: 128 routed experts top-1
plus one always-on shared expert (the Maverick design).  Early-fusion
vision frontend is STUBBED (text tokens only; noted in DESIGN.md).
Pure full attention => `long_500k` SKIPPED.  FSDP (400B).
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=8192, vocab=202048,
    period_pattern=(("attn", "dense"), ("attn", "moe")),
    n_experts=128, top_k=1, moe_d_ff=8192, n_shared_experts=1,
    moe_capacity_factor=2.0,           # top-1 routing skews harder
    rope_theta=500000.0,
    norm="rmsnorm", act="silu",
    fsdp_params=True,
)

SMOKE = ModelConfig(
    name="llama4-smoke",
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=503,
    period_pattern=(("attn", "dense"), ("attn", "moe")),
    n_experts=8, top_k=1, moe_d_ff=64, n_shared_experts=1, moe_chunk=64,
    ce_chunk=16, attn_chunk=16,
    norm="rmsnorm", act="silu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k", "decode_32k"))
