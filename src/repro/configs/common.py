"""Shared config vocabulary for the assigned architectures.

Every ``src/repro/configs/<id>.py`` exports:
  CONFIG — the full-size ModelConfig (exact dims from the assignment)
  SMOKE  — a reduced same-family config for CPU forward/train smoke tests
  SHAPES — the input-shape cells this arch runs (skips documented in
           DESIGN.md §Arch-applicability)

Shape semantics (assignment):
  train_4k     seq 4096  x global_batch 256   -> train_step
  prefill_32k  seq 32768 x global_batch 32    -> prefill_step (encoder: encode)
  decode_32k   seq 32768 x global_batch 128   -> serve_step (1 token vs cache)
  long_500k    seq 524288 x global_batch 1    -> serve_step, sub-quadratic only
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

ALL_SHAPES: Dict[str, dict] = {
    "train_4k":    dict(kind="train",   seq_len=4_096,   global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32_768,  global_batch=32),
    "decode_32k":  dict(kind="decode",  seq_len=32_768,  global_batch=128),
    "long_500k":   dict(kind="decode",  seq_len=524_288, global_batch=1),
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str         # train | prefill | decode | encode
    seq_len: int
    global_batch: int


def shapes_for(names: Tuple[str, ...], encoder_only: bool = False
               ) -> Tuple[ShapeSpec, ...]:
    out = []
    for n in names:
        s = ALL_SHAPES[n]
        kind = s["kind"]
        if encoder_only and kind == "prefill":
            kind = "encode"
        out.append(ShapeSpec(name=n, kind=kind, seq_len=s["seq_len"],
                             global_batch=s["global_batch"]))
    return tuple(out)
