"""internvl2-76b — VLM: InternViT frontend + InternLM2 backbone
[arXiv:2404.16821].

Backbone only (assignment: "the modality frontend is a STUB"): 80L
d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256; head_dim 128.
``input_specs()`` supplies precomputed patch embeddings (d_frontend=4096,
the projector output width); the model linearly projects them to d_model.
Pure full attention => `long_500k` SKIPPED.  FSDP (>=70B).
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=28672, vocab=128256,
    period_pattern=(("attn", "dense"),),
    input_kind="embed", d_frontend=4096,
    norm="rmsnorm", act="silu",
    fsdp_params=True,
)

SMOKE = ModelConfig(
    name="internvl2-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=503,
    period_pattern=(("attn", "dense"),),
    input_kind="embed", d_frontend=32, ce_chunk=16, attn_chunk=16,
    norm="rmsnorm", act="silu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k", "decode_32k"))
