"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each entry maps the assignment's architecture id to its config module
(CONFIG full-size, SMOKE reduced, SHAPES runnable cells).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Tuple

from repro.configs.common import ShapeSpec
from repro.models.model import ModelConfig

_MODULES: Dict[str, str] = {
    "rwkv6-1.6b": "repro.configs.rwkv6_1_6b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "gemma3-4b": "repro.configs.gemma3_4b",
    "command-r-plus-104b": "repro.configs.command_r_plus_104b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "hubert-xlarge": "repro.configs.hubert_xlarge",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "jamba-v0.1-52b": "repro.configs.jamba_v01_52b",
}

ARCH_IDS: Tuple[str, ...] = tuple(_MODULES)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    config: ModelConfig
    smoke: ModelConfig
    shapes: Tuple[ShapeSpec, ...]

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} does not run shape {name!r} "
                       f"(available: {[s.name for s in self.shapes]})")


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(_MODULES[arch_id])
    return ArchSpec(arch_id=arch_id, config=mod.CONFIG, smoke=mod.SMOKE,
                    shapes=mod.SHAPES)


def all_cells() -> Tuple[Tuple[str, str], ...]:
    """Every runnable (arch, shape) pair — the dry-run/roofline matrix."""
    cells = []
    for aid in ARCH_IDS:
        for s in get_arch(aid).shapes:
            cells.append((aid, s.name))
    return tuple(cells)
