"""qwen3-moe-235b-a22b — MoE 128 experts top-8 [hf:Qwen/Qwen3-235B-A22B].

94L d_model=4096 64H (GQA kv=4) vocab=151936; head_dim 128, qk-norm.
Fine-grained experts: moe_d_ff=1536 per expert, every layer MoE, no shared
expert.  Experts sharded over 'model' (EP=16 -> 8 experts/device); FSDP.
Pure full attention => `long_500k` SKIPPED.
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, head_dim=128,
    d_ff=1536, vocab=151936,
    period_pattern=(("attn", "moe"),),
    qk_norm=True,
    n_experts=128, top_k=8, moe_d_ff=1536, n_shared_experts=0,
    norm="rmsnorm", act="silu",
    fsdp_params=True,
)

SMOKE = ModelConfig(
    name="qwen3-moe-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=32, vocab=503,
    period_pattern=(("attn", "moe"),),
    qk_norm=True, n_experts=8, top_k=2, moe_d_ff=32, moe_chunk=64,
    ce_chunk=16, attn_chunk=16,
    norm="rmsnorm", act="silu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k", "decode_32k"))
