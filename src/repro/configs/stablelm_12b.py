"""stablelm-12b — dense GQA [hf:stabilityai/stablelm-2-12b].

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352; head_dim 160,
partial rotary (25%).  Pure full attention => `long_500k` SKIPPED
(DESIGN.md §Arch-applicability).
"""
from repro.configs.common import shapes_for
from repro.models.model import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=160,
    d_ff=13824, vocab=100352,
    period_pattern=(("attn", "dense"),),
    rotary_frac=0.25, rope_theta=10000.0,
    norm="layernorm", act="silu",
)

SMOKE = ModelConfig(
    name="stablelm-12b-smoke",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab=503,
    period_pattern=(("attn", "dense"),),
    rotary_frac=0.25, ce_chunk=16, attn_chunk=16,
    norm="layernorm", act="silu", remat=False,
)

SHAPES = shapes_for(("train_4k", "prefill_32k", "decode_32k"))
